//! Quickstart: solve the paper's 1-D Cubic problem with the Queue-Lock
//! engine in ~30 lines.
//!
//!     cargo run --release --example quickstart

use cupso::engine::{Engine, ParallelSettings, QueueLockEngine};
use cupso::fitness::{Cubic, Fitness, Objective};
use cupso::pso::PsoParams;

fn main() {
    // The paper's §6.2 workload, scaled to a second or two of runtime.
    let params = PsoParams::paper_1d(/*particles=*/ 1024, /*iters=*/ 10_000);

    // Queue-Lock (Algorithm 2 + 3): the paper's fastest algorithm.
    let mut engine = QueueLockEngine::new(ParallelSettings::with_workers(0));
    let out = engine.run(&params, &Cubic, Objective::Maximize, /*seed=*/ 42);

    println!("gbest fitness : {:.6}", out.gbest_fit);
    println!("gbest position: {:.6}", out.gbest_pos[0]);
    println!("known optimum : {:.6} at x = 100", Cubic.optimum(1).unwrap());
    println!(
        "improvement rarity: {:.5}% of {} particle updates pushed to a queue",
        100.0 * out.counters.queue_push_rate(),
        out.counters.particle_updates,
    );

    assert!(out.gbest_fit > 899_999.0, "should solve 1-D cubic exactly");
    println!("OK");
}
