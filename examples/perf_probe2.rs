// perf probe 2: Plane-A queue engine per-dim cost at large n (striding test)
use cupso::engine::{Engine, ParallelSettings, QueueEngine};
use cupso::fitness::{Cubic, Objective};
use cupso::pso::PsoParams;
use std::time::Instant;

fn main() {
    for (n, d, iters) in [(65536usize, 120usize, 10u64), (8192, 120, 50), (65536, 1, 2000)] {
        let params = PsoParams { dim: d, ..PsoParams::paper_1d(n, iters) };
        let mut e = QueueEngine::new(ParallelSettings::with_workers(0));
        let t = Instant::now();
        let out = e.run(&params, &Cubic, Objective::Maximize, 42);
        let s = t.elapsed().as_secs_f64();
        let per = s / (n as f64 * iters as f64 * d as f64);
        println!("queue n={n} d={d} iters={iters}: {:.3}s, {:.2} ns/dim-update (gbest {:.0})", s, per * 1e9, out.gbest_fit);
    }
}
