//! Function gallery: every fitness function × every Plane-A engine —
//! solution quality and wall time in one table.
//!
//!     cargo run --release --example function_gallery

use cupso::config::EngineKind;
use cupso::fitness::{by_name, ALL_NAMES};
use cupso::metrics::{Stopwatch, Table};
use cupso::pso::PsoParams;

fn main() {
    let dim = 8;
    let iters = 2_000;
    let particles = 512;

    let mut table = Table::new(
        &format!("Gallery — {particles} particles, {dim}-D, {iters} iters"),
        &["Function", "Engine", "gbest", "optimum", "time (s)"],
    );

    for name in ALL_NAMES {
        let fitness = by_name(name).unwrap();
        let objective = fitness.default_objective();
        let params = PsoParams::for_fitness(fitness.as_ref(), particles, dim, iters, 0.5);
        for kind in EngineKind::TABLE3 {
            let mut engine = cupso::engine::build(kind, 0).unwrap();
            let sw = Stopwatch::start();
            let out = engine.run(&params, fitness.as_ref(), objective, 7);
            table.row(&[
                name.to_string(),
                kind.label().to_string(),
                format!("{:.4}", out.gbest_fit),
                fitness
                    .optimum(dim)
                    .map(|o| format!("{o:.1}"))
                    .unwrap_or_else(|| "-".into()),
                format!("{:.3}", sw.elapsed_s()),
            ]);
        }
    }
    println!("{}", table.to_markdown());
    println!(
        "Note: all five engines share the same synchronous-PSO physics; the\n\
         parallel four should agree closely on quality (Queue-Lock may differ\n\
         slightly — it relaxes cross-block ordering, §4.2 of the paper)."
    );
}
