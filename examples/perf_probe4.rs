// perf probe 4: can a 4-wide lane-batched philox auto-vectorize?
use std::time::Instant;

const M0: u32 = 0xD251_1F53;
const M1: u32 = 0xCD9E_8D57;
const W0: u32 = 0x9E37_79B9;
const W1: u32 = 0xBB67_AE85;

#[inline(always)]
fn round_x4(c: &mut [[u32; 4]; 4], k: [u32; 2]) {
    for l in 0..4 {
        let p0 = (c[0][l] as u64).wrapping_mul(M0 as u64);
        let p1 = (c[2][l] as u64).wrapping_mul(M1 as u64);
        let (h0, l0) = ((p0 >> 32) as u32, p0 as u32);
        let (h1, l1) = ((p1 >> 32) as u32, p1 as u32);
        let n0 = h1 ^ c[1][l] ^ k[0];
        let n1 = l1;
        let n2 = h0 ^ c[3][l] ^ k[1];
        let n3 = l0;
        c[0][l] = n0; c[1][l] = n1; c[2][l] = n2; c[3][l] = n3;
    }
}

#[inline]
fn philox_x4(mut c: [[u32; 4]; 4], mut k: [u32; 2]) -> [[u32; 4]; 4] {
    for r in 0..10 {
        if r > 0 { k[0] = k[0].wrapping_add(W0); k[1] = k[1].wrapping_add(W1); }
        round_x4(&mut c, k);
    }
    c
}

fn main() {
    const CALLS: u64 = 5_000_000; // 4 blocks per call => 20M blocks
    let key = [123u32, 456u32];
    let t = Instant::now();
    let mut acc = 0u32;
    for i in 0..CALLS {
        let base = (i * 4) as u32;
        let c = [[base, base+1, base+2, base+3], [7; 4], [9; 4], [11; 4]];
        let o = philox_x4(c, key);
        acc ^= o[0][0] ^ o[1][1] ^ o[2][2] ^ o[3][3];
    }
    std::hint::black_box(acc);
    let per_block = t.elapsed().as_secs_f64() / (CALLS * 4) as f64 * 1e9;
    println!("batched philox: {:.2} ns/block ({:.2} ns per f64-pair block)", per_block, per_block);
}
