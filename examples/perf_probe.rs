// perf probe: per-particle-iteration cost of the serial hot loop
use cupso::fitness::{Cubic, Objective};
use cupso::pso::{serial, PsoParams};
use std::time::Instant;

fn main() {
    for (n, d, iters) in [(2048usize, 1usize, 5000u64), (1024, 120, 100)] {
        let params = PsoParams { dim: d, ..PsoParams::paper_1d(n, iters) };
        let t = Instant::now();
        let out = serial::run(&params, &Cubic, Objective::Maximize, 42);
        let s = t.elapsed().as_secs_f64();
        let per = s / (n as f64 * iters as f64);
        println!("n={n} d={d}: {:.3}s total, {:.1} ns/particle-iter, {:.2} ns/dim  (gbest {:.0})",
            s, per * 1e9, per * 1e9 / d as f64, out.gbest_fit);
    }
}
