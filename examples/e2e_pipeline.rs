//! End-to-end driver — proves all three layers compose on a real
//! workload and reports the paper's headline metrics on this testbed.
//!
//! Pipeline exercised:
//!   L1 Pallas kernels → L2 JAX scan chunks → `make artifacts` (HLO text)
//!   → L3 Rust: PJRT load/compile → sharded coordinator (sync barrier vs
//!   async lock) → cross-checked against the serial CPU baseline and the
//!   Plane-A Queue engine.
//!
//! Reported (and recorded in EXPERIMENTS.md §E2E):
//!   * serial CPU vs XLA-plane wall time + speedup,
//!   * sync-barrier vs async-lock coordinator (the queue-lock idea at
//!     coordinator scale),
//!   * reduction vs queue vs fused artifact variants on the XLA plane
//!     (the paper's algorithm comparison, Plane B edition),
//!   * solution quality cross-check between all planes.
//!
//!     make artifacts && cargo run --release --example e2e_pipeline

use cupso::coordinator::{AsyncScheduler, CoordinatorConfig, SyncScheduler};
use cupso::engine::{Engine, ParallelSettings, QueueEngine, SerialEngine};
use cupso::fitness::{Cubic, Fitness, Objective};
use cupso::metrics::{Stopwatch, Table};
use cupso::pso::PsoParams;
use cupso::runtime::{XlaRuntime, XlaSwarmState};
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let dir = Path::new("artifacts");
    let rt = XlaRuntime::open(dir)
        .map_err(|e| anyhow::anyhow!("{e:#}\n\nrun `make artifacts` first"))?;
    println!(
        "[1/4] runtime up: platform={}, {} artifacts (jax {})\n",
        rt.platform(),
        rt.manifest().names().len(),
        rt.manifest().jax_version
    );

    // ---------------------------------------------------------------
    // Part A — the paper's 120-D workload: serial CPU vs the 3-layer
    // stack (4 shards × 256 particles, 500 iterations each).
    // ---------------------------------------------------------------
    let dim = 120;
    let shard_particles = 256;
    let shards = 4;
    let iters = 500;

    let params_total = PsoParams::paper_120d(shard_particles * shards, iters);
    let mut serial = SerialEngine;
    let sw = Stopwatch::start();
    let cpu_out = serial.run(&params_total, &Cubic, Objective::Maximize, 42);
    let t_cpu = sw.elapsed_s();
    println!(
        "[2/4] serial CPU   : {:>8.3}s  gbest {:.1}",
        t_cpu, cpu_out.gbest_fit
    );

    let mut cfg = CoordinatorConfig::new("queue", shard_particles, dim, iters);
    cfg.shards = shards;
    // Warm the executable cache so scheduler timings exclude the one-time
    // PJRT compilation.
    rt.load_config("queue", shard_particles, dim)?;
    let sw = Stopwatch::start();
    let sync_out = SyncScheduler::run(&rt, &cfg)?;
    let t_sync = sw.elapsed_s();
    println!(
        "      XLA sync    : {:>8.3}s  gbest {:.1}  ({} chunk calls, {} merges)",
        t_sync, sync_out.gbest_fit, sync_out.chunk_calls, sync_out.merges
    );

    let sw = Stopwatch::start();
    let async_out = AsyncScheduler::run(&rt, &cfg)?;
    let t_async = sw.elapsed_s();
    println!(
        "      XLA async   : {:>8.3}s  gbest {:.1}  ({} chunk calls, {} merges)",
        t_async, async_out.gbest_fit, async_out.chunk_calls, async_out.merges
    );

    // Plane-A queue engine on the same workload, for the cross-plane check.
    let mut queue = QueueEngine::new(ParallelSettings::with_workers(0));
    let sw = Stopwatch::start();
    let queue_out = queue.run(&params_total, &Cubic, Objective::Maximize, 42);
    let t_queue = sw.elapsed_s();
    println!(
        "      Plane-A queue: {:>7.3}s  gbest {:.1}\n",
        t_queue, queue_out.gbest_fit
    );

    let mut part_a = Table::new(
        "E2E Part A — 120-D Cubic, 1024 particles total, 500 iters",
        &["Plane", "Time (s)", "Speedup vs CPU", "gbest", "% of optimum"],
    );
    let opt = Cubic.optimum(dim).unwrap();
    for (name, t, fit) in [
        ("CPU serial (Algorithm 1)", t_cpu, cpu_out.gbest_fit),
        ("XLA 3-layer, sync barrier", t_sync, sync_out.gbest_fit),
        ("XLA 3-layer, async lock", t_async, async_out.gbest_fit),
        ("Plane-A Queue engine", t_queue, queue_out.gbest_fit),
    ] {
        part_a.row(&[
            name.to_string(),
            format!("{t:.3}"),
            format!("{:.2}x", t_cpu / t),
            format!("{fit:.1}"),
            format!("{:.2}%", 100.0 * fit / opt),
        ]);
    }
    println!("{}", part_a.to_markdown());

    // ---------------------------------------------------------------
    // Part B — artifact-variant comparison on the XLA plane: the
    // paper's reduction-vs-queue question, asked of the lowered HLO.
    // ---------------------------------------------------------------
    println!("[3/4] artifact variants (n=4096, 1-D, 10 chunks × 50 iters each):");
    let mut part_b = Table::new(
        "E2E Part B — variant comparison on the XLA plane",
        &["Variant", "Time/iter (µs)", "gbest", "Note"],
    );
    for variant in ["reduction", "queue", "fused"] {
        let exec = rt.load_config(variant, 4096, 1)?;
        let meta_iters = exec.iters_per_call();
        let params = PsoParams::paper_1d(4096, meta_iters);
        let mut st = XlaSwarmState::init(&params, &Cubic, Objective::Maximize, 7, 0);
        // Warm-up call (compile amortized by cache, first-run page-ins).
        exec.run(&mut st.clone(), [1, 1], 0)?;
        let sw = Stopwatch::start();
        let chunks = 10u64;
        for c in 0..chunks {
            exec.run(&mut st, [1, 1], (c * meta_iters) as i64)?;
        }
        let per_iter_us = sw.elapsed_s() / (chunks * meta_iters) as f64 * 1e6;
        part_b.row(&[
            variant.to_string(),
            format!("{per_iter_us:.1}"),
            format!("{:.1}", st.gbest_fit),
            match variant {
                "reduction" => "full argmax every iter".into(),
                "queue" => "predicate-then-reduce".into(),
                _ => "carry-fused (queue-lock analog)".to_string(),
            },
        ]);
    }
    println!("{}", part_b.to_markdown());

    // ---------------------------------------------------------------
    // Part C — cross-plane quality check + headline summary.
    // ---------------------------------------------------------------
    println!("[4/4] cross-checks:");
    // Quality bands per plane: the in-loop serial baseline and the sharded
    // coordinators (island diversity) converge faster per iteration than a
    // single synchronous swarm, so Plane-A's fully-synchronous engine gets
    // a wider band at this iteration budget (its *equivalence* to the
    // synchronous oracle is tested bit-exactly elsewhere).
    for (plane, fit, band) in [
        ("cpu", cpu_out.gbest_fit, 0.95),
        ("xla-sync", sync_out.gbest_fit, 0.97),
        ("xla-async", async_out.gbest_fit, 0.97),
        ("plane-a-queue", queue_out.gbest_fit, 0.60),
    ] {
        assert!(
            fit > band * opt,
            "{plane} quality {fit} below {:.0}% of optimum {opt}",
            band * 100.0
        );
        println!(
            "  {plane:<14} gbest within {:.2}% of optimum (band {:.0}%) ✓",
            100.0 * (1.0 - fit / opt),
            band * 100.0
        );
    }
    println!(
        "\nheadline: XLA plane is {:.1}x (sync) / {:.1}x (async) vs serial CPU on this host;\n\
         async-lock vs sync-barrier coordinator: {:.2}x; all planes agree on quality.",
        t_cpu / t_sync,
        t_cpu / t_async,
        t_sync / t_async,
    );
    Ok(())
}
