//! Real-time target tracking — the application class the paper's intro
//! motivates ("PSO could be used to track moving objects … the capability
//! of fast convergence of PSO is critical to fit the real-time
//! requirements").
//!
//! A target moves along a smooth trajectory in a 3-D scene; each frame
//! the swarm re-optimizes a dynamic fitness (negative distance to the
//! hidden target, observed only through the fitness oracle). The demo
//! reports per-frame latency against a 60 fps budget and the tracking
//! error, comparing the Queue-Lock engine with the serial baseline.
//!
//!     cargo run --release --example target_tracking

use cupso::engine::{Engine, ParallelSettings, QueueLockEngine, SerialEngine};
use cupso::fitness::{Fitness, Objective};
use cupso::metrics::{Stopwatch, Summary, Table};
use cupso::pso::PsoParams;

/// Negative squared distance to a hidden target — maximized at it.
struct TrackTarget {
    target: [f64; 3],
}

impl Fitness for TrackTarget {
    fn name(&self) -> &'static str {
        "track"
    }

    fn default_bounds(&self) -> (f64, f64) {
        (-100.0, 100.0)
    }

    fn default_objective(&self) -> Objective {
        Objective::Maximize
    }

    fn eval(&self, x: &[f64]) -> f64 {
        -x.iter()
            .zip(&self.target)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()
    }
}

/// The hidden trajectory: a Lissajous curve through the scene.
fn target_at(frame: usize) -> [f64; 3] {
    let t = frame as f64 * 0.08;
    [
        80.0 * (0.7 * t).sin(),
        60.0 * (1.1 * t).cos(),
        40.0 * (1.7 * t + 0.5).sin(),
    ]
}

fn track<E: Engine>(engine: &mut E, frames: usize, iters_per_frame: u64) -> (Summary, Summary) {
    let mut latencies = Vec::new();
    let mut errors = Vec::new();
    for frame in 0..frames {
        let fitness = TrackTarget {
            target: target_at(frame),
        };
        // Re-acquire each frame with a short PSO burst. (Re-seeding per
        // frame keeps engines comparable; a production tracker would warm
        // start from the previous swarm.)
        let params = PsoParams::for_fitness(&fitness, 256, 3, iters_per_frame, 0.5);
        let sw = Stopwatch::start();
        let out = engine.run(&params, &fitness, Objective::Maximize, frame as u64);
        latencies.push(sw.elapsed_s() * 1e3);
        let err = out
            .gbest_pos
            .iter()
            .zip(&fitness.target)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()
            .sqrt();
        errors.push(err);
    }
    let latencies =
        Summary::from_samples(&latencies).expect("one latency sample per tracked frame");
    let errors = Summary::from_samples(&errors).expect("one error sample per tracked frame");
    (latencies, errors)
}

fn main() {
    const FRAMES: usize = 120;
    const ITERS: u64 = 60;
    const BUDGET_MS: f64 = 16.7; // 60 fps

    let mut table = Table::new(
        &format!("Target tracking — {FRAMES} frames, {ITERS} PSO iters/frame, 256 particles"),
        &["Engine", "p50 (ms)", "p95 (ms)", "max (ms)", "mean err", "frames > 16.7ms"],
    );

    let mut serial = SerialEngine;
    let mut queue_lock = QueueLockEngine::new(ParallelSettings::with_workers(0));

    let runs: Vec<(&str, (Summary, Summary))> = vec![
        ("CPU serial", track(&mut serial, FRAMES, ITERS)),
        ("Queue Lock", track(&mut queue_lock, FRAMES, ITERS)),
    ];
    for (name, (lat, err)) in &runs {
        let over = (0..100)
            .map(|p| lat.percentile(p as f64))
            .filter(|&l| l > BUDGET_MS)
            .count();
        table.row(&[
            name.to_string(),
            format!("{:.2}", lat.median()),
            format!("{:.2}", lat.percentile(95.0)),
            format!("{:.2}", lat.max()),
            format!("{:.2}", err.mean()),
            format!("~{}%", over),
        ]);
    }
    println!("{}", table.to_markdown());

    for (name, (_, err)) in &runs {
        assert!(
            err.mean() < 5.0,
            "{name}: tracking error {} too large",
            err.mean()
        );
    }
    println!("both engines keep mean tracking error < 5 units — OK");
}
