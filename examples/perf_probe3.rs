// perf probe 3: hot-loop cost breakdown — full loop vs no-RNG vs RNG-only
use cupso::rng::PhiloxStream;
use std::time::Instant;

fn main() {
    const N: usize = 8192;
    const D: usize = 120;
    const ITERS: u64 = 30;
    let stream = PhiloxStream::new(1);
    let mut pos = vec![0.5f64; N * D];
    let mut vel = vec![0.1f64; N * D];
    let pb = vec![0.7f64; N * D];

    // Full row loop (mirrors step_block phase 1).
    let t = Instant::now();
    for iter in 0..ITERS {
        for d in 0..D {
            let base = d * N;
            for i in 0..N {
                let (r1, r2) = stream.r1r2(i as u64, iter, d as u32);
                let v = (1.0 * vel[base + i] + 2.0 * r1 * (pb[base + i] - pos[base + i])
                    + 2.0 * r2 * (0.3 - pos[base + i])).clamp(-100.0, 100.0);
                vel[base + i] = v;
                pos[base + i] = (pos[base + i] + v).clamp(-100.0, 100.0);
            }
        }
    }
    let full = t.elapsed().as_secs_f64();

    // Same loop, RNG replaced by constants.
    let t = Instant::now();
    for _iter in 0..ITERS {
        for d in 0..D {
            let base = d * N;
            for i in 0..N {
                let (r1, r2) = (0.42f64, 0.17f64);
                let v = (1.0 * vel[base + i] + 2.0 * r1 * (pb[base + i] - pos[base + i])
                    + 2.0 * r2 * (0.3 - pos[base + i])).clamp(-100.0, 100.0);
                vel[base + i] = v;
                pos[base + i] = (pos[base + i] + v).clamp(-100.0, 100.0);
            }
        }
    }
    let norng = t.elapsed().as_secs_f64();

    // RNG only.
    let t = Instant::now();
    let mut acc = 0.0;
    for iter in 0..ITERS {
        for d in 0..D {
            for i in 0..N {
                let (r1, r2) = stream.r1r2(i as u64, iter, d as u32);
                acc += r1 + r2;
            }
        }
    }
    let rngonly = t.elapsed().as_secs_f64();
    std::hint::black_box(acc);

    let per = 1e9 / (N as f64 * D as f64 * ITERS as f64);
    println!("full: {:.2} ns/dim | no-rng: {:.2} | rng-only: {:.2}", full * per, norng * per, rngonly * per);
}
