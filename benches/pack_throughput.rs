//! Fleet stepping cost — swarm-packed megabatch vs per-job stream
//! executors (ISSUE 6).
//!
//! A fleet of small compatible jobs pays the scheduler's per-job round
//! machinery (pick, budget, launch pair, report) once per job per round
//! on the executor path, but once per *pack* per round on the packed
//! path: all member swarms live in one shared slab and step under a
//! single grid-stride launch pair. This bench isolates that fixed cost
//! with deliberately tiny jobs (64 particles, 1-D — arithmetic is
//! negligible) swept over fleet sizes {8, 64, 256}:
//!
//! * `per_jobstep_ns` — wall time divided by (jobs × iterations);
//! * `overhead_ns` — `per_jobstep` minus the solo S=1 fast-path
//!   `per_jobstep` (one job, no fleet machinery: the pure stepping
//!   cost), floored at zero;
//! * `executor_vs_packed_overhead` — executor-path overhead divided by
//!   packed-path overhead at the same fleet size. The acceptance bar
//!   (ISSUE 6) is ≥ 5× at 64 jobs.
//!
//! Scale via CUPSO_BENCH_SCALE=ci|paper|smoke; set CUPSO_BENCH_JSON to
//! also write `BENCH_pack.json`.

use cupso::benchkit::json::{BenchJson, JsonObj};
use cupso::benchkit::{measure_timed, results_dir, BenchConfig};
use cupso::config::EngineKind;
use cupso::fitness::{Cubic, Objective};
use cupso::metrics::Table;
use cupso::pso::PsoParams;
use cupso::scheduler::{JobScheduler, JobSpec};
use std::sync::Arc;

/// A fleet of identical tiny Queue jobs (all pack-compatible).
fn specs(jobs: usize, iters: u64) -> Vec<JobSpec> {
    (0..jobs)
        .map(|j| {
            JobSpec::new(
                &format!("pack{j}"),
                EngineKind::Queue,
                PsoParams::paper_1d(64, iters),
                Arc::new(Cubic),
                Objective::Maximize,
                j as u64 + 1,
            )
        })
        .collect()
}

fn main() {
    let cfg = BenchConfig::from_env();
    let iters = cfg.iters(20_000);
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    println!(
        "pack_throughput: 64-particle 1-D jobs, {iters} iters each ({}), \
         {} reps trimmed-mean, {cores} cores\n",
        cfg.scale_note(),
        cfg.reps
    );

    let mut table = Table::new(
        "Fleet stepping cost — packed megabatch vs stream executors",
        &["Mode", "jobs", "time (s)", "ns/job-step", "overhead ns/job-step"],
    );
    let mut doc = BenchJson::new("pack", &cfg);

    let mut measure = |scheduler: &JobScheduler, jobs: usize| -> f64 {
        let job_specs = specs(jobs, iters);
        let s = measure_timed(&cfg, || {
            let outcomes = scheduler.run(&job_specs).unwrap();
            for o in &outcomes {
                assert_eq!(o.steps, iters, "{}", o.name);
            }
        });
        s.trimmed_mean()
    };

    // One job on the S=1 fast path: the pure per-step cost with no fleet
    // machinery at all, charged as the baseline for every mode below.
    let solo = JobScheduler::with_streams(4, 1);
    let base_wall = measure(&solo, 1);
    let base = base_wall / iters as f64;
    table.row(&[
        "solo".into(),
        "1".into(),
        format!("{base_wall:.4}"),
        format!("{:.0}", base * 1e9),
        "0".into(),
    ]);
    doc.push(
        JsonObj::new()
            .str("mode", "solo")
            .int("jobs", 1)
            .int("iters", iters)
            .num("wall_s", base_wall)
            .num("per_jobstep_ns", base * 1e9)
            .num("overhead_ns", 0.0),
    );

    for fleet in [8usize, 64, 256] {
        let mut overheads = [0.0f64; 2]; // [executor, packed]
        let executors = JobScheduler::with_streams(4, 4);
        let packed = JobScheduler::with_streams(4, 1).pack(true);
        for (slot, (mode, scheduler)) in [("executors", &executors), ("packed", &packed)]
            .into_iter()
            .enumerate()
        {
            let wall = measure(scheduler, fleet);
            let per_jobstep = wall / (fleet as u64 * iters) as f64;
            let overhead = (per_jobstep - base).max(0.0);
            overheads[slot] = overhead;
            table.row(&[
                mode.into(),
                fleet.to_string(),
                format!("{wall:.4}"),
                format!("{:.0}", per_jobstep * 1e9),
                format!("{:.0}", overhead * 1e9),
            ]);
            doc.push(
                JsonObj::new()
                    .str("mode", mode)
                    .int("jobs", fleet as u64)
                    .int("iters", iters)
                    .num("wall_s", wall)
                    .num("per_jobstep_ns", per_jobstep * 1e9)
                    .num("overhead_ns", overhead * 1e9),
            );
        }
        let ratio = if overheads[1] > 0.0 {
            overheads[0] / overheads[1]
        } else {
            f64::INFINITY
        };
        println!(
            "{fleet} jobs: executor per-job-step overhead is {ratio:.1}x the \
             packed overhead"
        );
        doc.push(
            JsonObj::new()
                .str("mode", "summary")
                .int("jobs", fleet as u64)
                .num("executor_overhead_ns", overheads[0] * 1e9)
                .num("packed_overhead_ns", overheads[1] * 1e9)
                .num("executor_vs_packed_overhead", ratio),
        );
    }

    println!("\n{}", table.to_markdown());
    table.emit(&results_dir(), "pack_throughput").unwrap();
    if let Some(path) = doc.emit().unwrap() {
        println!("wrote {}", path.display());
    }
    println!(
        "expectation: executor fleets pay a pick + launch pair + report per\n\
         job per round where packs pay one launch pair per round for the\n\
         whole fleet; the acceptance bar is >= 5x lower overhead at 64 jobs."
    );
}
