//! §5.1 ablation — SoA vs AoS particle layout.
//!
//! The paper adopts Structure-of-Arrays for coalesced GPU access and
//! calls AoS "almost the worst case". The CPU analog of coalescing is
//! streaming/prefetch-friendly access: the SoA sweep walks each field
//! row contiguously, while AoS hops over interleaved structs. We measure
//! the identical PSO sweep over both layouts across dimensionalities.

use cupso::benchkit::{measure_timed, results_dir, BenchConfig};
use cupso::fitness::{Cubic, Fitness, Objective};
use cupso::metrics::Table;
use cupso::pso::{AosSwarm, PsoParams, SwarmState};
use cupso::rng::PhiloxStream;

/// SoA sweep: one full velocity/position/fitness/pbest pass.
fn sweep_soa(state: &mut SwarmState, params: &PsoParams, stream: &PhiloxStream, iter: u64) {
    let gbest = vec![0.0; state.dim];
    for i in 0..state.n {
        cupso::pso::update_particle(state, i, &gbest, params, stream, iter);
        cupso::pso::eval_and_pbest(state, i, &Cubic, Objective::Maximize);
    }
}

/// AoS sweep: identical math over `Vec<Particle>`.
fn sweep_aos(swarm: &mut AosSwarm, params: &PsoParams, stream: &PhiloxStream, iter: u64) {
    let dim = swarm.particles[0].pos.len();
    let gbest = vec![0.0; dim];
    for (i, p) in swarm.particles.iter_mut().enumerate() {
        for d in 0..dim {
            let (r1, r2) = stream.r1r2(i as u64, iter, d as u32);
            let v = (params.w * p.vel[d]
                + params.c1 * r1 * (p.pbest_pos[d] - p.pos[d])
                + params.c2 * r2 * (gbest[d] - p.pos[d]))
                .clamp(-params.max_v, params.max_v);
            p.vel[d] = v;
            p.pos[d] = (p.pos[d] + v).clamp(params.min_pos, params.max_pos);
        }
        let fit = Cubic.eval(&p.pos);
        p.fit = fit;
        if fit > p.pbest_fit {
            p.pbest_fit = fit;
            p.pbest_pos.copy_from_slice(&p.pos);
        }
    }
}

fn main() {
    let cfg = BenchConfig::from_env();
    println!("ablation_layout: SoA vs AoS sweeps\n");

    let mut table = Table::new(
        "Layout ablation (§5.1): SoA vs AoS, full-swarm sweep time",
        &["Particles", "Dim", "Sweeps", "SoA (s)", "AoS (s)", "AoS/SoA"],
    );

    for (n, dim, sweeps) in [
        (4096usize, 1usize, 2000u64),
        (4096, 16, 400),
        (4096, 120, 100),
        (65536, 120, 8),
    ] {
        let sweeps = cfg.iters(sweeps * cfg.iter_divisor); // keep row cost flat-ish
        let params = PsoParams::paper_1d(n, sweeps);
        let params = PsoParams { dim, ..params };
        let stream = PhiloxStream::new(3);

        let mut soa = SwarmState::init(&params, &stream);
        let t_soa = measure_timed(&cfg, || {
            for it in 0..sweeps {
                sweep_soa(&mut soa, &params, &stream, it);
            }
        })
        .trimmed_mean();

        let mut aos = AosSwarm::init(&params, &stream);
        let t_aos = measure_timed(&cfg, || {
            for it in 0..sweeps {
                sweep_aos(&mut aos, &params, &stream, it);
            }
        })
        .trimmed_mean();

        table.row(&[
            n.to_string(),
            dim.to_string(),
            sweeps.to_string(),
            format!("{t_soa:.4}"),
            format!("{t_aos:.4}"),
            format!("{:.2}x", t_aos / t_soa),
        ]);
    }
    table.emit(&results_dir(), "ablation_layout").unwrap();
    println!(
        "expectation: the gap grows with dimensionality (SoA streams each\n\
         dimension row; AoS strides across per-particle structs and defeats\n\
         hardware prefetch) — the CPU shadow of the paper's coalescing\n\
         argument. The GPU-model AoS penalty (gpusim aos_penalty = 3x) is\n\
         what the paper's 'worst case' phrasing corresponds to."
    );
}
