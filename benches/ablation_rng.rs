//! §5.4 ablation — cuRAND-style Philox vs a "custom-made" generator
//! (xoshiro256++) inside the same PSO hot loop. The paper reports the
//! cuRAND path ≈1.1× faster than a hand-ported generator on the GPU; we
//! re-measure both raw generation throughput and the in-loop effect on
//! this host, plus the counter-based stateless mode the engines use.

use cupso::benchkit::{measure_timed, results_dir, BenchConfig};
use cupso::fitness::{Cubic, Fitness, Objective};
use cupso::metrics::Table;
use cupso::pso::PsoParams;
use cupso::rng::{Philox4x32, PhiloxStream, RngEngine, Xoshiro256pp};

/// A minimal serial PSO sweep generic over the RNG engine — isolates the
/// generator cost in an otherwise identical loop.
fn pso_loop<R: RngEngine>(rng: &mut R, params: &PsoParams, iters: u64) -> f64 {
    let n = params.n;
    let mut pos: Vec<f64> = (0..n)
        .map(|_| rng.uniform(params.min_pos, params.max_pos))
        .collect();
    let mut vel: Vec<f64> = (0..n)
        .map(|_| rng.uniform(-params.max_v, params.max_v))
        .collect();
    let mut pbest_pos = pos.clone();
    let mut pbest_fit: Vec<f64> = pos.iter().map(|&p| Cubic.eval(&[p])).collect();
    let mut gbest_fit = f64::NEG_INFINITY;
    let mut gbest_pos = 0.0;
    for (i, &f) in pbest_fit.iter().enumerate() {
        if f > gbest_fit {
            gbest_fit = f;
            gbest_pos = pos[i];
        }
    }
    for _ in 0..iters {
        for i in 0..n {
            let r1 = rng.next_f64();
            let r2 = rng.next_f64();
            let v = (params.w * vel[i]
                + params.c1 * r1 * (pbest_pos[i] - pos[i])
                + params.c2 * r2 * (gbest_pos - pos[i]))
                .clamp(-params.max_v, params.max_v);
            let p = (pos[i] + v).clamp(params.min_pos, params.max_pos);
            vel[i] = v;
            pos[i] = p;
            let fit = Cubic.eval(&[p]);
            if fit > pbest_fit[i] {
                pbest_fit[i] = fit;
                pbest_pos[i] = p;
            }
            if fit > gbest_fit {
                gbest_fit = fit;
                gbest_pos = p;
            }
        }
    }
    gbest_fit
}

fn main() {
    let cfg = BenchConfig::from_env();
    let iters = cfg.iters(100_000);
    let params = PsoParams::paper_1d(1024, iters);
    println!("ablation_rng: 1024 particles × {iters} iters\n");

    // Raw generation throughput (ns per f64).
    const DRAWS: u64 = 10_000_000;
    let raw = |mut r: Box<dyn RngEngine>| {
        let s = measure_timed(&cfg, || {
            let mut acc = 0.0;
            for _ in 0..DRAWS {
                acc += r.next_f64();
            }
            std::hint::black_box(acc);
        });
        s.trimmed_mean() / DRAWS as f64 * 1e9
    };
    let raw_philox = raw(Box::new(Philox4x32::seeded(1)));
    let raw_xoshiro = raw(Box::new(Xoshiro256pp::seeded(1)));

    // Counter-based stateless mode (what the engines actually use — the
    // cuRAND-style per-(particle, iter) derivation).
    let stream = PhiloxStream::new(1);
    let s = measure_timed(&cfg, || {
        let mut acc = 0.0;
        for i in 0..(DRAWS / 2) {
            let (a, b) = stream.r1r2(i, i >> 8, 0);
            acc += a + b;
        }
        std::hint::black_box(acc);
    });
    let raw_stream = s.trimmed_mean() / DRAWS as f64 * 1e9;

    // In-loop effect.
    let mut philox = Philox4x32::seeded(7);
    let t_philox = measure_timed(&cfg, || {
        std::hint::black_box(pso_loop(&mut philox, &params, iters));
    })
    .trimmed_mean();
    let mut xoshiro = Xoshiro256pp::seeded(7);
    let t_xoshiro = measure_timed(&cfg, || {
        std::hint::black_box(pso_loop(&mut xoshiro, &params, iters));
    })
    .trimmed_mean();

    let mut table = Table::new(
        "RNG ablation (§5.4): Philox (cuRAND engine) vs xoshiro256++ (custom)",
        &["Metric", "Philox", "xoshiro256++", "Philox counter-mode", "ratio x/philox"],
    );
    table.row(&[
        "raw ns / f64".into(),
        format!("{raw_philox:.2}"),
        format!("{raw_xoshiro:.2}"),
        format!("{raw_stream:.2}"),
        format!("{:.2}", raw_xoshiro / raw_philox),
    ]);
    table.row(&[
        "PSO loop (s)".into(),
        format!("{t_philox:.4}"),
        format!("{t_xoshiro:.4}"),
        "-".into(),
        format!("{:.3}", t_xoshiro / t_philox),
    ]);
    table.emit(&results_dir(), "ablation_rng").unwrap();
    println!(
        "paper context: on the GPU, cuRAND's Philox beat the custom port by\n\
         ~1.1x (hardware-tuned, per-thread state in registers). On a CPU the\n\
         custom xoshiro is the cheaper generator — the in-loop gap shows how\n\
         little the generator matters once the fitness+update work dominates,\n\
         which is the honest CPU reading of the paper's 1.1x."
    );
}
