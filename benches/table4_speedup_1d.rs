//! Table 4 — speedups achieved by the Queue-Lock algorithm on the 1-D
//! problem (paper: CPU serial vs GPU Queue-Lock, 128…131072 particles,
//! peak ≈195× at 65 536, drop at 131 072).
//!
//! Measured columns use Plane A (serial vs Queue-Lock on threads); the
//! estimated column replays the sweep on the Plane-C GTX-1080Ti model,
//! which reproduces the paper's peak-then-drop signature. Set
//! CUPSO_BENCH_JSON to also write `BENCH_table4_speedup_1d.json`.

use cupso::benchkit::json::{BenchJson, JsonObj};
use cupso::benchkit::{measure_timed, results_dir, BenchConfig};
use cupso::config::EngineKind;
use cupso::engine::{Engine, ParallelSettings, QueueLockEngine, SerialEngine};
use cupso::fitness::{Cubic, Objective};
use cupso::gpusim;
use cupso::metrics::Table;
use cupso::pso::PsoParams;

fn main() {
    let cfg = BenchConfig::from_env();
    let iters = cfg.iters(100_000);
    println!(
        "table4_speedup_1d: {} iterations ({}), {} reps\n",
        iters,
        cfg.scale_note(),
        cfg.reps
    );

    let mut table = Table::new(
        &format!("Table 4 — 1-D speedup, CPU vs Queue Lock ({iters} iters)"),
        &[
            "Particles",
            "CPU (s)",
            "QueueLock (s)",
            "Speedup",
            "est. GPU speedup",
            "paper speedup",
        ],
    );
    let mut doc = BenchJson::new("table4_speedup_1d", &cfg);

    let settings = ParallelSettings::with_workers(0);
    for (n, _, _, paper_speedup) in gpusim::paper::TABLE4 {
        if n > cfg.max_particles {
            continue;
        }
        // Large serial rows dominate the bench; halve reps beyond 32k.
        let mut row_cfg = cfg.clone();
        if n >= 32_768 {
            row_cfg.reps = (cfg.reps / 2).max(2);
        }
        let params = PsoParams::paper_1d(n, iters);
        let mut serial = SerialEngine;
        let t_cpu = measure_timed(&row_cfg, || {
            serial.run(&params, &Cubic, Objective::Maximize, 42);
        })
        .trimmed_mean();
        let mut ql = QueueLockEngine::new(settings.clone());
        let t_ql = measure_timed(&row_cfg, || {
            ql.run(&params, &Cubic, Objective::Maximize, 42);
        })
        .trimmed_mean();
        let est_cpu = gpusim::estimate_seconds(EngineKind::SerialCpu, n, 1, 100_000);
        let est_gpu = gpusim::estimate_seconds(EngineKind::QueueLock, n, 1, 100_000);
        table.row(&[
            n.to_string(),
            format!("{t_cpu:.4}"),
            format!("{t_ql:.4}"),
            format!("{:.2}", t_cpu / t_ql),
            format!("{:.2}", est_cpu / est_gpu),
            format!("{paper_speedup:.2}"),
        ]);
        doc.push(
            JsonObj::new()
                .int("particles", n as u64)
                .int("iters", iters)
                .num("cpu_s", t_cpu)
                .num("queuelock_s", t_ql)
                .num("speedup", t_cpu / t_ql)
                .num("est_gpu_speedup", est_cpu / est_gpu)
                .num("paper_speedup", paper_speedup),
        );
    }
    table.emit(&results_dir(), "table4_speedup_1d").unwrap();
    if let Some(path) = doc.emit().unwrap() {
        println!("wrote {}", path.display());
    }
    println!(
        "the measured speedup is bounded by this host's core count; the\n\
         estimated-GPU column carries the paper's ~200x class and the\n\
         131072-particle oversubscription drop."
    );
}
