//! §7 future-work ablation — synchronous launch-per-iteration engines vs
//! the persistent asynchronous engine ([`cupso::engine::AsyncEngine`]).
//!
//! Measures (a) wall time: the async engine pays ONE dispatch per run
//! instead of 1–2 per iteration, and (b) solution quality: asynchrony
//! trades gbest freshness for throughput — the quality column shows the
//! price (usually none on these workloads).

use cupso::benchkit::{measure_timed, results_dir, BenchConfig};
use cupso::engine::{AsyncEngine, Engine, ParallelSettings, QueueEngine, QueueLockEngine};
use cupso::fitness::{Cubic, Objective};
use cupso::metrics::Table;
use cupso::pso::PsoParams;

fn main() {
    let cfg = BenchConfig::from_env();
    println!("ablation_async: dispatch-per-iteration vs persistent kernel\n");

    let mut table = Table::new(
        "Async ablation: launches per run vs wall time vs quality",
        &["Workload", "Engine", "Dispatches", "Time (s)", "gbest", "% opt"],
    );

    let settings = ParallelSettings::with_workers(0);
    for (n, d, paper_iters) in [(2048usize, 1usize, 100_000u64), (8192, 120, 2000)] {
        let iters = cfg.iters(paper_iters);
        let params = PsoParams {
            dim: d,
            ..PsoParams::paper_1d(n, iters)
        };
        let opt = 900_000.0 * d as f64;
        let blocks = (n + 255) / 256;
        let runs: Vec<(Box<dyn Engine>, u64)> = vec![
            (Box::new(QueueEngine::new(settings.clone())), 2 * iters),
            (Box::new(QueueLockEngine::new(settings.clone())), iters),
            (Box::new(AsyncEngine::new(settings.clone())), 1),
        ];
        for (mut engine, dispatches) in runs {
            let mut last_fit = 0.0;
            let s = measure_timed(&cfg, || {
                last_fit = engine.run(&params, &Cubic, Objective::Maximize, 42).gbest_fit;
            });
            table.row(&[
                format!("n={n} d={d} it={iters} ({blocks} blocks)"),
                engine.name().to_string(),
                dispatches.to_string(),
                format!("{:.4}", s.trimmed_mean()),
                format!("{last_fit:.0}"),
                format!("{:.2}%", 100.0 * last_fit / opt),
            ]);
        }
    }
    table.emit(&results_dir(), "ablation_async").unwrap();
    println!(
        "reading: the persistent engine amortizes all dispatch overhead into\n\
         one launch (the paper's §7 'asynchronous execution scheme'); on a\n\
         multi-core host the gap equals the per-iteration dispatch cost ×\n\
         iterations, with no quality loss on these workloads."
    );
}
