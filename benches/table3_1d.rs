//! Table 3 — execution times of the five implementations on the 1-D
//! problem (paper: 100k iterations, particles 32…2048).
//!
//! Emits three aligned columns per cell: **measured** (Plane A, this
//! host), **estimated GPU** (Plane C, GTX-1080Ti model), and **paper**
//! (the published number). Scale via CUPSO_BENCH_SCALE=ci|paper|smoke;
//! set CUPSO_BENCH_JSON to also write `BENCH_table3_1d.json`.

use cupso::benchkit::json::{BenchJson, JsonObj};
use cupso::benchkit::{measure_timed, results_dir, BenchConfig};
use cupso::config::EngineKind;
use cupso::fitness::{Cubic, Objective};
use cupso::gpusim;
use cupso::metrics::Table;
use cupso::pso::PsoParams;

fn main() {
    let cfg = BenchConfig::from_env();
    let iters = cfg.iters(100_000);
    let scale = 100_000.0 / iters as f64;
    println!(
        "table3_1d: {} iterations per run ({}), {} reps trimmed-mean\n",
        iters,
        cfg.scale_note(),
        cfg.reps
    );

    let mut table = Table::new(
        &format!("Table 3 — 1-D Cubic ({} iters, extrapolated to 100k)", iters),
        &[
            "Particles",
            "Engine",
            "measured (s)",
            "extrap. 100k (s)",
            "est. GPU (s)",
            "paper (s)",
        ],
    );
    let mut doc = BenchJson::new("table3_1d", &cfg);

    for (row_idx, &n) in gpusim::TABLE3_PARTICLES.iter().enumerate() {
        let params = PsoParams::paper_1d(n, iters);
        let paper_row = gpusim::paper::TABLE3[row_idx];
        let paper_vals = [
            paper_row.1, paper_row.2, paper_row.3, paper_row.4, paper_row.5,
        ];
        for (col, kind) in EngineKind::TABLE3.into_iter().enumerate() {
            let mut engine = cupso::engine::build(kind, 0).unwrap();
            let summary = measure_timed(&cfg, || {
                engine.run(&params, &Cubic, Objective::Maximize, 42);
            });
            let measured = summary.trimmed_mean();
            let est = gpusim::estimate_seconds(kind, n, 1, 100_000);
            table.row(&[
                n.to_string(),
                kind.label().to_string(),
                format!("{measured:.4}"),
                format!("{:.3}", measured * scale),
                format!("{est:.3}"),
                format!("{:.3}", paper_vals[col]),
            ]);
            doc.push(
                JsonObj::new()
                    .str("engine", kind.label())
                    .int("particles", n as u64)
                    .int("iters", iters)
                    .num("measured_s", measured)
                    .num("extrapolated_100k_s", measured * scale)
                    .num("est_gpu_s", est)
                    .num("paper_s", paper_vals[col]),
            );
        }
    }
    table.emit(&results_dir(), "table3_1d").unwrap();
    if let Some(path) = doc.emit().unwrap() {
        println!("wrote {}", path.display());
    }

    println!(
        "shape checks: within each particle count the measured ranking should\n\
         echo the paper's (QueueLock fastest, Reduction slowest among GPU-\n\
         style engines); absolute numbers differ — this is a CPU-thread\n\
         substrate, see DESIGN.md §Plane A."
    );
}
