//! Plane-B benchmark — AOT artifact execution throughput and the
//! coordinator-scheduler comparison.
//!
//! Panels:
//!  1. per-variant µs/iteration for each lowered artifact config
//!     (the paper's reduction-vs-queue question on the XLA plane);
//!  2. sync-barrier vs async-lock coordinator on the 120-D workload
//!     (the queue-lock idea at coordinator scale);
//!  3. host↔device transfer + dispatch overhead per chunk call.
//!
//! Requires `make artifacts`.

use cupso::benchkit::{measure_timed, results_dir, BenchConfig};
use cupso::coordinator::{AsyncScheduler, CoordinatorConfig, SyncScheduler};
use cupso::fitness::{Cubic, Objective};
use cupso::metrics::{Stopwatch, Table};
use cupso::pso::PsoParams;
use cupso::runtime::{XlaRuntime, XlaSwarmState};
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let cfg = BenchConfig::from_env();
    let rt = XlaRuntime::open(Path::new("artifacts"))
        .map_err(|e| anyhow::anyhow!("{e:#}\n\nrun `make artifacts` first"))?;
    println!("xla_runtime: platform={}, {} reps\n", rt.platform(), cfg.reps);

    // ---- Panel 1: per-artifact throughput ----
    let mut t1 = Table::new(
        "XLA artifact throughput",
        &["Artifact", "Variant", "n", "dim", "µs/iter", "µs/chunk call"],
    );
    for meta in rt.manifest().iter().cloned().collect::<Vec<_>>() {
        let exec = rt.load(&meta.name)?;
        let params = PsoParams {
            dim: meta.dim,
            n: meta.n,
            ..PsoParams::paper_1d(meta.n, meta.iters)
        };
        let st = XlaSwarmState::init(&params, &Cubic, Objective::Maximize, 7, 0);
        exec.run(&mut st.clone(), [1, 1], 0)?; // warm
        let chunks = 5u64;
        let s = measure_timed(&cfg, || {
            let mut local = st.clone();
            for c in 0..chunks {
                exec.run(&mut local, [1, 1], (c * meta.iters) as i64).unwrap();
            }
        });
        let per_chunk = s.trimmed_mean() / chunks as f64 * 1e6;
        t1.row(&[
            meta.name.clone(),
            meta.variant.clone(),
            meta.n.to_string(),
            meta.dim.to_string(),
            format!("{:.1}", per_chunk / meta.iters as f64),
            format!("{per_chunk:.0}"),
        ]);
    }
    t1.emit(&results_dir(), "xla_throughput")?;

    // ---- Panel 2: scheduler comparison ----
    let mut t2 = Table::new(
        "Coordinator schedulers — 4 shards × 256 particles × 120-D",
        &["Scheduler", "Iters/shard", "Wall (s)", "gbest", "merges"],
    );
    let mut ccfg = CoordinatorConfig::new("queue", 256, 120, cfg.iters(25_000).max(100));
    ccfg.shards = 4;
    for (name, f) in [
        ("sync barrier", SyncScheduler::run as fn(&XlaRuntime, &CoordinatorConfig) -> anyhow::Result<cupso::coordinator::CoordOutput>),
        ("async lock", AsyncScheduler::run),
    ] {
        let sw = Stopwatch::start();
        let out = f(&rt, &ccfg)?;
        t2.row(&[
            name.to_string(),
            out.iters_per_shard.to_string(),
            format!("{:.3}", sw.elapsed_s()),
            format!("{:.1}", out.gbest_fit),
            out.merges.to_string(),
        ]);
    }
    t2.emit(&results_dir(), "xla_schedulers")?;

    // ---- Panel 3: dispatch overhead (tiny chunk on big state) ----
    let exec = rt.load_config("queue", 4096, 1)?;
    let params = PsoParams::paper_1d(4096, exec.meta.iters);
    let st = XlaSwarmState::init(&params, &Cubic, Objective::Maximize, 3, 0);
    let s = measure_timed(&cfg, || {
        let mut local = st.clone();
        exec.run(&mut local, [1, 1], 0).unwrap();
    });
    println!(
        "dispatch+transfer+execute for one n=4096 chunk ({} iters): {:.2} ms\n\
         (state is 4096×1 f64 ≈ 160 KB each way per call — the L3 hot path\n\
         cost the coordinator amortizes by choosing chunked artifacts)",
        exec.meta.iters,
        s.trimmed_mean() * 1e3
    );
    Ok(())
}
