//! Table 5 — speedups achieved by the Queue algorithm on the 120-D
//! problem (paper: CPU vs GPU Queue, per-row iteration counts, peak
//! ≈225× at 32 768 particles). Set CUPSO_BENCH_JSON to also write
//! `BENCH_table5_speedup_120d.json`.

use cupso::benchkit::json::{BenchJson, JsonObj};
use cupso::benchkit::{measure_timed, results_dir, BenchConfig};
use cupso::config::EngineKind;
use cupso::engine::{Engine, ParallelSettings, QueueEngine, SerialEngine};
use cupso::fitness::{Cubic, Objective};
use cupso::gpusim;
use cupso::metrics::Table;
use cupso::pso::PsoParams;

fn main() {
    let cfg = BenchConfig::from_env();
    println!(
        "table5_speedup_120d: paper per-row iterations ÷{} ({}), {} reps\n",
        cfg.iter_divisor,
        cfg.scale_note(),
        cfg.reps
    );

    let mut table = Table::new(
        "Table 5 — 120-D speedup, CPU vs Queue",
        &[
            "Particles",
            "Iters (paper)",
            "Iters (run)",
            "CPU (s)",
            "Queue (s)",
            "Speedup",
            "est. GPU speedup",
            "paper speedup",
        ],
    );
    let mut doc = BenchJson::new("table5_speedup_120d", &cfg);

    let settings = ParallelSettings::with_workers(0);
    for ((n, paper_iters), (_, _, _, _, paper_speedup)) in gpusim::TABLE5_ROWS
        .iter()
        .zip(gpusim::paper::TABLE5.iter())
    {
        if *n > cfg.max_particles {
            continue;
        }
        let iters = cfg.iters(*paper_iters);
        let mut row_cfg = cfg.clone();
        if *n >= 32_768 {
            row_cfg.reps = (cfg.reps / 2).max(2);
        }
        let params = PsoParams::paper_120d(*n, iters);
        let mut serial = SerialEngine;
        let t_cpu = measure_timed(&row_cfg, || {
            serial.run(&params, &Cubic, Objective::Maximize, 42);
        })
        .trimmed_mean();
        let mut q = QueueEngine::new(settings.clone());
        let t_q = measure_timed(&row_cfg, || {
            q.run(&params, &Cubic, Objective::Maximize, 42);
        })
        .trimmed_mean();
        let est_cpu = gpusim::estimate_seconds(EngineKind::SerialCpu, *n, 120, *paper_iters);
        let est_gpu = gpusim::estimate_seconds(EngineKind::Queue, *n, 120, *paper_iters);
        table.row(&[
            n.to_string(),
            paper_iters.to_string(),
            iters.to_string(),
            format!("{t_cpu:.4}"),
            format!("{t_q:.4}"),
            format!("{:.2}", t_cpu / t_q),
            format!("{:.2}", est_cpu / est_gpu),
            format!("{paper_speedup:.2}"),
        ]);
        doc.push(
            JsonObj::new()
                .int("particles", *n as u64)
                .int("paper_iters", *paper_iters)
                .int("iters", iters)
                .num("cpu_s", t_cpu)
                .num("queue_s", t_q)
                .num("speedup", t_cpu / t_q)
                .num("est_gpu_speedup", est_cpu / est_gpu)
                .num("paper_speedup", *paper_speedup),
        );
    }
    table.emit(&results_dir(), "table5_speedup_120d").unwrap();
    if let Some(path) = doc.emit().unwrap() {
        println!("wrote {}", path.display());
    }
    println!(
        "the 120-D problem is compute/memory-bound: the measured speedup\n\
         approaches the host's core count, while the estimated-GPU column\n\
         shows the paper's 200x class with its peak in the 32k-131k range."
    );
}
