//! Durable-snapshot overhead — what periodic crash-safety costs per
//! scheduling round (ISSUE 9).
//!
//! A serving session with `checkpoint_every = N` pays, every N rounds,
//! one full durable snapshot: each job checkpoint plus the manifest is
//! written, fsynced, published by rename, and the directory fsynced —
//! the manifest last, as the commit point. Off-cadence rounds pay two
//! field reads and a modulo (the zero-alloc tier pins that). This bench
//! sweeps the cadence over a small run-dry service fleet and reports:
//!
//! * `per_round_ns` — wall time over scheduling rounds at each cadence;
//! * `overhead_ns` — `per_round` minus the checkpointing-off baseline;
//! * `per_persist_us` — total overhead divided by snapshots taken: the
//!   marginal price of one durable snapshot (fsync-dominated, so expect
//!   storage-latency class, not CPU class).
//!
//! Scale via CUPSO_BENCH_SCALE=ci|paper|smoke; set CUPSO_BENCH_JSON to
//! also write `BENCH_durability.json`.

use cupso::benchkit::json::{BenchJson, JsonObj};
use cupso::benchkit::{measure_timed, results_dir, BenchConfig};
use cupso::config::{BatchConfig, EngineKind};
use cupso::fitness::{Cubic, Objective};
use cupso::metrics::Table;
use cupso::pso::PsoParams;
use cupso::scheduler::{JobScheduler, JobSpec};
use cupso::service::ServiceSession;
use std::sync::Arc;

const JOBS: usize = 2;

fn specs(iters: u64) -> Vec<JobSpec> {
    (0..JOBS)
        .map(|j| {
            JobSpec::new(
                &format!("dur{j}"),
                EngineKind::Queue,
                PsoParams::paper_1d(64, iters),
                Arc::new(Cubic),
                Objective::Maximize,
                j as u64 + 1,
            )
        })
        .collect()
}

fn knobs(every: u64) -> BatchConfig {
    BatchConfig {
        workers: 2,
        policy: "round-robin".into(),
        streams: 1,
        batch_steps: 1,
        preempt_quantum: 0,
        pack: false,
        pack_min: 2,
        pack_max: 0,
        quota_jobs: 0,
        quota_steps: 0,
        checkpoint_every: every,
        checkpoint_keep: 1,
        telemetry: true,
        trace_dump: None,
        jobs: Vec::new(),
    }
}

fn main() {
    let cfg = BenchConfig::from_env();
    // Small on purpose: every persist is fsync-bound, so the sweep cost
    // is dominated by the densest cadence, not the swarm arithmetic.
    let iters = cfg.iters(2_000);
    let rounds = JOBS as u64 * iters; // streams=1 round-robin: 1 step/round
    let dir = std::env::temp_dir().join(format!("cupso-bench-durability-{}", std::process::id()));
    println!(
        "durability: {JOBS} queue jobs x {iters} iters ({rounds} rounds, {}), \
         {} reps trimmed-mean, flat snapshots in {}\n",
        cfg.scale_note(),
        cfg.reps,
        dir.display()
    );

    let mut table = Table::new(
        "Durable periodic snapshots — per-round overhead by cadence",
        &["every", "persists", "time (s)", "ns/round", "overhead ns/round", "us/persist"],
    );
    let mut doc = BenchJson::new("durability", &cfg);

    let mut measure = |every: u64| -> f64 {
        let scheduler = JobScheduler::with_workers(2);
        let s = measure_timed(&cfg, || {
            std::fs::remove_dir_all(&dir).ok();
            std::fs::create_dir_all(&dir).unwrap();
            let snapshot_dir = (every > 0).then(|| dir.clone());
            let (service, handle) =
                ServiceSession::new(&scheduler, knobs(every), snapshot_dir, specs(iters))
                    .unwrap();
            drop(handle);
            let end = service.run_with(|_| {}).unwrap();
            assert_eq!(end.finished_total, JOBS as u64);
        });
        s.trimmed_mean()
    };

    let base_wall = measure(0);
    let base_round = base_wall / rounds as f64;
    table.row(&[
        "off".into(),
        "0".into(),
        format!("{base_wall:.4}"),
        format!("{:.0}", base_round * 1e9),
        "0".into(),
        "-".into(),
    ]);
    doc.push(
        JsonObj::new()
            .int("every", 0)
            .int("rounds", rounds)
            .int("persists", 0)
            .num("wall_s", base_wall)
            .num("per_round_ns", base_round * 1e9)
            .num("overhead_ns", 0.0),
    );

    for every in [1024u64, 256, 64] {
        // Cadence persists while running, plus the final one at run-dry.
        let persists = rounds / every + 1;
        let wall = measure(every);
        let per_round = wall / rounds as f64;
        let overhead = (per_round - base_round).max(0.0);
        let per_persist = (wall - base_wall).max(0.0) / persists as f64;
        table.row(&[
            every.to_string(),
            persists.to_string(),
            format!("{wall:.4}"),
            format!("{:.0}", per_round * 1e9),
            format!("{:.0}", overhead * 1e9),
            format!("{:.1}", per_persist * 1e6),
        ]);
        doc.push(
            JsonObj::new()
                .int("every", every)
                .int("rounds", rounds)
                .int("persists", persists)
                .num("wall_s", wall)
                .num("per_round_ns", per_round * 1e9)
                .num("overhead_ns", overhead * 1e9)
                .num("per_persist_us", per_persist * 1e6),
        );
    }

    std::fs::remove_dir_all(&dir).ok();
    println!("\n{}", table.to_markdown());
    table.emit(&results_dir(), "durability").unwrap();
    if let Some(path) = doc.emit().unwrap() {
        println!("wrote {}", path.display());
    }
    println!(
        "expectation: off-cadence rounds are free (the zero-alloc tier proves\n\
         they don't even allocate); each persist costs storage-latency class\n\
         time — 2 files x (fsync data + fsync dir) plus the manifest commit\n\
         point — so amortized overhead falls linearly with the cadence."
    );
}
