//! Per-round scheduler overhead — persistent stream executors vs the
//! legacy spawn-per-round baseline.
//!
//! The scheduler's fixed cost per concurrent round used to be S−1 OS
//! thread spawns + joins; the persistent executors replace that with a
//! command-slot publish + wake (see `rust/src/scheduler/executor.rs`).
//! This bench isolates that fixed cost with deliberately tiny jobs
//! (64 particles, 1-D — arithmetic is negligible, the round machinery
//! dominates) swept over `batch_steps ∈ {1, 16}` × `S ∈ {1, 4}`:
//!
//! * `per_round_ns` — wall time divided by scheduling rounds;
//! * `overhead_ns` — `per_round` minus the S=1 fast-path `per_round` at
//!   the same batch (the fast path steps inline with no stepping threads
//!   in either mode, so the difference is the round's thread handoff);
//! * `speedup` — spawn-mode overhead / executor-mode overhead at the
//!   same (S, batch). The acceptance bar (ISSUE 4) is ≥ 2× at
//!   `batch=1, S=4`.
//!
//! Scale via CUPSO_BENCH_SCALE=ci|paper|smoke; set CUPSO_BENCH_JSON to
//! also write `BENCH_scheduler.json` (the committed baseline at the repo
//! root was produced at ci scale).

use cupso::benchkit::json::{BenchJson, JsonObj};
use cupso::benchkit::{measure_timed, results_dir, BenchConfig};
use cupso::config::EngineKind;
use cupso::fitness::{Cubic, Objective};
use cupso::metrics::Table;
use cupso::pso::PsoParams;
use cupso::scheduler::{JobScheduler, JobSpec};
use std::sync::Arc;

/// One tiny job per stream so every round fills all S streams.
fn specs(jobs: usize, iters: u64) -> Vec<JobSpec> {
    (0..jobs)
        .map(|j| {
            JobSpec::new(
                &format!("lat{j}"),
                EngineKind::Queue,
                PsoParams::paper_1d(64, iters),
                Arc::new(Cubic),
                Objective::Maximize,
                j as u64 + 1,
            )
        })
        .collect()
}

fn main() {
    let cfg = BenchConfig::from_env();
    let iters = cfg.iters(100_000);
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    println!(
        "scheduler_latency: 64-particle 1-D jobs, {iters} iters each ({}), \
         {} reps trimmed-mean, {cores} cores\n",
        cfg.scale_note(),
        cfg.reps
    );

    let mut table = Table::new(
        "Scheduler per-round overhead — executors vs spawn-per-round",
        &["Mode", "S", "batch", "rounds", "time (s)", "ns/round", "overhead ns/round"],
    );
    let mut doc = BenchJson::new("scheduler", &cfg);

    // (streams, batch, spawn_mode) -> per-round seconds; the S=1 entry per
    // batch is the shared fast-path baseline both modes are charged
    // against.
    let mut measure = |streams: usize, batch: u64, spawn: bool| -> (u64, f64, f64) {
        let rounds = iters.div_ceil(batch);
        let job_specs = specs(streams, iters);
        let scheduler = JobScheduler::with_streams(streams, streams)
            .batch_steps(batch)
            .spawn_per_round(spawn);
        let s = measure_timed(&cfg, || {
            let outcomes = scheduler.run(&job_specs).unwrap();
            for o in &outcomes {
                assert_eq!(o.steps, iters, "{}", o.name);
            }
        });
        let wall = s.trimmed_mean();
        (rounds, wall, wall / rounds as f64)
    };

    for batch in [1u64, 16] {
        // S=1 takes the no-thread fast path in both modes: the common
        // baseline for this batch size.
        let (base_rounds, base_wall, base_round) = measure(1, batch, false);
        table.row(&[
            "fast-path".into(),
            "1".into(),
            batch.to_string(),
            base_rounds.to_string(),
            format!("{base_wall:.4}"),
            format!("{:.0}", base_round * 1e9),
            "0".into(),
        ]);
        doc.push(
            JsonObj::new()
                .str("mode", "fast-path")
                .int("streams", 1)
                .int("batch_steps", batch)
                .int("rounds", base_rounds)
                .num("wall_s", base_wall)
                .num("per_round_ns", base_round * 1e9)
                .num("overhead_ns", 0.0),
        );

        let mut overheads = [0.0f64; 2]; // [executor, spawn]
        for (slot, (mode, spawn)) in [("executor", false), ("spawn-per-round", true)]
            .into_iter()
            .enumerate()
        {
            let (rounds, wall, per_round) = measure(4, batch, spawn);
            let overhead = (per_round - base_round).max(0.0);
            overheads[slot] = overhead;
            table.row(&[
                mode.into(),
                "4".into(),
                batch.to_string(),
                rounds.to_string(),
                format!("{wall:.4}"),
                format!("{:.0}", per_round * 1e9),
                format!("{:.0}", overhead * 1e9),
            ]);
            doc.push(
                JsonObj::new()
                    .str("mode", mode)
                    .int("streams", 4)
                    .int("batch_steps", batch)
                    .int("rounds", rounds)
                    .num("wall_s", wall)
                    .num("per_round_ns", per_round * 1e9)
                    .num("overhead_ns", overhead * 1e9),
            );
        }
        let speedup = if overheads[0] > 0.0 {
            overheads[1] / overheads[0]
        } else {
            f64::INFINITY
        };
        println!(
            "S=4 batch={batch}: spawn-per-round overhead is {speedup:.1}x the \
             executor overhead"
        );
        doc.push(
            JsonObj::new()
                .str("mode", "summary")
                .int("streams", 4)
                .int("batch_steps", batch)
                .num("spawn_overhead_ns", overheads[1] * 1e9)
                .num("executor_overhead_ns", overheads[0] * 1e9)
                .num("spawn_vs_executor_overhead", speedup),
        );
    }

    println!("\n{}", table.to_markdown());
    table.emit(&results_dir(), "scheduler_latency").unwrap();
    if let Some(path) = doc.emit().unwrap() {
        println!("wrote {}", path.display());
    }
    println!(
        "expectation: executor rounds pay a slot publish + wake (~1 µs class)\n\
         where spawn rounds pay S-1 thread spawns + joins (~10-100 µs class);\n\
         the acceptance bar is >= 2x lower overhead at batch=1, S=4."
    );
}
