//! Scheduler throughput — 16 concurrent 1-D paper jobs multiplexed over
//! ONE shared pool: sequential one-shot runs vs the serialized scheduler
//! vs concurrent-stream scheduling at S ∈ {1, 2, 4}.
//!
//! What this measures:
//! * the overhead of the step-wise multiplexing layer (per-step dispatch,
//!   policy pick, telemetry) against run-to-completion execution of an
//!   identical workload — serialized scheduler vs sequential must stay
//!   within noise;
//! * the aggregate multi-job throughput gain from concurrent pool
//!   streams: with S streams, up to S grids are in flight at once, so the
//!   per-step dispatch/join "launch overhead" of independent tenants
//!   overlaps instead of serializing. On a ≥ 4-core host S=4 should beat
//!   S=1; on smaller hosts the streams time-slice and the table shows it.
//!
//! Batched stepping (`--batch-steps` analog) is swept alongside because
//! it is the second half of the same optimization: fewer, fatter
//! scheduling rounds amortize both the round bookkeeping and (for S > 1)
//! the per-round thread handoff.
//!
//! Scale via CUPSO_BENCH_SCALE=ci|paper|smoke (see benchkit).

use cupso::benchkit::json::{BenchJson, JsonObj};
use cupso::benchkit::{measure_timed, results_dir, BenchConfig};
use cupso::config::EngineKind;
use cupso::engine::{self, Engine, ParallelSettings};
use cupso::fitness::{Cubic, Objective};
use cupso::metrics::Table;
use cupso::pso::PsoParams;
use cupso::scheduler::{JobScheduler, JobSpec, SchedPolicy};
use std::sync::Arc;

const JOBS: usize = 16;

fn specs(iters: u64) -> Vec<JobSpec> {
    // Mixed bit-exact engines over the paper's 1-D workload, distinct
    // seeds so the jobs are genuinely independent tenants.
    let kinds = [
        EngineKind::Queue,
        EngineKind::Reduction,
        EngineKind::LoopUnrolling,
        EngineKind::QueueLock,
    ];
    (0..JOBS)
        .map(|j| {
            JobSpec::new(
                &format!("job{j:02}"),
                kinds[j % kinds.len()],
                PsoParams::paper_1d(1024, iters),
                Arc::new(Cubic),
                Objective::Maximize,
                j as u64 + 1,
            )
        })
        .collect()
}

fn main() {
    let cfg = BenchConfig::from_env();
    let iters = cfg.iters(2_000);
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    println!(
        "scheduler_throughput: {JOBS} jobs x {} iters each ({}), {} reps trimmed-mean, {} cores\n",
        iters,
        cfg.scale_note(),
        cfg.reps,
        cores
    );

    // Quality is only asserted at scales with enough iterations to
    // converge; smoke scale (2 iters) is a plumbing check, not a solve.
    let quality_bar = if iters >= 40 { 890_000.0 } else { f64::NEG_INFINITY };
    let total_steps = (JOBS as u64 * iters) as f64;
    // "speedup vs seq" follows the repo's speedup convention
    // (baseline / variant, higher = faster), matching the table4/5
    // benches.
    let mut table = Table::new(
        &format!("Scheduler throughput — {JOBS} x 1-D Cubic, {iters} iters"),
        &["Mode", "time (s)", "jobs/s", "steps/s", "speedup vs seq"],
    );

    // Machine-readable record of the same numbers (BENCH_<name>.json via
    // CUPSO_BENCH_JSON — CI uploads it next to the latency bench's).
    let mut doc = BenchJson::new("scheduler_throughput", &cfg);

    // --- sequential one-shot baseline (single-stream pool) ---------------
    let settings = ParallelSettings::with_workers(0);
    let job_specs = specs(iters);
    let seq = measure_timed(&cfg, || {
        for spec in &job_specs {
            let out = engine::build_with(spec.engine, settings.clone())
                .unwrap()
                .run(&spec.params, &Cubic, spec.objective, spec.seed);
            assert!(out.gbest_fit > quality_bar);
        }
    });
    let seq_t = seq.trimmed_mean();
    table.row(&[
        "sequential one-shot".into(),
        format!("{seq_t:.4}"),
        format!("{:.1}", JOBS as f64 / seq_t),
        format!("{:.0}", total_steps / seq_t),
        "1.00x".into(),
    ]);
    doc.push(
        JsonObj::new()
            .str("label", "sequential one-shot")
            .int("jobs", JOBS as u64)
            .int("iters", iters)
            .num("time_s", seq_t)
            .num("jobs_per_s", JOBS as f64 / seq_t)
            .num("steps_per_s", total_steps / seq_t)
            .num("speedup_vs_seq", 1.0)
            .nums("samples_s", seq.samples()),
    );
    drop(settings);

    // --- scheduler sweep: S streams × step batch, both policies for the
    // serialized case, round-robin for the concurrent ones ---------------
    let mut emit = |label: String, scheduler: &JobScheduler| {
        let job_specs = specs(iters);
        let s = measure_timed(&cfg, || {
            let outcomes = scheduler.run(&job_specs).unwrap();
            for o in &outcomes {
                assert!(o.output.gbest_fit > quality_bar, "{}", o.name);
            }
        });
        let t = s.trimmed_mean();
        table.row(&[
            label.clone(),
            format!("{t:.4}"),
            format!("{:.1}", JOBS as f64 / t),
            format!("{:.0}", total_steps / t),
            format!("{:.2}x", seq_t / t),
        ]);
        doc.push(
            JsonObj::new()
                .str("label", &label)
                .int("jobs", JOBS as u64)
                .int("iters", iters)
                .int("streams", scheduler.streams() as u64)
                .num("time_s", t)
                .num("jobs_per_s", JOBS as f64 / t)
                .num("steps_per_s", total_steps / t)
                .num("speedup_vs_seq", seq_t / t)
                .nums("samples_s", s.samples()),
        );
    };

    // Serialized path (S=1, batch=1): must be within noise of PR 1's
    // scheduler — the fast path takes no stepping threads.
    for policy in [SchedPolicy::RoundRobin, SchedPolicy::EarliestDeadlineFirst] {
        let scheduler = JobScheduler::with_streams(0, 1).policy(policy);
        emit(format!("scheduler S=1 batch=1 ({policy})"), &scheduler);
    }

    // Concurrent streams. batch=16 amortizes the per-round stepping
    // threads; batch=1 shows the unamortized handoff cost.
    for streams in [1usize, 2, 4] {
        for batch in [1u64, 16] {
            if streams == 1 && batch == 1 {
                continue; // already reported above
            }
            let scheduler = JobScheduler::with_streams(0, streams).batch_steps(batch);
            emit(format!("scheduler S={streams} batch={batch}"), &scheduler);
        }
    }

    println!("{}", table.to_markdown());
    table.emit(&results_dir(), "scheduler_throughput").unwrap();
    if let Some(path) = doc.emit().unwrap() {
        println!("bench JSON → {}", path.display());
    }
    println!(
        "expectation: serialized scheduler ~1x sequential (prepare-once\n\
         buffers, no per-step allocation); S=4/batch=16 beats S=1 on hosts\n\
         with >= 4 cores because up to 4 tenant grids overlap their\n\
         dispatch/join launch overhead instead of serializing on one\n\
         launch guard."
    );
}
