//! Scheduler throughput — 16 concurrent 1-D paper jobs multiplexed over
//! ONE shared pool vs the same 16 jobs run sequentially as one-shot
//! `Engine::run` calls (each on the shared pool too, but exclusively).
//!
//! What this measures: the overhead of the step-wise multiplexing layer
//! (per-step dispatch, policy pick, telemetry) against run-to-completion
//! execution of an identical workload. Because the engines are step-wise
//! and every buffer is allocated in `prepare`, the expected gap is small;
//! large gaps would indicate per-step allocation or pool thrash.
//!
//! Scale via CUPSO_BENCH_SCALE=ci|paper|smoke (see benchkit).

use cupso::benchkit::{measure_timed, results_dir, BenchConfig};
use cupso::config::EngineKind;
use cupso::engine::{self, Engine, ParallelSettings};
use cupso::fitness::{Cubic, Objective};
use cupso::metrics::Table;
use cupso::pso::PsoParams;
use cupso::scheduler::{JobScheduler, JobSpec, SchedPolicy};
use std::sync::Arc;

const JOBS: usize = 16;

fn specs(iters: u64) -> Vec<JobSpec> {
    // Mixed bit-exact engines over the paper's 1-D workload, distinct
    // seeds so the jobs are genuinely independent tenants.
    let kinds = [
        EngineKind::Queue,
        EngineKind::Reduction,
        EngineKind::LoopUnrolling,
        EngineKind::QueueLock,
    ];
    (0..JOBS)
        .map(|j| {
            JobSpec::new(
                &format!("job{j:02}"),
                kinds[j % kinds.len()],
                PsoParams::paper_1d(1024, iters),
                Arc::new(Cubic),
                Objective::Maximize,
                j as u64 + 1,
            )
        })
        .collect()
}

fn main() {
    let cfg = BenchConfig::from_env();
    let iters = cfg.iters(2_000);
    println!(
        "scheduler_throughput: {JOBS} jobs x {} iters each ({}), {} reps trimmed-mean\n",
        iters,
        cfg.scale_note(),
        cfg.reps
    );

    let settings = ParallelSettings::with_workers(0);
    // Quality is only asserted at scales with enough iterations to
    // converge; smoke scale (2 iters) is a plumbing check, not a solve.
    let quality_bar = if iters >= 40 { 890_000.0 } else { f64::NEG_INFINITY };
    let mut table = Table::new(
        &format!("Scheduler throughput — {JOBS} x 1-D Cubic, {iters} iters"),
        &["Mode", "time (s)", "jobs/s", "steps/s", "vs sequential"],
    );

    // --- sequential one-shot baseline -----------------------------------
    let job_specs = specs(iters);
    let seq = measure_timed(&cfg, || {
        for spec in &job_specs {
            let out = engine::build_with(spec.engine, settings.clone())
                .unwrap()
                .run(&spec.params, &Cubic, spec.objective, spec.seed);
            assert!(out.gbest_fit > quality_bar);
        }
    });
    let seq_t = seq.trimmed_mean();
    let total_steps = (JOBS as u64 * iters) as f64;
    table.row(&[
        "sequential one-shot".into(),
        format!("{seq_t:.4}"),
        format!("{:.1}", JOBS as f64 / seq_t),
        format!("{:.0}", total_steps / seq_t),
        "1.00x".into(),
    ]);

    // --- interleaved via the scheduler, both policies --------------------
    for policy in [SchedPolicy::RoundRobin, SchedPolicy::EarliestDeadlineFirst] {
        let scheduler = JobScheduler::new(settings.clone()).policy(policy);
        let job_specs = specs(iters);
        let s = measure_timed(&cfg, || {
            let outcomes = scheduler.run(&job_specs).unwrap();
            for o in &outcomes {
                assert!(o.output.gbest_fit > quality_bar, "{}", o.name);
            }
        });
        let t = s.trimmed_mean();
        table.row(&[
            format!("scheduler ({policy})"),
            format!("{t:.4}"),
            format!("{:.1}", JOBS as f64 / t),
            format!("{:.0}", total_steps / t),
            format!("{:.2}x", t / seq_t),
        ]);
    }

    println!("{}", table.to_markdown());
    table.emit(&results_dir(), "scheduler_throughput").unwrap();
    println!(
        "expectation: interleaved ~1x sequential (prepare-once buffers, no\n\
         per-step allocation); the scheduler buys multi-tenancy and early\n\
         termination, not raw speed."
    );
}
