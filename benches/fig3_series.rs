//! Figure 3 — "plotting of the execution times of the five
//! implementations with different number of particles involved".
//!
//! Regenerates the figure as an ASCII chart (log-y, like the published
//! plot's visual spread) plus a CSV series file for external plotting.
//! Two panels: measured (Plane A) and estimated GTX-1080Ti (Plane C).
//! Set CUPSO_BENCH_JSON to also write `BENCH_fig3_series.json`.

use cupso::benchkit::json::{BenchJson, JsonObj};
use cupso::benchkit::{measure_timed, results_dir, BenchConfig};
use cupso::config::EngineKind;
use cupso::fitness::{Cubic, Objective};
use cupso::gpusim;
use cupso::metrics::{write_csv, AsciiPlot, Table};
use cupso::pso::PsoParams;

fn main() {
    let cfg = BenchConfig::from_env();
    let iters = cfg.iters(100_000);
    println!("fig3_series: {} iterations ({})\n", iters, cfg.scale_note());

    let particles = gpusim::TABLE3_PARTICLES;
    let mut measured: Vec<(EngineKind, Vec<f64>)> = Vec::new();
    for kind in EngineKind::TABLE3 {
        let mut series = Vec::new();
        for &n in &particles {
            let params = PsoParams::paper_1d(n, iters);
            let mut engine = cupso::engine::build(kind, 0).unwrap();
            let s = measure_timed(&cfg, || {
                engine.run(&params, &Cubic, Objective::Maximize, 42);
            });
            series.push(s.trimmed_mean());
        }
        measured.push((kind, series));
    }

    // Panel 1: measured on this host.
    let mut plot = AsciiPlot::new(
        &format!("Figure 3 (measured, Plane A) — seconds for {iters} iters, log y"),
        64,
        18,
    )
    .log_y()
    .x_labels(&particles.to_vec());
    for (kind, series) in &measured {
        plot = plot.series(kind.label(), series);
    }
    println!("{}", plot.render());

    // Panel 2: the Plane-C estimated GTX-1080Ti, which reproduces the
    // published figure's absolute shape.
    let mut plot = AsciiPlot::new(
        "Figure 3 (estimated GTX-1080Ti, Plane C) — seconds for 100k iters, log y",
        64,
        18,
    )
    .log_y()
    .x_labels(&particles.to_vec());
    let mut est_rows = Vec::new();
    for kind in EngineKind::TABLE3 {
        let series: Vec<f64> = particles
            .iter()
            .map(|&n| gpusim::estimate_seconds(kind, n, 1, 100_000))
            .collect();
        plot = plot.series(kind.label(), &series);
        est_rows.push((kind, series));
    }
    println!("{}", plot.render());

    // CSV: one row per (engine, n) with both panels.
    let mut table = Table::new(
        "fig3 series",
        &["Engine", "Particles", "measured_s", "estimated_gpu_s"],
    );
    for ((kind, m), (_, e)) in measured.iter().zip(est_rows.iter()) {
        for (i, &n) in particles.iter().enumerate() {
            table.row(&[
                kind.label().to_string(),
                n.to_string(),
                format!("{:.5}", m[i]),
                format!("{:.5}", e[i]),
            ]);
        }
    }
    let path = results_dir().join("fig3_series.csv");
    write_csv(&path, &table.to_csv()).unwrap();
    println!("series written to {}", path.display());

    let mut doc = BenchJson::new("fig3_series", &cfg);
    for ((kind, m), (_, e)) in measured.iter().zip(est_rows.iter()) {
        for (i, &n) in particles.iter().enumerate() {
            doc.push(
                JsonObj::new()
                    .str("engine", kind.label())
                    .int("particles", n as u64)
                    .int("iters", iters)
                    .num("measured_s", m[i])
                    .num("estimated_gpu_s", e[i]),
            );
        }
    }
    if let Some(path) = doc.emit().unwrap() {
        println!("wrote {}", path.display());
    }
}
