//! §4.1 premise check — "the possibility of the satisfied condition may
//! be less than 0.1%".
//!
//! The queue algorithm's entire advantage rests on improvements over the
//! incumbent global best being rare. We measure the actual conditional-
//! push rate of the Queue engine across workloads and iteration budgets,
//! showing both the magnitude (≪0.1% on long runs) and the decay (early
//! iterations improve often; the rate collapses as the swarm converges —
//! the basis for gpusim's amortized IMPROVE_RATE).

use cupso::benchkit::{results_dir, BenchConfig};
use cupso::engine::{Engine, ParallelSettings, QueueEngine};
use cupso::fitness::{Cubic, Objective};
use cupso::metrics::Table;
use cupso::pso::PsoParams;

fn main() {
    let cfg = BenchConfig::from_env();
    println!("ablation_queue_rarity: measured conditional-push rates\n");
    let settings = ParallelSettings::with_workers(0);

    let mut table = Table::new(
        "Queue-push rarity (§4.1): pushes / particle-updates",
        &[
            "Particles",
            "Dim",
            "Iters",
            "Updates",
            "Pushes",
            "Rate (%)",
            "< 0.1%?",
        ],
    );

    let workloads: &[(usize, usize, u64)] = &[
        (1024, 1, cfg.iters(100_000)),
        (2048, 1, cfg.iters(100_000)),
        (65_536.min(cfg.max_particles), 1, cfg.iters(20_000)),
        (1024, 120, cfg.iters(20_000)),
        (8192, 120, cfg.iters(5_000)),
        // Short runs: the rate is much higher early (decay evidence).
        (1024, 120, 20),
        (1024, 120, 200),
        (1024, 120, 2000),
    ];

    for &(n, dim, iters) in workloads {
        let params = PsoParams {
            dim,
            ..PsoParams::paper_1d(n, iters)
        };
        let mut engine = QueueEngine::new(settings.clone());
        let out = engine.run(&params, &Cubic, Objective::Maximize, 42);
        let rate = out.counters.queue_push_rate();
        table.row(&[
            n.to_string(),
            dim.to_string(),
            iters.to_string(),
            out.counters.particle_updates.to_string(),
            out.counters.queue_pushes.to_string(),
            format!("{:.5}", 100.0 * rate),
            if rate < 0.001 { "yes" } else { "no (short run)" }.to_string(),
        ]);
    }
    table.emit(&results_dir(), "ablation_queue_rarity").unwrap();
    println!(
        "reading: long runs land well under the paper's 0.1% bound; short\n\
         runs show the early-phase improvement burst, explaining why the\n\
         amortized rate used by the cost model (5e-5) is an order below the\n\
         paper's upper bound."
    );
}
