//! Service saturation — the TCP + event-loop front end under a client
//! stampede (ISSUE 8).
//!
//! Three phases against a live `ServiceSession` behind the poll-based
//! event loop, all over real TCP sockets:
//!
//! * **admission** — N concurrent clients released by a barrier, each
//!   submitting one tenant-labelled job under the weighted-fair policy;
//!   measures per-client admission latency (connect → acknowledged),
//!   reported as p50 / p99 / max. Every client must be served.
//! * **shed** — `cap` holder connections occupy the whole connection
//!   table (each confirms admission with a ping), then N − cap probe
//!   clients connect; every probe must receive the loud
//!   `{"ok": false, ..., "shed": true}` refusal line, never a mystery
//!   timeout. Closing the holders must free slots again.
//! * **watch** — M subscribers attach before one job runs R rounds;
//!   every subscriber must receive exactly R report lines plus the
//!   terminal `{"event":"end"}` (R < WATCH_BUFFER, so lag is
//!   impossible); reports total fan-out line throughput.
//!
//! Scale via CUPSO_BENCH_SCALE=ci|paper|smoke (ci runs the acceptance
//! scale: 1024 concurrent TCP clients); set CUPSO_BENCH_JSON to also
//! write `BENCH_service.json`.

use cupso::benchkit::json::{BenchJson, JsonObj};
use cupso::benchkit::{results_dir, BenchConfig};
use cupso::config::BatchConfig;
use cupso::metrics::{Stopwatch, Table};
use cupso::scheduler::{JobScheduler, SchedPolicy};
use cupso::service::proto::Json;
use cupso::service::{bind_tcp, spawn_server_on, Listener, ServiceEnd, ServiceSession};
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::{Arc, Barrier};
use std::thread::JoinHandle;
use std::time::Duration;

/// A serve daemon on an ephemeral TCP port: event loop + service.
struct Daemon {
    addr: SocketAddr,
    svc: JoinHandle<ServiceEnd>,
}

fn start(policy: &str, max_conns: usize) -> Daemon {
    let knobs = BatchConfig {
        workers: 2,
        policy: policy.into(),
        streams: 2,
        batch_steps: 1,
        preempt_quantum: 0,
        pack: false,
        pack_min: 2,
        pack_max: 0,
        quota_jobs: 0,
        quota_steps: 0,
        checkpoint_every: 0,
        checkpoint_keep: 1,
        telemetry: true,
        trace_dump: None,
        jobs: Vec::new(),
    };
    let scheduler = JobScheduler::with_streams(2, 2)
        .policy(SchedPolicy::parse(policy).unwrap())
        .batch_steps(1);
    let (service, handle) = ServiceSession::new(&scheduler, knobs, None, Vec::new()).unwrap();
    let tcp = bind_tcp("127.0.0.1:0").unwrap();
    let addr = tcp.local_addr().unwrap();
    let _accept = spawn_server_on(vec![Listener::Tcp(tcp)], handle, max_conns);
    let svc = std::thread::spawn(move || service.run().unwrap());
    Daemon { addr, svc }
}

fn roundtrip(addr: SocketAddr, line: &str) -> Json {
    let mut stream = TcpStream::connect(addr).expect("connect");
    stream
        .set_read_timeout(Some(Duration::from_secs(120)))
        .unwrap();
    writeln!(stream, "{line}").unwrap();
    stream.flush().unwrap();
    let mut reader = BufReader::new(stream);
    let mut reply = String::new();
    reader.read_line(&mut reply).unwrap();
    Json::parse(reply.trim()).unwrap_or_else(|e| panic!("bad response {reply:?}: {e}"))
}

fn ok(doc: &Json) -> bool {
    doc.get("ok").map(|v| v == &Json::Bool(true)).unwrap_or(false)
}

// Thousands of concurrent clients: keep stacks small.
fn spawn_client<T: Send + 'static>(f: impl FnOnce() -> T + Send + 'static) -> JoinHandle<T> {
    std::thread::Builder::new()
        .stack_size(256 * 1024)
        .spawn(f)
        .unwrap()
}

fn wait_finished(addr: SocketAddr, n: u64) {
    loop {
        let doc = roundtrip(addr, r#"{"op": "status"}"#);
        let done = doc
            .get("finished_total")
            .and_then(|v| v.as_u64("finished_total").ok());
        if done == Some(n) {
            return;
        }
        std::thread::sleep(Duration::from_millis(20));
    }
}

fn drain(addr: SocketAddr) {
    let doc = roundtrip(addr, r#"{"op": "drain"}"#);
    assert!(ok(&doc), "{doc:?}");
}

fn percentile(sorted: &[f64], p: f64) -> f64 {
    let idx = ((sorted.len() as f64 - 1.0) * p).round() as usize;
    sorted[idx]
}

/// Phase 1: N concurrent tenant-labelled submits; per-client latency.
fn admission_phase(clients: usize, doc: &mut BenchJson, table: &mut Table) {
    let d = start("weighted-fair", clients + 8);
    let go = Arc::new(Barrier::new(clients));
    let handles: Vec<_> = (0..clients)
        .map(|i| {
            let go = Arc::clone(&go);
            let addr = d.addr;
            spawn_client(move || {
                go.wait();
                let sw = Stopwatch::start();
                let reply = roundtrip(
                    addr,
                    &format!(
                        r#"{{"op": "submit", "job": {{"name": "sat{i}", "fitness": "cubic", "particles": 16, "iters": 100, "seed": {}, "tenant": "t{}"}}}}"#,
                        i + 1,
                        i % 8
                    ),
                );
                assert!(ok(&reply), "client {i}: {reply:?}");
                sw.elapsed_s() * 1e3
            })
        })
        .collect();
    let mut lat_ms: Vec<f64> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    lat_ms.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let (p50, p99, max) = (
        percentile(&lat_ms, 0.50),
        percentile(&lat_ms, 0.99),
        *lat_ms.last().unwrap(),
    );
    // Let the admitted fleet run dry, then stop the daemon.
    wait_finished(d.addr, clients as u64);
    drain(d.addr);
    let end = d.svc.join().unwrap();
    assert_eq!(end.finished_total, clients as u64, "every client served");

    println!(
        "admission: {clients} concurrent TCP submits — latency ms \
         p50 {p50:.1} / p99 {p99:.1} / max {max:.1}"
    );
    table.row(&[
        "admission".into(),
        clients.to_string(),
        format!("{p50:.1}"),
        format!("{p99:.1}"),
        format!("{max:.1}"),
        "-".into(),
    ]);
    doc.push(
        JsonObj::new()
            .str("phase", "admission")
            .int("clients", clients as u64)
            .int("served", clients as u64)
            .num("latency_p50_ms", p50)
            .num("latency_p99_ms", p99)
            .num("latency_max_ms", max),
    );
}

/// Phase 2: a full connection table sheds the overflow, loudly.
fn shed_phase(clients: usize, doc: &mut BenchJson, table: &mut Table) {
    let cap = (clients / 4).max(8);
    let d = start("round-robin", cap);
    // Holders: exactly `cap` connections, each proven live by a ping
    // roundtrip, held open so the table stays full.
    let holders: Vec<TcpStream> = (0..cap)
        .map(|i| {
            let mut stream = TcpStream::connect(d.addr).expect("holder connect");
            stream
                .set_read_timeout(Some(Duration::from_secs(120)))
                .unwrap();
            writeln!(stream, r#"{{"op": "ping"}}"#).unwrap();
            stream.flush().unwrap();
            let mut reader = BufReader::new(stream);
            let mut reply = String::new();
            reader.read_line(&mut reply).unwrap();
            let ack = Json::parse(reply.trim()).unwrap();
            assert!(ok(&ack), "holder {i}: {ack:?}");
            reader.into_inner()
        })
        .collect();
    // Probes: everyone past the cap gets the loud refusal line.
    let probes = clients - cap;
    let handles: Vec<_> = (0..probes)
        .map(|i| {
            let addr = d.addr;
            spawn_client(move || {
                let stream = TcpStream::connect(addr).expect("probe connect");
                stream
                    .set_read_timeout(Some(Duration::from_secs(120)))
                    .unwrap();
                let mut reader = BufReader::new(stream);
                let mut line = String::new();
                reader.read_line(&mut line).unwrap();
                let reply = Json::parse(line.trim())
                    .unwrap_or_else(|e| panic!("probe {i}: bad shed line {line:?}: {e}"));
                assert!(!ok(&reply), "probe {i} was not shed: {reply:?}");
                assert_eq!(reply.get("shed"), Some(&Json::Bool(true)), "{reply:?}");
                assert!(
                    reply.str_field("error").unwrap().contains("connection cap"),
                    "{reply:?}"
                );
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    // Releasing the holders frees slots: a fresh client is served again.
    drop(holders);
    loop {
        let doc = roundtrip(d.addr, r#"{"op": "ping"}"#);
        if ok(&doc) {
            break;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    drain(d.addr);
    d.svc.join().unwrap();

    println!("shed: cap {cap} held, all {probes} over-cap probes refused loudly");
    table.row(&[
        "shed".into(),
        clients.to_string(),
        "-".into(),
        "-".into(),
        "-".into(),
        format!("{cap} served / {probes} shed"),
    ]);
    doc.push(
        JsonObj::new()
            .str("phase", "shed")
            .int("clients", clients as u64)
            .int("cap", cap as u64)
            .int("served", cap as u64)
            .int("shed", probes as u64),
    );
}

/// Phase 3: M watch subscribers, one job, exact fan-out accounting.
fn watch_phase(clients: usize, doc: &mut BenchJson, table: &mut Table) {
    let watchers = (clients / 4).clamp(8, 256);
    let rounds = 512u64; // < WATCH_BUFFER - 1: lag is impossible
    let d = start("round-robin", watchers + 8);
    let ready = Arc::new(Barrier::new(watchers + 1));
    let handles: Vec<_> = (0..watchers)
        .map(|i| {
            let addr = d.addr;
            let ready = Arc::clone(&ready);
            spawn_client(move || {
                let mut stream = TcpStream::connect(addr).expect("watcher connect");
                stream
                    .set_read_timeout(Some(Duration::from_secs(120)))
                    .unwrap();
                writeln!(stream, r#"{{"op": "watch"}}"#).unwrap();
                stream.flush().unwrap();
                let mut reader = BufReader::new(stream);
                let mut line = String::new();
                reader.read_line(&mut line).unwrap();
                assert!(ok(&Json::parse(line.trim()).unwrap()), "watcher {i}: {line:?}");
                ready.wait();
                let mut lines = 0u64;
                loop {
                    line.clear();
                    reader.read_line(&mut line).unwrap();
                    let ev = Json::parse(line.trim())
                        .unwrap_or_else(|e| panic!("watcher {i}: bad event {line:?}: {e}"));
                    lines += 1;
                    if ev.str_field("event").unwrap() == "end" {
                        return lines;
                    }
                }
            })
        })
        .collect();
    ready.wait(); // every subscription acknowledged before the job starts
    let sw = Stopwatch::start();
    let reply = roundtrip(
        d.addr,
        &format!(
            r#"{{"op": "submit", "job": {{"name": "beacon", "fitness": "cubic", "particles": 64, "iters": {rounds}, "seed": 9}}}}"#
        ),
    );
    assert!(ok(&reply), "{reply:?}");
    wait_finished(d.addr, 1);
    drain(d.addr);
    let counts: Vec<u64> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    let wall = sw.elapsed_s();
    d.svc.join().unwrap();
    for (i, &n) in counts.iter().enumerate() {
        assert_eq!(n, rounds + 1, "watcher {i}: {rounds} reports + end");
    }
    let total: u64 = counts.iter().sum();
    let per_s = total as f64 / wall;

    println!(
        "watch: {watchers} subscribers × {} lines in {wall:.3}s — {per_s:.0} lines/s fan-out",
        rounds + 1
    );
    table.row(&[
        "watch".into(),
        watchers.to_string(),
        "-".into(),
        "-".into(),
        "-".into(),
        format!("{per_s:.0} lines/s"),
    ]);
    doc.push(
        JsonObj::new()
            .str("phase", "watch")
            .int("watchers", watchers as u64)
            .int("rounds", rounds)
            .int("lines", total)
            .num("wall_s", wall)
            .num("lines_per_s", per_s),
    );
}

fn main() {
    let cfg = BenchConfig::from_env();
    // Client counts by scale: the ci acceptance bar is >= 1000
    // concurrent TCP clients (ISSUE 8); paper scale doubles it, smoke
    // stays lightweight.
    let clients = if cfg.iter_divisor == 1 {
        2048
    } else if cfg.iter_divisor <= 50 {
        1024
    } else {
        128
    };
    println!(
        "service_saturation: {clients} TCP clients ({}), event-loop daemon, \
         weighted-fair admissions\n",
        cfg.scale_note()
    );

    let mut table = Table::new(
        "Service saturation — TCP event-loop front end",
        &["Phase", "Clients", "p50 ms", "p99 ms", "max ms", "Throughput / counts"],
    );
    let mut doc = BenchJson::new("service", &cfg);

    admission_phase(clients, &mut doc, &mut table);
    shed_phase(clients, &mut doc, &mut table);
    watch_phase(clients, &mut doc, &mut table);

    println!("\n{}", table.to_markdown());
    table.emit(&results_dir(), "service_saturation").unwrap();
    if let Some(path) = doc.emit().unwrap() {
        println!("wrote {}", path.display());
    }
    println!(
        "expectation: admission latency stays in the tens-of-ms class under a\n\
         full-table stampede (every submit is acknowledged at a round boundary),\n\
         over-cap clients always get the loud shed line, and watch fan-out\n\
         delivers every report to every subscriber exactly once."
    );
}
