//! Launcher integration: drive the real `cupso` binary end to end.

use std::process::Command;

fn cupso(args: &[&str]) -> (bool, String) {
    let out = Command::new(env!("CARGO_BIN_EXE_cupso"))
        .args(args)
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .output()
        .expect("spawn cupso");
    let text = format!(
        "{}{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    (out.status.success(), text)
}

#[test]
fn no_args_prints_usage() {
    let (ok, text) = cupso(&[]);
    assert!(ok);
    assert!(text.contains("Commands:"));
    assert!(text.contains("compare"));
}

#[test]
fn run_solves_small_cubic() {
    let (ok, text) = cupso(&[
        "run",
        "--particles",
        "128",
        "--iters",
        "200",
        "--engine",
        "queuelock",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("gbest fitness"), "{text}");
    assert!(text.contains("queue pushes"), "{text}");
    // 1-D cubic run at this size reaches the optimum.
    assert!(text.contains("900000"), "{text}");
}

#[test]
fn run_with_history_prints_table() {
    let (ok, text) = cupso(&[
        "run", "--particles", "64", "--iters", "100", "--history",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("## Convergence"), "{text}");
}

#[test]
fn run_rejects_bad_engine() {
    let (ok, text) = cupso(&["run", "--engine", "warp"]);
    assert!(!ok);
    assert!(text.contains("bad engine"), "{text}");
}

#[test]
fn run_accepts_config_file_with_override() {
    let dir = std::env::temp_dir().join("cupso-cli-test");
    std::fs::create_dir_all(&dir).unwrap();
    let cfg = dir.join("run.toml");
    std::fs::write(
        &cfg,
        "particles = 64\niters = 100\nengine = \"queue\"\nfitness = \"sphere\"\ndim = 3\n",
    )
    .unwrap();
    let (ok, text) = cupso(&["run", "--config", cfg.to_str().unwrap(), "--iters", "150"]);
    assert!(ok, "{text}");
    assert!(text.contains("150 iters"), "{text}");
    assert!(text.contains("engine=Queue"), "{text}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn compare_ranks_all_five() {
    let (ok, text) = cupso(&["compare", "--particles", "128", "--iters", "150"]);
    assert!(ok, "{text}");
    for name in ["CPU", "Reduction", "Loop Unrolling", "Queue", "Queue Lock"] {
        assert!(text.contains(name), "missing {name} in:\n{text}");
    }
}

#[test]
fn simulate_emits_all_three_tables() {
    let (ok, text) = cupso(&["simulate"]);
    assert!(ok, "{text}");
    assert!(text.contains("Table 3"), "{text}");
    assert!(text.contains("Table 4"), "{text}");
    assert!(text.contains("Table 5"), "{text}");
    // The estimated peak-then-drop: last Table 4 row's speedup below peak.
    assert!(text.contains("195.45"), "paper column present: {text}");
}

#[test]
fn info_lists_engines_and_artifacts() {
    let (ok, text) = cupso(&["info"]);
    assert!(ok, "{text}");
    assert!(text.contains("engines:"), "{text}");
    assert!(text.contains("cubic"), "{text}");
    // artifacts/ exists in the repo once `make artifacts` has run.
    assert!(
        text.contains("pso_queue") || text.contains("none"),
        "{text}"
    );
}

#[test]
fn xla_async_runs_on_artifacts() {
    let (ok, text) = cupso(&[
        "xla",
        "--variant",
        "queue",
        "--particles",
        "1024",
        "--dim",
        "1",
        "--shards",
        "2",
        "--iters",
        "100",
        "--scheduler",
        "async",
    ]);
    if !ok && (text.contains("without the `xla` feature") || text.contains("run `make artifacts`")) {
        // Plane-B is stubbed out (offline build) or artifacts are absent;
        // the launcher must still fail gracefully with a useful message.
        eprintln!("skipping xla CLI test: {text}");
        return;
    }
    assert!(ok, "{text}");
    assert!(text.contains("gbest fitness"), "{text}");
    assert!(text.contains("chunk calls"), "{text}");
}

#[test]
fn batch_runs_demo_config_and_reports() {
    let (ok, text) = cupso(&["batch", "--config", "config/batch_demo.toml"]);
    assert!(ok, "{text}");
    assert!(text.contains("Batch results"), "{text}");
    for job in [
        "cubic-target",
        "cubic-120d",
        "sphere-stall",
        "rastrigin-capped",
    ] {
        assert!(text.contains(job), "missing job {job} in:\n{text}");
    }
    // The target job stops early, the capped job at its cap.
    assert!(text.contains("target-reached"), "{text}");
    assert!(text.contains("max-iter"), "{text}");
    assert!(text.contains("aggregate:"), "{text}");
}

#[test]
fn batch_rejects_missing_config() {
    let (ok, text) = cupso(&["batch", "--config", "config/nope.toml"]);
    assert!(!ok);
    assert!(text.contains("nope.toml"), "{text}");
}

#[test]
fn batch_policy_override_edf() {
    let (ok, text) = cupso(&[
        "batch",
        "--config",
        "config/batch_demo.toml",
        "--policy",
        "edf",
        "--workers",
        "2",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("edf policy"), "{text}");
}

#[test]
fn batch_streams_and_batch_steps_override() {
    let (ok, text) = cupso(&[
        "batch",
        "--config",
        "config/batch_demo.toml",
        "--streams",
        "4",
        "--batch-steps",
        "16",
        "--workers",
        "2",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("4 streams"), "{text}");
    assert!(text.contains("16 steps/round"), "{text}");
    assert!(text.contains("Batch results"), "{text}");
    // The capped job still stops exactly at its step cap: batches are
    // clamped to explicit max_steps criteria.
    assert!(text.contains("max-iter"), "{text}");

    let (ok, text) = cupso(&[
        "batch",
        "--config",
        "config/batch_demo.toml",
        "--streams",
        "0",
    ]);
    assert!(!ok);
    assert!(text.contains("streams"), "{text}");
}

#[test]
fn unknown_command_fails_with_usage() {
    let (ok, text) = cupso(&["frobnicate"]);
    assert!(!ok);
    assert!(text.contains("unknown command"), "{text}");
}

/// A deterministic-engines-only batch (no queuelock/async), so the
/// per-job results table is bit-reproducible across interruption.
const DETERMINISTIC_BATCH: &str = r#"
[scheduler]
workers = 2
policy = "round-robin"
streams = 2
batch_steps = 3
preempt_quantum = 4

[jobs.alpha]
fitness = "cubic"
engine = "queue"
particles = 128
dim = 1
iters = 40
seed = 11

[jobs.beta]
fitness = "sphere"
engine = "reduction"
particles = 96
dim = 3
iters = 50
seed = 12

[jobs.gamma]
fitness = "cubic"
engine = "unroll"
particles = 130
dim = 1
iters = 30
seed = 13
max_steps = 25

[jobs.delta]
fitness = "rastrigin"
engine = "cpu"
particles = 64
dim = 2
iters = 35
seed = 14
"#;

/// Pull the per-job rows out of the "Batch results" markdown table —
/// every stable field (job, engine, workload, steps, stop reason, gbest)
/// lives on these lines.
fn batch_result_rows(text: &str) -> Vec<String> {
    let rows: Vec<String> = text
        .lines()
        .filter(|l| {
            ["alpha", "beta", "gamma", "delta"]
                .iter()
                .any(|job| l.starts_with(&format!("| {job}")))
        })
        .map(|l| l.to_string())
        .collect();
    assert_eq!(rows.len(), 4, "expected 4 result rows in:\n{text}");
    rows
}

#[test]
fn batch_checkpoint_suspend_then_resume_reproduces_results() {
    let dir = std::env::temp_dir().join("cupso-cli-ckpt-e2e");
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    let cfg = dir.join("batch.toml");
    std::fs::write(&cfg, DETERMINISTIC_BATCH).unwrap();
    let ckpt_dir = dir.join("snap");

    // Reference: the never-interrupted batch.
    let (ok, reference) = cupso(&["batch", "--config", cfg.to_str().unwrap()]);
    assert!(ok, "{reference}");
    let expected_rows = batch_result_rows(&reference);

    // Interrupted: suspend after 4 scheduling rounds…
    let (ok, text) = cupso(&[
        "batch",
        "--config",
        cfg.to_str().unwrap(),
        "--checkpoint-dir",
        ckpt_dir.to_str().unwrap(),
        "--suspend-after",
        "4",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("suspended 4 jobs"), "{text}");
    assert!(
        !text.contains("Batch results"),
        "suspended batch must not print results: {text}"
    );
    assert!(ckpt_dir.join("manifest.toml").exists());
    for i in 0..4 {
        assert!(ckpt_dir.join(format!("job_{i}.ckpt")).exists(), "job_{i}");
    }

    // …then resume reproduces the reference per-job results exactly.
    let (ok, resumed) = cupso(&["resume", ckpt_dir.to_str().unwrap()]);
    assert!(ok, "{resumed}");
    assert!(resumed.contains("cupso resume: 4 jobs"), "{resumed}");
    let resumed_rows = batch_result_rows(&resumed);
    assert_eq!(
        resumed_rows, expected_rows,
        "resumed batch diverged from the uninterrupted run"
    );
    // The capped job still stops at its exact cap across the boundary.
    assert!(resumed.contains("max-iter"), "{resumed}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn batch_periodic_checkpointing_completes_with_identical_results() {
    // --checkpoint-dir without --suspend-after: the batch runs to
    // completion through suspend/restore cycles every N rounds, leaving a
    // resumable snapshot behind — results identical to the plain run.
    let dir = std::env::temp_dir().join("cupso-cli-ckpt-periodic");
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    let cfg = dir.join("batch.toml");
    std::fs::write(&cfg, DETERMINISTIC_BATCH).unwrap();
    let ckpt_dir = dir.join("snap");

    let (ok, reference) = cupso(&["batch", "--config", cfg.to_str().unwrap()]);
    assert!(ok, "{reference}");
    let (ok, text) = cupso(&[
        "batch",
        "--config",
        cfg.to_str().unwrap(),
        "--checkpoint-dir",
        ckpt_dir.to_str().unwrap(),
        "--checkpoint-every",
        "3",
    ]);
    assert!(ok, "{text}");
    assert_eq!(batch_result_rows(&text), batch_result_rows(&reference));
    assert!(ckpt_dir.join("manifest.toml").exists(), "periodic snapshot");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn batch_checkpoint_keep_rotates_snapshots_and_resume_picks_latest() {
    // --checkpoint-keep N > 1: periodic snapshots land in numbered
    // snap_<seq>/ subdirectories, pruned to the latest N, and `cupso
    // resume <dir>` resolves the newest one — reproducing the
    // uninterrupted batch exactly.
    let dir = std::env::temp_dir().join("cupso-cli-ckpt-rotate");
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    let cfg = dir.join("batch.toml");
    std::fs::write(&cfg, DETERMINISTIC_BATCH).unwrap();
    let ckpt_dir = dir.join("snap");

    let (ok, reference) = cupso(&["batch", "--config", cfg.to_str().unwrap()]);
    assert!(ok, "{reference}");
    let expected_rows = batch_result_rows(&reference);

    let (ok, text) = cupso(&[
        "batch",
        "--config",
        cfg.to_str().unwrap(),
        "--checkpoint-dir",
        ckpt_dir.to_str().unwrap(),
        "--checkpoint-every",
        "2",
        "--checkpoint-keep",
        "2",
        "--suspend-after",
        "6",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("suspended 4 jobs"), "{text}");
    // Rotated layout: no root manifest, at most 2 snap_* dirs retained
    // (6 rounds at every=2 plus the suspension snapshot = 4 written).
    assert!(
        !ckpt_dir.join("manifest.toml").exists(),
        "keep > 1 must not write the flat layout"
    );
    let snaps: Vec<String> = std::fs::read_dir(&ckpt_dir)
        .unwrap()
        .filter_map(|e| e.unwrap().file_name().into_string().ok())
        .filter(|n| n.starts_with("snap_"))
        .collect();
    assert!(
        !snaps.is_empty() && snaps.len() <= 2,
        "expected 1..=2 retained snapshots, got {snaps:?}"
    );
    for snap in &snaps {
        assert!(ckpt_dir.join(snap).join("manifest.toml").exists(), "{snap}");
    }

    let (ok, resumed) = cupso(&["resume", ckpt_dir.to_str().unwrap()]);
    assert!(ok, "{resumed}");
    assert_eq!(
        batch_result_rows(&resumed),
        expected_rows,
        "resume from rotated snapshot diverged from the uninterrupted run"
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn batch_rejects_zero_checkpoint_keep() {
    let (ok, text) = cupso(&[
        "batch",
        "--config",
        "config/batch_demo.toml",
        "--checkpoint-keep",
        "0",
    ]);
    assert!(!ok);
    assert!(text.contains("checkpoint-keep"), "{text}");
}

#[test]
fn resume_rejects_missing_or_bad_directories() {
    let (ok, text) = cupso(&["resume"]);
    assert!(!ok);
    assert!(text.contains("checkpoint-dir"), "{text}");
    let (ok, text) = cupso(&["resume", "/nonexistent/cupso-snap"]);
    assert!(!ok);
    assert!(text.contains("manifest"), "{text}");
}

/// Kills the `cupso serve` child if a test assertion unwinds first.
struct ServeGuard(std::process::Child);

impl Drop for ServeGuard {
    fn drop(&mut self) {
        let _ = self.0.kill();
        let _ = self.0.wait();
    }
}

fn spawn_serve(args: &[&str]) -> ServeGuard {
    let child = Command::new(env!("CARGO_BIN_EXE_cupso"))
        .args(args)
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .stdout(std::process::Stdio::null())
        .stderr(std::process::Stdio::null())
        .spawn()
        .expect("spawn cupso serve");
    ServeGuard(child)
}

/// Poll `cupso status` until the daemon answers (the socket exists and
/// the protocol responds), failing after ~15s.
fn wait_for_service(socket: &str) {
    for _ in 0..300 {
        let (ok, _) = cupso(&["status", "--socket", socket]);
        if ok {
            return;
        }
        std::thread::sleep(std::time::Duration::from_millis(50));
    }
    panic!("service on {socket} never became reachable");
}

/// Wait for the serve child to exit on its own (after a drain), failing
/// after ~30s.
fn wait_for_exit(guard: &mut ServeGuard) {
    for _ in 0..600 {
        if let Some(status) = guard.0.try_wait().expect("try_wait") {
            assert!(status.success(), "serve exited with {status}");
            return;
        }
        std::thread::sleep(std::time::Duration::from_millis(50));
    }
    panic!("cupso serve did not exit after drain");
}

/// Deterministic-engine service config: two resident jobs with budgets
/// large enough that a prompt drain always catches them live.
const SERVE_BATCH: &str = r#"
[scheduler]
workers = 2
policy = "round-robin"
streams = 2
batch_steps = 3

[jobs.alpha]
fitness = "cubic"
engine = "queue"
particles = 128
dim = 1
iters = 150_000
seed = 11

[jobs.beta]
fitness = "sphere"
engine = "reduction"
particles = 96
dim = 2
iters = 120_000
seed = 12
"#;

/// The same two jobs plus the live-submitted third — the uninterrupted
/// reference batch for the drain→resume comparison.
const SERVE_REFERENCE_BATCH: &str = r#"
[scheduler]
workers = 2
policy = "round-robin"
streams = 2
batch_steps = 3

[jobs.alpha]
fitness = "cubic"
engine = "queue"
particles = 128
dim = 1
iters = 150_000
seed = 11

[jobs.beta]
fitness = "sphere"
engine = "reduction"
particles = 96
dim = 2
iters = 120_000
seed = 12

[jobs.gamma]
fitness = "cubic"
engine = "unroll"
particles = 130
dim = 1
iters = 100_000
seed = 13
"#;

/// The acceptance e2e: a live service accepts a submit after startup,
/// `drain` snapshots every live job (the dynamically admitted one
/// included), and `cupso resume` continues the snapshot to the exact
/// per-job results of the uninterrupted batch.
#[test]
fn serve_submit_drain_resume_reproduces_uninterrupted_batch() {
    let dir = std::env::temp_dir().join("cupso-cli-serve-e2e");
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    let socket = dir.join("svc.sock");
    let socket = socket.to_str().unwrap();
    let snap = dir.join("drain");
    let serve_cfg = dir.join("serve.toml");
    let reference_cfg = dir.join("reference.toml");
    std::fs::write(&serve_cfg, SERVE_BATCH).unwrap();
    std::fs::write(&reference_cfg, SERVE_REFERENCE_BATCH).unwrap();

    // Reference: all three jobs in one uninterrupted batch (admission
    // timing is invisible for the bit-exact engines).
    let (ok, reference) = cupso(&["batch", "--config", reference_cfg.to_str().unwrap()]);
    assert!(ok, "{reference}");
    let expected_rows: Vec<String> = reference
        .lines()
        .filter(|l| ["alpha", "beta", "gamma"].iter().any(|j| l.starts_with(&format!("| {j}"))))
        .map(|l| l.to_string())
        .collect();
    assert_eq!(expected_rows.len(), 3, "{reference}");

    let mut serve = spawn_serve(&[
        "serve",
        "--socket",
        socket,
        "--config",
        serve_cfg.to_str().unwrap(),
        "--checkpoint-dir",
        snap.to_str().unwrap(),
    ]);
    wait_for_service(socket);

    // Live admission after startup.
    let (ok, text) = cupso(&[
        "submit", "--socket", socket, "--name", "gamma", "--fitness", "cubic", "--engine",
        "unroll", "--particles", "130", "--dim", "1", "--iters", "100_000", "--seed", "13",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("submitted gamma"), "{text}");

    // Status sees all three live.
    let (ok, text) = cupso(&["status", "--socket", socket]);
    assert!(ok, "{text}");
    for job in ["alpha", "beta", "gamma"] {
        assert!(text.contains(job), "missing {job} in:\n{text}");
    }
    assert!(text.contains("3 live"), "{text}");

    // Drain: every live job lands in the snapshot.
    let (ok, text) = cupso(&["drain", "--socket", socket]);
    assert!(ok, "{text}");
    assert!(text.contains("drained 3 live jobs"), "{text}");
    wait_for_exit(&mut serve);
    assert!(snap.join("manifest.toml").exists());
    let manifest = std::fs::read_to_string(snap.join("manifest.toml")).unwrap();
    assert!(manifest.contains("source = \"serve\""), "{manifest}");

    // The drained service resumes through the standard resume path.
    let (ok, resumed) = cupso(&["resume", snap.to_str().unwrap()]);
    assert!(ok, "{resumed}");
    assert!(resumed.contains("cupso resume: 3 jobs"), "{resumed}");
    let resumed_rows: Vec<String> = resumed
        .lines()
        .filter(|l| ["alpha", "beta", "gamma"].iter().any(|j| l.starts_with(&format!("| {j}"))))
        .map(|l| l.to_string())
        .collect();
    assert_eq!(
        resumed_rows, expected_rows,
        "drained service diverged from the uninterrupted batch"
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn serve_status_cancel_and_idle_drain() {
    let dir = std::env::temp_dir().join("cupso-cli-serve-cancel");
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    let socket = dir.join("svc.sock");
    let socket = socket.to_str().unwrap();

    // No config, no checkpoint dir: an empty idle service.
    let mut serve = spawn_serve(&["serve", "--socket", socket]);
    wait_for_service(socket);

    let (ok, text) = cupso(&["status", "--socket", socket]);
    assert!(ok, "{text}");
    assert!(text.contains("0 live, 0 finished"), "{text}");

    // Submit an effectively endless job, see it, cancel it.
    let (ok, text) = cupso(&[
        "submit", "--socket", socket, "--name", "spin", "--fitness", "cubic", "--engine",
        "queue", "--particles", "64", "--iters", "1_000_000_000",
    ]);
    assert!(ok, "{text}");
    let (ok, text) = cupso(&["status", "--socket", socket]);
    assert!(ok, "{text}");
    assert!(text.contains("spin"), "{text}");
    // A duplicate submit of a live name is a loud protocol error.
    let (ok, text) = cupso(&[
        "submit", "--socket", socket, "--name", "spin", "--iters", "10",
    ]);
    assert!(!ok);
    assert!(text.contains("unique"), "{text}");
    let (ok, text) = cupso(&["cancel", "--socket", socket, "spin"]);
    assert!(ok, "{text}");
    assert!(text.contains("cancelled spin"), "{text}");
    // Cancelling it again fails loudly.
    let (ok, text) = cupso(&["cancel", "--socket", socket, "spin"]);
    assert!(!ok);
    assert!(text.contains("spin"), "{text}");

    // Idle drain needs no snapshot dir and shuts the daemon down.
    let (ok, text) = cupso(&["drain", "--socket", socket]);
    assert!(ok, "{text}");
    assert!(text.contains("no live jobs"), "{text}");
    wait_for_exit(&mut serve);

    // The socket is gone: clients fail loudly.
    let (ok, text) = cupso(&["status", "--socket", socket]);
    assert!(!ok);
    assert!(text.contains("connecting"), "{text}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn batch_suspend_requires_checkpoint_dir() {
    let (ok, text) = cupso(&[
        "batch",
        "--config",
        "config/batch_demo.toml",
        "--suspend-after",
        "2",
    ]);
    assert!(!ok);
    assert!(text.contains("--checkpoint-dir"), "{text}");
}

/// Like [`cupso`] but with one extra environment variable set.
fn cupso_env(args: &[&str], key: &str, val: &str) -> (bool, String) {
    let out = Command::new(env!("CARGO_BIN_EXE_cupso"))
        .args(args)
        .env(key, val)
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .output()
        .expect("spawn cupso");
    let text = format!(
        "{}{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    (out.status.success(), text)
}

/// Two deterministic long-budget jobs: still live whenever the crash
/// tests kill the daemon, finite enough for the recovery run to finish.
const CRASH_BATCH: &str = r#"
[scheduler]
workers = 2
policy = "round-robin"
streams = 2
batch_steps = 3

[jobs.alpha]
fitness = "cubic"
engine = "queue"
particles = 128
dim = 1
iters = 150_000
seed = 11

[jobs.beta]
fitness = "sphere"
engine = "reduction"
particles = 96
dim = 2
iters = 120_000
seed = 12
"#;

/// ISSUE 9 acceptance: `kill -9` a serving daemon mid-run, restart it on
/// the same `--checkpoint-dir`, and the jobs still finish with the
/// uninterrupted batch's exact results. The second incarnation gets no
/// `--config` — every live job it serves must come from the snapshot the
/// killed daemon left behind (the supervisor-restart recovery story).
#[test]
fn serve_survives_sigkill_and_warm_restart_finishes_the_jobs() {
    let dir = std::env::temp_dir().join("cupso-cli-sigkill");
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    let cfg = dir.join("batch.toml");
    std::fs::write(&cfg, CRASH_BATCH).unwrap();
    let snap = dir.join("snap");
    let socket1 = dir.join("svc1.sock");
    let socket2 = dir.join("svc2.sock");

    let (ok, reference) = cupso(&["batch", "--config", cfg.to_str().unwrap()]);
    assert!(ok, "{reference}");
    let expected_rows: Vec<String> = reference
        .lines()
        .filter(|l| l.starts_with("| alpha") || l.starts_with("| beta"))
        .map(|l| l.to_string())
        .collect();
    assert_eq!(expected_rows.len(), 2, "{reference}");

    // Incarnation 1: periodic live snapshots every 5 rounds.
    let mut first = spawn_serve(&[
        "serve",
        "--socket",
        socket1.to_str().unwrap(),
        "--config",
        cfg.to_str().unwrap(),
        "--checkpoint-dir",
        snap.to_str().unwrap(),
        "--checkpoint-every",
        "5",
    ]);
    wait_for_service(socket1.to_str().unwrap());
    // Wait for the first committed snapshot, then kill without warning —
    // SIGKILL, not a drain: no shutdown code runs, the daemon may die
    // mid-write. Whatever half-written state that leaves, the restart
    // must recover from the last *committed* snapshot.
    for _ in 0..300 {
        if snap.join("manifest.toml").exists() {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(50));
    }
    assert!(snap.join("manifest.toml").exists(), "no snapshot to kill over");
    first.0.kill().expect("SIGKILL serve");
    first.0.wait().expect("reap serve");

    // Incarnation 2: same snapshot dir, fresh socket, NO --config.
    let mut second = spawn_serve(&[
        "serve",
        "--socket",
        socket2.to_str().unwrap(),
        "--checkpoint-dir",
        snap.to_str().unwrap(),
    ]);
    wait_for_service(socket2.to_str().unwrap());
    let (ok, text) = cupso(&["status", "--socket", socket2.to_str().unwrap()]);
    assert!(ok, "{text}");
    assert!(
        text.contains("2 live"),
        "warm restart must adopt both snapshot jobs:\n{text}"
    );

    // Drain the adopted jobs and continue them through the standard
    // resume path: results must be bit-exact with the uninterrupted run.
    let (ok, text) = cupso(&["drain", "--socket", socket2.to_str().unwrap()]);
    assert!(ok, "{text}");
    assert!(text.contains("drained 2 live jobs"), "{text}");
    wait_for_exit(&mut second);

    let (ok, resumed) = cupso(&["resume", snap.to_str().unwrap()]);
    assert!(ok, "{resumed}");
    assert!(resumed.contains("cupso resume: 2 jobs"), "{resumed}");
    let resumed_rows: Vec<String> = resumed
        .lines()
        .filter(|l| l.starts_with("| alpha") || l.starts_with("| beta"))
        .map(|l| l.to_string())
        .collect();
    assert_eq!(
        resumed_rows, expected_rows,
        "recovery after kill -9 diverged from the uninterrupted run"
    );
    std::fs::remove_dir_all(&dir).ok();
}

/// `cupso submit --retries N` keeps knocking while the daemon is still
/// starting (the supervisor-restart window), and a duplicate of a live
/// name still fails immediately — retries never mask a real conflict.
#[test]
fn submit_retries_bridge_a_late_starting_daemon() {
    let dir = std::env::temp_dir().join("cupso-cli-submit-retry");
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    let socket = dir.join("svc.sock");
    let socket = socket.to_str().unwrap();

    // Client first, daemon later: the submit must survive the gap.
    let submit = Command::new(env!("CARGO_BIN_EXE_cupso"))
        .args([
            "submit", "--socket", socket, "--retries", "60", "--name", "solo", "--fitness",
            "cubic", "--engine", "queue", "--particles", "64", "--iters", "1_000_000_000",
        ])
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .stdout(std::process::Stdio::piped())
        .stderr(std::process::Stdio::piped())
        .spawn()
        .expect("spawn cupso submit");
    std::thread::sleep(std::time::Duration::from_millis(300));
    let mut serve = spawn_serve(&["serve", "--socket", socket]);
    let out = submit.wait_with_output().expect("submit output");
    let text = format!(
        "{}{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    assert!(out.status.success(), "{text}");
    assert!(text.contains("retrying"), "submit never had to retry:\n{text}");
    assert!(text.contains("submitted solo"), "{text}");

    // The name is live: a duplicate fails on its FIRST attempt — only a
    // retry of one's own submit treats "already live" as success.
    let (ok, text) = cupso(&[
        "submit", "--socket", socket, "--retries", "3", "--name", "solo", "--iters", "10",
    ]);
    assert!(!ok);
    assert!(text.contains("unique"), "{text}");

    let (ok, text) = cupso(&["cancel", "--socket", socket, "solo"]);
    assert!(ok, "{text}");
    let (ok, text) = cupso(&["drain", "--socket", socket]);
    assert!(ok, "{text}");
    wait_for_exit(&mut serve);
    std::fs::remove_dir_all(&dir).ok();
}

/// `CUPSO_FAULT_PLAN=persist@3=abort` crashes a periodic-checkpointing
/// batch at its 3rd persist point; `cupso resume` then finishes from the
/// last committed snapshot with the uninterrupted run's exact rows. The
/// same seam refuses a typo'd plan loudly instead of ignoring it.
#[test]
fn fault_plan_abort_at_persist_then_resume_reproduces_results() {
    let dir = std::env::temp_dir().join("cupso-cli-fault-abort");
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    let cfg = dir.join("batch.toml");
    std::fs::write(&cfg, DETERMINISTIC_BATCH).unwrap();
    let snap = dir.join("snap");

    let (ok, reference) = cupso(&["batch", "--config", cfg.to_str().unwrap()]);
    assert!(ok, "{reference}");
    let expected_rows = batch_result_rows(&reference);

    let (ok, text) = cupso_env(
        &[
            "batch",
            "--config",
            cfg.to_str().unwrap(),
            "--checkpoint-dir",
            snap.to_str().unwrap(),
            "--checkpoint-every",
            "3",
        ],
        "CUPSO_FAULT_PLAN",
        "persist@3=abort",
    );
    assert!(!ok, "the abort directive must kill the batch:\n{text}");
    assert!(text.contains("fault injection armed"), "{text}");
    assert!(text.contains("aborting process"), "{text}");
    assert!(
        snap.join("manifest.toml").exists(),
        "two persists committed before the abort"
    );

    let (ok, resumed) = cupso(&["resume", snap.to_str().unwrap()]);
    assert!(ok, "{resumed}");
    assert!(resumed.contains("cupso resume: 4 jobs"), "{resumed}");
    assert_eq!(
        batch_result_rows(&resumed),
        expected_rows,
        "resume after an injected crash diverged from the uninterrupted run"
    );

    // A typo'd plan is a loud startup error, never silently no faults.
    let (ok, text) = cupso_env(
        &["batch", "--config", cfg.to_str().unwrap()],
        "CUPSO_FAULT_PLAN",
        "chmod@1",
    );
    assert!(!ok);
    assert!(text.contains("CUPSO_FAULT_PLAN"), "{text}");
    std::fs::remove_dir_all(&dir).ok();
}
