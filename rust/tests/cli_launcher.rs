//! Launcher integration: drive the real `cupso` binary end to end.

use std::process::Command;

fn cupso(args: &[&str]) -> (bool, String) {
    let out = Command::new(env!("CARGO_BIN_EXE_cupso"))
        .args(args)
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .output()
        .expect("spawn cupso");
    let text = format!(
        "{}{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    (out.status.success(), text)
}

#[test]
fn no_args_prints_usage() {
    let (ok, text) = cupso(&[]);
    assert!(ok);
    assert!(text.contains("Commands:"));
    assert!(text.contains("compare"));
}

#[test]
fn run_solves_small_cubic() {
    let (ok, text) = cupso(&[
        "run",
        "--particles",
        "128",
        "--iters",
        "200",
        "--engine",
        "queuelock",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("gbest fitness"), "{text}");
    assert!(text.contains("queue pushes"), "{text}");
    // 1-D cubic run at this size reaches the optimum.
    assert!(text.contains("900000"), "{text}");
}

#[test]
fn run_with_history_prints_table() {
    let (ok, text) = cupso(&[
        "run", "--particles", "64", "--iters", "100", "--history",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("## Convergence"), "{text}");
}

#[test]
fn run_rejects_bad_engine() {
    let (ok, text) = cupso(&["run", "--engine", "warp"]);
    assert!(!ok);
    assert!(text.contains("bad engine"), "{text}");
}

#[test]
fn run_accepts_config_file_with_override() {
    let dir = std::env::temp_dir().join("cupso-cli-test");
    std::fs::create_dir_all(&dir).unwrap();
    let cfg = dir.join("run.toml");
    std::fs::write(
        &cfg,
        "particles = 64\niters = 100\nengine = \"queue\"\nfitness = \"sphere\"\ndim = 3\n",
    )
    .unwrap();
    let (ok, text) = cupso(&["run", "--config", cfg.to_str().unwrap(), "--iters", "150"]);
    assert!(ok, "{text}");
    assert!(text.contains("150 iters"), "{text}");
    assert!(text.contains("engine=Queue"), "{text}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn compare_ranks_all_five() {
    let (ok, text) = cupso(&["compare", "--particles", "128", "--iters", "150"]);
    assert!(ok, "{text}");
    for name in ["CPU", "Reduction", "Loop Unrolling", "Queue", "Queue Lock"] {
        assert!(text.contains(name), "missing {name} in:\n{text}");
    }
}

#[test]
fn simulate_emits_all_three_tables() {
    let (ok, text) = cupso(&["simulate"]);
    assert!(ok, "{text}");
    assert!(text.contains("Table 3"), "{text}");
    assert!(text.contains("Table 4"), "{text}");
    assert!(text.contains("Table 5"), "{text}");
    // The estimated peak-then-drop: last Table 4 row's speedup below peak.
    assert!(text.contains("195.45"), "paper column present: {text}");
}

#[test]
fn info_lists_engines_and_artifacts() {
    let (ok, text) = cupso(&["info"]);
    assert!(ok, "{text}");
    assert!(text.contains("engines:"), "{text}");
    assert!(text.contains("cubic"), "{text}");
    // artifacts/ exists in the repo once `make artifacts` has run.
    assert!(
        text.contains("pso_queue") || text.contains("none"),
        "{text}"
    );
}

#[test]
fn xla_async_runs_on_artifacts() {
    let (ok, text) = cupso(&[
        "xla",
        "--variant",
        "queue",
        "--particles",
        "1024",
        "--dim",
        "1",
        "--shards",
        "2",
        "--iters",
        "100",
        "--scheduler",
        "async",
    ]);
    if !ok && (text.contains("without the `xla` feature") || text.contains("run `make artifacts`")) {
        // Plane-B is stubbed out (offline build) or artifacts are absent;
        // the launcher must still fail gracefully with a useful message.
        eprintln!("skipping xla CLI test: {text}");
        return;
    }
    assert!(ok, "{text}");
    assert!(text.contains("gbest fitness"), "{text}");
    assert!(text.contains("chunk calls"), "{text}");
}

#[test]
fn batch_runs_demo_config_and_reports() {
    let (ok, text) = cupso(&["batch", "--config", "config/batch_demo.toml"]);
    assert!(ok, "{text}");
    assert!(text.contains("Batch results"), "{text}");
    for job in [
        "cubic-target",
        "cubic-120d",
        "sphere-stall",
        "rastrigin-capped",
    ] {
        assert!(text.contains(job), "missing job {job} in:\n{text}");
    }
    // The target job stops early, the capped job at its cap.
    assert!(text.contains("target-reached"), "{text}");
    assert!(text.contains("max-iter"), "{text}");
    assert!(text.contains("aggregate:"), "{text}");
}

#[test]
fn batch_rejects_missing_config() {
    let (ok, text) = cupso(&["batch", "--config", "config/nope.toml"]);
    assert!(!ok);
    assert!(text.contains("nope.toml"), "{text}");
}

#[test]
fn batch_policy_override_edf() {
    let (ok, text) = cupso(&[
        "batch",
        "--config",
        "config/batch_demo.toml",
        "--policy",
        "edf",
        "--workers",
        "2",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("edf policy"), "{text}");
}

#[test]
fn batch_streams_and_batch_steps_override() {
    let (ok, text) = cupso(&[
        "batch",
        "--config",
        "config/batch_demo.toml",
        "--streams",
        "4",
        "--batch-steps",
        "16",
        "--workers",
        "2",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("4 streams"), "{text}");
    assert!(text.contains("16 steps/round"), "{text}");
    assert!(text.contains("Batch results"), "{text}");
    // The capped job still stops exactly at its step cap: batches are
    // clamped to explicit max_steps criteria.
    assert!(text.contains("max-iter"), "{text}");

    let (ok, text) = cupso(&[
        "batch",
        "--config",
        "config/batch_demo.toml",
        "--streams",
        "0",
    ]);
    assert!(!ok);
    assert!(text.contains("streams"), "{text}");
}

#[test]
fn unknown_command_fails_with_usage() {
    let (ok, text) = cupso(&["frobnicate"]);
    assert!(!ok);
    assert!(text.contains("unknown command"), "{text}");
}

/// A deterministic-engines-only batch (no queuelock/async), so the
/// per-job results table is bit-reproducible across interruption.
const DETERMINISTIC_BATCH: &str = r#"
[scheduler]
workers = 2
policy = "round-robin"
streams = 2
batch_steps = 3
preempt_quantum = 4

[jobs.alpha]
fitness = "cubic"
engine = "queue"
particles = 128
dim = 1
iters = 40
seed = 11

[jobs.beta]
fitness = "sphere"
engine = "reduction"
particles = 96
dim = 3
iters = 50
seed = 12

[jobs.gamma]
fitness = "cubic"
engine = "unroll"
particles = 130
dim = 1
iters = 30
seed = 13
max_steps = 25

[jobs.delta]
fitness = "rastrigin"
engine = "cpu"
particles = 64
dim = 2
iters = 35
seed = 14
"#;

/// Pull the per-job rows out of the "Batch results" markdown table —
/// every stable field (job, engine, workload, steps, stop reason, gbest)
/// lives on these lines.
fn batch_result_rows(text: &str) -> Vec<String> {
    let rows: Vec<String> = text
        .lines()
        .filter(|l| {
            ["alpha", "beta", "gamma", "delta"]
                .iter()
                .any(|job| l.starts_with(&format!("| {job}")))
        })
        .map(|l| l.to_string())
        .collect();
    assert_eq!(rows.len(), 4, "expected 4 result rows in:\n{text}");
    rows
}

#[test]
fn batch_checkpoint_suspend_then_resume_reproduces_results() {
    let dir = std::env::temp_dir().join("cupso-cli-ckpt-e2e");
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    let cfg = dir.join("batch.toml");
    std::fs::write(&cfg, DETERMINISTIC_BATCH).unwrap();
    let ckpt_dir = dir.join("snap");

    // Reference: the never-interrupted batch.
    let (ok, reference) = cupso(&["batch", "--config", cfg.to_str().unwrap()]);
    assert!(ok, "{reference}");
    let expected_rows = batch_result_rows(&reference);

    // Interrupted: suspend after 4 scheduling rounds…
    let (ok, text) = cupso(&[
        "batch",
        "--config",
        cfg.to_str().unwrap(),
        "--checkpoint-dir",
        ckpt_dir.to_str().unwrap(),
        "--suspend-after",
        "4",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("suspended 4 jobs"), "{text}");
    assert!(
        !text.contains("Batch results"),
        "suspended batch must not print results: {text}"
    );
    assert!(ckpt_dir.join("manifest.toml").exists());
    for i in 0..4 {
        assert!(ckpt_dir.join(format!("job_{i}.ckpt")).exists(), "job_{i}");
    }

    // …then resume reproduces the reference per-job results exactly.
    let (ok, resumed) = cupso(&["resume", ckpt_dir.to_str().unwrap()]);
    assert!(ok, "{resumed}");
    assert!(resumed.contains("cupso resume: 4 jobs"), "{resumed}");
    let resumed_rows = batch_result_rows(&resumed);
    assert_eq!(
        resumed_rows, expected_rows,
        "resumed batch diverged from the uninterrupted run"
    );
    // The capped job still stops at its exact cap across the boundary.
    assert!(resumed.contains("max-iter"), "{resumed}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn batch_periodic_checkpointing_completes_with_identical_results() {
    // --checkpoint-dir without --suspend-after: the batch runs to
    // completion through suspend/restore cycles every N rounds, leaving a
    // resumable snapshot behind — results identical to the plain run.
    let dir = std::env::temp_dir().join("cupso-cli-ckpt-periodic");
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    let cfg = dir.join("batch.toml");
    std::fs::write(&cfg, DETERMINISTIC_BATCH).unwrap();
    let ckpt_dir = dir.join("snap");

    let (ok, reference) = cupso(&["batch", "--config", cfg.to_str().unwrap()]);
    assert!(ok, "{reference}");
    let (ok, text) = cupso(&[
        "batch",
        "--config",
        cfg.to_str().unwrap(),
        "--checkpoint-dir",
        ckpt_dir.to_str().unwrap(),
        "--checkpoint-every",
        "3",
    ]);
    assert!(ok, "{text}");
    assert_eq!(batch_result_rows(&text), batch_result_rows(&reference));
    assert!(ckpt_dir.join("manifest.toml").exists(), "periodic snapshot");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn batch_checkpoint_keep_rotates_snapshots_and_resume_picks_latest() {
    // --checkpoint-keep N > 1: periodic snapshots land in numbered
    // snap_<seq>/ subdirectories, pruned to the latest N, and `cupso
    // resume <dir>` resolves the newest one — reproducing the
    // uninterrupted batch exactly.
    let dir = std::env::temp_dir().join("cupso-cli-ckpt-rotate");
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    let cfg = dir.join("batch.toml");
    std::fs::write(&cfg, DETERMINISTIC_BATCH).unwrap();
    let ckpt_dir = dir.join("snap");

    let (ok, reference) = cupso(&["batch", "--config", cfg.to_str().unwrap()]);
    assert!(ok, "{reference}");
    let expected_rows = batch_result_rows(&reference);

    let (ok, text) = cupso(&[
        "batch",
        "--config",
        cfg.to_str().unwrap(),
        "--checkpoint-dir",
        ckpt_dir.to_str().unwrap(),
        "--checkpoint-every",
        "2",
        "--checkpoint-keep",
        "2",
        "--suspend-after",
        "6",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("suspended 4 jobs"), "{text}");
    // Rotated layout: no root manifest, at most 2 snap_* dirs retained
    // (6 rounds at every=2 plus the suspension snapshot = 4 written).
    assert!(
        !ckpt_dir.join("manifest.toml").exists(),
        "keep > 1 must not write the flat layout"
    );
    let snaps: Vec<String> = std::fs::read_dir(&ckpt_dir)
        .unwrap()
        .filter_map(|e| e.unwrap().file_name().into_string().ok())
        .filter(|n| n.starts_with("snap_"))
        .collect();
    assert!(
        !snaps.is_empty() && snaps.len() <= 2,
        "expected 1..=2 retained snapshots, got {snaps:?}"
    );
    for snap in &snaps {
        assert!(ckpt_dir.join(snap).join("manifest.toml").exists(), "{snap}");
    }

    let (ok, resumed) = cupso(&["resume", ckpt_dir.to_str().unwrap()]);
    assert!(ok, "{resumed}");
    assert_eq!(
        batch_result_rows(&resumed),
        expected_rows,
        "resume from rotated snapshot diverged from the uninterrupted run"
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn batch_rejects_zero_checkpoint_keep() {
    let (ok, text) = cupso(&[
        "batch",
        "--config",
        "config/batch_demo.toml",
        "--checkpoint-keep",
        "0",
    ]);
    assert!(!ok);
    assert!(text.contains("checkpoint-keep"), "{text}");
}

#[test]
fn resume_rejects_missing_or_bad_directories() {
    let (ok, text) = cupso(&["resume"]);
    assert!(!ok);
    assert!(text.contains("checkpoint-dir"), "{text}");
    let (ok, text) = cupso(&["resume", "/nonexistent/cupso-snap"]);
    assert!(!ok);
    assert!(text.contains("manifest"), "{text}");
}

#[test]
fn batch_suspend_requires_checkpoint_dir() {
    let (ok, text) = cupso(&[
        "batch",
        "--config",
        "config/batch_demo.toml",
        "--suspend-after",
        "2",
    ]);
    assert!(!ok);
    assert!(text.contains("--checkpoint-dir"), "{text}");
}
