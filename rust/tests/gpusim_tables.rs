//! Plane-C acceptance: the calibrated GTX-1080Ti model must reproduce
//! the *shape* of every paper table — who wins, by what factor, where
//! the crossovers and peaks fall — and track absolute values within a
//! generous band (it is a first-principles model, not a curve fit to
//! every row).

use cupso::config::EngineKind;
use cupso::gpusim::{estimate, estimate_cpu, paper, DeviceSpec, TABLE3_PARTICLES, TABLE5_ROWS};

const ITERS_1D: u64 = 100_000;

fn gpu() -> DeviceSpec {
    DeviceSpec::gtx_1080ti()
}

fn cpu() -> DeviceSpec {
    DeviceSpec::xeon_e3_1275()
}

/// |model/paper| must lie in [1/band, band].
fn within_band(model: f64, paper: f64, band: f64, what: &str) {
    let ratio = model / paper;
    assert!(
        (1.0 / band..=band).contains(&ratio),
        "{what}: model {model:.3}s vs paper {paper:.3}s (ratio {ratio:.2}, band {band})"
    );
}

#[test]
fn table3_absolute_times_within_2x() {
    for (n, p_cpu, p_red, p_unr, p_q, p_ql) in paper::TABLE3 {
        let m_cpu = estimate_cpu(&cpu(), n, 1, ITERS_1D);
        within_band(m_cpu, p_cpu, 1.5, &format!("T3 cpu n={n}"));
        let cases = [
            (EngineKind::Reduction, p_red),
            (EngineKind::LoopUnrolling, p_unr),
            (EngineKind::Queue, p_q),
            (EngineKind::QueueLock, p_ql),
        ];
        for (engine, p) in cases {
            let m = estimate(&gpu(), engine, n, 1, ITERS_1D).total(ITERS_1D);
            within_band(m, p, 2.0, &format!("T3 {engine:?} n={n}"));
        }
    }
}

#[test]
fn table3_ranking_matches_figure3() {
    // Figure 3's ranking: QueueLock < Queue < LoopUnrolling < Reduction
    // at every particle count; CPU crosses the GPU curves between 64 and
    // 256 particles.
    for n in TABLE3_PARTICLES {
        let r = estimate(&gpu(), EngineKind::Reduction, n, 1, 1).per_iter();
        let u = estimate(&gpu(), EngineKind::LoopUnrolling, n, 1, 1).per_iter();
        let q = estimate(&gpu(), EngineKind::Queue, n, 1, 1).per_iter();
        let l = estimate(&gpu(), EngineKind::QueueLock, n, 1, 1).per_iter();
        assert!(l < q && q < u && u < r, "ranking broken at n={n}");
    }
    let cpu_at = |n: usize| estimate_cpu(&cpu(), n, 1, ITERS_1D);
    let gpu_red =
        |n: usize| estimate(&gpu(), EngineKind::Reduction, n, 1, ITERS_1D).total(ITERS_1D);
    assert!(cpu_at(32) < gpu_red(32), "CPU should win tiny swarms");
    assert!(cpu_at(256) > gpu_red(256), "GPU should win by 256");
}

#[test]
fn table4_speedup_peaks_then_drops() {
    let mut speedups = Vec::new();
    for (n, _, _, paper_speedup) in paper::TABLE4 {
        let t_cpu = estimate_cpu(&cpu(), n, 1, ITERS_1D);
        let t_gpu = estimate(&gpu(), EngineKind::QueueLock, n, 1, ITERS_1D).total(ITERS_1D);
        let s = t_cpu / t_gpu;
        speedups.push((n, s, paper_speedup));
    }
    // The peak must be at 65 536 — not at the largest size (Table 4's
    // signature oversubscription drop at 131 072).
    let (peak_n, peak_s, _) = *speedups
        .iter()
        .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
        .unwrap();
    assert_eq!(peak_n, 65_536, "peak at n={peak_n} (speedups: {speedups:?})");
    // Headline: "about 200 times faster" at the peak.
    assert!(
        (130.0..=320.0).contains(&peak_s),
        "peak speedup {peak_s} not in the paper's ~200x class"
    );
    let last = speedups.last().unwrap();
    assert!(last.1 < peak_s, "no drop at 131072");
    // Monotone rise up to the peak.
    for w in speedups.windows(2) {
        if w[1].0 <= 65_536 {
            assert!(w[1].1 > w[0].1, "speedup not rising: {w:?}");
        }
    }
}

#[test]
fn table5_120d_speedups_track_paper() {
    let mut speedups = Vec::new();
    for (n, iters) in TABLE5_ROWS {
        let t_cpu = estimate_cpu(&cpu(), n, 120, iters);
        let t_gpu = estimate(&gpu(), EngineKind::Queue, n, 120, iters).total(iters);
        speedups.push((n, t_cpu / t_gpu));
    }
    // Paper peak: 225x at 32768. Memory-bound model peaks once launches
    // amortize; accept the peak anywhere in the saturated tail but the
    // magnitude must be in the 100-400x class there.
    let tail: Vec<_> = speedups.iter().filter(|(n, _)| *n >= 16384).collect();
    for (n, s) in &tail {
        assert!(
            (100.0..=400.0).contains(s),
            "120-D speedup at n={n} is {s}, outside the paper class"
        );
    }
    // Rising front edge, like Table 5.
    assert!(speedups[0].1 < speedups[4].1);
    // Absolute GPU times within 2x of the paper rows.
    for ((n, iters), (_, _, _, p_gpu, _)) in TABLE5_ROWS.iter().zip(paper::TABLE5.iter()) {
        let m = estimate(&gpu(), EngineKind::Queue, *n, 120, *iters).total(*iters);
        within_band(m, *p_gpu, 2.0, &format!("T5 queue n={n}"));
    }
}

#[test]
fn queue_lock_advantage_shrinks_in_high_dim() {
    // §6.3: in 120-D the step kernel dominates, so QueueLock's saved
    // launch matters little — the paper picks Queue there. Model must
    // agree: the relative gap at 120-D is far smaller than at 1-D.
    let gap = |d: usize, n: usize| {
        let q = estimate(&gpu(), EngineKind::Queue, n, d, 1).per_iter();
        let l = estimate(&gpu(), EngineKind::QueueLock, n, d, 1).per_iter();
        (q - l) / q
    };
    let gap_1d = gap(1, 2048);
    let gap_120d = gap(120, 32768);
    assert!(gap_1d > 0.3, "1-D gap {gap_1d} too small");
    assert!(gap_120d < 0.05, "120-D gap {gap_120d} should be negligible");
}
