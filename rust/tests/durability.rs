//! Crash-recovery determinism tier (ISSUE 9): kill the service at every
//! injected persist point — and, for the queue engine, at every
//! individual store write/fsync/rename — then warm-restart from the
//! surviving snapshot and prove the observable outcomes are bit-exact
//! with the never-interrupted run.
//!
//! The contract under test: a job's final `(steps, stop, gbest)` is an
//! *exactly-once observable* even though execution is at-least-once. A
//! crash may re-run work since the last durable snapshot, but the
//! deterministic engines replay it bit-identically, so the union of
//! results observed across incarnations equals the uninterrupted run's —
//! and any job observed on both sides of the crash must agree exactly.
//!
//! Faults are injected through the process-global store-I/O seam
//! ([`cupso::checkpoint::io`]); every test that installs an I/O
//! implementation holds [`lock_io`] and restores [`RealIo`] on drop.

use anyhow::Result;
use cupso::checkpoint::io::{
    self as storeio, FaultAction, FaultOp, FaultPlan, FaultyIo, RealIo, StoreIo,
};
use cupso::checkpoint::store::{load_snapshot, snapshot_present};
use cupso::checkpoint::JobCheckpoint;
use cupso::config::{BatchConfig, EngineKind};
use cupso::fitness::{Cubic, Objective};
use cupso::pso::PsoParams;
use cupso::scheduler::{JobScheduler, JobSpec};
use cupso::service::{ServiceEnd, ServiceSession};
use cupso::telemetry::{self, Counter};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex, MutexGuard};

/// The I/O seam is process-global, so fault-injecting tests serialize.
static IO_LOCK: Mutex<()> = Mutex::new(());

/// Holds the seam lock and restores [`RealIo`] on drop — even when the
/// test body panics, the next test starts from clean I/O.
struct IoGuard(#[allow(dead_code)] MutexGuard<'static, ()>);

impl Drop for IoGuard {
    fn drop(&mut self) {
        storeio::reset();
    }
}

fn lock_io() -> IoGuard {
    let locked = IO_LOCK.lock();
    IoGuard(locked.unwrap_or_else(std::sync::PoisonError::into_inner))
}

/// (name, iteration budget, seed).
type Job = (&'static str, u64, u64);

/// name → (final iter, stop reason, gbest bits): everything a client can
/// observe about a finished job, with the fitness compared bit-for-bit.
type Fp = BTreeMap<String, (u64, String, u64)>;

fn knobs(every: u64, keep: usize) -> BatchConfig {
    BatchConfig {
        workers: 2,
        policy: "round-robin".into(),
        streams: 1,
        batch_steps: 1,
        preempt_quantum: 0,
        pack: false,
        pack_min: 2,
        pack_max: 0,
        quota_jobs: 0,
        quota_steps: 0,
        checkpoint_every: every,
        checkpoint_keep: keep,
        telemetry: true,
        trace_dump: None,
        jobs: Vec::new(),
    }
}

/// The flight-recorder counter tracking fired directives against `op`
/// (ISSUE 10: injected faults are themselves observable, so a plan
/// whose directive never fires is a loud test failure, not a no-op).
fn fired_counter(op: FaultOp) -> Counter {
    match op {
        FaultOp::Write => Counter::FaultsFiredWrite,
        FaultOp::Fsync => Counter::FaultsFiredFsync,
        FaultOp::Rename => Counter::FaultsFiredRename,
        FaultOp::Persist => Counter::FaultsFiredPersist,
    }
}

/// Sum of all four fault-fired counters (multi-directive plans).
fn faults_fired_total() -> u64 {
    [FaultOp::Write, FaultOp::Fsync, FaultOp::Rename, FaultOp::Persist]
        .into_iter()
        .map(|op| telemetry::counter(fired_counter(op)))
        .sum()
}

fn spec(name: &str, engine: EngineKind, iters: u64, seed: u64) -> JobSpec {
    JobSpec::new(
        name,
        engine,
        PsoParams::paper_1d(48, iters),
        Arc::new(Cubic),
        Objective::Maximize,
        seed,
    )
}

fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("cupso-durability-{tag}"));
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Run one service incarnation to its end (or its death), recording the
/// finished-job telemetry the whole way — that record survives a fatal
/// persist error the same way a watching client's notes would.
fn run_observing(
    engine: EngineKind,
    dir: &Path,
    every: u64,
    keep: usize,
    jobs: &[Job],
    adopt: Option<&[JobCheckpoint]>,
) -> (Result<ServiceEnd>, Fp) {
    let mut seen: Fp = BTreeMap::new();
    let run = (|| -> Result<ServiceEnd> {
        let scheduler = JobScheduler::with_workers(2);
        let initial: Vec<JobSpec> = if adopt.is_some() {
            Vec::new()
        } else {
            jobs.iter()
                .map(|&(name, iters, seed)| spec(name, engine, iters, seed))
                .collect()
        };
        let (mut service, handle) = ServiceSession::new(
            &scheduler,
            knobs(every, keep),
            Some(dir.to_path_buf()),
            initial,
        )?;
        if let Some(ckpts) = adopt {
            service.adopt(ckpts)?;
        }
        drop(handle);
        service.run_with(|r| {
            if let Some(stop) = r.finished {
                seen.insert(
                    r.name.to_string(),
                    (r.iter, stop.to_string(), r.gbest_fit.to_bits()),
                );
            }
        })
    })();
    (run, seen)
}

/// Warm-restart recovery after a fatal injected fault: adopt the newest
/// committed snapshot (which an EIO-style fault can never have torn —
/// failed writes are never published), or start cold if the crash
/// predates the first commit point. Returns the recovery incarnation's
/// observed finishes.
fn recover(engine: EngineKind, dir: &Path, every: u64, keep: usize, jobs: &[Job]) -> Fp {
    if snapshot_present(dir) {
        let loaded = load_snapshot(dir).expect("a committed snapshot must load");
        loaded.report();
        assert!(
            loaded.is_clean(),
            "fail-stop faults must never commit torn snapshots"
        );
        let (end, seen) = run_observing(engine, dir, every, keep, jobs, Some(&loaded.jobs));
        end.expect("recovery run");
        seen
    } else {
        let (end, seen) = run_observing(engine, dir, every, keep, jobs, None);
        end.expect("cold restart");
        seen
    }
}

/// The exactly-once-observable check: pre-crash ∪ post-crash finishes
/// must equal the uninterrupted run's, and a job observed in both
/// incarnations must agree bit-for-bit.
fn check_union(pre: &Fp, post: &Fp, want: &Fp, what: &str) {
    let mut union = pre.clone();
    for (name, row) in post {
        if let Some(prev) = union.get(name) {
            assert_eq!(prev, row, "{what}: job {name} diverged across the crash");
        }
        union.insert(name.clone(), row.clone());
    }
    assert_eq!(
        &union, want,
        "{what}: observable outcomes differ from the uninterrupted run"
    );
}

/// Exhaustive crash sweep: size the run with a fault-free counting pass,
/// then kill it at the 1st, 2nd, … nth occurrence of `op` and prove
/// recovery each time.
fn crash_sweep(engine: EngineKind, op: FaultOp, tag: &str, jobs: &[Job], every: u64) {
    let _io = lock_io();
    let base = temp_dir(&format!("{tag}-base"));
    let counter = Arc::new(FaultyIo::new(FaultPlan::default()));
    storeio::install(counter.clone());
    let (end, want) = run_observing(engine, &base, every, 1, jobs, None);
    end.expect("baseline run");
    let points = counter.counts()[op.index()];
    storeio::reset();
    assert_eq!(want.len(), jobs.len(), "baseline must finish every job");
    assert!(points >= 2, "{tag}: workload too small ({points} {op:?} points)");

    for nth in 1..=points {
        let dir = temp_dir(&format!("{tag}-{nth}"));
        let plan = FaultPlan::single(op, nth, FaultAction::Eio);
        let fired_before = telemetry::counter(fired_counter(op));
        storeio::install(Arc::new(FaultyIo::new(plan)));
        let (crashed, seen_pre) = run_observing(engine, &dir, every, 1, jobs, None);
        storeio::reset();
        // Exactly-once injection: the single directive fired once — the
        // sweep position `nth` exists by the counting pass above, and a
        // fired directive is spent, never re-armed.
        assert_eq!(
            telemetry::counter(fired_counter(op)) - fired_before,
            1,
            "{tag}: {op:?}@{nth} must fire exactly once"
        );
        match crashed {
            // The fault landed on the best-effort final snapshot: the
            // daemon warns but the run itself is unaffected.
            Ok(_) => assert_eq!(
                seen_pre, want,
                "{tag}: surviving run diverged under {op:?}@{nth}"
            ),
            Err(_) => {
                let seen_post = recover(engine, &dir, every, 1, jobs);
                check_union(&seen_pre, &seen_post, &want, &format!("{tag} {op:?}@{nth}"));
            }
        }
    }
}

const PERSIST_JOBS: &[Job] = &[("alpha", 26, 9), ("beta", 34, 21), ("gamma", 21, 5)];
const OP_JOBS: &[Job] = &[("left", 10, 3), ("right", 14, 8)];

#[test]
fn cpu_crash_at_every_persist_point_recovers_bit_exact() {
    crash_sweep(
        EngineKind::SerialCpu,
        FaultOp::Persist,
        "cpu",
        PERSIST_JOBS,
        6,
    );
}

#[test]
fn reduction_crash_at_every_persist_point_recovers_bit_exact() {
    crash_sweep(
        EngineKind::Reduction,
        FaultOp::Persist,
        "red",
        PERSIST_JOBS,
        6,
    );
}

#[test]
fn unroll_crash_at_every_persist_point_recovers_bit_exact() {
    crash_sweep(
        EngineKind::LoopUnrolling,
        FaultOp::Persist,
        "unr",
        PERSIST_JOBS,
        6,
    );
}

#[test]
fn queue_crash_at_every_persist_point_recovers_bit_exact() {
    crash_sweep(EngineKind::Queue, FaultOp::Persist, "que", PERSIST_JOBS, 6);
}

#[test]
fn queue_crash_at_every_store_write_recovers_bit_exact() {
    crash_sweep(EngineKind::Queue, FaultOp::Write, "qwrite", OP_JOBS, 4);
}

#[test]
fn queue_crash_at_every_store_fsync_recovers_bit_exact() {
    crash_sweep(EngineKind::Queue, FaultOp::Fsync, "qfsync", OP_JOBS, 4);
}

#[test]
fn queue_crash_at_every_store_rename_recovers_bit_exact() {
    crash_sweep(EngineKind::Queue, FaultOp::Rename, "qrename", OP_JOBS, 4);
}

#[test]
fn seeded_fault_plans_recover_or_survive() {
    // Randomized single-fault coverage on top of the exhaustive sweeps:
    // same seed, same plan, so a failure here is replayable verbatim.
    let _io = lock_io();
    let every = 4;
    let base = temp_dir("seeded-base");
    let counter = Arc::new(FaultyIo::new(FaultPlan::default()));
    storeio::install(counter.clone());
    let (end, want) = run_observing(EngineKind::Queue, &base, every, 1, OP_JOBS, None);
    end.expect("baseline run");
    let counts = counter.counts();
    storeio::reset();
    let ops_per_kind = counts[..3].iter().copied().min().unwrap();
    assert!(ops_per_kind >= 2, "workload too small: {counts:?}");

    for seed in 0..24u64 {
        let plan = FaultPlan::seeded(seed, ops_per_kind);
        let dir = temp_dir(&format!("seeded-{seed}"));
        storeio::install(Arc::new(FaultyIo::new(plan)));
        let (crashed, seen_pre) = run_observing(EngineKind::Queue, &dir, every, 1, OP_JOBS, None);
        storeio::reset();
        match crashed {
            // Truncate faults report success (a silently lost tail), so
            // the run itself completes; EIO/ENOSPC on the final
            // best-effort snapshot also leaves the run whole.
            Ok(_) => assert_eq!(seen_pre, want, "seed {seed}: surviving run diverged"),
            Err(_) => {
                let seen_post = recover(EngineKind::Queue, &dir, every, 1, OP_JOBS);
                check_union(&seen_pre, &seen_post, &want, &format!("seed {seed}"));
            }
        }
    }
}

// ------------------------------------------------------------------
// Torn-snapshot recovery: quarantine, manifest commit point, rotated
// fallback.
// ------------------------------------------------------------------

/// Crash a run at the given persist point and return (its pre-crash
/// observations, the baseline fingerprint). `expect_faults` pins the
/// number of plan directives that must have fired — exactly, via the
/// flight-recorder fault counters.
fn crashed_dir(
    tag: &str,
    plan: &str,
    every: u64,
    keep: usize,
    expect_faults: u64,
) -> (PathBuf, Fp, Fp) {
    let base = temp_dir(&format!("{tag}-base"));
    let (end, want) = run_observing(EngineKind::Queue, &base, every, keep, OP_JOBS, None);
    end.expect("baseline run");
    let dir = temp_dir(tag);
    let fired_before = faults_fired_total();
    storeio::install(Arc::new(FaultyIo::new(FaultPlan::parse(plan).unwrap())));
    let (crashed, seen_pre) = run_observing(EngineKind::Queue, &dir, every, keep, OP_JOBS, None);
    storeio::reset();
    crashed.expect_err("the injected fault must kill the daemon");
    assert_eq!(
        faults_fired_total() - fired_before,
        expect_faults,
        "{tag}: plan {plan:?} must fire exactly {expect_faults} directive(s)"
    );
    (dir, seen_pre, want)
}

#[test]
fn torn_job_checkpoint_is_quarantined_and_the_rest_resumes() {
    let _io = lock_io();
    // Writes per flat persist: job_0, job_1, manifest. Tearing write #4
    // (persist 2's job_0) and dying at persist 3 leaves a *committed*
    // snapshot whose job_0 payload is torn — the checksum catches it.
    let (dir, seen_pre, want) =
        crashed_dir("torn-job", "write@4=truncate:16; persist@3", 4, 1, 2);
    let loaded = load_snapshot(&dir).expect("manifest is intact, load must succeed");
    loaded.report();
    assert!(!loaded.is_clean());
    assert_eq!(loaded.quarantined.len(), 1, "exactly job_0 is damaged");
    assert_eq!(loaded.quarantined[0].index, 0);
    assert!(
        loaded.quarantined[0].error.contains("job_0"),
        "quarantine report names the file: {}",
        loaded.quarantined[0].error
    );
    assert_eq!(loaded.jobs.len(), 1, "the undamaged job survives");

    let adopt = Some(loaded.jobs.as_slice());
    let (end, seen_post) = run_observing(EngineKind::Queue, &dir, 4, 1, OP_JOBS, adopt);
    end.expect("recovery with quarantine");
    // The surviving job's outcome is bit-exact; the torn job is *lost*,
    // but loudly — the quarantine row accounts for it.
    let mut union = seen_pre.clone();
    union.extend(seen_post.clone());
    for (name, row) in &union {
        assert_eq!(want.get(name), Some(row), "{name} not bit-exact");
    }
    assert_eq!(
        union.len() + loaded.quarantined.len(),
        want.len(),
        "every missing job must be accounted for by a quarantine row"
    );
}

#[test]
fn missing_job_checkpoint_is_quarantined_like_a_torn_one() {
    let _io = lock_io();
    let (dir, _seen_pre, _want) = crashed_dir("missing-job", "persist@3", 4, 1, 1);
    std::fs::remove_file(dir.join("job_1.ckpt")).expect("snapshot holds job_1");
    let loaded = load_snapshot(&dir).expect("manifest intact");
    assert_eq!(loaded.quarantined.len(), 1);
    assert_eq!(loaded.quarantined[0].index, 1);
    assert_eq!(loaded.jobs.len(), 1);
}

#[test]
fn torn_manifest_fails_the_load_loudly_never_a_silent_subset() {
    let _io = lock_io();
    // Write #6 is persist 2's manifest: tearing it leaves a flat layout
    // whose commit point itself is damaged — the whole load must fail
    // loudly (the manifest can no longer certify anything).
    let (dir, _seen_pre, _want) =
        crashed_dir("torn-manifest", "write@6=truncate:20; persist@3", 4, 1, 2);
    let err = load_snapshot(&dir).expect_err("torn manifest must not load");
    let msg = format!("{err:#}");
    assert!(msg.contains("manifest"), "error names the manifest: {msg}");
}

#[test]
fn rotated_fallback_prefers_newest_fully_valid_snapshot() {
    let _io = lock_io();
    let jobs: &[Job] = &[("left", 30, 3), ("right", 34, 8)];
    let every = 4;
    let keep = 3;
    let base = temp_dir("rot-base");
    let (end, want) = run_observing(EngineKind::Queue, &base, every, keep, jobs, None);
    end.expect("baseline run");

    // Die at persist 4: snap_000000..2 are committed and retained.
    let dir = temp_dir("rot-crash");
    let plan = FaultPlan::single(FaultOp::Persist, 4, FaultAction::Eio);
    let fired_before = telemetry::counter(Counter::FaultsFiredPersist);
    storeio::install(Arc::new(FaultyIo::new(plan)));
    let (crashed, seen_pre) = run_observing(EngineKind::Queue, &dir, every, keep, jobs, None);
    storeio::reset();
    crashed.expect_err("persist fault must kill the daemon");
    assert_eq!(
        telemetry::counter(Counter::FaultsFiredPersist) - fired_before,
        1,
        "the single persist directive must fire exactly once"
    );
    for snap in ["snap_000000", "snap_000001", "snap_000002"] {
        assert!(dir.join(snap).join("manifest.toml").is_file(), "{snap}");
    }

    // Wound the newest snapshot: recovery must fall back to the newest
    // fully-valid one rather than resume snap_2 minus a job.
    std::fs::write(dir.join("snap_000002").join("job_0.ckpt"), b"torn").unwrap();
    let loaded = load_snapshot(&dir).unwrap();
    loaded.report();
    assert_eq!(loaded.dir, dir.join("snap_000001"), "newest fully-valid wins");
    assert!(loaded.quarantined.is_empty());
    assert_eq!(loaded.skipped.len(), 1, "the damaged newer snapshot is reported");
    assert_eq!(loaded.jobs.len(), 2);

    let adopt = Some(loaded.jobs.as_slice());
    let (end, seen_post) = run_observing(EngineKind::Queue, &dir, every, keep, jobs, adopt);
    end.expect("recovery from the fallback snapshot");
    check_union(&seen_pre, &seen_post, &want, "rotated fallback");
}

#[test]
fn all_rotated_candidates_damaged_falls_back_with_quarantine_then_fails_loudly() {
    let _io = lock_io();
    let jobs: &[Job] = &[("left", 30, 3), ("right", 34, 8)];
    let every = 4;
    let keep = 3;
    let base = temp_dir("rot-all-base");
    let (end, want) = run_observing(EngineKind::Queue, &base, every, keep, jobs, None);
    end.expect("baseline run");

    let dir = temp_dir("rot-all-crash");
    let plan = FaultPlan::single(FaultOp::Persist, 4, FaultAction::Eio);
    storeio::install(Arc::new(FaultyIo::new(plan)));
    let (crashed, seen_pre) = run_observing(EngineKind::Queue, &dir, every, keep, jobs, None);
    storeio::reset();
    crashed.expect_err("persist fault must kill the daemon");

    // Every candidate loses job_0: the newest loadable one wins, with
    // its damage quarantined — a lossy but loud recovery.
    for snap in ["snap_000000", "snap_000001", "snap_000002"] {
        std::fs::write(dir.join(snap).join("job_0.ckpt"), b"torn").unwrap();
    }
    let loaded = load_snapshot(&dir).unwrap();
    loaded.report();
    assert_eq!(loaded.dir, dir.join("snap_000002"), "newest loadable wins");
    assert_eq!(loaded.quarantined.len(), 1);
    assert_eq!(loaded.jobs.len(), 1);

    let adopt = Some(loaded.jobs.as_slice());
    let (end, seen_post) = run_observing(EngineKind::Queue, &dir, every, keep, jobs, adopt);
    end.expect("lossy recovery");
    let mut union = seen_pre.clone();
    union.extend(seen_post);
    for (name, row) in &union {
        assert_eq!(want.get(name), Some(row), "{name} not bit-exact");
    }
    assert_eq!(union.len() + loaded.quarantined.len(), want.len());

    // With every manifest gone there is nothing to certify a snapshot:
    // the load fails loudly instead of inventing an empty resume.
    for snap in ["snap_000000", "snap_000001", "snap_000002"] {
        std::fs::remove_file(dir.join(snap).join("manifest.toml")).ok();
    }
    assert!(!snapshot_present(&dir));
    let err = load_snapshot(&dir).expect_err("no committed snapshot left");
    assert!(format!("{err:#}").contains("no manifest"), "{err:#}");
}

// ------------------------------------------------------------------
// Durable-write ordering: the discipline itself, observed op by op.
// ------------------------------------------------------------------

/// Logs every store operation (delegating to [`RealIo`]) so the test can
/// assert the write → fsync → rename → dir-fsync order and the
/// manifest-last commit point literally, not just by their effects.
struct RecordingIo {
    inner: RealIo,
    log: Mutex<Vec<String>>,
}

fn tail(p: &Path) -> String {
    let name = p.file_name().unwrap_or(p.as_os_str());
    name.to_string_lossy().into_owned()
}

impl StoreIo for RecordingIo {
    fn write(&self, path: &Path, bytes: &[u8]) -> std::io::Result<()> {
        self.log.lock().unwrap().push(format!("write {}", tail(path)));
        self.inner.write(path, bytes)
    }

    fn fsync_file(&self, path: &Path) -> std::io::Result<()> {
        self.log.lock().unwrap().push(format!("fsync {}", tail(path)));
        self.inner.fsync_file(path)
    }

    fn rename(&self, from: &Path, to: &Path) -> std::io::Result<()> {
        self.log
            .lock()
            .unwrap()
            .push(format!("rename {} -> {}", tail(from), tail(to)));
        self.inner.rename(from, to)
    }

    fn fsync_dir(&self, dir: &Path) -> std::io::Result<()> {
        self.log.lock().unwrap().push("fsyncdir".to_string());
        self.inner.fsync_dir(dir)
    }

    fn persist_point(&self) -> std::io::Result<()> {
        self.log.lock().unwrap().push("persist".to_string());
        Ok(())
    }
}

#[test]
fn snapshot_io_orders_fsync_before_publish_and_manifest_last() {
    let _io = lock_io();
    let dir = temp_dir("ordering");
    let rec = Arc::new(RecordingIo {
        inner: RealIo,
        log: Mutex::new(Vec::new()),
    });
    storeio::install(rec.clone());
    let (end, _) = run_observing(EngineKind::Queue, &dir, 2, 1, &[("only", 5, 1)], None);
    end.expect("run");
    storeio::reset();
    let log = rec.log.lock().unwrap().clone();

    // Group ops by persist point; nothing may touch the store outside one.
    let mut groups: Vec<Vec<String>> = Vec::new();
    for entry in log {
        if entry == "persist" {
            groups.push(Vec::new());
        } else {
            groups
                .last_mut()
                .expect("store ops before the first persist point")
                .push(entry);
        }
    }
    assert!(groups.len() >= 2, "want several persists: {groups:?}");
    for g in &groups {
        assert!(!g.is_empty() && g.len() % 4 == 0, "4 ops per file: {g:?}");
        let chunks: Vec<&[String]> = g.chunks(4).collect();
        for chunk in &chunks {
            let file = chunk[0]
                .strip_prefix("write ")
                .unwrap_or_else(|| panic!("chunk must start with its write: {chunk:?}"));
            assert!(file.ends_with(".tmp"), "writes land in the temp file: {chunk:?}");
            assert_eq!(chunk[1], format!("fsync {file}"), "data durable before publish");
            assert!(
                chunk[2].starts_with(&format!("rename {file} -> ")),
                "publish follows the fsync: {chunk:?}"
            );
            assert_eq!(chunk[3], "fsyncdir", "the publish itself is made durable");
        }
        let last = chunks.last().unwrap();
        assert!(
            last[2].ends_with("-> manifest.toml"),
            "manifest is the commit point — published last: {g:?}"
        );
    }
}
