//! Resume-equivalence tier: suspending a run to a [`RunCheckpoint`] and
//! restoring it — through the codec, onto a different pool/stream, or
//! inside a preemptive scheduler — must be invisible to the numerics of
//! the bit-exact engines.
//!
//! Mirrors the structure of `scheduler_determinism.rs`: a reference
//! trajectory is recorded once, then every suspension point / scheduling
//! configuration is replayed against it. The invariant under test is the
//! tentpole's: **bit-identical across any suspend/restore/migrate
//! schedule** — interrupting a job at any step k, restoring it (same or
//! different stream), and finishing yields a `RunOutput` identical to the
//! never-interrupted run, counters included.

use cupso::checkpoint::{JobCheckpoint, RunCheckpoint, RunKind};
use cupso::config::EngineKind;
use cupso::engine::{self, Engine, ParallelSettings, Run, StepReport};
use cupso::fitness::{Cubic, Objective};
use cupso::pso::{serial_sync, Counters, PsoParams, RunOutput};
use cupso::scheduler::{BatchRun, JobScheduler, JobSpec, SchedPolicy, StopReason};
use std::path::Path;
use std::sync::Arc;

/// The engines held to bit-exact resume equivalence.
const BIT_EXACT: [EngineKind; 4] = [
    EngineKind::SerialCpu,
    EngineKind::Reduction,
    EngineKind::LoopUnrolling,
    EngineKind::Queue,
];

fn assert_outputs_equal(a: &RunOutput, b: &RunOutput, what: &str) {
    assert_eq!(a.gbest_fit, b.gbest_fit, "{what}: fit");
    assert_eq!(a.gbest_pos, b.gbest_pos, "{what}: pos");
    assert_eq!(a.history, b.history, "{what}: history");
    assert_eq!(a.iters, b.iters, "{what}: iters");
    assert_counters_equal(&a.counters, &b.counters, what);
}

fn assert_counters_equal(a: &Counters, b: &Counters, what: &str) {
    assert_eq!(
        a.particle_updates, b.particle_updates,
        "{what}: particle_updates"
    );
    assert_eq!(a.queue_pushes, b.queue_pushes, "{what}: queue_pushes");
    assert_eq!(a.gbest_updates, b.gbest_updates, "{what}: gbest_updates");
    assert_eq!(
        a.pbest_improvements, b.pbest_improvements,
        "{what}: pbest_improvements"
    );
}

/// Drive a fresh run of `kind`, recording every step report and the final
/// output — the uninterrupted reference.
fn reference_trajectory(
    kind: EngineKind,
    params: &PsoParams,
    seed: u64,
) -> (Vec<StepReport>, RunOutput) {
    let mut e = engine::build(kind, 4).unwrap();
    let mut run = e.prepare(params, &Cubic, Objective::Maximize, seed);
    let mut reports = Vec::new();
    loop {
        let rep = run.step();
        let done = rep.done;
        reports.push(rep);
        if done {
            break;
        }
    }
    (reports, run.finish())
}

/// The tentpole assertion: for every bit-exact engine and every
/// suspension step k (0 = before the first step, max_iter = after the
/// last), checkpoint → codec round-trip → restore → continue is
/// bit-identical to the uninterrupted run — reports, final output and
/// counters.
#[test]
fn checkpoint_at_every_step_resumes_bit_exactly() {
    for params in [PsoParams::paper_1d(300, 12), PsoParams::paper_120d(40, 8)] {
        for kind in BIT_EXACT {
            let what = format!("{kind:?} n={} d={}", params.n, params.dim);
            let (reports, reference) = reference_trajectory(kind, &params, 42);
            for k in 0..=params.max_iter {
                // Fresh run, suspended after k steps.
                let mut e = engine::build(kind, 4).unwrap();
                let mut run = e.prepare(&params, &Cubic, Objective::Maximize, 42);
                for _ in 0..k {
                    run.step();
                }
                let ckpt = run.checkpoint();
                assert_eq!(ckpt.iter, k, "{what}: checkpoint iter");
                drop(run);
                // Through the wire format (proves the codec carries the
                // full state, not just the in-memory struct).
                let ckpt = RunCheckpoint::decode(&ckpt.encode())
                    .unwrap_or_else(|e| panic!("{what} k={k}: decode failed: {e}"));
                // Restore on a *different* pool (2 workers vs 4 — the
                // engines' numerics must not depend on the substrate).
                let mut resumed =
                    engine::restore_with(&ckpt, ParallelSettings::with_workers(2), &Cubic)
                        .unwrap_or_else(|e| panic!("{what} k={k}: restore failed: {e}"));
                assert_eq!(resumed.iters_done(), k, "{what} k={k}");
                for expected in &reports[k as usize..] {
                    let rep = resumed.step();
                    assert_eq!(&rep, expected, "{what} k={k}: continued step diverged");
                }
                let out = resumed.finish();
                assert_outputs_equal(&out, &reference, &format!("{what} k={k}"));
            }
        }
    }
}

#[test]
fn sync_serial_oracle_checkpoints_and_resumes() {
    // The second serial reference (the PPSO oracle) round-trips too, via
    // the kind-dispatching restore path.
    let params = PsoParams::paper_120d(24, 15);
    let reference = serial_sync::run(&params, &Cubic, Objective::Maximize, 9);
    let mut run = Box::new(serial_sync::SyncSerialRun::new(
        &params,
        &Cubic,
        Objective::Maximize,
        9,
    ));
    for _ in 0..7 {
        run.step();
    }
    let ckpt = RunCheckpoint::decode(&run.checkpoint().encode()).unwrap();
    assert_eq!(ckpt.kind, RunKind::SerialSync);
    let mut resumed =
        engine::restore_with(&ckpt, ParallelSettings::with_workers(1), &Cubic).unwrap();
    while !resumed.step().done {}
    let out = resumed.finish();
    assert_outputs_equal(&out, &reference, "sync-serial oracle resume");
}

#[test]
fn queue_lock_and_async_restore_to_valid_states() {
    // The relaxed engines: checkpoints are taken at grid-quiescent
    // boundaries, so a restored run must be a *valid* continuation
    // (monotone, bound-respecting, full budget) even though the exact
    // trajectory is not replayable.
    for kind in [EngineKind::QueueLock, EngineKind::AsyncPersistent] {
        let params = PsoParams::paper_1d(512, 30);
        let mut e = engine::build(kind, 4).unwrap();
        let mut run = e.prepare(&params, &Cubic, Objective::Maximize, 3);
        for _ in 0..11 {
            run.step();
        }
        let fit_at_suspend = run.gbest_fit();
        let ckpt = RunCheckpoint::decode(&run.checkpoint().encode()).unwrap();
        assert_eq!(ckpt.iter, 11);
        let mut resumed =
            engine::restore_with(&ckpt, ParallelSettings::with_workers(4), &Cubic).unwrap();
        assert_eq!(resumed.gbest_fit(), fit_at_suspend, "{kind:?}");
        while !resumed.step().done {}
        let out = resumed.finish();
        assert_eq!(out.iters, 30, "{kind:?}");
        for w in out.history.windows(2) {
            assert!(w[1].1 >= w[0].1, "{kind:?}: gbest worsened across resume");
        }
        assert!(
            out.gbest_fit >= fit_at_suspend,
            "{kind:?}: resume lost progress"
        );
        // With a single block the async step-run has no room to race:
        // resume is bit-exact against the synchronous oracle there.
        if kind == EngineKind::AsyncPersistent {
            let small = PsoParams::paper_1d(200, 20);
            let oracle = serial_sync::run(&small, &Cubic, Objective::Maximize, 7);
            let mut e = engine::build(kind, 4).unwrap();
            let mut run = e.prepare(&small, &Cubic, Objective::Maximize, 7);
            for _ in 0..9 {
                run.step();
            }
            let ckpt = run.checkpoint();
            let mut resumed =
                engine::restore_with(&ckpt, ParallelSettings::with_workers(4), &Cubic).unwrap();
            while !resumed.step().done {}
            let out = resumed.finish();
            assert_eq!(out.gbest_fit, oracle.gbest_fit, "single-block async resume");
            assert_eq!(out.gbest_pos, oracle.gbest_pos, "single-block async resume");
        }
    }
}

#[test]
fn restore_rejects_kind_mismatch_and_inconsistency() {
    let params = PsoParams::paper_1d(64, 6);
    let mut e = engine::build(EngineKind::Queue, 2).unwrap();
    let mut run = e.prepare(&params, &Cubic, Objective::Maximize, 1);
    run.step();
    let ckpt = run.checkpoint();

    // A Queue checkpoint does not restore on Reduction — and the
    // unrolled variant is likewise its own kind.
    let mut reduction = engine::build(EngineKind::Reduction, 2).unwrap();
    let err = reduction.restore(&ckpt, &Cubic).unwrap_err().to_string();
    assert!(err.contains("queue"), "{err}");
    assert!(err.contains("reduction"), "{err}");
    let mut unrolled = engine::build(EngineKind::LoopUnrolling, 2).unwrap();
    assert!(unrolled.restore(&ckpt, &Cubic).is_err());

    // Structural inconsistency is a loud error, not a corrupt run.
    let mut torn = ckpt.clone();
    torn.gbest_pos.push(0.0);
    let mut queue = engine::build(EngineKind::Queue, 2).unwrap();
    assert!(queue.restore(&torn, &Cubic).is_err());
    let mut overrun = ckpt.clone();
    overrun.iter = params.max_iter + 1;
    assert!(queue.restore(&overrun, &Cubic).is_err());
}

/// The scheduler half of the tentpole: preemption (suspend after a
/// quantum) and migration (restore on whichever stream is free) under
/// both policies, several stream counts and batch sizes — per-job
/// results bit-identical to solo one-shot runs.
#[test]
fn preemptive_scheduling_with_migration_matches_solo() {
    let mk_specs = || -> Vec<JobSpec> {
        let mut specs = vec![
            JobSpec::new(
                "cpu",
                EngineKind::SerialCpu,
                PsoParams::paper_1d(150, 18),
                Arc::new(Cubic),
                Objective::Maximize,
                21,
            ),
            JobSpec::new(
                "r1",
                EngineKind::Reduction,
                PsoParams::paper_1d(300, 25),
                Arc::new(Cubic),
                Objective::Maximize,
                1,
            ),
            JobSpec::new(
                "u1",
                EngineKind::LoopUnrolling,
                PsoParams::paper_120d(40, 12),
                Arc::new(Cubic),
                Objective::Maximize,
                3,
            ),
            JobSpec::new(
                "q1",
                EngineKind::Queue,
                PsoParams::paper_1d(513, 20),
                Arc::new(Cubic),
                Objective::Maximize,
                5,
            ),
            JobSpec::new(
                "q2",
                EngineKind::Queue,
                PsoParams::paper_120d(100, 10),
                Arc::new(Cubic),
                Objective::Maximize,
                6,
            ),
        ];
        specs[1].deadline = Some(30);
        specs[3].deadline = Some(15);
        specs
    };
    let solo: Vec<RunOutput> = mk_specs()
        .iter()
        .map(|s| {
            engine::build(s.engine, 4)
                .unwrap()
                .run(&s.params, &Cubic, Objective::Maximize, s.seed)
        })
        .collect();
    for (streams, batch, quantum, policy) in [
        (1, 1, 1, SchedPolicy::RoundRobin), // suspend/restore every step
        (2, 1, 3, SchedPolicy::RoundRobin),
        (2, 4, 2, SchedPolicy::RoundRobin), // quantum < batch: park every round
        (3, 2, 5, SchedPolicy::EarliestDeadlineFirst),
        (2, 1, 1, SchedPolicy::EarliestDeadlineFirst),
    ] {
        let scheduler = JobScheduler::with_streams(4, streams)
            .policy(policy)
            .batch_steps(batch)
            .preempt_quantum(quantum);
        let outcomes = scheduler.run(&mk_specs()).unwrap();
        for (outcome, reference) in outcomes.iter().zip(&solo) {
            assert_eq!(outcome.stop, StopReason::Exhausted, "{}", outcome.name);
            assert_outputs_equal(
                &outcome.output,
                reference,
                &format!(
                    "S={streams} batch={batch} q={quantum} {policy} job {}",
                    outcome.name
                ),
            );
        }
    }
}

/// Suspend a whole batch mid-flight, serialize every job checkpoint
/// through the codec, and resume it on a scheduler with a *different
/// stream layout* (cross-session migration): identical results.
#[test]
fn batch_suspend_codec_roundtrip_resume_on_different_streams() {
    let mk_specs = || -> Vec<JobSpec> {
        (0..5)
            .map(|j| {
                JobSpec::new(
                    &format!("t{j}"),
                    [
                        EngineKind::Queue,
                        EngineKind::Reduction,
                        EngineKind::LoopUnrolling,
                    ][j % 3],
                    PsoParams::paper_1d(100 + j * 50, 14 + j as u64),
                    Arc::new(Cubic),
                    Objective::Maximize,
                    j as u64,
                )
            })
            .collect()
    };
    let reference = JobScheduler::with_streams(4, 2).run(&mk_specs()).unwrap();
    for policy in [SchedPolicy::RoundRobin, SchedPolicy::EarliestDeadlineFirst] {
        // Phase 1: 2 streams, cap after 6 rounds.
        let specs = mk_specs();
        let first = JobScheduler::with_streams(4, 2).policy(policy);
        let snap = match first.run_session(&specs, None, Some(6), |_| {}).unwrap() {
            BatchRun::Suspended(snap) => snap,
            BatchRun::Complete(_) => panic!("{policy}: batch cannot finish in 6 rounds"),
        };
        // Serialize every job through the wire format.
        let snap: Vec<JobCheckpoint> = snap
            .iter()
            .map(|j| JobCheckpoint::decode(&j.encode()).unwrap())
            .collect();
        // Phase 2: resumed on 3 streams with preemption enabled — every
        // job migrates relative to its old pinning at some point.
        let second = JobScheduler::with_streams(4, 3)
            .policy(policy)
            .preempt_quantum(2);
        let outcomes = match second.run_session(&specs, Some(&snap), None, |_| {}).unwrap() {
            BatchRun::Complete(outcomes) => outcomes,
            BatchRun::Suspended(_) => panic!("uncapped session must complete"),
        };
        for (outcome, reference) in outcomes.iter().zip(&reference) {
            assert_eq!(outcome.steps, reference.steps, "{policy} {}", outcome.name);
            assert_eq!(outcome.stop, reference.stop, "{policy} {}", outcome.name);
            assert_outputs_equal(
                &outcome.output,
                &reference.output,
                &format!("{policy} resumed job {}", outcome.name),
            );
        }
    }
}

#[test]
fn suspended_snapshot_carries_finished_jobs_through_resume() {
    // A job that terminates before the round cap must survive the
    // suspend/resume cycle with its stop reason and exact output.
    let mk_specs = || {
        vec![
            JobSpec::new(
                "short",
                EngineKind::Queue,
                PsoParams::paper_1d(64, 3),
                Arc::new(Cubic),
                Objective::Maximize,
                1,
            ),
            JobSpec::new(
                "long",
                EngineKind::Queue,
                PsoParams::paper_1d(64, 30),
                Arc::new(Cubic),
                Objective::Maximize,
                2,
            ),
        ]
    };
    let reference = JobScheduler::with_workers(2).run(&mk_specs()).unwrap();
    let scheduler = JobScheduler::with_workers(2);
    let specs = mk_specs();
    // 10 rounds: "short" (3 steps) finished, "long" (30) still live.
    let snap = match scheduler.run_session(&specs, None, Some(10), |_| {}).unwrap() {
        BatchRun::Suspended(snap) => snap,
        BatchRun::Complete(_) => panic!("long job cannot finish in 10 rounds"),
    };
    assert_eq!(snap[0].stop, Some(StopReason::Exhausted.code()));
    assert_eq!(snap[0].run.iter, 3);
    assert_eq!(snap[1].stop, None);
    let outcomes = match scheduler.run_session(&specs, Some(&snap), None, |_| {}).unwrap() {
        BatchRun::Complete(outcomes) => outcomes,
        BatchRun::Suspended(_) => panic!("uncapped session must complete"),
    };
    for (outcome, reference) in outcomes.iter().zip(&reference) {
        assert_eq!(outcome.stop, reference.stop, "{}", outcome.name);
        assert_eq!(outcome.steps, reference.steps, "{}", outcome.name);
        assert_outputs_equal(&outcome.output, &reference.output, &outcome.name);
    }
}

// ---------------------------------------------------------------------
// Golden fixture: pins the version-1 wire format. The files under
// rust/tests/fixtures/ are committed artifacts; today's decoder must keep
// reading them forever. Regenerate (only on a deliberate, compatible
// format change) with:
//   cargo test --test checkpoint_resume regenerate_golden_fixtures -- --ignored
// ---------------------------------------------------------------------

fn fixture_dir() -> std::path::PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("rust/tests/fixtures")
}

/// The exact checkpoint the golden fixture encodes — hand-built so the
/// expected values below are self-evident.
fn golden_run_checkpoint() -> RunCheckpoint {
    RunCheckpoint {
        version: cupso::checkpoint::VERSION,
        kind: RunKind::Queue,
        objective: Objective::Maximize,
        seed: 7,
        params: PsoParams {
            w: 1.0,
            c1: 2.0,
            c2: 2.0,
            min_pos: -100.0,
            max_pos: 100.0,
            max_v: 100.0,
            max_iter: 6,
            n: 4,
            dim: 2,
        },
        iter: 3,
        gbest_fit: 123.456,
        gbest_pos: vec![1.5, -2.5],
        history: vec![(0, 50.0), (2, 123.456)],
        counters: Counters {
            pbest_improvements: 5,
            queue_pushes: 4,
            gbest_updates: 2,
            particle_updates: 12,
        },
        swarm: cupso::pso::SwarmState {
            n: 4,
            dim: 2,
            pos: vec![1.5, -2.5, 3.0, 4.0, 5.0, -6.0, 7.0, 8.0],
            vel: vec![0.5, -0.5, 0.25, -0.0, 1.0, -1.0, 2.0, -2.0],
            fit: vec![123.456, -1.0, f64::from_bits(0x7ff8000000000000), 0.0],
            pbest_pos: vec![1.5, -2.5, 3.0, 4.0, 5.0, -6.0, 7.0, 8.0],
            pbest_fit: vec![123.456, -1.0, -2.0, 0.0],
        },
    }
}

fn golden_job_checkpoint() -> JobCheckpoint {
    JobCheckpoint {
        name: "golden".into(),
        fitness: "cubic".into(),
        stalled: 1,
        stop: None,
        target_fit: Some(899000.0),
        stall_window: None,
        max_steps: Some(100),
        deadline: Some(50),
        run: std::sync::Arc::new(golden_run_checkpoint()),
    }
}

#[test]
fn golden_fixture_v1_still_decodes() {
    let bytes = std::fs::read(fixture_dir().join("run_v1.ckpt"))
        .expect("committed fixture rust/tests/fixtures/run_v1.ckpt");
    let ckpt = RunCheckpoint::decode(&bytes).expect("version-1 fixture must decode forever");
    let expected = golden_run_checkpoint();
    assert_eq!(ckpt.kind, RunKind::Queue);
    assert_eq!(ckpt.objective, Objective::Maximize);
    assert_eq!(ckpt.seed, 7);
    assert_eq!(ckpt.iter, 3);
    assert_eq!(ckpt.params.n, 4);
    assert_eq!(ckpt.params.dim, 2);
    assert_eq!(ckpt.params.max_iter, 6);
    assert_eq!(ckpt.params.max_v, 100.0);
    assert_eq!(ckpt.gbest_fit, 123.456);
    assert_eq!(ckpt.gbest_pos, vec![1.5, -2.5]);
    assert_eq!(ckpt.history, expected.history);
    assert_eq!(ckpt.counters.queue_pushes, 4);
    assert_eq!(ckpt.counters.gbest_updates, 2);
    let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
    assert_eq!(bits(&ckpt.swarm.pos), bits(&expected.swarm.pos));
    assert_eq!(bits(&ckpt.swarm.vel), bits(&expected.swarm.vel), "-0.0 sign");
    assert_eq!(bits(&ckpt.swarm.fit), bits(&expected.swarm.fit), "NaN bits");
    assert_eq!(bits(&ckpt.swarm.pbest_fit), bits(&expected.swarm.pbest_fit));

    // And it is live state, not just bytes: restoring it yields a
    // deterministic Queue run that completes its remaining 3 iterations.
    let restore = || {
        let mut run =
            engine::restore_with(&ckpt, ParallelSettings::with_workers(2), &Cubic).unwrap();
        while !run.step().done {}
        run.finish()
    };
    let a = restore();
    let b = restore();
    assert_eq!(a.iters, 6);
    assert_eq!(a.gbest_fit, b.gbest_fit, "restored continuation must be deterministic");
    assert_eq!(a.gbest_pos, b.gbest_pos);
    assert_eq!(a.history, b.history);
    assert!(a.gbest_fit >= 123.456, "gbest is monotone across restore");
}

#[test]
fn golden_job_fixture_v1_still_decodes() {
    let bytes = std::fs::read(fixture_dir().join("job_v1.ckpt"))
        .expect("committed fixture rust/tests/fixtures/job_v1.ckpt");
    let job = JobCheckpoint::decode(&bytes).expect("version-1 job fixture must decode forever");
    assert_eq!(&*job.name, "golden");
    assert_eq!(job.fitness, "cubic");
    assert_eq!(job.stalled, 1);
    assert_eq!(job.stop, None);
    assert_eq!(job.target_fit, Some(899000.0));
    assert_eq!(job.stall_window, None);
    assert_eq!(job.max_steps, Some(100));
    assert_eq!(job.deadline, Some(50));
    assert_eq!(job.run.kind, RunKind::Queue);
    assert_eq!(job.run.iter, 3);
}

#[test]
fn fixture_bytes_match_current_encoder() {
    // The committed fixtures are byte-for-byte what today's encoder
    // produces for the same state — encoder and decoder pin the same
    // format. (If this fails but the decode tests pass, the encoder
    // changed silently: bump the version.)
    let run_bytes = std::fs::read(fixture_dir().join("run_v1.ckpt")).unwrap();
    assert_eq!(run_bytes, golden_run_checkpoint().encode(), "run fixture drifted");
    let job_bytes = std::fs::read(fixture_dir().join("job_v1.ckpt")).unwrap();
    assert_eq!(job_bytes, golden_job_checkpoint().encode(), "job fixture drifted");
}

/// Writes the golden fixtures. Ignored: run only on a deliberate format
/// revision (with a version bump and a new `_v<N>` file — never
/// overwrite `_v1`, old versions must stay covered).
#[test]
#[ignore]
fn regenerate_golden_fixtures() {
    let dir = fixture_dir();
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("run_v1.ckpt"), golden_run_checkpoint().encode()).unwrap();
    std::fs::write(dir.join("job_v1.ckpt"), golden_job_checkpoint().encode()).unwrap();
}
