//! Allocation-accounting tier: the scheduler hot path and the suspension
//! path must not touch the heap where the design says they don't.
//!
//! A counting [`GlobalAlloc`] wrapper tallies every allocation in the
//! process, which proves two invariants of ISSUE 4:
//!
//! 1. **Zero-allocation steady state** — a warmed-up `run_session` round
//!    (no global-best improvement, no preemption) performs ZERO heap
//!    allocations per step for the bit-exact engines (CPU, Reduction,
//!    Loop-Unrolling, Queue), on both the single-stream fast path and the
//!    executor-stepped concurrent path. The workload is a constant
//!    ("flat") fitness: the seeded global best can never be strictly
//!    improved, so every step exercises exactly the steady-state code.
//! 2. **Move-based suspension** — `Run::into_checkpoint` MOVES the swarm
//!    arrays into the checkpoint; suspending a job must allocate far less
//!    than one swarm array's worth of bytes (a deep copy would cost
//!    several arrays).
//!
//! The counter is process-global, so every test here serializes on one
//! mutex; this file must contain only allocation-accounting tests.

use cupso::config::{BatchConfig, EngineKind};
use cupso::engine::{self, Engine, Run};
use cupso::fitness::{Fitness, Objective};
use cupso::pso::PsoParams;
use cupso::scheduler::{JobScheduler, JobSpec};
use cupso::service::ServiceSession;
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);
static BYTES: AtomicU64 = AtomicU64::new(0);

// SAFETY: delegates everything to `System`; only adds relaxed counters.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        BYTES.fetch_add(new_size as u64, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

/// Serializes the accounting tests (the counters are process-global).
static LOCK: Mutex<()> = Mutex::new(());

fn allocs() -> u64 {
    ALLOCS.load(Ordering::SeqCst)
}

fn bytes() -> u64 {
    BYTES.load(Ordering::SeqCst)
}

/// A constant fitness: every evaluation is 0.0, so after seeding the
/// global best can never strictly improve — every subsequent step is pure
/// steady state. The batch/range entries are overridden to write the
/// constant without the default implementations' scratch vector.
struct Flat;

impl Fitness for Flat {
    fn name(&self) -> &'static str {
        "flat"
    }

    fn default_bounds(&self) -> (f64, f64) {
        (-1.0, 1.0)
    }

    fn default_objective(&self) -> Objective {
        Objective::Maximize
    }

    fn eval(&self, _x: &[f64]) -> f64 {
        0.0
    }

    fn eval_batch(&self, _pos: &[f64], _n: usize, _dim: usize, fit: &mut [f64]) {
        for f in fit.iter_mut() {
            *f = 0.0;
        }
    }

    fn eval_range(
        &self,
        _pos: &[f64],
        _n: usize,
        _dim: usize,
        _lo: usize,
        _hi: usize,
        fit: &mut [f64],
    ) {
        for f in fit.iter_mut() {
            *f = 0.0;
        }
    }
}

/// The engines held to the zero-allocation steady-state bar.
const BIT_EXACT: [EngineKind; 4] = [
    EngineKind::SerialCpu,
    EngineKind::Reduction,
    EngineKind::LoopUnrolling,
    EngineKind::Queue,
];

fn flat_specs(kind: EngineKind, jobs: usize, iters: u64) -> Vec<JobSpec> {
    (0..jobs)
        .map(|j| {
            JobSpec::new(
                &format!("flat{j}"),
                kind,
                PsoParams::for_fitness(&Flat, 64, 1, iters, 0.5),
                Arc::new(Flat),
                Objective::Maximize,
                j as u64 + 1,
            )
        })
        .collect()
}

#[test]
fn warmed_up_rounds_allocate_nothing_for_bit_exact_engines() {
    let _g = LOCK.lock().unwrap();
    // S=1 exercises the inline fast path; S=2 exercises the persistent
    // executor path (publish + wake per round).
    for kind in BIT_EXACT {
        for streams in [1usize, 2] {
            let iters = 600u64;
            let specs = flat_specs(kind, 2, iters);
            let scheduler = JobScheduler::with_streams(2, streams);
            // Warm up for 50 telemetry reports (runs, executors, pool and
            // history buffers all allocated by then), measure across the
            // next 400, ignore the tail (termination + finish may
            // allocate legitimately).
            let (warm, upto) = (50u64, 450u64);
            let mut calls = 0u64;
            let mut start = 0u64;
            let mut end = 0u64;
            let outcomes = scheduler
                .run_with(&specs, |_| {
                    calls += 1;
                    if calls == warm {
                        start = allocs();
                    }
                    if calls == upto {
                        end = allocs();
                    }
                })
                .unwrap();
            assert!(calls >= upto, "{kind:?} S={streams}: too few rounds ({calls})");
            assert_eq!(
                end - start,
                0,
                "{kind:?} S={streams}: steady-state rounds allocated {} times",
                end - start
            );
            // Sanity: the jobs really ran their budget with no improvement
            // (constant fitness ⇒ gbest stays at the seeded 0.0).
            for o in &outcomes {
                assert_eq!(o.steps, iters);
                assert_eq!(o.output.gbest_fit, 0.0);
                assert_eq!(o.output.counters.gbest_updates, 0);
            }
        }
    }
}

#[test]
fn service_rounds_with_empty_control_queue_allocate_nothing() {
    let _g = LOCK.lock().unwrap();
    // ISSUE 5: the service loop drains its control queue at every round
    // boundary. When the queue is empty (no submits/cancels/watchers
    // pending) that drain is one non-allocating try_recv, so a warmed-up
    // service round must stay exactly as allocation-free as a plain
    // scheduler round — on both the S=1 fast path and the executor path.
    for streams in [1usize, 2] {
        let iters = 600u64;
        let specs = flat_specs(EngineKind::Queue, 2, iters);
        let scheduler = JobScheduler::with_streams(2, streams);
        let knobs = BatchConfig {
            workers: 2,
            policy: "round-robin".into(),
            streams,
            batch_steps: 1,
            preempt_quantum: 0,
            pack: false,
            pack_min: 2,
            pack_max: 0,
            quota_jobs: 0,
            quota_steps: 0,
            checkpoint_every: 0,
            checkpoint_keep: 1,
            telemetry: true,
            trace_dump: None,
            jobs: Vec::new(),
        };
        let (service, handle) = ServiceSession::new(&scheduler, knobs, None, specs).unwrap();
        // Drop the only handle: the control queue stays empty forever and
        // the service runs its admitted work dry.
        drop(handle);
        let (warm, upto) = (50u64, 450u64);
        let mut calls = 0u64;
        let mut start = 0u64;
        let mut end = 0u64;
        let outcome = service
            .run_with(|_| {
                calls += 1;
                if calls == warm {
                    start = allocs();
                }
                if calls == upto {
                    end = allocs();
                }
            })
            .unwrap();
        assert!(calls >= upto, "S={streams}: too few rounds ({calls})");
        assert_eq!(
            end - start,
            0,
            "S={streams}: service steady-state rounds allocated {} times",
            end - start
        );
        assert_eq!(outcome.results.len(), 2);
        assert_eq!(outcome.finished_total, 2);
        for o in &outcome.results {
            assert_eq!(o.steps, iters);
            assert_eq!(o.gbest_fit, 0.0);
        }
        assert_eq!(outcome.drained, 0);
    }
}

#[test]
fn service_rounds_between_snapshots_allocate_nothing() {
    let _g = LOCK.lock().unwrap();
    // ISSUE 9: configuring periodic snapshots must not tax the rounds
    // that don't persist. The cadence check (`rounds % every`) runs at
    // every round boundary; with a sink constructed and a cadence too
    // large to ever fire inside the run, warmed-up rounds must stay
    // exactly as allocation-free as a service with no checkpointing.
    let iters = 600u64;
    let specs = flat_specs(EngineKind::Queue, 2, iters);
    let scheduler = JobScheduler::with_streams(2, 1);
    let dir = std::env::temp_dir().join(format!("cupso-zeroalloc-snap-{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    let knobs = BatchConfig {
        workers: 2,
        policy: "round-robin".into(),
        streams: 1,
        batch_steps: 1,
        preempt_quantum: 0,
        pack: false,
        pack_min: 2,
        pack_max: 0,
        quota_jobs: 0,
        quota_steps: 0,
        checkpoint_every: 1 << 30,
        checkpoint_keep: 1,
        telemetry: true,
        trace_dump: None,
        jobs: Vec::new(),
    };
    let (service, handle) =
        ServiceSession::new(&scheduler, knobs, Some(dir.clone()), specs).unwrap();
    drop(handle);
    let (warm, upto) = (50u64, 450u64);
    let mut calls = 0u64;
    let mut start = 0u64;
    let mut end = 0u64;
    let outcome = service
        .run_with(|_| {
            calls += 1;
            if calls == warm {
                start = allocs();
            }
            if calls == upto {
                end = allocs();
            }
        })
        .unwrap();
    assert!(calls >= upto, "too few rounds ({calls})");
    assert_eq!(
        end - start,
        0,
        "non-persisting rounds with a snapshot sink allocated {} times",
        end - start
    );
    assert_eq!(outcome.finished_total, 2);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn instrumented_service_rounds_still_allocate_nothing() {
    let _g = LOCK.lock().unwrap();
    use cupso::telemetry::{self, Counter, Series};
    // ISSUE 10: the flight recorder must be invisible to the allocator
    // too. With telemetry explicitly enabled, warmed-up service rounds —
    // phase clocks lapping into histograms, the rounds counter bumping —
    // perform ZERO heap allocations: recording is pre-allocated statics
    // and `Instant` reads, nothing else. The counter/histogram deltas
    // prove the instrumentation was really live while we measured.
    let was = telemetry::enabled();
    telemetry::set_enabled(true);
    for streams in [1usize, 2] {
        let iters = 600u64;
        let specs = flat_specs(EngineKind::Queue, 2, iters);
        let scheduler = JobScheduler::with_streams(2, streams);
        let knobs = BatchConfig {
            workers: 2,
            policy: "round-robin".into(),
            streams,
            batch_steps: 1,
            preempt_quantum: 0,
            pack: false,
            pack_min: 2,
            pack_max: 0,
            quota_jobs: 0,
            quota_steps: 0,
            checkpoint_every: 0,
            checkpoint_keep: 1,
            telemetry: true,
            trace_dump: None,
            jobs: Vec::new(),
        };
        let (service, handle) = ServiceSession::new(&scheduler, knobs, None, specs).unwrap();
        drop(handle);
        let rounds_before = telemetry::counter(Counter::Rounds);
        let splits_before = telemetry::histo(Series::RoundStepNs).count;
        let (warm, upto) = (50u64, 450u64);
        let mut calls = 0u64;
        let mut start = 0u64;
        let mut end = 0u64;
        let outcome = service
            .run_with(|_| {
                calls += 1;
                if calls == warm {
                    start = allocs();
                }
                if calls == upto {
                    end = allocs();
                }
            })
            .unwrap();
        assert!(calls >= upto, "S={streams}: too few rounds ({calls})");
        assert_eq!(
            end - start,
            0,
            "S={streams}: instrumented steady-state rounds allocated {} times",
            end - start
        );
        assert!(
            telemetry::counter(Counter::Rounds) > rounds_before,
            "S={streams}: instrumentation recorded no rounds"
        );
        assert!(
            telemetry::histo(Series::RoundStepNs).count > splits_before,
            "S={streams}: instrumentation recorded no step-phase splits"
        );
        assert_eq!(outcome.finished_total, 2);
    }
    telemetry::set_enabled(was);
}

#[test]
fn warmed_up_packed_rounds_allocate_nothing() {
    let _g = LOCK.lock().unwrap();
    // ISSUE 6: a warmed-up packed round (reconcile no-op, one launch
    // pair for the whole fleet, one report per member, empty service
    // control queue) must allocate nothing — same bar as the standalone
    // steady state. Four compatible flat jobs fuse into a single pack on
    // the first round; with a constant fitness the global bests never
    // improve, so every measured round is pure packed steady state.
    let iters = 600u64;
    let jobs = 4usize;
    let specs = flat_specs(EngineKind::Queue, jobs, iters);
    let scheduler = JobScheduler::with_streams(2, 1).pack(true);
    let knobs = BatchConfig {
        workers: 2,
        policy: "round-robin".into(),
        streams: 1,
        batch_steps: 1,
        preempt_quantum: 0,
        pack: true,
        pack_min: 2,
        pack_max: 0,
        quota_jobs: 0,
        quota_steps: 0,
        checkpoint_every: 0,
        checkpoint_keep: 1,
        telemetry: true,
        trace_dump: None,
        jobs: Vec::new(),
    };
    let (service, handle) = ServiceSession::new(&scheduler, knobs, None, specs).unwrap();
    drop(handle);
    // Packed members report every round, so telemetry fires jobs× per
    // round; warm across the first 50 rounds, measure the next 350.
    let (warm, upto) = (50 * jobs as u64, 400 * jobs as u64);
    let mut calls = 0u64;
    let mut start = 0u64;
    let mut end = 0u64;
    let outcome = service
        .run_with(|_| {
            calls += 1;
            if calls == warm {
                start = allocs();
            }
            if calls == upto {
                end = allocs();
            }
        })
        .unwrap();
    assert!(calls >= upto, "too few packed reports ({calls})");
    assert_eq!(
        end - start,
        0,
        "packed steady-state rounds allocated {} times",
        end - start
    );
    assert_eq!(outcome.results.len(), jobs);
    assert_eq!(outcome.finished_total, jobs as u64);
    for o in &outcome.results {
        assert_eq!(o.steps, iters);
        assert_eq!(o.gbest_fit, 0.0);
    }
    assert_eq!(outcome.drained, 0);
}

#[test]
fn pack_formation_and_dissolution_stay_within_budget() {
    let _g = LOCK.lock().unwrap();
    // Pack lifecycle allocation budget (ISSUE 6). For J jobs of swarm
    // unit U = n*dim*8 bytes, one packed session legitimately pays:
    //   * admit: J standalone runs (swarm + queues + scratch, ≈ 4.5 U
    //     each);
    //   * formation (once): the slab fill plus pack queues/scratch
    //     (≈ 4 U per member — `into_checkpoint` MOVES each swarm, so
    //     formation adds one slab copy, not two);
    //   * dissolution (once, at termination): one extracted checkpoint
    //     per member (≈ 3.5 U each).
    // That bounds the whole session near 12 U per member. The failure
    // mode this budget guards against is lifecycle churn — a pack that
    // re-forms every round pays formation + extraction per round, i.e.
    // ≈ 7.5 U * iters per member, an order of magnitude above. A 20 U
    // per-member budget separates the two with wide margins.
    let (n, dim, iters, jobs) = (4096usize, 4usize, 12u64, 4usize);
    let unit = (n * dim * 8) as u64;
    let specs: Vec<JobSpec> = (0..jobs)
        .map(|j| {
            JobSpec::new(
                &format!("pk{j}"),
                EngineKind::Queue,
                PsoParams::for_fitness(&Flat, n, dim, iters, 0.5),
                Arc::new(Flat),
                Objective::Maximize,
                j as u64 + 1,
            )
        })
        .collect();
    let scheduler = JobScheduler::with_streams(2, 1).pack(true);
    let before = bytes();
    let outcomes = scheduler.run(&specs).unwrap();
    let total = bytes() - before;
    for o in &outcomes {
        assert_eq!(o.steps, iters);
    }
    let budget = 20 * jobs as u64 * unit;
    assert!(
        total < budget,
        "packed session allocated {total} bytes (budget {budget}; a \
         form/extract-per-round churn regression lands an order of \
         magnitude above it)"
    );
}

#[test]
fn suspension_moves_the_swarm_instead_of_deep_copying() {
    let _g = LOCK.lock().unwrap();
    // Big swarm: each SoA position/velocity/pbest array is n*dim*8 =
    // 512 KiB, so a deep copy would show up as ≥ 1.5 MiB. The suspension
    // path may allocate small things (gbest_pos, checkpoint struct), but
    // never an array's worth.
    let (n, dim) = (8192usize, 8usize);
    let swarm_array_bytes = (n * dim * 8) as u64;
    for kind in [
        EngineKind::SerialCpu,
        EngineKind::Reduction,
        EngineKind::LoopUnrolling,
        EngineKind::Queue,
        EngineKind::QueueLock,
        EngineKind::AsyncPersistent,
    ] {
        let params = PsoParams::for_fitness(&Flat, n, dim, 50, 0.5);
        let mut eng = engine::build(kind, 2).unwrap();
        let mut run = eng.prepare(&params, &Flat, Objective::Maximize, 1);
        run.step();
        let before = bytes();
        let ckpt = run.into_checkpoint();
        let copied = bytes() - before;
        assert!(
            copied < swarm_array_bytes,
            "{kind:?}: into_checkpoint allocated {copied} bytes (≥ one \
             {swarm_array_bytes}-byte swarm array ⇒ deep copy regression)"
        );
        // And it is a real checkpoint: full swarm, correct progress.
        assert_eq!(ckpt.iter, 1);
        assert_eq!(ckpt.swarm.pos.len(), n * dim);
        ckpt.validate().unwrap();
    }
}

#[test]
fn preemptive_suspension_in_the_scheduler_stays_cheap() {
    let _g = LOCK.lock().unwrap();
    // Scheduler-level regression: with more jobs than streams and a
    // 1-step quantum, every round suspends a job. The whole session's
    // allocation traffic must stay far below "one swarm deep-copy per
    // suspension" (the old clone-twice behavior).
    let (n, dim, iters) = (4096usize, 4usize, 12u64);
    let swarm_array_bytes = (n * dim * 8) as u64;
    let specs: Vec<JobSpec> = (0..3)
        .map(|j| {
            JobSpec::new(
                &format!("p{j}"),
                EngineKind::Queue,
                PsoParams::for_fitness(&Flat, n, dim, iters, 0.5),
                Arc::new(Flat),
                Objective::Maximize,
                j as u64 + 1,
            )
        })
        .collect();
    let scheduler = JobScheduler::with_streams(2, 1).preempt_quantum(1);
    let before = bytes();
    let outcomes = scheduler.run(&specs).unwrap();
    let total = bytes() - before;
    for o in &outcomes {
        assert_eq!(o.steps, iters);
    }
    // 3 jobs × 12 steps with quantum 1 ⇒ 36 suspensions and 36 restores.
    // Each restore legitimately allocates a fresh run (~4.3 swarm-array
    // units: swarm copy + queues + scratch ≈ 155 units total, plus ~20
    // for the initial prepares); each suspension must NOT add a swarm
    // deep-copy on top of the move. A clone-based suspension costs ~3.5
    // extra units × 36 ≈ +126 units, so a 250-unit budget separates the
    // two behaviors with ≥ 30% margin on both sides.
    let budget = 250 * swarm_array_bytes;
    assert!(
        total < budget,
        "preemptive session allocated {total} bytes (budget {budget}; \
         a deep-copy-per-suspension regression lands well above it)"
    );
}
