//! Plane-B integration: PJRT artifact loading, chunk execution semantics,
//! and both coordinator schedulers, against the real `artifacts/` output
//! of `make artifacts` (the Makefile orders this correctly).
//!
//! Every test **skips** (passes vacuously, with a note on stderr) when
//! the runtime cannot open — either the build lacks the `xla` feature
//! (the offline default, see `runtime/mod.rs`) or `artifacts/` is absent.

use cupso::coordinator::{AsyncScheduler, CoordinatorConfig, SyncScheduler};
use cupso::fitness::{Cubic, Fitness, Objective};
use cupso::pso::PsoParams;
use cupso::runtime::{XlaRuntime, XlaSwarmState};
use std::path::Path;

fn runtime() -> Option<XlaRuntime> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    match XlaRuntime::open(&dir) {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("skipping Plane-B test: {e:#}");
            None
        }
    }
}

/// `let Some(rt) = … else return` in every test body.
macro_rules! runtime_or_skip {
    () => {
        match runtime() {
            Some(rt) => rt,
            None => return,
        }
    };
}

fn state_for(rt: &XlaRuntime, variant: &str, n: usize, d: usize) -> XlaSwarmState {
    let meta = rt.find(variant, n, d).expect("artifact in manifest");
    let params = PsoParams {
        w: meta.w,
        c1: meta.c1,
        c2: meta.c2,
        min_pos: meta.min_pos,
        max_pos: meta.max_pos,
        max_v: meta.max_v,
        max_iter: meta.iters,
        n,
        dim: d,
    };
    XlaSwarmState::init(&params, &Cubic, Objective::Maximize, 7, 0)
}

#[test]
fn manifest_lists_default_configs() {
    let rt = runtime_or_skip!();
    for variant in ["reduction", "queue", "fused"] {
        assert!(
            rt.find(variant, 1024, 1).is_some(),
            "missing {variant} n=1024 d=1"
        );
        assert!(
            rt.find(variant, 256, 120).is_some(),
            "missing {variant} n=256 d=120"
        );
    }
    assert_eq!(rt.platform(), "cpu");
}

#[test]
fn chunk_advances_state_and_traces_monotone() {
    let rt = runtime_or_skip!();
    let exec = rt.load_config("queue", 1024, 1).unwrap();
    let mut st = state_for(&rt, "queue", 1024, 1);
    let initial = st.gbest_fit;
    let trace = exec.run(&mut st, [1, 2], 0).unwrap();
    assert_eq!(trace.len(), exec.meta.iters as usize);
    for w in trace.windows(2) {
        assert!(w[1] >= w[0], "gbest worsened inside the chunk");
    }
    assert!(st.gbest_fit >= initial);
    // 1-D cubic with 1024 particles: 50 iterations should solve it.
    assert!(
        st.gbest_fit > 899_000.0,
        "gbest {} after one chunk",
        st.gbest_fit
    );
    // Positions stayed in bounds.
    assert!(st.pos.iter().all(|&p| (-100.0..=100.0).contains(&p)));
}

#[test]
fn all_variants_agree_bitwise_from_same_state() {
    // The three lowered variants embed the same synchronous semantics —
    // from identical state + key they must produce identical outputs.
    let rt = runtime_or_skip!();
    let mut results = Vec::new();
    for variant in ["reduction", "queue", "fused"] {
        let exec = rt.load_config(variant, 1024, 1).unwrap();
        let mut st = state_for(&rt, variant, 1024, 1);
        let trace = exec.run(&mut st, [9, 9], 0).unwrap();
        results.push((variant, st, trace));
    }
    let (_, st0, tr0) = &results[0];
    for (variant, st, tr) in &results[1..] {
        assert_eq!(st.gbest_fit, st0.gbest_fit, "{variant} fit");
        assert_eq!(st.gbest_pos, st0.gbest_pos, "{variant} pos");
        assert_eq!(st.pos, st0.pos, "{variant} swarm pos");
        assert_eq!(tr, tr0, "{variant} trace");
    }
}

#[test]
fn chunks_chain_exactly() {
    // Replaying the second chunk from the mid-state must equal the
    // chained evolution (the coordinator contract).
    let rt = runtime_or_skip!();
    let exec = rt.load_config("fused", 1024, 1).unwrap();
    let k = exec.meta.iters as i64;

    let mut chained = state_for(&rt, "fused", 1024, 1);
    exec.run(&mut chained, [3, 4], 0).unwrap();
    let mid = chained.clone();
    exec.run(&mut chained, [3, 4], k).unwrap();

    let mut replay = mid;
    exec.run(&mut replay, [3, 4], k).unwrap();
    assert_eq!(chained.pos, replay.pos);
    assert_eq!(chained.gbest_fit, replay.gbest_fit);
}

#[test]
fn executable_cache_reuses_compilations() {
    let rt = runtime_or_skip!();
    let t0 = std::time::Instant::now();
    let _a = rt.load("pso_queue_n1024_d1_k50").unwrap();
    let first = t0.elapsed();
    let t1 = std::time::Instant::now();
    let _b = rt.load("pso_queue_n1024_d1_k50").unwrap();
    let second = t1.elapsed();
    assert!(
        second < first / 2,
        "cache ineffective: first {first:?}, second {second:?}"
    );
}

#[test]
fn sync_scheduler_runs_and_improves() {
    let rt = runtime_or_skip!();
    let mut cfg = CoordinatorConfig::new("queue", 256, 120, 100);
    cfg.shards = 3;
    let out = SyncScheduler::run(&rt, &cfg).unwrap();
    assert_eq!(out.chunk_calls, 3 * out.iters_per_shard / 25);
    assert_eq!(out.shard_fits.len(), 3);
    // Quality: 120-D cubic optimum is 108M; 100 iterations with 3×256
    // particles should be well on the way (over 60% of optimal).
    let opt = Cubic.optimum(120).unwrap();
    assert!(
        out.gbest_fit > 0.6 * opt,
        "gbest {} vs optimum {opt}",
        out.gbest_fit
    );
    // History monotone.
    for w in out.history.windows(2) {
        assert!(w[1].1 >= w[0].1);
    }
    // The shared best dominates every shard.
    for &f in &out.shard_fits {
        assert!(out.gbest_fit >= f);
    }
}

#[test]
fn async_scheduler_matches_sync_quality() {
    let rt = runtime_or_skip!();
    let mut cfg = CoordinatorConfig::new("queue", 256, 120, 100);
    cfg.shards = 3;
    let sync = SyncScheduler::run(&rt, &cfg).unwrap();
    let asy = AsyncScheduler::run(&rt, &cfg).unwrap();
    assert_eq!(asy.chunk_calls, sync.chunk_calls);
    // Async relaxes propagation, not quality class.
    let rel = (asy.gbest_fit - sync.gbest_fit).abs() / sync.gbest_fit.abs();
    assert!(
        rel < 0.1,
        "async {} vs sync {} (rel {rel})",
        asy.gbest_fit,
        sync.gbest_fit
    );
    for w in asy.history.windows(2) {
        assert!(w[1].1 >= w[0].1, "async gbest worsened");
    }
}

#[test]
fn missing_artifact_errors_helpfully() {
    let rt = runtime_or_skip!();
    let err = rt.load_config("queue", 12345, 1).unwrap_err().to_string();
    assert!(err.contains("no artifact"), "{err}");
    assert!(err.contains("available"), "{err}");
}

#[test]
fn shape_mismatch_is_rejected() {
    let rt = runtime_or_skip!();
    let exec = rt.load_config("queue", 1024, 1).unwrap();
    let mut st = state_for(&rt, "queue", 1024, 1);
    st.n = 512; // lie about the shape
    st.pos.truncate(512);
    let err = exec.run(&mut st, [0, 0], 0).unwrap_err().to_string();
    assert!(err.contains("does not match"), "{err}");
}
