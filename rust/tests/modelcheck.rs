//! Model-checked concurrency tier (see `rust/src/modelcheck/`).
//!
//! Run modes:
//!
//! * `cargo test --test modelcheck` — stock build: every scenario runs as
//!   bounded real-thread stress (no schedule control); the tier is cheap
//!   and exercises the same closures.
//! * `RUSTFLAGS="--cfg cupso_model" cargo test --test modelcheck` — the
//!   real thing: bounded-exhaustive schedule exploration with the
//!   vector-clock race detector. The CI `modelcheck` job runs this, plus
//!   the two mutation builds (`--cfg cupso_mutate_spinlock_release`,
//!   `--cfg cupso_mutate_executor_done`) where the `spinlock_*` /
//!   `executor_*` tests here MUST fail — that failure is asserted by CI,
//!   keeping the detector honest forever.
//!
//! Test names matter: the mutation runs filter on the `spinlock` /
//! `executor` substrings.

use cupso::exec::sync::Ordering;
use cupso::exec::{AtomicF64, SharedQueue, SpinLock};
use cupso::modelcheck::{protocols, Explorer, Scenario};
use std::sync::atomic::{AtomicU64 as StdAtomicU64, AtomicUsize as StdAtomicUsize};
use std::sync::Arc;

/// Mutual exclusion + release visibility of the Algorithm-3 lock: two
/// threads increment a plain (non-atomic) counter under the lock. The
/// guarded accesses are unsynchronized unless every unlock→lock pair
/// carries a happens-before edge — exactly what the Release unlock store
/// provides. Under `--cfg cupso_mutate_spinlock_release` that edge is
/// gone and the race detector must flag the guarded cell.
#[test]
fn spinlock_mutual_exclusion_and_release_visibility() {
    let report = Explorer::new().explore(|| {
        let lock = Arc::new(SpinLock::new(0u64));
        let mut s = Scenario::new();
        for _ in 0..2 {
            let lock = lock.clone();
            s.thread(move || {
                for _ in 0..2 {
                    *lock.lock() += 1;
                }
            });
        }
        let lock2 = lock.clone();
        s.check(move || {
            assert_eq!(*lock2.lock(), 4, "lost an increment under the lock");
            assert_eq!(lock2.acquisition_count(), 5);
        });
        s
    });
    assert!(
        report.race_free(),
        "SpinLock critical sections must be synchronized: {:?}",
        report.races
    );
    assert!(report.schedules > 0);
    assert_eq!(report.truncated, 0, "exploration silently lost depth");
}

/// `fetch_max` linearizes: whatever the interleaving of three racing
/// updaters, the cell converges to the global max and every intermediate
/// CAS retry preserves monotonicity.
#[test]
fn atomic_f64_fetch_max_linearizes_to_global_max() {
    let report = Explorer::new().explore(|| {
        let a = Arc::new(AtomicF64::new(f64::NEG_INFINITY));
        let mut s = Scenario::new();
        for v in [1.0, 3.0, 2.0] {
            let a = a.clone();
            s.thread(move || {
                a.fetch_max(v);
                let seen = a.load(Ordering::Acquire);
                assert!(seen >= v, "fetch_max went backwards: {seen} < {v}");
            });
        }
        let a2 = a.clone();
        s.check(move || assert_eq!(a2.load(Ordering::Relaxed), 3.0));
        s
    });
    assert!(report.race_free(), "{:?}", report.races);
    assert_eq!(report.truncated, 0, "exploration silently lost depth");
}

/// `fetch_min` mirror of the above (the Minimize objective sense).
#[test]
fn atomic_f64_fetch_min_linearizes_to_global_min() {
    let report = Explorer::new().explore(|| {
        let a = Arc::new(AtomicF64::new(f64::INFINITY));
        let mut s = Scenario::new();
        for v in [-1.0, -5.0] {
            let a = a.clone();
            s.thread(move || {
                a.fetch_min(v);
            });
        }
        let a2 = a.clone();
        s.check(move || assert_eq!(a2.load(Ordering::Relaxed), -5.0));
        s
    });
    assert!(report.race_free(), "{:?}", report.races);
    assert_eq!(report.truncated, 0, "exploration silently lost depth");
}

/// No lost push, no duplicate slot: concurrent pushers end up with
/// unique indices and every value survives to the post-quiescence scan.
#[test]
fn queue_concurrent_pushes_keep_unique_slots() {
    let report = Explorer::new().explore(|| {
        let q: Arc<SharedQueue<u64>> = Arc::new(SharedQueue::new(4));
        let mut s = Scenario::new();
        for t in 0..2u64 {
            let q = q.clone();
            s.thread(move || {
                for i in 0..2 {
                    q.push(t * 2 + i).expect("capacity 4 cannot overflow");
                }
            });
        }
        let q2 = q.clone();
        s.check(move || {
            assert_eq!(q2.len(), 4);
            let mut seen = [false; 4];
            q2.scan(|&v| {
                assert!(!seen[v as usize], "value {v} scanned twice");
                seen[v as usize] = true;
            });
            assert!(seen.iter().all(|&b| b), "lost a push");
        });
        s
    });
    assert!(report.race_free(), "{:?}", report.races);
    assert_eq!(report.truncated, 0, "exploration silently lost depth");
}

/// Overflow discipline: on a capacity-2 queue, exactly two of four
/// racing pushes win and the cursor never leaves `0..=capacity` (the
/// no-underflow half of the claim — the saturating CAS claim cannot be
/// driven below zero because no compensating decrement exists).
#[test]
fn queue_overflow_exactly_capacity_pushes_win() {
    let report = Explorer::new().explore(|| {
        let q: Arc<SharedQueue<u64>> = Arc::new(SharedQueue::new(2));
        let wins = Arc::new(StdAtomicUsize::new(0));
        let mut s = Scenario::new();
        for t in 0..2u64 {
            let q = q.clone();
            let wins = wins.clone();
            s.thread(move || {
                for i in 0..2 {
                    if q.push(t * 2 + i).is_some() {
                        wins.fetch_add(1, Ordering::Relaxed);
                    }
                    assert!(q.len() <= 2, "cursor escaped 0..=capacity");
                }
            });
        }
        let (q2, w2) = (q.clone(), wins.clone());
        s.check(move || {
            assert_eq!(w2.load(Ordering::Relaxed), 2, "exactly capacity wins");
            assert_eq!(q2.len(), 2);
        });
        s
    });
    assert!(report.race_free(), "{:?}", report.races);
    assert_eq!(report.truncated, 0, "exploration silently lost depth");
}

/// Pushes racing a reset: the *counter* invariant (cursor stays within
/// `0..=capacity`, scans stay in bounds) holds under every interleaving
/// — that is what the saturating-CAS claim buys. The slot *cells* do
/// race in this regime (two claims of the same index across a reset are
/// not ordered — which is exactly why every engine quiesces producers
/// before `reset`, per the queue's SAFETY contract), so this scenario
/// asserts the invariant while tolerating cell races; it runs only under
/// the model, where the virtual scheduler serializes the accesses.
#[cfg(cupso_model)]
#[test]
fn queue_reset_race_never_corrupts_cursor() {
    let report = Explorer::new().continue_past_races().explore(|| {
        let q: Arc<SharedQueue<u64>> = Arc::new(SharedQueue::new(1));
        let mut s = Scenario::new();
        for t in 0..2u64 {
            let q = q.clone();
            s.thread(move || {
                for i in 0..2 {
                    q.push(t * 2 + i);
                    assert!(q.len() <= 1, "cursor escaped 0..=capacity");
                }
            });
        }
        {
            let q = q.clone();
            s.thread(move || q.reset());
        }
        let q2 = q.clone();
        s.check(move || assert!(q2.len() <= 1));
        s
    });
    // The cursor invariant held on every explored schedule (the asserts
    // above) even though the cells race by design here.
    assert!(report.schedules > 0);
    assert_eq!(report.truncated, 0, "exploration silently lost depth");
}

/// The executor slot's publish→echo protocol over two full rounds plus
/// shutdown: every report read back intact, `cmd`/`report` cells fully
/// synchronized. Under `--cfg cupso_mutate_executor_done` the echo loses
/// its Release and the detector must flag the cells.
#[test]
fn executor_slot_publish_echo_rounds_and_shutdown() {
    let report = Explorer::new().explore(|| protocols::executor_slot_scenario(2));
    assert!(
        report.race_free(),
        "executor slot protocol must be synchronized: {:?}",
        report.races
    );
    assert!(report.schedules > 0);
    assert_eq!(report.truncated, 0, "exploration silently lost depth");
}

/// The poison path: a panicking command still echoes (so `wait` cannot
/// hang), the producer observes the poison and never touches the report
/// cell — no race, no deadlock, clean shutdown.
#[test]
fn executor_slot_poison_path_echoes_without_report() {
    let report = Explorer::new().explore(protocols::executor_poison_scenario);
    assert!(report.race_free(), "{:?}", report.races);
    assert_eq!(report.truncated, 0, "exploration silently lost depth");
}

/// Sanity for the harness itself: the detector must actually *find* a
/// deliberately unsynchronized pair (two relaxed-published writes to the
/// same plain cell). Guards against the detector silently degrading into
/// a yes-machine. Model builds only — in stress builds this would be a
/// true data race on real threads.
#[cfg(cupso_model)]
#[test]
fn detector_flags_a_deliberate_race() {
    use cupso::exec::sync::{AtomicU64, RacyCell};

    struct Racy {
        cell: RacyCell<u64>,
        flag: AtomicU64,
    }
    // SAFETY: deliberately unsound sharing — the model serializes it.
    unsafe impl Sync for Racy {}
    unsafe impl Send for Racy {}

    let report = Explorer::new().explore(|| {
        let r = Arc::new(Racy {
            cell: RacyCell::new(0),
            flag: AtomicU64::new(0),
        });
        let mut s = Scenario::new();
        for t in 0..2u64 {
            let r = r.clone();
            s.thread(move || {
                // SAFETY: serialized by the model's virtual scheduler
                // (this test only compiles under cupso_model).
                unsafe { *r.cell.write() = t };
                // Relaxed publish: no happens-before edge — racy.
                r.flag.store(t, Ordering::Relaxed);
            });
        }
        s
    });
    assert!(
        !report.race_free(),
        "the detector missed a textbook data race"
    );
}

/// The modelcheck tier runs the *facade* end to end in both builds; this
/// pins the zero-cost claim's API half — facade types interoperate with
/// plain std atomics in the same code (the engines rely on it).
#[test]
fn facade_interoperates_with_std_atomics() {
    let report = Explorer::new().stress_iters(4).explore(|| {
        let a = Arc::new(StdAtomicU64::new(0));
        let mut s = Scenario::new();
        let a2 = a.clone();
        s.thread(move || {
            a2.fetch_add(1, Ordering::SeqCst);
        });
        let a3 = a.clone();
        s.check(move || assert_eq!(a3.load(Ordering::SeqCst), 1));
        s
    });
    assert!(report.race_free());
}
