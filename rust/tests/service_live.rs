//! Service-layer integration: drive a live [`ServiceSession`] over its
//! real line-JSON protocol — Unix socket and TCP side by side — in
//! process: submit, status, watch, cancel, drain — then prove the
//! drained snapshot resumes to the uninterrupted results through the
//! library's resume path.
//!
//! (The `cupso` binary's serve/submit/... verbs are exercised end to end
//! in `cli_launcher.rs`; this tier pins the protocol and the
//! drain-to-snapshot semantics without process-spawn overhead.)

use cupso::checkpoint::store::read_snapshot;
use cupso::config::{BatchConfig, EngineKind};
use cupso::fitness::{Cubic, Fitness, Objective};
use cupso::pso::PsoParams;
use cupso::scheduler::{BatchRun, JobScheduler, JobSpec, StopReason};
use cupso::service::proto::Json;
use cupso::service::{bind, bind_tcp, spawn_server, spawn_server_on, Listener, ServiceSession};
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::os::unix::net::UnixStream;
use std::path::{Path, PathBuf};
use std::sync::Arc;

fn knobs(streams: usize) -> BatchConfig {
    BatchConfig {
        workers: 2,
        policy: "round-robin".into(),
        streams,
        batch_steps: 1,
        preempt_quantum: 0,
        pack: false,
        pack_min: 2,
        pack_max: 0,
        quota_jobs: 0,
        quota_steps: 0,
        checkpoint_every: 0,
        checkpoint_keep: 1,
        telemetry: true,
        trace_dump: None,
        jobs: Vec::new(),
    }
}

fn spec(name: &str, engine: EngineKind, n: usize, iters: u64, seed: u64) -> JobSpec {
    JobSpec::new(
        name,
        engine,
        PsoParams::paper_1d(n, iters),
        Arc::new(Cubic),
        Objective::Maximize,
        seed,
    )
}

fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("cupso-service-live-{tag}"));
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// One request line → one parsed response line over any fresh stream
/// (the two transports speak the byte-identical protocol).
fn roundtrip_on<S: Read + Write>(mut stream: S, line: &str) -> Json {
    writeln!(stream, "{line}").unwrap();
    stream.flush().unwrap();
    let mut reader = BufReader::new(stream);
    let mut reply = String::new();
    reader.read_line(&mut reply).unwrap();
    Json::parse(reply.trim()).unwrap_or_else(|e| panic!("bad response {reply:?}: {e}"))
}

fn roundtrip(socket: &Path, line: &str) -> Json {
    roundtrip_on(UnixStream::connect(socket).expect("connect"), line)
}

fn roundtrip_tcp(addr: SocketAddr, line: &str) -> Json {
    roundtrip_on(TcpStream::connect(addr).expect("connect tcp"), line)
}

fn ok(doc: &Json) -> bool {
    doc.get("ok").map(|v| v == &Json::Bool(true)).unwrap_or(false)
}

fn rows<'a>(doc: &'a Json, key: &str) -> &'a [Json] {
    match doc.get(key) {
        Some(Json::Arr(items)) => items,
        other => panic!("{key} not an array: {other:?}"),
    }
}

#[test]
fn socket_protocol_submit_status_cancel_watch_drain() {
    let dir = temp_dir("proto");
    let socket = dir.join("svc.sock");
    let snap_dir = dir.join("drain");
    let scheduler = JobScheduler::with_streams(2, 2);
    let (service, handle) = ServiceSession::new(
        &scheduler,
        knobs(2),
        Some(snap_dir.clone()),
        vec![spec("resident", EngineKind::Queue, 128, 500_000, 1)],
    )
    .unwrap();
    let listener = bind(&socket).unwrap();
    let _accept = spawn_server(listener, handle);
    let svc = std::thread::spawn(move || service.run().unwrap());

    // Ping.
    let doc = roundtrip(&socket, r#"{"op": "ping"}"#);
    assert!(ok(&doc), "{doc:?}");

    // Submit a second live job over the wire.
    let doc = roundtrip(
        &socket,
        r#"{"op": "submit", "job": {"name": "wired", "fitness": "cubic", "engine": "reduction", "particles": 96, "iters": 400000, "seed": 2}}"#,
    );
    assert!(ok(&doc), "{doc:?}");
    assert_eq!(doc.str_field("name").unwrap(), "wired");
    assert_eq!(doc.get("slot").unwrap().as_u64("slot").unwrap(), 1);

    // Duplicate name → loud protocol error.
    let doc = roundtrip(
        &socket,
        r#"{"op": "submit", "job": {"name": "wired", "iters": 10}}"#,
    );
    assert!(!ok(&doc));
    assert!(doc.str_field("error").unwrap().contains("unique"), "{doc:?}");

    // Malformed request → error, connection survives server-side.
    let doc = roundtrip(&socket, r#"{"op": "submit", "job": {"name": "x", "particles": 0}}"#);
    assert!(!ok(&doc));
    assert!(doc.str_field("error").unwrap().contains("particles"));

    // Status: both jobs live.
    let doc = roundtrip(&socket, r#"{"op": "status"}"#);
    assert!(ok(&doc), "{doc:?}");
    let live = match doc.get("live").unwrap() {
        Json::Arr(items) => items,
        other => panic!("live not an array: {other:?}"),
    };
    assert_eq!(live.len(), 2);
    assert_eq!(live[0].str_field("name").unwrap(), "resident");
    assert_eq!(live[1].str_field("name").unwrap(), "wired");
    assert!(live[0].get("steps").unwrap().as_u64("steps").unwrap() > 0);

    // Watch: the ack line, then at least a few report events.
    {
        let stream = UnixStream::connect(&socket).unwrap();
        let mut writer = stream.try_clone().unwrap();
        writeln!(writer, r#"{{"op": "watch"}}"#).unwrap();
        writer.flush().unwrap();
        let mut reader = BufReader::new(stream);
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let ack = Json::parse(line.trim()).unwrap();
        assert!(ok(&ack), "{ack:?}");
        for _ in 0..4 {
            line.clear();
            reader.read_line(&mut line).unwrap();
            let ev = Json::parse(line.trim()).unwrap();
            assert_eq!(ev.str_field("event").unwrap(), "report");
            let job = ev.str_field("job").unwrap();
            assert!(job == "resident" || job == "wired", "{ev:?}");
        }
        // Dropping the connection unsubscribes us (server reaps on the
        // next failed send).
    }

    // Cancel the wired job.
    let doc = roundtrip(&socket, r#"{"op": "cancel", "name": "wired"}"#);
    assert!(ok(&doc), "{doc:?}");
    let job = doc.get("job").unwrap();
    assert_eq!(job.str_field("name").unwrap(), "wired");
    assert_eq!(job.str_field("stop").unwrap(), "cancelled");
    let doc = roundtrip(&socket, r#"{"op": "cancel", "name": "wired"}"#);
    assert!(!ok(&doc), "double cancel must fail: {doc:?}");

    // Drain: the resident job lands in the snapshot, the service stops.
    let doc = roundtrip(&socket, r#"{"op": "drain"}"#);
    assert!(ok(&doc), "{doc:?}");
    assert_eq!(doc.get("snapshotted").unwrap().as_u64("s").unwrap(), 1);
    assert_eq!(doc.get("finished").unwrap().as_u64("f").unwrap(), 1);
    assert_eq!(doc.str_field("dir").unwrap(), snap_dir.display().to_string());

    let end = svc.join().unwrap();
    assert_eq!(end.drained, 1);
    assert_eq!(end.results.len(), 1);
    assert_eq!(end.results[0].stop, StopReason::Cancelled);

    // The snapshot is a regular resumable batch snapshot.
    let (manifest_knobs, keep, ckpts) = read_snapshot(&snap_dir).unwrap();
    assert_eq!(keep, 1);
    assert_eq!(manifest_knobs.streams, 2);
    assert_eq!(ckpts.len(), 1);
    assert_eq!(&*ckpts[0].name, "resident");
    assert!(ckpts[0].stop.is_none());
    let manifest = std::fs::read_to_string(snap_dir.join("manifest.toml")).unwrap();
    assert!(manifest.contains("source = \"serve\""), "{manifest}");

    std::fs::remove_dir_all(&dir).ok();
}

/// Drain → resume equivalence at the library level: a service that
/// admitted one job at startup and one live, drained mid-run, must
/// resume (through the standard scheduler resume path) to the exact
/// results of the uninterrupted batch.
#[test]
fn drained_service_resumes_to_uninterrupted_results() {
    let dir = temp_dir("resume");
    let snap_dir = dir.join("drain");
    let mk_a = || spec("early", EngineKind::Queue, 256, 30_000, 11);
    let mk_b = || spec("live", EngineKind::Reduction, 200, 25_000, 12);
    let scheduler = JobScheduler::with_streams(2, 2);
    let reference = scheduler.run(&[mk_a(), mk_b()]).unwrap();

    let (service, handle) = ServiceSession::new(
        &scheduler,
        knobs(2),
        Some(snap_dir.clone()),
        vec![mk_a()],
    )
    .unwrap();
    let svc = std::thread::spawn(move || service.run().unwrap());
    handle.submit(mk_b()).unwrap();
    // Let both jobs make some progress, then drain mid-flight.
    loop {
        let status = handle.status().unwrap();
        if status.live.len() == 2 && status.live.iter().all(|j| j.steps > 50) {
            break;
        }
        assert!(
            status.live.len() + status.finished.len() == 2,
            "lost a job: {status:?}"
        );
        std::thread::sleep(std::time::Duration::from_millis(5));
    }
    let report = handle.drain().unwrap();
    assert_eq!(report.snapshotted, 2, "both jobs must still be live");
    let end = svc.join().unwrap();
    assert_eq!(end.drained, 2);

    // Resume exactly like `cupso resume` does.
    let (_, _, ckpts) = read_snapshot(&snap_dir).unwrap();
    let specs = ckpts
        .iter()
        .map(JobSpec::from_checkpoint)
        .collect::<anyhow::Result<Vec<_>>>()
        .unwrap();
    let resumed = match scheduler.run_session(&specs, Some(&ckpts), None, |_| {}).unwrap() {
        BatchRun::Complete(outcomes) => outcomes,
        BatchRun::Suspended(_) => panic!("uncapped resume must complete"),
    };
    assert_eq!(resumed.len(), 2);
    for (r, reference) in resumed.iter().zip(&reference) {
        assert_eq!(&r.name, &reference.name);
        assert_eq!(r.steps, reference.steps, "{}", r.name);
        assert_eq!(r.output.gbest_fit, reference.output.gbest_fit, "{}", r.name);
        assert_eq!(r.output.gbest_pos, reference.output.gbest_pos, "{}", r.name);
        assert_eq!(r.output.history, reference.output.history, "{}", r.name);
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// ISSUE 6: draining a service with live *packs* snapshots every packed
/// member as a standalone checkpoint, and the snapshot resumes — on a
/// scheduler with packing disabled — to the exact results of the
/// uninterrupted fleet. Pack membership is execution policy, never
/// state.
#[test]
fn drained_packed_service_resumes_to_uninterrupted_results() {
    let dir = temp_dir("pack-resume");
    let snap_dir = dir.join("drain");
    let fleet = 8usize;
    let mk_fleet = || -> Vec<JobSpec> {
        (0..fleet)
            .map(|j| {
                spec(
                    &format!("pk{j}"),
                    EngineKind::Queue,
                    64 + 32 * j,
                    20_000 + 1_000 * j as u64,
                    j as u64 + 1,
                )
            })
            .collect()
    };
    // Reference: the same fleet, uninterrupted and unpacked.
    let plain = JobScheduler::with_streams(2, 2);
    let reference = plain.run(&mk_fleet()).unwrap();

    let packed = JobScheduler::with_streams(2, 1).pack(true);
    let pack_knobs = BatchConfig {
        pack: true,
        ..knobs(1)
    };
    let (service, handle) =
        ServiceSession::new(&packed, pack_knobs, Some(snap_dir.clone()), mk_fleet()).unwrap();
    let svc = std::thread::spawn(move || service.run().unwrap());
    // Let the packed fleet make real progress, then drain mid-flight.
    loop {
        let status = handle.status().unwrap();
        if status.live.len() == fleet && status.live.iter().all(|j| j.steps > 50) {
            break;
        }
        assert!(
            status.live.len() + status.finished.len() == fleet,
            "lost a job: {status:?}"
        );
        std::thread::sleep(std::time::Duration::from_millis(5));
    }
    let report = handle.drain().unwrap();
    assert_eq!(report.snapshotted, fleet, "the whole fleet must still be live");
    let end = svc.join().unwrap();
    assert_eq!(end.drained, fleet);

    // Resume on a NON-packed scheduler: packed-born checkpoints are
    // ordinary checkpoints.
    let (manifest_knobs, _, ckpts) = read_snapshot(&snap_dir).unwrap();
    assert!(manifest_knobs.pack, "manifest must record the pack knob");
    let specs = ckpts
        .iter()
        .map(JobSpec::from_checkpoint)
        .collect::<anyhow::Result<Vec<_>>>()
        .unwrap();
    let resumed = match plain.run_session(&specs, Some(&ckpts), None, |_| {}).unwrap() {
        BatchRun::Complete(outcomes) => outcomes,
        BatchRun::Suspended(_) => panic!("uncapped resume must complete"),
    };
    assert_eq!(resumed.len(), fleet);
    let by_name = |name: &str| reference.iter().find(|o| o.name == name).unwrap();
    for r in &resumed {
        let reference = by_name(&r.name);
        assert_eq!(r.steps, reference.steps, "{}", r.name);
        assert_eq!(r.output.gbest_fit, reference.output.gbest_fit, "{}", r.name);
        assert_eq!(r.output.gbest_pos, reference.output.gbest_pos, "{}", r.name);
        assert_eq!(r.output.history, reference.output.history, "{}", r.name);
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// ISSUE 8 satellite: `bind` reclaims only *bona fide* stale sockets.
/// The old reclaim path unlinked whatever sat at the path the moment
/// `connect` failed — including regular files that were never ours.
#[test]
fn stale_socket_is_cleaned_up_and_live_socket_is_refused() {
    let dir = temp_dir("bind");
    let socket = dir.join("svc.sock");
    // A genuinely stale socket: a previous daemon bound it and died
    // without unlinking (std's UnixListener does not unlink on drop).
    drop(bind(&socket).unwrap());
    assert!(socket.exists(), "drop must leave the socket file behind");
    let listener = bind(&socket).expect("stale socket must be reclaimed");
    // A *live* socket must be refused.
    let err = bind(&socket).unwrap_err().to_string();
    assert!(err.contains("already being served"), "{err}");
    drop(listener);

    // A regular file at the path is not ours to delete: refuse loudly
    // and leave every byte in place.
    let decoy = dir.join("decoy.txt");
    std::fs::write(&decoy, b"important bytes").unwrap();
    let err = bind(&decoy).unwrap_err().to_string();
    assert!(err.contains("not a socket"), "{err}");
    assert_eq!(
        std::fs::read(&decoy).unwrap(),
        b"important bytes",
        "bind must never unlink a non-socket"
    );
    std::fs::remove_dir_all(&dir).ok();
}

/// ISSUE 8 tentpole: one service, two doors. A TCP listener and the
/// Unix socket front the same scheduler over the byte-identical
/// protocol; submissions through either transport are visible — and
/// cancellable — through the other.
#[test]
fn tcp_and_unix_clients_share_one_service() {
    let dir = temp_dir("tcp");
    let socket = dir.join("svc.sock");
    let scheduler = JobScheduler::with_streams(2, 2);
    let (service, handle) = ServiceSession::new(
        &scheduler,
        knobs(2),
        None,
        vec![spec("resident", EngineKind::Queue, 128, 500_000, 1)],
    )
    .unwrap();
    let tcp = bind_tcp("127.0.0.1:0").unwrap();
    let addr = tcp.local_addr().unwrap();
    let listeners = vec![Listener::Unix(bind(&socket).unwrap()), Listener::Tcp(tcp)];
    let _accept = spawn_server_on(listeners, handle, 64);
    let svc = std::thread::spawn(move || service.run().unwrap());

    // Ping through the TCP door.
    assert!(ok(&roundtrip_tcp(addr, r#"{"op": "ping"}"#)));

    // Submit over TCP (with a tenant label riding the same `job` object)...
    let doc = roundtrip_tcp(
        addr,
        r#"{"op": "submit", "job": {"name": "tcp-born", "fitness": "cubic", "engine": "reduction", "particles": 96, "iters": 400000, "seed": 2, "tenant": "edge"}}"#,
    );
    assert!(ok(&doc), "{doc:?}");

    // ...and the Unix side sees it: one scheduler behind both doors.
    let doc = roundtrip(&socket, r#"{"op": "status"}"#);
    assert!(ok(&doc), "{doc:?}");
    let live = rows(&doc, "live");
    assert_eq!(live.len(), 2);
    assert_eq!(live[1].str_field("name").unwrap(), "tcp-born");

    // A TCP watch subscription gets the same event stream.
    {
        let stream = TcpStream::connect(addr).unwrap();
        let mut writer = stream.try_clone().unwrap();
        writeln!(writer, r#"{{"op": "watch"}}"#).unwrap();
        writer.flush().unwrap();
        let mut reader = BufReader::new(stream);
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(ok(&Json::parse(line.trim()).unwrap()), "{line:?}");
        for _ in 0..4 {
            line.clear();
            reader.read_line(&mut line).unwrap();
            let ev = Json::parse(line.trim()).unwrap();
            assert_eq!(ev.str_field("event").unwrap(), "report");
        }
    }

    // Cancel the TCP-born job from the Unix side, then shut down
    // through TCP: a drain with no live jobs needs no snapshot dir.
    assert!(ok(&roundtrip(&socket, r#"{"op": "cancel", "name": "tcp-born"}"#)));
    assert!(ok(&roundtrip_tcp(addr, r#"{"op": "cancel", "name": "resident"}"#)));
    let doc = roundtrip_tcp(addr, r#"{"op": "drain"}"#);
    assert!(ok(&doc), "{doc:?}");

    let end = svc.join().unwrap();
    assert_eq!(end.finished_total, 2);
    std::fs::remove_dir_all(&dir).ok();
}

/// ISSUE 8 tentpole: per-tenant admission quotas are enforced at the
/// wire with loud, named errors — and a cancel releases the quota,
/// because usage is scanned off the live slot table, never a counter.
#[test]
fn tenant_quotas_are_enforced_at_the_wire() {
    let dir = temp_dir("quota");
    let socket = dir.join("svc.sock");
    let scheduler = JobScheduler::with_streams(2, 2);
    let mut cfg = knobs(2);
    cfg.quota_jobs = 1;
    let (service, handle) = ServiceSession::new(&scheduler, cfg, None, Vec::new()).unwrap();
    let _accept = spawn_server(bind(&socket).unwrap(), handle);
    let svc = std::thread::spawn(move || service.run().unwrap());

    let submit = |name: &str, tenant: &str| {
        roundtrip(
            &socket,
            &format!(
                r#"{{"op": "submit", "job": {{"name": "{name}", "fitness": "cubic", "particles": 64, "iters": 500000, "tenant": "{tenant}"}}}}"#
            ),
        )
    };
    // First job per tenant fits; the second trips the cap, loudly.
    assert!(ok(&submit("a1", "acme")));
    let doc = submit("a2", "acme");
    assert!(!ok(&doc), "{doc:?}");
    let err = doc.str_field("error").unwrap();
    assert!(err.contains("concurrent-job quota"), "{err}");
    assert!(err.contains("acme"), "{err}");
    // Another tenant's pool is untouched.
    assert!(ok(&submit("b1", "bloor")));
    // Cancelling the blocker frees the slot for the refused job.
    assert!(ok(&roundtrip(&socket, r#"{"op": "cancel", "name": "a1"}"#)));
    assert!(ok(&submit("a2", "acme")));

    for name in ["a2", "b1"] {
        assert!(ok(&roundtrip(&socket, &format!(r#"{{"op": "cancel", "name": "{name}"}}"#))));
    }
    assert!(ok(&roundtrip(&socket, r#"{"op": "drain"}"#)));
    let end = svc.join().unwrap();
    assert_eq!(end.finished_total, 3);
    std::fs::remove_dir_all(&dir).ok();
}

/// ISSUE 8 satellite: a maximize job whose swarm never improves keeps
/// `gbest = -inf`, which JSON cannot carry as a number. The wire
/// renders it as `null` in status rows, watch reports, and cancel
/// acknowledgements — and clients must round-trip that without dying.
#[test]
fn non_finite_gbest_is_null_on_the_wire_and_survives_clients() {
    /// Every evaluation is -inf: under maximize, nothing ever strictly
    /// improves on the -inf starting gbest.
    struct BottomlessPit;
    impl Fitness for BottomlessPit {
        fn name(&self) -> &'static str {
            "bottomless"
        }
        fn default_bounds(&self) -> (f64, f64) {
            (-1.0, 1.0)
        }
        fn default_objective(&self) -> Objective {
            Objective::Maximize
        }
        fn eval(&self, _x: &[f64]) -> f64 {
            f64::NEG_INFINITY
        }
    }

    let dir = temp_dir("null-gbest");
    let socket = dir.join("svc.sock");
    let scheduler = JobScheduler::with_streams(1, 1);
    let job = JobSpec::new(
        "abyss",
        EngineKind::Queue,
        PsoParams::paper_1d(64, 300_000),
        Arc::new(BottomlessPit),
        Objective::Maximize,
        7,
    );
    let (service, handle) = ServiceSession::new(&scheduler, knobs(1), None, vec![job]).unwrap();
    let _accept = spawn_server(bind(&socket).unwrap(), handle);
    let svc = std::thread::spawn(move || service.run().unwrap());

    // Status: the live row carries `"gbest": null`, parses, and
    // re-renders to a line that parses right back (what
    // `cupso status --json` prints is this exact re-render).
    let doc = roundtrip(&socket, r#"{"op": "status"}"#);
    assert!(ok(&doc), "{doc:?}");
    let live = rows(&doc, "live");
    assert_eq!(live.len(), 1);
    assert_eq!(live[0].num_or_null_field("gbest").unwrap(), None);
    let again = Json::parse(&doc.render()).expect("re-rendered status must parse");
    assert_eq!(rows(&again, "live")[0].num_or_null_field("gbest").unwrap(), None);

    // Watch: report rows for the never-improving job carry null too.
    {
        let stream = UnixStream::connect(&socket).unwrap();
        let mut writer = stream.try_clone().unwrap();
        writeln!(writer, r#"{{"op": "watch"}}"#).unwrap();
        writer.flush().unwrap();
        let mut reader = BufReader::new(stream);
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(ok(&Json::parse(line.trim()).unwrap()), "{line:?}");
        line.clear();
        reader.read_line(&mut line).unwrap();
        let ev = Json::parse(line.trim()).unwrap_or_else(|e| panic!("bad event {line:?}: {e}"));
        assert_eq!(ev.str_field("event").unwrap(), "report");
        assert_eq!(ev.str_field("job").unwrap(), "abyss");
        assert_eq!(ev.num_or_null_field("gbest").unwrap(), None);
    }

    // Cancel: the finished row tolerates the null as well.
    let doc = roundtrip(&socket, r#"{"op": "cancel", "name": "abyss"}"#);
    assert!(ok(&doc), "{doc:?}");
    let job = doc.get("job").unwrap();
    assert_eq!(job.num_or_null_field("gbest").unwrap(), None);

    assert!(ok(&roundtrip(&socket, r#"{"op": "drain"}"#)));
    svc.join().unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

/// ISSUE 10 tentpole: the `metrics` verb serves one structured JSON
/// document — byte-identical in shape over both transports — that
/// `cupso top` and `cupso status --metrics` render client-side. Pin
/// the envelope and the document's top-level shape.
#[test]
fn metrics_verb_has_a_stable_shape_over_unix_and_tcp() {
    let dir = temp_dir("metrics");
    let socket = dir.join("svc.sock");
    let scheduler = JobScheduler::with_streams(2, 2);
    let (service, handle) = ServiceSession::new(
        &scheduler,
        knobs(2),
        None,
        vec![spec("resident", EngineKind::Queue, 128, 500_000, 1)],
    )
    .unwrap();
    let tcp = bind_tcp("127.0.0.1:0").unwrap();
    let addr = tcp.local_addr().unwrap();
    let listeners = vec![Listener::Unix(bind(&socket).unwrap()), Listener::Tcp(tcp)];
    let _accept = spawn_server_on(listeners, handle, 64);
    let svc = std::thread::spawn(move || service.run().unwrap());

    let check = |doc: &Json| {
        assert!(ok(doc), "{doc:?}");
        assert_eq!(doc.str_field("op").unwrap(), "metrics");
        let m = doc.get("metrics").expect("reply carries a metrics object");
        m.get("enabled").unwrap().as_bool("enabled").unwrap();
        m.get("uptime_s").unwrap().as_u64("uptime_s").unwrap();
        // Always present; null until a snapshot lands (this service has
        // no snapshot dir, so it may be null or — because telemetry is
        // process-global — a number left by a sibling test's persist).
        m.num_or_null_field("last_snapshot_age_s").unwrap();
        let counters = m.get("counters").expect("counters object");
        for name in [
            "rounds_total",
            "jobs_admitted_total",
            "jobs_finished_total",
            "conns_accepted_total",
            "snapshots_total",
        ] {
            counters
                .get(name)
                .unwrap_or_else(|| panic!("missing counter {name}"))
                .as_u64(name)
                .unwrap();
        }
        let gauges = m.get("gauges").expect("gauges object");
        gauges
            .get("conn_pending_hwm")
            .expect("conn_pending_hwm gauge")
            .as_u64("conn_pending_hwm")
            .unwrap();
        let histos = m.get("histos").expect("histos object");
        let step = histos.get("round_step_ns").expect("round_step_ns histo");
        step.get("count").unwrap().as_u64("count").unwrap();
        assert!(step.get("bins").is_some(), "histos carry their bins");
        let trace = m.get("trace").expect("trace object");
        trace.get("recorded").unwrap().as_u64("recorded").unwrap();
        assert!(trace.get("capacity").unwrap().as_u64("capacity").unwrap() > 0);
    };
    check(&roundtrip(&socket, r#"{"op": "metrics"}"#));
    check(&roundtrip_tcp(addr, r#"{"op": "metrics"}"#));

    // The resident job's admission is on the books (the registry is
    // process-global, so `>= 1`, not `== 1`).
    let doc = roundtrip(&socket, r#"{"op": "metrics"}"#);
    let admitted = doc
        .get("metrics")
        .unwrap()
        .get("counters")
        .unwrap()
        .get("jobs_admitted_total")
        .unwrap()
        .as_u64("jobs_admitted_total")
        .unwrap();
    assert!(admitted >= 1, "resident admission must be counted");

    assert!(ok(&roundtrip(&socket, r#"{"op": "cancel", "name": "resident"}"#)));
    assert!(ok(&roundtrip(&socket, r#"{"op": "drain"}"#)));
    svc.join().unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

/// ISSUE 10 tentpole: a drain dumps the flight-recorder trace ring to
/// the configured sink, in the pinned line format, with the admit and
/// drain events of this very service on it.
#[test]
fn drain_dumps_the_trace_ring_to_the_configured_file() {
    let dir = temp_dir("trace-dump");
    let socket = dir.join("svc.sock");
    let dump = dir.join("trace.log");
    // Point the process-global trace sink at our file. Sibling tests
    // draining concurrently may append their own dumps here too — every
    // assertion below is containment, not equality, for that reason.
    cupso::telemetry::set_trace_path(Some(dump.clone()));

    let scheduler = JobScheduler::with_streams(2, 2);
    let (service, handle) = ServiceSession::new(
        &scheduler,
        knobs(2),
        None,
        vec![spec("resident", EngineKind::Queue, 128, 500_000, 1)],
    )
    .unwrap();
    let _accept = spawn_server(bind(&socket).unwrap(), handle);
    let svc = std::thread::spawn(move || service.run().unwrap());

    assert!(ok(&roundtrip(&socket, r#"{"op": "cancel", "name": "resident"}"#)));
    assert!(ok(&roundtrip(&socket, r#"{"op": "drain"}"#)));
    svc.join().unwrap();
    cupso::telemetry::set_trace_path(None);

    let text = std::fs::read_to_string(&dump).expect("drain must write the trace dump");
    assert!(text.contains("== cupso trace ring (drain):"), "{text}");
    assert!(text.contains("event=admit"), "{text}");
    assert!(text.contains("event=cancel"), "{text}");
    assert!(text.contains("event=drain"), "{text}");
    assert!(text.contains("== end trace ring =="), "{text}");
    // Every event line carries the pinned key=value fields.
    let line = text
        .lines()
        .find(|l| l.starts_with("trace seq="))
        .expect("at least one event line");
    for key in ["t_ms=", "event=", "a=", "b="] {
        assert!(line.contains(key), "{line}");
    }
    std::fs::remove_dir_all(&dir).ok();
}
