//! Bounded saturation tier (ISSUE 8): the TCP event-loop front end
//! under concurrent client load, scaled down for `cargo test`. The
//! full stampede (1000+ concurrent TCP clients) lives in
//! `benches/service_saturation.rs`; this tier pins the same invariants
//! at a size every CI runner can afford:
//!
//! * every concurrent submit is admitted and acknowledged (admission
//!   never deadlocks or drops a client under a burst);
//! * a full connection table sheds over-cap clients with the loud
//!   `{"ok": false, ..., "shed": true}` line — and frees slots again
//!   when holders disconnect;
//! * watch fan-out delivers every report to every subscriber exactly
//!   once, terminated by `{"event":"end"}`.

use cupso::config::BatchConfig;
use cupso::scheduler::{JobScheduler, SchedPolicy};
use cupso::service::proto::Json;
use cupso::service::{bind_tcp, spawn_server_on, Listener, ServiceEnd, ServiceSession};
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::{Arc, Barrier};
use std::thread::JoinHandle;
use std::time::Duration;

struct Daemon {
    addr: SocketAddr,
    svc: JoinHandle<ServiceEnd>,
}

fn start(policy: &str, max_conns: usize) -> Daemon {
    let knobs = BatchConfig {
        workers: 2,
        policy: policy.into(),
        streams: 2,
        batch_steps: 1,
        preempt_quantum: 0,
        pack: false,
        pack_min: 2,
        pack_max: 0,
        quota_jobs: 0,
        quota_steps: 0,
        checkpoint_every: 0,
        checkpoint_keep: 1,
        telemetry: true,
        trace_dump: None,
        jobs: Vec::new(),
    };
    let scheduler = JobScheduler::with_streams(2, 2)
        .policy(SchedPolicy::parse(policy).unwrap())
        .batch_steps(1);
    let (service, handle) = ServiceSession::new(&scheduler, knobs, None, Vec::new()).unwrap();
    let tcp = bind_tcp("127.0.0.1:0").unwrap();
    let addr = tcp.local_addr().unwrap();
    let _accept = spawn_server_on(vec![Listener::Tcp(tcp)], handle, max_conns);
    let svc = std::thread::spawn(move || service.run().unwrap());
    Daemon { addr, svc }
}

fn roundtrip(addr: SocketAddr, line: &str) -> Json {
    let mut stream = TcpStream::connect(addr).expect("connect");
    stream
        .set_read_timeout(Some(Duration::from_secs(120)))
        .unwrap();
    writeln!(stream, "{line}").unwrap();
    stream.flush().unwrap();
    let mut reader = BufReader::new(stream);
    let mut reply = String::new();
    reader.read_line(&mut reply).unwrap();
    Json::parse(reply.trim()).unwrap_or_else(|e| panic!("bad response {reply:?}: {e}"))
}

fn ok(doc: &Json) -> bool {
    doc.get("ok").map(|v| v == &Json::Bool(true)).unwrap_or(false)
}

fn wait_finished(addr: SocketAddr, n: u64) {
    loop {
        let doc = roundtrip(addr, r#"{"op": "status"}"#);
        let done = doc
            .get("finished_total")
            .and_then(|v| v.as_u64("finished_total").ok());
        if done == Some(n) {
            return;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
}

fn drain(addr: SocketAddr) {
    let doc = roundtrip(addr, r#"{"op": "drain"}"#);
    assert!(ok(&doc), "{doc:?}");
}

#[test]
fn concurrent_tcp_submit_burst_is_fully_admitted() {
    let clients = 96usize;
    let d = start("weighted-fair", clients + 8);
    let go = Arc::new(Barrier::new(clients));
    let handles: Vec<_> = (0..clients)
        .map(|i| {
            let go = Arc::clone(&go);
            let addr = d.addr;
            std::thread::Builder::new()
                .stack_size(256 * 1024)
                .spawn(move || {
                    go.wait();
                    let reply = roundtrip(
                        addr,
                        &format!(
                            r#"{{"op": "submit", "job": {{"name": "burst{i}", "fitness": "cubic", "particles": 16, "iters": 50, "seed": {}, "tenant": "t{}"}}}}"#,
                            i + 1,
                            i % 4
                        ),
                    );
                    assert!(ok(&reply), "client {i}: {reply:?}");
                })
                .unwrap()
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    wait_finished(d.addr, clients as u64);
    drain(d.addr);
    let end = d.svc.join().unwrap();
    assert_eq!(end.finished_total, clients as u64);
}

#[test]
fn over_cap_clients_are_shed_loudly_and_slots_recycle() {
    let cap = 8usize;
    let probes = 24usize;
    let d = start("round-robin", cap);
    // Fill the table with proven-live holder connections.
    let holders: Vec<TcpStream> = (0..cap)
        .map(|i| {
            let mut stream = TcpStream::connect(d.addr).expect("holder connect");
            stream
                .set_read_timeout(Some(Duration::from_secs(120)))
                .unwrap();
            writeln!(stream, r#"{{"op": "ping"}}"#).unwrap();
            stream.flush().unwrap();
            let mut reader = BufReader::new(stream);
            let mut reply = String::new();
            reader.read_line(&mut reply).unwrap();
            assert!(ok(&Json::parse(reply.trim()).unwrap()), "holder {i}: {reply:?}");
            reader.into_inner()
        })
        .collect();
    // Every probe past the cap gets the loud refusal, concurrently.
    let handles: Vec<_> = (0..probes)
        .map(|i| {
            let addr = d.addr;
            std::thread::spawn(move || {
                let stream = TcpStream::connect(addr).expect("probe connect");
                stream
                    .set_read_timeout(Some(Duration::from_secs(120)))
                    .unwrap();
                let mut reader = BufReader::new(stream);
                let mut line = String::new();
                reader.read_line(&mut line).unwrap();
                let reply = Json::parse(line.trim())
                    .unwrap_or_else(|e| panic!("probe {i}: bad shed line {line:?}: {e}"));
                assert!(!ok(&reply), "probe {i} must be refused: {reply:?}");
                assert_eq!(reply.get("shed"), Some(&Json::Bool(true)), "{reply:?}");
                assert!(
                    reply.str_field("error").unwrap().contains("connection cap"),
                    "{reply:?}"
                );
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    // Releasing holders frees slots: service again, not a dead daemon.
    drop(holders);
    loop {
        if ok(&roundtrip(d.addr, r#"{"op": "ping"}"#)) {
            break;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    drain(d.addr);
    d.svc.join().unwrap();
}

#[test]
fn watch_fanout_delivers_every_report_to_every_subscriber() {
    let watchers = 8usize;
    let rounds = 64u64;
    let d = start("round-robin", watchers + 8);
    let ready = Arc::new(Barrier::new(watchers + 1));
    let handles: Vec<_> = (0..watchers)
        .map(|i| {
            let addr = d.addr;
            let ready = Arc::clone(&ready);
            std::thread::spawn(move || {
                let mut stream = TcpStream::connect(addr).expect("watcher connect");
                stream
                    .set_read_timeout(Some(Duration::from_secs(120)))
                    .unwrap();
                writeln!(stream, r#"{{"op": "watch"}}"#).unwrap();
                stream.flush().unwrap();
                let mut reader = BufReader::new(stream);
                let mut line = String::new();
                reader.read_line(&mut line).unwrap();
                assert!(ok(&Json::parse(line.trim()).unwrap()), "watcher {i}: {line:?}");
                ready.wait();
                let mut reports = 0u64;
                loop {
                    line.clear();
                    reader.read_line(&mut line).unwrap();
                    let ev = Json::parse(line.trim())
                        .unwrap_or_else(|e| panic!("watcher {i}: bad event {line:?}: {e}"));
                    match ev.str_field("event").unwrap() {
                        "end" => return reports,
                        "report" => {
                            assert_eq!(ev.str_field("job").unwrap(), "beacon");
                            reports += 1;
                        }
                        other => panic!("watcher {i}: unexpected event {other:?}"),
                    }
                }
            })
        })
        .collect();
    ready.wait(); // all subscriptions acknowledged before the job runs
    let reply = roundtrip(
        d.addr,
        &format!(
            r#"{{"op": "submit", "job": {{"name": "beacon", "fitness": "cubic", "particles": 32, "iters": {rounds}, "seed": 9}}}}"#
        ),
    );
    assert!(ok(&reply), "{reply:?}");
    wait_finished(d.addr, 1);
    drain(d.addr);
    for (i, h) in handles.into_iter().enumerate() {
        let reports = h.join().unwrap();
        assert_eq!(reports, rounds, "watcher {i} must see every round");
    }
    d.svc.join().unwrap();
}
