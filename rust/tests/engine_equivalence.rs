//! Cross-engine equivalence — the core correctness argument for the
//! queue algorithms.
//!
//! Reduction, Loop-Unrolling and Queue differ *only* in how the best
//! datum is aggregated; with the counter-based RNG all three must
//! reproduce the synchronous serial reference trajectory **bit-exactly**,
//! for every workload shape. Queue-Lock relaxes inter-block ordering, so
//! it is held to: bit-exactness in the single-block case, and monotone +
//! quality-band behaviour in the general case.

use cupso::engine::{Engine, ParallelSettings, QueueEngine, QueueLockEngine, ReductionEngine};
use cupso::fitness::{by_name, Cubic, Fitness, Objective};
use cupso::pso::{serial_sync, PsoParams};
use cupso::testsupport::{gen_usize, prop_check};

/// Workload grid for the exact-equivalence checks: both paper dims, odd
/// swarm sizes (partial blocks), and sizes around block boundaries.
fn workloads() -> Vec<PsoParams> {
    vec![
        PsoParams::paper_1d(32, 40),
        PsoParams::paper_1d(100, 40),   // partial block
        PsoParams::paper_1d(256, 40),   // exactly one block
        PsoParams::paper_1d(257, 40),   // one block + 1
        PsoParams::paper_1d(1024, 25),  // multiple blocks
        PsoParams::paper_120d(64, 15),
        PsoParams::paper_120d(300, 10), // partial blocks, high dim
    ]
}

#[test]
fn reduction_unroll_queue_match_serial_sync_bit_exactly() {
    let settings = ParallelSettings::with_workers(4);
    for params in workloads() {
        let oracle = serial_sync::run(&params, &Cubic, Objective::Maximize, 42);
        let engines: Vec<Box<dyn Engine>> = vec![
            Box::new(ReductionEngine::new(settings.clone())),
            Box::new(ReductionEngine::unrolled(settings.clone())),
            Box::new(QueueEngine::new(settings.clone())),
        ];
        for mut e in engines {
            let out = e.run(&params, &Cubic, Objective::Maximize, 42);
            assert_eq!(
                out.gbest_fit, oracle.gbest_fit,
                "{} fit mismatch on n={} d={}",
                e.name(),
                params.n,
                params.dim
            );
            assert_eq!(
                out.gbest_pos, oracle.gbest_pos,
                "{} pos mismatch on n={} d={}",
                e.name(),
                params.n,
                params.dim
            );
            assert_eq!(
                out.history, oracle.history,
                "{} trajectory mismatch on n={} d={}",
                e.name(),
                params.n,
                params.dim
            );
        }
    }
}

#[test]
fn queue_lock_single_block_is_bit_exact() {
    // With one block there is no cross-block race: the fused engine is
    // sequentially identical to the synchronous reference.
    let settings = ParallelSettings::with_workers(4);
    for params in [PsoParams::paper_1d(200, 50), PsoParams::paper_120d(128, 15)] {
        let oracle = serial_sync::run(&params, &Cubic, Objective::Maximize, 7);
        let mut e = QueueLockEngine::new(settings.clone());
        let out = e.run(&params, &Cubic, Objective::Maximize, 7);
        assert_eq!(out.gbest_fit, oracle.gbest_fit, "n={}", params.n);
        assert_eq!(out.gbest_pos, oracle.gbest_pos);
        assert_eq!(out.history, oracle.history);
    }
}

#[test]
fn queue_lock_multi_block_is_monotone_and_in_quality_band() {
    let settings = ParallelSettings::with_workers(8);
    let params = PsoParams::paper_120d(1024, 40);
    let oracle = serial_sync::run(&params, &Cubic, Objective::Maximize, 9);
    let mut e = QueueLockEngine::new(settings);
    let out = e.run(&params, &Cubic, Objective::Maximize, 9);
    for w in out.history.windows(2) {
        assert!(w[1].1 >= w[0].1, "gbest worsened");
    }
    // Relaxed sync alters the trajectory — typically *helping* (a block
    // sees gbest updates from earlier blocks of the same iteration, like
    // the serial in-loop Algorithm 1) — but must not degrade the quality
    // class: no worse than 80% of the synchronous reference.
    assert!(
        out.gbest_fit >= 0.8 * oracle.gbest_fit,
        "queue-lock quality {} degraded vs oracle {}",
        out.gbest_fit,
        oracle.gbest_fit
    );
}

#[test]
fn property_equivalence_over_random_workloads() {
    // Property test: random (n, dim, iters, seed) — queue engine equals
    // the oracle bit-exactly on every sampled workload.
    let settings = ParallelSettings::with_workers(4);
    prop_check(
        0xC0FFEE,
        12,
        |rng| {
            let n = gen_usize(rng, 2, 600);
            let dim = [1usize, 2, 3, 7, 120][gen_usize(rng, 0, 4)];
            let iters = gen_usize(rng, 1, 25) as u64;
            let seed = rng.next_u64();
            (n, dim, iters, seed)
        },
        |&(n, dim, iters, seed)| {
            let mut out = Vec::new();
            if n > 2 {
                out.push((n / 2, dim, iters, seed));
            }
            if iters > 1 {
                out.push((n, dim, iters / 2, seed));
            }
            if dim > 1 {
                out.push((n, 1, iters, seed));
            }
            out
        },
        |&(n, dim, iters, seed)| {
            let params = PsoParams::paper_1d(n, iters);
            let params = PsoParams { dim, ..params };
            let oracle = serial_sync::run(&params, &Cubic, Objective::Maximize, seed);
            let mut e = QueueEngine::new(settings.clone());
            let got = e.run(&params, &Cubic, Objective::Maximize, seed);
            if got.gbest_fit != oracle.gbest_fit || got.gbest_pos != oracle.gbest_pos {
                return Err(format!(
                    "queue {} vs oracle {}",
                    got.gbest_fit, oracle.gbest_fit
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn equivalence_holds_for_minimization_too() {
    let sphere = by_name("sphere").unwrap();
    let params = PsoParams::for_fitness(sphere.as_ref(), 300, 5, 30, 0.5);
    let settings = ParallelSettings::with_workers(4);
    let oracle = serial_sync::run(&params, sphere.as_ref(), Objective::Minimize, 3);
    for mut e in [
        Box::new(ReductionEngine::new(settings.clone())) as Box<dyn Engine>,
        Box::new(QueueEngine::new(settings.clone())),
    ] {
        let out = e.run(&params, sphere.as_ref(), Objective::Minimize, 3);
        assert_eq!(out.gbest_fit, oracle.gbest_fit, "{}", e.name());
        assert_eq!(out.gbest_pos, oracle.gbest_pos, "{}", e.name());
    }
}

/// Cubic everywhere except a NaN pocket for `x[0] > 50` — deterministic,
/// hits both seeded-NaN and stepped-into-NaN particles.
struct NanPocket;

impl cupso::fitness::Fitness for NanPocket {
    fn name(&self) -> &'static str {
        "nan-pocket"
    }
    fn default_bounds(&self) -> (f64, f64) {
        (-100.0, 100.0)
    }
    fn default_objective(&self) -> Objective {
        Objective::Maximize
    }
    fn eval(&self, x: &[f64]) -> f64 {
        if x[0] > 50.0 {
            f64::NAN
        } else {
            Cubic.eval(x)
        }
    }
}

/// Always-NaN objective: nothing can ever improve.
struct AlwaysNan;

impl cupso::fitness::Fitness for AlwaysNan {
    fn name(&self) -> &'static str {
        "always-nan"
    }
    fn default_bounds(&self) -> (f64, f64) {
        (-100.0, 100.0)
    }
    fn default_objective(&self) -> Objective {
        Objective::Maximize
    }
    fn eval(&self, _x: &[f64]) -> f64 {
        f64::NAN
    }
}

#[test]
fn nan_fitness_behaves_identically_across_all_engines() {
    // The NaN policy (fitness module docs): NaN candidates never win, so
    // a partially-NaN objective must leave the bit-exact engines, well,
    // bit-exact against the synchronous oracle. A single-block workload
    // (n ≤ 256) extends the guarantee to Queue-Lock and Async too.
    use cupso::config::EngineKind;
    let params = PsoParams::paper_1d(200, 40);
    let oracle = serial_sync::run(&params, &NanPocket, Objective::Maximize, 11);
    assert!(
        oracle.gbest_fit.is_finite(),
        "oracle best must be finite, got {}",
        oracle.gbest_fit
    );
    for (_, f) in &oracle.history {
        assert!(!f.is_nan(), "NaN leaked into the oracle history");
    }
    for kind in [
        EngineKind::Reduction,
        EngineKind::LoopUnrolling,
        EngineKind::Queue,
        EngineKind::QueueLock,
        EngineKind::AsyncPersistent,
    ] {
        let mut e = cupso::engine::build(kind, 4).unwrap();
        let out = e.run(&params, &NanPocket, Objective::Maximize, 11);
        assert_eq!(out.gbest_fit, oracle.gbest_fit, "{kind:?}");
        assert_eq!(out.gbest_pos, oracle.gbest_pos, "{kind:?}");
        assert_eq!(out.history, oracle.history, "{kind:?}");
    }
    // Algorithm 1 (in-loop gbest) is not bit-comparable to the sync
    // oracle, but the policy invariants must hold there too.
    let serial = cupso::pso::serial::run(&params, &NanPocket, Objective::Maximize, 11);
    assert!(serial.gbest_fit.is_finite());
    assert!(!serial.gbest_pos[0].is_nan());
    for (_, f) in &serial.history {
        assert!(!f.is_nan(), "NaN leaked into the serial history");
    }
    // Sanity: the pocket is actually exercised — some seeded particle
    // starts above x = 50 in [-100, 100] with 200 particles.
    let mut fit = vec![0.0; 1];
    NanPocket.eval_range(&[60.0], 1, 1, 0, 1, &mut fit);
    assert!(fit[0].is_nan());
}

#[test]
fn all_nan_fitness_never_improves_in_any_engine() {
    // Degenerate case: every evaluation is NaN. The global best must stay
    // at the seeding identity (worst = −∞ under Maximize) with zero
    // gbest updates, identically everywhere, for multi-block shapes too.
    use cupso::config::EngineKind;
    let params = PsoParams::paper_1d(700, 15);
    for kind in EngineKind::TABLE3
        .into_iter()
        .chain([EngineKind::AsyncPersistent])
    {
        let mut e = cupso::engine::build(kind, 4).unwrap();
        let out = e.run(&params, &AlwaysNan, Objective::Maximize, 3);
        assert_eq!(
            out.gbest_fit,
            f64::NEG_INFINITY,
            "{kind:?}: NaN won the global best"
        );
        assert_eq!(out.counters.gbest_updates, 0, "{kind:?}");
        for (_, f) in &out.history {
            assert_eq!(*f, f64::NEG_INFINITY, "{kind:?}");
        }
    }
    let oracle = serial_sync::run(&params, &AlwaysNan, Objective::Maximize, 3);
    assert_eq!(oracle.gbest_fit, f64::NEG_INFINITY);
}

#[test]
fn worker_count_does_not_change_results() {
    // The same engine must produce identical results regardless of
    // parallelism degree (1, 2, 8 workers) — scheduling must not leak
    // into numerics for the synchronized engines.
    let params = PsoParams::paper_120d(500, 12);
    let mut reference = None;
    for workers in [1usize, 2, 8] {
        let settings = ParallelSettings::with_workers(workers);
        let mut e = QueueEngine::new(settings);
        let out = e.run(&params, &Cubic, Objective::Maximize, 5);
        match &reference {
            None => reference = Some(out),
            Some(r) => {
                assert_eq!(out.gbest_fit, r.gbest_fit, "workers={workers}");
                assert_eq!(out.gbest_pos, r.gbest_pos, "workers={workers}");
                assert_eq!(out.history, r.history, "workers={workers}");
            }
        }
    }
}
