//! Scheduler correctness: interleaving N concurrent jobs over one shared
//! pool must be invisible to each job's numerics, and the termination
//! criteria must stop jobs exactly when documented.
//!
//! The determinism argument: a [`Run`] owns its entire mutable state
//! (swarm, queues, aux arrays, RNG counters), so the only shared resource
//! is the worker pool — and pool launches are serialized. For the
//! bit-exact engines (Reduction / Loop-Unrolling / Queue / CPU) the
//! trajectory is therefore identical solo vs interleaved, which this
//! suite enforces against both `Engine::run` one-shots and the
//! synchronous serial oracle.

use cupso::config::EngineKind;
use cupso::engine::{self, Engine, ParallelSettings};
use cupso::fitness::{Cubic, Objective};
use cupso::pso::{serial_sync, PsoParams, RunOutput};
use cupso::scheduler::{
    BatchRun, JobScheduler, JobSpec, SchedPolicy, StopReason, TerminationCriteria,
};
use std::sync::Arc;

/// The engines held to bit-exact scheduling invariance.
const BIT_EXACT: [EngineKind; 4] = [
    EngineKind::SerialCpu,
    EngineKind::Reduction,
    EngineKind::LoopUnrolling,
    EngineKind::Queue,
];

fn cubic_spec(name: &str, engine: EngineKind, params: PsoParams, seed: u64) -> JobSpec {
    JobSpec::new(
        name,
        engine,
        params,
        Arc::new(Cubic),
        Objective::Maximize,
        seed,
    )
}

fn assert_outputs_equal(a: &RunOutput, b: &RunOutput, what: &str) {
    assert_eq!(a.gbest_fit, b.gbest_fit, "{what}: fit");
    assert_eq!(a.gbest_pos, b.gbest_pos, "{what}: pos");
    assert_eq!(a.history, b.history, "{what}: history");
    assert_eq!(a.iters, b.iters, "{what}: iters");
}

#[test]
fn stepwise_api_matches_one_shot_for_all_plane_a_engines() {
    // Driving prepare/step/finish manually equals Engine::run for every
    // bit-exact kind, on workloads spanning partial blocks and both dims.
    for params in [
        PsoParams::paper_1d(100, 30),
        PsoParams::paper_1d(257, 20),
        PsoParams::paper_120d(64, 10),
    ] {
        for kind in BIT_EXACT {
            let one_shot = engine::build(kind, 4)
                .unwrap()
                .run(&params, &Cubic, Objective::Maximize, 42);
            let mut e = engine::build(kind, 4).unwrap();
            let mut run = e.prepare(&params, &Cubic, Objective::Maximize, 42);
            while !run.step().done {}
            let stepped = run.finish();
            assert_outputs_equal(&stepped, &one_shot, &format!("{kind:?} n={}", params.n));
        }
    }
}

#[test]
fn stepwise_engines_still_match_the_oracle() {
    // The acceptance bar: through the new prepare/step API, the bit-exact
    // parallel engines reproduce the synchronous serial reference.
    for params in [PsoParams::paper_1d(300, 25), PsoParams::paper_120d(70, 12)] {
        let oracle = serial_sync::run(&params, &Cubic, Objective::Maximize, 7);
        for kind in [
            EngineKind::Reduction,
            EngineKind::LoopUnrolling,
            EngineKind::Queue,
        ] {
            let mut e = engine::build(kind, 4).unwrap();
            let mut run = e.prepare(&params, &Cubic, Objective::Maximize, 7);
            while !run.step().done {}
            let out = run.finish();
            assert_outputs_equal(&out, &oracle, &format!("{kind:?} vs oracle n={}", params.n));
        }
    }
}

#[test]
fn interleaved_jobs_match_solo_runs_bit_exactly() {
    // Six concurrent jobs (two per bit-exact parallel engine, different
    // seeds and shapes) on ONE shared pool, stepped round-robin, must
    // produce the same RunOutput as solo one-shot runs of the same specs.
    let specs: Vec<JobSpec> = vec![
        cubic_spec("r1", EngineKind::Reduction, PsoParams::paper_1d(300, 30), 1),
        cubic_spec("r2", EngineKind::Reduction, PsoParams::paper_120d(64, 12), 2),
        cubic_spec("u1", EngineKind::LoopUnrolling, PsoParams::paper_1d(257, 25), 3),
        cubic_spec("u2", EngineKind::LoopUnrolling, PsoParams::paper_120d(40, 15), 4),
        cubic_spec("q1", EngineKind::Queue, PsoParams::paper_1d(513, 20), 5),
        cubic_spec("q2", EngineKind::Queue, PsoParams::paper_120d(100, 10), 6),
    ];
    let scheduler = JobScheduler::with_workers(4);
    let outcomes = scheduler.run(&specs).unwrap();
    assert_eq!(outcomes.len(), specs.len());
    for (outcome, spec) in outcomes.iter().zip(&specs) {
        let solo = engine::build(spec.engine, 4).unwrap().run(
            &spec.params,
            &Cubic,
            Objective::Maximize,
            spec.seed,
        );
        assert_eq!(outcome.stop, StopReason::Exhausted, "{}", outcome.name);
        assert_eq!(outcome.steps, spec.params.max_iter, "{}", outcome.name);
        assert_outputs_equal(&outcome.output, &solo, &outcome.name);
    }
}

#[test]
fn interleaving_is_policy_invariant_for_bit_exact_engines() {
    // The same job set under round-robin and EDF (different interleaving
    // orders) yields identical per-job outputs.
    let mk_specs = || -> Vec<JobSpec> {
        let mut specs = vec![
            cubic_spec("a", EngineKind::Queue, PsoParams::paper_1d(200, 25), 11),
            cubic_spec("b", EngineKind::Reduction, PsoParams::paper_1d(300, 15), 12),
            cubic_spec("c", EngineKind::Queue, PsoParams::paper_120d(50, 10), 13),
        ];
        specs[0].deadline = Some(25);
        specs[1].deadline = Some(200);
        specs
    };
    let rr = JobScheduler::with_workers(3)
        .policy(SchedPolicy::RoundRobin)
        .run(&mk_specs())
        .unwrap();
    let edf = JobScheduler::with_workers(3)
        .policy(SchedPolicy::EarliestDeadlineFirst)
        .run(&mk_specs())
        .unwrap();
    for (a, b) in rr.iter().zip(&edf) {
        assert_outputs_equal(&a.output, &b.output, &a.name);
    }
    // Weighted-fair (with tenant labels steering its order) is held to
    // the same bar: policy and tenancy reorder rounds, never numerics.
    let wf = {
        let mut specs = mk_specs();
        specs[0].tenant = Some(Arc::from("t-a"));
        specs[1].tenant = Some(Arc::from("t-b"));
        JobScheduler::with_workers(3)
            .policy(SchedPolicy::WeightedFair)
            .run(&specs)
            .unwrap()
    };
    for (a, b) in rr.iter().zip(&wf) {
        assert_outputs_equal(&a.output, &b.output, &format!("wf {}", a.name));
    }
}

#[test]
fn step_many_matches_single_steps_for_all_plane_a_engines() {
    // Batched stepping must be trajectory-identical to manual stepping
    // for every bit-exact engine; the async engine's override (one
    // free-running launch per batch) joins the guarantee on single-block
    // workloads, where its relaxation has no room to bite.
    let mut kinds = BIT_EXACT.to_vec();
    kinds.push(EngineKind::QueueLock); // single block below → bit-exact
    kinds.push(EngineKind::AsyncPersistent);
    let params = PsoParams::paper_1d(200, 23);
    for kind in kinds {
        let mut e = engine::build(kind, 4).unwrap();
        let mut run = e.prepare(&params, &Cubic, Objective::Maximize, 5);
        while !run.step().done {}
        let stepped = run.finish();
        for batch in [1u64, 4, 7, 23, 100] {
            let mut e = engine::build(kind, 4).unwrap();
            let mut run = e.prepare(&params, &Cubic, Objective::Maximize, 5);
            while !run.step_many(batch).done {}
            let batched = run.finish();
            let what = format!("{kind:?} batch={batch} vs single-step");
            assert_eq!(batched.iters, 23, "{what}");
            if kind == EngineKind::AsyncPersistent && batch > 1 {
                // The async override documents batch-granular history
                // sampling, so only the trajectory endpoint is comparable.
                assert_eq!(batched.gbest_fit, stepped.gbest_fit, "{what}: fit");
                assert_eq!(batched.gbest_pos, stepped.gbest_pos, "{what}: pos");
            } else {
                assert_outputs_equal(&batched, &stepped, &what);
            }
        }
    }
}

#[test]
fn step_many_reports_batch_improvement_and_stops_at_budget() {
    let params = PsoParams::paper_1d(128, 10);
    let mut e = engine::build(EngineKind::Queue, 2).unwrap();
    let mut run = e.prepare(&params, &Cubic, Objective::Maximize, 1);
    // A 1-D Cubic swarm improves within the first few iterations, so the
    // first batch must report improvement with a position attached.
    let rep = run.step_many(4);
    assert_eq!(rep.iter, 4);
    assert!(rep.improved, "no improvement in the first 4 iterations");
    assert!(rep.gbest_pos.is_some());
    assert!(!rep.done);
    // Over-long batch clamps at the budget.
    let rep = run.step_many(100);
    assert_eq!(rep.iter, 10);
    assert!(rep.done);
    // Stepping a finished run stays a no-op.
    let rep = run.step_many(5);
    assert_eq!(rep.iter, 10);
    assert!(rep.done);
    assert!(!rep.improved);
    assert_eq!(run.finish().iters, 10);
}

/// The acceptance matrix: solo one-shot vs serialized interleaving vs
/// concurrent streams — bit-identical per-job outputs for every
/// bit-exact engine, at several stream counts, batch sizes and both
/// policies.
#[test]
fn concurrent_streams_match_solo_runs_bit_exactly() {
    let mk_specs = || -> Vec<JobSpec> {
        let mut specs = vec![
            cubic_spec("cpu", EngineKind::SerialCpu, PsoParams::paper_1d(150, 18), 21),
            cubic_spec("r1", EngineKind::Reduction, PsoParams::paper_1d(300, 30), 1),
            cubic_spec("r2", EngineKind::Reduction, PsoParams::paper_120d(64, 12), 2),
            cubic_spec("u1", EngineKind::LoopUnrolling, PsoParams::paper_1d(257, 25), 3),
            cubic_spec("q1", EngineKind::Queue, PsoParams::paper_1d(513, 20), 5),
            cubic_spec("q2", EngineKind::Queue, PsoParams::paper_120d(100, 10), 6),
        ];
        // Deadlines change the EDF interleaving order; bit-exactness must
        // survive any of it.
        specs[1].deadline = Some(40);
        specs[4].deadline = Some(15);
        specs
    };
    let solo: Vec<cupso::pso::RunOutput> = mk_specs()
        .iter()
        .map(|s| {
            engine::build(s.engine, 4)
                .unwrap()
                .run(&s.params, &Cubic, Objective::Maximize, s.seed)
        })
        .collect();
    for (streams, batch, policy) in [
        (1, 1, SchedPolicy::RoundRobin), // the serialized PR-1 path
        (2, 1, SchedPolicy::RoundRobin),
        (4, 3, SchedPolicy::RoundRobin),
        (2, 5, SchedPolicy::EarliestDeadlineFirst),
        (4, 1, SchedPolicy::EarliestDeadlineFirst),
        (3, 7, SchedPolicy::EarliestDeadlineFirst),
    ] {
        let scheduler = JobScheduler::with_streams(4, streams)
            .policy(policy)
            .batch_steps(batch);
        let outcomes = scheduler.run(&mk_specs()).unwrap();
        for (outcome, reference) in outcomes.iter().zip(&solo) {
            assert_eq!(outcome.stop, StopReason::Exhausted, "{}", outcome.name);
            assert_outputs_equal(
                &outcome.output,
                reference,
                &format!("S={streams} batch={batch} {policy} job {}", outcome.name),
            );
        }
    }
}

/// ISSUE 8 tentpole: the weighted-fair policy only reorders rounds.
/// With tenant labels attached (what the policy keys on), every job
/// still reproduces its solo run bit for bit at every streams/batch
/// combination.
#[test]
fn weighted_fair_matches_solo_runs_bit_exactly() {
    let mk_specs = || -> Vec<JobSpec> {
        let mut specs = vec![
            cubic_spec("a1", EngineKind::Queue, PsoParams::paper_1d(300, 30), 41),
            cubic_spec("a2", EngineKind::Reduction, PsoParams::paper_1d(257, 22), 42),
            cubic_spec("b1", EngineKind::LoopUnrolling, PsoParams::paper_1d(150, 28), 43),
            cubic_spec("anon", EngineKind::Queue, PsoParams::paper_120d(64, 12), 44),
        ];
        specs[0].tenant = Some(Arc::from("acme"));
        specs[1].tenant = Some(Arc::from("acme"));
        specs[2].tenant = Some(Arc::from("bloor"));
        specs
    };
    let solo: Vec<RunOutput> = mk_specs()
        .iter()
        .map(|s| {
            engine::build(s.engine, 4)
                .unwrap()
                .run(&s.params, &Cubic, Objective::Maximize, s.seed)
        })
        .collect();
    for (streams, batch) in [(1u64, 1u64), (2, 1), (2, 5), (4, 3)] {
        let scheduler = JobScheduler::with_streams(4, streams as usize)
            .policy(SchedPolicy::WeightedFair)
            .batch_steps(batch);
        let outcomes = scheduler.run(&mk_specs()).unwrap();
        for (outcome, reference) in outcomes.iter().zip(&solo) {
            assert_eq!(outcome.stop, StopReason::Exhausted, "{}", outcome.name);
            assert_outputs_equal(
                &outcome.output,
                reference,
                &format!("wf S={streams} batch={batch} job {}", outcome.name),
            );
        }
    }
}

/// ISSUE 8 acceptance: a *service* under the weighted-fair policy, with
/// per-tenant quotas shedding some admissions, fed by a mix of
/// in-process, Unix-socket, and TCP clients, still finishes every
/// admitted job with exactly its solo result. Transport, tenancy, and
/// refused neighbours are all invisible to trajectories.
#[test]
fn service_under_weighted_fair_quotas_and_mixed_transports_is_bit_exact() {
    use cupso::config::{BatchConfig, JobConfig};
    use cupso::service::proto::{Json, Request};
    use cupso::service::{bind, bind_tcp, spawn_server_on, Listener, ServiceSession};
    use std::io::{BufRead, BufReader, Write};

    fn roundtrip_on<S: std::io::Read + Write>(mut stream: S, line: &str) -> Json {
        writeln!(stream, "{line}").unwrap();
        stream.flush().unwrap();
        let mut reader = BufReader::new(stream);
        let mut reply = String::new();
        reader.read_line(&mut reply).unwrap();
        Json::parse(reply.trim()).unwrap_or_else(|e| panic!("bad response {reply:?}: {e}"))
    }
    fn is_ok(doc: &Json) -> bool {
        doc.get("ok").map(|v| v == &Json::Bool(true)).unwrap_or(false)
    }
    fn wire_job(name: &str, engine: &str, particles: usize, iters: u64, seed: u64, tenant: &str) -> JobConfig {
        let mut job = JobConfig::with_defaults(name);
        job.engine = EngineKind::parse(engine).unwrap();
        job.particles = particles;
        job.iters = iters;
        job.seed = seed;
        job.tenant = Some(tenant.to_string());
        job
    }

    let dir = std::env::temp_dir().join("cupso-determinism-service");
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    let socket = dir.join("svc.sock");

    // Specs admitted in-process; wire jobs are built as JobConfig so the
    // solo reference goes through the very same from_config path.
    let a1 = || cubic_spec("a1", EngineKind::Queue, PsoParams::paper_1d(200, 2_000), 31);
    let anon = || cubic_spec("anon", EngineKind::LoopUnrolling, PsoParams::paper_1d(64, 1_200), 34);
    let a2 = wire_job("a2", "reduction", 96, 1_500, 32, "acme");
    let b1 = wire_job("b1", "queue", 128, 1_800, 33, "bloor");

    let knobs = BatchConfig {
        workers: 2,
        policy: "weighted-fair".into(),
        streams: 2,
        batch_steps: 1,
        preempt_quantum: 0,
        pack: false,
        pack_min: 2,
        pack_max: 0,
        quota_jobs: 2,
        quota_steps: 0,
        checkpoint_every: 0,
        checkpoint_keep: 1,
        telemetry: true,
        trace_dump: None,
        jobs: Vec::new(),
    };
    let scheduler = JobScheduler::with_streams(2, 2)
        .policy(SchedPolicy::WeightedFair)
        .batch_steps(1);
    let (service, handle) = ServiceSession::new(&scheduler, knobs, None, Vec::new()).unwrap();
    let tcp = bind_tcp("127.0.0.1:0").unwrap();
    let addr = tcp.local_addr().unwrap();
    let listeners = vec![Listener::Unix(bind(&socket).unwrap()), Listener::Tcp(tcp)];
    let _accept = spawn_server_on(listeners, handle.clone(), 64);
    let svc = std::thread::spawn(move || service.run().unwrap());

    // Mixed admission paths: in-process, Unix, TCP — with tenants.
    let mut spec_a1 = a1();
    spec_a1.tenant = Some(Arc::from("acme"));
    handle.submit(spec_a1).unwrap();
    let doc = roundtrip_on(
        std::os::unix::net::UnixStream::connect(&socket).unwrap(),
        &Request::Submit(a2.clone()).render(),
    );
    assert!(is_ok(&doc), "{doc:?}");
    // A third concurrent acme job is shed at admission, loudly...
    let doc = roundtrip_on(
        std::net::TcpStream::connect(addr).unwrap(),
        &Request::Submit(wire_job("a3", "queue", 64, 500, 35, "acme")).render(),
    );
    assert!(!is_ok(&doc), "{doc:?}");
    assert!(doc.str_field("error").unwrap().contains("concurrent-job quota"), "{doc:?}");
    // ...while other tenants and anonymous jobs sail through.
    let doc = roundtrip_on(
        std::net::TcpStream::connect(addr).unwrap(),
        &Request::Submit(b1.clone()).render(),
    );
    assert!(is_ok(&doc), "{doc:?}");
    handle.submit(anon()).unwrap();

    // Run the admitted fleet dry, then stop the idle service (the
    // event loop holds its own handle, so shutdown goes over the wire).
    loop {
        let status = handle.status().unwrap();
        if status.live.is_empty() && status.finished.len() == 4 {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(5));
    }
    let doc = roundtrip_on(
        std::net::TcpStream::connect(addr).unwrap(),
        &Request::Drain.render(),
    );
    assert!(is_ok(&doc), "{doc:?}");
    drop(handle);
    let end = svc.join().unwrap();
    assert_eq!(end.finished_total, 4);

    // Every admitted job matches its solo run exactly — the shed a3 and
    // the transport mix left no trace on anyone's numerics.
    let solo_specs = vec![
        {
            let mut s = a1();
            s.tenant = Some(Arc::from("acme"));
            s
        },
        JobSpec::from_config(&a2).unwrap(),
        JobSpec::from_config(&b1).unwrap(),
        anon(),
    ];
    for spec in &solo_specs {
        let reference = engine::build(spec.engine, 2)
            .unwrap()
            .run(&spec.params, &Cubic, Objective::Maximize, spec.seed);
        let served = end
            .results
            .iter()
            .find(|r| r.name == &*spec.name)
            .unwrap_or_else(|| panic!("{} missing from results", spec.name));
        assert_eq!(served.stop, StopReason::Exhausted, "{}", spec.name);
        assert_eq!(served.steps, spec.params.max_iter, "{}", spec.name);
        assert_eq!(served.gbest_fit, reference.gbest_fit, "{}", spec.name);
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// ISSUE 4 determinism extension: the persistent-executor stepping path
/// (the default) and the legacy spawn-per-round path must be
/// indistinguishable — identical per-job outputs AND an identical
/// telemetry stream, across stream counts, batch sizes and preemption.
#[test]
fn executor_rounds_match_scoped_thread_rounds_bit_exactly() {
    let mk_specs = || -> Vec<JobSpec> {
        vec![
            cubic_spec("e1", EngineKind::Queue, PsoParams::paper_1d(300, 24), 1),
            cubic_spec("e2", EngineKind::Reduction, PsoParams::paper_1d(257, 30), 2),
            cubic_spec("e3", EngineKind::LoopUnrolling, PsoParams::paper_120d(64, 16), 3),
            cubic_spec("e4", EngineKind::SerialCpu, PsoParams::paper_1d(100, 20), 4),
            cubic_spec("e5", EngineKind::Queue, PsoParams::paper_120d(80, 12), 5),
        ]
    };
    for (streams, batch, quantum) in [(2usize, 1u64, 0u64), (3, 4, 0), (4, 1, 0), (2, 2, 3)] {
        let run_mode = |spawn: bool| {
            let mut trace = Vec::new();
            let outcomes = JobScheduler::with_streams(4, streams)
                .batch_steps(batch)
                .preempt_quantum(quantum)
                .spawn_per_round(spawn)
                .run_with(&mk_specs(), |r| {
                    trace.push((r.job, r.iter, r.gbest_fit, r.improved))
                })
                .unwrap();
            (outcomes, trace)
        };
        let (exec_outcomes, exec_trace) = run_mode(false);
        let (spawn_outcomes, spawn_trace) = run_mode(true);
        assert_eq!(
            exec_trace, spawn_trace,
            "telemetry diverged at S={streams} batch={batch} q={quantum}"
        );
        for (a, b) in exec_outcomes.iter().zip(&spawn_outcomes) {
            assert_eq!(a.stop, b.stop, "{}", a.name);
            assert_eq!(a.steps, b.steps, "{}", a.name);
            assert_outputs_equal(
                &a.output,
                &b.output,
                &format!("executor-vs-spawn S={streams} batch={batch} q={quantum} {}", a.name),
            );
        }
    }
}

/// ISSUE 10 tentpole invariant: the flight recorder is provably
/// invisible. The same fleet run with telemetry recording on and off —
/// serialized (S=1), concurrent streams, and packed — produces
/// bit-identical per-job outputs AND an identical report stream, while
/// the instrumented run demonstrably recorded (the rounds counter
/// moved). Instrumentation wraps phases and reads clocks, but never
/// touches run state; this is the proof.
#[test]
fn runs_with_telemetry_on_and_off_are_bit_identical() {
    use cupso::telemetry;
    let mk_specs = || -> Vec<JobSpec> {
        vec![
            cubic_spec("m1", EngineKind::Queue, PsoParams::paper_1d(300, 24), 1),
            cubic_spec("m2", EngineKind::Reduction, PsoParams::paper_1d(257, 18), 2),
            cubic_spec("m3", EngineKind::LoopUnrolling, PsoParams::paper_120d(64, 12), 3),
            cubic_spec("m4", EngineKind::Queue, PsoParams::paper_1d(150, 20), 4),
        ]
    };
    let was = telemetry::enabled();
    for (streams, pack) in [(1usize, false), (2, false), (2, true)] {
        let run_fleet = |record: bool| {
            telemetry::set_enabled(record);
            let mut trace = Vec::new();
            let outcomes = JobScheduler::with_streams(4, streams)
                .pack(pack)
                .run_with(&mk_specs(), |r| {
                    trace.push((r.job, r.iter, r.gbest_fit, r.improved))
                })
                .unwrap();
            telemetry::set_enabled(was);
            (outcomes, trace)
        };
        let rounds_before = telemetry::counter(telemetry::Counter::Rounds);
        let (on_outcomes, on_trace) = run_fleet(true);
        assert!(
            telemetry::counter(telemetry::Counter::Rounds) > rounds_before,
            "instrumented run recorded nothing (S={streams} pack={pack})"
        );
        let (off_outcomes, off_trace) = run_fleet(false);
        assert_eq!(
            on_trace, off_trace,
            "report stream diverged across the telemetry switch (S={streams} pack={pack})"
        );
        assert_eq!(on_outcomes.len(), off_outcomes.len());
        for (a, b) in on_outcomes.iter().zip(&off_outcomes) {
            assert_eq!(a.stop, b.stop, "{}", a.name);
            assert_eq!(a.steps, b.steps, "{}", a.name);
            assert_outputs_equal(
                &a.output,
                &b.output,
                &format!("telemetry on-vs-off S={streams} pack={pack} {}", a.name),
            );
        }
    }
    telemetry::set_enabled(was);
}

#[test]
fn concurrent_telemetry_is_deterministic() {
    // The same concurrent configuration run twice must produce the exact
    // same report stream (rounds joined, reports in job-index order).
    let mk_specs = || -> Vec<JobSpec> {
        (0..5)
            .map(|j| {
                cubic_spec(
                    &format!("t{j}"),
                    EngineKind::Queue,
                    PsoParams::paper_1d(100 + j * 50, 12),
                    j as u64,
                )
            })
            .collect()
    };
    let trace = |policy: SchedPolicy| -> Vec<(usize, u64, f64)> {
        let mut t = Vec::new();
        JobScheduler::with_streams(2, 3)
            .policy(policy)
            .batch_steps(2)
            .run_with(&mk_specs(), |r| t.push((r.job, r.iter, r.gbest_fit)))
            .unwrap();
        t
    };
    for policy in [SchedPolicy::RoundRobin, SchedPolicy::EarliestDeadlineFirst] {
        assert_eq!(trace(policy), trace(policy), "{policy}");
    }
}

#[test]
fn target_fitness_stops_early() {
    // 1-D Cubic reaches the optimum region fast; a target well below the
    // optimum must stop the job long before its 5000-iteration budget.
    let mut spec = cubic_spec(
        "target",
        EngineKind::QueueLock,
        PsoParams::paper_1d(1024, 5000),
        1,
    );
    spec.termination = TerminationCriteria::none().with_target_fit(890_000.0);
    let outcomes = JobScheduler::with_workers(4).run(&[spec]).unwrap();
    let o = &outcomes[0];
    assert_eq!(o.stop, StopReason::TargetReached);
    assert!(o.steps < 5000, "did not stop early ({} steps)", o.steps);
    assert!(o.output.gbest_fit >= 890_000.0);
    assert_eq!(o.output.iters, o.steps);
}

#[test]
fn stall_window_stops_converged_jobs() {
    // 1-D Cubic clamps to the boundary optimum within a few iterations;
    // after that nothing improves, so a stall window of 20 must fire well
    // before the 10000-iteration budget.
    let mut spec = cubic_spec(
        "stall",
        EngineKind::Queue,
        PsoParams::paper_1d(512, 10_000),
        3,
    );
    spec.termination = TerminationCriteria::none().with_stall_window(20);
    let outcomes = JobScheduler::with_workers(4).run(&[spec]).unwrap();
    let o = &outcomes[0];
    assert_eq!(o.stop, StopReason::Stalled);
    assert!(o.steps < 10_000, "stall never fired ({} steps)", o.steps);
}

#[test]
fn max_iter_criterion_caps_steps() {
    let mut spec = cubic_spec(
        "capped",
        EngineKind::Reduction,
        PsoParams::paper_120d(64, 1000),
        5,
    );
    spec.termination = TerminationCriteria::none().with_max_iter(37);
    let outcomes = JobScheduler::with_workers(2).run(&[spec]).unwrap();
    let o = &outcomes[0];
    assert_eq!(o.stop, StopReason::MaxIter);
    assert_eq!(o.steps, 37);
    assert_eq!(o.output.iters, 37);
    // A capped job's output equals the solo run paused at the same step.
    let mut e = engine::build(EngineKind::Reduction, 2).unwrap();
    let params = PsoParams::paper_120d(64, 1000);
    let mut run = e.prepare(&params, &Cubic, Objective::Maximize, 5);
    for _ in 0..37 {
        run.step();
    }
    let paused = run.finish();
    assert_outputs_equal(&o.output, &paused, "capped-vs-paused");
}

#[test]
fn jobs_without_criteria_run_to_exhaustion() {
    let spec = cubic_spec("full", EngineKind::Queue, PsoParams::paper_1d(128, 60), 9);
    let outcomes = JobScheduler::with_workers(2).run(&[spec]).unwrap();
    assert_eq!(outcomes[0].stop, StopReason::Exhausted);
    assert_eq!(outcomes[0].steps, 60);
}

#[test]
fn telemetry_stream_reports_every_step_and_final_state() {
    let specs = vec![
        cubic_spec("t1", EngineKind::Queue, PsoParams::paper_1d(64, 12), 1),
        cubic_spec("t2", EngineKind::Reduction, PsoParams::paper_1d(64, 8), 2),
    ];
    let scheduler = JobScheduler::with_workers(2);
    let mut per_job_steps = [0u64; 2];
    let mut finishes = Vec::new();
    let outcomes = scheduler
        .run_with(&specs, |r| {
            per_job_steps[r.job] += 1;
            assert_eq!(r.iter, per_job_steps[r.job]);
            if let Some(reason) = r.finished {
                finishes.push((r.job, reason));
            }
        })
        .unwrap();
    assert_eq!(per_job_steps, [12, 8]);
    assert_eq!(finishes.len(), 2);
    // Shared-pool smoke check: both outcomes solved the small workload.
    for o in &outcomes {
        assert!(o.output.gbest_fit > 800_000.0, "{}: {}", o.name, o.output.gbest_fit);
    }
}

#[test]
fn queue_lock_jobs_schedule_without_cross_talk() {
    // Queue-Lock is not bit-exact run-to-run (documented intra-run race),
    // but scheduled jobs must still be monotone and land in the quality
    // band, and interleaving must not corrupt neighbours.
    let specs = vec![
        cubic_spec("ql1", EngineKind::QueueLock, PsoParams::paper_1d(512, 80), 1),
        cubic_spec("q-ref", EngineKind::Queue, PsoParams::paper_1d(512, 80), 1),
    ];
    let outcomes = JobScheduler::with_workers(4).run(&specs).unwrap();
    for o in &outcomes {
        for w in o.output.history.windows(2) {
            assert!(w[1].1 >= w[0].1, "{}: gbest worsened", o.name);
        }
        assert!(o.output.gbest_fit > 890_000.0, "{}", o.name);
    }
    // The bit-exact neighbour still equals its solo run.
    let solo = engine::build(EngineKind::Queue, 4).unwrap().run(
        &PsoParams::paper_1d(512, 80),
        &Cubic,
        Objective::Maximize,
        1,
    );
    assert_outputs_equal(&outcomes[1].output, &solo, "queue neighbour of queue-lock");
}

/// ISSUE 5 determinism extension: **round-boundary admission and
/// cancellation are invisible to neighbours.** A session that admits
/// jobs mid-run, cancels one, and recycles its slot must leave every
/// bit-exact job's trajectory identical to its solo one-shot run — the
/// service layer's core correctness claim.
#[test]
fn late_admission_and_cancellation_are_invisible_to_neighbors() {
    let solo = |engine: EngineKind, params: &PsoParams, seed: u64| {
        engine::build(engine, 4)
            .unwrap()
            .run(params, &Cubic, Objective::Maximize, seed)
    };
    for streams in [1usize, 2, 3] {
        let scheduler = JobScheduler::with_streams(4, streams);
        let mut session = scheduler.session();
        session
            .admit(cubic_spec("keeper", EngineKind::Queue, PsoParams::paper_1d(300, 40), 1))
            .unwrap();
        session
            .admit(cubic_spec("victim", EngineKind::Reduction, PsoParams::paper_1d(200, 60), 2))
            .unwrap();
        for _ in 0..6 {
            session.round(&mut |_| {}).unwrap();
        }
        // Late admission while neighbours are mid-trajectory.
        session
            .admit(cubic_spec("late", EngineKind::LoopUnrolling, PsoParams::paper_1d(257, 30), 3))
            .unwrap();
        for _ in 0..4 {
            session.round(&mut |_| {}).unwrap();
        }
        // Cancellation at a round boundary; the freed slot is recycled
        // by the next admission.
        let cancelled = session.cancel("victim").unwrap();
        assert_eq!(cancelled.stop, StopReason::Cancelled);
        assert!(cancelled.steps > 0 && cancelled.steps < 60);
        session
            .admit(cubic_spec("recycled", EngineKind::Queue, PsoParams::paper_120d(64, 12), 4))
            .unwrap();
        while session.live() > 0 {
            session.round(&mut |_| {}).unwrap();
        }
        let mut outcomes = Vec::new();
        session.reap(|o| outcomes.push(o)).unwrap();
        assert_eq!(outcomes.len(), 3, "S={streams}");
        for o in &outcomes {
            let (engine, params, seed) = match &*o.name {
                "keeper" => (EngineKind::Queue, PsoParams::paper_1d(300, 40), 1),
                "late" => (EngineKind::LoopUnrolling, PsoParams::paper_1d(257, 30), 3),
                "recycled" => (EngineKind::Queue, PsoParams::paper_120d(64, 12), 4),
                other => panic!("unexpected job {other}"),
            };
            let reference = solo(engine, &params, seed);
            assert_eq!(o.stop, StopReason::Exhausted, "S={streams} {}", o.name);
            assert_outputs_equal(
                &o.output,
                &reference,
                &format!("S={streams} {} vs solo", o.name),
            );
        }
        // The cancelled job's partial output equals its solo run paused
        // at the same step — cancellation truncates, never perturbs.
        let mut e = engine::build(EngineKind::Reduction, 4).unwrap();
        let params = PsoParams::paper_1d(200, 60);
        let mut run = e.prepare(&params, &Cubic, Objective::Maximize, 2);
        for _ in 0..cancelled.steps {
            run.step();
        }
        let paused = run.finish();
        assert_outputs_equal(
            &cancelled.output,
            &paused,
            &format!("S={streams} cancelled prefix"),
        );
    }
}

/// A live session drained mid-run (some jobs admitted late) resumes from
/// its snapshot alone — and the completed results are bit-identical to
/// the same jobs run in one uninterrupted batch.
#[test]
fn drained_session_snapshot_resumes_to_uninterrupted_results() {
    let mk_a = || cubic_spec("a", EngineKind::Queue, PsoParams::paper_1d(300, 35), 7);
    let mk_b = || cubic_spec("b", EngineKind::Reduction, PsoParams::paper_120d(64, 25), 8);
    let scheduler = JobScheduler::with_streams(4, 2);
    // Reference: both jobs, one uninterrupted batch. (Admission timing
    // cannot matter for bit-exact engines, so this is the oracle even
    // though `b` is admitted late below.)
    let reference = scheduler.run(&[mk_a(), mk_b()]).unwrap();

    let mut session = scheduler.session();
    session.admit(mk_a()).unwrap();
    for _ in 0..5 {
        session.round(&mut |_| {}).unwrap();
    }
    session.admit(mk_b()).unwrap();
    for _ in 0..4 {
        session.round(&mut |_| {}).unwrap();
    }
    // Drain: snapshot every live job, then throw the session away.
    let snap = session.snapshot();
    drop(session);
    assert_eq!(snap.len(), 2);
    assert!(snap.iter().all(|j| j.stop.is_none()));

    // Resume purely from the snapshot (specs rebuilt from checkpoints,
    // exactly like `cupso resume` after a service drain).
    let specs = snap
        .iter()
        .map(JobSpec::from_checkpoint)
        .collect::<anyhow::Result<Vec<_>>>()
        .unwrap();
    let resumed = match scheduler.run_session(&specs, Some(&snap), None, |_| {}).unwrap() {
        BatchRun::Complete(outcomes) => outcomes,
        BatchRun::Suspended(_) => panic!("uncapped resume must complete"),
    };
    for (r, reference) in resumed.iter().zip(&reference) {
        assert_eq!(r.steps, reference.steps, "{}", r.name);
        assert_eq!(r.stop, reference.stop, "{}", r.name);
        assert_outputs_equal(&r.output, &reference.output, &r.name);
    }
}

/// ISSUE 6 headline invariant: a fleet stepped as swarm packs (one
/// shared slab, one grid-stride launch pair per round) is bit-exact
/// with the same fleet stepped standalone — outcomes, RunOutput,
/// counters AND the per-job telemetry stream.
#[test]
fn packed_fleet_matches_unpacked_fleet_bit_exactly() {
    // Ten Queue jobs: eight share dim 1 (one pack), two share dim 120
    // (a second pack); n, iteration budgets and seeds all differ.
    let mk_specs = || -> Vec<JobSpec> {
        let mut specs: Vec<JobSpec> = (0..8)
            .map(|j| {
                cubic_spec(
                    &format!("f{j}"),
                    EngineKind::Queue,
                    PsoParams::paper_1d(64 + 32 * j, 20 + 2 * j as u64),
                    j as u64 + 1,
                )
            })
            .collect();
        specs.push(cubic_spec("d1", EngineKind::Queue, PsoParams::paper_120d(40, 15), 21));
        specs.push(cubic_spec("d2", EngineKind::Queue, PsoParams::paper_120d(64, 18), 22));
        specs
    };
    let run_fleet = |scheduler: JobScheduler| {
        let mut traces: Vec<Vec<(u64, f64, bool)>> = vec![Vec::new(); 10];
        let outcomes = scheduler
            .run_with(&mk_specs(), |r| traces[r.job].push((r.iter, r.gbest_fit, r.improved)))
            .unwrap();
        (outcomes, traces)
    };
    let (packed, packed_traces) = run_fleet(JobScheduler::with_streams(4, 2).pack(true));
    let (plain, plain_traces) = run_fleet(JobScheduler::with_streams(4, 2));
    for (j, (a, b)) in packed.iter().zip(&plain).enumerate() {
        assert_eq!(a.stop, b.stop, "{}", a.name);
        assert_eq!(a.steps, b.steps, "{}", a.name);
        assert_outputs_equal(&a.output, &b.output, &format!("packed-vs-plain {}", a.name));
        let (ca, cb) = (&a.output.counters, &b.output.counters);
        assert_eq!(ca.particle_updates, cb.particle_updates, "{}", a.name);
        assert_eq!(ca.queue_pushes, cb.queue_pushes, "{}", a.name);
        assert_eq!(ca.gbest_updates, cb.gbest_updates, "{}", a.name);
        assert_eq!(ca.pbest_improvements, cb.pbest_improvements, "{}", a.name);
        // A packed job reports every round instead of when picked, but
        // its per-job report stream must be identical.
        assert_eq!(packed_traces[j], plain_traces[j], "telemetry for {}", a.name);
    }
    // And both equal the solo one-shot of every member.
    for (o, spec) in packed.iter().zip(&mk_specs()) {
        let solo = engine::build(spec.engine, 4).unwrap().run(
            &spec.params,
            &Cubic,
            Objective::Maximize,
            spec.seed,
        );
        assert_eq!(o.stop, StopReason::Exhausted, "{}", o.name);
        assert_outputs_equal(&o.output, &solo, &format!("packed {} vs solo", o.name));
    }
}

/// A compatibility group larger than `pack_max` splits; the leftover
/// chunk below `pack_min` stays standalone (the "admitted into a full
/// pack" path), and late admissions group among themselves — all of it
/// bit-exact.
#[test]
fn full_packs_leave_leftovers_standalone_and_bit_exact() {
    let mk = |j: usize| {
        cubic_spec(
            &format!("m{j}"),
            EngineKind::Queue,
            PsoParams::paper_1d(100 + 50 * j, 30),
            j as u64 + 1,
        )
    };
    let scheduler = JobScheduler::with_streams(4, 2).pack(true).pack_max(4);
    let mut session = scheduler.session();
    // Five compatible jobs against pack_max 4: one pack of four plus one
    // standalone leftover.
    for j in 0..5 {
        session.admit(mk(j)).unwrap();
    }
    for _ in 0..5 {
        session.round(&mut |_| {}).unwrap();
    }
    // The existing pack is full and never grows; the two late arrivals
    // group with the still-live leftover into a fresh pack.
    for j in 5..7 {
        session.admit(mk(j)).unwrap();
    }
    while session.live() > 0 {
        session.round(&mut |_| {}).unwrap();
    }
    let mut outcomes = Vec::new();
    session.reap(|o| outcomes.push(o)).unwrap();
    assert_eq!(outcomes.len(), 7);
    for o in &outcomes {
        let j: usize = o.name[1..].parse().unwrap();
        let solo = engine::build(EngineKind::Queue, 4).unwrap().run(
            &PsoParams::paper_1d(100 + 50 * j, 30),
            &Cubic,
            Objective::Maximize,
            j as u64 + 1,
        );
        assert_eq!(o.stop, StopReason::Exhausted, "{}", o.name);
        assert_eq!(o.steps, 30, "{}", o.name);
        assert_outputs_equal(&o.output, &solo, &format!("{} vs solo", o.name));
    }
}

/// Cancelling a packed member extracts its slice mid-flight: the
/// cancelled output equals its solo run paused at the same step, and
/// the surviving packmates finish bit-identical to their solo runs.
#[test]
fn cancel_mid_pack_truncates_without_perturbing_packmates() {
    let mk = |j: usize| {
        cubic_spec(
            &format!("c{j}"),
            EngineKind::Queue,
            PsoParams::paper_1d(100 + 50 * j, 40),
            j as u64 + 1,
        )
    };
    let scheduler = JobScheduler::with_streams(4, 1).pack(true);
    let mut session = scheduler.session();
    for j in 0..4 {
        session.admit(mk(j)).unwrap();
    }
    for _ in 0..6 {
        session.round(&mut |_| {}).unwrap();
    }
    // Packed members step every round, so six rounds = six steps.
    let cancelled = session.cancel("c1").unwrap();
    assert_eq!(cancelled.stop, StopReason::Cancelled);
    assert_eq!(cancelled.steps, 6);
    let mut e = engine::build(EngineKind::Queue, 4).unwrap();
    let params = PsoParams::paper_1d(150, 40);
    let mut run = e.prepare(&params, &Cubic, Objective::Maximize, 2);
    for _ in 0..6 {
        run.step();
    }
    let paused = run.finish();
    assert_outputs_equal(&cancelled.output, &paused, "cancelled packed prefix");
    // The three survivors (pack still ≥ pack_min) run to completion.
    while session.live() > 0 {
        session.round(&mut |_| {}).unwrap();
    }
    let mut outcomes = Vec::new();
    session.reap(|o| outcomes.push(o)).unwrap();
    assert_eq!(outcomes.len(), 3);
    for o in &outcomes {
        let j: usize = o.name[1..].parse().unwrap();
        let solo = engine::build(EngineKind::Queue, 4).unwrap().run(
            &PsoParams::paper_1d(100 + 50 * j, 40),
            &Cubic,
            Objective::Maximize,
            j as u64 + 1,
        );
        assert_outputs_equal(&o.output, &solo, &format!("packmate {} after cancel", o.name));
    }
}

/// Preemption pressure (more live jobs than streams, quantum set)
/// extracts packed members onto the standalone time-shared pool — the
/// trajectory must not notice the migration.
#[test]
fn preempted_packed_jobs_continue_standalone_bit_exactly() {
    let specs: Vec<JobSpec> = (0..3)
        .map(|j| {
            cubic_spec(
                &format!("pq{j}"),
                EngineKind::Queue,
                PsoParams::paper_1d(100 + 64 * j, 25),
                j as u64 + 1,
            )
        })
        .collect();
    let outcomes = JobScheduler::with_streams(4, 1)
        .pack(true)
        .preempt_quantum(2)
        .run(&specs)
        .unwrap();
    for (o, spec) in outcomes.iter().zip(&specs) {
        let solo = engine::build(spec.engine, 4).unwrap().run(
            &spec.params,
            &Cubic,
            Objective::Maximize,
            spec.seed,
        );
        assert_eq!(o.stop, StopReason::Exhausted, "{}", o.name);
        assert_eq!(o.steps, 25, "{}", o.name);
        assert_outputs_equal(&o.output, &solo, &format!("preempted-from-pack {}", o.name));
    }
}

/// Checkpoints cross the pack boundary in both directions: a snapshot
/// taken from a session with live packs resumes on a pack-disabled
/// scheduler, and a standalone snapshot resumes on a pack-enabled one —
/// both landing bit-identical to the uninterrupted fleet.
#[test]
fn checkpoints_cross_packed_and_unpacked_sessions_bit_exactly() {
    let fleet = 8usize;
    let mk_specs = || -> Vec<JobSpec> {
        (0..fleet)
            .map(|j| {
                cubic_spec(
                    &format!("x{j}"),
                    EngineKind::Queue,
                    PsoParams::paper_1d(64 + 32 * j, 30 + j as u64),
                    j as u64 + 1,
                )
            })
            .collect()
    };
    let plain_sched = JobScheduler::with_streams(4, 2);
    let packed_sched = JobScheduler::with_streams(4, 2).pack(true);
    let reference = plain_sched.run(&mk_specs()).unwrap();
    let part_run = |sched: &JobScheduler| {
        let mut session = sched.session();
        for s in mk_specs() {
            session.admit(s).unwrap();
        }
        for _ in 0..7 {
            session.round(&mut |_| {}).unwrap();
        }
        session.snapshot()
    };
    let pairs = [
        (&packed_sched, &plain_sched, "packed->plain"),
        (&plain_sched, &packed_sched, "plain->packed"),
    ];
    for (snap_from, resume_with, what) in pairs {
        let snap = part_run(snap_from);
        assert_eq!(snap.len(), fleet, "{what}");
        let specs = snap
            .iter()
            .map(JobSpec::from_checkpoint)
            .collect::<anyhow::Result<Vec<_>>>()
            .unwrap();
        let resumed = match resume_with.run_session(&specs, Some(&snap), None, |_| {}).unwrap() {
            BatchRun::Complete(outcomes) => outcomes,
            BatchRun::Suspended(_) => panic!("uncapped resume must complete"),
        };
        for (r, reference) in resumed.iter().zip(&reference) {
            assert_eq!(r.steps, reference.steps, "{what} {}", r.name);
            assert_eq!(r.stop, reference.stop, "{what} {}", r.name);
            assert_outputs_equal(&r.output, &reference.output, &format!("{what} {}", r.name));
        }
    }
}

#[test]
fn shared_pool_is_actually_shared() {
    // All jobs run over the scheduler's single pool: build with an
    // explicit ParallelSettings and verify the pool is reused (the
    // scheduler exposes it, and engines built on it share the Arc).
    let settings = ParallelSettings::with_workers(2);
    let scheduler = JobScheduler::new(settings.clone());
    assert!(Arc::ptr_eq(scheduler.pool(), &settings.pool));
    let specs = vec![
        cubic_spec("p1", EngineKind::Queue, PsoParams::paper_1d(64, 5), 1),
        cubic_spec("p2", EngineKind::Reduction, PsoParams::paper_1d(64, 5), 2),
    ];
    // Two jobs, one pool: just exercising the path proves no panic /
    // deadlock; the determinism tests above prove isolation.
    assert_eq!(scheduler.run(&specs).unwrap().len(), 2);
}
