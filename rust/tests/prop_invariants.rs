//! Property tests over the core invariants, across random workloads:
//!
//! * **bound invariant** — positions/velocities stay clamped for every
//!   engine on every workload;
//! * **monotone-gbest invariant** — the history never worsens;
//! * **gbest-dominates invariant** — the final gbest is ≥ every particle's
//!   pbest (maximize sense);
//! * **substrate stress** — GridPool under irregular grids and nested
//!   state, SharedQueue under concurrent churn;
//! * **checkpoint codec** — encode→decode round-trips every f64 bit
//!   pattern exactly (NaN payloads, ±0, ±∞), including empty/degenerate
//!   swarms, and corrupted/truncated/version-bumped inputs fail loudly,
//!   never panic;
//! * **snapshot torn-file invariant** — a job checkpoint or manifest
//!   truncated at *every* byte offset is a loud error (or a loud
//!   quarantine), never a panic and never a silent subset-resume.

use cupso::checkpoint::store::{load_snapshot, read_snapshot, write_snapshot};
use cupso::checkpoint::{JobCheckpoint, RunCheckpoint, RunKind};
use cupso::config::{BatchConfig, EngineKind};
use cupso::engine::{Engine, ParallelSettings};
use cupso::exec::{GridPool, SharedQueue};
use cupso::fitness::{Cubic, Objective};
use cupso::pso::{Counters, PsoParams, SwarmState};
use cupso::rng::{PhiloxStream, RngEngine, Xoshiro256pp};
use cupso::testsupport::{gen_usize, prop_check};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

#[test]
fn engines_respect_bounds_and_monotonicity() {
    prop_check(
        0xBEEF,
        10,
        |rng| {
            let n = gen_usize(rng, 3, 700);
            let dim = [1usize, 2, 5, 40][gen_usize(rng, 0, 3)];
            let iters = gen_usize(rng, 2, 40) as u64;
            let engine_idx = gen_usize(rng, 0, 4);
            let seed = rng.next_u64();
            (n, dim, iters, engine_idx, seed)
        },
        |&(n, dim, iters, e, seed)| {
            let mut shrunk = Vec::new();
            if n > 3 {
                shrunk.push((n / 2, dim, iters, e, seed));
            }
            if iters > 2 {
                shrunk.push((n, dim, iters / 2, e, seed));
            }
            shrunk
        },
        |&(n, dim, iters, engine_idx, seed)| {
            let kind = EngineKind::TABLE3[engine_idx];
            let params = PsoParams {
                dim,
                ..PsoParams::paper_1d(n, iters)
            };
            let mut engine = cupso::engine::build(kind, 2).unwrap();
            let out = engine.run(&params, &Cubic, Objective::Maximize, seed);
            // Monotone history.
            for w in out.history.windows(2) {
                if w[1].1 < w[0].1 {
                    return Err(format!("{kind:?}: gbest worsened {w:?}"));
                }
            }
            // Bounds on the final best position.
            for &p in &out.gbest_pos {
                if !(params.min_pos..=params.max_pos).contains(&p) {
                    return Err(format!("{kind:?}: gbest pos {p} out of bounds"));
                }
            }
            // gbest must at least match the best initial particle.
            let stream = PhiloxStream::new(seed);
            let mut init = SwarmState::init(&params, &stream);
            let (init_best, _) = init.seed_fitness(&Cubic, Objective::Maximize);
            if out.gbest_fit < init_best {
                return Err(format!(
                    "{kind:?}: final gbest {} below initial best {init_best}",
                    out.gbest_fit
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn grid_pool_covers_irregular_grids() {
    let pool = GridPool::new(3);
    prop_check(
        0xFACE,
        40,
        |rng| gen_usize(rng, 1, 300),
        |&b| if b > 1 { vec![b / 2] } else { vec![] },
        |&blocks| {
            let hits: Vec<AtomicUsize> = (0..blocks).map(|_| AtomicUsize::new(0)).collect();
            pool.launch(blocks, |ctx| {
                hits[ctx.block_id].fetch_add(1, Ordering::Relaxed);
            });
            for (i, h) in hits.iter().enumerate() {
                let v = h.load(Ordering::Relaxed);
                if v != 1 {
                    return Err(format!("block {i} ran {v} times (blocks={blocks})"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn grid_pool_rapid_relaunch_has_no_lost_or_stale_work() {
    // Stress the generation-handoff protocol: thousands of tiny launches
    // back to back, verifying the sum of all work (a stale-descriptor bug
    // would double-count or segfault).
    let pool = GridPool::new(4);
    let total = AtomicUsize::new(0);
    for round in 0..3000 {
        let blocks = (round % 7) + 1;
        pool.launch(blocks, |ctx| {
            total.fetch_add(ctx.block_id + 1, Ordering::Relaxed);
        });
    }
    let expect: usize = (0..3000).map(|r| ((r % 7) + 1) * ((r % 7) + 2) / 2).sum();
    assert_eq!(total.load(Ordering::Relaxed), expect);
}

#[test]
fn shared_queue_concurrent_reset_push_cycles() {
    // The per-iteration pattern: reset → concurrent pushes → scan.
    let pool = GridPool::new(4);
    let q: SharedQueue<(f64, u32)> = SharedQueue::new(1024);
    for iter in 0..200 {
        q.reset();
        pool.launch(8, |ctx| {
            for k in 0..16u32 {
                q.push((iter as f64, (ctx.block_id as u32) * 100 + k));
            }
        });
        assert_eq!(q.len(), 128, "iteration {iter}");
        let mut count = 0;
        q.scan(|&(f, _)| {
            assert_eq!(f, iter as f64, "stale entry survived reset");
            count += 1;
        });
        assert_eq!(count, 128);
    }
}

#[test]
fn engines_survive_degenerate_workloads() {
    // n=1 (single particle, single block), n=block_size boundary, dim=1
    // iters=1 — the smallest legal configurations must not panic and must
    // return a sane result.
    for kind in EngineKind::TABLE3 {
        for (n, iters) in [(1usize, 1u64), (1, 10), (256, 1), (257, 1)] {
            let params = PsoParams::paper_1d(n, iters);
            let mut engine = cupso::engine::build(kind, 2).unwrap();
            let out = engine.run(&params, &Cubic, Objective::Maximize, 5);
            assert!(
                out.gbest_fit.is_finite(),
                "{kind:?} n={n} iters={iters}: non-finite gbest"
            );
            assert_eq!(out.gbest_pos.len(), 1);
        }
    }
}

/// Arbitrary f64 bit patterns (quiet/signaling NaNs, ±0, ±∞, subnormals
/// — whatever the RNG produces).
fn rand_bits_vec(rng: &mut dyn RngEngine, len: usize) -> Vec<f64> {
    (0..len).map(|_| f64::from_bits(rng.next_u64())).collect()
}

/// A structurally-consistent checkpoint whose every f64 is an arbitrary
/// bit pattern. Exercises the codec, not the engines.
fn random_checkpoint(rng: &mut dyn RngEngine, n: usize, dim: usize) -> RunCheckpoint {
    let kind = RunKind::from_code((rng.next_u64() % 7) as u8).unwrap();
    let objective = if rng.next_u64() % 2 == 0 {
        Objective::Maximize
    } else {
        Objective::Minimize
    };
    let iter = rng.next_u64() % 50;
    let rows = n * dim;
    let hist_len = gen_usize(rng, 0, 5) as u64;
    RunCheckpoint {
        version: cupso::checkpoint::VERSION,
        kind,
        objective,
        seed: rng.next_u64(),
        params: PsoParams {
            w: f64::from_bits(rng.next_u64()),
            c1: f64::from_bits(rng.next_u64()),
            c2: f64::from_bits(rng.next_u64()),
            min_pos: f64::from_bits(rng.next_u64()),
            max_pos: f64::from_bits(rng.next_u64()),
            max_v: f64::from_bits(rng.next_u64()),
            max_iter: iter + rng.next_u64() % 50,
            n,
            dim,
        },
        iter,
        gbest_fit: f64::from_bits(rng.next_u64()),
        gbest_pos: rand_bits_vec(rng, dim),
        history: (0..hist_len)
            .map(|i| (i, f64::from_bits(rng.next_u64())))
            .collect(),
        counters: Counters {
            pbest_improvements: rng.next_u64(),
            queue_pushes: rng.next_u64(),
            gbest_updates: rng.next_u64(),
            particle_updates: rng.next_u64(),
        },
        swarm: SwarmState {
            n,
            dim,
            pos: rand_bits_vec(rng, rows),
            vel: rand_bits_vec(rng, rows),
            fit: rand_bits_vec(rng, n),
            pbest_pos: rand_bits_vec(rng, rows),
            pbest_fit: rand_bits_vec(rng, n),
        },
    }
}

fn bits_equal(a: &[f64], b: &[f64]) -> bool {
    a.len() == b.len()
        && a.iter()
            .zip(b)
            .all(|(x, y)| x.to_bits() == y.to_bits())
}

#[test]
fn checkpoint_codec_roundtrips_every_bit_pattern() {
    prop_check(
        0xC0DE,
        60,
        |rng| {
            // Degenerate shapes on purpose: empty swarm (n = 0), single
            // particle, dim 1 — the codec must carry them all.
            let n = [0usize, 1, 2, 7, 64][gen_usize(rng, 0, 4)];
            let dim = [1usize, 2, 17][gen_usize(rng, 0, 2)];
            (n, dim, rng.next_u64())
        },
        |_| vec![],
        |&(n, dim, seed)| {
            let mut rng = Xoshiro256pp::seeded(seed);
            let ckpt = random_checkpoint(&mut rng, n, dim);
            let bytes = ckpt.encode();
            let back = RunCheckpoint::decode(&bytes)
                .map_err(|e| format!("decode of own encoding failed: {e}"))?;
            if back.kind != ckpt.kind
                || back.objective != ckpt.objective
                || back.seed != ckpt.seed
                || back.iter != ckpt.iter
                || back.params.max_iter != ckpt.params.max_iter
                || back.params.n != n
                || back.params.dim != dim
            {
                return Err("scalar fields drifted through the codec".into());
            }
            if back.gbest_fit.to_bits() != ckpt.gbest_fit.to_bits()
                || !bits_equal(&back.gbest_pos, &ckpt.gbest_pos)
                || !bits_equal(&back.swarm.pos, &ckpt.swarm.pos)
                || !bits_equal(&back.swarm.vel, &ckpt.swarm.vel)
                || !bits_equal(&back.swarm.fit, &ckpt.swarm.fit)
                || !bits_equal(&back.swarm.pbest_pos, &ckpt.swarm.pbest_pos)
                || !bits_equal(&back.swarm.pbest_fit, &ckpt.swarm.pbest_fit)
            {
                return Err("f64 bit patterns drifted through the codec".into());
            }
            if back.history.len() != ckpt.history.len()
                || back
                    .history
                    .iter()
                    .zip(&ckpt.history)
                    .any(|(a, b)| a.0 != b.0 || a.1.to_bits() != b.1.to_bits())
            {
                return Err("history drifted through the codec".into());
            }
            if back.counters.queue_pushes != ckpt.counters.queue_pushes
                || back.counters.gbest_updates != ckpt.counters.gbest_updates
                || back.counters.pbest_improvements != ckpt.counters.pbest_improvements
                || back.counters.particle_updates != ckpt.counters.particle_updates
            {
                return Err("counters drifted through the codec".into());
            }
            Ok(())
        },
    );
}

#[test]
fn checkpoint_decoder_fails_loudly_never_panics() {
    prop_check(
        0xDEAD,
        40,
        |rng| rng.next_u64(),
        |_| vec![],
        |&seed| {
            let mut rng = Xoshiro256pp::seeded(seed);
            let n = gen_usize(&mut rng, 0, 8);
            let bytes = random_checkpoint(&mut rng, n, 2).encode();
            // Any single-byte flip breaks the checksum (or the header):
            // always Err, never panic, never a silently-wrong checkpoint.
            for _ in 0..16 {
                let at = gen_usize(&mut rng, 0, bytes.len() - 1);
                let mut bad = bytes.clone();
                bad[at] ^= 1 + (rng.next_u64() % 255) as u8;
                if bad != bytes && RunCheckpoint::decode(&bad).is_ok() {
                    return Err(format!("flipped byte {at} decoded successfully"));
                }
            }
            // Every truncation fails.
            for _ in 0..8 {
                let cut = gen_usize(&mut rng, 0, bytes.len() - 1);
                if RunCheckpoint::decode(&bytes[..cut]).is_ok() {
                    return Err(format!("truncation at {cut} decoded successfully"));
                }
            }
            // A future version is refused by today's decoder, loudly.
            let mut bumped = bytes.clone();
            bumped[8..12].copy_from_slice(&7u32.to_le_bytes());
            match RunCheckpoint::decode(&bumped) {
                Ok(_) => Err("version-7 header decoded".into()),
                Err(e) if e.to_string().contains("version") => Ok(()),
                Err(e) => Err(format!("version bump reported as {e} (want a version error)")),
            }
        },
    );
}

#[test]
fn custom_block_size_preserves_equivalence() {
    // Geometry must not leak into numerics: 64-, 256- and 1024-wide
    // blocks give identical results for the synchronized engines.
    use cupso::engine::QueueEngine;
    let params = PsoParams::paper_1d(500, 20);
    let mut reference = None;
    for bs in [64usize, 256, 1024] {
        let settings = ParallelSettings::with_workers(3).block_size(bs);
        let mut e = QueueEngine::new(settings);
        let out = e.run(&params, &Cubic, Objective::Maximize, 11);
        match &reference {
            None => reference = Some(out),
            Some(r) => {
                assert_eq!(out.gbest_fit, r.gbest_fit, "bs={bs}");
                assert_eq!(out.gbest_pos, r.gbest_pos, "bs={bs}");
                assert_eq!(out.history, r.history, "bs={bs}");
            }
        }
    }
}

// ------------------------------------------------------------------
// Snapshot store: torn files at every byte offset.
// ------------------------------------------------------------------

fn snapshot_knobs() -> BatchConfig {
    BatchConfig {
        workers: 2,
        policy: "round-robin".into(),
        streams: 1,
        batch_steps: 1,
        preempt_quantum: 0,
        pack: false,
        pack_min: 2,
        pack_max: 0,
        quota_jobs: 0,
        quota_steps: 0,
        checkpoint_every: 0,
        checkpoint_keep: 1,
        telemetry: true,
        trace_dump: None,
        jobs: Vec::new(),
    }
}

fn random_job_checkpoint(rng: &mut dyn RngEngine, name: &str) -> JobCheckpoint {
    JobCheckpoint {
        name: Arc::from(name),
        fitness: "cubic".into(),
        stalled: rng.next_u64() % 8,
        stop: None,
        target_fit: None,
        stall_window: None,
        max_steps: None,
        deadline: None,
        run: Arc::new(random_checkpoint(rng, 2, 1)),
    }
}

/// Write a two-job flat snapshot into a fresh temp dir and return it.
fn tiny_snapshot(tag: &str, seed: u64) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("cupso-prop-store-{tag}-{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    let mut rng = Xoshiro256pp::seeded(seed);
    let jobs = [
        random_job_checkpoint(&mut rng, "alpha"),
        random_job_checkpoint(&mut rng, "beta"),
    ];
    let mut buf = Vec::new();
    write_snapshot(&dir, &snapshot_knobs(), 1, "prop", &jobs, &mut buf).unwrap();
    dir
}

#[test]
fn snapshot_job_file_truncated_at_every_offset_is_loud_or_quarantined() {
    let dir = tiny_snapshot("job", 0x10B5);
    let path = dir.join("job_0.ckpt");
    let whole = std::fs::read(&path).unwrap();
    assert_eq!(read_snapshot(&dir).unwrap().2.len(), 2, "baseline intact");

    for cut in 0..whole.len() {
        std::fs::write(&path, &whole[..cut]).unwrap();
        // Strict read: the torn job fails the whole snapshot, loudly.
        let err = read_snapshot(&dir)
            .err()
            .unwrap_or_else(|| panic!("job_0 cut to {cut} bytes read strictly"));
        assert!(
            format!("{err:#}").contains("job_0"),
            "cut {cut}: error names the file: {err:#}"
        );
        // Lenient load: never a panic, never a silent subset — the torn
        // job is accounted for in the quarantine report.
        let loaded = load_snapshot(&dir)
            .unwrap_or_else(|e| panic!("lenient load failed at cut {cut}: {e:#}"));
        assert_eq!(loaded.quarantined.len(), 1, "cut {cut}");
        assert_eq!(loaded.quarantined[0].index, 0, "cut {cut}");
        assert_eq!(loaded.jobs.len(), 1, "cut {cut}: the intact job survives");
        assert_eq!(&*loaded.jobs[0].name, "beta", "cut {cut}");
    }

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn snapshot_manifest_truncated_at_every_offset_never_silently_drops_jobs() {
    let dir = tiny_snapshot("manifest", 0x3A2F);
    let path = dir.join("manifest.toml");
    let whole = std::fs::read_to_string(&path).unwrap();
    assert_eq!(read_snapshot(&dir).unwrap().2.len(), 2, "baseline intact");

    for cut in 0..whole.len() {
        std::fs::write(&path, &whole.as_bytes()[..cut]).unwrap();
        // The manifest has no checksum; its trailing `complete = true`
        // commit marker is what makes truncation detectable. Any cut
        // must either fail loudly or (when only trailing whitespace is
        // lost) read back the complete, identical snapshot — a subset
        // would silently abandon jobs.
        match read_snapshot(&dir) {
            Err(_) => {}
            Ok((knobs, keep, jobs)) => {
                assert_eq!(jobs.len(), 2, "cut {cut}: manifest read a subset");
                assert_eq!(keep, 1, "cut {cut}");
                assert_eq!(knobs.streams, 1, "cut {cut}");
            }
        }
        match load_snapshot(&dir) {
            Err(_) => {}
            Ok(loaded) => {
                assert!(loaded.is_clean(), "cut {cut}");
                assert_eq!(loaded.jobs.len(), 2, "cut {cut}: lenient load lost jobs");
            }
        }
    }

    std::fs::remove_dir_all(&dir).ok();
}
