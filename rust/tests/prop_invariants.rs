//! Property tests over the core invariants, across random workloads:
//!
//! * **bound invariant** — positions/velocities stay clamped for every
//!   engine on every workload;
//! * **monotone-gbest invariant** — the history never worsens;
//! * **gbest-dominates invariant** — the final gbest is ≥ every particle's
//!   pbest (maximize sense);
//! * **substrate stress** — GridPool under irregular grids and nested
//!   state, SharedQueue under concurrent churn.

use cupso::config::EngineKind;
use cupso::engine::{Engine, ParallelSettings};
use cupso::exec::{GridPool, SharedQueue};
use cupso::fitness::{Cubic, Objective};
use cupso::pso::{PsoParams, SwarmState};
use cupso::rng::PhiloxStream;
use cupso::testsupport::{gen_usize, prop_check};
use std::sync::atomic::{AtomicUsize, Ordering};

#[test]
fn engines_respect_bounds_and_monotonicity() {
    prop_check(
        0xBEEF,
        10,
        |rng| {
            let n = gen_usize(rng, 3, 700);
            let dim = [1usize, 2, 5, 40][gen_usize(rng, 0, 3)];
            let iters = gen_usize(rng, 2, 40) as u64;
            let engine_idx = gen_usize(rng, 0, 4);
            let seed = rng.next_u64();
            (n, dim, iters, engine_idx, seed)
        },
        |&(n, dim, iters, e, seed)| {
            let mut shrunk = Vec::new();
            if n > 3 {
                shrunk.push((n / 2, dim, iters, e, seed));
            }
            if iters > 2 {
                shrunk.push((n, dim, iters / 2, e, seed));
            }
            shrunk
        },
        |&(n, dim, iters, engine_idx, seed)| {
            let kind = EngineKind::TABLE3[engine_idx];
            let params = PsoParams {
                dim,
                ..PsoParams::paper_1d(n, iters)
            };
            let mut engine = cupso::engine::build(kind, 2).unwrap();
            let out = engine.run(&params, &Cubic, Objective::Maximize, seed);
            // Monotone history.
            for w in out.history.windows(2) {
                if w[1].1 < w[0].1 {
                    return Err(format!("{kind:?}: gbest worsened {w:?}"));
                }
            }
            // Bounds on the final best position.
            for &p in &out.gbest_pos {
                if !(params.min_pos..=params.max_pos).contains(&p) {
                    return Err(format!("{kind:?}: gbest pos {p} out of bounds"));
                }
            }
            // gbest must at least match the best initial particle.
            let stream = PhiloxStream::new(seed);
            let mut init = SwarmState::init(&params, &stream);
            let (init_best, _) = init.seed_fitness(&Cubic, Objective::Maximize);
            if out.gbest_fit < init_best {
                return Err(format!(
                    "{kind:?}: final gbest {} below initial best {init_best}",
                    out.gbest_fit
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn grid_pool_covers_irregular_grids() {
    let pool = GridPool::new(3);
    prop_check(
        0xFACE,
        40,
        |rng| gen_usize(rng, 1, 300),
        |&b| if b > 1 { vec![b / 2] } else { vec![] },
        |&blocks| {
            let hits: Vec<AtomicUsize> = (0..blocks).map(|_| AtomicUsize::new(0)).collect();
            pool.launch(blocks, |ctx| {
                hits[ctx.block_id].fetch_add(1, Ordering::Relaxed);
            });
            for (i, h) in hits.iter().enumerate() {
                let v = h.load(Ordering::Relaxed);
                if v != 1 {
                    return Err(format!("block {i} ran {v} times (blocks={blocks})"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn grid_pool_rapid_relaunch_has_no_lost_or_stale_work() {
    // Stress the generation-handoff protocol: thousands of tiny launches
    // back to back, verifying the sum of all work (a stale-descriptor bug
    // would double-count or segfault).
    let pool = GridPool::new(4);
    let total = AtomicUsize::new(0);
    for round in 0..3000 {
        let blocks = (round % 7) + 1;
        pool.launch(blocks, |ctx| {
            total.fetch_add(ctx.block_id + 1, Ordering::Relaxed);
        });
    }
    let expect: usize = (0..3000).map(|r| ((r % 7) + 1) * ((r % 7) + 2) / 2).sum();
    assert_eq!(total.load(Ordering::Relaxed), expect);
}

#[test]
fn shared_queue_concurrent_reset_push_cycles() {
    // The per-iteration pattern: reset → concurrent pushes → scan.
    let pool = GridPool::new(4);
    let q: SharedQueue<(f64, u32)> = SharedQueue::new(1024);
    for iter in 0..200 {
        q.reset();
        pool.launch(8, |ctx| {
            for k in 0..16u32 {
                q.push((iter as f64, (ctx.block_id as u32) * 100 + k));
            }
        });
        assert_eq!(q.len(), 128, "iteration {iter}");
        let mut count = 0;
        q.scan(|&(f, _)| {
            assert_eq!(f, iter as f64, "stale entry survived reset");
            count += 1;
        });
        assert_eq!(count, 128);
    }
}

#[test]
fn engines_survive_degenerate_workloads() {
    // n=1 (single particle, single block), n=block_size boundary, dim=1
    // iters=1 — the smallest legal configurations must not panic and must
    // return a sane result.
    for kind in EngineKind::TABLE3 {
        for (n, iters) in [(1usize, 1u64), (1, 10), (256, 1), (257, 1)] {
            let params = PsoParams::paper_1d(n, iters);
            let mut engine = cupso::engine::build(kind, 2).unwrap();
            let out = engine.run(&params, &Cubic, Objective::Maximize, 5);
            assert!(
                out.gbest_fit.is_finite(),
                "{kind:?} n={n} iters={iters}: non-finite gbest"
            );
            assert_eq!(out.gbest_pos.len(), 1);
        }
    }
}

#[test]
fn custom_block_size_preserves_equivalence() {
    // Geometry must not leak into numerics: 64-, 256- and 1024-wide
    // blocks give identical results for the synchronized engines.
    use cupso::engine::QueueEngine;
    let params = PsoParams::paper_1d(500, 20);
    let mut reference = None;
    for bs in [64usize, 256, 1024] {
        let settings = ParallelSettings::with_workers(3).block_size(bs);
        let mut e = QueueEngine::new(settings);
        let out = e.run(&params, &Cubic, Objective::Maximize, 11);
        match &reference {
            None => reference = Some(out),
            Some(r) => {
                assert_eq!(out.gbest_fit, r.gbest_fit, "bs={bs}");
                assert_eq!(out.gbest_pos, r.gbest_pos, "bs={bs}");
                assert_eq!(out.history, r.history, "bs={bs}");
            }
        }
    }
}
