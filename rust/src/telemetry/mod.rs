//! Flight-recorder telemetry: always-on, bit-exactness-preserving
//! runtime metrics for the serving stack.
//!
//! Three pieces:
//!
//! 1. **A process-global registry** of lock-free counters, high-water
//!    gauges and fixed-bin log₂-scale histograms — every cell is a
//!    pre-allocated `static` [`AtomicU64`] touched with `Relaxed`
//!    ordering only, so recording is a handful of uncontended atomic
//!    adds: no locks, no allocation, and no branching inside engine
//!    math (instrumentation lives at the scheduler/service layer and
//!    wraps phases; it never reads or writes run state). Timing uses
//!    [`Instant`] reads that feed *only* the registry, so the
//!    determinism tier can prove instrumented runs bit-identical to
//!    telemetry-disabled runs (`rust/tests/scheduler_determinism.rs`)
//!    and the zero-alloc tier can prove a warmed-up instrumented
//!    service round allocates nothing (`rust/tests/zero_alloc.rs`).
//!
//! 2. **A fixed-capacity trace ring** (the flight recorder): the last
//!    [`TRACE_CAP`] discrete scheduler/service events (admissions,
//!    cancellations, finishes, sheds, quota refusals, pack churn,
//!    snapshot outcomes, injected faults, drain) as fixed-size
//!    `String`-free records in a lock-free ring — a racing writer can
//!    at worst tear a slot that is being overwritten anyway. The ring
//!    is dumped to stderr (or the file set by [`set_trace_path`]) on
//!    panic ([`install_panic_hook`]), on a fatal persist failure, and
//!    on demand at drain.
//!
//! 3. **Exposure**: [`render_json`] is the body of the `metrics` wire
//!    verb (`service/proto.rs`); `cupso status --metrics` renders the
//!    same snapshot as Prometheus-style text and `cupso top` as a live
//!    terminal dashboard (both client-side, in `main.rs`).
//!
//! Histogram bin scheme: bin 0 counts exact zeros; bin `b ≥ 1` counts
//! values in `[2^(b−1), 2^b)`; the last bin absorbs everything at or
//! above `2^(HISTO_BINS−2)` (≈ 4.6 minutes for nanosecond series).
//! Log₂ binning costs one `leading_zeros` on the hot path and keeps
//! the whole registry a few KiB of statics.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering::Relaxed, Ordering::Release};
use std::sync::Mutex;
use std::time::{Instant, SystemTime, UNIX_EPOCH};

/// Number of histogram bins (see the module docs for the bin scheme).
pub const HISTO_BINS: usize = 40;

/// Capacity of the trace ring (events; oldest are overwritten).
pub const TRACE_CAP: usize = 1024;

/// Monotonic counters, indexed by discriminant.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(usize)]
pub enum Counter {
    /// Scheduling rounds completed.
    Rounds,
    /// Jobs admitted into a session (service or batch).
    JobsAdmitted,
    /// Jobs that ran to a terminal stop reason other than cancellation.
    JobsFinished,
    /// Jobs cancelled by request.
    JobsCancelled,
    /// Submissions refused by a per-tenant quota.
    QuotaRefusals,
    /// Connections accepted by the event loop.
    ConnsAccepted,
    /// Connections shed at the connection cap.
    ConnsShed,
    /// Watch telemetry events fanned out to subscribers.
    WatchEvents,
    /// Packs formed by the scheduler's packing policy.
    PacksFormed,
    /// Packs dissolved (underfull or swept).
    PacksDissolved,
    /// Snapshots persisted successfully.
    Snapshots,
    /// Snapshot persist attempts that failed.
    SnapshotFailures,
    /// Bytes handed to the store seam's durable writes.
    SnapshotBytes,
    /// fsync calls issued by the store seam (file + directory).
    SnapshotFsyncs,
    /// `CUPSO_FAULT_PLAN` write directives that actually fired.
    FaultsFiredWrite,
    /// Fault-plan fsync directives that actually fired.
    FaultsFiredFsync,
    /// Fault-plan rename directives that actually fired.
    FaultsFiredRename,
    /// Fault-plan persist-point directives that actually fired.
    FaultsFiredPersist,
    /// Trace-ring dumps emitted.
    TraceDumps,
}

impl Counter {
    /// Every counter, in render order.
    pub const ALL: [Counter; 19] = [
        Counter::Rounds,
        Counter::JobsAdmitted,
        Counter::JobsFinished,
        Counter::JobsCancelled,
        Counter::QuotaRefusals,
        Counter::ConnsAccepted,
        Counter::ConnsShed,
        Counter::WatchEvents,
        Counter::PacksFormed,
        Counter::PacksDissolved,
        Counter::Snapshots,
        Counter::SnapshotFailures,
        Counter::SnapshotBytes,
        Counter::SnapshotFsyncs,
        Counter::FaultsFiredWrite,
        Counter::FaultsFiredFsync,
        Counter::FaultsFiredRename,
        Counter::FaultsFiredPersist,
        Counter::TraceDumps,
    ];
    /// Number of counters.
    pub const COUNT: usize = Self::ALL.len();

    /// Stable wire/Prometheus name.
    pub fn name(self) -> &'static str {
        match self {
            Counter::Rounds => "rounds_total",
            Counter::JobsAdmitted => "jobs_admitted_total",
            Counter::JobsFinished => "jobs_finished_total",
            Counter::JobsCancelled => "jobs_cancelled_total",
            Counter::QuotaRefusals => "quota_refusals_total",
            Counter::ConnsAccepted => "conns_accepted_total",
            Counter::ConnsShed => "conns_shed_total",
            Counter::WatchEvents => "watch_events_total",
            Counter::PacksFormed => "packs_formed_total",
            Counter::PacksDissolved => "packs_dissolved_total",
            Counter::Snapshots => "snapshots_total",
            Counter::SnapshotFailures => "snapshot_failures_total",
            Counter::SnapshotBytes => "snapshot_bytes_total",
            Counter::SnapshotFsyncs => "snapshot_fsyncs_total",
            Counter::FaultsFiredWrite => "faults_fired_write_total",
            Counter::FaultsFiredFsync => "faults_fired_fsync_total",
            Counter::FaultsFiredRename => "faults_fired_rename_total",
            Counter::FaultsFiredPersist => "faults_fired_persist_total",
            Counter::TraceDumps => "trace_dumps_total",
        }
    }
}

/// Histogram series, indexed by discriminant.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(usize)]
pub enum Series {
    /// Round phase: policy pick (candidate ordering + selection).
    RoundPickNs,
    /// Round phase: command publish to the stream executors.
    RoundPublishNs,
    /// Round phase: waiting for executor completion echoes.
    RoundWakeNs,
    /// Round phase: stepping (inline fast path + packs + legacy spawns).
    RoundStepNs,
    /// Round phase: report application / global-best accounting.
    RoundGbestNs,
    /// Round phase: reaping finished slots.
    RoundReapNs,
    /// Per-executor latency from command publish to completion echo.
    ExecWakeToDoneNs,
    /// Wall time of one snapshot persist.
    SnapshotPersistNs,
    /// Bytes written durably by one snapshot.
    SnapshotBytesPer,
    /// fsyncs issued by one snapshot.
    SnapshotFsyncsPer,
    /// Watch subscribers fanned out to per stepped round.
    WatchFanout,
}

impl Series {
    /// Every series, in render order.
    pub const ALL: [Series; 11] = [
        Series::RoundPickNs,
        Series::RoundPublishNs,
        Series::RoundWakeNs,
        Series::RoundStepNs,
        Series::RoundGbestNs,
        Series::RoundReapNs,
        Series::ExecWakeToDoneNs,
        Series::SnapshotPersistNs,
        Series::SnapshotBytesPer,
        Series::SnapshotFsyncsPer,
        Series::WatchFanout,
    ];
    /// Number of series.
    pub const COUNT: usize = Self::ALL.len();

    /// Stable wire/Prometheus name.
    pub fn name(self) -> &'static str {
        match self {
            Series::RoundPickNs => "round_pick_ns",
            Series::RoundPublishNs => "round_publish_ns",
            Series::RoundWakeNs => "round_wake_ns",
            Series::RoundStepNs => "round_step_ns",
            Series::RoundGbestNs => "round_gbest_ns",
            Series::RoundReapNs => "round_reap_ns",
            Series::ExecWakeToDoneNs => "exec_wake_to_done_ns",
            Series::SnapshotPersistNs => "snapshot_persist_ns",
            Series::SnapshotBytesPer => "snapshot_bytes",
            Series::SnapshotFsyncsPer => "snapshot_fsyncs",
            Series::WatchFanout => "watch_fanout",
        }
    }
}

/// Gauges (set / running-max cells), indexed by discriminant.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(usize)]
pub enum Gauge {
    /// High-water mark of any connection's pending-reply queue.
    ConnPendingHwm,
    /// High-water mark of any connection's write-buffer bytes.
    ConnWbufHwm,
    /// Unix milliseconds when the service session started (0 = never).
    ServiceStartUnixMs,
    /// Unix milliseconds of the last successful snapshot (0 = never).
    LastSnapshotUnixMs,
}

impl Gauge {
    /// Every gauge, in render order.
    pub const ALL: [Gauge; 4] = [
        Gauge::ConnPendingHwm,
        Gauge::ConnWbufHwm,
        Gauge::ServiceStartUnixMs,
        Gauge::LastSnapshotUnixMs,
    ];
    /// Number of gauges.
    pub const COUNT: usize = Self::ALL.len();

    /// Stable wire/Prometheus name.
    pub fn name(self) -> &'static str {
        match self {
            Gauge::ConnPendingHwm => "conn_pending_hwm",
            Gauge::ConnWbufHwm => "conn_wbuf_hwm",
            Gauge::ServiceStartUnixMs => "service_start_unix_ms",
            Gauge::LastSnapshotUnixMs => "last_snapshot_unix_ms",
        }
    }
}

/// Discrete event kinds recorded in the trace ring.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u64)]
pub enum TraceKind {
    /// Job admitted (`a` = slot).
    Admit = 1,
    /// Job cancelled (`a` = slot).
    Cancel = 2,
    /// Job finished (`a` = slot, `b` = stop-reason code).
    Finish = 3,
    /// Submission refused by quota (`a` = 0 jobs / 1 steps).
    QuotaRefusal = 4,
    /// Connection shed at the cap (`a` = configured cap).
    Shed = 5,
    /// Pack formed (`a` = member count).
    PackForm = 6,
    /// Pack dissolved (`a` = member count).
    PackDissolve = 7,
    /// Snapshot persisted (`a` = live jobs captured).
    PersistOk = 8,
    /// Snapshot persist failed (`a` = live jobs attempted).
    PersistFail = 9,
    /// Injected fault directive fired (`a` = op index, `b` = nth).
    FaultFired = 10,
    /// Drain accepted.
    Drain = 11,
}

fn kind_name(code: u64) -> &'static str {
    match code {
        1 => "admit",
        2 => "cancel",
        3 => "finish",
        4 => "quota_refusal",
        5 => "shed",
        6 => "pack_form",
        7 => "pack_dissolve",
        8 => "persist_ok",
        9 => "persist_fail",
        10 => "fault_fired",
        11 => "drain",
        _ => "unknown",
    }
}

/// One fixed-bin log₂ histogram: pre-allocated atomics, `Relaxed` adds.
pub struct Histo {
    bins: [AtomicU64; HISTO_BINS],
    count: AtomicU64,
    sum: AtomicU64,
    max: AtomicU64,
}

impl Histo {
    const fn new() -> Self {
        Self {
            bins: [const { AtomicU64::new(0) }; HISTO_BINS],
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            max: AtomicU64::new(0),
        }
    }

    fn record(&self, v: u64) {
        self.bins[bin_of(v)].fetch_add(1, Relaxed);
        self.count.fetch_add(1, Relaxed);
        self.sum.fetch_add(v, Relaxed);
        self.max.fetch_max(v, Relaxed);
    }
}

/// Bin index for a value (see the module docs for the scheme).
pub fn bin_of(v: u64) -> usize {
    if v == 0 {
        0
    } else {
        (64 - v.leading_zeros() as usize).min(HISTO_BINS - 1)
    }
}

/// A point-in-time copy of one histogram.
#[derive(Debug, Clone)]
pub struct HistoSnapshot {
    /// Values recorded.
    pub count: u64,
    /// Sum of recorded values.
    pub sum: u64,
    /// Largest recorded value.
    pub max: u64,
    /// Per-bin counts.
    pub bins: [u64; HISTO_BINS],
}

impl HistoSnapshot {
    /// Arithmetic mean of recorded values (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }
}

struct TraceSlot {
    seq: AtomicU64,
    kind: AtomicU64,
    a: AtomicU64,
    b: AtomicU64,
    at_ms: AtomicU64,
}

impl TraceSlot {
    const fn new() -> Self {
        Self {
            seq: AtomicU64::new(0),
            kind: AtomicU64::new(0),
            a: AtomicU64::new(0),
            b: AtomicU64::new(0),
            at_ms: AtomicU64::new(0),
        }
    }
}

struct Registry {
    enabled: AtomicBool,
    counters: [AtomicU64; Counter::COUNT],
    histos: [Histo; Series::COUNT],
    gauges: [AtomicU64; Gauge::COUNT],
    trace_cursor: AtomicU64,
    trace: [TraceSlot; TRACE_CAP],
    trace_path: Mutex<Option<PathBuf>>,
}

static REGISTRY: Registry = Registry {
    enabled: AtomicBool::new(true),
    counters: [const { AtomicU64::new(0) }; Counter::COUNT],
    histos: [const { Histo::new() }; Series::COUNT],
    gauges: [const { AtomicU64::new(0) }; Gauge::COUNT],
    trace_cursor: AtomicU64::new(0),
    trace: [const { TraceSlot::new() }; TRACE_CAP],
    trace_path: Mutex::new(None),
};

/// Is recording enabled? (Default: on. One `Relaxed` load.)
#[inline]
pub fn enabled() -> bool {
    REGISTRY.enabled.load(Relaxed)
}

/// Enable or disable all recording. Disabling makes every record call
/// a no-op *and* skips the clock reads that feed the phase histograms —
/// the determinism tier compares runs across this switch.
pub fn set_enabled(on: bool) {
    REGISTRY.enabled.store(on, Relaxed);
}

/// Increment a counter by 1.
#[inline]
pub fn bump(c: Counter) {
    add(c, 1);
}

/// Increment a counter by `n`.
#[inline]
pub fn add(c: Counter, n: u64) {
    if enabled() {
        REGISTRY.counters[c as usize].fetch_add(n, Relaxed);
    }
}

/// Read a counter.
pub fn counter(c: Counter) -> u64 {
    REGISTRY.counters[c as usize].load(Relaxed)
}

/// Record one value into a histogram series.
#[inline]
pub fn record(s: Series, v: u64) {
    if enabled() {
        REGISTRY.histos[s as usize].record(v);
    }
}

/// Snapshot one histogram series.
pub fn histo(s: Series) -> HistoSnapshot {
    let h = &REGISTRY.histos[s as usize];
    let mut bins = [0u64; HISTO_BINS];
    for (out, bin) in bins.iter_mut().zip(h.bins.iter()) {
        *out = bin.load(Relaxed);
    }
    HistoSnapshot {
        count: h.count.load(Relaxed),
        sum: h.sum.load(Relaxed),
        max: h.max.load(Relaxed),
        bins,
    }
}

/// Raise a gauge to at least `v` (running maximum).
#[inline]
pub fn gauge_max(g: Gauge, v: u64) {
    if enabled() {
        REGISTRY.gauges[g as usize].fetch_max(v, Relaxed);
    }
}

/// Set a gauge (unconditional — timestamps must move, even backwards
/// across test sessions in one process).
pub fn gauge_set(g: Gauge, v: u64) {
    REGISTRY.gauges[g as usize].store(v, Relaxed);
}

/// Read a gauge.
pub fn gauge(g: Gauge) -> u64 {
    REGISTRY.gauges[g as usize].load(Relaxed)
}

/// Milliseconds since the Unix epoch (0 if the clock is before it).
pub fn unix_ms() -> u64 {
    SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_millis() as u64)
        .unwrap_or(0)
}

/// Mark the service session start (uptime anchor).
pub fn mark_service_start() {
    gauge_set(Gauge::ServiceStartUnixMs, unix_ms());
}

/// Whole seconds since [`mark_service_start`] (0 if never marked).
pub fn uptime_secs() -> u64 {
    let start = gauge(Gauge::ServiceStartUnixMs);
    if start == 0 {
        0
    } else {
        unix_ms().saturating_sub(start) / 1000
    }
}

/// Mark a successful snapshot now.
pub fn mark_snapshot_now() {
    gauge_set(Gauge::LastSnapshotUnixMs, unix_ms());
}

/// Whole seconds since the last successful snapshot (`None` = never).
pub fn last_snapshot_age_secs() -> Option<u64> {
    match gauge(Gauge::LastSnapshotUnixMs) {
        0 => None,
        at => Some(unix_ms().saturating_sub(at) / 1000),
    }
}

/// Record one discrete event into the trace ring.
pub fn trace(kind: TraceKind, a: u64, b: u64) {
    if !enabled() {
        return;
    }
    let r = &REGISTRY;
    let seq = r.trace_cursor.fetch_add(1, Relaxed) + 1;
    let slot = &r.trace[(seq - 1) as usize % TRACE_CAP];
    // seq = 0 marks the slot in-progress; readers skip it. A concurrent
    // writer lapping this slot would be overwriting it anyway — the dump
    // is a best-effort flight recording, not a consistent snapshot.
    slot.seq.store(0, Release);
    slot.kind.store(kind as u64, Relaxed);
    slot.a.store(a, Relaxed);
    slot.b.store(b, Relaxed);
    slot.at_ms.store(unix_ms(), Relaxed);
    slot.seq.store(seq, Release);
}

/// Total events ever recorded into the trace ring.
pub fn trace_recorded() -> u64 {
    REGISTRY.trace_cursor.load(Relaxed)
}

/// Route trace-ring dumps to a file (append) instead of stderr.
/// `None` restores stderr.
pub fn set_trace_path(path: Option<PathBuf>) {
    *REGISTRY.trace_path.lock().unwrap_or_else(|e| e.into_inner()) = path;
}

/// Where dumps currently go (`None` = stderr).
pub fn trace_path() -> Option<PathBuf> {
    REGISTRY
        .trace_path
        .lock()
        .unwrap_or_else(|e| e.into_inner())
        .clone()
}

/// Dump the trace ring (oldest → newest) to the configured sink.
/// Best-effort by design — it runs inside panic hooks and fatal-error
/// paths, so every I/O failure falls back to stderr rather than
/// propagating. Returns the number of events dumped.
pub fn dump_trace(reason: &str) -> usize {
    let r = &REGISTRY;
    let mut events: Vec<(u64, u64, u64, u64, u64)> = Vec::with_capacity(TRACE_CAP);
    for slot in r.trace.iter() {
        let seq = slot.seq.load(Relaxed);
        if seq != 0 {
            events.push((
                seq,
                slot.at_ms.load(Relaxed),
                slot.kind.load(Relaxed),
                slot.a.load(Relaxed),
                slot.b.load(Relaxed),
            ));
        }
    }
    events.sort_unstable_by_key(|e| e.0);
    let mut out = format!(
        "== cupso trace ring ({reason}): {} event(s) of {} recorded ==\n",
        events.len(),
        trace_recorded(),
    );
    for (seq, at_ms, kind, a, b) in &events {
        out.push_str(&format!(
            "trace seq={seq} t_ms={at_ms} event={} a={a} b={b}\n",
            kind_name(*kind)
        ));
    }
    out.push_str("== end trace ring ==\n");
    REGISTRY.counters[Counter::TraceDumps as usize].fetch_add(1, Relaxed);
    match trace_path() {
        Some(path) => {
            if append_file(&path, &out).is_err() {
                eprint!("{out}");
            }
        }
        None => eprint!("{out}"),
    }
    events.len()
}

fn append_file(path: &Path, text: &str) -> std::io::Result<()> {
    use std::io::Write as _;
    let mut f = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(path)?;
    f.write_all(text.as_bytes())
}

/// Install a panic hook that dumps the trace ring before the default
/// handler runs. Idempotent; chains any previously installed hook.
pub fn install_panic_hook() {
    static ONCE: std::sync::Once = std::sync::Once::new();
    ONCE.call_once(|| {
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            dump_trace("panic");
            prev(info);
        }));
    });
}

/// Per-round phase stopwatch: one [`Instant`] read per lap, recording
/// the split into the given series. Disabled telemetry makes `start`
/// return an inert clock — no clock reads at all on the disabled path,
/// so the on/off determinism comparison covers the timing calls too.
pub struct PhaseClock {
    last: Option<Instant>,
}

impl PhaseClock {
    /// Start timing (inert when telemetry is disabled).
    pub fn start() -> Self {
        Self {
            last: enabled().then(Instant::now),
        }
    }

    /// Record the split since the previous lap into `series`.
    pub fn lap(&mut self, series: Series) {
        if let Some(prev) = self.last {
            let now = Instant::now();
            record(series, now.duration_since(prev).as_nanos() as u64);
            self.last = Some(now);
        }
    }

    /// The instant of the previous lap (None when inert) — lets callers
    /// measure overlapping intervals (e.g. per-executor wake-to-done)
    /// without extra clock reads.
    pub fn mark(&self) -> Option<Instant> {
        self.last
    }

    /// Record the elapsed time since `from` into `series`.
    pub fn record_since(&self, from: Option<Instant>, series: Series) {
        if let (Some(from), Some(_)) = (from, self.last) {
            record(series, from.elapsed().as_nanos() as u64);
        }
    }
}

/// Render the full registry as one structured JSON object (the body of
/// the `metrics` wire verb): uptime, counters, gauges, per-series
/// histograms (count/sum/max/mean + raw bins), and trace-ring state.
pub fn render_json() -> String {
    use crate::service::proto::{array, Obj};
    let mut counters = Obj::new();
    for c in Counter::ALL {
        counters = counters.int(c.name(), counter(c));
    }
    let mut gauges = Obj::new();
    for g in Gauge::ALL {
        gauges = gauges.int(g.name(), gauge(g));
    }
    let mut histos = Obj::new();
    for s in Series::ALL {
        let h = histo(s);
        let hi_bin = h
            .bins
            .iter()
            .rposition(|&b| b != 0)
            .map(|i| i + 1)
            .unwrap_or(0);
        let body = Obj::new()
            .int("count", h.count)
            .int("sum", h.sum)
            .int("max", h.max)
            .num("mean", h.mean())
            .raw(
                "bins",
                &array(h.bins[..hi_bin].iter().map(|b| b.to_string())),
            )
            .render();
        histos = histos.raw(s.name(), &body);
    }
    let trace = Obj::new()
        .int("recorded", trace_recorded())
        .int("capacity", TRACE_CAP as u64)
        .render();
    let mut obj = Obj::new()
        .bool("enabled", enabled())
        .int("uptime_s", uptime_secs());
    obj = match last_snapshot_age_secs() {
        Some(age) => obj.int("last_snapshot_age_s", age),
        None => obj.raw("last_snapshot_age_s", "null"),
    };
    obj.raw("counters", &counters.render())
        .raw("gauges", &gauges.render())
        .raw("histos", &histos.render())
        .raw("trace", &trace.render())
        .render()
}

#[cfg(test)]
mod tests {
    use super::*;

    // The registry is process-global and lib unit tests run
    // concurrently; serialize the tests that toggle global switches.
    static TLOCK: Mutex<()> = Mutex::new(());

    #[test]
    fn bin_scheme_boundaries() {
        assert_eq!(bin_of(0), 0);
        assert_eq!(bin_of(1), 1);
        assert_eq!(bin_of(2), 2);
        assert_eq!(bin_of(3), 2);
        assert_eq!(bin_of(4), 3);
        assert_eq!(bin_of((1 << 20) - 1), 20);
        assert_eq!(bin_of(1 << 20), 21);
        assert_eq!(bin_of(u64::MAX), HISTO_BINS - 1);
    }

    #[test]
    fn counters_and_histos_accumulate() {
        let _g = TLOCK.lock().unwrap_or_else(|e| e.into_inner());
        let was = enabled();
        set_enabled(true);
        let before = counter(Counter::Rounds);
        bump(Counter::Rounds);
        add(Counter::Rounds, 2);
        assert_eq!(counter(Counter::Rounds), before + 3);

        let h0 = histo(Series::RoundPickNs);
        record(Series::RoundPickNs, 0);
        record(Series::RoundPickNs, 5);
        let h1 = histo(Series::RoundPickNs);
        assert_eq!(h1.count, h0.count + 2);
        assert_eq!(h1.sum, h0.sum + 5);
        assert!(h1.max >= 5);
        assert_eq!(h1.bins[0], h0.bins[0] + 1);
        assert_eq!(h1.bins[bin_of(5)], h0.bins[bin_of(5)] + 1);
        set_enabled(was);
    }

    #[test]
    fn disabled_recording_is_a_no_op() {
        let _g = TLOCK.lock().unwrap_or_else(|e| e.into_inner());
        let was = enabled();
        set_enabled(false);
        let c0 = counter(Counter::ConnsShed);
        let h0 = histo(Series::WatchFanout).count;
        let t0 = trace_recorded();
        bump(Counter::ConnsShed);
        record(Series::WatchFanout, 7);
        trace(TraceKind::Shed, 1, 2);
        let mut clock = PhaseClock::start();
        assert!(clock.mark().is_none(), "inert clock reads no Instant");
        clock.lap(Series::WatchFanout);
        assert_eq!(counter(Counter::ConnsShed), c0);
        assert_eq!(histo(Series::WatchFanout).count, h0);
        assert_eq!(trace_recorded(), t0);
        set_enabled(was);
    }

    #[test]
    fn trace_ring_wraps_and_dumps_to_file() {
        let _g = TLOCK.lock().unwrap_or_else(|e| e.into_inner());
        let was = enabled();
        set_enabled(true);
        for i in 0..(TRACE_CAP as u64 + 8) {
            trace(TraceKind::Admit, i, 0);
        }
        trace(TraceKind::Drain, 0, 0);
        let dir = std::env::temp_dir().join(format!(
            "cupso_trace_test_{}_{}",
            std::process::id(),
            unix_ms()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trace.log");
        set_trace_path(Some(path.clone()));
        let dumped = dump_trace("unit test");
        set_trace_path(None);
        assert!(dumped <= TRACE_CAP, "ring is bounded, dumped {dumped}");
        assert!(dumped > 0);
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("trace ring (unit test)"), "{text}");
        assert!(text.contains("event=admit"), "{text}");
        assert!(text.contains("event=drain"), "{text}");
        assert!(text.contains("== end trace ring =="), "{text}");
        std::fs::remove_dir_all(&dir).ok();
        set_enabled(was);
    }

    #[test]
    fn render_json_is_parseable_and_complete() {
        let _g = TLOCK.lock().unwrap_or_else(|e| e.into_inner());
        let was = enabled();
        set_enabled(true);
        record(Series::RoundStepNs, 1234);
        bump(Counter::Rounds);
        let doc = crate::service::proto::Json::parse(&render_json()).unwrap();
        assert!(doc.get("enabled").unwrap().as_bool("enabled").unwrap());
        let counters = doc.get("counters").unwrap();
        for c in Counter::ALL {
            assert!(counters.get(c.name()).is_some(), "missing {}", c.name());
        }
        let gauges = doc.get("gauges").unwrap();
        for g in Gauge::ALL {
            assert!(gauges.get(g.name()).is_some(), "missing {}", g.name());
        }
        let histos = doc.get("histos").unwrap();
        for s in Series::ALL {
            let h = histos.get(s.name()).unwrap_or_else(|| panic!("{}", s.name()));
            assert!(h.get("count").is_some() && h.get("bins").is_some());
        }
        let step = histos.get("round_step_ns").unwrap();
        assert!(step.get("count").unwrap().as_u64("count").unwrap() >= 1);
        assert!(doc.get("trace").unwrap().get("capacity").is_some());
        set_enabled(was);
    }

    #[test]
    fn phase_clock_records_laps_and_spans() {
        let _g = TLOCK.lock().unwrap_or_else(|e| e.into_inner());
        let was = enabled();
        set_enabled(true);
        let h0 = histo(Series::RoundGbestNs).count;
        let w0 = histo(Series::ExecWakeToDoneNs).count;
        let mut clock = PhaseClock::start();
        let mark = clock.mark();
        assert!(mark.is_some());
        clock.lap(Series::RoundGbestNs);
        clock.record_since(mark, Series::ExecWakeToDoneNs);
        assert_eq!(histo(Series::RoundGbestNs).count, h0 + 1);
        assert_eq!(histo(Series::ExecWakeToDoneNs).count, w0 + 1);
        set_enabled(was);
    }

    #[test]
    fn uptime_and_snapshot_age_anchor() {
        let _g = TLOCK.lock().unwrap_or_else(|e| e.into_inner());
        mark_service_start();
        assert!(uptime_secs() < 3600);
        mark_snapshot_now();
        assert!(last_snapshot_age_secs().unwrap() < 3600);
    }
}
