//! Dependency-free CLI argument parser (clap is unavailable offline).
//!
//! Supports the launcher's needs: a subcommand word followed by
//! `--flag value`, `--flag=value`, and boolean `--flag` options, with
//! declared options, typed accessors, and generated `--help` text.

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;

/// Declared option metadata (for help text + unknown-flag rejection).
#[derive(Debug, Clone)]
pub struct OptSpec {
    /// Long name without the `--`.
    pub name: &'static str,
    /// Help line.
    pub help: &'static str,
    /// Whether the option consumes a value.
    pub takes_value: bool,
    /// Shown default, if any.
    pub default: Option<&'static str>,
}

/// Parsed command line: subcommand + options + positional args.
#[derive(Debug, Clone)]
pub struct Args {
    /// The subcommand word (first non-flag argument), if any.
    pub command: Option<String>,
    opts: BTreeMap<String, String>,
    /// Remaining positional arguments.
    pub positional: Vec<String>,
}

impl Args {
    /// String option.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    /// Presence of a boolean flag.
    pub fn flag(&self, name: &str) -> bool {
        self.opts.contains_key(name)
    }

    /// Typed option with default.
    pub fn get_parse<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.opts.get(name) {
            None => Ok(default),
            Some(raw) => raw
                .replace('_', "")
                .parse()
                .map_err(|e| anyhow::anyhow!("--{name} {raw:?}: {e}")),
        }
    }
}

/// A subcommand parser: declared options + usage rendering.
pub struct Command {
    name: &'static str,
    about: &'static str,
    opts: Vec<OptSpec>,
}

impl Command {
    /// New subcommand spec.
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Self {
            name,
            about,
            opts: Vec::new(),
        }
    }

    /// Declare an option that takes a value.
    pub fn opt(mut self, name: &'static str, help: &'static str, default: Option<&'static str>) -> Self {
        self.opts.push(OptSpec {
            name,
            help,
            takes_value: true,
            default,
        });
        self
    }

    /// Declare a boolean flag.
    pub fn switch(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec {
            name,
            help,
            takes_value: false,
            default: None,
        });
        self
    }

    /// Render the help text.
    pub fn usage(&self) -> String {
        let mut out = format!("{} — {}\n\nOptions:\n", self.name, self.about);
        for o in &self.opts {
            let val = if o.takes_value { " <value>" } else { "" };
            let def = o
                .default
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            out.push_str(&format!("  --{}{val}\n        {}{def}\n", o.name, o.help));
        }
        out
    }

    /// Parse raw args (post-subcommand) against the declared options.
    pub fn parse(&self, raw: &[String]) -> Result<Args> {
        let mut opts = BTreeMap::new();
        let mut positional = Vec::new();
        let mut it = raw.iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(body) = arg.strip_prefix("--") {
                let (name, inline) = match body.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (body, None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|o| o.name == name)
                    .with_context(|| format!("unknown option --{name}\n\n{}", self.usage()))?;
                let value = if spec.takes_value {
                    match inline {
                        Some(v) => v,
                        None => it
                            .next()
                            .with_context(|| format!("--{name} expects a value"))?
                            .clone(),
                    }
                } else {
                    if inline.is_some() {
                        bail!("--{name} does not take a value");
                    }
                    "true".to_string()
                };
                opts.insert(name.to_string(), value);
            } else {
                positional.push(arg.clone());
            }
        }
        // Apply declared defaults for options not given, so `get` is
        // reliable wherever a default exists.
        for spec in &self.opts {
            if spec.takes_value && !opts.contains_key(spec.name) {
                if let Some(d) = spec.default {
                    opts.insert(spec.name.to_string(), d.to_string());
                }
            }
        }
        Ok(Args {
            command: Some(self.name.to_string()),
            opts,
            positional,
        })
    }
}

/// Split argv into `(subcommand, rest)`.
pub fn split_subcommand(argv: &[String]) -> (Option<&str>, &[String]) {
    match argv.first() {
        Some(first) if !first.starts_with('-') => (Some(first.as_str()), &argv[1..]),
        _ => (None, argv),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cmd() -> Command {
        Command::new("run", "run a swarm")
            .opt("particles", "swarm size", Some("1024"))
            .opt("engine", "algorithm", Some("queuelock"))
            .switch("verbose", "log more")
    }

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_space_and_equals_forms() {
        let a = cmd()
            .parse(&argv(&["--particles", "2048", "--engine=queue", "--verbose"]))
            .unwrap();
        assert_eq!(a.get("particles"), Some("2048"));
        assert_eq!(a.get("engine"), Some("queue"));
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn typed_accessor_with_default_and_underscores() {
        let a = cmd().parse(&argv(&["--particles", "65_536"])).unwrap();
        assert_eq!(a.get_parse("particles", 0usize).unwrap(), 65_536);
        assert_eq!(a.get_parse("missing-ok", 7u64).unwrap_or(7), 7);
    }

    #[test]
    fn rejects_unknown_and_malformed() {
        assert!(cmd().parse(&argv(&["--nope"])).is_err());
        assert!(cmd().parse(&argv(&["--particles"])).is_err());
        assert!(cmd().parse(&argv(&["--verbose=1"])).is_err());
    }

    #[test]
    fn positionals_collected() {
        let a = cmd().parse(&argv(&["config.toml", "--verbose"])).unwrap();
        assert_eq!(a.positional, vec!["config.toml"]);
    }

    #[test]
    fn subcommand_split() {
        let v = argv(&["bench", "--reps", "3"]);
        let (cmd, rest) = split_subcommand(&v);
        assert_eq!(cmd, Some("bench"));
        assert_eq!(rest.len(), 2);
        let v2 = argv(&["--help"]);
        assert_eq!(split_subcommand(&v2).0, None);
    }

    #[test]
    fn usage_lists_options() {
        let u = cmd().usage();
        assert!(u.contains("--particles"));
        assert!(u.contains("default: 1024"));
    }
}
