//! Atomic `f64` via CAS on the bit pattern — the CPU analog of CUDA's
//! software atomic-double idiom (`atomicCAS` on `unsigned long long`).

use crate::exec::sync::{AtomicU64, Ordering};

/// An `f64` updatable atomically across threads.
pub struct AtomicF64 {
    bits: AtomicU64,
}

impl AtomicF64 {
    /// New cell holding `v`.
    pub fn new(v: f64) -> Self {
        Self {
            bits: AtomicU64::new(v.to_bits()),
        }
    }

    /// Current value.
    #[inline]
    pub fn load(&self, order: Ordering) -> f64 {
        f64::from_bits(self.bits.load(order))
    }

    /// Unconditional store.
    #[inline]
    pub fn store(&self, v: f64, order: Ordering) {
        self.bits.store(v.to_bits(), order);
    }

    /// CAS loop applying `f` until it sticks; returns the previous value.
    #[inline]
    pub fn fetch_update<F: Fn(f64) -> Option<f64>>(&self, f: F) -> Result<f64, f64> {
        self.bits
            .fetch_update(Ordering::AcqRel, Ordering::Acquire, |b| {
                f(f64::from_bits(b)).map(f64::to_bits)
            })
            .map(f64::from_bits)
            .map_err(f64::from_bits)
    }

    /// Monotone max update (the `gbest_fit` pattern under Maximize):
    /// store `v` only if it exceeds the current value. Returns `true` if
    /// the store happened.
    #[inline]
    pub fn fetch_max(&self, v: f64) -> bool {
        self.fetch_update(|cur| if v > cur { Some(v) } else { None })
            .is_ok()
    }

    /// Monotone min update (Minimize sense).
    #[inline]
    pub fn fetch_min(&self, v: f64) -> bool {
        self.fetch_update(|cur| if v < cur { Some(v) } else { None })
            .is_ok()
    }
}

impl std::fmt::Debug for AtomicF64 {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "AtomicF64({})", self.load(Ordering::Relaxed))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::Ordering::*;

    #[test]
    fn load_store_roundtrip() {
        let a = AtomicF64::new(1.5);
        assert_eq!(a.load(Relaxed), 1.5);
        a.store(-2.25, Relaxed);
        assert_eq!(a.load(Relaxed), -2.25);
    }

    #[test]
    fn fetch_max_is_monotone() {
        let a = AtomicF64::new(0.0);
        assert!(a.fetch_max(3.0));
        assert!(!a.fetch_max(1.0));
        assert!(!a.fetch_max(3.0)); // strict: equal does not store
        assert_eq!(a.load(Relaxed), 3.0);
    }

    #[test]
    fn fetch_min_is_monotone() {
        let a = AtomicF64::new(0.0);
        assert!(a.fetch_min(-3.0));
        assert!(!a.fetch_min(5.0));
        assert_eq!(a.load(Relaxed), -3.0);
    }

    #[test]
    fn concurrent_max_converges_to_global_max() {
        // Scaled down under Miri (interpreter, ~10^4x slower).
        const ITERS: u64 = if cfg!(miri) { 100 } else { 10_000 };
        let a = std::sync::Arc::new(AtomicF64::new(f64::NEG_INFINITY));
        let mut handles = vec![];
        for t in 0..8u64 {
            let a = a.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..ITERS {
                    a.fetch_max((t * ITERS + i) as f64);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(a.load(Relaxed), (8 * ITERS - 1) as f64);
    }

    #[test]
    fn handles_neg_infinity_identity() {
        let a = AtomicF64::new(f64::NEG_INFINITY);
        assert!(a.fetch_max(-1e300));
        assert_eq!(a.load(Relaxed), -1e300);
    }

    #[test]
    fn nan_never_stores_via_fetch_max_or_min() {
        // The gbest fast path's half of the NaN policy (see
        // crate::fitness module docs): a NaN candidate never sticks.
        let a = AtomicF64::new(2.0);
        assert!(!a.fetch_max(f64::NAN));
        assert_eq!(a.load(Relaxed), 2.0);
        assert!(!a.fetch_min(f64::NAN));
        assert_eq!(a.load(Relaxed), 2.0);
    }
}
