//! The paper's Algorithm 3 lock, verbatim semantics:
//!
//! ```cuda
//! while (atomicCAS(lock, 0, 1) != 0);   // acquire
//! ...critical section...
//! __threadfence();
//! atomicExch(lock, 0);                  // release
//! ```
//!
//! A test-and-test-and-set spin lock with acquire/release fences playing
//! the role of `__threadfence()`. Used by the Queue-Lock engine to guard
//! `(gbest_fit, gbest_pos)` and by the async coordinator to guard the
//! cross-shard global best.

use crate::exec::sync::{self, AtomicU32, AtomicU64, Ordering, RacyCell};
use std::ops::{Deref, DerefMut};

/// Ordering of the unlock store (`atomicExch(lock, 0)`). `Release` is
/// what makes the critical section visible to the next acquirer; the
/// `cupso_mutate_spinlock_release` cfg weakens it to `Relaxed` so the
/// modelcheck CI job can prove the race detector refutes the weakened
/// protocol (see `rust/tests/modelcheck.rs`).
#[cfg(not(cupso_mutate_spinlock_release))]
const UNLOCK_ORDERING: Ordering = Ordering::Release;
#[cfg(cupso_mutate_spinlock_release)]
const UNLOCK_ORDERING: Ordering = Ordering::Relaxed;

/// CAS spin lock protecting `T`.
pub struct SpinLock<T> {
    flag: AtomicU32,
    data: RacyCell<T>,
    /// Total acquisitions (instrumentation for the contention ablation).
    acquisitions: AtomicU64,
}

// SAFETY: access to `data` is serialized by `flag`.
unsafe impl<T: Send> Send for SpinLock<T> {}
unsafe impl<T: Send> Sync for SpinLock<T> {}

impl<T> SpinLock<T> {
    /// New unlocked cell.
    pub fn new(value: T) -> Self {
        Self {
            flag: AtomicU32::new(0),
            data: RacyCell::new(value),
            acquisitions: AtomicU64::new(0),
        }
    }

    /// Acquire — the `while(atomicCAS(lock,0,1) != 0);` loop. The inner
    /// relaxed-load spin (test-and-test-and-set) avoids hammering the cache
    /// line with RMWs, the CPU equivalent of CUDA's backoff advice.
    #[inline]
    pub fn lock(&self) -> SpinGuard<'_, T> {
        loop {
            if self
                .flag
                .compare_exchange_weak(0, 1, Ordering::Acquire, Ordering::Relaxed)
                .is_ok()
            {
                break;
            }
            while self.flag.load(Ordering::Relaxed) != 0 {
                sync::spin_loop();
            }
        }
        self.acquisitions.fetch_add(1, Ordering::Relaxed);
        SpinGuard { lock: self }
    }

    /// Try to acquire without spinning.
    #[inline]
    pub fn try_lock(&self) -> Option<SpinGuard<'_, T>> {
        if self
            .flag
            .compare_exchange(0, 1, Ordering::Acquire, Ordering::Relaxed)
            .is_ok()
        {
            self.acquisitions.fetch_add(1, Ordering::Relaxed);
            Some(SpinGuard { lock: self })
        } else {
            None
        }
    }

    /// How many times the lock has been taken (contention instrumentation).
    pub fn acquisition_count(&self) -> u64 {
        self.acquisitions.load(Ordering::Relaxed)
    }

    /// Consume the lock, returning the inner value.
    pub fn into_inner(self) -> T {
        self.data.into_inner()
    }
}

/// RAII guard; drop = `__threadfence(); atomicExch(lock, 0);`.
pub struct SpinGuard<'a, T> {
    lock: &'a SpinLock<T>,
}

impl<T> Deref for SpinGuard<'_, T> {
    type Target = T;
    #[inline]
    fn deref(&self) -> &T {
        // SAFETY: guard holds the lock.
        unsafe { &*self.lock.data.read() }
    }
}

impl<T> DerefMut for SpinGuard<'_, T> {
    #[inline]
    fn deref_mut(&mut self) -> &mut T {
        // SAFETY: guard holds the lock exclusively.
        unsafe { &mut *self.lock.data.write() }
    }
}

impl<T> Drop for SpinGuard<'_, T> {
    #[inline]
    fn drop(&mut self) {
        // Release ordering publishes the critical section (__threadfence),
        // the store is the atomicExch(lock, 0). UNLOCK_ORDERING is
        // `Release` except under the mutation self-test cfg.
        self.lock.flag.store(0, UNLOCK_ORDERING);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    // Miri executes ~10^4x slower than native; keep the schedules it can
    // explore but drop the raw iteration count.
    const ITERS: u64 = if cfg!(miri) { 100 } else { 50_000 };

    #[test]
    fn exclusive_increments_do_not_race() {
        let lock = Arc::new(SpinLock::new(0u64));
        let mut handles = vec![];
        for _ in 0..8 {
            let lock = lock.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..ITERS {
                    *lock.lock() += 1;
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(*lock.lock(), 8 * ITERS);
        assert_eq!(lock.acquisition_count(), 8 * ITERS + 1);
    }

    #[test]
    fn try_lock_fails_while_held() {
        let lock = SpinLock::new(());
        let g = lock.lock();
        assert!(lock.try_lock().is_none());
        drop(g);
        assert!(lock.try_lock().is_some());
    }

    #[test]
    fn guards_compound_state() {
        // The Queue-Lock critical section updates (fit, pos) together —
        // verify no torn pairs under contention.
        let lock = Arc::new(SpinLock::new((0u64, 0u64)));
        let mut handles = vec![];
        for t in 1..=4u64 {
            let lock = lock.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..ITERS / 2 {
                    let mut g = lock.lock();
                    let v = t * 1_000_000 + i;
                    *g = (v, v);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let (a, b) = *lock.lock();
        assert_eq!(a, b, "torn write observed");
    }
}
