//! Persistent worker pool with a grid-launch API and concurrent streams.
//!
//! [`GridPool::launch`] is the `kernel<<<blocks, ...>>>()` analog: it hands
//! every logical *block* to a pool worker and returns only when all blocks
//! finished — the return edge is the inter-kernel implicit barrier. The
//! dispatch/join round trip is the CPU's "kernel launch overhead"; the
//! Queue-Lock engine's whole advantage (one launch per iteration instead
//! of two) is measured against exactly this cost, mirroring the paper.
//!
//! ## Streams
//!
//! A pool is partitioned into `S` disjoint **stream groups** (CUDA-stream
//! analog): each stream owns its own slice of the workers and its own
//! job slot / generation / claim counters / launch guard, so up to `S`
//! independent grids can be in flight simultaneously —
//! [`GridPool::launch_on`]`(s, …)` targets stream `s` and only ever
//! synchronizes with other launches on the *same* stream. This is the
//! paper's Algorithm-3 asynchrony idea lifted one level up: instead of
//! relaxing the barrier between thread groups *inside* one grid, the
//! stream groups relax the barrier between whole grids, so N tenant jobs
//! no longer serialize on a single launch guard. [`GridPool::new`] builds
//! a single-stream pool and [`GridPool::launch`] targets stream 0, which
//! keeps the original one-grid-in-flight semantics for every existing
//! caller.
//!
//! Workers spin briefly before parking on a condvar so back-to-back
//! launches (100k iterations × 1–2 launches each) stay in the fast path,
//! like a GPU's hardware dispatch queue.
//!
//! ## Handoff protocol (why this is race-free)
//!
//! Each stream's job slot is an `UnsafeCell<JobDesc>` guarded by a
//! generation counter plus an active-worker count:
//!
//! * the launcher writes the slot **only while `active == 0`**, then bumps
//!   `generation` (Release);
//! * a worker that observes a new generation first increments `active`
//!   (SeqCst), **re-loads** the generation, and only then reads the slot —
//!   so every slot read is ordered after the Release bump that published
//!   it, and the launcher can never overwrite a slot a worker might still
//!   read (it waits for `active == 0` both before writing and before
//!   returning from `launch`);
//! * block-claim (`next_block`) and completion (`blocks_done`) counters
//!   are reset together with the slot write, so a worker can never claim a
//!   block of generation *N+1* while holding the descriptor of *N*: it is
//!   inside `active > 0` for the whole window, which blocks the reset.
//!
//! Streams never share any of this state — a worker belongs to exactly
//! one stream for its whole life — so the single-stream proof carries
//! over unchanged: concurrent `launch_on` calls on *different* streams
//! touch disjoint `Shared` instances, and calls on the *same* stream are
//! serialized by that stream's launch guard.

use crate::exec::sync::{self, AtomicBool, AtomicU64, AtomicUsize, Ordering, RacyCell};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// Per-block context handed to the kernel closure.
#[derive(Debug, Clone, Copy)]
pub struct BlockCtx {
    /// `blockIdx.x`.
    pub block_id: usize,
    /// `gridDim.x`.
    pub num_blocks: usize,
    /// Which pool worker is running this block. Dedicated workers are
    /// globally unique across streams (`0..workers`); the thread calling
    /// `launch_on(s, …)` itself participates as id `workers() + s`, so
    /// per-worker scratch must be sized `workers() + streams()`.
    pub worker_id: usize,
}

/// Type-erased job descriptor; the raw closure pointer is valid exactly
/// while its `launch` call is on the stack.
#[derive(Clone, Copy)]
struct JobDesc {
    func: *const (dyn Fn(BlockCtx) + Sync),
    blocks: usize,
}

// SAFETY: the pointee is Sync and the handoff protocol (module docs)
// guarantees it is never dereferenced outside its launch window.
unsafe impl Send for JobDesc {}

struct Shared {
    /// Bumped once per launch (Release); workers detect work by comparing.
    generation: AtomicU64,
    /// Written by the launcher only while `active == 0`.
    job: RacyCell<Option<JobDesc>>,
    /// Next block index to claim.
    next_block: AtomicUsize,
    /// Blocks finished in the current generation.
    blocks_done: AtomicUsize,
    /// Workers currently between registration and deregistration.
    active: AtomicUsize,
    shutdown: AtomicBool,
    idle: Mutex<()>,
    work_cv: Condvar,
    /// Spin budget before yielding/parking. Spinning only pays when the
    /// waiters and the workers run on *different* cores; on an
    /// oversubscribed (or single-core) host a spinning waiter burns the
    /// exact timeslice the worker needs, so the budget drops to ~0 and
    /// every wait yields immediately.
    spin_rounds: u32,
}

// SAFETY: see module-level handoff protocol.
unsafe impl Send for Shared {}
unsafe impl Sync for Shared {}

/// One stream group: its shared handoff state, its dedicated workers,
/// and the guard serializing launches *on this stream only*.
struct StreamState {
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<()>>,
    launch_guard: Mutex<()>,
    /// Dedicated workers in this group (excluding the helping launcher).
    workers: usize,
}

/// A fixed set of persistent OS-thread workers executing grid launches,
/// partitioned into one or more concurrent streams.
///
/// Launches on one stream are serialized (one grid in flight per stream,
/// like a CUDA stream); launches on different streams run concurrently.
/// Kernels must not launch nested grids on the same stream.
pub struct GridPool {
    streams: Vec<StreamState>,
    workers: usize,
}

/// Spin budget when cores are plentiful. Under Miri every spin iteration
/// is interpreted, so the budget collapses to "yield immediately".
const SPIN_ROUNDS_PARALLEL: u32 = if cfg!(miri) { 4 } else { 20_000 };
/// Spin budget when the pool (workers + launchers) oversubscribes the
/// machine — effectively "yield immediately".
const SPIN_ROUNDS_OVERSUB: u32 = 16;

#[inline]
fn spin_wait<F: Fn() -> bool>(budget: u32, cond: F) {
    let mut spins = 0u32;
    while !cond() {
        spins += 1;
        if spins < budget {
            sync::spin_loop();
        } else {
            std::thread::yield_now();
        }
    }
}

impl GridPool {
    /// Single-stream pool with `workers` OS threads (0 = machine
    /// default).
    pub fn new(workers: usize) -> Self {
        Self::with_streams(workers, 1)
    }

    /// Pool with `workers` OS threads (0 = machine default, the single
    /// source of that rule) split across `streams` disjoint groups
    /// (clamped to ≥ 1). Workers are distributed as evenly as possible;
    /// when `workers < streams` the surplus streams get no dedicated
    /// workers and execute entirely on their launching thread (which
    /// always helps drain its grid anyway).
    pub fn with_streams(workers: usize, streams: usize) -> Self {
        let n_streams = streams.max(1);
        let cores = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        let workers = if workers == 0 { cores } else { workers };
        // Workers plus the (up to) one helping launcher per stream must
        // fit in the cores for spinning to be productive.
        let spin_rounds = if cores >= workers + n_streams {
            SPIN_ROUNDS_PARALLEL
        } else {
            SPIN_ROUNDS_OVERSUB
        };
        let base = workers / n_streams;
        let rem = workers % n_streams;
        let mut next_worker_id = 0usize;
        let streams = (0..n_streams)
            .map(|s| {
                let group_workers = base + usize::from(s < rem);
                let shared = Arc::new(Shared {
                    generation: AtomicU64::new(0),
                    job: RacyCell::new(None),
                    next_block: AtomicUsize::new(0),
                    blocks_done: AtomicUsize::new(0),
                    active: AtomicUsize::new(0),
                    shutdown: AtomicBool::new(false),
                    idle: Mutex::new(()),
                    work_cv: Condvar::new(),
                    spin_rounds,
                });
                // On a single-core host extra worker threads only add
                // context switches: the launcher (which always helps)
                // executes the whole grid itself through the identical
                // protocol, so semantics and the per-launch overhead
                // structure are unchanged.
                let spawn_workers = if cores == 1 { 0 } else { group_workers };
                let handles = (0..spawn_workers)
                    .map(|_| {
                        let wid = next_worker_id;
                        next_worker_id += 1;
                        let sh = shared.clone();
                        std::thread::Builder::new()
                            .name(format!("cupso-grid-{s}-{wid}"))
                            .spawn(move || worker_loop(sh, wid))
                            .expect("spawn grid worker")
                    })
                    .collect();
                StreamState {
                    shared,
                    handles,
                    launch_guard: Mutex::new(()),
                    workers: group_workers,
                }
            })
            .collect();
        Self { streams, workers }
    }

    /// Single-stream pool sized to the machine (`available_parallelism`).
    pub fn with_default_parallelism() -> Self {
        Self::new(0)
    }

    /// Total dedicated pool workers across all streams (excluding the
    /// helping launcher threads).
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Number of concurrent stream groups.
    pub fn streams(&self) -> usize {
        self.streams.len()
    }

    /// Dedicated workers in stream `s` (may be 0 — the launcher still
    /// drains such a stream's grids by itself).
    pub fn stream_workers(&self, s: usize) -> usize {
        self.streams[s].workers
    }

    /// Run `kernel` once per block on stream 0 and wait for every block —
    /// the `<<<blocks>>>` launch plus its implicit barrier. On a
    /// single-stream pool this is exactly the original serialized-pool
    /// semantics.
    pub fn launch<F: Fn(BlockCtx) + Sync>(&self, blocks: usize, kernel: F) {
        self.launch_on(0, blocks, kernel);
    }

    /// Run `kernel` once per block on stream `stream % streams()` and wait
    /// for every block. Launches on the same stream serialize on that
    /// stream's guard; launches on different streams proceed concurrently.
    ///
    /// The modulo wrap lets callers pin work by an arbitrary index (e.g.
    /// a job number) without tracking the pool's stream count.
    pub fn launch_on<F: Fn(BlockCtx) + Sync>(&self, stream: usize, blocks: usize, kernel: F) {
        if blocks == 0 {
            return;
        }
        let s = stream % self.streams.len();
        let st = &self.streams[s];
        let _g = st.launch_guard.lock().unwrap();
        let sh = &*st.shared;
        // Quiesce: nobody may still be reading the previous descriptor.
        spin_wait(sh.spin_rounds, || sh.active.load(Ordering::SeqCst) == 0);
        let obj: &(dyn Fn(BlockCtx) + Sync + '_) = &kernel;
        let desc = JobDesc {
            // SAFETY: erasing the closure's lifetime is sound because
            // this function joins (waits for blocks_done == blocks and
            // active == 0) before `kernel` can drop.
            func: unsafe {
                std::mem::transmute::<
                    *const (dyn Fn(BlockCtx) + Sync + '_),
                    *const (dyn Fn(BlockCtx) + Sync + 'static),
                >(obj as *const _)
            },
            blocks,
        };
        // Publish slot + counters, then bump the generation.
        // SAFETY: `active == 0` (quiesce above) — no worker holds the slot.
        unsafe { *sh.job.write() = Some(desc) };
        sh.next_block.store(0, Ordering::Relaxed);
        sh.blocks_done.store(0, Ordering::Relaxed);
        sh.generation.fetch_add(1, Ordering::Release);
        if !st.handles.is_empty() {
            let _idle = sh.idle.lock().unwrap();
            sh.work_cv.notify_all();
        }
        // The launcher helps drain the grid, then waits for stragglers and
        // for every worker to deregister (so the descriptor can be
        // invalidated when `kernel` drops). Its worker id is unique per
        // stream so concurrent launchers never collide.
        run_blocks(sh, desc, self.workers + s);
        spin_wait(sh.spin_rounds, || {
            sh.blocks_done.load(Ordering::Acquire) >= blocks
        });
        spin_wait(sh.spin_rounds, || sh.active.load(Ordering::SeqCst) == 0);
    }
}

impl Drop for GridPool {
    fn drop(&mut self) {
        for st in &self.streams {
            st.shared.shutdown.store(true, Ordering::SeqCst);
            let _idle = st.shared.idle.lock().unwrap();
            st.shared.work_cv.notify_all();
        }
        for st in &mut self.streams {
            for h in st.handles.drain(..) {
                let _ = h.join();
            }
        }
    }
}

/// Claim and run blocks until the grid is drained.
fn run_blocks(shared: &Shared, desc: JobDesc, worker_id: usize) {
    // SAFETY: descriptor validity per the module handoff protocol.
    let kernel = unsafe { &*desc.func };
    loop {
        let b = shared.next_block.fetch_add(1, Ordering::Relaxed);
        if b >= desc.blocks {
            break;
        }
        kernel(BlockCtx {
            block_id: b,
            num_blocks: desc.blocks,
            worker_id,
        });
        shared.blocks_done.fetch_add(1, Ordering::Release);
    }
}

fn worker_loop(shared: Arc<Shared>, worker_id: usize) {
    let mut seen_gen = 0u64;
    loop {
        // Spin for a new generation; park after the spin budget.
        let mut spins = 0u32;
        loop {
            if shared.shutdown.load(Ordering::SeqCst) {
                return;
            }
            if shared.generation.load(Ordering::Acquire) != seen_gen {
                break;
            }
            spins += 1;
            if spins >= shared.spin_rounds {
                let mut idle = shared.idle.lock().unwrap();
                while !shared.shutdown.load(Ordering::SeqCst)
                    && shared.generation.load(Ordering::Acquire) == seen_gen
                {
                    idle = shared.work_cv.wait(idle).unwrap();
                }
                break;
            }
            sync::spin_loop();
        }
        if shared.shutdown.load(Ordering::SeqCst) {
            return;
        }
        // Register, then re-load the generation: the re-loaded value is the
        // job this worker runs, and the slot for it is fully published.
        shared.active.fetch_add(1, Ordering::SeqCst);
        let g = shared.generation.load(Ordering::SeqCst);
        if g != seen_gen {
            seen_gen = g;
            // SAFETY: slot for `g` is published (Release bump / SeqCst
            // load) and cannot be overwritten while `active > 0`.
            if let Some(desc) = unsafe { *shared.job.read() } {
                run_blocks(&shared, desc, worker_id);
            }
        }
        shared.active.fetch_sub(1, Ordering::SeqCst);
    }
}
