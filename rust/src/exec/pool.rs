//! Persistent worker pool with a grid-launch API.
//!
//! [`GridPool::launch`] is the `kernel<<<blocks, ...>>>()` analog: it hands
//! every logical *block* to a pool worker and returns only when all blocks
//! finished — the return edge is the inter-kernel implicit barrier. The
//! dispatch/join round trip is the CPU's "kernel launch overhead"; the
//! Queue-Lock engine's whole advantage (one launch per iteration instead
//! of two) is measured against exactly this cost, mirroring the paper.
//!
//! Workers spin briefly before parking on a condvar so back-to-back
//! launches (100k iterations × 1–2 launches each) stay in the fast path,
//! like a GPU's hardware dispatch queue.
//!
//! ## Handoff protocol (why this is race-free)
//!
//! The job slot is an `UnsafeCell<JobDesc>` guarded by a generation
//! counter plus an active-worker count:
//!
//! * the launcher writes the slot **only while `active == 0`**, then bumps
//!   `generation` (Release);
//! * a worker that observes a new generation first increments `active`
//!   (SeqCst), **re-loads** the generation, and only then reads the slot —
//!   so every slot read is ordered after the Release bump that published
//!   it, and the launcher can never overwrite a slot a worker might still
//!   read (it waits for `active == 0` both before writing and before
//!   returning from `launch`);
//! * block-claim (`next_block`) and completion (`blocks_done`) counters
//!   are reset together with the slot write, so a worker can never claim a
//!   block of generation *N+1* while holding the descriptor of *N*: it is
//!   inside `active > 0` for the whole window, which blocks the reset.

use std::cell::UnsafeCell;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// Per-block context handed to the kernel closure.
#[derive(Debug, Clone, Copy)]
pub struct BlockCtx {
    /// `blockIdx.x`.
    pub block_id: usize,
    /// `gridDim.x`.
    pub num_blocks: usize,
    /// Which pool worker is running this block. Workers are `0..workers`;
    /// the launching thread itself participates as id `workers`, so
    /// per-worker scratch must be sized `workers() + 1`.
    pub worker_id: usize,
}

/// Type-erased job descriptor; the raw closure pointer is valid exactly
/// while its `launch` call is on the stack.
#[derive(Clone, Copy)]
struct JobDesc {
    func: *const (dyn Fn(BlockCtx) + Sync),
    blocks: usize,
}

// SAFETY: the pointee is Sync and the handoff protocol (module docs)
// guarantees it is never dereferenced outside its launch window.
unsafe impl Send for JobDesc {}

struct Shared {
    /// Bumped once per launch (Release); workers detect work by comparing.
    generation: AtomicU64,
    /// Written by the launcher only while `active == 0`.
    job: UnsafeCell<Option<JobDesc>>,
    /// Next block index to claim.
    next_block: AtomicUsize,
    /// Blocks finished in the current generation.
    blocks_done: AtomicUsize,
    /// Workers currently between registration and deregistration.
    active: AtomicUsize,
    shutdown: AtomicBool,
    idle: Mutex<()>,
    work_cv: Condvar,
    /// Spin budget before yielding/parking. Spinning only pays when the
    /// waiters and the workers run on *different* cores; on an
    /// oversubscribed (or single-core) host a spinning waiter burns the
    /// exact timeslice the worker needs, so the budget drops to ~0 and
    /// every wait yields immediately.
    spin_rounds: u32,
}

// SAFETY: see module-level handoff protocol.
unsafe impl Send for Shared {}
unsafe impl Sync for Shared {}

/// A fixed set of persistent OS-thread workers executing grid launches.
///
/// Launches are serialized (one grid in flight, like a single CUDA
/// stream); kernels must not launch nested grids on the same pool.
pub struct GridPool {
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<()>>,
    launch_guard: Mutex<()>,
    workers: usize,
}

/// Spin budget when cores are plentiful.
const SPIN_ROUNDS_PARALLEL: u32 = 20_000;
/// Spin budget when the pool (workers + launcher) oversubscribes the
/// machine — effectively "yield immediately".
const SPIN_ROUNDS_OVERSUB: u32 = 16;

#[inline]
fn spin_wait<F: Fn() -> bool>(budget: u32, cond: F) {
    let mut spins = 0u32;
    while !cond() {
        spins += 1;
        if spins < budget {
            std::hint::spin_loop();
        } else {
            std::thread::yield_now();
        }
    }
}

impl GridPool {
    /// Pool with `workers` OS threads; 0 clamps to 1.
    pub fn new(workers: usize) -> Self {
        let workers = workers.max(1);
        let cores = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        // workers + the helping launcher must fit in the cores for
        // spinning to be productive.
        let spin_rounds = if cores > workers {
            SPIN_ROUNDS_PARALLEL
        } else {
            SPIN_ROUNDS_OVERSUB
        };
        let shared = Arc::new(Shared {
            generation: AtomicU64::new(0),
            job: UnsafeCell::new(None),
            next_block: AtomicUsize::new(0),
            blocks_done: AtomicUsize::new(0),
            active: AtomicUsize::new(0),
            shutdown: AtomicBool::new(false),
            idle: Mutex::new(()),
            work_cv: Condvar::new(),
            spin_rounds,
        });
        // On a single-core host extra worker threads only add context
        // switches: the launcher (which always helps) executes the whole
        // grid itself through the identical protocol, so semantics and
        // the per-launch overhead structure are unchanged.
        let spawn_workers = if cores == 1 { 0 } else { workers };
        let handles = (0..spawn_workers)
            .map(|wid| {
                let sh = shared.clone();
                std::thread::Builder::new()
                    .name(format!("cupso-grid-{wid}"))
                    .spawn(move || worker_loop(sh, wid))
                    .expect("spawn grid worker")
            })
            .collect();
        Self {
            shared,
            handles,
            launch_guard: Mutex::new(()),
            workers,
        }
    }

    /// Pool sized to the machine (`available_parallelism`).
    pub fn with_default_parallelism() -> Self {
        let n = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4);
        Self::new(n)
    }

    /// Number of pool workers (excluding the helping launcher thread).
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Run `kernel` once per block and wait for every block — the
    /// `<<<blocks>>>` launch plus its implicit barrier.
    pub fn launch<F: Fn(BlockCtx) + Sync>(&self, blocks: usize, kernel: F) {
        if blocks == 0 {
            return;
        }
        let _g = self.launch_guard.lock().unwrap();
        let sh = &*self.shared;
        // Quiesce: nobody may still be reading the previous descriptor.
        spin_wait(sh.spin_rounds, || sh.active.load(Ordering::SeqCst) == 0);
        // Erase the closure's lifetime: sound because this function joins
        // (waits for blocks_done == blocks and active == 0) before `kernel`
        // can drop.
        let obj: &(dyn Fn(BlockCtx) + Sync + '_) = &kernel;
        let desc = JobDesc {
            func: unsafe {
                std::mem::transmute::<
                    *const (dyn Fn(BlockCtx) + Sync + '_),
                    *const (dyn Fn(BlockCtx) + Sync + 'static),
                >(obj as *const _)
            },
            blocks,
        };
        // Publish slot + counters, then bump the generation.
        unsafe { *sh.job.get() = Some(desc) };
        sh.next_block.store(0, Ordering::Relaxed);
        sh.blocks_done.store(0, Ordering::Relaxed);
        sh.generation.fetch_add(1, Ordering::Release);
        if !self.handles.is_empty() {
            let _idle = sh.idle.lock().unwrap();
            sh.work_cv.notify_all();
        }
        // The launcher helps drain the grid, then waits for stragglers and
        // for every worker to deregister (so the descriptor can be
        // invalidated when `kernel` drops).
        run_blocks(sh, desc, self.workers);
        spin_wait(sh.spin_rounds, || {
            sh.blocks_done.load(Ordering::Acquire) >= blocks
        });
        spin_wait(sh.spin_rounds, || sh.active.load(Ordering::SeqCst) == 0);
    }
}

impl Drop for GridPool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        {
            let _idle = self.shared.idle.lock().unwrap();
            self.shared.work_cv.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Claim and run blocks until the grid is drained.
fn run_blocks(shared: &Shared, desc: JobDesc, worker_id: usize) {
    // SAFETY: descriptor validity per the module handoff protocol.
    let kernel = unsafe { &*desc.func };
    loop {
        let b = shared.next_block.fetch_add(1, Ordering::Relaxed);
        if b >= desc.blocks {
            break;
        }
        kernel(BlockCtx {
            block_id: b,
            num_blocks: desc.blocks,
            worker_id,
        });
        shared.blocks_done.fetch_add(1, Ordering::Release);
    }
}

fn worker_loop(shared: Arc<Shared>, worker_id: usize) {
    let mut seen_gen = 0u64;
    loop {
        // Spin for a new generation; park after the spin budget.
        let mut spins = 0u32;
        loop {
            if shared.shutdown.load(Ordering::SeqCst) {
                return;
            }
            if shared.generation.load(Ordering::Acquire) != seen_gen {
                break;
            }
            spins += 1;
            if spins >= shared.spin_rounds {
                let mut idle = shared.idle.lock().unwrap();
                while !shared.shutdown.load(Ordering::SeqCst)
                    && shared.generation.load(Ordering::Acquire) == seen_gen
                {
                    idle = shared.work_cv.wait(idle).unwrap();
                }
                break;
            }
            std::hint::spin_loop();
        }
        if shared.shutdown.load(Ordering::SeqCst) {
            return;
        }
        // Register, then re-load the generation: the re-loaded value is the
        // job this worker runs, and the slot for it is fully published.
        shared.active.fetch_add(1, Ordering::SeqCst);
        let g = shared.generation.load(Ordering::SeqCst);
        if g != seen_gen {
            seen_gen = g;
            // SAFETY: slot for `g` is published (Release bump / SeqCst
            // load) and cannot be overwritten while `active > 0`.
            if let Some(desc) = unsafe { *shared.job.get() } {
                run_blocks(&shared, desc, worker_id);
            }
        }
        shared.active.fetch_sub(1, Ordering::SeqCst);
    }
}
