//! CUDA-like execution substrate on OS threads.
//!
//! The paper's algorithms are expressed against the CUDA machine model:
//! a *grid* of *thread blocks*, per-block shared memory, `atomicAdd` /
//! `atomicCAS` / `atomicExch`, `__syncthreads()`, and an implicit barrier
//! between kernel launches. This module rebuilds that model on a multicore
//! CPU so the algorithms (`engine/`) can be written structurally verbatim:
//!
//! | CUDA | here |
//! |---|---|
//! | thread block | one logical block processed by a pool worker ([`GridPool::launch`]) |
//! | kernel launch + implicit inter-kernel barrier | [`GridPool::launch`] dispatch + join |
//! | CUDA stream (concurrent grids) | a pool stream group ([`GridPool::launch_on`]) |
//! | shared-memory queue + `atomicAdd` on the index | [`SharedQueue`] |
//! | `atomicCAS(lock,0,1)` / `atomicExch(lock,0)` spin lock (Algorithm 3) | [`SpinLock`] |
//! | atomic double updates | [`AtomicF64`] |
//!
//! The cost *structure* carries over: a launch costs a dispatch/join round
//! (the kernel-launch analog), queue appends serialize on an atomic index,
//! and the lock serializes global-best updates — exactly the overheads the
//! paper's Queue and Queue-Lock algorithms trade against reduction traffic.

mod atomic_f64;
mod pool;
mod queue;
mod spinlock;
pub mod sync;

pub use atomic_f64::AtomicF64;
pub use pool::{BlockCtx, GridPool};
pub use queue::SharedQueue;
pub use spinlock::SpinLock;

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn launch_covers_every_block_exactly_once() {
        let pool = GridPool::new(4);
        let hits: Vec<AtomicUsize> = (0..37).map(|_| AtomicUsize::new(0)).collect();
        pool.launch(37, |ctx| {
            hits[ctx.block_id].fetch_add(1, Ordering::Relaxed);
        });
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::Relaxed), 1, "block {i}");
        }
    }

    #[test]
    fn launch_joins_before_returning() {
        // The inter-kernel barrier: effects of launch N are visible to
        // launch N+1.
        let pool = GridPool::new(3);
        let data: Vec<AtomicUsize> = (0..16).map(|_| AtomicUsize::new(0)).collect();
        pool.launch(16, |ctx| {
            data[ctx.block_id].store(ctx.block_id + 1, Ordering::Release);
        });
        let sum = AtomicUsize::new(0);
        pool.launch(16, |ctx| {
            sum.fetch_add(data[ctx.block_id].load(Ordering::Acquire), Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), (1..=16).sum::<usize>());
    }

    #[test]
    fn sequential_launches_reuse_workers() {
        // Scaled down under Miri (each launch round trip is interpreted).
        const LAUNCHES: usize = if cfg!(miri) { 25 } else { 1000 };
        let pool = GridPool::new(2);
        let count = AtomicUsize::new(0);
        for _ in 0..LAUNCHES {
            pool.launch(2, |_| {
                count.fetch_add(1, Ordering::Relaxed);
            });
        }
        assert_eq!(count.load(Ordering::Relaxed), 2 * LAUNCHES);
    }

    #[test]
    fn streams_partition_workers_evenly() {
        let pool = GridPool::with_streams(5, 3);
        assert_eq!(pool.streams(), 3);
        assert_eq!(pool.workers(), 5);
        let per: Vec<usize> = (0..3).map(|s| pool.stream_workers(s)).collect();
        assert_eq!(per.iter().sum::<usize>(), 5);
        assert_eq!(per, vec![2, 2, 1]);
        // More streams than workers: surplus streams are launcher-only.
        let tiny = GridPool::with_streams(2, 4);
        assert_eq!(tiny.streams(), 4);
        assert_eq!((0..4).map(|s| tiny.stream_workers(s)).sum::<usize>(), 2);
    }

    #[test]
    fn launch_on_covers_every_block_on_every_stream() {
        let pool = GridPool::with_streams(4, 2);
        for s in 0..3 {
            // s = 2 wraps to stream 0 (modulo semantics).
            let hits: Vec<AtomicUsize> = (0..23).map(|_| AtomicUsize::new(0)).collect();
            pool.launch_on(s, 23, |ctx| {
                assert_eq!(ctx.num_blocks, 23);
                hits[ctx.block_id].fetch_add(1, Ordering::Relaxed);
            });
            for (i, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::Relaxed), 1, "stream {s} block {i}");
            }
        }
    }

    #[test]
    fn concurrent_launches_on_distinct_streams_make_progress() {
        // Two launches in flight at once: the stream-1 kernel blocks until
        // the stream-0 kernel has run, which can only terminate if the two
        // grids genuinely execute concurrently (a serialized pool would
        // deadlock here; the test then fails by timeout).
        use std::sync::atomic::AtomicBool;
        let pool = std::sync::Arc::new(GridPool::with_streams(2, 2));
        let flag = std::sync::Arc::new(AtomicBool::new(false));
        let p2 = pool.clone();
        let f2 = flag.clone();
        let waiter = std::thread::spawn(move || {
            p2.launch_on(1, 1, |_| {
                while !f2.load(Ordering::Acquire) {
                    std::thread::yield_now();
                }
            });
        });
        pool.launch_on(0, 1, |_| flag.store(true, Ordering::Release));
        waiter.join().unwrap();
        assert!(flag.load(Ordering::Relaxed));
    }

    #[test]
    fn launcher_worker_ids_are_disjoint_per_stream() {
        // Dedicated workers are 0..workers(); the launcher on stream s
        // participates as workers() + s, so scratch sized
        // workers() + streams() is always in bounds.
        let pool = GridPool::with_streams(3, 2);
        let cap = pool.workers() + pool.streams();
        let seen: Vec<AtomicUsize> = (0..cap).map(|_| AtomicUsize::new(0)).collect();
        for s in 0..2 {
            pool.launch_on(s, 64, |ctx| {
                assert!(ctx.worker_id < cap, "worker id {} out of bounds", ctx.worker_id);
                seen[ctx.worker_id].fetch_add(1, Ordering::Relaxed);
            });
        }
        let total: usize = seen.iter().map(|c| c.load(Ordering::Relaxed)).sum();
        assert_eq!(total, 128);
    }
}
