//! CUDA-like execution substrate on OS threads.
//!
//! The paper's algorithms are expressed against the CUDA machine model:
//! a *grid* of *thread blocks*, per-block shared memory, `atomicAdd` /
//! `atomicCAS` / `atomicExch`, `__syncthreads()`, and an implicit barrier
//! between kernel launches. This module rebuilds that model on a multicore
//! CPU so the algorithms (`engine/`) can be written structurally verbatim:
//!
//! | CUDA | here |
//! |---|---|
//! | thread block | one logical block processed by a pool worker ([`GridPool::launch`]) |
//! | kernel launch + implicit inter-kernel barrier | [`GridPool::launch`] dispatch + join |
//! | shared-memory queue + `atomicAdd` on the index | [`SharedQueue`] |
//! | `atomicCAS(lock,0,1)` / `atomicExch(lock,0)` spin lock (Algorithm 3) | [`SpinLock`] |
//! | atomic double updates | [`AtomicF64`] |
//!
//! The cost *structure* carries over: a launch costs a dispatch/join round
//! (the kernel-launch analog), queue appends serialize on an atomic index,
//! and the lock serializes global-best updates — exactly the overheads the
//! paper's Queue and Queue-Lock algorithms trade against reduction traffic.

mod atomic_f64;
mod pool;
mod queue;
mod spinlock;

pub use atomic_f64::AtomicF64;
pub use pool::{BlockCtx, GridPool};
pub use queue::SharedQueue;
pub use spinlock::SpinLock;

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn launch_covers_every_block_exactly_once() {
        let pool = GridPool::new(4);
        let hits: Vec<AtomicUsize> = (0..37).map(|_| AtomicUsize::new(0)).collect();
        pool.launch(37, |ctx| {
            hits[ctx.block_id].fetch_add(1, Ordering::Relaxed);
        });
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::Relaxed), 1, "block {i}");
        }
    }

    #[test]
    fn launch_joins_before_returning() {
        // The inter-kernel barrier: effects of launch N are visible to
        // launch N+1.
        let pool = GridPool::new(3);
        let data: Vec<AtomicUsize> = (0..16).map(|_| AtomicUsize::new(0)).collect();
        pool.launch(16, |ctx| {
            data[ctx.block_id].store(ctx.block_id + 1, Ordering::Release);
        });
        let sum = AtomicUsize::new(0);
        pool.launch(16, |ctx| {
            sum.fetch_add(data[ctx.block_id].load(Ordering::Acquire), Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), (1..=16).sum::<usize>());
    }

    #[test]
    fn sequential_launches_reuse_workers() {
        let pool = GridPool::new(2);
        let count = AtomicUsize::new(0);
        for _ in 0..1000 {
            pool.launch(2, |_| {
                count.fetch_add(1, Ordering::Relaxed);
            });
        }
        assert_eq!(count.load(Ordering::Relaxed), 2000);
    }
}
