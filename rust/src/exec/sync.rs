//! Synchronization facade for the unsafe hot path.
//!
//! Every exec primitive (and the scheduler's executor slots) performs its
//! atomic operations and lock-free `UnsafeCell` accesses through this
//! module instead of `std::sync::atomic` / `std::cell::UnsafeCell`
//! directly. The indirection buys one thing: a **model-checkable** build.
//!
//! * Default build (`--cfg cupso_model` absent): every item is a
//!   re-export or `#[repr(transparent)]` + `#[inline]` wrapper of the
//!   `std` original — the facade compiles out entirely, zero overhead
//!   (the zero-allocation and latency tiers run against this shape).
//! * `--cfg cupso_model`: the atomic types and [`RacyCell`] route every
//!   operation through [`crate::modelcheck`]'s virtual scheduler. Inside
//!   an exploration ([`crate::modelcheck::Explorer`]) each operation is a
//!   scheduling point and feeds the vector-clock data-race detector;
//!   outside an exploration (threads the explorer does not own) the
//!   instrumented ops fall through to the plain `std` operation, so the
//!   rest of the test suite still runs correctly under the cfg.
//!
//! Two deliberate model-mode deviations, both documented invariants of
//! the checker rather than bugs:
//!
//! * `compare_exchange_weak` never fails spuriously under the model
//!   (it lowers to the strong CAS). Spurious failure is a *scheduling*
//!   artifact, and the explorer owns the schedule — allowing it would
//!   make replayed schedules non-deterministic.
//! * `SeqCst` is modeled as `AcqRel` for happens-before purposes: the
//!   detector tracks release/acquire edges only, not the single total
//!   order. This under-approximates `SeqCst` (it can flag an SC-only
//!   protocol as racy) — none of the model-checked protocols rely on
//!   SC-only reasoning; see DESIGN.md §Concurrency correctness.

pub use std::sync::atomic::Ordering;

/// `true` when `order` has an acquire component (load side).
#[cfg(cupso_model)]
pub(crate) fn acquires(order: Ordering) -> bool {
    matches!(order, Ordering::Acquire | Ordering::AcqRel | Ordering::SeqCst)
}

/// `true` when `order` has a release component (store side).
#[cfg(cupso_model)]
pub(crate) fn releases(order: Ordering) -> bool {
    matches!(order, Ordering::Release | Ordering::AcqRel | Ordering::SeqCst)
}

// ---------------------------------------------------------------------------
// Default build: transparent re-exports.
// ---------------------------------------------------------------------------

#[cfg(not(cupso_model))]
mod imp {
    pub use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, AtomicUsize};

    /// A spin-loop hint. Under the model this is a voluntary-yield
    /// scheduling point (the explorer deprioritizes the spinner); here it
    /// is exactly `std::hint::spin_loop`.
    #[inline(always)]
    pub fn spin_loop() {
        std::hint::spin_loop();
    }

    /// An `UnsafeCell` whose accesses are visible to the race detector.
    ///
    /// [`read`](RacyCell::read) / [`write`](RacyCell::write) mark the
    /// access intent at the call site; in the default build both are the
    /// plain `UnsafeCell::get`. Dereferencing the returned pointer is the
    /// caller's obligation, exactly as with `UnsafeCell` — the protocols
    /// that make those dereferences sound are what `cupso_model` builds
    /// verify.
    #[repr(transparent)]
    pub struct RacyCell<T>(std::cell::UnsafeCell<T>);

    impl<T> RacyCell<T> {
        /// Cell holding `value`.
        #[inline(always)]
        pub const fn new(value: T) -> Self {
            Self(std::cell::UnsafeCell::new(value))
        }

        /// Raw pointer for a read of the protected data.
        #[inline(always)]
        pub fn read(&self) -> *mut T {
            self.0.get()
        }

        /// Raw pointer for a write of the protected data.
        #[inline(always)]
        pub fn write(&self) -> *mut T {
            self.0.get()
        }

        /// Consume the cell (requires ownership, hence quiescence).
        #[inline(always)]
        pub fn into_inner(self) -> T {
            self.0.into_inner()
        }
    }
}

// ---------------------------------------------------------------------------
// Model build: every op is a scheduling point + a happens-before event.
// ---------------------------------------------------------------------------

#[cfg(cupso_model)]
mod imp {
    use super::{acquires, releases, Ordering};
    use crate::modelcheck::runtime::{self, AtomicAccess};

    macro_rules! model_atomic_int {
        ($name:ident, $std:ty, $ty:ty) => {
            /// Model-routed atomic (see module docs). API-compatible with
            /// the `std` type for every operation the crate uses.
            pub struct $name {
                inner: $std,
            }

            impl $name {
                pub const fn new(v: $ty) -> Self {
                    Self {
                        inner: <$std>::new(v),
                    }
                }

                #[inline]
                fn addr(&self) -> usize {
                    self as *const Self as usize
                }

                #[inline]
                pub fn load(&self, order: Ordering) -> $ty {
                    runtime::atomic_access(self.addr(), || {
                        (
                            self.inner.load(order),
                            AtomicAccess::Load {
                                acq: acquires(order),
                            },
                        )
                    })
                }

                #[inline]
                pub fn store(&self, v: $ty, order: Ordering) {
                    runtime::atomic_access(self.addr(), || {
                        (
                            self.inner.store(v, order),
                            AtomicAccess::Store {
                                rel: releases(order),
                            },
                        )
                    })
                }

                #[inline]
                pub fn swap(&self, v: $ty, order: Ordering) -> $ty {
                    runtime::atomic_access(self.addr(), || {
                        (
                            self.inner.swap(v, order),
                            AtomicAccess::Rmw {
                                acq: acquires(order),
                                rel: releases(order),
                            },
                        )
                    })
                }

                #[inline]
                pub fn fetch_add(&self, v: $ty, order: Ordering) -> $ty {
                    runtime::atomic_access(self.addr(), || {
                        (
                            self.inner.fetch_add(v, order),
                            AtomicAccess::Rmw {
                                acq: acquires(order),
                                rel: releases(order),
                            },
                        )
                    })
                }

                #[inline]
                pub fn fetch_sub(&self, v: $ty, order: Ordering) -> $ty {
                    runtime::atomic_access(self.addr(), || {
                        (
                            self.inner.fetch_sub(v, order),
                            AtomicAccess::Rmw {
                                acq: acquires(order),
                                rel: releases(order),
                            },
                        )
                    })
                }

                #[inline]
                pub fn compare_exchange(
                    &self,
                    current: $ty,
                    new: $ty,
                    success: Ordering,
                    failure: Ordering,
                ) -> Result<$ty, $ty> {
                    runtime::atomic_access(self.addr(), || {
                        let res = self.inner.compare_exchange(current, new, success, failure);
                        let access = match &res {
                            Ok(_) => AtomicAccess::Rmw {
                                acq: acquires(success),
                                rel: releases(success),
                            },
                            Err(_) => AtomicAccess::Load {
                                acq: acquires(failure),
                            },
                        };
                        (res, access)
                    })
                }

                /// Lowers to the strong CAS: spurious failure would make
                /// a replayed schedule non-deterministic (module docs).
                #[inline]
                pub fn compare_exchange_weak(
                    &self,
                    current: $ty,
                    new: $ty,
                    success: Ordering,
                    failure: Ordering,
                ) -> Result<$ty, $ty> {
                    self.compare_exchange(current, new, success, failure)
                }

                /// Load + CAS loop, each iteration its own scheduling
                /// point (mirrors `std`'s observable behavior).
                #[inline]
                pub fn fetch_update<F: FnMut($ty) -> Option<$ty>>(
                    &self,
                    set_order: Ordering,
                    fetch_order: Ordering,
                    mut f: F,
                ) -> Result<$ty, $ty> {
                    let mut prev = self.load(fetch_order);
                    while let Some(next) = f(prev) {
                        match self.compare_exchange_weak(prev, next, set_order, fetch_order) {
                            Ok(old) => return Ok(old),
                            Err(seen) => prev = seen,
                        }
                    }
                    Err(prev)
                }

                #[allow(dead_code)]
                pub fn into_inner(self) -> $ty {
                    self.inner.into_inner()
                }
            }
        };
    }

    model_atomic_int!(AtomicU32, std::sync::atomic::AtomicU32, u32);
    model_atomic_int!(AtomicU64, std::sync::atomic::AtomicU64, u64);
    model_atomic_int!(AtomicUsize, std::sync::atomic::AtomicUsize, usize);

    /// Model-routed atomic bool (subset the crate uses).
    pub struct AtomicBool {
        inner: std::sync::atomic::AtomicBool,
    }

    impl AtomicBool {
        pub const fn new(v: bool) -> Self {
            Self {
                inner: std::sync::atomic::AtomicBool::new(v),
            }
        }

        #[inline]
        fn addr(&self) -> usize {
            self as *const Self as usize
        }

        #[inline]
        pub fn load(&self, order: Ordering) -> bool {
            runtime::atomic_access(self.addr(), || {
                (
                    self.inner.load(order),
                    AtomicAccess::Load {
                        acq: acquires(order),
                    },
                )
            })
        }

        #[inline]
        pub fn store(&self, v: bool, order: Ordering) {
            runtime::atomic_access(self.addr(), || {
                (
                    self.inner.store(v, order),
                    AtomicAccess::Store {
                        rel: releases(order),
                    },
                )
            })
        }

        #[inline]
        pub fn swap(&self, v: bool, order: Ordering) -> bool {
            runtime::atomic_access(self.addr(), || {
                (
                    self.inner.swap(v, order),
                    AtomicAccess::Rmw {
                        acq: acquires(order),
                        rel: releases(order),
                    },
                )
            })
        }
    }

    /// Voluntary-yield scheduling point (see the default build's docs).
    #[inline]
    pub fn spin_loop() {
        runtime::voluntary_yield();
    }

    /// Race-detected `UnsafeCell` (see the default build's docs): `read`
    /// / `write` record a happens-before-checked access event before
    /// handing out the pointer.
    pub struct RacyCell<T>(std::cell::UnsafeCell<T>);

    impl<T> RacyCell<T> {
        #[inline]
        pub const fn new(value: T) -> Self {
            Self(std::cell::UnsafeCell::new(value))
        }

        #[inline]
        fn addr(&self) -> usize {
            self.0.get() as usize
        }

        #[inline]
        pub fn read(&self) -> *mut T {
            runtime::data_read(self.addr());
            self.0.get()
        }

        #[inline]
        pub fn write(&self) -> *mut T {
            runtime::data_write(self.addr());
            self.0.get()
        }

        #[inline]
        pub fn into_inner(self) -> T {
            self.0.into_inner()
        }
    }
}

pub use imp::{spin_loop, AtomicBool, AtomicU32, AtomicU64, AtomicUsize, RacyCell};
