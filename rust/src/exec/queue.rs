//! The paper's shared-memory queue (Algorithm 2, lines 1–5):
//!
//! ```cuda
//! if (fit > gbest_fit) {
//!     unsigned qIdx = atomicAdd(&num, 1);
//!     bestFitQueue[qIdx] = fit;
//!     bestPosQueue[qIdx] = pos;
//! }
//! ```
//!
//! A fixed-capacity array with an atomic append cursor. Entries are pushed
//! *conditionally* (only on improvement — <0.1% of updates per the paper's
//! measurement, re-verified by `benches/ablation_queue_rarity.rs`), then a
//! single scanner (thread 0 of the block) linearly reduces the queue.

use crate::exec::sync::{AtomicU64, AtomicUsize, Ordering, RacyCell};

/// Fixed-capacity multi-producer append array (`atomicAdd` on the cursor).
///
/// `T: Copy` because entries are `(fit, particle index)` pairs — the paper
/// stores particle *indices* in the high-dimension case to bound shared
/// memory (§5.3), and we mirror that.
pub struct SharedQueue<T: Copy> {
    slots: Box<[RacyCell<T>]>,
    len: AtomicUsize,
    /// Lifetime pushes (instrumentation for the rarity ablation).
    total_pushes: AtomicU64,
}

// SAFETY: slot writes are claimed by unique indices from `len`; reads only
// happen after producers quiesce (enforced by &mut or the barrier in the
// engine between the push phase and the scan phase).
unsafe impl<T: Copy + Send> Send for SharedQueue<T> {}
unsafe impl<T: Copy + Send> Sync for SharedQueue<T> {}

impl<T: Copy + Default> SharedQueue<T> {
    /// Queue with `capacity` slots (the shared-memory allocation).
    pub fn new(capacity: usize) -> Self {
        let slots: Vec<RacyCell<T>> =
            (0..capacity).map(|_| RacyCell::new(T::default())).collect();
        Self {
            slots: slots.into_boxed_slice(),
            len: AtomicUsize::new(0),
            total_pushes: AtomicU64::new(0),
        }
    }
}

impl<T: Copy> SharedQueue<T> {
    /// `atomicAdd(&num, 1)` + slot write. Returns the claimed index, or
    /// `None` if the queue is full (the paper sizes the queue = block size
    /// so overflow is impossible there; we keep the check for smaller
    /// capacities and count the drop).
    ///
    /// The claim is a saturating CAS (`fetch_update`) rather than a plain
    /// `fetch_add` + back-out `fetch_sub`: the unconditional back-out
    /// could interleave with a concurrent `reset` (or with other
    /// overflowing pushers racing a reset) and drive `len` below zero —
    /// wrapping it to a huge value and corrupting every later claim. With
    /// the CAS claim, `len` is *never* written past `capacity`, so no
    /// compensation exists to race with.
    #[inline]
    pub fn push(&self, value: T) -> Option<usize> {
        let cap = self.slots.len();
        let idx = self
            .len
            .fetch_update(Ordering::AcqRel, Ordering::Acquire, |n| {
                (n < cap).then_some(n + 1)
            })
            .ok()?;
        // SAFETY: idx was uniquely claimed by the successful CAS.
        unsafe { *self.slots[idx].write() = value };
        self.total_pushes.fetch_add(1, Ordering::Relaxed);
        Some(idx)
    }

    /// Number of live entries.
    #[inline]
    pub fn len(&self) -> usize {
        self.len.load(Ordering::Acquire).min(self.slots.len())
    }

    /// True if no entries were pushed since the last reset — the common
    /// (>99.9%) case the queue algorithm optimizes for.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Capacity (shared-memory slots).
    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Scan the live entries (the thread-0 loop of Algorithm 2, lines
    /// 10–16). Caller must be the only accessor (post-quiescence), which
    /// the engines guarantee by scanning after the block's push phase.
    #[inline]
    pub fn scan<F: FnMut(&T)>(&self, mut f: F) {
        let n = self.len();
        for slot in &self.slots[..n] {
            // SAFETY: producers have quiesced; indices < len are written.
            f(unsafe { &*slot.read() });
        }
    }

    /// Reset for the next iteration (`num = 0`).
    #[inline]
    pub fn reset(&self) {
        self.len.store(0, Ordering::Release);
    }

    /// Lifetime number of successful pushes (rarity instrumentation).
    pub fn total_pushes(&self) -> u64 {
        self.total_pushes.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn push_scan_roundtrip() {
        let q: SharedQueue<(f64, usize)> = SharedQueue::new(8);
        assert!(q.is_empty());
        q.push((1.0, 10));
        q.push((3.0, 30));
        q.push((2.0, 20));
        assert_eq!(q.len(), 3);
        let mut seen = vec![];
        q.scan(|&(f, i)| seen.push((f, i)));
        assert_eq!(seen, vec![(1.0, 10), (3.0, 30), (2.0, 20)]);
    }

    #[test]
    fn reset_clears_logical_content() {
        let q: SharedQueue<u64> = SharedQueue::new(4);
        q.push(1);
        q.push(2);
        q.reset();
        assert!(q.is_empty());
        let mut count = 0;
        q.scan(|_| count += 1);
        assert_eq!(count, 0);
        assert_eq!(q.total_pushes(), 2); // instrumentation survives reset
    }

    #[test]
    fn overflow_is_reported_not_ub() {
        let q: SharedQueue<u64> = SharedQueue::new(2);
        assert!(q.push(1).is_some());
        assert!(q.push(2).is_some());
        assert!(q.push(3).is_none());
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn concurrent_overflow_never_corrupts_len() {
        // Many producers hammering a tiny queue: exactly `capacity` pushes
        // may win per round, len must never exceed (or wrap below)
        // capacity, and a reset between rounds must restore full capacity.
        // The old fetch_add/fetch_sub back-out underflowed `len` when
        // overflowing pushers raced a reset.
        const CAP: usize = 16;
        const THREADS: u64 = 8;
        const PER_THREAD: u64 = if cfg!(miri) { 40 } else { 2_000 };
        let q: Arc<SharedQueue<u64>> = Arc::new(SharedQueue::new(CAP));
        for round in 0..4u64 {
            let mut handles = vec![];
            for t in 0..THREADS {
                let q = q.clone();
                handles.push(std::thread::spawn(move || {
                    let mut wins = 0u64;
                    for i in 0..PER_THREAD {
                        if q.push(t * PER_THREAD + i).is_some() {
                            wins += 1;
                        }
                        // The *raw* counter (not the clamped len()) must
                        // never overshoot capacity: the old fetch_add +
                        // back-out claim left a window where it did, and
                        // a reset in that window wrapped it below zero.
                        let raw = q.len.load(Ordering::Acquire);
                        assert!(raw <= CAP, "raw len {raw} overshot capacity");
                    }
                    wins
                }));
            }
            let wins: u64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
            assert_eq!(wins, CAP as u64, "round {round}: exactly CAP claims win");
            assert_eq!(q.len(), CAP);
            let mut seen = 0;
            q.scan(|_| seen += 1);
            assert_eq!(seen, CAP);
            q.reset();
            assert!(q.is_empty(), "round {round}: reset must restore the queue");
        }
        assert_eq!(q.total_pushes(), 4 * CAP as u64);
    }

    #[test]
    fn concurrent_pushes_claim_unique_slots() {
        const PER_THREAD: usize = if cfg!(miri) { 50 } else { 8_000 };
        const TOTAL: usize = 8 * PER_THREAD;
        let q: Arc<SharedQueue<u64>> = Arc::new(SharedQueue::new(TOTAL));
        let mut handles = vec![];
        for t in 0..8u64 {
            let q = q.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..PER_THREAD as u64 {
                    q.push(t * PER_THREAD as u64 + i).unwrap();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(q.len(), TOTAL);
        let mut seen = vec![false; TOTAL];
        q.scan(|&v| {
            assert!(!seen[v as usize], "duplicate value {v}");
            seen[v as usize] = true;
        });
        assert!(seen.iter().all(|&s| s), "lost a slot");
    }
}
