//! The per-iteration cost estimator.

use super::DeviceSpec;
use crate::config::EngineKind;

/// Expected fraction of particle updates that improve on the incumbent
/// global best, amortized over a full run. The paper's §4.1 gives <0.1%
/// as an upper bound observed early in the search; averaged over the
/// 100k-iteration runs the tables use, improvements concentrate in the
/// first few hundred iterations, so the amortized rate is another order
/// of magnitude lower (re-measured by `benches/ablation_queue_rarity.rs`).
pub const IMPROVE_RATE: f64 = 5e-5;

/// Block size assumed by the model (the CUDA `blockDim.x`).
pub const BLOCK_SIZE: usize = 256;

/// One iteration's estimated cost, decomposed (all seconds).
#[derive(Debug, Clone, Default)]
pub struct CostBreakdown {
    /// Kernel-launch + implicit inter-kernel sync.
    pub launch_s: f64,
    /// ALU time of the step phase.
    pub compute_s: f64,
    /// DRAM traffic time of the step phase.
    pub memory_s: f64,
    /// Best-datum aggregation (reduction passes / queue atomics / lock).
    pub aggregation_s: f64,
}

impl CostBreakdown {
    /// Per-iteration total (busy time is max(compute, memory): the GPU
    /// overlaps ALU and DRAM; launches and aggregation serialize).
    pub fn per_iter(&self) -> f64 {
        self.launch_s + self.compute_s.max(self.memory_s) + self.aggregation_s
    }

    /// Whole-run total.
    pub fn total(&self, iters: u64) -> f64 {
        self.per_iter() * iters as f64
    }
}

/// Estimate one iteration of a GPU engine on `dev`.
///
/// `n` particles, `dim` dimensions. See module docs for the model; the
/// result is deterministic (expected-value model, no sampling).
pub fn estimate(dev: &DeviceSpec, engine: EngineKind, n: usize, dim: usize, _iters: u64) -> CostBreakdown {
    let blocks = n.div_ceil(BLOCK_SIZE) as f64;
    let nf = n as f64;
    let df = dim as f64;

    // --- step phase: compute and memory ---
    // Oversubscription: past the residency knee, extra waves of thread
    // blocks pay scheduling/cache pressure (smooth exponent, not a step —
    // 65 536 threads on 57 344 residency is only mildly over).
    let resident = dev.max_resident_threads as f64;
    let oversub = dev
        .oversub_penalty
        .powf((nf / resident - 1.0).max(0.0));
    // Latency hiding: with low occupancy each in-thread instruction costs
    // more (quadratic decay of the penalty toward full residency).
    let occ = (nf / resident).min(1.0);
    let latency_mult = 1.0 + (dev.latency_mult_max - 1.0) * (1.0 - occ) * (1.0 - occ);
    // Threads beyond the core count time-slice; below it, the per-thread
    // serial depth is the floor.
    let step_cycles = nf * (dev.step_cycles_fixed + dev.step_cycles_per_dim * df);
    let effective_lanes = (nf.min(dev.cuda_cores as f64)).max(1.0);
    let compute_s = dev.cycles_to_s(step_cycles / effective_lanes) * latency_mult * oversub;
    let bytes = nf * (dev.bytes_fixed + dev.bytes_per_dim * df);
    let memory_s = bytes / (dev.mem_bw_gbps * 1e9) * oversub;

    // --- aggregation + launches, per algorithm ---
    let passes_block = (BLOCK_SIZE.min(n) as f64).log2().ceil();
    let passes_grid = blocks.log2().ceil().max(1.0);
    // Blocks execute their reductions concurrently across SMs; depth
    // serializes, breadth parallelizes.
    let block_conc = (blocks / dev.sm_count as f64).ceil().max(1.0);
    let (launches, aggregation_s) = match engine {
        EngineKind::Reduction => {
            let block_red = dev.cycles_to_s(passes_block * dev.reduction_pass_cycles) * block_conc;
            let grid_red = dev.cycles_to_s(passes_grid * dev.reduction_pass_cycles);
            // aux-array traffic: one (fit, idx) pair per block, both ways.
            let aux = 2.0 * blocks * 16.0 / (dev.mem_bw_gbps * 1e9);
            (2.0, block_red + grid_red + aux)
        }
        EngineKind::LoopUnrolling => {
            let block_red = dev.cycles_to_s(passes_block * dev.unrolled_pass_cycles) * block_conc;
            let grid_red = dev.cycles_to_s(passes_grid * dev.unrolled_pass_cycles);
            let aux = 2.0 * blocks * 16.0 / (dev.mem_bw_gbps * 1e9);
            (2.0, block_red + grid_red + aux)
        }
        EngineKind::Queue => {
            // Conditional appends: expected pushes serialize on the block
            // atomic; the thread-0 scan touches only the pushed entries.
            let pushes = nf * IMPROVE_RATE;
            let atomics = dev.cycles_to_s(pushes * dev.atomic_cycles);
            let scan = dev.cycles_to_s(pushes * 8.0);
            let aux = 2.0 * blocks * 16.0 / (dev.mem_bw_gbps * 1e9);
            // 2nd kernel: single block scans `blocks` aux entries.
            let second = dev.cycles_to_s(blocks * 4.0);
            (2.0, atomics + scan + aux + second)
        }
        EngineKind::QueueLock => {
            let pushes = nf * IMPROVE_RATE;
            let atomics = dev.cycles_to_s(pushes * dev.atomic_cycles);
            let scan = dev.cycles_to_s(pushes * 8.0);
            // Lock: improving blocks serialize on the CAS; expected
            // lockers ≈ blocks × P(block improved) ≤ pushes.
            let lockers = (blocks * (1.0 - (1.0 - IMPROVE_RATE).powi(BLOCK_SIZE as i32))).min(pushes.max(1.0));
            let lock = dev.cycles_to_s(lockers * 2.0 * dev.atomic_cycles + lockers * df * 16.0);
            (1.0, atomics + scan + lock)
        }
        EngineKind::AsyncPersistent => {
            // Persistent kernel: launch cost amortizes to ~0 per iteration;
            // aggregation identical to Queue-Lock.
            let pushes = nf * IMPROVE_RATE;
            let atomics = dev.cycles_to_s(pushes * dev.atomic_cycles);
            let scan = dev.cycles_to_s(pushes * 8.0);
            let lockers =
                (blocks * (1.0 - (1.0 - IMPROVE_RATE).powi(BLOCK_SIZE as i32))).min(pushes.max(1.0));
            let lock = dev.cycles_to_s(lockers * 2.0 * dev.atomic_cycles + lockers * df * 16.0);
            (0.0, atomics + scan + lock)
        }
        EngineKind::SerialCpu | EngineKind::XlaSync | EngineKind::XlaAsync => {
            // Not GPU algorithms; priced as a single launch, no agg.
            (1.0, 0.0)
        }
    };

    CostBreakdown {
        launch_s: launches * dev.launch_overhead_us * 1e-6,
        compute_s,
        memory_s,
        aggregation_s,
    }
}

/// Serial CPU estimate for the whole run (the paper's "CPU" column).
pub fn estimate_cpu(dev: &DeviceSpec, n: usize, dim: usize, iters: u64) -> f64 {
    let cycles =
        n as f64 * (dev.step_cycles_fixed + dev.step_cycles_per_dim * dim as f64) * iters as f64;
    dev.cycles_to_s(cycles)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gpu() -> DeviceSpec {
        DeviceSpec::gtx_1080ti()
    }

    #[test]
    fn one_d_region_is_launch_bound() {
        // In the paper's flat region the per-iteration cost barely moves
        // with n — launches dominate.
        let c32 = estimate(&gpu(), EngineKind::QueueLock, 32, 1, 1).per_iter();
        let c2048 = estimate(&gpu(), EngineKind::QueueLock, 2048, 1, 1).per_iter();
        assert!(c2048 < 2.0 * c32, "flat region broken: {c32} vs {c2048}");
        let b = estimate(&gpu(), EngineKind::QueueLock, 2048, 1, 1);
        assert!(b.launch_s > b.compute_s.max(b.memory_s));
    }

    #[test]
    fn algorithm_ordering_matches_paper_1d() {
        for n in super::super::TABLE3_PARTICLES {
            let r = estimate(&gpu(), EngineKind::Reduction, n, 1, 1).per_iter();
            let u = estimate(&gpu(), EngineKind::LoopUnrolling, n, 1, 1).per_iter();
            let q = estimate(&gpu(), EngineKind::Queue, n, 1, 1).per_iter();
            let l = estimate(&gpu(), EngineKind::QueueLock, n, 1, 1).per_iter();
            assert!(l < q && q < u && u < r, "ordering broken at n={n}: {l} {q} {u} {r}");
        }
    }

    #[test]
    fn queue_lock_beats_reduction_by_about_2x() {
        // Paper headline: 2.2× vs the reduction baseline (1-D, n=2048).
        let r = estimate(&gpu(), EngineKind::Reduction, 2048, 1, 1).per_iter();
        let l = estimate(&gpu(), EngineKind::QueueLock, 2048, 1, 1).per_iter();
        let ratio = r / l;
        assert!(
            (1.8..=2.6).contains(&ratio),
            "Reduction/QueueLock ratio {ratio} outside the paper band"
        );
    }

    #[test]
    fn high_dim_is_memory_bound() {
        let b = estimate(&gpu(), EngineKind::Queue, 32768, 120, 1);
        assert!(b.memory_s > b.compute_s);
        assert!(b.memory_s > b.launch_s);
    }

    #[test]
    fn oversubscription_penalizes_131072() {
        // Per-particle efficiency must degrade past the residency knee.
        let t64k = estimate(&gpu(), EngineKind::QueueLock, 65536, 1, 1).per_iter();
        let t128k = estimate(&gpu(), EngineKind::QueueLock, 131072, 1, 1).per_iter();
        assert!(
            t128k > 2.0 * t64k,
            "no oversubscription knee: {t64k} -> {t128k}"
        );
    }

    #[test]
    fn cpu_estimate_is_linear_in_n_and_iters() {
        let dev = DeviceSpec::xeon_e3_1275();
        let a = estimate_cpu(&dev, 1000, 1, 1000);
        assert!((estimate_cpu(&dev, 2000, 1, 1000) / a - 2.0).abs() < 1e-9);
        assert!((estimate_cpu(&dev, 1000, 1, 2000) / a - 2.0).abs() < 1e-9);
    }
}
