//! Device descriptions for the cost model.

/// A priced execution platform. GPU fields describe the SIMT machine; the
/// CPU constructor only uses `clock_ghz` and the per-dimension cycle cost.
#[derive(Debug, Clone)]
pub struct DeviceSpec {
    /// Human name for table headers.
    pub name: &'static str,
    /// Streaming multiprocessors.
    pub sm_count: usize,
    /// Total scalar cores (SIMT lanes).
    pub cuda_cores: usize,
    /// Core clock in GHz.
    pub clock_ghz: f64,
    /// DRAM bandwidth in GB/s.
    pub mem_bw_gbps: f64,
    /// Kernel-launch + implicit-sync overhead per launch, in µs.
    pub launch_overhead_us: f64,
    /// Max resident threads across the device (oversubscription knee).
    pub max_resident_threads: usize,
    /// Multiplier applied per extra wave beyond residency (scheduling /
    /// cache pressure): reproduces the 131 072-particle slowdown.
    pub oversub_penalty: f64,
    /// Cycles for one serialized atomic RMW on shared/global memory.
    pub atomic_cycles: f64,
    /// Cycles per tree-reduction pass per block (compare+swap+sync).
    pub reduction_pass_cycles: f64,
    /// Same with the unrolled tail (no loop bookkeeping, warp-sync).
    pub unrolled_pass_cycles: f64,
    /// Per-particle per-dimension compute cycles of the PSO step
    /// (RNG draw + Eq.1 FMAs + clamp + fitness term + pbest merge).
    pub step_cycles_per_dim: f64,
    /// Fixed per-particle cycles independent of dimension.
    pub step_cycles_fixed: f64,
    /// Bytes of global traffic per particle per dimension (SoA layout).
    pub bytes_per_dim: f64,
    /// Fixed per-particle bytes (fitness, pbest_fit, queue predicate).
    pub bytes_fixed: f64,
    /// Coalescing efficiency multiplier for AoS layout (ablation).
    pub aos_penalty: f64,
    /// Latency multiplier at near-zero occupancy: with too few resident
    /// warps the SM cannot hide ALU/memory latency, so each in-thread
    /// instruction costs ~this factor more. Decays quadratically to 1 at
    /// full residency. (The paper's 120-D small-swarm rows — ~100 µs per
    /// iteration for 128 particles whose ideal depth is ~7 µs — pin this
    /// at ≈15 for the GTX-1080Ti.)
    pub latency_mult_max: f64,
}

impl DeviceSpec {
    /// The paper's GPU: GTX-1080Ti, 28 SMs, 3584 cores @1.48 GHz,
    /// 484 GB/s, CUDA 11.2. Launch overhead and pass costs calibrated
    /// against Table 3 (DESIGN.md §Plane C); everything else is the
    /// public datasheet.
    pub fn gtx_1080ti() -> Self {
        Self {
            name: "GTX-1080Ti (model)",
            sm_count: 28,
            cuda_cores: 3584,
            clock_ghz: 1.481,
            mem_bw_gbps: 484.0,
            launch_overhead_us: 1.9,
            max_resident_threads: 28 * 2048,
            oversub_penalty: 1.22,
            atomic_cycles: 120.0,
            reduction_pass_cycles: 150.0,
            unrolled_pass_cycles: 58.0,
            step_cycles_per_dim: 86.0,
            step_cycles_fixed: 24.0,
            // pos/vel/pbest_pos read+write + r-draws materialized: ~7
            // doubles moved per dim, plus ~3 per-particle scalars.
            bytes_per_dim: 7.0 * 8.0,
            bytes_fixed: 3.0 * 8.0,
            aos_penalty: 3.0,
            latency_mult_max: 15.0,
        }
    }

    /// The paper's CPU: Xeon E3-1275 v5 @3.6 GHz. The serial model only
    /// needs cycle costs; 112 cycles per particle-dimension-iteration is
    /// the constant the paper's own Table 3/5 CPU columns imply (0.100 s
    /// / (32 × 100k) at d=1 and 2.392 s / (128 × 5k × 120) at d=120 both
    /// give ≈112).
    pub fn xeon_e3_1275() -> Self {
        Self {
            name: "Xeon E3-1275 v5 (model)",
            sm_count: 1,
            cuda_cores: 1,
            clock_ghz: 3.6,
            mem_bw_gbps: 34.0,
            launch_overhead_us: 0.0,
            max_resident_threads: 8,
            oversub_penalty: 1.0,
            atomic_cycles: 20.0,
            reduction_pass_cycles: 0.0,
            unrolled_pass_cycles: 0.0,
            step_cycles_per_dim: 112.0,
            step_cycles_fixed: 10.0,
            bytes_per_dim: 7.0 * 8.0,
            bytes_fixed: 3.0 * 8.0,
            aos_penalty: 1.15,
            latency_mult_max: 1.0,
        }
    }

    /// Seconds for `cycles` of serialized work at this clock.
    #[inline]
    pub fn cycles_to_s(&self, cycles: f64) -> f64 {
        cycles / (self.clock_ghz * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn datasheet_values() {
        let g = DeviceSpec::gtx_1080ti();
        assert_eq!(g.cuda_cores, 3584);
        assert_eq!(g.sm_count, 28);
        assert!(g.mem_bw_gbps > 400.0);
        let c = DeviceSpec::xeon_e3_1275();
        assert_eq!(c.clock_ghz, 3.6);
    }

    #[test]
    fn cpu_calibration_reproduces_paper_cpu_column() {
        // The constant must reproduce both tables' CPU columns within 15%.
        let c = DeviceSpec::xeon_e3_1275();
        let t_1d = c.cycles_to_s((c.step_cycles_fixed + c.step_cycles_per_dim) * 32.0 * 100_000.0);
        assert!((t_1d - 0.100).abs() / 0.100 < 0.15, "1-D: {t_1d}");
        let t_120d = c.cycles_to_s(
            (c.step_cycles_fixed + c.step_cycles_per_dim * 120.0) * 128.0 * 5000.0,
        );
        assert!((t_120d - 2.392).abs() / 2.392 < 0.15, "120-D: {t_120d}");
    }

    #[test]
    fn cycles_to_s_scales_with_clock() {
        let g = DeviceSpec::gtx_1080ti();
        assert!((g.cycles_to_s(1.481e9) - 1.0).abs() < 1e-9);
    }
}
