//! Plane C — analytical GPU cost model.
//!
//! The paper's absolute numbers come from a GTX-1080Ti we don't have;
//! this module rebuilds them from first principles so every table can be
//! emitted with an **estimated-GPU** column next to the measured Plane-A
//! one. The model prices each algorithm's per-iteration work on a device
//! description ([`DeviceSpec`]):
//!
//! * kernel-launch overhead × launches (2 for the two-kernel algorithms,
//!   1 for the fused Queue-Lock) — the dominant term in the paper's flat
//!   1-D region (GPU times barely move from 32 to 2048 particles);
//! * compute: per-particle cycles (RNG + Eq.1/Eq.2 FMAs + fitness) spread
//!   over the CUDA cores;
//! * memory: SoA-coalesced global traffic over the DRAM bandwidth —
//!   the dominant term in the 120-D tables;
//! * aggregation: tree-reduction passes (with or without unrolling),
//!   conditional-queue atomics (rare by the <0.1% observation), the
//!   global CAS lock, aux-array traffic;
//! * oversubscription: beyond the resident-thread capacity, extra waves
//!   multiply the busy time — this reproduces the paper's speedup drop at
//!   131 072 particles (Table 4).
//!
//! Constants are calibrated once against Table 3 (see
//! `rust/tests/gpusim_tables.rs` for the acceptance bands) and then used
//! unchanged for Tables 4 and 5 — the model must *predict* those.

mod cost;
mod device;

pub use cost::{estimate, estimate_cpu, CostBreakdown};
pub use device::DeviceSpec;

use crate::config::EngineKind;

/// Paper Table 3/4 rows: 1-D particle sweep.
pub const TABLE3_PARTICLES: [usize; 7] = [32, 64, 128, 256, 512, 1024, 2048];

/// Paper Table 4 rows (1-D speedup sweep).
pub const TABLE4_PARTICLES: [usize; 11] = [
    128, 256, 512, 1024, 2048, 4096, 8192, 16384, 32768, 65536, 131072,
];

/// Paper Table 5 rows: (particles, iterations) for the 120-D sweep.
pub const TABLE5_ROWS: [(usize, u64); 11] = [
    (128, 5000),
    (256, 4000),
    (512, 3000),
    (1024, 2000),
    (2048, 2000),
    (4096, 1500),
    (8192, 1000),
    (16384, 1000),
    (32768, 1000),
    (65536, 1000),
    (131072, 800),
];

/// Reference values from the paper (for reporting paper-vs-model deltas).
pub mod paper {
    /// Table 3: (particles, cpu, reduction, unroll, queue, queue_lock) in
    /// seconds at 100k iterations.
    pub const TABLE3: [(usize, f64, f64, f64, f64, f64); 7] = [
        (32, 0.100, 0.413, 0.394, 0.368, 0.216),
        (64, 0.187, 0.419, 0.402, 0.368, 0.219),
        (128, 0.385, 0.447, 0.408, 0.371, 0.220),
        (256, 0.825, 0.455, 0.419, 0.371, 0.222),
        (512, 1.503, 0.467, 0.422, 0.391, 0.223),
        (1024, 3.042, 0.491, 0.439, 0.394, 0.227),
        (2048, 6.277, 0.508, 0.451, 0.409, 0.230),
    ];

    /// Table 4: (particles, cpu_s, queue_lock_s, speedup).
    pub const TABLE4: [(usize, f64, f64, f64); 11] = [
        (128, 0.385, 0.220, 1.75),
        (256, 0.825, 0.222, 3.71),
        (512, 1.503, 0.223, 6.73),
        (1024, 3.042, 0.227, 13.40),
        (2048, 6.277, 0.230, 27.29),
        (4096, 12.410, 0.265, 46.83),
        (8192, 23.850, 0.316, 75.47),
        (16384, 47.355, 0.417, 113.56),
        (32768, 94.629, 0.643, 147.16),
        (65536, 200.536, 1.026, 195.45),
        (131072, 378.671, 2.759, 137.24),
    ];

    /// Table 5: (particles, iterations, cpu_s, queue_s, speedup).
    pub const TABLE5: [(usize, u64, f64, f64, f64); 11] = [
        (128, 5000, 2.392, 0.487, 4.91),
        (256, 4000, 3.543, 0.384, 9.22),
        (512, 3000, 5.305, 0.288, 18.42),
        (1024, 2000, 7.078, 0.225, 31.45),
        (2048, 2000, 14.214, 0.255, 55.74),
        (4096, 1500, 21.593, 0.220, 98.15),
        (8192, 1000, 29.494, 0.191, 154.41),
        (16384, 1000, 59.125, 0.294, 201.10),
        (32768, 1000, 128.349, 0.570, 225.17),
        (65536, 1000, 237.933, 1.169, 203.53),
        (131072, 800, 379.820, 1.744, 217.78),
    ];
}

/// Estimated seconds for `(engine, n, dim, iters)` on the default
/// GTX-1080Ti + Xeon pair (convenience wrapper).
pub fn estimate_seconds(engine: EngineKind, n: usize, dim: usize, iters: u64) -> f64 {
    match engine {
        EngineKind::SerialCpu => estimate_cpu(&DeviceSpec::xeon_e3_1275(), n, dim, iters),
        _ => estimate(&DeviceSpec::gtx_1080ti(), engine, n, dim, iters).total(iters),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_constants_match_paper_shapes() {
        assert_eq!(TABLE3_PARTICLES.len(), paper::TABLE3.len());
        assert_eq!(TABLE4_PARTICLES.len(), paper::TABLE4.len());
        assert_eq!(TABLE5_ROWS.len(), paper::TABLE5.len());
        // Table 5 iteration counts are the paper's own.
        for ((n, it), (pn, pit, ..)) in TABLE5_ROWS.iter().zip(paper::TABLE5.iter()) {
            assert_eq!(n, pn);
            assert_eq!(it, pit);
        }
    }

    #[test]
    fn estimate_seconds_dispatches_cpu_vs_gpu() {
        let cpu = estimate_seconds(EngineKind::SerialCpu, 2048, 1, 100_000);
        let gpu = estimate_seconds(EngineKind::QueueLock, 2048, 1, 100_000);
        assert!(cpu > gpu, "cpu {cpu} must exceed gpu {gpu} at n=2048");
    }
}
