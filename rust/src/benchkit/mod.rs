//! Bench runner used by every `benches/*.rs` target.
//!
//! criterion is unavailable in this offline environment, so this module
//! provides the harness: warmup, R repetitions, the paper's trimmed-mean
//! estimator ([`crate::metrics::Summary::trimmed_mean`]), and scale
//! control. The paper runs each configuration 10 times and drops min/max
//! (§6.1); `BenchConfig::paper()` reproduces that protocol, while the
//! default CI scale keeps `cargo bench` minutes-fast.
//!
//! Scale knobs (environment, so `cargo bench` needs no arg plumbing):
//! * `CUPSO_BENCH_SCALE=paper` — full paper workloads (100k iterations,
//!   up to 131072 particles). Expect minutes-to-hours like the original.
//! * `CUPSO_BENCH_SCALE=ci` (default) — iteration counts divided so every
//!   table finishes in a few minutes while preserving the comparisons.
//! * `CUPSO_BENCH_REPS=n` — override repetition count.
//!
//! Unrecognized values of either variable abort the bench loudly instead
//! of silently falling back to CI scale (see [`BenchConfig::from_env`]).
//!
//! Machine-readable output: set `CUPSO_BENCH_JSON=<path>` and bench
//! targets additionally write a `BENCH_<name>.json` document (wall
//! times, derived metrics, config, git revision) — see [`json`].

pub mod json;

use crate::metrics::Summary;

/// Measurement protocol configuration.
#[derive(Debug, Clone)]
pub struct BenchConfig {
    /// Timed repetitions per configuration.
    pub reps: usize,
    /// Untimed warmup repetitions.
    pub warmup: usize,
    /// Iteration-count divisor vs the paper's workloads (1 = paper scale).
    pub iter_divisor: u64,
    /// Cap on particle-count sweeps (paper max 131072).
    pub max_particles: usize,
}

impl BenchConfig {
    /// The paper's protocol: 10 runs, trim min/max, full workloads.
    pub fn paper() -> Self {
        Self {
            reps: 10,
            warmup: 1,
            iter_divisor: 1,
            max_particles: 131_072,
        }
    }

    /// CI-scale: identical comparisons, ~50× smaller workloads.
    pub fn ci() -> Self {
        Self {
            reps: 5,
            warmup: 1,
            iter_divisor: 50,
            max_particles: 131_072,
        }
    }

    /// Resolve a scale name (`paper` | `ci` | `smoke`).
    pub fn from_scale(scale: &str) -> Option<Self> {
        match scale {
            "paper" => Some(Self::paper()),
            "ci" => Some(Self::ci()),
            "smoke" => Some(Self {
                reps: 2,
                warmup: 0,
                iter_divisor: 1000,
                max_particles: 8192,
            }),
            _ => None,
        }
    }

    /// Resolve from the environment (see module docs).
    ///
    /// An *unset* `CUPSO_BENCH_SCALE` defaults to CI scale, but a set,
    /// unrecognized value panics: a typo like `SCALE=papr` silently
    /// benchmarking 1/50th of the paper workload would produce numbers
    /// that look plausible and mean nothing.
    pub fn from_env() -> Self {
        let mut cfg = match std::env::var("CUPSO_BENCH_SCALE") {
            Ok(v) => Self::from_scale(&v).unwrap_or_else(|| {
                panic!("CUPSO_BENCH_SCALE={v:?} is not one of paper|ci|smoke")
            }),
            Err(_) => Self::ci(),
        };
        if let Ok(r) = std::env::var("CUPSO_BENCH_REPS") {
            cfg.reps = r
                .parse()
                .unwrap_or_else(|e| panic!("CUPSO_BENCH_REPS={r:?}: {e}"));
        }
        cfg
    }

    /// Scale a paper iteration count by the divisor (≥1 iteration).
    pub fn iters(&self, paper_iters: u64) -> u64 {
        (paper_iters / self.iter_divisor).max(1)
    }

    /// Scale factor back to paper iterations (for reporting extrapolated
    /// absolute times next to measured ones).
    pub fn scale_note(&self) -> String {
        if self.iter_divisor == 1 {
            "paper scale".to_string()
        } else {
            format!("iterations ÷{}", self.iter_divisor)
        }
    }
}

/// Run `f` under the protocol and summarize the measured seconds.
pub fn measure<F: FnMut() -> f64>(cfg: &BenchConfig, mut f: F) -> Summary {
    for _ in 0..cfg.warmup {
        let _ = f();
    }
    let samples: Vec<f64> = (0..cfg.reps.max(1)).map(|_| f()).collect();
    Summary::from_samples(&samples)
        .expect("bench samples are non-empty by construction (reps.max(1))")
}

/// Run a closure `reps` times, timing each run wholesale.
pub fn measure_timed<F: FnMut()>(cfg: &BenchConfig, mut f: F) -> Summary {
    measure(cfg, || {
        let sw = crate::metrics::Stopwatch::start();
        f();
        sw.elapsed_s()
    })
}

/// Where bench CSV outputs land (`target/bench-results`).
pub fn results_dir() -> std::path::PathBuf {
    let dir = std::path::PathBuf::from("target/bench-results");
    std::fs::create_dir_all(&dir).ok();
    dir
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_protocol_matches_section_6_1() {
        let p = BenchConfig::paper();
        assert_eq!(p.reps, 10);
        assert_eq!(p.iter_divisor, 1);
        assert_eq!(p.iters(100_000), 100_000);
    }

    #[test]
    fn from_scale_resolves_known_names_and_rejects_typos() {
        assert_eq!(BenchConfig::from_scale("paper").unwrap().iter_divisor, 1);
        assert_eq!(BenchConfig::from_scale("ci").unwrap().iter_divisor, 50);
        assert_eq!(BenchConfig::from_scale("smoke").unwrap().iter_divisor, 1000);
        // Typos must be rejected, not silently mapped to CI scale —
        // from_env turns this None into a panic.
        assert!(BenchConfig::from_scale("papr").is_none());
        assert!(BenchConfig::from_scale("PAPER").is_none());
        assert!(BenchConfig::from_scale("").is_none());
    }

    #[test]
    fn ci_scale_preserves_at_least_one_iteration() {
        let c = BenchConfig::ci();
        assert!(c.iters(10) >= 1);
        assert_eq!(c.iters(100_000), 2_000);
    }

    #[test]
    fn measure_collects_reps_samples() {
        let cfg = BenchConfig {
            reps: 4,
            warmup: 1,
            iter_divisor: 1,
            max_particles: 1,
        };
        let mut calls = 0;
        let s = measure(&cfg, || {
            calls += 1;
            calls as f64
        });
        assert_eq!(calls, 5); // 1 warmup + 4 timed
        assert_eq!(s.n(), 4);
    }
}
