//! Machine-readable bench output — the repo's perf trajectory format.
//!
//! Every bench target can publish its measurements as a `BENCH_<name>.json`
//! document via the `CUPSO_BENCH_JSON` environment variable:
//!
//! * unset — no JSON is written (stdout tables only, the old behavior);
//! * `CUPSO_BENCH_JSON=path/to/file.json` — write exactly there;
//! * `CUPSO_BENCH_JSON=some/dir` — write `some/dir/BENCH_<name>.json`.
//!
//! The document records the bench name, scale, repetition protocol, the
//! git revision the numbers were taken at, and one record per measured
//! configuration (label, config fields, wall-clock samples and derived
//! metrics). Serialization is a small hand-rolled writer — serde is
//! unavailable offline — emitting a stable, diff-friendly layout so
//! committed baselines (e.g. `BENCH_scheduler.json`) review like text.

use super::BenchConfig;
use std::path::PathBuf;

/// Escape a string for a JSON string literal.
fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Render an `f64` as a JSON value (non-finite values become `null` —
/// JSON has no NaN/∞).
fn number(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "null".to_string()
    }
}

/// One JSON object, built key by key (insertion order preserved).
#[derive(Default)]
pub struct JsonObj {
    parts: Vec<String>,
}

impl JsonObj {
    /// Empty object.
    pub fn new() -> Self {
        Self::default()
    }

    /// Add a string field.
    pub fn str(mut self, key: &str, value: &str) -> Self {
        self.parts
            .push(format!("\"{}\": \"{}\"", escape(key), escape(value)));
        self
    }

    /// Add a numeric field.
    pub fn num(mut self, key: &str, value: f64) -> Self {
        self.parts
            .push(format!("\"{}\": {}", escape(key), number(value)));
        self
    }

    /// Add an integer field.
    pub fn int(mut self, key: &str, value: u64) -> Self {
        self.parts.push(format!("\"{}\": {value}", escape(key)));
        self
    }

    /// Add an array-of-numbers field (e.g. the raw wall-time samples).
    pub fn nums(mut self, key: &str, values: &[f64]) -> Self {
        let body: Vec<String> = values.iter().map(|&v| number(v)).collect();
        self.parts
            .push(format!("\"{}\": [{}]", escape(key), body.join(", ")));
        self
    }

    fn render(&self, indent: &str) -> String {
        if self.parts.is_empty() {
            return "{}".to_string();
        }
        let inner = self
            .parts
            .iter()
            .map(|p| format!("{indent}  {p}"))
            .collect::<Vec<_>>()
            .join(",\n");
        format!("{{\n{inner}\n{indent}}}")
    }
}

/// A bench run's JSON document: shared metadata plus one record per
/// measured configuration.
pub struct BenchJson {
    bench: String,
    scale: String,
    reps: usize,
    iter_divisor: u64,
    git_rev: String,
    records: Vec<JsonObj>,
}

impl BenchJson {
    /// Start a document for bench `name` under the given protocol.
    pub fn new(name: &str, cfg: &BenchConfig) -> Self {
        Self {
            bench: name.to_string(),
            scale: std::env::var("CUPSO_BENCH_SCALE").unwrap_or_else(|_| "ci".to_string()),
            reps: cfg.reps,
            iter_divisor: cfg.iter_divisor,
            git_rev: git_rev(),
            records: Vec::new(),
        }
    }

    /// Append one measured configuration.
    pub fn push(&mut self, record: JsonObj) {
        self.records.push(record);
    }

    /// Render the whole document.
    pub fn render(&self) -> String {
        let records = self
            .records
            .iter()
            .map(|r| format!("    {}", r.render("    ")))
            .collect::<Vec<_>>()
            .join(",\n");
        format!(
            "{{\n  \"bench\": \"{}\",\n  \"scale\": \"{}\",\n  \"reps\": {},\n  \
             \"iter_divisor\": {},\n  \"git_rev\": \"{}\",\n  \"records\": [\n{}\n  ]\n}}\n",
            escape(&self.bench),
            escape(&self.scale),
            self.reps,
            self.iter_divisor,
            escape(&self.git_rev),
            records
        )
    }

    /// Write the document if `CUPSO_BENCH_JSON` is set (see the module
    /// docs for path resolution). Returns the path written, if any.
    pub fn emit(&self) -> std::io::Result<Option<PathBuf>> {
        let Some(raw) = std::env::var_os("CUPSO_BENCH_JSON") else {
            return Ok(None);
        };
        let raw = PathBuf::from(raw);
        let path = if raw.extension().is_some_and(|e| e == "json") {
            if let Some(parent) = raw.parent() {
                if !parent.as_os_str().is_empty() {
                    std::fs::create_dir_all(parent)?;
                }
            }
            raw
        } else {
            std::fs::create_dir_all(&raw)?;
            raw.join(format!("BENCH_{}.json", self.bench))
        };
        std::fs::write(&path, self.render())?;
        Ok(Some(path))
    }
}

/// The current git revision (short), or `"unknown"` outside a repo.
fn git_rev() -> String {
    std::process::Command::new("git")
        .args(["rev-parse", "--short", "HEAD"])
        .output()
        .ok()
        .filter(|o| o.status.success())
        .and_then(|o| String::from_utf8(o.stdout).ok())
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .unwrap_or_else(|| "unknown".to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn objects_render_in_insertion_order_with_escaping() {
        let obj = JsonObj::new()
            .str("label", "S=4 \"batch\"=1\n")
            .int("rounds", 2000)
            .num("per_round_ns", 1234.5)
            .num("bad", f64::NAN)
            .nums("samples", &[0.25, 0.5]);
        let s = obj.render("");
        assert!(s.contains("\"label\": \"S=4 \\\"batch\\\"=1\\n\""), "{s}");
        assert!(s.contains("\"rounds\": 2000"), "{s}");
        assert!(s.contains("\"per_round_ns\": 1234.5"), "{s}");
        assert!(s.contains("\"bad\": null"), "{s}");
        assert!(s.contains("\"samples\": [0.25, 0.5]"), "{s}");
        // Insertion order is preserved.
        assert!(s.find("label").unwrap() < s.find("rounds").unwrap());
    }

    #[test]
    fn document_renders_metadata_and_records() {
        let cfg = BenchConfig {
            reps: 3,
            warmup: 1,
            iter_divisor: 50,
            max_particles: 1,
        };
        let mut doc = BenchJson::new("unit", &cfg);
        doc.push(JsonObj::new().str("label", "a").int("n", 1));
        doc.push(JsonObj::new().str("label", "b").int("n", 2));
        let s = doc.render();
        assert!(s.contains("\"bench\": \"unit\""), "{s}");
        assert!(s.contains("\"reps\": 3"), "{s}");
        assert!(s.contains("\"iter_divisor\": 50"), "{s}");
        assert!(s.contains("\"git_rev\": "), "{s}");
        assert!(s.contains("\"label\": \"a\""), "{s}");
        assert!(s.contains("\"label\": \"b\""), "{s}");
        // Crude structural sanity: balanced braces and brackets.
        assert_eq!(
            s.matches('{').count(),
            s.matches('}').count(),
            "unbalanced braces:\n{s}"
        );
        assert_eq!(s.matches('[').count(), s.matches(']').count());
    }

    #[test]
    fn emit_writes_to_dir_and_explicit_file() {
        let dir = std::env::temp_dir().join("cupso-bench-json-unit");
        std::fs::remove_dir_all(&dir).ok();
        let cfg = BenchConfig::ci();
        let mut doc = BenchJson::new("emitter", &cfg);
        doc.push(JsonObj::new().str("label", "x"));
        // Unset: no write.
        std::env::remove_var("CUPSO_BENCH_JSON");
        assert_eq!(doc.emit().unwrap(), None);
        // Directory form.
        std::env::set_var("CUPSO_BENCH_JSON", &dir);
        let path = doc.emit().unwrap().expect("path written");
        assert_eq!(path, dir.join("BENCH_emitter.json"));
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("\"bench\": \"emitter\""));
        // Explicit-file form.
        let file = dir.join("custom.json");
        std::env::set_var("CUPSO_BENCH_JSON", &file);
        let path = doc.emit().unwrap().expect("path written");
        assert_eq!(path, file);
        assert!(file.exists());
        std::env::remove_var("CUPSO_BENCH_JSON");
        std::fs::remove_dir_all(&dir).ok();
    }
}
