//! Plane-A parallel PSO engines — the paper's four GPU algorithms mapped
//! onto the CUDA-like substrate of [`crate::exec`].
//!
//! All four share the same "1st kernel" body ([`common::step_block`]):
//! velocity/position update + fitness + pbest per particle. They differ
//! only in how the swarm's best datum is aggregated each iteration:
//!
//! | engine | aggregation | launches/iter |
//! |---|---|---|
//! | [`ReductionEngine`] | per-block tree reduction → aux arrays → 2nd-kernel tree reduction | 2 |
//! | [`ReductionEngine::unrolled`] | same, final levels unrolled (the "Loop Unrolling" column) | 2 |
//! | [`QueueEngine`] | conditional atomic-append queue (Algorithm 2) → aux arrays → 2nd-kernel scan | 2 |
//! | [`QueueLockEngine`] | queue + global CAS lock (Algorithm 3), kernels fused | 1 |
//!
//! Reduction, Loop-Unrolling and Queue are *bit-exact* equivalents of the
//! synchronous serial reference ([`crate::pso::serial_sync`]) — verified
//! by `rust/tests/engine_equivalence.rs`. Queue-Lock relaxes the
//! inter-block barrier exactly as the paper describes, so its trajectory
//! can deviate when several blocks improve concurrently (it remains
//! monotone and converges to the same quality; with a single block it is
//! bit-exact too).
//!
//! ## Execution model: prepare / step / finish
//!
//! Every engine is a **step-wise solver**: [`Engine::prepare`] allocates
//! the run's entire working set once (swarm state, aux arrays, queues,
//! scratch) and returns a [`Run`] handle; [`Run::step`] advances exactly
//! one PSO iteration and reports progress; [`Run::finish`] consumes the
//! handle into the final [`RunOutput`]. [`Engine::run`] is a convenience
//! loop over that API, so one-shot callers are untouched while the
//! [`crate::scheduler`] can multiplex many concurrent runs over one
//! shared [`crate::exec::GridPool`]. Because a `Run` owns all of its
//! mutable state, interleaving steps of different runs cannot perturb
//! any run's trajectory (see `rust/tests/scheduler_determinism.rs`).
//!
//! ## Checkpoint / restore
//!
//! A `Run` at a step boundary is grid-quiescent (every launch joined
//! before `step` returned), and the Philox streams are counter-based, so
//! [`Run::checkpoint`] can capture the *complete* run state as a
//! [`RunCheckpoint`]; [`Engine::restore`] (or the kind-dispatching
//! [`restore_with`]) turns it back into a live run — on any pool, any
//! stream. For the bit-exact engines the resumed trajectory and final
//! [`RunOutput`] are identical to the uninterrupted run, at *every*
//! suspension step (`rust/tests/checkpoint_resume.rs`). The Async
//! engine's relaxed intra-step semantics mean its checkpoints are merely
//! valid quiescent states, not replayable trajectories — documented in
//! [`AsyncEngine`].

mod async_persistent;
mod common;
mod pack;
mod queue;
mod queue_lock;
mod reduction;

pub use async_persistent::AsyncEngine;
pub use common::{GlobalBest, ParallelSettings};
pub use pack::PackedRun;
pub use queue::QueueEngine;
pub use queue_lock::QueueLockEngine;
pub use reduction::ReductionEngine;

use crate::checkpoint::{RunCheckpoint, RunKind};
use crate::config::EngineKind;
use crate::fitness::{Fitness, Objective};
use crate::pso::{PsoParams, RunOutput};
use anyhow::Result;

/// Progress report for one [`Run::step`].
#[derive(Debug, Clone, PartialEq)]
pub struct StepReport {
    /// Iterations completed so far (this step included).
    pub iter: u64,
    /// Global-best fitness after this step.
    pub gbest_fit: f64,
    /// Global-best position — populated when this step improved it; use
    /// [`Run::gbest_pos`] to read it at any other time.
    pub gbest_pos: Option<Vec<f64>>,
    /// Whether this step improved the global best.
    pub improved: bool,
    /// Whether the run's iteration budget (`params.max_iter`) is spent.
    pub done: bool,
}

/// A prepared, resumable PSO run: all per-run buffers are allocated, the
/// swarm is seeded, and each [`step`](Run::step) advances one iteration.
///
/// Stepping a finished run is a no-op that reports `done = true`, so
/// drivers may poll freely. Dropping a `Run` abandons the trajectory;
/// [`finish`](Run::finish) yields the same [`RunOutput`] the one-shot
/// [`Engine::run`] would have produced after the executed steps.
pub trait Run: Send {
    /// Iterations completed so far.
    fn iters_done(&self) -> u64;

    /// The run's iteration budget (`params.max_iter`).
    fn max_iter(&self) -> u64;

    /// Current global-best fitness.
    fn gbest_fit(&self) -> f64;

    /// Current global-best position (length = dim).
    fn gbest_pos(&self) -> Vec<f64>;

    /// Advance one PSO iteration (or report `done` if the budget is spent).
    fn step(&mut self) -> StepReport;

    /// Advance up to `k` iterations (stopping early at the budget) and
    /// report the state after the batch: `iter`/`gbest_fit`/`done` are
    /// those of the last executed step, while `improved` (and the
    /// accompanying `gbest_pos`) covers the *whole* batch — true if any
    /// step in it improved the global best. `k = 0` behaves like `k = 1`.
    ///
    /// The default loops over [`step`](Run::step) and is therefore
    /// trajectory-identical to manual stepping; engines may override it to
    /// amortize per-step overhead (e.g. one grid launch for the whole
    /// batch), as long as a batch of `k` steps stays within the engine's
    /// documented step semantics.
    fn step_many(&mut self, k: u64) -> StepReport {
        let mut report = self.step();
        let mut improved = report.improved;
        for _ in 1..k {
            if report.done {
                break;
            }
            report = self.step();
            improved |= report.improved;
        }
        report.improved = improved;
        if improved && report.gbest_pos.is_none() {
            // The global best is monotone, so the current position is the
            // one published by the batch's last improvement.
            report.gbest_pos = Some(self.gbest_pos());
        }
        report
    }

    /// Consume the run into its final output (valid after any number of
    /// steps — early termination simply reports fewer `iters`).
    fn finish(self: Box<Self>) -> RunOutput;

    /// Capture the run's complete state at the current step boundary.
    ///
    /// Always taken at a grid-quiescent point: `step`/`step_many` only
    /// return after every launched block joined, and `&mut self` stepping
    /// excludes a concurrent `&self` checkpoint, so the captured arrays
    /// are never mid-kernel. Restoring the checkpoint (same or different
    /// pool/stream/process) continues bit-identically for the bit-exact
    /// engines; see the module docs for the Async caveat.
    fn checkpoint(&self) -> RunCheckpoint;

    /// Consume the run into its checkpoint — the suspension path. Every
    /// engine overrides the default to MOVE its swarm arrays (and
    /// history) into the checkpoint instead of deep-copying them, so
    /// preempting a job costs O(1) heap traffic, not O(n·dim)
    /// (`rust/tests/zero_alloc.rs` enforces this). Semantically identical
    /// to `checkpoint()` followed by dropping the run.
    fn into_checkpoint(self: Box<Self>) -> RunCheckpoint {
        self.checkpoint()
    }
}

/// A PSO solver implementation (one of the paper's five columns).
pub trait Engine: Send {
    /// Column label (matches the paper's tables).
    fn name(&self) -> &'static str;

    /// Allocate and seed a run: swarm init + fitness seeding + every
    /// per-run buffer, so the steady-state [`Run::step`] allocates
    /// nothing beyond its improvement reports.
    fn prepare<'a>(
        &mut self,
        params: &PsoParams,
        fitness: &'a dyn Fitness,
        objective: Objective,
        seed: u64,
    ) -> Box<dyn Run + 'a>;

    /// Rebuild a live run from a checkpoint captured by
    /// [`Run::checkpoint`]. The checkpoint must have been produced by a
    /// run of this engine's kind (variant included — a Loop-Unrolling
    /// checkpoint does not restore on the plain Reduction engine), and
    /// must be structurally consistent; anything else is a loud error,
    /// never a silently-wrong run. The restored run continues from
    /// `ckpt.iter` with the identical RNG stream, swarm, global best,
    /// history and counters.
    fn restore<'a>(
        &mut self,
        ckpt: &RunCheckpoint,
        fitness: &'a dyn Fitness,
    ) -> Result<Box<dyn Run + 'a>>;

    /// Solve: run `params.max_iter` iterations and return the best datum.
    ///
    /// Default: drive [`Engine::prepare`] / [`Run::step`] to exhaustion.
    /// Bit-identical to stepping manually.
    fn run(
        &mut self,
        params: &PsoParams,
        fitness: &dyn Fitness,
        objective: Objective,
        seed: u64,
    ) -> RunOutput {
        let mut run = self.prepare(params, fitness, objective, seed);
        while !run.step().done {}
        run.finish()
    }
}

/// The serial Algorithm 1 as an [`Engine`] (the "CPU" column).
pub struct SerialEngine;

impl Engine for SerialEngine {
    fn name(&self) -> &'static str {
        "CPU"
    }

    fn prepare<'a>(
        &mut self,
        params: &PsoParams,
        fitness: &'a dyn Fitness,
        objective: Objective,
        seed: u64,
    ) -> Box<dyn Run + 'a> {
        Box::new(crate::pso::serial::SerialRun::new(
            params, fitness, objective, seed,
        ))
    }

    fn restore<'a>(
        &mut self,
        ckpt: &RunCheckpoint,
        fitness: &'a dyn Fitness,
    ) -> Result<Box<dyn Run + 'a>> {
        Ok(Box::new(crate::pso::serial::SerialRun::restore(
            ckpt, fitness,
        )?))
    }
}

/// Shared restore preamble: the checkpoint must carry the expected run
/// kind, be structurally consistent, and hold a non-empty swarm.
pub(crate) fn restore_guard(ckpt: &RunCheckpoint, expected: RunKind) -> Result<()> {
    if ckpt.kind != expected {
        anyhow::bail!(
            "cannot restore a {} checkpoint as a {} run",
            ckpt.kind,
            expected
        );
    }
    ckpt.validate()?;
    if ckpt.params.n == 0 {
        anyhow::bail!("cannot restore a checkpoint with an empty swarm");
    }
    Ok(())
}

/// Restore any checkpoint by its recorded kind: builds the matching
/// engine on `settings` (so the run can land on a different pool or
/// stream than it was suspended from — the scheduler's migration path)
/// and delegates to its [`Engine::restore`]. The synchronous serial
/// oracle, which is a run type but not a launcher engine, is dispatched
/// directly.
pub fn restore_with<'a>(
    ckpt: &RunCheckpoint,
    settings: ParallelSettings,
    fitness: &'a dyn Fitness,
) -> Result<Box<dyn Run + 'a>> {
    match ckpt.kind {
        RunKind::SerialSync => Ok(Box::new(crate::pso::serial_sync::SyncSerialRun::restore(
            ckpt, fitness,
        )?)),
        kind => {
            let engine_kind = kind
                .engine_kind()
                .expect("every non-oracle run kind maps to an engine kind");
            let mut engine = build_with(engine_kind, settings)
                .ok_or_else(|| anyhow::anyhow!("engine {engine_kind} cannot be restored"))?;
            engine.restore(ckpt, fitness)
        }
    }
}

/// Construct an engine by kind on its own pool (Plane-A kinds only; the
/// XLA kinds live in [`crate::coordinator`]).
pub fn build(kind: EngineKind, workers: usize) -> Option<Box<dyn Engine>> {
    build_with(kind, ParallelSettings::with_workers(workers))
}

/// Construct an engine by kind on the given settings — the entry point
/// the [`crate::scheduler`] uses so every job shares one [`GridPool`]
/// (see [`ParallelSettings::with_pool`]).
///
/// [`GridPool`]: crate::exec::GridPool
pub fn build_with(kind: EngineKind, settings: ParallelSettings) -> Option<Box<dyn Engine>> {
    match kind {
        EngineKind::SerialCpu => Some(Box::new(SerialEngine)),
        EngineKind::Reduction => Some(Box::new(ReductionEngine::new(settings))),
        EngineKind::LoopUnrolling => Some(Box::new(ReductionEngine::unrolled(settings))),
        EngineKind::Queue => Some(Box::new(QueueEngine::new(settings))),
        EngineKind::QueueLock => Some(Box::new(QueueLockEngine::new(settings))),
        EngineKind::AsyncPersistent => Some(Box::new(AsyncEngine::new(settings))),
        EngineKind::XlaSync | EngineKind::XlaAsync => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fitness::Cubic;

    #[test]
    fn build_covers_all_plane_a_kinds() {
        for kind in EngineKind::TABLE3 {
            let e = build(kind, 2).expect("plane-A engine");
            assert_eq!(e.name(), kind.label());
        }
        assert!(build(EngineKind::XlaSync, 2).is_none());
    }

    #[test]
    fn every_engine_solves_cubic_1d() {
        let params = PsoParams::paper_1d(128, 150);
        for kind in EngineKind::TABLE3 {
            let mut e = build(kind, 4).unwrap();
            let out = e.run(&params, &Cubic, Objective::Maximize, 1);
            assert!(
                out.gbest_fit > 890_000.0,
                "{}: gbest {}",
                e.name(),
                out.gbest_fit
            );
        }
    }

    #[test]
    fn stepwise_reports_are_consistent() {
        let params = PsoParams::paper_1d(64, 20);
        for kind in EngineKind::TABLE3 {
            let mut e = build(kind, 2).unwrap();
            let mut run = e.prepare(&params, &Cubic, Objective::Maximize, 3);
            assert_eq!(run.iters_done(), 0);
            assert_eq!(run.max_iter(), 20);
            let mut last_fit = run.gbest_fit();
            let mut steps = 0u64;
            loop {
                let rep = run.step();
                steps += 1;
                assert_eq!(rep.iter, steps, "{kind:?}");
                assert!(rep.gbest_fit >= last_fit, "{kind:?}: gbest worsened");
                assert_eq!(rep.improved, rep.gbest_pos.is_some(), "{kind:?}");
                last_fit = rep.gbest_fit;
                if rep.done {
                    break;
                }
            }
            assert_eq!(steps, 20);
            // Stepping past the budget is a no-op.
            let rep = run.step();
            assert!(rep.done);
            assert_eq!(rep.iter, 20);
            assert!(!rep.improved);
            let out = run.finish();
            assert_eq!(out.iters, 20);
            assert_eq!(out.gbest_fit, last_fit);
        }
    }

    #[test]
    fn early_finish_reports_partial_iters() {
        let params = PsoParams::paper_1d(64, 50);
        let mut e = build(EngineKind::Queue, 2).unwrap();
        let mut run = e.prepare(&params, &Cubic, Objective::Maximize, 9);
        for _ in 0..7 {
            run.step();
        }
        let out = run.finish();
        assert_eq!(out.iters, 7);
        assert_eq!(out.history.last().unwrap().0, 7);
        assert_eq!(out.counters.particle_updates, 64 * 7);
    }
}
