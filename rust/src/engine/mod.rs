//! Plane-A parallel PSO engines — the paper's four GPU algorithms mapped
//! onto the CUDA-like substrate of [`crate::exec`].
//!
//! All four share the same "1st kernel" body ([`common::step_block`]):
//! velocity/position update + fitness + pbest per particle. They differ
//! only in how the swarm's best datum is aggregated each iteration:
//!
//! | engine | aggregation | launches/iter |
//! |---|---|---|
//! | [`ReductionEngine`] | per-block tree reduction → aux arrays → 2nd-kernel tree reduction | 2 |
//! | [`ReductionEngine::unrolled`] | same, final levels unrolled (the "Loop Unrolling" column) | 2 |
//! | [`QueueEngine`] | conditional atomic-append queue (Algorithm 2) → aux arrays → 2nd-kernel scan | 2 |
//! | [`QueueLockEngine`] | queue + global CAS lock (Algorithm 3), kernels fused | 1 |
//!
//! Reduction, Loop-Unrolling and Queue are *bit-exact* equivalents of the
//! synchronous serial reference ([`crate::pso::serial_sync`]) — verified
//! by `rust/tests/engine_equivalence.rs`. Queue-Lock relaxes the
//! inter-block barrier exactly as the paper describes, so its trajectory
//! can deviate when several blocks improve concurrently (it remains
//! monotone and converges to the same quality; with a single block it is
//! bit-exact too).

mod async_persistent;
mod common;
mod queue;
mod queue_lock;
mod reduction;

pub use async_persistent::AsyncEngine;
pub use common::{GlobalBest, ParallelSettings};
pub use queue::QueueEngine;
pub use queue_lock::QueueLockEngine;
pub use reduction::ReductionEngine;

use crate::config::EngineKind;
use crate::fitness::{Fitness, Objective};
use crate::pso::{PsoParams, RunOutput};

/// A PSO solver implementation (one of the paper's five columns).
pub trait Engine: Send {
    /// Column label (matches the paper's tables).
    fn name(&self) -> &'static str;

    /// Solve: run `params.max_iter` iterations and return the best datum.
    fn run(
        &mut self,
        params: &PsoParams,
        fitness: &dyn Fitness,
        objective: Objective,
        seed: u64,
    ) -> RunOutput;
}

/// The serial Algorithm 1 as an [`Engine`] (the "CPU" column).
pub struct SerialEngine;

impl Engine for SerialEngine {
    fn name(&self) -> &'static str {
        "CPU"
    }

    fn run(
        &mut self,
        params: &PsoParams,
        fitness: &dyn Fitness,
        objective: Objective,
        seed: u64,
    ) -> RunOutput {
        crate::pso::serial::run(params, fitness, objective, seed)
    }
}

/// Construct an engine by kind (Plane-A kinds only; the XLA kinds live in
/// [`crate::coordinator`]).
pub fn build(kind: EngineKind, workers: usize) -> Option<Box<dyn Engine>> {
    let settings = ParallelSettings::with_workers(workers);
    match kind {
        EngineKind::SerialCpu => Some(Box::new(SerialEngine)),
        EngineKind::Reduction => Some(Box::new(ReductionEngine::new(settings))),
        EngineKind::LoopUnrolling => Some(Box::new(ReductionEngine::unrolled(settings))),
        EngineKind::Queue => Some(Box::new(QueueEngine::new(settings))),
        EngineKind::QueueLock => Some(Box::new(QueueLockEngine::new(settings))),
        EngineKind::AsyncPersistent => Some(Box::new(AsyncEngine::new(settings))),
        EngineKind::XlaSync | EngineKind::XlaAsync => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fitness::Cubic;

    #[test]
    fn build_covers_all_plane_a_kinds() {
        for kind in EngineKind::TABLE3 {
            let e = build(kind, 2).expect("plane-A engine");
            assert_eq!(e.name(), kind.label());
        }
        assert!(build(EngineKind::XlaSync, 2).is_none());
    }

    #[test]
    fn every_engine_solves_cubic_1d() {
        let params = PsoParams::paper_1d(128, 150);
        for kind in EngineKind::TABLE3 {
            let mut e = build(kind, 4).unwrap();
            let out = e.run(&params, &Cubic, Objective::Maximize, 1);
            assert!(
                out.gbest_fit > 890_000.0,
                "{}: gbest {}",
                e.name(),
                out.gbest_fit
            );
        }
    }
}
