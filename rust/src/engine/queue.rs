//! The Queue engine — Algorithm 2 (§4.1), the paper's first contribution.
//!
//! Per iteration:
//! 1. **1st kernel**: every block steps its particles; each particle whose
//!    fresh fitness beats the (unlocked, possibly stale) global best
//!    conditionally appends `(fit, idx)` to the block's shared-memory
//!    queue via `atomicAdd` (lines 1–5). Then "thread 0" scans the queue
//!    (lines 7–20) — almost always empty, the <0.1% observation — and
//!    writes the block best to the aux arrays.
//! 2. **2nd kernel**: a single block applies the same conditional-queue
//!    idea over the aux arrays to update the global best.
//!
//! Versus the Reduction engine the per-iteration cost drops from
//! `O(bs)` copies + `O(log bs)` reduction passes to a *predicate per
//! particle* — the queue is only touched on improvement.

use super::common::{step_block, GlobalBest, ParallelSettings, PerBlock, SharedSwarm, StepScratch};
use super::Engine;
use crate::exec::SharedQueue;
use crate::fitness::{Fitness, Objective};
use crate::pso::serial_sync::better_with_tie;
use crate::pso::{history_stride, Counters, PsoParams, RunOutput, SwarmState};
use crate::rng::PhiloxStream;

/// The Queue engine (two kernels, aux arrays, no global lock).
pub struct QueueEngine {
    settings: ParallelSettings,
}

impl QueueEngine {
    /// New engine on the given pool/geometry.
    pub fn new(settings: ParallelSettings) -> Self {
        Self { settings }
    }
}

impl Engine for QueueEngine {
    fn name(&self) -> &'static str {
        "Queue"
    }

    fn run(
        &mut self,
        params: &PsoParams,
        fitness: &dyn Fitness,
        objective: Objective,
        seed: u64,
    ) -> RunOutput {
        let stream = PhiloxStream::new(seed);
        let mut init = SwarmState::init(params, &stream);
        let (fit0, gi) = init.seed_fitness(fitness, objective);
        let gbest = GlobalBest::new(fit0, &init.position_of(gi));
        let state = SharedSwarm::new(init);

        let blocks = self.settings.blocks_for(params.n);
        // One shared-memory queue per block, sized to the block (§5.3:
        // store indices, not positions, to bound shared memory).
        let queues: Vec<SharedQueue<(f64, u32)>> = (0..blocks)
            .map(|_| SharedQueue::new(self.settings.block_size))
            .collect();
        let aux = PerBlock::from_fn(blocks, |_| (objective.worst(), u32::MAX));
        let step_scratch =
            PerBlock::from_fn(blocks, |_| StepScratch::new(self.settings.block_size));

        let stride = history_stride(params.max_iter);
        let mut history = Vec::new();
        let mut frozen = gbest.pos_vec();

        for iter in 0..params.max_iter {
            gbest.load_pos(&mut frozen);
            let frozen_ref = &frozen;
            let threshold = gbest.fit_relaxed();
            // ---- 1st kernel: step + conditional queue + thread-0 scan ----
            self.settings.pool.launch(blocks, |ctx| {
                let b = ctx.block_id;
                let (lo, hi) = self.settings.block_range(b, params.n);
                let q = &queues[b];
                q.reset();
                // SAFETY: this block only touches particles [lo, hi).
                let st = unsafe { state.get() };
                let ss = unsafe { step_scratch.get(b) };
                step_block(
                    st, lo, hi, frozen_ref, params, fitness, objective, &stream, iter, ss,
                );
                // Algorithm 2 lines 1–5: conditional atomic append.
                for k in 0..(hi - lo) {
                    let fit = ss.fit[k];
                    if objective.better(fit, threshold) {
                        q.push((fit, (lo + k) as u32));
                    }
                }
                // Lines 7–20: "thread 0" scans the queue, writes aux[b].
                let mut best = (objective.worst(), u32::MAX);
                q.scan(|&(f, i)| {
                    if better_with_tie(objective, f, i as usize, best.0, best.1 as usize) {
                        best = (f, i);
                    }
                });
                // SAFETY: aux[b] is this block's slot.
                unsafe { *aux.get(b) = best };
            });
            // ---- 2nd kernel: single block scans aux -> global best ----
            self.settings.pool.launch(1, |_| {
                let mut best = (objective.worst(), u32::MAX);
                for b in 0..blocks {
                    // SAFETY: 1st kernel joined; exclusive read.
                    let (f, i) = unsafe { *aux.get(b) };
                    if better_with_tie(objective, f, i as usize, best.0, best.1 as usize) {
                        best = (f, i);
                    }
                }
                if best.1 != u32::MAX {
                    let st = unsafe { state.get() };
                    gbest.update_exclusive(objective, best.0, &st.position_of(best.1 as usize));
                }
            });
            if iter % stride == 0 {
                history.push((iter, gbest.fit_relaxed()));
            }
        }
        history.push((params.max_iter, gbest.fit_relaxed()));

        let counters = Counters {
            particle_updates: params.n as u64 * params.max_iter,
            queue_pushes: queues.iter().map(|q| q.total_pushes()).sum(),
            gbest_updates: gbest.update_count(),
            ..Default::default()
        };
        RunOutput {
            gbest_fit: gbest.fit_relaxed(),
            gbest_pos: gbest.pos_vec(),
            iters: params.max_iter,
            history,
            counters,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fitness::Cubic;

    #[test]
    fn solves_cubic_and_counts_pushes() {
        let params = PsoParams::paper_1d(256, 100);
        let mut e = QueueEngine::new(ParallelSettings::with_workers(4));
        let out = e.run(&params, &Cubic, Objective::Maximize, 3);
        assert!(out.gbest_fit > 890_000.0, "gbest {}", out.gbest_fit);
        // The rarity premise: pushes must be a small fraction of updates.
        assert!(out.counters.queue_pushes > 0);
        let rate = out.counters.queue_push_rate();
        assert!(rate < 0.2, "push rate {rate} unexpectedly high");
        // Every gbest improvement implies at least one push that iteration.
        assert!(out.counters.queue_pushes >= out.counters.gbest_updates);
    }

    #[test]
    fn monotone_history() {
        let params = PsoParams::paper_120d(64, 60);
        let mut e = QueueEngine::new(ParallelSettings::with_workers(3));
        let out = e.run(&params, &Cubic, Objective::Maximize, 5);
        for w in out.history.windows(2) {
            assert!(w[1].1 >= w[0].1);
        }
    }
}
