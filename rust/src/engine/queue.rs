//! The Queue engine — Algorithm 2 (§4.1), the paper's first contribution.
//!
//! Per iteration:
//! 1. **1st kernel**: every block steps its particles; each particle whose
//!    fresh fitness beats the (unlocked, possibly stale) global best
//!    conditionally appends `(fit, idx)` to the block's shared-memory
//!    queue via `atomicAdd` (lines 1–5). Then "thread 0" scans the queue
//!    (lines 7–20) — almost always empty, the <0.1% observation — and
//!    writes the block best to the aux arrays.
//! 2. **2nd kernel**: a single block applies the same conditional-queue
//!    idea over the aux arrays to update the global best.
//!
//! Versus the Reduction engine the per-iteration cost drops from
//! `O(bs)` copies + `O(log bs)` reduction passes to a *predicate per
//! particle* — the queue is only touched on improvement.
//!
//! Step-wise: [`Engine::prepare`] allocates the queues/aux/scratch once
//! ([`QueueRun`]); each [`Run::step`] is the two launches above.

use super::common::{step_block, GlobalBest, ParallelSettings, PerBlock, SharedSwarm, StepScratch};
use super::{restore_guard, Engine, Run, StepReport};
use crate::checkpoint::{RunCheckpoint, RunKind, VERSION};
use crate::exec::SharedQueue;
use crate::fitness::{Fitness, Objective};
use crate::pso::serial_sync::better_with_tie;
use crate::pso::{history_capacity, history_stride, Counters, PsoParams, RunOutput, SwarmState};
use crate::rng::PhiloxStream;
use anyhow::Result;

/// The Queue engine (two kernels, aux arrays, no global lock).
pub struct QueueEngine {
    settings: ParallelSettings,
}

impl QueueEngine {
    /// New engine on the given pool/geometry.
    pub fn new(settings: ParallelSettings) -> Self {
        Self { settings }
    }

    /// Allocate queues/aux/scratch around an existing state — shared by
    /// `prepare` and `restore` so the two paths cannot drift. The queues
    /// start empty either way (they are reset at the top of every step);
    /// `push_base` carries pushes counted before a suspension.
    #[allow(clippy::too_many_arguments)]
    fn assemble<'a>(
        &self,
        params: &PsoParams,
        fitness: &'a dyn Fitness,
        objective: Objective,
        seed: u64,
        swarm: SwarmState,
        gbest: GlobalBest,
        mut history: Vec<(u64, f64)>,
        iter: u64,
        push_base: u64,
    ) -> QueueRun<'a> {
        history.reserve(history_capacity(params.max_iter).saturating_sub(history.len()));
        let state = SharedSwarm::new(swarm);
        let blocks = self.settings.blocks_for(params.n);
        // One shared-memory queue per block, sized to the block (§5.3:
        // store indices, not positions, to bound shared memory).
        let queues: Vec<SharedQueue<(f64, u32)>> = (0..blocks)
            .map(|_| SharedQueue::new(self.settings.block_size))
            .collect();
        let aux = PerBlock::from_fn(blocks, |_| (objective.worst(), u32::MAX));
        let step_scratch =
            PerBlock::from_fn(blocks, |_| StepScratch::new(self.settings.block_size));

        let frozen = gbest.pos_vec();
        QueueRun {
            params: params.clone(),
            fitness,
            objective,
            settings: self.settings.clone(),
            seed,
            stream: PhiloxStream::new(seed),
            state,
            gbest,
            queues,
            aux,
            step_scratch,
            push_base,
            frozen,
            stride: history_stride(params.max_iter),
            history,
            iter,
        }
    }
}

impl Engine for QueueEngine {
    fn name(&self) -> &'static str {
        "Queue"
    }

    fn prepare<'a>(
        &mut self,
        params: &PsoParams,
        fitness: &'a dyn Fitness,
        objective: Objective,
        seed: u64,
    ) -> Box<dyn Run + 'a> {
        let stream = PhiloxStream::new(seed);
        let mut init = SwarmState::init(params, &stream);
        let (fit0, gi) = init.seed_fitness(fitness, objective);
        let gbest = GlobalBest::new(fit0, &init.position_of(gi));
        Box::new(self.assemble(params, fitness, objective, seed, init, gbest, Vec::new(), 0, 0))
    }

    fn restore<'a>(
        &mut self,
        ckpt: &RunCheckpoint,
        fitness: &'a dyn Fitness,
    ) -> Result<Box<dyn Run + 'a>> {
        restore_guard(ckpt, RunKind::Queue)?;
        let gbest = GlobalBest::restore(ckpt.gbest_fit, &ckpt.gbest_pos, ckpt.counters.gbest_updates);
        Ok(Box::new(self.assemble(
            &ckpt.params,
            fitness,
            ckpt.objective,
            ckpt.seed,
            ckpt.swarm.clone(),
            gbest,
            ckpt.history.clone(),
            ckpt.iter,
            ckpt.counters.queue_pushes,
        )))
    }
}

/// A prepared Queue run: swarm, per-block queues, aux arrays and scratch
/// allocated once, reused every step.
pub struct QueueRun<'a> {
    params: PsoParams,
    fitness: &'a dyn Fitness,
    objective: Objective,
    settings: ParallelSettings,
    seed: u64,
    stream: PhiloxStream,
    state: SharedSwarm,
    gbest: GlobalBest,
    queues: Vec<SharedQueue<(f64, u32)>>,
    aux: PerBlock<(f64, u32)>,
    step_scratch: PerBlock<StepScratch>,
    /// Queue pushes accumulated before the last restore (the live queues
    /// only count pushes since then).
    push_base: u64,
    frozen: Vec<f64>,
    stride: u64,
    history: Vec<(u64, f64)>,
    iter: u64,
}

impl Run for QueueRun<'_> {
    fn iters_done(&self) -> u64 {
        self.iter
    }

    fn max_iter(&self) -> u64 {
        self.params.max_iter
    }

    fn gbest_fit(&self) -> f64 {
        self.gbest.fit_relaxed()
    }

    fn gbest_pos(&self) -> Vec<f64> {
        self.gbest.pos_vec()
    }

    fn step(&mut self) -> StepReport {
        if self.iter >= self.params.max_iter {
            return StepReport {
                iter: self.iter,
                gbest_fit: self.gbest.fit_relaxed(),
                gbest_pos: None,
                improved: false,
                done: true,
            };
        }
        let iter = self.iter;
        let updates_before = self.gbest.update_count();
        self.gbest.load_pos(&mut self.frozen);
        {
            let settings = &self.settings;
            let params = &self.params;
            let fitness = self.fitness;
            let objective = self.objective;
            let stream = &self.stream;
            let state = &self.state;
            let step_scratch = &self.step_scratch;
            let queues = &self.queues;
            let aux = &self.aux;
            let gbest = &self.gbest;
            let frozen_ref = &self.frozen;
            let threshold = gbest.fit_relaxed();
            let blocks = settings.blocks_for(params.n);
            // ---- 1st kernel: step + conditional queue + thread-0 scan ----
            settings.launch(blocks, |ctx| {
                let b = ctx.block_id;
                let (lo, hi) = settings.block_range(b, params.n);
                let q = &queues[b];
                q.reset();
                // SAFETY: this block only touches particles [lo, hi).
                let st = unsafe { state.get() };
                let ss = unsafe { step_scratch.get(b) };
                step_block(
                    st, lo, hi, frozen_ref, params, fitness, objective, stream, iter, ss,
                );
                // Algorithm 2 lines 1–5: conditional atomic append.
                for k in 0..(hi - lo) {
                    let fit = ss.fit[k];
                    if objective.better(fit, threshold) {
                        q.push((fit, (lo + k) as u32));
                    }
                }
                // Lines 7–20: "thread 0" scans the queue, writes aux[b].
                let mut best = (objective.worst(), u32::MAX);
                q.scan(|&(f, i)| {
                    if better_with_tie(objective, f, i as usize, best.0, best.1 as usize) {
                        best = (f, i);
                    }
                });
                // SAFETY: aux[b] is this block's slot.
                unsafe { *aux.get(b) = best };
            });
            // ---- 2nd kernel: single block scans aux -> global best ----
            settings.launch(1, |_| {
                let mut best = (objective.worst(), u32::MAX);
                for b in 0..aux.len() {
                    // SAFETY: 1st kernel joined; exclusive read.
                    let (f, i) = unsafe { *aux.get(b) };
                    if better_with_tie(objective, f, i as usize, best.0, best.1 as usize) {
                        best = (f, i);
                    }
                }
                if best.1 != u32::MAX {
                    // SAFETY: read-only position access after the push
                    // phase quiesced (single scanner block).
                    let st = unsafe { state.get() };
                    gbest.update_exclusive(objective, best.0, |dst| {
                        st.position_into(best.1 as usize, dst)
                    });
                }
            });
        }
        self.iter += 1;
        if iter % self.stride == 0 {
            self.history.push((iter, self.gbest.fit_relaxed()));
        }
        let improved = self.gbest.update_count() > updates_before;
        StepReport {
            iter: self.iter,
            gbest_fit: self.gbest.fit_relaxed(),
            gbest_pos: improved.then(|| self.gbest.pos_vec()),
            improved,
            done: self.iter >= self.params.max_iter,
        }
    }

    fn finish(self: Box<Self>) -> RunOutput {
        let this = *self;
        let QueueRun {
            params,
            state,
            gbest,
            queues,
            push_base,
            mut history,
            iter,
            ..
        } = this;
        history.push((iter, gbest.fit_relaxed()));
        let swarm = state.into_inner();
        debug_assert_eq!(swarm.check_bounds(&params), Ok(()));
        let counters = Counters {
            particle_updates: params.n as u64 * iter,
            queue_pushes: push_base + queues.iter().map(|q| q.total_pushes()).sum::<u64>(),
            gbest_updates: gbest.update_count(),
            ..Default::default()
        };
        RunOutput {
            gbest_fit: gbest.fit_relaxed(),
            gbest_pos: gbest.pos_vec(),
            iters: iter,
            history,
            counters,
        }
    }

    fn checkpoint(&self) -> RunCheckpoint {
        // SAFETY: between steps every launched block has joined, and
        // `&mut self` stepping excludes this `&self` call, so the swarm is
        // quiescent and fully visible.
        let swarm = unsafe { self.state.get() }.clone();
        RunCheckpoint {
            version: VERSION,
            kind: RunKind::Queue,
            objective: self.objective,
            seed: self.seed,
            params: self.params.clone(),
            iter: self.iter,
            gbest_fit: self.gbest.fit_relaxed(),
            gbest_pos: self.gbest.pos_vec(),
            history: self.history.clone(),
            counters: Counters {
                particle_updates: self.params.n as u64 * self.iter,
                queue_pushes: self.push_base
                    + self.queues.iter().map(|q| q.total_pushes()).sum::<u64>(),
                gbest_updates: self.gbest.update_count(),
                ..Default::default()
            },
            swarm,
        }
    }

    fn into_checkpoint(self: Box<Self>) -> RunCheckpoint {
        // Suspension path: the run is being torn down, so the swarm and
        // history are MOVED into the checkpoint — no deep copy of the SoA
        // arrays (the zero-alloc suspension invariant, rust/tests/zero_alloc.rs).
        let this = *self;
        let counters = Counters {
            particle_updates: this.params.n as u64 * this.iter,
            queue_pushes: this.push_base
                + this.queues.iter().map(|q| q.total_pushes()).sum::<u64>(),
            gbest_updates: this.gbest.update_count(),
            ..Default::default()
        };
        RunCheckpoint {
            version: VERSION,
            kind: RunKind::Queue,
            objective: this.objective,
            seed: this.seed,
            iter: this.iter,
            gbest_fit: this.gbest.fit_relaxed(),
            gbest_pos: this.gbest.pos_vec(),
            history: this.history,
            counters,
            params: this.params,
            swarm: this.state.into_inner(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fitness::Cubic;

    #[test]
    fn solves_cubic_and_counts_pushes() {
        let params = PsoParams::paper_1d(256, 100);
        let mut e = QueueEngine::new(ParallelSettings::with_workers(4));
        let out = e.run(&params, &Cubic, Objective::Maximize, 3);
        assert!(out.gbest_fit > 890_000.0, "gbest {}", out.gbest_fit);
        // The rarity premise: pushes must be a small fraction of updates.
        assert!(out.counters.queue_pushes > 0);
        let rate = out.counters.queue_push_rate();
        assert!(rate < 0.2, "push rate {rate} unexpectedly high");
        // Every gbest improvement implies at least one push that iteration.
        assert!(out.counters.queue_pushes >= out.counters.gbest_updates);
    }

    #[test]
    fn monotone_history() {
        let params = PsoParams::paper_120d(64, 60);
        let mut e = QueueEngine::new(ParallelSettings::with_workers(3));
        let out = e.run(&params, &Cubic, Objective::Maximize, 5);
        for w in out.history.windows(2) {
            assert!(w[1].1 >= w[0].1);
        }
    }

    #[test]
    fn stepwise_reuses_buffers_across_steps() {
        // Two interleaved runs on the same engine must not share state:
        // prepare twice, step alternately, outputs equal two solo runs.
        let params = PsoParams::paper_1d(100, 30);
        let settings = ParallelSettings::with_workers(2);
        let solo_a = QueueEngine::new(settings.clone()).run(&params, &Cubic, Objective::Maximize, 1);
        let solo_b = QueueEngine::new(settings.clone()).run(&params, &Cubic, Objective::Maximize, 2);
        let mut engine = QueueEngine::new(settings);
        let mut ra = engine.prepare(&params, &Cubic, Objective::Maximize, 1);
        let mut rb = engine.prepare(&params, &Cubic, Objective::Maximize, 2);
        loop {
            let da = ra.step().done;
            let db = rb.step().done;
            if da && db {
                break;
            }
        }
        let a = ra.finish();
        let b = rb.finish();
        assert_eq!(a.gbest_fit, solo_a.gbest_fit);
        assert_eq!(a.history, solo_a.history);
        assert_eq!(b.gbest_fit, solo_b.gbest_fit);
        assert_eq!(b.history, solo_b.history);
    }
}
