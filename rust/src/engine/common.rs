//! Shared machinery for the parallel engines: block geometry, the shared
//! "1st kernel" body, the global-best cell, and disjoint per-block
//! storage.

use crate::exec::{AtomicF64, GridPool, SpinLock};
use crate::fitness::{Fitness, Objective};
use crate::pso::{PsoParams, SwarmState};
use crate::rng::PhiloxStream;
use std::cell::UnsafeCell;
use std::sync::atomic::Ordering;
use std::sync::Arc;

/// Pool + geometry configuration shared by the engines.
#[derive(Clone)]
pub struct ParallelSettings {
    /// The worker pool (shareable across engines so benches reuse threads).
    pub pool: Arc<GridPool>,
    /// Particles per logical block (the CUDA `blockDim.x`; paper-style 256).
    pub block_size: usize,
    /// Which pool stream every grid launch of these settings targets
    /// (wrapped modulo the pool's stream count). On a single-stream pool
    /// this is always stream 0, i.e. the original serialized semantics;
    /// the [`crate::scheduler`] pins each job to one stream so independent
    /// jobs launch concurrently.
    pub stream: usize,
}

impl ParallelSettings {
    /// Default block size, matching common CUDA practice for PPSO.
    pub const DEFAULT_BLOCK_SIZE: usize = 256;

    /// Settings with `workers` pool threads (0 = machine default) on a
    /// single-stream pool.
    pub fn with_workers(workers: usize) -> Self {
        Self::with_streams(workers, 1)
    }

    /// Settings with `workers` pool threads (0 = machine default; the
    /// pool owns that resolution) split into `streams` concurrent stream
    /// groups, targeting stream 0.
    pub fn with_streams(workers: usize, streams: usize) -> Self {
        Self {
            pool: Arc::new(GridPool::with_streams(workers, streams)),
            block_size: Self::DEFAULT_BLOCK_SIZE,
            stream: 0,
        }
    }

    /// Settings on an existing pool (targeting stream 0).
    pub fn with_pool(pool: Arc<GridPool>) -> Self {
        Self {
            pool,
            block_size: Self::DEFAULT_BLOCK_SIZE,
            stream: 0,
        }
    }

    /// Override the block size (geometry ablations).
    pub fn block_size(mut self, bs: usize) -> Self {
        self.block_size = bs.max(1);
        self
    }

    /// Pin every launch to pool stream `s % pool.streams()`.
    pub fn on_stream(mut self, s: usize) -> Self {
        self.stream = s % self.pool.streams();
        self
    }

    /// Launch a grid on the pinned stream — the engines' single entry to
    /// the pool, so a run's stream assignment is one field, not N call
    /// sites.
    #[inline]
    pub fn launch<F: Fn(crate::exec::BlockCtx) + Sync>(&self, blocks: usize, kernel: F) {
        self.pool.launch_on(self.stream, blocks, kernel);
    }

    /// Number of blocks covering `n` particles.
    pub fn blocks_for(&self, n: usize) -> usize {
        n.div_ceil(self.block_size)
    }

    /// Particle range `[lo, hi)` of block `b`.
    pub fn block_range(&self, b: usize, n: usize) -> (usize, usize) {
        let lo = b * self.block_size;
        let hi = ((b + 1) * self.block_size).min(n);
        (lo, hi)
    }
}

/// Swarm state shared across blocks. Blocks touch disjoint particle
/// columns, so `&mut` access per block is sound (the SoA arrays interleave
/// columns, but element indices `d*n + i` are disjoint for disjoint `i`).
pub(crate) struct SharedSwarm(UnsafeCell<SwarmState>);

// SAFETY: disjoint-column discipline per the type docs above.
unsafe impl Sync for SharedSwarm {}

impl SharedSwarm {
    pub fn new(state: SwarmState) -> Self {
        Self(UnsafeCell::new(state))
    }

    /// # Safety
    /// Caller must only touch particle columns of its own block while any
    /// other block may be live, and must not alias reads of columns being
    /// written elsewhere.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn get(&self) -> &mut SwarmState {
        // SAFETY: non-aliasing per this function's contract (caller stays
        // within its own block's columns).
        unsafe { &mut *self.0.get() }
    }

    /// Reclaim the swarm after all blocks quiesced (used by
    /// [`crate::engine::Run::finish`] to run invariant checks).
    pub fn into_inner(self) -> SwarmState {
        self.0.into_inner()
    }
}

/// Disjoint per-block storage: block `b` may mutate entry `b` while other
/// blocks mutate theirs.
pub(crate) struct PerBlock<T> {
    cells: Vec<UnsafeCell<T>>,
}

// SAFETY: one-block-per-entry discipline per the type docs above.
unsafe impl<T: Send> Sync for PerBlock<T> {}

impl<T> PerBlock<T> {
    pub fn from_fn<F: FnMut(usize) -> T>(n: usize, mut f: F) -> Self {
        Self {
            cells: (0..n).map(|i| UnsafeCell::new(f(i))).collect(),
        }
    }

    /// # Safety
    /// Each index must be accessed by at most one block at a time; reads
    /// of other blocks' entries require those blocks to have quiesced
    /// (e.g. after an inter-kernel barrier).
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn get(&self, i: usize) -> &mut T {
        // SAFETY: at most one live accessor per index, per this
        // function's contract.
        unsafe { &mut *self.cells[i].get() }
    }

    /// Number of per-block slots (= the grid's block count).
    pub fn len(&self) -> usize {
        self.cells.len()
    }

    /// Whether the grid has zero blocks (never for a seeded run; kept so
    /// `len` satisfies clippy's `len_without_is_empty`).
    pub fn is_empty(&self) -> bool {
        self.cells.is_empty()
    }
}

/// The global best datum.
///
/// `fit` is an atomic so the Queue engines can read the improvement
/// threshold without a lock (the paper reads `gbest_fit` unsynchronized in
/// Algorithm 2 line 1 — on the CPU that must be an atomic load to stay
/// defined). `pos` entries are atomics for the same reason: the fused
/// Queue-Lock kernel lets one block update the position while another is
/// still stepping against it, which is the paper's documented benign race
/// (per-element visibility, possible cross-dimension tearing — "no bad
/// side effect" in 1-D).
pub struct GlobalBest {
    fit: AtomicF64,
    pos: Vec<AtomicF64>,
    /// Serializes compound updates (Algorithm 3's lock).
    lock: SpinLock<()>,
    /// Reusable gather buffer for winning updates, so publishing an
    /// improvement allocates nothing (perf pass, EXPERIMENTS.md §Perf).
    /// Writers are exclusive by construction: `update_locked` touches it
    /// only under `lock`, `update_exclusive` only from the single-block
    /// 2nd kernel, and no engine mixes the two entry points.
    gather: UnsafeCell<Vec<f64>>,
    updates: std::sync::atomic::AtomicU64,
}

// SAFETY: every field but `gather` is atomic/lock; `gather` is only
// written by mutually-exclusive writers (see its field docs) and never
// read outside the writing call.
unsafe impl Sync for GlobalBest {}

impl GlobalBest {
    /// Initialize from the seeded swarm's best.
    pub fn new(fit: f64, pos: &[f64]) -> Self {
        Self::restore(fit, pos, 0)
    }

    /// Rebuild from a checkpoint: the best datum plus the improvement
    /// counter accumulated before suspension, so a resumed run's
    /// `gbest_updates` telemetry continues where it left off.
    pub fn restore(fit: f64, pos: &[f64], updates: u64) -> Self {
        Self {
            fit: AtomicF64::new(fit),
            pos: pos.iter().map(|&p| AtomicF64::new(p)).collect(),
            lock: SpinLock::new(()),
            gather: UnsafeCell::new(vec![0.0; pos.len()]),
            updates: std::sync::atomic::AtomicU64::new(updates),
        }
    }

    /// Unlocked threshold read (Algorithm 2 line 1).
    #[inline]
    pub fn fit_relaxed(&self) -> f64 {
        self.fit.load(Ordering::Relaxed)
    }

    /// Snapshot the position into `out` (relaxed per-element loads).
    #[inline]
    pub fn load_pos(&self, out: &mut [f64]) {
        for (o, p) in out.iter_mut().zip(&self.pos) {
            *o = p.load(Ordering::Relaxed);
        }
    }

    /// Snapshot as a fresh vec.
    pub fn pos_vec(&self) -> Vec<f64> {
        let mut v = vec![0.0; self.pos.len()];
        self.load_pos(&mut v);
        v
    }

    /// Algorithm 3 verbatim: take the CAS lock, re-check, update
    /// `(gbest_fit, gbest_pos)`, fence, release. `pos_src` gathers the
    /// candidate position into the internal scratch buffer only if the
    /// re-check passes (so losers don't pay the gather, and winners don't
    /// allocate).
    pub fn update_locked<F: FnOnce(&mut [f64])>(
        &self,
        objective: Objective,
        fit: f64,
        pos_src: F,
    ) -> bool {
        if !objective.better(fit, self.fit_relaxed()) {
            return false;
        }
        let _g = self.lock.lock();
        // Re-check under the lock (another block may have won the race).
        if !objective.better(fit, self.fit.load(Ordering::Acquire)) {
            return false;
        }
        // SAFETY: exclusive under `lock` (see the field docs).
        let pos = unsafe { &mut *self.gather.get() };
        pos_src(pos);
        for (slot, &p) in self.pos.iter().zip(pos.iter()) {
            slot.store(p, Ordering::Relaxed);
        }
        self.fit.store(fit, Ordering::Release);
        self.updates.fetch_add(1, Ordering::Relaxed);
        true
    }

    /// Exclusive (single-block 2nd kernel) update — no lock needed, but
    /// kept atomic so concurrent relaxed readers stay defined. `pos_src`
    /// gathers into the internal scratch only on acceptance; exclusivity
    /// of the caller (a single-block kernel) guards the scratch.
    pub fn update_exclusive<F: FnOnce(&mut [f64])>(
        &self,
        objective: Objective,
        fit: f64,
        pos_src: F,
    ) -> bool {
        if !objective.better(fit, self.fit.load(Ordering::Acquire)) {
            return false;
        }
        // SAFETY: the caller is the only writer (single-block 2nd kernel);
        // engines never mix this entry with `update_locked`.
        let pos = unsafe { &mut *self.gather.get() };
        pos_src(pos);
        for (slot, &p) in self.pos.iter().zip(pos.iter()) {
            slot.store(p, Ordering::Relaxed);
        }
        self.fit.store(fit, Ordering::Release);
        self.updates.fetch_add(1, Ordering::Relaxed);
        true
    }

    /// How many times the global best was improved.
    pub fn update_count(&self) -> u64 {
        self.updates.load(Ordering::Relaxed)
    }

    /// Lock acquisitions (Queue-Lock contention instrumentation).
    pub fn lock_acquisitions(&self) -> u64 {
        self.lock.acquisition_count()
    }
}

/// Reusable per-block scratch for the dimension-major step.
pub(crate) struct StepScratch {
    /// Fitness of the block's particles this iteration.
    pub fit: Vec<f64>,
    /// Which particles improved their pbest (row-masked copy phase).
    pub improved: Vec<bool>,
}

impl StepScratch {
    pub fn new(block_size: usize) -> Self {
        Self {
            fit: vec![0.0; block_size],
            improved: vec![false; block_size],
        }
    }
}

/// A mutable view of one swarm's SoA arrays — either a standalone
/// [`SwarmState`]'s fields or one member's region of a pack slab
/// ([`crate::engine::PackedRun`]). Within the view the layout is the
/// standalone dimension-major one: `pos[d * n + i]`. Routing both the
/// solo engines and the pack through [`step_block_view`] makes the two
/// execution layouts bit-identical *by construction* — same function,
/// same per-element op sequence.
pub(crate) struct SwarmView<'s> {
    pub n: usize,
    pub dim: usize,
    pub pos: &'s mut [f64],
    pub vel: &'s mut [f64],
    pub fit: &'s mut [f64],
    pub pbest_pos: &'s mut [f64],
    pub pbest_fit: &'s mut [f64],
}

/// The shared "1st kernel" body: step every particle of block `b` against
/// the frozen global-best position, then evaluate fitness and update
/// pbest. Returns the block's best `(fit, idx)` of *this iteration* under
/// the index tie-break (lowest index wins).
///
/// **Dimension-major** (perf pass, EXPERIMENTS.md §Perf): each phase
/// streams contiguous SoA rows — velocity/position update row by row,
/// fitness via [`Fitness::eval_range`], then a row-masked pbest copy —
/// instead of striding across all rows per particle. Numerically
/// bit-identical to the per-particle order (same draws, same per-element
/// op sequence, ascending-dimension fitness accumulation), which the
/// equivalence suite enforces.
#[allow(clippy::too_many_arguments)]
#[inline]
pub(crate) fn step_block(
    state: &mut SwarmState,
    lo: usize,
    hi: usize,
    gbest_pos: &[f64],
    params: &PsoParams,
    fitness: &dyn Fitness,
    objective: Objective,
    stream: &PhiloxStream,
    iter: u64,
    scratch: &mut StepScratch,
) -> (f64, usize) {
    let mut view = SwarmView {
        n: state.n,
        dim: state.dim,
        pos: &mut state.pos,
        vel: &mut state.vel,
        fit: &mut state.fit,
        pbest_pos: &mut state.pbest_pos,
        pbest_fit: &mut state.pbest_fit,
    };
    step_block_view(
        &mut view, lo, hi, gbest_pos, params, fitness, objective, stream, iter, scratch,
    )
}

/// [`step_block`] generalized over a [`SwarmView`] — the single body both
/// the standalone engines and the pack slab execute.
#[allow(clippy::too_many_arguments)]
#[inline]
pub(crate) fn step_block_view(
    state: &mut SwarmView<'_>,
    lo: usize,
    hi: usize,
    gbest_pos: &[f64],
    params: &PsoParams,
    fitness: &dyn Fitness,
    objective: Objective,
    stream: &PhiloxStream,
    iter: u64,
    scratch: &mut StepScratch,
) -> (f64, usize) {
    let n = state.n;
    let dim = state.dim;
    let m = hi - lo;

    // Phase 1 — velocity + position (Eq. 1, Eq. 2, clamps), row by row,
    // with the Philox draws generated four particles at a time (the
    // lane-batched generator vectorizes; bit-identical to scalar draws).
    for d in 0..dim {
        let base = d * n;
        let gb = gbest_pos[d];
        let (pos_row, vel_row, pb_row) = (
            &mut state.pos[base + lo..base + hi],
            &mut state.vel[base + lo..base + hi],
            &state.pbest_pos[base + lo..base + hi],
        );
        macro_rules! upd {
            ($k:expr, $r1:expr, $r2:expr) => {{
                let k = $k;
                let v = params.w * vel_row[k]
                    + params.c1 * $r1 * (pb_row[k] - pos_row[k])
                    + params.c2 * $r2 * (gb - pos_row[k]);
                let v = v.clamp(-params.max_v, params.max_v);
                vel_row[k] = v;
                pos_row[k] = (pos_row[k] + v).clamp(params.min_pos, params.max_pos);
            }};
        }
        // Perf note (EXPERIMENTS.md §Perf): the lane-batched
        // `PhiloxStream::r1r2_x4` wins 3.7× in isolation but *loses* in
        // this memory-interleaved loop (A/B best-of-5: 21.2 vs 19.5
        // ns/dim) — the scalar draw overlaps with the row stores, the
        // batch does not. Scalar path kept.
        for k in 0..m {
            let (r1, r2) = stream.r1r2((lo + k) as u64, iter, d as u32);
            upd!(k, r1, r2);
        }
    }

    // Phase 2 — fitness over the block range (streaming for separable
    // functions via eval_range overrides).
    fitness.eval_range(&state.pos, n, dim, lo, hi, &mut scratch.fit[..m]);

    // Phase 3 — pbest merge + block best (per-particle scalars, then a
    // row-masked position copy).
    let mut best = objective.worst();
    let mut best_i = usize::MAX;
    let mut any_improved = false;
    for k in 0..m {
        let i = lo + k;
        let fit = scratch.fit[k];
        state.fit[i] = fit;
        let better = objective.better(fit, state.pbest_fit[i]);
        scratch.improved[k] = better;
        any_improved |= better;
        if better {
            state.pbest_fit[i] = fit;
        }
        if crate::pso::serial_sync::better_with_tie(objective, fit, i, best, best_i) {
            best = fit;
            best_i = i;
        }
    }
    if any_improved {
        for d in 0..dim {
            let base = d * n;
            for k in 0..m {
                if scratch.improved[k] {
                    state.pbest_pos[base + lo + k] = state.pos[base + lo + k];
                }
            }
        }
    }
    (best, best_i)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geometry_partitions_exactly() {
        let s = ParallelSettings::with_workers(1).block_size(256);
        assert_eq!(s.blocks_for(2048), 8);
        assert_eq!(s.blocks_for(2049), 9);
        assert_eq!(s.block_range(0, 2048), (0, 256));
        assert_eq!(s.block_range(7, 2000), (1792, 2000));
        // Union of ranges covers 0..n without overlap.
        let n = 1000;
        let mut covered = vec![false; n];
        for b in 0..s.blocks_for(n) {
            let (lo, hi) = s.block_range(b, n);
            for c in &mut covered[lo..hi] {
                assert!(!*c);
                *c = true;
            }
        }
        assert!(covered.iter().all(|&c| c));
    }

    #[test]
    fn global_best_lock_update_semantics() {
        let g = GlobalBest::new(10.0, &[1.0, 2.0]);
        // Worse candidate: rejected without calling pos_src.
        let updated = g.update_locked(Objective::Maximize, 5.0, |_| panic!("must not gather"));
        assert!(!updated);
        // Better candidate: accepted.
        assert!(g.update_locked(Objective::Maximize, 20.0, |dst| {
            dst.copy_from_slice(&[3.0, 4.0])
        }));
        assert_eq!(g.fit_relaxed(), 20.0);
        assert_eq!(g.pos_vec(), vec![3.0, 4.0]);
        assert_eq!(g.update_count(), 1);
    }

    #[test]
    fn global_best_concurrent_updates_keep_max() {
        let g = Arc::new(GlobalBest::new(f64::NEG_INFINITY, &[0.0]));
        let mut handles = vec![];
        for t in 0..8u64 {
            let g = g.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..5000u64 {
                    let v = (t * 5000 + i) as f64;
                    g.update_locked(Objective::Maximize, v, |dst| dst[0] = v);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(g.fit_relaxed(), 39_999.0);
        assert_eq!(g.pos_vec(), vec![39_999.0]);
    }
}
