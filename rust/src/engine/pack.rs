//! Swarm-packing megabatch — step an entire fleet of jobs in one
//! grid-stride launch (the fleet-level analogue of PSSO's flattened
//! population, arXiv:2110.01470).
//!
//! The scheduler's per-job dispatch cost (a publish + wake per stream per
//! round, `benches/scheduler_latency.rs`) dominates fleets of small
//! swarms: S streams step at most S jobs per round, and every job pays
//! the round machinery individually. A [`PackedRun`] removes that cost by
//! fusing compatible jobs into **one shared SoA slab** — the positions,
//! velocities, pbest and fitness arrays of all member swarms laid out
//! contiguously, member by member — and stepping *every* member with a
//! single pair of grid launches per iteration:
//!
//! 1. **1st kernel** over `Σ blocks_m` flat blocks: a block's flat index
//!    decodes through `block_member` to its `(member, local block)` pair;
//!    within the member's slab region the layout is exactly the
//!    standalone dimension-major one, so the block runs the *identical*
//!    [`step_block_view`] body a solo [`QueueRun`] runs — same Philox
//!    draws (member-local particle indices, per-member streams), same
//!    conditional queue append against the member's frozen threshold,
//!    same thread-0 scan into the member's aux slots.
//! 2. **2nd kernel** over `members` blocks: block `m` exclusively scans
//!    member `m`'s aux range and updates member `m`'s own
//!    [`GlobalBest`] — per-job gbest updates, never shared.
//!
//! Packing is therefore **purely an execution-layout choice**: per-job
//! RNG streams, gbest updates, NaN ordering, history stride and counters
//! are all bit-identical to solo execution, which the determinism tier
//! proves (`rust/tests/scheduler_determinism.rs` § pack). Members are
//! formed from — and extract back into — ordinary [`RunKind::Queue`]
//! checkpoints, so a packed job can leave the pack (cancel, preemption,
//! dissolution, drain) into a standalone checkpoint-equivalent state and
//! resume anywhere a solo Queue run can.
//!
//! Compatibility rule (enforced by [`PackedRun::form`]): members must be
//! Queue-kind checkpoints with equal `dim` and equal objective; particle
//! counts and iteration budgets may differ (done members simply skip).
//!
//! [`QueueRun`]: crate::engine::QueueEngine

use super::common::{
    step_block_view, GlobalBest, ParallelSettings, PerBlock, StepScratch, SwarmView,
};
use super::{Run, StepReport};
use crate::checkpoint::{RunCheckpoint, RunKind, VERSION};
use crate::exec::SharedQueue;
use crate::fitness::{Fitness, Objective};
use crate::pso::serial_sync::better_with_tie;
use crate::pso::{history_capacity, history_stride, Counters, PsoParams, RunOutput, SwarmState};
use crate::rng::PhiloxStream;
use anyhow::{bail, Result};
use std::cell::UnsafeCell;
use std::sync::Arc;

/// The pack's shared SoA arrays: every member's swarm, contiguous.
/// Member `m` owns `pos/vel/pbest_pos[row_off .. row_off + n·dim]` and
/// `fit/pbest_fit[par_off .. par_off + n]`; within its region the layout
/// is the standalone dimension-major `[d * n + i]`.
struct Slab {
    pos: Vec<f64>,
    vel: Vec<f64>,
    fit: Vec<f64>,
    pbest_pos: Vec<f64>,
    pbest_fit: Vec<f64>,
}

/// Slab shared across blocks — the same discipline as
/// [`super::common::SharedSwarm`]: blocks of one member touch disjoint
/// particle columns of that member's region, and different members'
/// regions are disjoint by construction.
struct SharedSlab(UnsafeCell<Slab>);

// SAFETY: disjoint-region discipline per the type docs above.
unsafe impl Sync for SharedSlab {}

impl SharedSlab {
    /// # Safety
    /// Caller must only touch the particle columns of its own block's
    /// member region while other blocks may be live.
    #[allow(clippy::mut_from_ref)]
    unsafe fn get(&self) -> &mut Slab {
        // SAFETY: non-aliasing per this function's contract (blocks stay
        // within their own member regions).
        unsafe { &mut *self.0.get() }
    }
}

/// One packed job: its own params, fitness, RNG stream, global best and
/// bookkeeping — everything a solo `QueueRun` keeps per run — plus the
/// member's offsets into the shared slab and flat block range.
struct Member {
    params: PsoParams,
    objective: Objective,
    fitness: Arc<dyn Fitness + Send>,
    seed: u64,
    rng: PhiloxStream,
    gbest: GlobalBest,
    /// Frozen global-best position for the current iteration (host-side
    /// refresh before each launch pair, exactly like the solo run).
    frozen: Vec<f64>,
    /// Frozen improvement threshold for the current iteration.
    threshold: f64,
    /// Start of this member's region in the row slabs (`pos`/`vel`/
    /// `pbest_pos`).
    row_off: usize,
    /// Start of this member's region in the particle slabs (`fit`/
    /// `pbest_fit`).
    par_off: usize,
    /// First flat block of this member.
    block_off: usize,
    /// Flat blocks this member spans.
    blocks: usize,
    /// Queue pushes accumulated before pack formation.
    push_base: u64,
    stride: u64,
    history: Vec<(u64, f64)>,
    iter: u64,
    /// False once the member was extracted (tombstone: its slab region
    /// and blocks are simply skipped from then on).
    live: bool,
    /// Whether this member steps in the current iteration (host-set
    /// before each launch pair; read-only inside the kernels).
    step_active: bool,
    /// Iterations remaining in the current budgeted batch.
    budget: u64,
    /// Gbest update count at batch start (per-batch `improved` flag).
    updates_before: u64,
}

/// A fleet of compatible Queue jobs stepped as one unit — see the module
/// docs. Formed from per-job [`RunCheckpoint`]s; members extract back
/// into per-job checkpoints at any step boundary.
pub struct PackedRun {
    settings: ParallelSettings,
    dim: usize,
    members: Vec<Member>,
    slab: SharedSlab,
    /// One conditional-append queue per flat block (Algorithm 2's
    /// shared-memory queue, identical geometry to the solo run).
    queues: Vec<SharedQueue<(f64, u32)>>,
    /// Per-flat-block `(fit, idx)` best of the iteration.
    aux: PerBlock<(f64, u32)>,
    scratch: PerBlock<StepScratch>,
    /// Flat block index → member index (the grid-stride decode table).
    block_member: Vec<u32>,
    total_blocks: usize,
    live: usize,
}

impl PackedRun {
    /// Form a pack from per-member `(checkpoint, fitness)` pairs. Every
    /// checkpoint must be a structurally valid [`RunKind::Queue`]
    /// checkpoint; all members must share `dim` and objective. The slab
    /// copies each member's swarm out of its checkpoint (one copy — the
    /// checkpoints themselves are typically moves out of live runs).
    pub fn form(
        settings: ParallelSettings,
        members_in: &[(Arc<RunCheckpoint>, Arc<dyn Fitness + Send>)],
    ) -> Result<Self> {
        let Some((first, _)) = members_in.first() else {
            bail!("cannot form an empty pack");
        };
        let dim = first.params.dim;
        let objective = first.objective;
        for (ckpt, _) in members_in {
            if ckpt.kind != RunKind::Queue {
                bail!("pack members must be Queue runs, got {}", ckpt.kind);
            }
            ckpt.validate()?;
            if ckpt.params.n == 0 {
                bail!("cannot pack a checkpoint with an empty swarm");
            }
            if ckpt.params.dim != dim {
                bail!(
                    "pack members must share dim: {} vs {}",
                    ckpt.params.dim,
                    dim
                );
            }
            if ckpt.objective != objective {
                bail!("pack members must share the optimization objective");
            }
        }

        let bs = settings.block_size;
        let mut members = Vec::with_capacity(members_in.len());
        let mut block_member = Vec::new();
        let (mut row_off, mut par_off, mut block_off) = (0usize, 0usize, 0usize);
        for (m, (ckpt, fitness)) in members_in.iter().enumerate() {
            let n = ckpt.params.n;
            let blocks = n.div_ceil(bs);
            let mut history = ckpt.history.clone();
            history.reserve(history_capacity(ckpt.params.max_iter).saturating_sub(history.len()));
            let gbest =
                GlobalBest::restore(ckpt.gbest_fit, &ckpt.gbest_pos, ckpt.counters.gbest_updates);
            let frozen = gbest.pos_vec();
            members.push(Member {
                params: ckpt.params.clone(),
                objective: ckpt.objective,
                fitness: Arc::clone(fitness),
                seed: ckpt.seed,
                rng: PhiloxStream::new(ckpt.seed),
                gbest,
                frozen,
                threshold: ckpt.gbest_fit,
                row_off,
                par_off,
                block_off,
                blocks,
                push_base: ckpt.counters.queue_pushes,
                stride: history_stride(ckpt.params.max_iter),
                history,
                iter: ckpt.iter,
                live: true,
                step_active: false,
                budget: 0,
                updates_before: 0,
            });
            block_member.extend(std::iter::repeat(m as u32).take(blocks));
            row_off += n * dim;
            par_off += n;
            block_off += blocks;
        }
        let total_blocks = block_off;

        let mut slab = Slab {
            pos: Vec::with_capacity(row_off),
            vel: Vec::with_capacity(row_off),
            fit: Vec::with_capacity(par_off),
            pbest_pos: Vec::with_capacity(row_off),
            pbest_fit: Vec::with_capacity(par_off),
        };
        for (ckpt, _) in members_in {
            slab.pos.extend_from_slice(&ckpt.swarm.pos);
            slab.vel.extend_from_slice(&ckpt.swarm.vel);
            slab.fit.extend_from_slice(&ckpt.swarm.fit);
            slab.pbest_pos.extend_from_slice(&ckpt.swarm.pbest_pos);
            slab.pbest_fit.extend_from_slice(&ckpt.swarm.pbest_fit);
        }

        let queues = (0..total_blocks).map(|_| SharedQueue::new(bs)).collect();
        let aux = PerBlock::from_fn(total_blocks, |b| {
            (
                members[block_member[b] as usize].objective.worst(),
                u32::MAX,
            )
        });
        let scratch = PerBlock::from_fn(total_blocks, |_| StepScratch::new(bs));
        let live = members.len();
        Ok(Self {
            settings,
            dim,
            members,
            slab: SharedSlab(UnsafeCell::new(slab)),
            queues,
            aux,
            scratch,
            block_member,
            total_blocks,
            live,
        })
    }

    /// Member slots, tombstoned ones included.
    pub fn len(&self) -> usize {
        self.members.len()
    }

    /// Whether the pack holds no member slots at all.
    pub fn is_empty(&self) -> bool {
        self.members.is_empty()
    }

    /// Members not yet extracted.
    pub fn live_members(&self) -> usize {
        self.live
    }

    /// Whether member `m` is still in the pack.
    pub fn member_live(&self, m: usize) -> bool {
        self.members[m].live
    }

    /// Iterations member `m` has completed.
    pub fn member_iter(&self, m: usize) -> u64 {
        self.members[m].iter
    }

    /// Member `m`'s current global-best fitness.
    pub fn member_gbest_fit(&self, m: usize) -> f64 {
        self.members[m].gbest.fit_relaxed()
    }

    /// Give member `m` a budget of `k` iterations for the next
    /// [`step_budgeted`](Self::step_budgeted) batch, and mark the batch
    /// start for its `improved` flag. Allocation-free.
    pub fn set_budget(&mut self, m: usize, k: u64) {
        let mem = &mut self.members[m];
        debug_assert!(mem.live, "budget for an extracted pack member");
        mem.budget = k;
        mem.updates_before = mem.gbest.update_count();
    }

    /// Step every budgeted member until its budget or iteration budget is
    /// spent — one launch pair per fleet iteration, regardless of member
    /// count. Members advance in lockstep; a member whose budget (or
    /// `max_iter`) runs out earlier simply skips the remaining
    /// iterations. Allocation-free in the steady state (histories are
    /// pre-reserved; improvements publish into the member's own
    /// [`GlobalBest`] scratch).
    pub fn step_budgeted(&mut self) {
        loop {
            let mut any = false;
            for mem in &mut self.members {
                mem.step_active = mem.live && mem.budget > 0 && mem.iter < mem.params.max_iter;
                if mem.step_active {
                    any = true;
                    // Freeze the member's own gbest for this iteration —
                    // identical to the solo run's pre-launch snapshot.
                    mem.gbest.load_pos(&mut mem.frozen);
                    mem.threshold = mem.gbest.fit_relaxed();
                }
            }
            if !any {
                break;
            }
            self.launch_iteration();
            for mem in &mut self.members {
                if !mem.step_active {
                    continue;
                }
                let it = mem.iter;
                mem.iter += 1;
                mem.budget -= 1;
                if it % mem.stride == 0 {
                    mem.history.push((it, mem.gbest.fit_relaxed()));
                }
            }
        }
        for mem in &mut self.members {
            mem.budget = 0;
        }
    }

    /// One fleet iteration: the two launches of the module docs.
    fn launch_iteration(&self) {
        let Self {
            settings,
            dim,
            members,
            slab,
            queues,
            aux,
            scratch,
            block_member,
            total_blocks,
            ..
        } = self;
        let dim = *dim;
        // ---- 1st kernel: flat blocks decode to (member, local block) ----
        settings.launch(*total_blocks, |ctx| {
            let b = ctx.block_id;
            let mem = &members[block_member[b] as usize];
            if !mem.step_active {
                return;
            }
            let n = mem.params.n;
            let (lo, hi) = settings.block_range(b - mem.block_off, n);
            let q = &queues[b];
            q.reset();
            // SAFETY: this block only touches particles [lo, hi) of its
            // member's region; regions of different members are disjoint.
            let sl = unsafe { slab.get() };
            let r = mem.row_off..mem.row_off + n * dim;
            let p = mem.par_off..mem.par_off + n;
            let mut view = SwarmView {
                n,
                dim,
                pos: &mut sl.pos[r.clone()],
                vel: &mut sl.vel[r.clone()],
                fit: &mut sl.fit[p.clone()],
                pbest_pos: &mut sl.pbest_pos[r],
                pbest_fit: &mut sl.pbest_fit[p],
            };
            // SAFETY: scratch[b] and aux[b] are this block's slots.
            let ss = unsafe { scratch.get(b) };
            step_block_view(
                &mut view,
                lo,
                hi,
                &mem.frozen,
                &mem.params,
                &*mem.fitness,
                mem.objective,
                &mem.rng,
                mem.iter,
                ss,
            );
            // Algorithm 2 lines 1–5 against the member's own threshold.
            for k in 0..(hi - lo) {
                let fit = ss.fit[k];
                if mem.objective.better(fit, mem.threshold) {
                    q.push((fit, (lo + k) as u32));
                }
            }
            let mut best = (mem.objective.worst(), u32::MAX);
            q.scan(|&(f, i)| {
                if better_with_tie(mem.objective, f, i as usize, best.0, best.1 as usize) {
                    best = (f, i);
                }
            });
            // SAFETY: entry `b` is this block's own PerBlock slot.
            unsafe { *aux.get(b) = best };
        });
        // ---- 2nd kernel: block m scans member m's aux range ----
        settings.launch(members.len(), |ctx| {
            let mem = &members[ctx.block_id];
            if !mem.step_active {
                return;
            }
            let mut best = (mem.objective.worst(), u32::MAX);
            for b in mem.block_off..mem.block_off + mem.blocks {
                // SAFETY: 1st kernel joined; exclusive read.
                let (f, i) = unsafe { *aux.get(b) };
                if better_with_tie(mem.objective, f, i as usize, best.0, best.1 as usize) {
                    best = (f, i);
                }
            }
            if best.1 != u32::MAX {
                // SAFETY: 1st kernel joined, this block only reads its own
                // member's region.
                let sl = unsafe { slab.get() };
                let n = mem.params.n;
                let i = best.1 as usize;
                mem.gbest.update_exclusive(mem.objective, best.0, |dst| {
                    for (d, slot) in dst.iter_mut().enumerate() {
                        *slot = sl.pos[mem.row_off + d * n + i];
                    }
                });
            }
        });
    }

    /// Member `m`'s report for the last budgeted batch — same contract as
    /// [`Run::step_many`]: `iter`/`gbest_fit`/`done` are current,
    /// `improved` (and the accompanying position) covers the whole batch.
    pub fn member_report(&self, m: usize) -> StepReport {
        let mem = &self.members[m];
        let improved = mem.gbest.update_count() > mem.updates_before;
        StepReport {
            iter: mem.iter,
            gbest_fit: mem.gbest.fit_relaxed(),
            gbest_pos: improved.then(|| mem.gbest.pos_vec()),
            improved,
            done: mem.iter >= mem.params.max_iter,
        }
    }

    fn member_counters(&self, m: usize) -> Counters {
        let mem = &self.members[m];
        Counters {
            particle_updates: mem.params.n as u64 * mem.iter,
            queue_pushes: mem.push_base
                + self.queues[mem.block_off..mem.block_off + mem.blocks]
                    .iter()
                    .map(|q| q.total_pushes())
                    .sum::<u64>(),
            gbest_updates: mem.gbest.update_count(),
            ..Default::default()
        }
    }

    fn member_swarm(&self, m: usize) -> SwarmState {
        let mem = &self.members[m];
        let n = mem.params.n;
        // SAFETY: between steps the grid is quiescent and `&self` excludes
        // concurrent stepping.
        let sl = unsafe { self.slab.get() };
        let r = mem.row_off..mem.row_off + n * self.dim;
        let p = mem.par_off..mem.par_off + n;
        SwarmState {
            n,
            dim: self.dim,
            pos: sl.pos[r.clone()].to_vec(),
            vel: sl.vel[r.clone()].to_vec(),
            fit: sl.fit[p.clone()].to_vec(),
            pbest_pos: sl.pbest_pos[r].to_vec(),
            pbest_fit: sl.pbest_fit[p].to_vec(),
        }
    }

    /// Non-destructive per-member checkpoint (snapshot persistence). The
    /// result is an ordinary Queue checkpoint — indistinguishable from
    /// one taken off a solo run at the same iteration.
    pub fn checkpoint_member(&self, m: usize) -> RunCheckpoint {
        let mem = &self.members[m];
        assert!(mem.live, "checkpoint of an extracted pack member");
        RunCheckpoint {
            version: VERSION,
            kind: RunKind::Queue,
            objective: mem.objective,
            seed: mem.seed,
            params: mem.params.clone(),
            iter: mem.iter,
            gbest_fit: mem.gbest.fit_relaxed(),
            gbest_pos: mem.gbest.pos_vec(),
            history: mem.history.clone(),
            counters: self.member_counters(m),
            swarm: self.member_swarm(m),
        }
    }

    /// Extract member `m` out of the pack into a standalone Queue
    /// checkpoint (cancellation, preemption, dissolution, termination).
    /// The member becomes a tombstone: its slab region and blocks are
    /// skipped from now on. The swarm is copied out of the slab (the
    /// slab itself never reallocates); the history is moved.
    pub fn extract_member(&mut self, m: usize) -> RunCheckpoint {
        assert!(self.members[m].live, "double extraction of a pack member");
        let counters = self.member_counters(m);
        let swarm = self.member_swarm(m);
        let mem = &mut self.members[m];
        mem.live = false;
        self.live -= 1;
        RunCheckpoint {
            version: VERSION,
            kind: RunKind::Queue,
            objective: mem.objective,
            seed: mem.seed,
            params: mem.params.clone(),
            iter: mem.iter,
            gbest_fit: mem.gbest.fit_relaxed(),
            gbest_pos: mem.gbest.pos_vec(),
            history: std::mem::take(&mut mem.history),
            counters,
            swarm,
        }
    }

    /// Index of the single live member, for the whole-fleet [`Run`]
    /// methods that only make sense on a degenerate pack.
    fn sole_live(&self, what: &str) -> usize {
        assert!(
            self.live == 1,
            "PackedRun::{what} requires exactly one live member ({} live); \
             use the per-member API (checkpoint_member / extract_member)",
            self.live
        );
        self.members
            .iter()
            .position(|m| m.live)
            .expect("live count said one")
    }
}

/// Fleet-level [`Run`] view of a pack: stepping advances *every* live
/// member, progress aggregates over the fleet (min iterations, best
/// global best under the shared objective). `finish`/`checkpoint`/
/// `into_checkpoint` are only defined for a pack with exactly one live
/// member (the degenerate solo case); multi-member packs use the
/// per-member API — the scheduler never calls the whole-fleet forms.
impl Run for PackedRun {
    fn iters_done(&self) -> u64 {
        self.members
            .iter()
            .filter(|m| m.live)
            .map(|m| m.iter)
            .min()
            .unwrap_or(0)
    }

    fn max_iter(&self) -> u64 {
        self.members
            .iter()
            .map(|m| m.params.max_iter)
            .max()
            .unwrap_or(0)
    }

    fn gbest_fit(&self) -> f64 {
        let objective = self.members[0].objective;
        let mut best = objective.worst();
        for mem in self.members.iter().filter(|m| m.live) {
            let fit = mem.gbest.fit_relaxed();
            if objective.better(fit, best) {
                best = fit;
            }
        }
        best
    }

    fn gbest_pos(&self) -> Vec<f64> {
        let objective = self.members[0].objective;
        let mut best = objective.worst();
        let mut pos = vec![0.0; self.dim];
        for mem in self.members.iter().filter(|m| m.live) {
            let fit = mem.gbest.fit_relaxed();
            if objective.better(fit, best) {
                best = fit;
                mem.gbest.load_pos(&mut pos);
            }
        }
        pos
    }

    fn step(&mut self) -> StepReport {
        self.step_many(1)
    }

    fn step_many(&mut self, k: u64) -> StepReport {
        let k = k.max(1);
        for m in 0..self.members.len() {
            if self.members[m].live {
                self.set_budget(m, k);
            }
        }
        self.step_budgeted();
        let mut improved = false;
        let mut done = true;
        for m in 0..self.members.len() {
            if !self.members[m].live {
                continue;
            }
            let r = self.member_report(m);
            improved |= r.improved;
            done &= r.done;
        }
        StepReport {
            iter: self.iters_done(),
            gbest_fit: self.gbest_fit(),
            gbest_pos: improved.then(|| self.gbest_pos()),
            improved,
            done,
        }
    }

    fn finish(self: Box<Self>) -> RunOutput {
        let mut this = *self;
        let m = this.sole_live("finish");
        let counters = this.member_counters(m);
        let swarm = this.member_swarm(m);
        let mem = &mut this.members[m];
        let mut history = std::mem::take(&mut mem.history);
        history.push((mem.iter, mem.gbest.fit_relaxed()));
        debug_assert_eq!(swarm.check_bounds(&mem.params), Ok(()));
        RunOutput {
            gbest_fit: mem.gbest.fit_relaxed(),
            gbest_pos: mem.gbest.pos_vec(),
            iters: mem.iter,
            history,
            counters,
        }
    }

    fn checkpoint(&self) -> RunCheckpoint {
        let m = self.sole_live("checkpoint");
        self.checkpoint_member(m)
    }

    fn into_checkpoint(self: Box<Self>) -> RunCheckpoint {
        let m = self.sole_live("into_checkpoint");
        let mut this = *self;
        this.extract_member(m)
    }
}
