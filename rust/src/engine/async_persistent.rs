//! The paper's §7 future work, built: a fully **asynchronous** engine in
//! the persistent-kernel style (cf. Mussi et al. [9], the GPU-async PSO
//! line the paper cites).
//!
//! Where Queue-Lock still launches one kernel per iteration (the grid is
//! re-synchronized at every iteration boundary), this engine launches the
//! grid **once**: each block loops through all `max_iter` iterations of
//! its own particles, reading the global best from the shared atomics at
//! the top of every iteration and publishing improvements through the
//! Algorithm-3 lock. No iteration barrier exists anywhere — blocks drift
//! apart freely, bounded only by the monotone global best.
//!
//! Semantics: weaker than Queue-Lock (a block may step against a gbest
//! that is several iterations stale for other blocks — exactly the
//! asynchrony of [9]); still monotone, still bound-respecting, and
//! empirically the same quality class (tests below + the property suite).
//! Launch overhead drops from `max_iter` dispatches to **one**.

use super::common::{step_block, GlobalBest, ParallelSettings, PerBlock, SharedSwarm, StepScratch};
use super::Engine;
use crate::fitness::{Fitness, Objective};
use crate::pso::{history_stride, Counters, PsoParams, RunOutput, SwarmState};
use crate::rng::PhiloxStream;
use std::sync::atomic::{AtomicU64, Ordering};

/// Persistent-kernel asynchronous engine (one launch per run).
pub struct AsyncEngine {
    settings: ParallelSettings,
}

impl AsyncEngine {
    /// New engine on the given pool/geometry.
    pub fn new(settings: ParallelSettings) -> Self {
        Self { settings }
    }
}

impl Engine for AsyncEngine {
    fn name(&self) -> &'static str {
        "Async Persistent"
    }

    fn run(
        &mut self,
        params: &PsoParams,
        fitness: &dyn Fitness,
        objective: Objective,
        seed: u64,
    ) -> RunOutput {
        let stream = PhiloxStream::new(seed);
        let mut init = SwarmState::init(params, &stream);
        let (fit0, gi) = init.seed_fitness(fitness, objective);
        let gbest = GlobalBest::new(fit0, &init.position_of(gi));
        let state = SharedSwarm::new(init);

        let blocks = self.settings.blocks_for(params.n);
        let step_scratch =
            PerBlock::from_fn(blocks, |_| StepScratch::new(self.settings.block_size));
        let snapshots = PerBlock::from_fn(blocks, |_| vec![0.0; params.dim]);
        // Sampled history: block 0 records the global best as it passes
        // its own iteration marks (other blocks may be ahead or behind —
        // that skew is the point of the design).
        let stride = history_stride(params.max_iter);
        let history_cells = PerBlock::from_fn(1, |_| Vec::<(u64, f64)>::new());
        let pbest_improvements = AtomicU64::new(0);

        // ---- the single persistent launch ----
        self.settings.pool.launch(blocks, |ctx| {
            let b = ctx.block_id;
            let (lo, hi) = self.settings.block_range(b, params.n);
            // SAFETY: per-block disjoint state/scratch (see common.rs).
            let st = unsafe { state.get() };
            let ss = unsafe { step_scratch.get(b) };
            let frozen = unsafe { snapshots.get(b) };
            for iter in 0..params.max_iter {
                gbest.load_pos(frozen);
                let (best, best_i) = step_block(
                    st, lo, hi, frozen, params, fitness, objective, &stream, iter, ss,
                );
                if best_i != usize::MAX && objective.better(best, gbest.fit_relaxed()) {
                    gbest.update_locked(objective, best, || st.position_of(best_i));
                }
                if b == 0 && iter % stride == 0 {
                    // SAFETY: only block 0 touches the history cell.
                    unsafe { history_cells.get(0) }.push((iter, gbest.fit_relaxed()));
                }
            }
            let improved = ss.improved.iter().filter(|&&x| x).count() as u64;
            pbest_improvements.fetch_add(improved, Ordering::Relaxed);
        });

        let mut history = std::mem::take(unsafe { history_cells.get(0) });
        history.push((params.max_iter, gbest.fit_relaxed()));

        let counters = Counters {
            particle_updates: params.n as u64 * params.max_iter,
            gbest_updates: gbest.update_count(),
            pbest_improvements: pbest_improvements.load(Ordering::Relaxed),
            ..Default::default()
        };
        RunOutput {
            gbest_fit: gbest.fit_relaxed(),
            gbest_pos: gbest.pos_vec(),
            iters: params.max_iter,
            history,
            counters,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fitness::Cubic;

    #[test]
    fn solves_cubic_both_dims() {
        let mut e = AsyncEngine::new(ParallelSettings::with_workers(4));
        let p1 = PsoParams::paper_1d(512, 150);
        let out = e.run(&p1, &Cubic, Objective::Maximize, 1);
        assert!(out.gbest_fit > 890_000.0, "1-D gbest {}", out.gbest_fit);

        let p120 = PsoParams::paper_120d(256, 80);
        let out = e.run(&p120, &Cubic, Objective::Maximize, 2);
        let opt = 900_000.0 * 120.0;
        assert!(out.gbest_fit > 0.5 * opt, "120-D gbest {}", out.gbest_fit);
    }

    #[test]
    fn monotone_history_despite_full_asynchrony() {
        let mut e = AsyncEngine::new(ParallelSettings::with_workers(8));
        let params = PsoParams::paper_120d(1024, 60);
        let out = e.run(&params, &Cubic, Objective::Maximize, 3);
        for w in out.history.windows(2) {
            assert!(w[1].1 >= w[0].1, "gbest worsened: {w:?}");
        }
    }

    #[test]
    fn single_block_reduces_to_queue_lock_semantics() {
        // With one block there is no asynchrony: identical to Queue-Lock
        // (and hence to the synchronous oracle).
        let params = PsoParams::paper_1d(200, 50);
        let settings = ParallelSettings::with_workers(4);
        let oracle = crate::pso::serial_sync::run(&params, &Cubic, Objective::Maximize, 7);
        let mut e = AsyncEngine::new(settings);
        let out = e.run(&params, &Cubic, Objective::Maximize, 7);
        assert_eq!(out.gbest_fit, oracle.gbest_fit);
        assert_eq!(out.gbest_pos, oracle.gbest_pos);
    }
}
