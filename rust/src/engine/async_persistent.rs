//! The paper's §7 future work, built: a fully **asynchronous** engine in
//! the persistent-kernel style (cf. Mussi et al. [9], the GPU-async PSO
//! line the paper cites).
//!
//! Where Queue-Lock still launches one kernel per iteration (the grid is
//! re-synchronized at every iteration boundary), this engine launches the
//! grid **once**: each block loops through all `max_iter` iterations of
//! its own particles, reading the global best from the shared atomics at
//! the top of every iteration and publishing improvements through the
//! Algorithm-3 lock. No iteration barrier exists anywhere — blocks drift
//! apart freely, bounded only by the monotone global best.
//!
//! Semantics: weaker than Queue-Lock (a block may step against a gbest
//! that is several iterations stale for other blocks — exactly the
//! asynchrony of [9]); still monotone, still bound-respecting, and
//! empirically the same quality class (tests below + the property suite).
//! Launch overhead drops from `max_iter` dispatches to **one**.
//!
//! **Step-wise caveat:** a persistent kernel is inherently one-shot, so
//! [`Engine::prepare`] cannot preserve the barrier-free semantics — a
//! `step()` boundary *is* a grid-wide barrier. [`AsyncStepRun`] therefore
//! steps with Queue-Lock-style per-iteration launches (per-block gbest
//! snapshots, lock-based publication, no queue). [`Engine::run`] keeps
//! the true single-launch persistent kernel, overriding the default
//! prepare/step loop.

use super::common::{step_block, GlobalBest, ParallelSettings, PerBlock, SharedSwarm, StepScratch};
use super::{restore_guard, Engine, Run, StepReport};
use crate::checkpoint::{RunCheckpoint, RunKind, VERSION};
use crate::fitness::{Fitness, Objective};
use crate::pso::{history_capacity, history_stride, Counters, PsoParams, RunOutput, SwarmState};
use crate::rng::PhiloxStream;
use anyhow::Result;
use std::sync::atomic::{AtomicU64, Ordering};

/// Persistent-kernel asynchronous engine (one launch per run).
pub struct AsyncEngine {
    settings: ParallelSettings,
}

impl AsyncEngine {
    /// New engine on the given pool/geometry.
    pub fn new(settings: ParallelSettings) -> Self {
        Self { settings }
    }

    /// Allocate scratch/snapshots around an existing state — shared by
    /// `prepare` and `restore` so the two paths cannot drift.
    #[allow(clippy::too_many_arguments)]
    fn assemble<'a>(
        &self,
        params: &PsoParams,
        fitness: &'a dyn Fitness,
        objective: Objective,
        seed: u64,
        swarm: SwarmState,
        gbest: GlobalBest,
        mut history: Vec<(u64, f64)>,
        iter: u64,
        pbest_improvements: u64,
    ) -> AsyncStepRun<'a> {
        history.reserve(history_capacity(params.max_iter).saturating_sub(history.len()));
        let state = SharedSwarm::new(swarm);
        let blocks = self.settings.blocks_for(params.n);
        let step_scratch =
            PerBlock::from_fn(blocks, |_| StepScratch::new(self.settings.block_size));
        let snapshots = PerBlock::from_fn(blocks, |_| vec![0.0; params.dim]);

        AsyncStepRun {
            params: params.clone(),
            fitness,
            objective,
            settings: self.settings.clone(),
            seed,
            stream: PhiloxStream::new(seed),
            state,
            gbest,
            snapshots,
            step_scratch,
            pbest_improvements: AtomicU64::new(pbest_improvements),
            stride: history_stride(params.max_iter),
            history,
            iter,
        }
    }
}

impl Engine for AsyncEngine {
    fn name(&self) -> &'static str {
        "Async Persistent"
    }

    fn prepare<'a>(
        &mut self,
        params: &PsoParams,
        fitness: &'a dyn Fitness,
        objective: Objective,
        seed: u64,
    ) -> Box<dyn Run + 'a> {
        let stream = PhiloxStream::new(seed);
        let mut init = SwarmState::init(params, &stream);
        let (fit0, gi) = init.seed_fitness(fitness, objective);
        let gbest = GlobalBest::new(fit0, &init.position_of(gi));
        Box::new(self.assemble(params, fitness, objective, seed, init, gbest, Vec::new(), 0, 0))
    }

    /// Restore a suspended async step-run. **Relaxed-boundary caveat:**
    /// checkpoints of this engine are taken at grid-quiescent points (a
    /// `step`/`step_many` boundary — inside a batch the blocks free-run,
    /// so there is no mid-batch state to capture). The restored state is
    /// complete and valid, but as with any async run the continuation
    /// trajectory is not replayable: blocks may interleave differently
    /// than they would have in the uninterrupted run.
    fn restore<'a>(
        &mut self,
        ckpt: &RunCheckpoint,
        fitness: &'a dyn Fitness,
    ) -> Result<Box<dyn Run + 'a>> {
        restore_guard(ckpt, RunKind::AsyncPersistent)?;
        let gbest = GlobalBest::restore(ckpt.gbest_fit, &ckpt.gbest_pos, ckpt.counters.gbest_updates);
        Ok(Box::new(self.assemble(
            &ckpt.params,
            fitness,
            ckpt.objective,
            ckpt.seed,
            ckpt.swarm.clone(),
            gbest,
            ckpt.history.clone(),
            ckpt.iter,
            ckpt.counters.pbest_improvements,
        )))
    }

    fn run(
        &mut self,
        params: &PsoParams,
        fitness: &dyn Fitness,
        objective: Objective,
        seed: u64,
    ) -> RunOutput {
        let stream = PhiloxStream::new(seed);
        let mut init = SwarmState::init(params, &stream);
        let (fit0, gi) = init.seed_fitness(fitness, objective);
        let gbest = GlobalBest::new(fit0, &init.position_of(gi));
        let state = SharedSwarm::new(init);

        let blocks = self.settings.blocks_for(params.n);
        let step_scratch =
            PerBlock::from_fn(blocks, |_| StepScratch::new(self.settings.block_size));
        let snapshots = PerBlock::from_fn(blocks, |_| vec![0.0; params.dim]);
        // Sampled history: block 0 records the global best as it passes
        // its own iteration marks (other blocks may be ahead or behind —
        // that skew is the point of the design).
        let stride = history_stride(params.max_iter);
        let history_cells = PerBlock::from_fn(1, |_| Vec::<(u64, f64)>::new());
        let pbest_improvements = AtomicU64::new(0);

        // ---- the single persistent launch ----
        self.settings.launch(blocks, |ctx| {
            let b = ctx.block_id;
            let (lo, hi) = self.settings.block_range(b, params.n);
            // SAFETY: per-block disjoint state/scratch (see common.rs).
            let st = unsafe { state.get() };
            let ss = unsafe { step_scratch.get(b) };
            let frozen = unsafe { snapshots.get(b) };
            for iter in 0..params.max_iter {
                gbest.load_pos(frozen);
                let (best, best_i) = step_block(
                    st, lo, hi, frozen, params, fitness, objective, &stream, iter, ss,
                );
                if best_i != usize::MAX && objective.better(best, gbest.fit_relaxed()) {
                    gbest.update_locked(objective, best, |dst| st.position_into(best_i, dst));
                }
                if b == 0 && iter % stride == 0 {
                    // SAFETY: only block 0 touches the history cell.
                    unsafe { history_cells.get(0) }.push((iter, gbest.fit_relaxed()));
                }
            }
            let improved = ss.improved.iter().filter(|&&x| x).count() as u64;
            pbest_improvements.fetch_add(improved, Ordering::Relaxed);
        });

        // SAFETY: all blocks quiesced (launch returned); exclusive access.
        let mut history = std::mem::take(unsafe { history_cells.get(0) });
        history.push((params.max_iter, gbest.fit_relaxed()));

        let counters = Counters {
            particle_updates: params.n as u64 * params.max_iter,
            gbest_updates: gbest.update_count(),
            pbest_improvements: pbest_improvements.load(Ordering::Relaxed),
            ..Default::default()
        };
        RunOutput {
            gbest_fit: gbest.fit_relaxed(),
            gbest_pos: gbest.pos_vec(),
            iters: params.max_iter,
            history,
            counters,
        }
    }
}

/// Step-wise adaptation of the async engine: one launch per step with
/// per-block gbest snapshots and lock-based publication (see the module
/// docs for why the persistent kernel itself cannot be stepped).
pub struct AsyncStepRun<'a> {
    params: PsoParams,
    fitness: &'a dyn Fitness,
    objective: Objective,
    settings: ParallelSettings,
    seed: u64,
    stream: PhiloxStream,
    state: SharedSwarm,
    gbest: GlobalBest,
    snapshots: PerBlock<Vec<f64>>,
    step_scratch: PerBlock<StepScratch>,
    pbest_improvements: AtomicU64,
    stride: u64,
    history: Vec<(u64, f64)>,
    iter: u64,
}

impl Run for AsyncStepRun<'_> {
    fn iters_done(&self) -> u64 {
        self.iter
    }

    fn max_iter(&self) -> u64 {
        self.params.max_iter
    }

    fn gbest_fit(&self) -> f64 {
        self.gbest.fit_relaxed()
    }

    fn gbest_pos(&self) -> Vec<f64> {
        self.gbest.pos_vec()
    }

    fn step(&mut self) -> StepReport {
        if self.iter >= self.params.max_iter {
            return StepReport {
                iter: self.iter,
                gbest_fit: self.gbest.fit_relaxed(),
                gbest_pos: None,
                improved: false,
                done: true,
            };
        }
        let iter = self.iter;
        let updates_before = self.gbest.update_count();
        {
            let settings = &self.settings;
            let params = &self.params;
            let fitness = self.fitness;
            let objective = self.objective;
            let stream = &self.stream;
            let state = &self.state;
            let step_scratch = &self.step_scratch;
            let snapshots = &self.snapshots;
            let gbest = &self.gbest;
            let pbest_improvements = &self.pbest_improvements;
            let blocks = settings.blocks_for(params.n);
            settings.launch(blocks, |ctx| {
                let b = ctx.block_id;
                let (lo, hi) = settings.block_range(b, params.n);
                // SAFETY: per-block disjoint state/scratch (see common.rs).
                let st = unsafe { state.get() };
                let ss = unsafe { step_scratch.get(b) };
                let frozen = unsafe { snapshots.get(b) };
                gbest.load_pos(frozen);
                let (best, best_i) = step_block(
                    st, lo, hi, frozen, params, fitness, objective, stream, iter, ss,
                );
                if best_i != usize::MAX && objective.better(best, gbest.fit_relaxed()) {
                    gbest.update_locked(objective, best, |dst| st.position_into(best_i, dst));
                }
                let improved = ss.improved[..hi - lo].iter().filter(|&&x| x).count() as u64;
                pbest_improvements.fetch_add(improved, Ordering::Relaxed);
            });
        }
        self.iter += 1;
        if iter % self.stride == 0 {
            self.history.push((iter, self.gbest.fit_relaxed()));
        }
        let improved = self.gbest.update_count() > updates_before;
        StepReport {
            iter: self.iter,
            gbest_fit: self.gbest.fit_relaxed(),
            gbest_pos: improved.then(|| self.gbest.pos_vec()),
            improved,
            done: self.iter >= self.params.max_iter,
        }
    }

    /// Batched stepping in the engine's native style: ONE launch in which
    /// every block free-runs the batch's `k` iterations (re-reading the
    /// global best at each iteration top, publishing through the lock) —
    /// the per-iteration dispatch/join overhead is paid once per batch
    /// instead of once per step. Blocks of the same batch drift apart
    /// freely, which is exactly the asynchrony this engine documents for
    /// its one-shot `run`; with a single block (or `k = 1`) it is
    /// bit-identical to the default step loop. History is sampled at
    /// batch, not step, granularity: stride marks crossed inside a batch
    /// all record the post-batch global best.
    fn step_many(&mut self, k: u64) -> StepReport {
        if self.iter >= self.params.max_iter {
            return StepReport {
                iter: self.iter,
                gbest_fit: self.gbest.fit_relaxed(),
                gbest_pos: None,
                improved: false,
                done: true,
            };
        }
        let start = self.iter;
        let end = start.saturating_add(k.max(1)).min(self.params.max_iter);
        let updates_before = self.gbest.update_count();
        {
            let settings = &self.settings;
            let params = &self.params;
            let fitness = self.fitness;
            let objective = self.objective;
            let stream = &self.stream;
            let state = &self.state;
            let step_scratch = &self.step_scratch;
            let snapshots = &self.snapshots;
            let gbest = &self.gbest;
            let pbest_improvements = &self.pbest_improvements;
            let blocks = settings.blocks_for(params.n);
            settings.launch(blocks, |ctx| {
                let b = ctx.block_id;
                let (lo, hi) = settings.block_range(b, params.n);
                // SAFETY: per-block disjoint state/scratch (see common.rs).
                let st = unsafe { state.get() };
                let ss = unsafe { step_scratch.get(b) };
                let frozen = unsafe { snapshots.get(b) };
                let mut improved = 0u64;
                for iter in start..end {
                    gbest.load_pos(frozen);
                    let (best, best_i) = step_block(
                        st, lo, hi, frozen, params, fitness, objective, stream, iter, ss,
                    );
                    if best_i != usize::MAX && objective.better(best, gbest.fit_relaxed()) {
                        gbest.update_locked(objective, best, |dst| st.position_into(best_i, dst));
                    }
                    improved +=
                        ss.improved[..hi - lo].iter().filter(|&&x| x).count() as u64;
                }
                pbest_improvements.fetch_add(improved, Ordering::Relaxed);
            });
        }
        self.iter = end;
        for mark in start..end {
            if mark % self.stride == 0 {
                self.history.push((mark, self.gbest.fit_relaxed()));
            }
        }
        let improved = self.gbest.update_count() > updates_before;
        StepReport {
            iter: self.iter,
            gbest_fit: self.gbest.fit_relaxed(),
            gbest_pos: improved.then(|| self.gbest.pos_vec()),
            improved,
            done: self.iter >= self.params.max_iter,
        }
    }

    fn finish(self: Box<Self>) -> RunOutput {
        let this = *self;
        let AsyncStepRun {
            params,
            state,
            gbest,
            pbest_improvements,
            mut history,
            iter,
            ..
        } = this;
        history.push((iter, gbest.fit_relaxed()));
        let swarm = state.into_inner();
        debug_assert_eq!(swarm.check_bounds(&params), Ok(()));
        let counters = Counters {
            particle_updates: params.n as u64 * iter,
            gbest_updates: gbest.update_count(),
            pbest_improvements: pbest_improvements.load(Ordering::Relaxed),
            ..Default::default()
        };
        RunOutput {
            gbest_fit: gbest.fit_relaxed(),
            gbest_pos: gbest.pos_vec(),
            iters: iter,
            history,
            counters,
        }
    }

    fn checkpoint(&self) -> RunCheckpoint {
        // SAFETY: between steps/batches the grid has joined (that IS the
        // quiescent boundary this engine documents for checkpoints), and
        // `&mut self` stepping excludes this `&self` call.
        let swarm = unsafe { self.state.get() }.clone();
        RunCheckpoint {
            version: VERSION,
            kind: RunKind::AsyncPersistent,
            objective: self.objective,
            seed: self.seed,
            params: self.params.clone(),
            iter: self.iter,
            gbest_fit: self.gbest.fit_relaxed(),
            gbest_pos: self.gbest.pos_vec(),
            history: self.history.clone(),
            counters: Counters {
                particle_updates: self.params.n as u64 * self.iter,
                gbest_updates: self.gbest.update_count(),
                pbest_improvements: self.pbest_improvements.load(Ordering::Relaxed),
                ..Default::default()
            },
            swarm,
        }
    }

    fn into_checkpoint(self: Box<Self>) -> RunCheckpoint {
        // Suspension path: swarm and history are MOVED, never deep-copied
        // (rust/tests/zero_alloc.rs pins this).
        let this = *self;
        let counters = Counters {
            particle_updates: this.params.n as u64 * this.iter,
            gbest_updates: this.gbest.update_count(),
            pbest_improvements: this.pbest_improvements.load(Ordering::Relaxed),
            ..Default::default()
        };
        RunCheckpoint {
            version: VERSION,
            kind: RunKind::AsyncPersistent,
            objective: this.objective,
            seed: this.seed,
            iter: this.iter,
            gbest_fit: this.gbest.fit_relaxed(),
            gbest_pos: this.gbest.pos_vec(),
            history: this.history,
            counters,
            params: this.params,
            swarm: this.state.into_inner(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fitness::Cubic;

    #[test]
    fn solves_cubic_both_dims() {
        let mut e = AsyncEngine::new(ParallelSettings::with_workers(4));
        let p1 = PsoParams::paper_1d(512, 150);
        let out = e.run(&p1, &Cubic, Objective::Maximize, 1);
        assert!(out.gbest_fit > 890_000.0, "1-D gbest {}", out.gbest_fit);

        let p120 = PsoParams::paper_120d(256, 80);
        let out = e.run(&p120, &Cubic, Objective::Maximize, 2);
        let opt = 900_000.0 * 120.0;
        assert!(out.gbest_fit > 0.5 * opt, "120-D gbest {}", out.gbest_fit);
    }

    #[test]
    fn monotone_history_despite_full_asynchrony() {
        let mut e = AsyncEngine::new(ParallelSettings::with_workers(8));
        let params = PsoParams::paper_120d(1024, 60);
        let out = e.run(&params, &Cubic, Objective::Maximize, 3);
        for w in out.history.windows(2) {
            assert!(w[1].1 >= w[0].1, "gbest worsened: {w:?}");
        }
    }

    #[test]
    fn single_block_reduces_to_queue_lock_semantics() {
        // With one block there is no asynchrony: identical to Queue-Lock
        // (and hence to the synchronous oracle).
        let params = PsoParams::paper_1d(200, 50);
        let settings = ParallelSettings::with_workers(4);
        let oracle = crate::pso::serial_sync::run(&params, &Cubic, Objective::Maximize, 7);
        let mut e = AsyncEngine::new(settings);
        let out = e.run(&params, &Cubic, Objective::Maximize, 7);
        assert_eq!(out.gbest_fit, oracle.gbest_fit);
        assert_eq!(out.gbest_pos, oracle.gbest_pos);
    }

    #[test]
    fn stepwise_single_block_matches_oracle() {
        // The step-wise adaptation barriers every iteration; with a single
        // block it is bit-exact against the synchronous reference.
        let params = PsoParams::paper_1d(200, 50);
        let oracle = crate::pso::serial_sync::run(&params, &Cubic, Objective::Maximize, 7);
        let mut e = AsyncEngine::new(ParallelSettings::with_workers(4));
        let mut run = e.prepare(&params, &Cubic, Objective::Maximize, 7);
        while !run.step().done {}
        let out = run.finish();
        assert_eq!(out.gbest_fit, oracle.gbest_fit);
        assert_eq!(out.gbest_pos, oracle.gbest_pos);
    }
}
