//! The Reduction and Loop-Unrolling engines (§3.2) — the state of the art
//! the paper compares against ([3] and the Harris reduction).
//!
//! Per iteration:
//! 1. **1st kernel** (one launch): every block steps its particles, then
//!    copies the fresh fitness values into a per-block scratch array and
//!    tree-reduces it (`s = bs/2, bs/4, …, 1` — the full `O(log n)` memory
//!    traffic the queue algorithm avoids), writing the block best to the
//!    aux arrays `(auxFit[b], auxIdx[b])`.
//! 2. **2nd kernel** (second launch = the implicit inter-kernel barrier):
//!    a single block tree-reduces the aux arrays and updates the global
//!    best.
//!
//! The Loop-Unrolling variant replaces the last reduction levels
//! (`s ≤ 32`) with straight-line code — the warp-unrolling optimization of
//! the Harris notes, which removes loop/branch overhead but none of the
//! memory traffic or the inter-kernel synchronization.
//!
//! Both variants are step-wise ([`Engine::prepare`] → [`ReductionRun`]):
//! every buffer is allocated once in `prepare` and reused across steps.

use super::common::{step_block, GlobalBest, ParallelSettings, PerBlock, SharedSwarm, StepScratch};
use super::{restore_guard, Engine, Run, StepReport};
use crate::checkpoint::{RunCheckpoint, RunKind, VERSION};
use crate::fitness::{Fitness, Objective};
use crate::pso::{history_capacity, history_stride, Counters, PsoParams, RunOutput, SwarmState};
use crate::rng::PhiloxStream;
use anyhow::Result;

/// Per-block reduction scratch (`bestFit` / index arrays in shared memory).
struct Scratch {
    fits: Vec<f64>,
    idxs: Vec<u32>,
}

/// Tree-reduce `m` live entries (scratch is padded to a power of two with
/// the objective's worst). Winner lands in slot 0. `unrolled` switches the
/// final levels to straight-line code.
fn reduce_tree(scratch: &mut Scratch, m: usize, objective: Objective, unrolled: bool) -> (f64, u32) {
    use crate::pso::serial_sync::better_with_tie;
    let len = m.next_power_of_two();
    let (fits, idxs) = (&mut scratch.fits, &mut scratch.idxs);

    /// One reduction level: fold `[j + s]` into `[j]`. A NaN incumbent
    /// yields to any non-NaN candidate: the strict comparison alone would
    /// silently *retain* NaN (both orderings are false against it) and
    /// discard finite values folded into that slot — unlike the linear
    /// scans of the serial references and the Queue engines, which NaN
    /// can never enter. This keeps the tree's NaN behavior identical to
    /// theirs (see the NaN policy in `crate::fitness`).
    macro_rules! level {
        ($s:expr) => {
            let s = $s;
            for j in 0..s {
                if better_with_tie(
                    objective,
                    fits[j + s],
                    idxs[j + s] as usize,
                    fits[j],
                    idxs[j] as usize,
                ) || (fits[j].is_nan() && !fits[j + s].is_nan())
                {
                    fits[j] = fits[j + s];
                    idxs[j] = idxs[j + s];
                }
            }
        };
    }

    let mut s = len / 2;
    while s > 32 {
        level!(s);
        s /= 2;
    }
    if unrolled {
        // The Harris-style unrolled tail: no loop bookkeeping for s ≤ 32.
        if s >= 32 {
            level!(32);
        }
        if s >= 16 {
            level!(16);
        }
        if s >= 8 {
            level!(8);
        }
        if s >= 4 {
            level!(4);
        }
        if s >= 2 {
            level!(2);
        }
        if s >= 1 {
            level!(1);
        }
    } else {
        while s >= 1 {
            level!(s);
            s /= 2;
        }
    }
    (fits[0], idxs[0])
}

/// The Reduction / Loop-Unrolling engine.
pub struct ReductionEngine {
    settings: ParallelSettings,
    unrolled: bool,
}

impl ReductionEngine {
    /// Plain parallel reduction (the paper's "Reduction" column).
    pub fn new(settings: ParallelSettings) -> Self {
        Self {
            settings,
            unrolled: false,
        }
    }

    /// Unrolled final levels (the paper's "Loop Unrolling" column).
    pub fn unrolled(settings: ParallelSettings) -> Self {
        Self {
            settings,
            unrolled: true,
        }
    }

    /// The checkpoint kind this engine variant produces/restores.
    fn kind(&self) -> RunKind {
        if self.unrolled {
            RunKind::LoopUnrolling
        } else {
            RunKind::Reduction
        }
    }

    /// Allocate every per-run buffer around an existing swarm/global-best
    /// state — shared by `prepare` (freshly seeded state) and `restore`
    /// (state from a checkpoint), so the two paths cannot drift.
    #[allow(clippy::too_many_arguments)]
    fn assemble<'a>(
        &self,
        params: &PsoParams,
        fitness: &'a dyn Fitness,
        objective: Objective,
        seed: u64,
        swarm: SwarmState,
        gbest: GlobalBest,
        mut history: Vec<(u64, f64)>,
        iter: u64,
    ) -> ReductionRun<'a> {
        history.reserve(history_capacity(params.max_iter).saturating_sub(history.len()));
        let state = SharedSwarm::new(swarm);
        let blocks = self.settings.blocks_for(params.n);
        let pad = self.settings.block_size.next_power_of_two();
        let scratch = PerBlock::from_fn(blocks, |_| Scratch {
            fits: vec![objective.worst(); pad],
            idxs: vec![u32::MAX; pad],
        });
        let step_scratch =
            PerBlock::from_fn(blocks, |_| StepScratch::new(self.settings.block_size));
        // aux arrays: (auxFit[b], auxIdx[b]) + 2nd-kernel scratch.
        let aux = PerBlock::from_fn(blocks, |_| (objective.worst(), u32::MAX));
        let aux_pad = blocks.next_power_of_two();
        let k2_scratch = PerBlock::from_fn(1, |_| Scratch {
            fits: vec![objective.worst(); aux_pad],
            idxs: vec![u32::MAX; aux_pad],
        });

        let frozen = gbest.pos_vec();
        ReductionRun {
            params: params.clone(),
            fitness,
            objective,
            settings: self.settings.clone(),
            unrolled: self.unrolled,
            seed,
            stream: PhiloxStream::new(seed),
            state,
            gbest,
            scratch,
            step_scratch,
            aux,
            k2_scratch,
            frozen,
            stride: history_stride(params.max_iter),
            history,
            iter,
        }
    }
}

impl Engine for ReductionEngine {
    fn name(&self) -> &'static str {
        if self.unrolled {
            "Loop Unrolling"
        } else {
            "Reduction"
        }
    }

    fn prepare<'a>(
        &mut self,
        params: &PsoParams,
        fitness: &'a dyn Fitness,
        objective: Objective,
        seed: u64,
    ) -> Box<dyn Run + 'a> {
        let stream = PhiloxStream::new(seed);
        let mut init = SwarmState::init(params, &stream);
        let (fit0, gi) = init.seed_fitness(fitness, objective);
        let gbest = GlobalBest::new(fit0, &init.position_of(gi));
        Box::new(self.assemble(params, fitness, objective, seed, init, gbest, Vec::new(), 0))
    }

    fn restore<'a>(
        &mut self,
        ckpt: &RunCheckpoint,
        fitness: &'a dyn Fitness,
    ) -> Result<Box<dyn Run + 'a>> {
        restore_guard(ckpt, self.kind())?;
        let gbest = GlobalBest::restore(ckpt.gbest_fit, &ckpt.gbest_pos, ckpt.counters.gbest_updates);
        Ok(Box::new(self.assemble(
            &ckpt.params,
            fitness,
            ckpt.objective,
            ckpt.seed,
            ckpt.swarm.clone(),
            gbest,
            ckpt.history.clone(),
            ckpt.iter,
        )))
    }
}

/// A prepared Reduction / Loop-Unrolling run: the swarm, both kernels'
/// scratch, and the aux arrays live here for the run's whole lifetime.
pub struct ReductionRun<'a> {
    params: PsoParams,
    fitness: &'a dyn Fitness,
    objective: Objective,
    settings: ParallelSettings,
    unrolled: bool,
    seed: u64,
    stream: PhiloxStream,
    state: SharedSwarm,
    gbest: GlobalBest,
    scratch: PerBlock<Scratch>,
    step_scratch: PerBlock<StepScratch>,
    aux: PerBlock<(f64, u32)>,
    k2_scratch: PerBlock<Scratch>,
    frozen: Vec<f64>,
    stride: u64,
    history: Vec<(u64, f64)>,
    iter: u64,
}

impl Run for ReductionRun<'_> {
    fn iters_done(&self) -> u64 {
        self.iter
    }

    fn max_iter(&self) -> u64 {
        self.params.max_iter
    }

    fn gbest_fit(&self) -> f64 {
        self.gbest.fit_relaxed()
    }

    fn gbest_pos(&self) -> Vec<f64> {
        self.gbest.pos_vec()
    }

    fn step(&mut self) -> StepReport {
        if self.iter >= self.params.max_iter {
            return StepReport {
                iter: self.iter,
                gbest_fit: self.gbest.fit_relaxed(),
                gbest_pos: None,
                improved: false,
                done: true,
            };
        }
        let iter = self.iter;
        let updates_before = self.gbest.update_count();
        self.gbest.load_pos(&mut self.frozen);
        {
            let settings = &self.settings;
            let params = &self.params;
            let fitness = self.fitness;
            let objective = self.objective;
            let unrolled = self.unrolled;
            let stream = &self.stream;
            let state = &self.state;
            let step_scratch = &self.step_scratch;
            let scratch = &self.scratch;
            let aux = &self.aux;
            let k2_scratch = &self.k2_scratch;
            let gbest = &self.gbest;
            let frozen_ref = &self.frozen;
            let blocks = settings.blocks_for(params.n);
            // ---- 1st kernel: step + intra-block reduction -> aux ----
            settings.launch(blocks, |ctx| {
                let b = ctx.block_id;
                let (lo, hi) = settings.block_range(b, params.n);
                // SAFETY: this block only touches particles [lo, hi).
                let st = unsafe { state.get() };
                let ss = unsafe { step_scratch.get(b) };
                step_block(
                    st, lo, hi, frozen_ref, params, fitness, objective, stream, iter, ss,
                );
                // Copy fits to shared-memory scratch and tree-reduce —
                // the full O(bs) traffic + O(log bs) passes of the
                // reduction approach, paid EVERY iteration.
                // SAFETY: scratch[b] is this block's own.
                let sc = unsafe { scratch.get(b) };
                let m = hi - lo;
                let len = m.next_power_of_two();
                for k in 0..m {
                    sc.fits[k] = st.fit[lo + k];
                    sc.idxs[k] = (lo + k) as u32;
                }
                for k in m..len {
                    sc.fits[k] = objective.worst();
                    sc.idxs[k] = u32::MAX;
                }
                let (bf, bi) = reduce_tree(sc, m, objective, unrolled);
                // SAFETY: aux[b] is this block's own slot.
                unsafe { *aux.get(b) = (bf, bi) };
            });
            // ---- 2nd kernel: single block reduces aux -> global best ----
            settings.launch(1, |_| {
                debug_assert!(!aux.is_empty());
                // SAFETY: all 1st-kernel blocks joined; single block here.
                let sc = unsafe { k2_scratch.get(0) };
                let blocks = aux.len();
                let aux_pad = blocks.next_power_of_two();
                for b in 0..blocks {
                    let (f, i) = unsafe { *aux.get(b) };
                    sc.fits[b] = f;
                    sc.idxs[b] = i;
                }
                for b in blocks..aux_pad {
                    sc.fits[b] = objective.worst();
                    sc.idxs[b] = u32::MAX;
                }
                let (bf, bi) = reduce_tree(sc, blocks, objective, unrolled);
                if bi != u32::MAX {
                    // SAFETY: read-only position access after the update
                    // kernel joined (single reducer block).
                    let st = unsafe { state.get() };
                    gbest.update_exclusive(objective, bf, |dst| {
                        st.position_into(bi as usize, dst)
                    });
                }
            });
        }
        self.iter += 1;
        if iter % self.stride == 0 {
            self.history.push((iter, self.gbest.fit_relaxed()));
        }
        let improved = self.gbest.update_count() > updates_before;
        StepReport {
            iter: self.iter,
            gbest_fit: self.gbest.fit_relaxed(),
            gbest_pos: improved.then(|| self.gbest.pos_vec()),
            improved,
            done: self.iter >= self.params.max_iter,
        }
    }

    fn finish(self: Box<Self>) -> RunOutput {
        let this = *self;
        let ReductionRun {
            params,
            state,
            gbest,
            mut history,
            iter,
            ..
        } = this;
        history.push((iter, gbest.fit_relaxed()));
        let swarm = state.into_inner();
        debug_assert_eq!(swarm.check_bounds(&params), Ok(()));
        let counters = Counters {
            particle_updates: params.n as u64 * iter,
            gbest_updates: gbest.update_count(),
            ..Default::default()
        };
        RunOutput {
            gbest_fit: gbest.fit_relaxed(),
            gbest_pos: gbest.pos_vec(),
            iters: iter,
            history,
            counters,
        }
    }

    fn checkpoint(&self) -> RunCheckpoint {
        // SAFETY: between steps every launched block has joined, and
        // `&mut self` stepping excludes this `&self` call, so the swarm is
        // quiescent and fully visible.
        let swarm = unsafe { self.state.get() }.clone();
        RunCheckpoint {
            version: VERSION,
            kind: if self.unrolled {
                RunKind::LoopUnrolling
            } else {
                RunKind::Reduction
            },
            objective: self.objective,
            seed: self.seed,
            params: self.params.clone(),
            iter: self.iter,
            gbest_fit: self.gbest.fit_relaxed(),
            gbest_pos: self.gbest.pos_vec(),
            history: self.history.clone(),
            counters: Counters {
                particle_updates: self.params.n as u64 * self.iter,
                gbest_updates: self.gbest.update_count(),
                ..Default::default()
            },
            swarm,
        }
    }

    fn into_checkpoint(self: Box<Self>) -> RunCheckpoint {
        // Suspension path: swarm and history are MOVED, never deep-copied
        // (rust/tests/zero_alloc.rs pins this).
        let this = *self;
        let counters = Counters {
            particle_updates: this.params.n as u64 * this.iter,
            gbest_updates: this.gbest.update_count(),
            ..Default::default()
        };
        RunCheckpoint {
            version: VERSION,
            kind: if this.unrolled {
                RunKind::LoopUnrolling
            } else {
                RunKind::Reduction
            },
            objective: this.objective,
            seed: this.seed,
            iter: this.iter,
            gbest_fit: this.gbest.fit_relaxed(),
            gbest_pos: this.gbest.pos_vec(),
            history: this.history,
            counters,
            params: this.params,
            swarm: this.state.into_inner(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fitness::Cubic;

    fn scratch_from(vals: &[f64]) -> Scratch {
        let len = vals.len().next_power_of_two();
        let mut fits = vec![f64::NEG_INFINITY; len];
        let mut idxs = vec![u32::MAX; len];
        for (i, &v) in vals.iter().enumerate() {
            fits[i] = v;
            idxs[i] = i as u32;
        }
        Scratch { fits, idxs }
    }

    #[test]
    fn tree_reduce_finds_argmax_with_tie_break() {
        for unrolled in [false, true] {
            let mut sc = scratch_from(&[1.0, 7.0, 7.0, 3.0, -2.0]);
            let (f, i) = reduce_tree(&mut sc, 5, Objective::Maximize, unrolled);
            assert_eq!(f, 7.0);
            assert_eq!(i, 1, "tie must go to the lower index (unrolled={unrolled})");
        }
    }

    #[test]
    fn tree_reduce_never_lets_nan_shadow_finite_values() {
        // A NaN that lands in a fold slot must not eat the finite value
        // folded into it (the strict comparison is false both ways
        // against NaN, which would silently retain it).
        for unrolled in [false, true] {
            let mut sc = scratch_from(&[f64::NAN, 5.0, f64::NAN, 3.0]);
            let (f, i) = reduce_tree(&mut sc, 4, Objective::Maximize, unrolled);
            assert_eq!((f, i), (5.0, 1), "unrolled={unrolled}");
            // All-NaN input: the winner is NaN (rejected downstream by
            // the strict gbest comparison), never a fabricated number.
            let mut sc = scratch_from(&[f64::NAN, f64::NAN]);
            let (f, _) = reduce_tree(&mut sc, 2, Objective::Maximize, unrolled);
            assert!(f.is_nan() || f == f64::NEG_INFINITY, "unrolled={unrolled}");
        }
    }

    #[test]
    fn tree_reduce_single_element() {
        let mut sc = scratch_from(&[4.2]);
        assert_eq!(reduce_tree(&mut sc, 1, Objective::Maximize, true), (4.2, 0));
    }

    #[test]
    fn tree_reduce_large_random_matches_linear_scan() {
        use crate::rng::{RngEngine, Xoshiro256pp};
        let mut rng = Xoshiro256pp::seeded(1);
        for unrolled in [false, true] {
            for m in [2usize, 31, 32, 33, 255, 256, 257, 1000] {
                let vals: Vec<f64> = (0..m).map(|_| rng.uniform(-1e6, 1e6)).collect();
                let mut sc = scratch_from(&vals);
                let (f, i) = reduce_tree(&mut sc, m, Objective::Maximize, unrolled);
                let (li, lf) = vals
                    .iter()
                    .enumerate()
                    .fold((usize::MAX, f64::NEG_INFINITY), |(bi, bf), (j, &v)| {
                        if v > bf {
                            (j, v)
                        } else {
                            (bi, bf)
                        }
                    });
                assert_eq!((f, i as usize), (lf, li), "m={m} unrolled={unrolled}");
            }
        }
    }

    #[test]
    fn engine_solves_and_both_variants_agree() {
        let params = PsoParams::paper_1d(300, 80);
        let s1 = ParallelSettings::with_workers(4);
        let mut plain = ReductionEngine::new(s1.clone());
        let mut unrl = ReductionEngine::unrolled(s1);
        let a = plain.run(&params, &Cubic, Objective::Maximize, 9);
        let b = unrl.run(&params, &Cubic, Objective::Maximize, 9);
        assert_eq!(a.gbest_fit, b.gbest_fit, "unrolling must not change results");
        assert_eq!(a.gbest_pos, b.gbest_pos);
        assert!(a.gbest_fit > 890_000.0);
    }

    #[test]
    fn stepwise_matches_one_shot() {
        let params = PsoParams::paper_1d(300, 40);
        let settings = ParallelSettings::with_workers(4);
        let one_shot =
            ReductionEngine::new(settings.clone()).run(&params, &Cubic, Objective::Maximize, 5);
        let mut engine = ReductionEngine::new(settings);
        let mut run = engine.prepare(&params, &Cubic, Objective::Maximize, 5);
        while !run.step().done {}
        let stepped = run.finish();
        assert_eq!(stepped.gbest_fit, one_shot.gbest_fit);
        assert_eq!(stepped.gbest_pos, one_shot.gbest_pos);
        assert_eq!(stepped.history, one_shot.history);
        assert_eq!(stepped.iters, one_shot.iters);
    }
}
