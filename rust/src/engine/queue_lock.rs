//! The Queue-Lock engine — Algorithm 2 + Algorithm 3 fused (§4.2), the
//! paper's second contribution.
//!
//! The two kernels are fused into one launch per iteration: each block
//! steps its particles with the conditional queue exactly as the Queue
//! engine, but instead of writing its block best to aux arrays and
//! waiting for a second kernel, it immediately compares against the
//! global best and — only when better — takes the CAS spin lock and
//! updates `(gbest_fit, gbest_pos)` in place (Algorithm 3). This removes
//! the aux-array traffic *and* the inter-kernel barrier; blocks of the
//! same iteration run unsynchronized against each other, which is the
//! paper's documented relaxation ("no bad side effect", best for 1-D).
//!
//! Step-wise: [`Engine::prepare`] allocates queues, snapshots and scratch
//! once ([`QueueLockRun`]); each [`Run::step`] is the single fused launch.

use super::common::{step_block, GlobalBest, ParallelSettings, PerBlock, SharedSwarm, StepScratch};
use super::{restore_guard, Engine, Run, StepReport};
use crate::checkpoint::{RunCheckpoint, RunKind, VERSION};
use crate::exec::SharedQueue;
use crate::fitness::{Fitness, Objective};
use crate::pso::serial_sync::better_with_tie;
use crate::pso::{history_capacity, history_stride, Counters, PsoParams, RunOutput, SwarmState};
use crate::rng::PhiloxStream;
use anyhow::Result;

/// The fused Queue-Lock engine (one kernel per iteration).
pub struct QueueLockEngine {
    settings: ParallelSettings,
}

impl QueueLockEngine {
    /// New engine on the given pool/geometry.
    pub fn new(settings: ParallelSettings) -> Self {
        Self { settings }
    }

    /// Allocate queues/snapshots/scratch around an existing state —
    /// shared by `prepare` and `restore` so the two paths cannot drift.
    #[allow(clippy::too_many_arguments)]
    fn assemble<'a>(
        &self,
        params: &PsoParams,
        fitness: &'a dyn Fitness,
        objective: Objective,
        seed: u64,
        swarm: SwarmState,
        gbest: GlobalBest,
        mut history: Vec<(u64, f64)>,
        iter: u64,
        push_base: u64,
    ) -> QueueLockRun<'a> {
        history.reserve(history_capacity(params.max_iter).saturating_sub(history.len()));
        let state = SharedSwarm::new(swarm);
        let blocks = self.settings.blocks_for(params.n);
        let queues: Vec<SharedQueue<(f64, u32)>> = (0..blocks)
            .map(|_| SharedQueue::new(self.settings.block_size))
            .collect();
        // Per-block gbest_pos snapshot buffer: in the fused kernel the
        // global position can be updated by another block mid-iteration
        // (the paper's benign race); each block snapshots at its start.
        let snapshots = PerBlock::from_fn(blocks, |_| vec![0.0; params.dim]);
        let step_scratch =
            PerBlock::from_fn(blocks, |_| StepScratch::new(self.settings.block_size));

        QueueLockRun {
            params: params.clone(),
            fitness,
            objective,
            settings: self.settings.clone(),
            seed,
            stream: PhiloxStream::new(seed),
            state,
            gbest,
            queues,
            snapshots,
            step_scratch,
            push_base,
            stride: history_stride(params.max_iter),
            history,
            iter,
        }
    }
}

impl Engine for QueueLockEngine {
    fn name(&self) -> &'static str {
        "Queue Lock"
    }

    fn prepare<'a>(
        &mut self,
        params: &PsoParams,
        fitness: &'a dyn Fitness,
        objective: Objective,
        seed: u64,
    ) -> Box<dyn Run + 'a> {
        let stream = PhiloxStream::new(seed);
        let mut init = SwarmState::init(params, &stream);
        let (fit0, gi) = init.seed_fitness(fitness, objective);
        let gbest = GlobalBest::new(fit0, &init.position_of(gi));
        Box::new(self.assemble(params, fitness, objective, seed, init, gbest, Vec::new(), 0, 0))
    }

    /// Restore a suspended Queue-Lock run. Checkpoints are only ever
    /// taken at step boundaries (grid quiescent), so the captured state
    /// is complete and consistent; the engine's documented intra-run race
    /// means the *continuation* may differ run-to-run, exactly as an
    /// uninterrupted Queue-Lock run may.
    fn restore<'a>(
        &mut self,
        ckpt: &RunCheckpoint,
        fitness: &'a dyn Fitness,
    ) -> Result<Box<dyn Run + 'a>> {
        restore_guard(ckpt, RunKind::QueueLock)?;
        let gbest = GlobalBest::restore(ckpt.gbest_fit, &ckpt.gbest_pos, ckpt.counters.gbest_updates);
        Ok(Box::new(self.assemble(
            &ckpt.params,
            fitness,
            ckpt.objective,
            ckpt.seed,
            ckpt.swarm.clone(),
            gbest,
            ckpt.history.clone(),
            ckpt.iter,
            ckpt.counters.queue_pushes,
        )))
    }
}

/// A prepared Queue-Lock run (fused kernel, per-block snapshots).
pub struct QueueLockRun<'a> {
    params: PsoParams,
    fitness: &'a dyn Fitness,
    objective: Objective,
    settings: ParallelSettings,
    seed: u64,
    stream: PhiloxStream,
    state: SharedSwarm,
    gbest: GlobalBest,
    queues: Vec<SharedQueue<(f64, u32)>>,
    snapshots: PerBlock<Vec<f64>>,
    step_scratch: PerBlock<StepScratch>,
    /// Queue pushes accumulated before the last restore.
    push_base: u64,
    stride: u64,
    history: Vec<(u64, f64)>,
    iter: u64,
}

impl Run for QueueLockRun<'_> {
    fn iters_done(&self) -> u64 {
        self.iter
    }

    fn max_iter(&self) -> u64 {
        self.params.max_iter
    }

    fn gbest_fit(&self) -> f64 {
        self.gbest.fit_relaxed()
    }

    fn gbest_pos(&self) -> Vec<f64> {
        self.gbest.pos_vec()
    }

    fn step(&mut self) -> StepReport {
        if self.iter >= self.params.max_iter {
            return StepReport {
                iter: self.iter,
                gbest_fit: self.gbest.fit_relaxed(),
                gbest_pos: None,
                improved: false,
                done: true,
            };
        }
        let iter = self.iter;
        let updates_before = self.gbest.update_count();
        {
            let settings = &self.settings;
            let params = &self.params;
            let fitness = self.fitness;
            let objective = self.objective;
            let stream = &self.stream;
            let state = &self.state;
            let step_scratch = &self.step_scratch;
            let queues = &self.queues;
            let snapshots = &self.snapshots;
            let gbest = &self.gbest;
            let blocks = settings.blocks_for(params.n);
            // ---- single fused kernel ----
            settings.launch(blocks, |ctx| {
                let b = ctx.block_id;
                let (lo, hi) = settings.block_range(b, params.n);
                let q = &queues[b];
                q.reset();
                // SAFETY: snapshot buffer b belongs to this block.
                let frozen = unsafe { snapshots.get(b) };
                gbest.load_pos(frozen);
                let threshold = gbest.fit_relaxed();
                // SAFETY: this block only touches particles [lo, hi).
                let st = unsafe { state.get() };
                let ss = unsafe { step_scratch.get(b) };
                step_block(
                    st, lo, hi, frozen, params, fitness, objective, stream, iter, ss,
                );
                for k in 0..(hi - lo) {
                    let fit = ss.fit[k];
                    if objective.better(fit, threshold) {
                        q.push((fit, (lo + k) as u32));
                    }
                }
                // Thread-0 scan of the block queue…
                let mut best = (objective.worst(), u32::MAX);
                q.scan(|&(f, i)| {
                    if better_with_tie(objective, f, i as usize, best.0, best.1 as usize) {
                        best = (f, i);
                    }
                });
                // …then Algorithm 3: lock + re-check + in-place update,
                // replacing the aux-array write and the 2nd kernel.
                if best.1 != u32::MAX {
                    gbest.update_locked(objective, best.0, |dst| {
                        st.position_into(best.1 as usize, dst)
                    });
                }
            });
        }
        self.iter += 1;
        if iter % self.stride == 0 {
            self.history.push((iter, self.gbest.fit_relaxed()));
        }
        let improved = self.gbest.update_count() > updates_before;
        StepReport {
            iter: self.iter,
            gbest_fit: self.gbest.fit_relaxed(),
            gbest_pos: improved.then(|| self.gbest.pos_vec()),
            improved,
            done: self.iter >= self.params.max_iter,
        }
    }

    fn finish(self: Box<Self>) -> RunOutput {
        let this = *self;
        let QueueLockRun {
            params,
            state,
            gbest,
            queues,
            push_base,
            mut history,
            iter,
            ..
        } = this;
        history.push((iter, gbest.fit_relaxed()));
        let swarm = state.into_inner();
        debug_assert_eq!(swarm.check_bounds(&params), Ok(()));
        let counters = Counters {
            particle_updates: params.n as u64 * iter,
            queue_pushes: push_base + queues.iter().map(|q| q.total_pushes()).sum::<u64>(),
            gbest_updates: gbest.update_count(),
            ..Default::default()
        };
        RunOutput {
            gbest_fit: gbest.fit_relaxed(),
            gbest_pos: gbest.pos_vec(),
            iters: iter,
            history,
            counters,
        }
    }

    fn checkpoint(&self) -> RunCheckpoint {
        // SAFETY: between steps the fused kernel's grid has joined, and
        // `&mut self` stepping excludes this `&self` call — the paper's
        // intra-iteration race is quiesced at every step boundary.
        let swarm = unsafe { self.state.get() }.clone();
        RunCheckpoint {
            version: VERSION,
            kind: RunKind::QueueLock,
            objective: self.objective,
            seed: self.seed,
            params: self.params.clone(),
            iter: self.iter,
            gbest_fit: self.gbest.fit_relaxed(),
            gbest_pos: self.gbest.pos_vec(),
            history: self.history.clone(),
            counters: Counters {
                particle_updates: self.params.n as u64 * self.iter,
                queue_pushes: self.push_base
                    + self.queues.iter().map(|q| q.total_pushes()).sum::<u64>(),
                gbest_updates: self.gbest.update_count(),
                ..Default::default()
            },
            swarm,
        }
    }

    fn into_checkpoint(self: Box<Self>) -> RunCheckpoint {
        // Suspension path: swarm and history are MOVED, never deep-copied
        // (rust/tests/zero_alloc.rs pins this).
        let this = *self;
        let counters = Counters {
            particle_updates: this.params.n as u64 * this.iter,
            queue_pushes: this.push_base
                + this.queues.iter().map(|q| q.total_pushes()).sum::<u64>(),
            gbest_updates: this.gbest.update_count(),
            ..Default::default()
        };
        RunCheckpoint {
            version: VERSION,
            kind: RunKind::QueueLock,
            objective: this.objective,
            seed: this.seed,
            iter: this.iter,
            gbest_fit: this.gbest.fit_relaxed(),
            gbest_pos: this.gbest.pos_vec(),
            history: this.history,
            counters,
            params: this.params,
            swarm: this.state.into_inner(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fitness::Cubic;

    #[test]
    fn solves_cubic_1d() {
        let params = PsoParams::paper_1d(512, 100);
        let mut e = QueueLockEngine::new(ParallelSettings::with_workers(4));
        let out = e.run(&params, &Cubic, Objective::Maximize, 1);
        assert!(out.gbest_fit > 890_000.0, "gbest {}", out.gbest_fit);
        assert!((out.gbest_pos[0] - 100.0).abs() < 2.0);
    }

    #[test]
    fn monotone_despite_relaxed_sync() {
        let params = PsoParams::paper_120d(128, 60);
        let mut e = QueueLockEngine::new(ParallelSettings::with_workers(8));
        let out = e.run(&params, &Cubic, Objective::Maximize, 2);
        for w in out.history.windows(2) {
            assert!(w[1].1 >= w[0].1, "gbest must never worsen");
        }
    }

    #[test]
    fn lock_taken_rarely() {
        // The whole point: the lock serializes only improvements, which
        // are rare relative to particle updates.
        let params = PsoParams::paper_1d(1024, 200);
        let mut e = QueueLockEngine::new(ParallelSettings::with_workers(4));
        let out = e.run(&params, &Cubic, Objective::Maximize, 7);
        let updates = out.counters.particle_updates;
        assert!(
            out.counters.gbest_updates * 50 < updates,
            "gbest updates {} vs particle updates {}",
            out.counters.gbest_updates,
            updates
        );
    }
}
