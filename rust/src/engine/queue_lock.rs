//! The Queue-Lock engine — Algorithm 2 + Algorithm 3 fused (§4.2), the
//! paper's second contribution.
//!
//! The two kernels are fused into one launch per iteration: each block
//! steps its particles with the conditional queue exactly as the Queue
//! engine, but instead of writing its block best to aux arrays and
//! waiting for a second kernel, it immediately compares against the
//! global best and — only when better — takes the CAS spin lock and
//! updates `(gbest_fit, gbest_pos)` in place (Algorithm 3). This removes
//! the aux-array traffic *and* the inter-kernel barrier; blocks of the
//! same iteration run unsynchronized against each other, which is the
//! paper's documented relaxation ("no bad side effect", best for 1-D).

use super::common::{step_block, GlobalBest, ParallelSettings, SharedSwarm, StepScratch};
use super::Engine;
use crate::exec::SharedQueue;
use crate::fitness::{Fitness, Objective};
use crate::pso::serial_sync::better_with_tie;
use crate::pso::{history_stride, Counters, PsoParams, RunOutput, SwarmState};
use crate::rng::PhiloxStream;

/// The fused Queue-Lock engine (one kernel per iteration).
pub struct QueueLockEngine {
    settings: ParallelSettings,
}

impl QueueLockEngine {
    /// New engine on the given pool/geometry.
    pub fn new(settings: ParallelSettings) -> Self {
        Self { settings }
    }
}

impl Engine for QueueLockEngine {
    fn name(&self) -> &'static str {
        "Queue Lock"
    }

    fn run(
        &mut self,
        params: &PsoParams,
        fitness: &dyn Fitness,
        objective: Objective,
        seed: u64,
    ) -> RunOutput {
        let stream = PhiloxStream::new(seed);
        let mut init = SwarmState::init(params, &stream);
        let (fit0, gi) = init.seed_fitness(fitness, objective);
        let gbest = GlobalBest::new(fit0, &init.position_of(gi));
        let state = SharedSwarm::new(init);

        let blocks = self.settings.blocks_for(params.n);
        let queues: Vec<SharedQueue<(f64, u32)>> = (0..blocks)
            .map(|_| SharedQueue::new(self.settings.block_size))
            .collect();

        let stride = history_stride(params.max_iter);
        let mut history = Vec::new();
        // Per-block gbest_pos snapshot buffer: in the fused kernel the
        // global position can be updated by another block mid-iteration
        // (the paper's benign race); each block snapshots at its start.
        let snapshots = super::common::PerBlock::from_fn(blocks, |_| vec![0.0; params.dim]);
        let step_scratch = super::common::PerBlock::from_fn(blocks, |_| {
            StepScratch::new(self.settings.block_size)
        });

        for iter in 0..params.max_iter {
            // ---- single fused kernel ----
            self.settings.pool.launch(blocks, |ctx| {
                let b = ctx.block_id;
                let (lo, hi) = self.settings.block_range(b, params.n);
                let q = &queues[b];
                q.reset();
                // SAFETY: snapshot buffer b belongs to this block.
                let frozen = unsafe { snapshots.get(b) };
                gbest.load_pos(frozen);
                let threshold = gbest.fit_relaxed();
                // SAFETY: this block only touches particles [lo, hi).
                let st = unsafe { state.get() };
                let ss = unsafe { step_scratch.get(b) };
                step_block(
                    st, lo, hi, frozen, params, fitness, objective, &stream, iter, ss,
                );
                for k in 0..(hi - lo) {
                    let fit = ss.fit[k];
                    if objective.better(fit, threshold) {
                        q.push((fit, (lo + k) as u32));
                    }
                }
                // Thread-0 scan of the block queue…
                let mut best = (objective.worst(), u32::MAX);
                q.scan(|&(f, i)| {
                    if better_with_tie(objective, f, i as usize, best.0, best.1 as usize) {
                        best = (f, i);
                    }
                });
                // …then Algorithm 3: lock + re-check + in-place update,
                // replacing the aux-array write and the 2nd kernel.
                if best.1 != u32::MAX {
                    gbest.update_locked(objective, best.0, || {
                        st.position_of(best.1 as usize)
                    });
                }
            });
            if iter % stride == 0 {
                history.push((iter, gbest.fit_relaxed()));
            }
        }
        history.push((params.max_iter, gbest.fit_relaxed()));

        let counters = Counters {
            particle_updates: params.n as u64 * params.max_iter,
            queue_pushes: queues.iter().map(|q| q.total_pushes()).sum(),
            gbest_updates: gbest.update_count(),
            ..Default::default()
        };
        RunOutput {
            gbest_fit: gbest.fit_relaxed(),
            gbest_pos: gbest.pos_vec(),
            iters: params.max_iter,
            history,
            counters,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fitness::Cubic;

    #[test]
    fn solves_cubic_1d() {
        let params = PsoParams::paper_1d(512, 100);
        let mut e = QueueLockEngine::new(ParallelSettings::with_workers(4));
        let out = e.run(&params, &Cubic, Objective::Maximize, 1);
        assert!(out.gbest_fit > 890_000.0, "gbest {}", out.gbest_fit);
        assert!((out.gbest_pos[0] - 100.0).abs() < 2.0);
    }

    #[test]
    fn monotone_despite_relaxed_sync() {
        let params = PsoParams::paper_120d(128, 60);
        let mut e = QueueLockEngine::new(ParallelSettings::with_workers(8));
        let out = e.run(&params, &Cubic, Objective::Maximize, 2);
        for w in out.history.windows(2) {
            assert!(w[1].1 >= w[0].1, "gbest must never worsen");
        }
    }

    #[test]
    fn lock_taken_rarely() {
        // The whole point: the lock serializes only improvements, which
        // are rare relative to particle updates.
        let params = PsoParams::paper_1d(1024, 200);
        let mut e = QueueLockEngine::new(ParallelSettings::with_workers(4));
        let out = e.run(&params, &Cubic, Objective::Maximize, 7);
        let updates = out.counters.particle_updates;
        assert!(
            out.counters.gbest_updates * 50 < updates,
            "gbest updates {} vs particle updates {}",
            out.counters.gbest_updates,
            updates
        );
    }
}
