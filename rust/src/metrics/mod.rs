//! Measurement and reporting utilities.
//!
//! The paper reports "the average numbers of the execution time for 10
//! runs, removing the maximum and minimum numbers" (§6.1) — that exact
//! trimmed-mean estimator is [`Summary::trimmed_mean`] and is what every
//! bench target reports. Output side: aligned markdown tables (matching
//! the paper's table layout), CSV for downstream plotting, and an ASCII
//! line plot used to regenerate Figure 3 in the terminal.

mod plot;
mod stats;
mod table;

pub use plot::AsciiPlot;
pub use stats::Summary;
pub use table::{write_csv, Table};

use std::time::Instant;

/// Monotonic stopwatch with split support.
#[derive(Debug, Clone, Copy)]
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    /// Start timing now.
    pub fn start() -> Self {
        Self {
            start: Instant::now(),
        }
    }

    /// Seconds since start.
    #[inline]
    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Restart and return the lap time in seconds.
    pub fn lap_s(&mut self) -> f64 {
        let t = self.elapsed_s();
        self.start = Instant::now();
        t
    }
}

/// Time a closure, returning `(seconds, output)`.
pub fn time_it<T, F: FnOnce() -> T>(f: F) -> (f64, T) {
    let sw = Stopwatch::start();
    let out = f();
    (sw.elapsed_s(), out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_measures_forward_time() {
        let mut sw = Stopwatch::start();
        std::thread::sleep(std::time::Duration::from_millis(5));
        let lap = sw.lap_s();
        assert!(lap >= 0.004, "lap {lap}");
        assert!(sw.elapsed_s() < lap, "restarted");
    }

    #[test]
    fn time_it_returns_output() {
        let (t, v) = time_it(|| 21 * 2);
        assert_eq!(v, 42);
        assert!(t >= 0.0);
    }
}
