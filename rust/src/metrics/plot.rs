//! Terminal line plots — regenerates Figure 3 ("plotting of the execution
//! times of the five implementations") as an ASCII chart with a log-scaled
//! y-axis option, since the paper's series span 0.1 s … 6 s.

/// Multi-series ASCII line plot on a character canvas.
pub struct AsciiPlot {
    title: String,
    width: usize,
    height: usize,
    log_y: bool,
    x_labels: Vec<String>,
    series: Vec<(String, Vec<f64>)>,
}

/// Glyphs assigned to series, in order.
const GLYPHS: &[char] = &['*', 'o', '+', 'x', '#', '@', '%'];

impl AsciiPlot {
    /// New plot canvas (`width`×`height` interior cells).
    pub fn new(title: &str, width: usize, height: usize) -> Self {
        Self {
            title: title.to_string(),
            width: width.max(16),
            height: height.max(6),
            log_y: false,
            x_labels: Vec::new(),
            series: Vec::new(),
        }
    }

    /// Use log10 scaling on the y axis.
    pub fn log_y(mut self) -> Self {
        self.log_y = true;
        self
    }

    /// Category labels along x (e.g. particle counts).
    pub fn x_labels<S: ToString>(mut self, labels: &[S]) -> Self {
        self.x_labels = labels.iter().map(|l| l.to_string()).collect();
        self
    }

    /// Add one named series (same length as `x_labels`).
    pub fn series(mut self, name: &str, values: &[f64]) -> Self {
        self.series.push((name.to_string(), values.to_vec()));
        self
    }

    /// Render the chart.
    pub fn render(&self) -> String {
        let mut out = format!("{}\n", self.title);
        if self.series.is_empty() {
            out.push_str("(no data)\n");
            return out;
        }
        // Sanitize before scaling: a NaN or ±∞ sample (a 0/0 rate, an
        // empty summary) must not poison the axis bounds, and log-y
        // clamps zero/negative values instead of producing NaN rows.
        let tx = |v: f64| {
            let v = if v.is_finite() { v } else { 0.0 };
            if self.log_y {
                v.max(1e-12).log10()
            } else {
                v
            }
        };
        let all: Vec<f64> = self
            .series
            .iter()
            .flat_map(|(_, vs)| vs.iter().map(|&v| tx(v)))
            .collect();
        let lo = all.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = all.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let span = if (hi - lo).abs() < 1e-15 { 1.0 } else { hi - lo };
        let npts = self.series.iter().map(|(_, v)| v.len()).max().unwrap();
        let mut canvas = vec![vec![' '; self.width]; self.height];
        for (si, (_, vs)) in self.series.iter().enumerate() {
            let glyph = GLYPHS[si % GLYPHS.len()];
            for (i, &v) in vs.iter().enumerate() {
                let x = if npts <= 1 {
                    0
                } else {
                    i * (self.width - 1) / (npts - 1)
                };
                let yf = (tx(v) - lo) / span;
                let y = self.height - 1 - ((yf * (self.height - 1) as f64).round() as usize);
                canvas[y.min(self.height - 1)][x] = glyph;
            }
        }
        // y-axis labels: top and bottom values (untransformed).
        let inv = |t: f64| if self.log_y { 10f64.powf(t) } else { t };
        let top = format!("{:>9.3}", inv(hi));
        let bot = format!("{:>9.3}", inv(lo));
        for (r, line) in canvas.iter().enumerate() {
            let label = if r == 0 {
                &top
            } else if r == self.height - 1 {
                &bot
            } else {
                &String::new()
            };
            out.push_str(&format!("{label:>9} |{}\n", line.iter().collect::<String>()));
        }
        out.push_str(&format!("{:>9} +{}\n", "", "-".repeat(self.width)));
        if !self.x_labels.is_empty() {
            let first = self.x_labels.first().unwrap();
            let last = self.x_labels.last().unwrap();
            let gap = self
                .width
                .saturating_sub(first.len() + last.len());
            out.push_str(&format!("{:>9}  {}{}{}\n", "", first, " ".repeat(gap), last));
        }
        out.push_str(&format!(
            "{:>9}  legend: {}\n",
            "",
            self.series
                .iter()
                .enumerate()
                .map(|(i, (n, _))| format!("{}={}", GLYPHS[i % GLYPHS.len()], n))
                .collect::<Vec<_>>()
                .join("  ")
        ));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_all_series_glyphs() {
        let p = AsciiPlot::new("t", 40, 10)
            .x_labels(&[32, 64, 128])
            .series("a", &[1.0, 2.0, 3.0])
            .series("b", &[3.0, 2.0, 1.0]);
        let r = p.render();
        assert!(r.contains('*'));
        assert!(r.contains('o'));
        assert!(r.contains("legend: *=a  o=b"));
    }

    #[test]
    fn log_scale_handles_wide_ranges() {
        let p = AsciiPlot::new("t", 40, 10)
            .log_y()
            .series("a", &[0.001, 1000.0]);
        let r = p.render();
        assert!(r.contains("1000"));
    }

    #[test]
    fn log_scale_clamps_zero_negative_and_non_finite() {
        let p = AsciiPlot::new("t", 40, 10)
            .log_y()
            .series("a", &[0.0, -3.0, f64::NAN, f64::INFINITY, 10.0]);
        let r = p.render();
        // Every row renders (no NaN-indexed panics), the axis labels are
        // finite numbers, and the finite sample anchors the top.
        assert!(!r.contains("NaN"), "{r}");
        assert!(!r.contains("inf"), "{r}");
        assert!(r.contains("10.000"), "{r}");
    }

    #[test]
    fn constant_series_does_not_divide_by_zero() {
        let p = AsciiPlot::new("t", 30, 8).series("c", &[5.0, 5.0, 5.0]);
        let r = p.render();
        assert!(r.contains('*'));
    }

    #[test]
    fn empty_plot_is_graceful() {
        assert!(AsciiPlot::new("e", 20, 6).render().contains("(no data)"));
    }
}
