//! Aligned markdown tables + CSV emission — the bench targets print the
//! same rows the paper's tables report.

use std::fmt::Write as _;
use std::io::Write as _;
use std::path::Path;

/// Column alignment.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Align {
    Left,
    Right,
}

/// A simple row/column table with markdown rendering.
#[derive(Debug, Clone)]
pub struct Table {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// New table with a title and column headers.
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row (must match the header arity).
    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.headers.len(),
            "row arity mismatch in table '{}'",
            self.title
        );
        self.rows.push(cells.to_vec());
        self
    }

    /// Convenience: format-heterogeneous row.
    pub fn rowf(&mut self, cells: &[&dyn std::fmt::Display]) -> &mut Self {
        let cells: Vec<String> = cells.iter().map(|c| c.to_string()).collect();
        self.row(&cells)
    }

    /// Number of data rows.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// True when no rows were added.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Title accessor.
    pub fn title(&self) -> &str {
        &self.title
    }

    /// Render as an aligned markdown table (numbers right-aligned).
    pub fn to_markdown(&self) -> String {
        let cols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        // Right-align columns whose body cells are all numeric-ish.
        let aligns: Vec<Align> = (0..cols)
            .map(|i| {
                let numeric = self.rows.iter().all(|r| {
                    let c = r[i].trim().trim_end_matches('x').replace(',', "");
                    !c.is_empty() && c.parse::<f64>().is_ok()
                });
                if numeric && !self.rows.is_empty() {
                    Align::Right
                } else {
                    Align::Left
                }
            })
            .collect();
        let pad = |s: &str, w: usize, a: Align| match a {
            Align::Left => format!("{s:<w$}"),
            Align::Right => format!("{s:>w$}"),
        };
        let mut out = String::new();
        let _ = writeln!(out, "## {}", self.title);
        let hdr: Vec<String> = self
            .headers
            .iter()
            .enumerate()
            .map(|(i, h)| pad(h, widths[i], aligns[i]))
            .collect();
        let _ = writeln!(out, "| {} |", hdr.join(" | "));
        let sep: Vec<String> = widths
            .iter()
            .zip(&aligns)
            .map(|(w, a)| match a {
                Align::Left => format!("{:-<w$}", ""),
                Align::Right => format!("{:->w$}", ""),
            })
            .collect();
        let _ = writeln!(out, "|-{}-|", sep.join("-|-"));
        for row in &self.rows {
            let cells: Vec<String> = row
                .iter()
                .enumerate()
                .map(|(i, c)| pad(c, widths[i], aligns[i]))
                .collect();
            let _ = writeln!(out, "| {} |", cells.join(" | "));
        }
        out
    }

    /// Render as CSV (header + rows).
    pub fn to_csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        let _ = writeln!(
            out,
            "{}",
            self.headers.iter().map(|h| esc(h)).collect::<Vec<_>>().join(",")
        );
        for row in &self.rows {
            let _ = writeln!(
                out,
                "{}",
                row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(",")
            );
        }
        out
    }

    /// Print markdown to stdout and write CSV next to `dir` as
    /// `<slug>.csv`; returns the CSV path.
    pub fn emit(&self, dir: &Path, slug: &str) -> std::io::Result<std::path::PathBuf> {
        println!("{}", self.to_markdown());
        let path = dir.join(format!("{slug}.csv"));
        write_csv(&path, &self.to_csv())?;
        Ok(path)
    }
}

/// Write `content` to `path`, creating parent directories.
pub fn write_csv(path: &Path, content: &str) -> std::io::Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut f = std::fs::File::create(path)?;
    f.write_all(content.as_bytes())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Table {
        let mut t = Table::new("Demo", &["Name", "Value"]);
        t.row(&["alpha".into(), "1.50".into()]);
        t.row(&["beta".into(), "12.25".into()]);
        t
    }

    #[test]
    fn markdown_is_aligned_and_complete() {
        let md = sample().to_markdown();
        assert!(md.contains("## Demo"));
        assert!(md.contains("| Name  |"));
        assert!(md.contains("|  1.50 |")); // numeric column right-aligned
        assert_eq!(md.lines().count(), 5);
    }

    #[test]
    fn csv_escapes_and_rounds_trips() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(&["with,comma".into(), "q\"uote".into()]);
        let csv = t.to_csv();
        assert!(csv.contains("\"with,comma\""));
        assert!(csv.contains("\"q\"\"uote\""));
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn arity_mismatch_panics() {
        Table::new("x", &["a", "b"]).row(&["only-one".into()]);
    }

    #[test]
    fn emit_writes_csv_file() {
        let dir = std::env::temp_dir().join("cupso-table-test");
        let p = sample().emit(&dir, "demo").unwrap();
        let content = std::fs::read_to_string(&p).unwrap();
        assert!(content.starts_with("Name,Value"));
        std::fs::remove_dir_all(&dir).ok();
    }
}
