//! Run-summary statistics, headlined by the paper's trimmed mean.

use anyhow::{bail, Result};

/// Summary statistics over a sample of measurements (seconds, ratios, …).
#[derive(Debug, Clone)]
pub struct Summary {
    sorted: Vec<f64>,
}

impl Summary {
    /// Build from raw samples (order irrelevant). An empty set (a
    /// zero-rep bench config, e.g. `CUPSO_BENCH_REPS=0`) or a NaN
    /// sample is a loud `Err`, not a panic — callers decide whether a
    /// degenerate measurement aborts the whole run.
    pub fn from_samples(samples: &[f64]) -> Result<Self> {
        if samples.is_empty() {
            bail!("empty sample set (zero-rep bench config?)");
        }
        if samples.iter().any(|x| x.is_nan()) {
            bail!("NaN in samples: {samples:?}");
        }
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Ok(Self { sorted })
    }

    /// The samples, ascending (for machine-readable bench records).
    pub fn samples(&self) -> &[f64] {
        &self.sorted
    }

    /// Number of samples.
    pub fn n(&self) -> usize {
        self.sorted.len()
    }

    /// Plain arithmetic mean.
    pub fn mean(&self) -> f64 {
        self.sorted.iter().sum::<f64>() / self.sorted.len() as f64
    }

    /// The paper's estimator (§6.1): mean after dropping the single
    /// minimum and single maximum. Falls back to the plain mean when
    /// fewer than 3 samples exist.
    pub fn trimmed_mean(&self) -> f64 {
        if self.sorted.len() < 3 {
            return self.mean();
        }
        let inner = &self.sorted[1..self.sorted.len() - 1];
        inner.iter().sum::<f64>() / inner.len() as f64
    }

    /// Median (50th percentile).
    pub fn median(&self) -> f64 {
        self.percentile(50.0)
    }

    /// Smallest sample.
    pub fn min(&self) -> f64 {
        self.sorted[0]
    }

    /// Largest sample.
    pub fn max(&self) -> f64 {
        *self.sorted.last().unwrap()
    }

    /// Linear-interpolated percentile, `p` in `[0, 100]`.
    pub fn percentile(&self, p: f64) -> f64 {
        let n = self.sorted.len();
        if n == 1 {
            return self.sorted[0];
        }
        let rank = (p.clamp(0.0, 100.0) / 100.0) * (n - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        let frac = rank - lo as f64;
        self.sorted[lo] * (1.0 - frac) + self.sorted[hi] * frac
    }

    /// Sample standard deviation (n−1 denominator).
    pub fn stddev(&self) -> f64 {
        let n = self.sorted.len();
        if n < 2 {
            return 0.0;
        }
        let m = self.mean();
        let ss: f64 = self.sorted.iter().map(|x| (x - m) * (x - m)).sum();
        (ss / (n - 1) as f64).sqrt()
    }

    /// Relative spread — stddev / |mean| (useful to flag noisy benches).
    ///
    /// The magnitude of the mean is what normalizes the spread, so the
    /// coefficient of variation is non-negative for negative-mean samples
    /// too (a plain `stddev / mean` would report a negative "spread").
    /// A zero mean with nonzero spread is maximal relative noise and
    /// reports `+∞`, not the old misleading `0.0`.
    pub fn cv(&self) -> f64 {
        let sd = self.stddev();
        if sd == 0.0 {
            return 0.0;
        }
        let m = self.mean();
        if m == 0.0 {
            f64::INFINITY
        } else {
            sd / m.abs()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trimmed_mean_drops_min_and_max() {
        // 10 runs as the paper does: drop 1 (min) and 100 (max).
        let s = Summary::from_samples(&[1.0, 5.0, 5.0, 5.0, 5.0, 5.0, 5.0, 5.0, 5.0, 100.0])
            .unwrap();
        assert_eq!(s.trimmed_mean(), 5.0);
        assert!((s.mean() - 14.1).abs() < 1e-12);
    }

    #[test]
    fn trimmed_mean_small_samples_fall_back() {
        assert_eq!(Summary::from_samples(&[2.0]).unwrap().trimmed_mean(), 2.0);
        assert_eq!(Summary::from_samples(&[2.0, 4.0]).unwrap().trimmed_mean(), 3.0);
    }

    #[test]
    fn order_statistics() {
        let s = Summary::from_samples(&[3.0, 1.0, 2.0]).unwrap();
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 3.0);
        assert_eq!(s.median(), 2.0);
        assert_eq!(s.n(), 3);
    }

    #[test]
    fn percentile_interpolates() {
        let s = Summary::from_samples(&[0.0, 10.0]).unwrap();
        assert_eq!(s.percentile(0.0), 0.0);
        assert_eq!(s.percentile(50.0), 5.0);
        assert_eq!(s.percentile(100.0), 10.0);
    }

    #[test]
    fn stddev_of_constant_is_zero() {
        let s = Summary::from_samples(&[4.0, 4.0, 4.0]).unwrap();
        assert_eq!(s.stddev(), 0.0);
        assert_eq!(s.cv(), 0.0);
    }

    #[test]
    fn cv_is_nonnegative_for_negative_means() {
        // Speedup *differences* or signed deltas can have negative means;
        // the relative spread must still come out ≥ 0.
        let neg = Summary::from_samples(&[-4.0, -5.0, -6.0]).unwrap();
        assert!(neg.mean() < 0.0);
        assert!(neg.cv() > 0.0, "cv {}", neg.cv());
        // Mirror-image samples have the same spread.
        let pos = Summary::from_samples(&[4.0, 5.0, 6.0]).unwrap();
        assert_eq!(neg.cv(), pos.cv());
        // All-zero samples stay well-defined.
        assert_eq!(Summary::from_samples(&[0.0, 0.0]).unwrap().cv(), 0.0);
        // Zero mean + nonzero spread is maximal relative noise, not zero.
        assert_eq!(
            Summary::from_samples(&[-1.0, 1.0]).unwrap().cv(),
            f64::INFINITY
        );
    }

    #[test]
    fn rejects_nan_with_an_error_not_a_panic() {
        let err = Summary::from_samples(&[1.0, f64::NAN]).unwrap_err();
        assert!(err.to_string().contains("NaN"), "{err}");
    }

    #[test]
    fn rejects_empty_sample_set() {
        let err = Summary::from_samples(&[]).unwrap_err();
        assert!(err.to_string().contains("empty sample set"), "{err}");
    }
}
