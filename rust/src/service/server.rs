//! Socket front end for a [`ServiceSession`]: Unix-domain **and TCP**
//! listeners multiplexed by one nonblocking `poll(2)` event loop.
//!
//! The previous front end spawned one thread per connection, which is
//! exactly the synchronization-overhead trap the paper describes one
//! layer down: at the ROADMAP's 10k-client target the daemon drowns in
//! thread spawn/wakeup costs before the scheduler breaks a sweat. The
//! rewrite keeps the wire protocol byte-for-byte intact and changes only
//! the machinery:
//!
//! * **One loop thread** owns every listener and every connection,
//!   parked in `poll(2)` (raw FFI — no runtime dependency) until a
//!   socket or the service has something for it.
//! * **Self-pipe waker**: the loop registers a [`Waker`] with the
//!   service ([`super::Control::SetWaker`]); the service writes one byte
//!   into the pipe after processing controls or fanning out telemetry,
//!   which is what lets the loop use the *deferred* [`ServiceHandle`]
//!   calls — it enqueues a control, remembers the reply channel in the
//!   connection's in-order pending queue, and never blocks.
//! * **Bounded buffers**: one persistent read buffer per connection
//!   (lines are parsed in place — no per-chunk copy, no per-request
//!   `String`), one write buffer flushed writability-driven, both
//!   capped. Watch fan-out pulls from the subscription's bounded
//!   [`WatchStream`] only when the socket can take more.
//! * **Connection cap with loud shedding**: past `max_conns` the
//!   accept loop answers `{"ok":false,...,"shed":true}` and closes,
//!   instead of growing without bound — overload is visible, not a
//!   mystery timeout.
//!
//! Every mutation still funnels through the round-boundary control
//! queue; the socket layer adds no new synchronization.
//!
//! [`ServiceSession`]: super::ServiceSession
//! [`Waker`]: super::Waker

use super::proto::{self, Obj, Request};
use super::{
    DrainReport, FinishedJob, JobStatus, ServiceHandle, StatusReport, Submitted, Waker,
    WatchStream,
};
use crate::telemetry::{self, Counter, Gauge, TraceKind};
use anyhow::{bail, Context, Result};
use std::collections::VecDeque;
use std::io::{self, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::raw::{c_int, c_short, c_ulong};
use std::os::unix::io::{AsRawFd, RawFd};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::Path;
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::{Arc, Weak};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Bind the service's Unix socket. A stale socket file left by a dead
/// daemon is removed and rebound; a *live* one (something accepts
/// connections) is a loud error — two daemons must not fight over one
/// path. Anything that is not a socket is refused outright: the old
/// code unlinked whatever sat at the path after any failed connect, so
/// `cupso serve --socket <some-regular-file>` could delete a user's
/// file.
pub fn bind(path: &Path) -> Result<UnixListener> {
    match UnixListener::bind(path) {
        Ok(listener) => Ok(listener),
        Err(e) if e.kind() == io::ErrorKind::AddrInUse => {
            use std::os::unix::fs::FileTypeExt;
            let meta = std::fs::symlink_metadata(path)
                .with_context(|| format!("inspecting {}", path.display()))?;
            if !meta.file_type().is_socket() {
                bail!(
                    "{} exists and is not a socket — refusing to replace it",
                    path.display()
                );
            }
            match UnixStream::connect(path) {
                Ok(_) => bail!("{} is already being served", path.display()),
                // Only connection-refused proves the bound daemon is
                // gone. Any other probe failure (permissions, interrupts)
                // is not evidence of staleness — removing on it would
                // reintroduce the delete-someone-else's-socket bug.
                Err(probe) if probe.kind() == io::ErrorKind::ConnectionRefused => {
                    std::fs::remove_file(path)
                        .with_context(|| format!("removing stale socket {}", path.display()))?;
                    UnixListener::bind(path)
                        .with_context(|| format!("binding {} after stale cleanup", path.display()))
                }
                Err(probe) => Err(probe)
                    .with_context(|| format!("probing existing socket {}", path.display())),
            }
        }
        Err(e) => Err(e).with_context(|| format!("binding {}", path.display())),
    }
}

/// Bind the TCP listener (`cupso serve --listen host:port`).
pub fn bind_tcp(addr: &str) -> Result<TcpListener> {
    TcpListener::bind(addr).with_context(|| format!("binding tcp {addr}"))
}

/// Default cap on concurrent connections (`cupso serve --max-conns`).
/// Past it, new clients are shed loudly — see the module docs.
pub const DEFAULT_MAX_CONNS: usize = 1024;

/// One bound accept socket: Unix and TCP share the connection-handling
/// core behind this.
pub enum Listener {
    /// Unix-domain (`--socket path`).
    Unix(UnixListener),
    /// TCP (`--listen host:port`).
    Tcp(TcpListener),
}

impl Listener {
    fn set_nonblocking(&self) -> io::Result<()> {
        match self {
            Listener::Unix(l) => l.set_nonblocking(true),
            Listener::Tcp(l) => l.set_nonblocking(true),
        }
    }

    fn raw_fd(&self) -> RawFd {
        match self {
            Listener::Unix(l) => l.as_raw_fd(),
            Listener::Tcp(l) => l.as_raw_fd(),
        }
    }

    fn accept(&self) -> io::Result<Stream> {
        match self {
            Listener::Unix(l) => l.accept().map(|(s, _)| Stream::Unix(s)),
            Listener::Tcp(l) => l.accept().map(|(s, _)| {
                // One JSON line per exchange: Nagle only adds latency.
                let _ = s.set_nodelay(true);
                Stream::Tcp(s)
            }),
        }
    }
}

/// One accepted connection's transport.
enum Stream {
    Unix(UnixStream),
    Tcp(TcpStream),
}

impl Stream {
    fn set_nonblocking(&self) -> io::Result<()> {
        match self {
            Stream::Unix(s) => s.set_nonblocking(true),
            Stream::Tcp(s) => s.set_nonblocking(true),
        }
    }

    fn raw_fd(&self) -> RawFd {
        match self {
            Stream::Unix(s) => s.as_raw_fd(),
            Stream::Tcp(s) => s.as_raw_fd(),
        }
    }
}

impl Read for Stream {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        match self {
            Stream::Unix(s) => s.read(buf),
            Stream::Tcp(s) => s.read(buf),
        }
    }
}

impl Write for Stream {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        match self {
            Stream::Unix(s) => s.write(buf),
            Stream::Tcp(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> io::Result<()> {
        match self {
            Stream::Unix(s) => s.flush(),
            Stream::Tcp(s) => s.flush(),
        }
    }
}

/// Spawn the event loop over one Unix listener with the default cap —
/// the historical entry point, kept for callers and tests.
pub fn spawn_server(listener: UnixListener, handle: ServiceHandle) -> JoinHandle<()> {
    spawn_server_on(vec![Listener::Unix(listener)], handle, DEFAULT_MAX_CONNS)
}

/// Spawn the event-loop thread serving every listener (Unix and TCP
/// side by side), capped at `max_conns` concurrent connections.
pub fn spawn_server_on(
    listeners: Vec<Listener>,
    handle: ServiceHandle,
    max_conns: usize,
) -> JoinHandle<()> {
    std::thread::Builder::new()
        .name("cupso-serve-loop".into())
        .spawn(move || {
            match EventLoop::new(listeners, handle, max_conns) {
                Ok(ev) => {
                    if let Err(e) = ev.run() {
                        eprintln!("cupso serve: event loop error: {e:#}");
                    }
                }
                Err(e) => eprintln!("cupso serve: event loop setup failed: {e:#}"),
            }
        })
        .expect("spawn event loop thread")
}

/// Longest request line the server accepts. Generous for any real
/// request (a submit is a few hundred bytes) while bounding the memory a
/// newline-free sender can pin per connection.
const MAX_REQUEST_BYTES: usize = 1 << 20;

/// Unflushed reply/telemetry bytes a connection may hold before the
/// loop stops pumping (and stops reading new requests from) it.
const WBUF_SOFT_CAP: usize = 256 * 1024;

/// Unanswered pipelined requests a connection may queue before the loop
/// stops reading from it.
const MAX_PIPELINE: usize = 128;

/// Fallback poll timeout. The waker is the real wake path; the timeout
/// only bounds how stale the loop can get if a wake is ever lost.
const POLL_TIMEOUT_MS: c_int = 200;

// ---- poll(2) FFI (the loop's only unsafe surface) ----

/// POSIX `struct pollfd`.
#[repr(C)]
struct PollFd {
    fd: RawFd,
    events: c_short,
    revents: c_short,
}

const POLLIN: c_short = 0x001;
const POLLOUT: c_short = 0x004;
const POLLERR: c_short = 0x008;
const POLLHUP: c_short = 0x010;
const POLLNVAL: c_short = 0x020;

extern "C" {
    fn poll(fds: *mut PollFd, nfds: c_ulong, timeout: c_int) -> c_int;
}

/// Safe wrapper: block until an fd is ready or `timeout_ms` passes.
/// EINTR reads as "zero fds ready" — the caller's loop just re-polls.
fn poll_wait(fds: &mut [PollFd], timeout_ms: c_int) -> io::Result<usize> {
    // SAFETY: `fds` is a live, exclusively borrowed slice of #[repr(C)]
    // pollfd records; its length is passed alongside the pointer, and
    // poll(2) only reads fd/events and writes revents within that
    // bound. The slice outlives the call.
    let n = unsafe { poll(fds.as_mut_ptr(), fds.len() as c_ulong, timeout_ms) };
    if n < 0 {
        let err = io::Error::last_os_error();
        if err.kind() == io::ErrorKind::Interrupted {
            return Ok(0);
        }
        return Err(err);
    }
    Ok(n as usize)
}

/// One request's reply, queued in arrival order: responses must land in
/// request order even though the service answers asynchronously, so the
/// head of this queue gates everything behind it.
enum Pending {
    /// Already rendered (ping, parse errors, the watch ack).
    Ready(String),
    Submit(Receiver<Result<Submitted, String>>),
    Cancel(Receiver<Result<FinishedJob, String>>),
    Status(Receiver<StatusReport>),
    Drain(Receiver<Result<DrainReport, String>>),
}

/// One live connection: transport plus bounded read/write buffers and
/// the in-order pending-reply queue.
struct Conn {
    stream: Stream,
    /// Unparsed request bytes. Persistent: lines are parsed in place
    /// and the consumed prefix drained, so the steady request path
    /// copies nothing per chunk and allocates no per-line `String`.
    rbuf: Vec<u8>,
    /// Rendered-but-unflushed reply/telemetry bytes...
    wbuf: Vec<u8>,
    /// ...of which `..wpos` has already reached the socket.
    wpos: usize,
    pending: VecDeque<Pending>,
    /// Set once a `watch` request flipped this connection one-way.
    watch: Option<WatchStream>,
    /// Held from the drain request until its reply is *rendered*...
    drain_latch: Option<Sender<()>>,
    /// ...then armed here and fired when the reply is *flushed* — the
    /// daemon defers its exit on this latch, so the acknowledgement
    /// reaches the client before the process goes away.
    fire_on_flush: Option<Sender<()>>,
    /// Client closed its write half.
    eof: bool,
    /// Close once `wbuf` is flushed.
    closing: bool,
    /// Drop at the next sweep.
    dead: bool,
}

impl Conn {
    fn new(stream: Stream) -> Self {
        Self {
            stream,
            rbuf: Vec::new(),
            wbuf: Vec::new(),
            wpos: 0,
            pending: VecDeque::new(),
            watch: None,
            drain_latch: None,
            fire_on_flush: None,
            eof: false,
            closing: false,
            dead: false,
        }
    }

    fn unflushed(&self) -> usize {
        self.wbuf.len() - self.wpos
    }

    /// Nothing left to emit: the shutdown sweep's retention test.
    fn drained_out(&self) -> bool {
        self.unflushed() == 0
            && match &self.watch {
                Some(_) => self.closing,
                None => self.pending.is_empty(),
            }
    }
}

/// Append one protocol line to a write buffer.
fn push_line(wbuf: &mut Vec<u8>, line: &str) {
    wbuf.extend_from_slice(line.as_bytes());
    wbuf.push(b'\n');
}

/// The single-threaded server core. See the module docs.
struct EventLoop {
    handle: ServiceHandle,
    listeners: Vec<Listener>,
    /// Read side of the self-pipe the [`Waker`] writes into.
    wake_rx: UnixStream,
    /// Liveness probe: the service holds the only strong count of its
    /// registered waker, so this upgrading to `None` means the service
    /// loop has returned and it is time to flush and exit.
    alive: Weak<dyn Fn() + Send + Sync>,
    conns: Vec<Conn>,
    pollfds: Vec<PollFd>,
    max_conns: usize,
}

impl EventLoop {
    fn new(listeners: Vec<Listener>, handle: ServiceHandle, max_conns: usize) -> Result<Self> {
        for l in &listeners {
            l.set_nonblocking().context("listener nonblocking")?;
        }
        let (wake_tx, wake_rx) = UnixStream::pair().context("creating self-pipe")?;
        wake_tx.set_nonblocking(true).context("self-pipe")?;
        wake_rx.set_nonblocking(true).context("self-pipe")?;
        let waker: Waker = Arc::new(move || {
            // A full pipe is fine — the loop is already due to wake.
            let _ = (&wake_tx).write(&[1u8]);
        });
        let alive = Arc::downgrade(&waker);
        // MPSC ordering: registered before any client control this loop
        // will ever enqueue, so the service always has the waker by the
        // time a deferred reply needs announcing.
        handle.set_waker(waker)?;
        Ok(Self {
            handle,
            listeners,
            wake_rx,
            alive,
            conns: Vec::new(),
            pollfds: Vec::new(),
            max_conns: max_conns.max(1),
        })
    }

    fn run(mut self) -> Result<()> {
        loop {
            if self.alive.upgrade().is_none() {
                // Service loop returned: flush what remains and exit.
                self.shutdown_flush();
                return Ok(());
            }
            self.build_pollfds();
            poll_wait(&mut self.pollfds, POLL_TIMEOUT_MS).context("poll")?;
            if self.pollfds[0].revents & (POLLIN | POLLERR | POLLHUP) != 0 {
                self.drain_wake();
            }
            // Connection events against this iteration's pollfd
            // snapshot (fresh accepts simply poll next time around).
            let base = 1 + self.listeners.len();
            for i in 0..self.conns.len() {
                let revents = self.pollfds[base + i].revents;
                if revents & (POLLERR | POLLNVAL) != 0 {
                    self.conns[i].dead = true;
                    continue;
                }
                if revents & (POLLIN | POLLHUP) != 0
                    && !read_requests(&self.handle, &mut self.conns[i])
                {
                    self.conns[i].dead = true;
                }
            }
            // Pump: service replies and watch telemetry into write
            // buffers, write buffers into sockets.
            for conn in &mut self.conns {
                if conn.dead {
                    continue;
                }
                pump_replies(conn);
                pump_watch(conn);
                if !flush_conn(conn) {
                    conn.dead = true;
                    continue;
                }
                if conn.unflushed() == 0
                    && (conn.closing
                        || (conn.eof && conn.watch.is_none() && conn.pending.is_empty()))
                {
                    conn.dead = true;
                }
            }
            self.conns.retain(|c| !c.dead);
            self.accept_all();
        }
    }

    fn build_pollfds(&mut self) {
        self.pollfds.clear();
        self.pollfds.push(PollFd {
            fd: self.wake_rx.as_raw_fd(),
            events: POLLIN,
            revents: 0,
        });
        for l in &self.listeners {
            self.pollfds.push(PollFd {
                fd: l.raw_fd(),
                events: POLLIN,
                revents: 0,
            });
        }
        for c in &self.conns {
            // Queue high-water marks for the metrics registry: how deep
            // the in-order reply queue and write buffer ever got.
            telemetry::gauge_max(Gauge::ConnPendingHwm, c.pending.len() as u64);
            telemetry::gauge_max(Gauge::ConnWbufHwm, c.unflushed() as u64);
            let backpressured =
                c.pending.len() >= MAX_PIPELINE || c.unflushed() >= WBUF_SOFT_CAP;
            let mut events = 0;
            if !c.eof && !c.closing && !backpressured {
                events |= POLLIN;
            }
            if c.unflushed() > 0 {
                events |= POLLOUT;
            }
            self.pollfds.push(PollFd {
                fd: c.stream.raw_fd(),
                events,
                revents: 0,
            });
        }
    }

    fn drain_wake(&mut self) {
        let mut scratch = [0u8; 64];
        loop {
            match (&self.wake_rx).read(&mut scratch) {
                Ok(0) => break, // write side gone with the service
                Ok(_) => continue,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(_) => break, // WouldBlock: drained
            }
        }
    }

    /// Accept everything waiting on every listener. Past the cap each
    /// accept is answered with a loud shed line and closed — overload
    /// must be visible to the client, not a mystery timeout, and the
    /// daemon's memory stays bounded by `max_conns` connections.
    fn accept_all(&mut self) {
        for l in &self.listeners {
            loop {
                match l.accept() {
                    Ok(stream) => {
                        if self.conns.len() >= self.max_conns {
                            shed(stream, self.max_conns);
                            continue;
                        }
                        if stream.set_nonblocking().is_err() {
                            continue;
                        }
                        telemetry::bump(Counter::ConnsAccepted);
                        self.conns.push(Conn::new(stream));
                    }
                    Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                    Err(_) => break, // WouldBlock: backlog drained
                }
            }
        }
    }

    /// The service is gone: resolve every still-pending reply (their
    /// channels are disconnected — each becomes a loud error line),
    /// drain ended watch backlogs, and flush within a bounded grace
    /// period so a drain acknowledgement or final `end` line never
    /// silently vanishes with the process.
    fn shutdown_flush(&mut self) {
        let deadline = Instant::now() + Duration::from_secs(5);
        loop {
            for conn in &mut self.conns {
                pump_replies(conn);
                pump_watch(conn);
                if !flush_conn(conn) {
                    conn.dead = true;
                }
            }
            self.conns.retain(|c| !c.dead && !c.drained_out());
            if self.conns.is_empty() || Instant::now() >= deadline {
                return;
            }
            self.pollfds.clear();
            for c in &self.conns {
                self.pollfds.push(PollFd {
                    fd: c.stream.raw_fd(),
                    events: POLLOUT,
                    revents: 0,
                });
            }
            if poll_wait(&mut self.pollfds, 50).is_err() {
                return;
            }
        }
    }
}

/// Refuse one over-cap connection, loudly.
fn shed(mut stream: Stream, cap: usize) {
    telemetry::bump(Counter::ConnsShed);
    telemetry::trace(TraceKind::Shed, cap as u64, 0);
    let line = Obj::new()
        .bool("ok", false)
        .str(
            "error",
            &format!("server at its connection cap ({cap}); retry later"),
        )
        .bool("shed", true)
        .render();
    let _ = stream.set_nonblocking();
    // Best effort: one nonblocking write into the fresh socket buffer.
    let mut bytes = Vec::with_capacity(line.len() + 1);
    push_line(&mut bytes, &line);
    let _ = stream.write(&bytes);
}

/// Drain the socket into the connection's read buffer and dispatch any
/// complete request lines. `false` = transport error, drop the
/// connection.
fn read_requests(handle: &ServiceHandle, conn: &mut Conn) -> bool {
    let mut scratch = [0u8; 4096];
    loop {
        match conn.stream.read(&mut scratch) {
            Ok(0) => {
                conn.eof = true;
                return true;
            }
            Ok(n) => {
                if conn.watch.is_some() || conn.closing {
                    continue; // one-way stream: inbound bytes are discarded
                }
                conn.rbuf.extend_from_slice(&scratch[..n]);
                drain_lines(handle, conn);
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => return true,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(_) => return false,
        }
    }
}

/// Parse and dispatch every complete line in the read buffer, in place:
/// the buffer itself is the line buffer (the old per-connection reader
/// copied each chunk through a fresh `to_vec` and built a `String` per
/// request — pure overhead on the hot path).
fn drain_lines(handle: &ServiceHandle, conn: &mut Conn) {
    let rbuf = std::mem::take(&mut conn.rbuf);
    let mut consumed = 0usize;
    while let Some(nl) = rbuf[consumed..].iter().position(|&b| b == b'\n') {
        let line = &rbuf[consumed..consumed + nl];
        consumed += nl + 1;
        handle_line(handle, conn, line);
        if conn.watch.is_some() {
            // Flipped one-way: everything after the watch request is
            // discarded by protocol.
            consumed = rbuf.len();
            break;
        }
    }
    conn.rbuf = rbuf;
    conn.rbuf.drain(..consumed);
    if conn.rbuf.len() > MAX_REQUEST_BYTES {
        conn.pending.push_back(Pending::Ready(proto::error_line(&format!(
            "request line exceeds {MAX_REQUEST_BYTES} bytes"
        ))));
        conn.rbuf.clear();
        conn.closing = true;
    }
}

/// Dispatch one request line: immediate answers (ping, errors, the
/// watch ack) enter the pending queue pre-rendered; everything else
/// enqueues a control and parks its reply channel there. Either way the
/// queue preserves request order.
fn handle_line(handle: &ServiceHandle, conn: &mut Conn, line: &[u8]) {
    let Ok(text) = std::str::from_utf8(line) else {
        conn.pending
            .push_back(Pending::Ready(proto::error_line("request line is not UTF-8")));
        return;
    };
    if text.trim().is_empty() {
        return;
    }
    let pending = match Request::parse(text) {
        Err(e) => Pending::Ready(proto::error_line(&format!("{e:#}"))),
        Ok(Request::Ping) => {
            Pending::Ready(Obj::new().bool("ok", true).str("op", "ping").render())
        }
        Ok(Request::Submit(job)) => match crate::scheduler::JobSpec::from_config(&job) {
            Err(e) => Pending::Ready(proto::error_line(&format!("{e:#}"))),
            Ok(spec) => match handle.submit_deferred(spec) {
                Ok(rx) => Pending::Submit(rx),
                Err(e) => Pending::Ready(proto::error_line(&format!("{e:#}"))),
            },
        },
        Ok(Request::Cancel { name }) => match handle.cancel_deferred(&name) {
            Ok(rx) => Pending::Cancel(rx),
            Err(e) => Pending::Ready(proto::error_line(&format!("{e:#}"))),
        },
        Ok(Request::Status) => match handle.status_deferred() {
            Ok(rx) => Pending::Status(rx),
            Err(e) => Pending::Ready(proto::error_line(&format!("{e:#}"))),
        },
        Ok(Request::Drain) => {
            let (latch_tx, latch_rx) = channel();
            match handle.drain_deferred(Some(latch_rx)) {
                Ok(rx) => {
                    conn.drain_latch = Some(latch_tx);
                    Pending::Drain(rx)
                }
                Err(e) => Pending::Ready(proto::error_line(&format!("{e:#}"))),
            }
        }
        Ok(Request::Watch) => match handle.watch() {
            Ok(stream) => {
                conn.watch = Some(stream);
                Pending::Ready(Obj::new().bool("ok", true).str("op", "watch").render())
            }
            Err(e) => Pending::Ready(proto::error_line(&format!("{e:#}"))),
        },
        // The registry is process-global and lock-free, so metrics are
        // answered inline like ping — no round-boundary control, no
        // perturbation of the session the metrics describe.
        Ok(Request::Metrics) => Pending::Ready(metrics_line()),
    };
    conn.pending.push_back(pending);
}

/// Move ready replies from the head of the pending queue into the write
/// buffer — head-of-line order is the protocol's reply order. A
/// disconnected reply channel (service gone mid-request) resolves to a
/// loud error line rather than a silent drop.
fn pump_replies(conn: &mut Conn) {
    while conn.unflushed() < WBUF_SOFT_CAP {
        let line = match conn.pending.front() {
            None => break,
            Some(Pending::Ready(_)) => match conn.pending.pop_front() {
                Some(Pending::Ready(line)) => line,
                _ => unreachable!("front was Ready"),
            },
            Some(Pending::Submit(rx)) => match rx.try_recv() {
                Err(TryRecvError::Empty) => break,
                Ok(ack) => {
                    conn.pending.pop_front();
                    submit_line(ack)
                }
                Err(TryRecvError::Disconnected) => {
                    conn.pending.pop_front();
                    gone_line()
                }
            },
            Some(Pending::Cancel(rx)) => match rx.try_recv() {
                Err(TryRecvError::Empty) => break,
                Ok(ack) => {
                    conn.pending.pop_front();
                    cancel_line(ack)
                }
                Err(TryRecvError::Disconnected) => {
                    conn.pending.pop_front();
                    gone_line()
                }
            },
            Some(Pending::Status(rx)) => match rx.try_recv() {
                Err(TryRecvError::Empty) => break,
                Ok(report) => {
                    conn.pending.pop_front();
                    status_line(&report)
                }
                Err(TryRecvError::Disconnected) => {
                    conn.pending.pop_front();
                    gone_line()
                }
            },
            Some(Pending::Drain(rx)) => match rx.try_recv() {
                Err(TryRecvError::Empty) => break,
                Ok(ack) => {
                    conn.pending.pop_front();
                    // Arm the exit latch: fired once this reply reaches
                    // the socket, releasing the daemon to exit.
                    conn.fire_on_flush = conn.drain_latch.take();
                    drain_line(ack)
                }
                Err(TryRecvError::Disconnected) => {
                    conn.pending.pop_front();
                    conn.fire_on_flush = conn.drain_latch.take();
                    gone_line()
                }
            },
        };
        push_line(&mut conn.wbuf, &line);
    }
}

/// Writability-driven watch fan-out: pull telemetry lines from the
/// bounded subscription only while the write buffer has room, and only
/// once every pending reply is out (the ack precedes the stream). When
/// the stream has ended and its backlog is fully buffered, the
/// connection closes after the flush.
fn pump_watch(conn: &mut Conn) {
    let Some(watch) = &conn.watch else { return };
    if !conn.pending.is_empty() {
        return;
    }
    while conn.unflushed() < WBUF_SOFT_CAP {
        match watch.try_next() {
            Some(line) => push_line(&mut conn.wbuf, &line),
            None => {
                if watch.ended() {
                    conn.closing = true;
                }
                break;
            }
        }
    }
}

/// Flush the write buffer as far as the socket allows. `false` =
/// transport error, drop the connection.
fn flush_conn(conn: &mut Conn) -> bool {
    while conn.wpos < conn.wbuf.len() {
        match conn.stream.write(&conn.wbuf[conn.wpos..]) {
            Ok(0) => return false,
            Ok(n) => conn.wpos += n,
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(_) => return false,
        }
    }
    if conn.wpos == conn.wbuf.len() {
        conn.wbuf.clear();
        conn.wpos = 0;
        if let Some(latch) = conn.fire_on_flush.take() {
            let _ = latch.send(());
        }
    } else if conn.wpos > WBUF_SOFT_CAP {
        // Reclaim the flushed prefix so a long-lived watch connection
        // does not grow its buffer without bound.
        conn.wbuf.drain(..conn.wpos);
        conn.wpos = 0;
    }
    true
}

fn gone_line() -> String {
    proto::error_line("service shut down mid-request")
}

fn submit_line(ack: Result<Submitted, String>) -> String {
    match ack {
        Ok(ack) => Obj::new()
            .bool("ok", true)
            .str("op", "submit")
            .str("name", &ack.name)
            .int("slot", ack.slot as u64)
            .int("stream", ack.stream as u64)
            .render(),
        Err(e) => proto::error_line(&e),
    }
}

fn cancel_line(ack: Result<FinishedJob, String>) -> String {
    match ack {
        Ok(row) => Obj::new()
            .bool("ok", true)
            .str("op", "cancel")
            .raw("job", &finished_json(&row))
            .render(),
        Err(e) => proto::error_line(&e),
    }
}

fn status_line(report: &StatusReport) -> String {
    let live = proto::array(report.live.iter().map(live_json));
    let finished = proto::array(report.finished.iter().map(finished_json));
    // Lifetime counters and timestamps come from the telemetry
    // registry: daemon uptime, process-wide admission/cancel/shed
    // totals, and the age of the last durable snapshot.
    let mut obj = Obj::new()
        .bool("ok", true)
        .str("op", "status")
        .int("rounds", report.rounds)
        .int("streams", report.streams as u64)
        .int("finished_total", report.finished_total)
        .int("uptime_s", telemetry::uptime_secs())
        .int("admitted_total", telemetry::counter(Counter::JobsAdmitted))
        .int("cancelled_total", telemetry::counter(Counter::JobsCancelled))
        .int("shed_total", telemetry::counter(Counter::ConnsShed));
    obj = match telemetry::last_snapshot_age_secs() {
        Some(age) => obj.int("last_snapshot_age_s", age),
        None => obj.raw("last_snapshot_age_s", "null"),
    };
    obj.raw("live", &live).raw("finished", &finished).render()
}

/// The `metrics` verb's reply: the full registry snapshot under a
/// `metrics` key (counters, gauges, per-series histograms, trace-ring
/// state — see [`telemetry::render_json`]).
fn metrics_line() -> String {
    Obj::new()
        .bool("ok", true)
        .str("op", "metrics")
        .raw("metrics", &telemetry::render_json())
        .render()
}

fn drain_line(ack: Result<DrainReport, String>) -> String {
    match ack {
        Ok(report) => {
            let mut obj = Obj::new()
                .bool("ok", true)
                .str("op", "drain")
                .int("snapshotted", report.snapshotted as u64)
                .int("finished", report.finished);
            if let Some(dir) = &report.dir {
                obj = obj.str("dir", &dir.display().to_string());
            }
            obj.render()
        }
        Err(e) => proto::error_line(&e),
    }
}

fn live_json(j: &JobStatus) -> String {
    Obj::new()
        .str("name", &j.name)
        .str("engine", &proto::engine_token(j.engine))
        .int("steps", j.steps)
        .int("max_iter", j.max_iter)
        .num("gbest", j.gbest_fit)
        .int("stream", j.stream as u64)
        .render()
}

fn finished_json(f: &FinishedJob) -> String {
    Obj::new()
        .str("name", &f.name)
        .str("engine", &proto::engine_token(f.engine))
        .str("stop", &f.stop.to_string())
        .int("steps", f.steps)
        .num("gbest", f.gbest_fit)
        .render()
}
