//! Unix-domain-socket front end for a [`ServiceSession`].
//!
//! One accept thread, one thread per connection. Each connection is a
//! sequence of request lines answered by response lines
//! ([`super::proto`]); a `watch` request flips the connection into a
//! one-way telemetry stream until either side disconnects. Connection
//! threads only ever talk to the daemon through a [`ServiceHandle`], so
//! every mutation still funnels through the round-boundary control
//! queue — the socket layer adds no new synchronization.
//!
//! [`ServiceSession`]: super::ServiceSession

use super::proto::{self, Obj, Request};
use super::{FinishedJob, JobStatus, ServiceHandle};
use anyhow::{bail, Context, Result};
use std::io::{BufRead, BufReader, Write};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::Path;
use std::thread::JoinHandle;

/// Bind the service socket. A stale socket file left by a dead daemon
/// is removed and rebound; a *live* one (something accepts connections)
/// is a loud error — two daemons must not fight over one path.
pub fn bind(path: &Path) -> Result<UnixListener> {
    match UnixListener::bind(path) {
        Ok(listener) => Ok(listener),
        Err(e) if e.kind() == std::io::ErrorKind::AddrInUse => {
            if UnixStream::connect(path).is_ok() {
                bail!("{} is already being served", path.display());
            }
            std::fs::remove_file(path)
                .with_context(|| format!("removing stale socket {}", path.display()))?;
            UnixListener::bind(path)
                .with_context(|| format!("binding {} after stale cleanup", path.display()))
        }
        Err(e) => Err(e).with_context(|| format!("binding {}", path.display())),
    }
}

/// Spawn the accept loop: one detached thread per connection, each
/// driving `handle`. The loop ends when the listener errors (e.g. the
/// process is shutting down and closed it).
pub fn spawn_server(listener: UnixListener, handle: ServiceHandle) -> JoinHandle<()> {
    std::thread::Builder::new()
        .name("cupso-serve-accept".into())
        .spawn(move || {
            for conn in listener.incoming() {
                let Ok(stream) = conn else { break };
                let handle = handle.clone();
                let _ = std::thread::Builder::new()
                    .name("cupso-serve-conn".into())
                    .spawn(move || {
                        let _ = handle_conn(stream, handle);
                    });
            }
        })
        .expect("spawn accept thread")
}

/// Longest request line the server accepts. Generous for any real
/// request (a submit is a few hundred bytes) while bounding the memory a
/// newline-free sender can pin per connection.
const MAX_REQUEST_BYTES: usize = 1 << 20;

/// Read one `\n`-terminated line, refusing to buffer more than `max`
/// bytes (`BufRead::lines` would grow without bound on a newline-free
/// stream). `Ok(None)` = clean EOF.
fn read_line_bounded(reader: &mut impl BufRead, max: usize) -> Result<Option<String>> {
    let mut line: Vec<u8> = Vec::new();
    loop {
        let (chunk, newline_at) = {
            let buf = reader.fill_buf().context("reading request line")?;
            if buf.is_empty() {
                if line.is_empty() {
                    return Ok(None);
                }
                bail!("connection closed mid-request");
            }
            let newline_at = buf.iter().position(|&b| b == b'\n');
            let take = newline_at.map_or(buf.len(), |p| p);
            (buf[..take].to_vec(), newline_at)
        };
        if line.len() + chunk.len() > max {
            bail!("request line exceeds {max} bytes");
        }
        line.extend_from_slice(&chunk);
        match newline_at {
            Some(p) => {
                reader.consume(p + 1);
                let text = String::from_utf8(line).context("request line is not UTF-8")?;
                return Ok(Some(text));
            }
            None => reader.consume(chunk.len()),
        }
    }
}

fn handle_conn(stream: UnixStream, handle: ServiceHandle) -> Result<()> {
    let mut reader = BufReader::new(stream.try_clone().context("cloning connection")?);
    let mut writer = stream;
    while let Some(line) = read_line_bounded(&mut reader, MAX_REQUEST_BYTES)? {
        if line.trim().is_empty() {
            continue;
        }
        let reply = match Request::parse(&line) {
            Err(e) => proto::error_line(&format!("{e:#}")),
            Ok(Request::Drain) => {
                // Drain shuts the daemon down; hand it a completion
                // latch so it waits for this response to reach the
                // client before the process exits (otherwise the reply
                // write races process teardown and the client sees EOF).
                let (done_tx, done_rx) = std::sync::mpsc::channel();
                let reply = match handle.drain_then(done_rx) {
                    Ok(report) => {
                        let mut obj = Obj::new()
                            .bool("ok", true)
                            .str("op", "drain")
                            .int("snapshotted", report.snapshotted as u64)
                            .int("finished", report.finished);
                        if let Some(dir) = &report.dir {
                            obj = obj.str("dir", &dir.display().to_string());
                        }
                        obj.render()
                    }
                    Err(e) => proto::error_line(&format!("{e:#}")),
                };
                writeln!(writer, "{reply}")?;
                writer.flush()?;
                let _ = done_tx.send(());
                continue;
            }
            Ok(Request::Watch) => {
                // Ack, then switch to the one-way stream until the
                // client disconnects or the service ends.
                let rx = match handle.watch() {
                    Ok(rx) => rx,
                    Err(e) => {
                        writeln!(writer, "{}", proto::error_line(&format!("{e:#}")))?;
                        return Ok(());
                    }
                };
                writeln!(writer, "{}", Obj::new().bool("ok", true).str("op", "watch").render())?;
                writer.flush()?;
                for event in rx {
                    if writeln!(writer, "{event}").is_err() {
                        break; // client went away; retain() reaps us
                    }
                }
                return Ok(());
            }
            Ok(req) => respond(&handle, req),
        };
        writeln!(writer, "{reply}")?;
        writer.flush()?;
    }
    Ok(())
}

/// Execute one non-watch request and render its response line.
fn respond(handle: &ServiceHandle, req: Request) -> String {
    let result = match req {
        Request::Ping => Ok(Obj::new().bool("ok", true).str("op", "ping").render()),
        Request::Submit(job) => crate::scheduler::JobSpec::from_config(&job)
            .and_then(|spec| handle.submit(spec))
            .map(|ack| {
                Obj::new()
                    .bool("ok", true)
                    .str("op", "submit")
                    .str("name", &ack.name)
                    .int("slot", ack.slot as u64)
                    .int("stream", ack.stream as u64)
                    .render()
            }),
        Request::Cancel { name } => handle.cancel(&name).map(|row| {
            Obj::new()
                .bool("ok", true)
                .str("op", "cancel")
                .raw("job", &finished_json(&row))
                .render()
        }),
        Request::Status => handle.status().map(|report| {
            let live = proto::array(report.live.iter().map(live_json));
            let finished = proto::array(report.finished.iter().map(finished_json));
            Obj::new()
                .bool("ok", true)
                .str("op", "status")
                .int("rounds", report.rounds)
                .int("streams", report.streams as u64)
                .int("finished_total", report.finished_total)
                .raw("live", &live)
                .raw("finished", &finished)
                .render()
        }),
        Request::Drain | Request::Watch => {
            unreachable!("drain and watch are handled by the connection loop")
        }
    };
    result.unwrap_or_else(|e| proto::error_line(&format!("{e:#}")))
}

fn live_json(j: &JobStatus) -> String {
    Obj::new()
        .str("name", &j.name)
        .str("engine", &proto::engine_token(j.engine))
        .int("steps", j.steps)
        .int("max_iter", j.max_iter)
        .num("gbest", j.gbest_fit)
        .int("stream", j.stream as u64)
        .render()
}

fn finished_json(f: &FinishedJob) -> String {
    Obj::new()
        .str("name", &f.name)
        .str("engine", &proto::engine_token(f.engine))
        .str("stop", &f.stop.to_string())
        .int("steps", f.steps)
        .num("gbest", f.gbest_fit)
        .render()
}
