//! The service wire protocol: line-oriented JSON over a Unix domain
//! socket.
//!
//! Hand-rolled like [`crate::benchkit::json`] — serde is unavailable
//! offline — but bidirectional: this module carries a small JSON-subset
//! **parser** ([`Json::parse`]) next to a compact single-line writer.
//! Every request and every response is exactly one `\n`-terminated JSON
//! object, so framing is trivial (`BufRead::lines`) and a shell client
//! (`nc -U`, the `cupso submit/status/...` verbs) stays one line of
//! text per exchange.
//!
//! ## Requests
//!
//! ```text
//! {"op": "ping"}
//! {"op": "submit", "job": {"name": "a", "fitness": "sphere", ...}}
//! {"op": "cancel", "name": "a"}
//! {"op": "status"}
//! {"op": "drain"}
//! {"op": "watch"}
//! {"op": "metrics"}
//! ```
//!
//! ## Responses
//!
//! Every response carries `"ok": true|false`; failures carry `"error"`.
//! `watch` switches the connection to a one-way stream: one
//! `{"event": "report", ...}` line per scheduling round and job until
//! the client disconnects or the service drains (a final
//! `{"event": "end"}` line). An idle service emits periodic
//! `{"event": "ping"}` heartbeats on watch streams — consumers should
//! ignore event types they don't know.
//!
//! The `job` object mirrors the `[jobs.<name>]` section of a batch TOML
//! field for field, and decoding funnels through the same
//! [`JobConfig::validate`] — the two intake paths cannot drift.

use crate::config::{EngineKind, JobConfig};
use crate::fitness::Objective;
use anyhow::{bail, Context, Result};

/// A parsed JSON value (the subset the protocol needs).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// Any number (always carried as `f64`).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object, insertion-ordered.
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Parse one JSON value from `text` (must be the whole input modulo
    /// surrounding whitespace). Nesting is capped at [`MAX_DEPTH`]: the
    /// parser recurses per level, and a hostile `[[[[…` line must be an
    /// error, not a stack overflow that aborts the daemon.
    pub fn parse(text: &str) -> Result<Json> {
        let bytes = text.as_bytes();
        let mut pos = 0usize;
        let value = parse_value(bytes, &mut pos, 0)?;
        skip_ws(bytes, &mut pos);
        if pos != bytes.len() {
            bail!("trailing characters after JSON value at byte {pos}");
        }
        Ok(value)
    }

    /// Render this value back to one compact JSON line — the exact
    /// writer the daemon's responses use ([`Obj`]/[`array`] are built on
    /// the same `escape`/`number` primitives), so a parse → render round
    /// trip cannot drift from what travels on the wire.
    pub fn render(&self) -> String {
        match self {
            Json::Null => "null".to_string(),
            Json::Bool(b) => b.to_string(),
            Json::Num(n) => number(*n),
            Json::Str(s) => format!("\"{}\"", escape(s)),
            Json::Arr(items) => array(items.iter().map(Json::render)),
            Json::Obj(fields) => {
                let body: Vec<String> = fields
                    .iter()
                    .map(|(k, v)| format!("\"{}\": {}", escape(k), v.render()))
                    .collect();
                format!("{{{}}}", body.join(", "))
            }
        }
    }

    /// Object field lookup (first match).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Coerce to a string.
    pub fn as_str(&self, ctx: &str) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            other => bail!("{ctx}: expected string, got {other:?}"),
        }
    }

    /// Coerce to a float.
    pub fn as_f64(&self, ctx: &str) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            other => bail!("{ctx}: expected number, got {other:?}"),
        }
    }

    /// Coerce to a non-negative integer. Rejects fractions and negatives
    /// (a submit with `particles = -1` must be loud) AND anything above
    /// 2^53: numbers travel as `f64`, so larger integers would round
    /// silently — e.g. a hash-derived seed of 2^53+1 would admit a job
    /// with a *different* seed, corrupting reproducibility without any
    /// error. Loud refusal is the only safe answer.
    pub fn as_u64(&self, ctx: &str) -> Result<u64> {
        let n = self.as_f64(ctx)?;
        const MAX_EXACT: f64 = (1u64 << 53) as f64;
        if !(n.is_finite() && n >= 0.0 && n.fract() == 0.0) {
            bail!("{ctx}: expected a non-negative integer, got {n}");
        }
        // `>=`, not `>`: 2^53 itself must be refused because 2^53 + 1
        // parses to exactly 2^53 in f64 — accepting the boundary value
        // would silently admit its unrepresentable neighbour.
        if n >= MAX_EXACT {
            bail!(
                "{ctx}: {n} is at or above 2^53, where JSON numbers stop \
                 carrying integers exactly — pick a smaller value"
            );
        }
        Ok(n as u64)
    }

    /// Coerce to a bool.
    pub fn as_bool(&self, ctx: &str) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            other => bail!("{ctx}: expected bool, got {other:?}"),
        }
    }

    /// Required string field of an object.
    pub fn str_field(&self, key: &str) -> Result<&str> {
        self.get(key)
            .with_context(|| format!("missing field {key:?}"))?
            .as_str(key)
    }

    /// Required numeric field that may legitimately be `null`: the wire
    /// writer ([`number`]) has no representation for non-finite floats,
    /// so a just-admitted maximize job's `gbest = -inf` travels as
    /// `null`. Clients must read fitness fields through this (a bare
    /// [`as_f64`](Self::as_f64) would reject the very first status or
    /// watch row of such a job). `None` = "no finite value yet".
    pub fn num_or_null_field(&self, key: &str) -> Result<Option<f64>> {
        match self
            .get(key)
            .with_context(|| format!("missing field {key:?}"))?
        {
            Json::Null => Ok(None),
            value => value.as_f64(key).map(Some),
        }
    }
}

/// Deepest value nesting the parser accepts (recursion-depth bound).
pub const MAX_DEPTH: usize = 64;

fn skip_ws(bytes: &[u8], pos: &mut usize) {
    while *pos < bytes.len() && matches!(bytes[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn parse_value(bytes: &[u8], pos: &mut usize, depth: usize) -> Result<Json> {
    if depth > MAX_DEPTH {
        bail!("JSON nesting deeper than {MAX_DEPTH} levels");
    }
    skip_ws(bytes, pos);
    let Some(&c) = bytes.get(*pos) else {
        bail!("unexpected end of JSON input");
    };
    match c {
        b'{' => parse_obj(bytes, pos, depth),
        b'[' => parse_arr(bytes, pos, depth),
        b'"' => Ok(Json::Str(parse_string(bytes, pos)?)),
        b't' => parse_lit(bytes, pos, "true", Json::Bool(true)),
        b'f' => parse_lit(bytes, pos, "false", Json::Bool(false)),
        b'n' => parse_lit(bytes, pos, "null", Json::Null),
        _ => parse_num(bytes, pos),
    }
}

fn parse_lit(bytes: &[u8], pos: &mut usize, lit: &str, value: Json) -> Result<Json> {
    if bytes[*pos..].starts_with(lit.as_bytes()) {
        *pos += lit.len();
        Ok(value)
    } else {
        bail!("invalid JSON literal at byte {pos}");
    }
}

fn parse_num(bytes: &[u8], pos: &mut usize) -> Result<Json> {
    let start = *pos;
    while *pos < bytes.len()
        && matches!(bytes[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
    {
        *pos += 1;
    }
    let text = std::str::from_utf8(&bytes[start..*pos]).expect("ascii number slice");
    let n: f64 = text
        .parse()
        .map_err(|e| anyhow::anyhow!("bad JSON number {text:?} at byte {start}: {e}"))?;
    Ok(Json::Num(n))
}

fn parse_string(bytes: &[u8], pos: &mut usize) -> Result<String> {
    debug_assert_eq!(bytes[*pos], b'"');
    *pos += 1;
    let mut out = String::new();
    loop {
        let Some(&c) = bytes.get(*pos) else {
            bail!("unterminated JSON string");
        };
        *pos += 1;
        match c {
            b'"' => return Ok(out),
            b'\\' => {
                let Some(&esc) = bytes.get(*pos) else {
                    bail!("unterminated escape in JSON string");
                };
                *pos += 1;
                match esc {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'n' => out.push('\n'),
                    b'r' => out.push('\r'),
                    b't' => out.push('\t'),
                    b'b' => out.push('\u{0008}'),
                    b'f' => out.push('\u{000c}'),
                    b'u' => {
                        let hex = bytes
                            .get(*pos..*pos + 4)
                            .context("truncated \\u escape in JSON string")?;
                        let hex = std::str::from_utf8(hex).context("non-ASCII \\u escape")?;
                        let code =
                            u32::from_str_radix(hex, 16).context("bad \\u escape in JSON string")?;
                        *pos += 4;
                        // Surrogates are not needed by this protocol; map
                        // them (and any other invalid scalar) to an error.
                        out.push(
                            char::from_u32(code)
                                .with_context(|| format!("\\u{hex} is not a scalar value"))?,
                        );
                    }
                    other => bail!("unknown escape \\{} in JSON string", other as char),
                }
            }
            _ => {
                // Multi-byte UTF-8: copy the whole sequence verbatim.
                let width = utf8_width(c);
                let seq = bytes
                    .get(*pos - 1..*pos - 1 + width)
                    .context("truncated UTF-8 in JSON string")?;
                out.push_str(std::str::from_utf8(seq).context("invalid UTF-8 in JSON string")?);
                *pos += width - 1;
            }
        }
    }
}

fn utf8_width(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

fn parse_obj(bytes: &[u8], pos: &mut usize, depth: usize) -> Result<Json> {
    debug_assert_eq!(bytes[*pos], b'{');
    *pos += 1;
    let mut fields = Vec::new();
    skip_ws(bytes, pos);
    if bytes.get(*pos) == Some(&b'}') {
        *pos += 1;
        return Ok(Json::Obj(fields));
    }
    loop {
        skip_ws(bytes, pos);
        if bytes.get(*pos) != Some(&b'"') {
            bail!("expected object key at byte {pos}");
        }
        let key = parse_string(bytes, pos)?;
        skip_ws(bytes, pos);
        if bytes.get(*pos) != Some(&b':') {
            bail!("expected ':' after object key at byte {pos}");
        }
        *pos += 1;
        let value = parse_value(bytes, pos, depth + 1)?;
        fields.push((key, value));
        skip_ws(bytes, pos);
        match bytes.get(*pos) {
            Some(&b',') => *pos += 1,
            Some(&b'}') => {
                *pos += 1;
                return Ok(Json::Obj(fields));
            }
            _ => bail!("expected ',' or '}}' at byte {pos}"),
        }
    }
}

fn parse_arr(bytes: &[u8], pos: &mut usize, depth: usize) -> Result<Json> {
    debug_assert_eq!(bytes[*pos], b'[');
    *pos += 1;
    let mut items = Vec::new();
    skip_ws(bytes, pos);
    if bytes.get(*pos) == Some(&b']') {
        *pos += 1;
        return Ok(Json::Arr(items));
    }
    loop {
        items.push(parse_value(bytes, pos, depth + 1)?);
        skip_ws(bytes, pos);
        match bytes.get(*pos) {
            Some(&b',') => *pos += 1,
            Some(&b']') => {
                *pos += 1;
                return Ok(Json::Arr(items));
            }
            _ => bail!("expected ',' or ']' at byte {pos}"),
        }
    }
}

/// Escape a string for a JSON string literal.
pub fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Render an `f64` as a JSON value (non-finite values become `null` —
/// JSON has no NaN/∞).
pub fn number(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "null".to_string()
    }
}

/// A compact single-line JSON object, built key by key (insertion order
/// preserved). Unlike the bench writer this one nests: [`Obj::raw`]
/// splices a pre-rendered value (another object, an array).
#[derive(Default)]
pub struct Obj {
    parts: Vec<String>,
}

impl Obj {
    /// Empty object.
    pub fn new() -> Self {
        Self::default()
    }

    /// Add a string field.
    pub fn str(mut self, key: &str, value: &str) -> Self {
        self.parts
            .push(format!("\"{}\": \"{}\"", escape(key), escape(value)));
        self
    }

    /// Add a numeric field.
    pub fn num(mut self, key: &str, value: f64) -> Self {
        self.parts
            .push(format!("\"{}\": {}", escape(key), number(value)));
        self
    }

    /// Add an integer field.
    pub fn int(mut self, key: &str, value: u64) -> Self {
        self.parts.push(format!("\"{}\": {value}", escape(key)));
        self
    }

    /// Add a boolean field.
    pub fn bool(mut self, key: &str, value: bool) -> Self {
        self.parts.push(format!("\"{}\": {value}", escape(key)));
        self
    }

    /// Splice a pre-rendered JSON value (nested object / array).
    pub fn raw(mut self, key: &str, rendered: &str) -> Self {
        self.parts.push(format!("\"{}\": {rendered}", escape(key)));
        self
    }

    /// Render as one compact line.
    pub fn render(&self) -> String {
        format!("{{{}}}", self.parts.join(", "))
    }
}

/// Render a JSON array from pre-rendered items.
pub fn array<I: IntoIterator<Item = String>>(items: I) -> String {
    let body: Vec<String> = items.into_iter().collect();
    format!("[{}]", body.join(", "))
}

/// One client request.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    /// Liveness probe.
    Ping,
    /// Admit a new job at the next round boundary.
    Submit(JobConfig),
    /// Cancel a live job by name at the next round boundary.
    Cancel {
        /// The job's identity key.
        name: String,
    },
    /// Snapshot of live jobs, finished results and round progress.
    Status,
    /// Checkpoint all live jobs to the service's snapshot directory and
    /// shut down (resumable via `cupso resume`).
    Drain,
    /// Subscribe this connection to the per-round telemetry stream.
    Watch,
    /// Snapshot of the process-global telemetry registry (counters,
    /// round-phase histograms, gauges, trace-ring state).
    Metrics,
}

impl Request {
    /// Parse one request line.
    pub fn parse(line: &str) -> Result<Request> {
        let doc = Json::parse(line)?;
        let op = doc.str_field("op")?;
        Ok(match op {
            "ping" => Request::Ping,
            "submit" => {
                let job = doc.get("job").context("submit: missing field \"job\"")?;
                Request::Submit(job_from_json(job)?)
            }
            "cancel" => Request::Cancel {
                name: doc.str_field("name")?.to_string(),
            },
            "status" => Request::Status,
            "drain" => Request::Drain,
            "watch" => Request::Watch,
            "metrics" => Request::Metrics,
            other => {
                bail!("unknown op {other:?} (ping|submit|cancel|status|drain|watch|metrics)")
            }
        })
    }

    /// Render as one request line (no trailing newline).
    pub fn render(&self) -> String {
        match self {
            Request::Ping => Obj::new().str("op", "ping").render(),
            Request::Submit(job) => Obj::new()
                .str("op", "submit")
                .raw("job", &job_to_json(job))
                .render(),
            Request::Cancel { name } => {
                Obj::new().str("op", "cancel").str("name", name).render()
            }
            Request::Status => Obj::new().str("op", "status").render(),
            Request::Drain => Obj::new().str("op", "drain").render(),
            Request::Watch => Obj::new().str("op", "watch").render(),
            Request::Metrics => Obj::new().str("op", "metrics").render(),
        }
    }
}

/// Canonical engine token: the table label, lowercased and de-spaced —
/// always accepted back by [`EngineKind::parse`].
pub fn engine_token(kind: EngineKind) -> String {
    kind.label().replace(' ', "").to_ascii_lowercase()
}

/// Serialize a job config as the protocol's `job` object (optional
/// fields omitted when unset).
pub fn job_to_json(job: &JobConfig) -> String {
    let mut obj = Obj::new()
        .str("name", &job.name)
        .str("fitness", &job.fitness)
        .int("particles", job.particles as u64)
        .int("dim", job.dim as u64)
        .int("iters", job.iters)
        .str("engine", &engine_token(job.engine))
        .num("vmax_frac", job.vmax_frac)
        .int("seed", job.seed);
    if let Some(o) = job.objective {
        obj = obj.str(
            "objective",
            match o {
                Objective::Maximize => "max",
                Objective::Minimize => "min",
            },
        );
    }
    if let Some(t) = job.target_fitness {
        obj = obj.num("target_fitness", t);
    }
    if let Some(w) = job.stall_window {
        obj = obj.int("stall_window", w);
    }
    if let Some(m) = job.max_steps {
        obj = obj.int("max_steps", m);
    }
    if let Some(d) = job.deadline {
        obj = obj.int("deadline", d);
    }
    if let Some(t) = &job.tenant {
        obj = obj.str("tenant", t);
    }
    obj.render()
}

/// Decode the protocol's `job` object into a validated [`JobConfig`] —
/// the same defaults and the same `validate()` as a `[jobs.<name>]`
/// batch-TOML section, so the two intake paths cannot drift.
pub fn job_from_json(doc: &Json) -> Result<JobConfig> {
    let name = doc.str_field("name")?;
    if name.is_empty() {
        bail!("job name must not be empty");
    }
    let mut job = JobConfig::with_defaults(name);
    for (key, value) in match doc {
        Json::Obj(fields) => fields.iter(),
        other => bail!("job: expected object, got {other:?}"),
    } {
        let ctx = format!("job.{key}");
        match key.as_str() {
            "name" => {}
            "fitness" => job.fitness = value.as_str(&ctx)?.to_string(),
            "objective" => {
                let v = value.as_str(&ctx)?;
                job.objective =
                    Some(Objective::parse(v).with_context(|| format!("bad objective {v}"))?);
            }
            "particles" => job.particles = value.as_u64(&ctx)? as usize,
            "dim" => job.dim = value.as_u64(&ctx)? as usize,
            "iters" => job.iters = value.as_u64(&ctx)?,
            "engine" => {
                let v = value.as_str(&ctx)?;
                job.engine = EngineKind::parse(v).with_context(|| format!("bad engine {v}"))?;
            }
            "vmax_frac" => job.vmax_frac = value.as_f64(&ctx)?,
            "seed" => job.seed = value.as_u64(&ctx)?,
            "target_fitness" => job.target_fitness = Some(value.as_f64(&ctx)?),
            "stall_window" => job.stall_window = Some(value.as_u64(&ctx)?),
            "max_steps" => job.max_steps = Some(value.as_u64(&ctx)?),
            "deadline" => job.deadline = Some(value.as_u64(&ctx)?),
            "tenant" => job.tenant = Some(value.as_str(&ctx)?.to_string()),
            other => bail!("job {name}: unknown field {other:?}"),
        }
    }
    job.validate()?;
    Ok(job)
}

/// Render a failure response.
pub fn error_line(err: &str) -> String {
    Obj::new().bool("ok", false).str("error", err).render()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars_strings_and_nesting() {
        let doc = Json::parse(
            r#"{"a": 1, "b": -2.5, "c": "x\n\"y\"", "d": true, "e": null, "f": [1, "two"], "g": {"h": 3}}"#,
        )
        .unwrap();
        assert_eq!(doc.get("a").unwrap().as_u64("a").unwrap(), 1);
        assert_eq!(doc.get("b").unwrap().as_f64("b").unwrap(), -2.5);
        assert_eq!(doc.get("c").unwrap().as_str("c").unwrap(), "x\n\"y\"");
        assert!(doc.get("d").unwrap().as_bool("d").unwrap());
        assert_eq!(doc.get("e"), Some(&Json::Null));
        match doc.get("f").unwrap() {
            Json::Arr(items) => assert_eq!(items.len(), 2),
            other => panic!("not an array: {other:?}"),
        }
        assert_eq!(
            doc.get("g").unwrap().get("h").unwrap().as_u64("h").unwrap(),
            3
        );
    }

    #[test]
    fn rejects_malformed_input() {
        for bad in [
            "",
            "{",
            "{\"a\": }",
            "{\"a\": 1,}",
            "{\"a\" 1}",
            "[1, 2",
            "\"unterminated",
            "{\"a\": 1} trailing",
            "{\"a\": tru}",
            "nul",
        ] {
            assert!(Json::parse(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn unicode_and_escapes_roundtrip() {
        let original = "héllo \"wörld\" →\t\\end";
        let line = Obj::new().str("s", original).render();
        let doc = Json::parse(&line).unwrap();
        assert_eq!(doc.str_field("s").unwrap(), original);
        // \u escapes decode too.
        let doc = Json::parse(r#"{"s": "Aé"}"#).unwrap();
        assert_eq!(doc.str_field("s").unwrap(), "Aé");
    }

    #[test]
    fn as_u64_rejects_fractions_negatives_and_imprecise_integers() {
        assert!(Json::Num(1.5).as_u64("x").is_err());
        assert!(Json::Num(-1.0).as_u64("x").is_err());
        assert_eq!(Json::Num(7.0).as_u64("x").unwrap(), 7);
        // 2^53 - 1 is the last value every neighbour of which is still
        // distinguishable; from 2^53 on, f64 rounds silently (2^53 + 1
        // parses to exactly 2^53), so the boundary itself must already
        // be refused — a seed that parsed off-by-one would corrupt
        // reproducibility without any error.
        let max_exact = (1u64 << 53) - 1;
        assert_eq!(Json::Num(max_exact as f64).as_u64("x").unwrap(), max_exact);
        for too_big in [9007199254740992.0, 9.007199254740994e15, 1e300] {
            let err = Json::Num(too_big).as_u64("seed").unwrap_err().to_string();
            assert!(err.contains("2^53"), "{too_big}: {err}");
        }
    }

    #[test]
    fn nesting_depth_is_bounded_not_a_stack_overflow() {
        // A hostile `[[[[…` request must be a parse error; unbounded
        // recursion would abort the whole daemon.
        let deep = "[".repeat(100_000);
        let err = Json::parse(&deep).unwrap_err().to_string();
        assert!(err.contains("nesting"), "{err}");
        // Reasonable nesting still parses.
        let ok = format!("{}1{}", "[".repeat(20), "]".repeat(20));
        assert!(Json::parse(&ok).is_ok());
    }

    #[test]
    fn render_round_trips_every_shape() {
        let line = r#"{"a": 1, "b": [true, null, "x\ny"], "c": {"d": -2.5}}"#;
        let doc = Json::parse(line).unwrap();
        let rendered = doc.render();
        assert_eq!(Json::parse(&rendered).unwrap(), doc);
    }

    #[test]
    fn requests_roundtrip_through_render_and_parse() {
        let mut job = JobConfig::with_defaults("alpha");
        job.fitness = "sphere".into();
        job.dim = 3;
        job.iters = 500;
        job.engine = EngineKind::Queue;
        job.seed = 9;
        job.objective = Some(Objective::Minimize);
        job.target_fitness = Some(1e-3);
        job.deadline = Some(400);
        job.tenant = Some("team-a".into());
        for req in [
            Request::Ping,
            Request::Submit(job),
            Request::Cancel { name: "alpha".into() },
            Request::Status,
            Request::Drain,
            Request::Watch,
            Request::Metrics,
        ] {
            let line = req.render();
            let back = Request::parse(&line).unwrap();
            match (&req, &back) {
                (Request::Submit(a), Request::Submit(b)) => {
                    assert_eq!(a.name, b.name);
                    assert_eq!(a.fitness, b.fitness);
                    assert_eq!(a.objective, b.objective);
                    assert_eq!(a.particles, b.particles);
                    assert_eq!(a.dim, b.dim);
                    assert_eq!(a.iters, b.iters);
                    assert_eq!(a.engine, b.engine);
                    assert_eq!(a.vmax_frac, b.vmax_frac);
                    assert_eq!(a.seed, b.seed);
                    assert_eq!(a.target_fitness, b.target_fitness);
                    assert_eq!(a.stall_window, b.stall_window);
                    assert_eq!(a.max_steps, b.max_steps);
                    assert_eq!(a.deadline, b.deadline);
                    assert_eq!(a.tenant, b.tenant);
                }
                (a, b) => assert_eq!(a, b, "{line}"),
            }
        }
    }

    #[test]
    fn every_engine_token_parses_back() {
        for kind in [
            EngineKind::SerialCpu,
            EngineKind::Reduction,
            EngineKind::LoopUnrolling,
            EngineKind::Queue,
            EngineKind::QueueLock,
            EngineKind::AsyncPersistent,
        ] {
            let token = engine_token(kind);
            assert_eq!(EngineKind::parse(&token), Some(kind), "{token}");
        }
    }

    #[test]
    fn submit_decoding_is_validated_and_loud() {
        // Unknown field.
        let err = Request::parse(r#"{"op": "submit", "job": {"name": "x", "nope": 1}}"#)
            .unwrap_err()
            .to_string();
        assert!(err.contains("nope"), "{err}");
        // Invalid workload (validate() fires).
        let err = Request::parse(r#"{"op": "submit", "job": {"name": "x", "particles": 0}}"#)
            .unwrap_err()
            .to_string();
        assert!(err.contains("particles"), "{err}");
        // XLA engines are not schedulable.
        let err = Request::parse(r#"{"op": "submit", "job": {"name": "x", "engine": "xla"}}"#)
            .unwrap_err()
            .to_string();
        assert!(err.contains("not schedulable"), "{err}");
        // Missing name.
        assert!(Request::parse(r#"{"op": "submit", "job": {"fitness": "sphere"}}"#).is_err());
        // Unknown op.
        let err = Request::parse(r#"{"op": "frobnicate"}"#).unwrap_err().to_string();
        assert!(err.contains("unknown op"), "{err}");
    }

    #[test]
    fn non_finite_fitness_travels_as_null_and_reads_back_tolerantly() {
        // A just-admitted maximize job's gbest is -inf until its first
        // improving round; the writer must emit `null` (JSON has no
        // infinities) and the tolerant reader must accept it.
        for v in [f64::NEG_INFINITY, f64::INFINITY, f64::NAN] {
            assert_eq!(number(v), "null", "{v}");
        }
        let line = Obj::new()
            .str("name", "hot")
            .num("gbest", f64::NEG_INFINITY)
            .int("steps", 0)
            .render();
        let doc = Json::parse(&line).unwrap();
        assert_eq!(doc.num_or_null_field("gbest").unwrap(), None);
        assert_eq!(doc.num_or_null_field("steps").unwrap(), Some(0.0));
        // A finite gbest still reads through the same accessor.
        let doc = Json::parse(r#"{"gbest": -2.5}"#).unwrap();
        assert_eq!(doc.num_or_null_field("gbest").unwrap(), Some(-2.5));
        // Missing stays loud; wrong type stays loud.
        assert!(doc.num_or_null_field("absent").is_err());
        let doc = Json::parse(r#"{"gbest": "oops"}"#).unwrap();
        assert!(doc.num_or_null_field("gbest").is_err());
    }

    #[test]
    fn error_line_is_parseable() {
        let line = error_line("bad \"thing\"\nhappened");
        let doc = Json::parse(&line).unwrap();
        assert!(!doc.get("ok").unwrap().as_bool("ok").unwrap());
        assert_eq!(doc.str_field("error").unwrap(), "bad \"thing\"\nhappened");
    }
}
