//! `cupso serve` — the live job-service daemon.
//!
//! Every earlier entry point (`cupso batch`, [`crate::scheduler`]'s
//! fixed-batch calls) takes a job list decided before the session
//! starts. A service handling live traffic cannot: tenants submit work
//! to a *running* scheduler, cancel it, watch it, and expect the whole
//! thing to shut down cleanly without losing their state. This module
//! provides exactly that, in three pieces:
//!
//! * [`ServiceSession`] — the daemon loop. It owns a dynamic
//!   [`Session`] (slot table, admission, cancellation, reaping) and an
//!   MPSC **control queue** ([`Control`]) that is drained at every
//!   round boundary: submits, cancels, status probes and the drain
//!   request all take effect *between* scheduling rounds, when every
//!   grid is quiescent. That boundary is what keeps the determinism
//!   invariant alive under live traffic — a job's trajectory is
//!   bit-identical regardless of when its neighbours were admitted or
//!   cancelled (`rust/tests/scheduler_determinism.rs`) — and it costs
//!   nothing in the steady state: an empty control queue is one
//!   non-allocating `try_recv` per round, so warmed-up rounds stay
//!   zero-allocation (`rust/tests/zero_alloc.rs`).
//! * [`ServiceHandle`] — the cloneable client side of the control
//!   queue, with blocking convenience calls (`submit`, `cancel`,
//!   `status`, `drain`, `watch`) plus non-blocking `*_deferred`
//!   variants that return the reply channel instead of waiting on it —
//!   the event-loop server drives those, because a single-threaded loop
//!   must never park on one client's reply. In-process tests drive the
//!   blocking forms.
//! * [`proto`] / [`server`] — a line-oriented JSON protocol served over
//!   Unix-domain **and TCP** sockets by one nonblocking `poll(2)` event
//!   loop, so `cupso submit/status/cancel/drain` (or `nc -U` / `nc`)
//!   can talk to a daemon in another process — or another machine.
//!   The loop registers a [`Waker`] (via [`Control::SetWaker`]) so the
//!   service can rouse it when replies or telemetry become ready.
//!
//! **Drain semantics.** `drain` checkpoints every live job through the
//! shared snapshot store ([`crate::checkpoint::store`], the same
//! `manifest.toml` + `job_<i>.ckpt` layout `cupso batch
//! --checkpoint-dir` writes) and shuts the loop down. A drained service
//! therefore resumes through the *existing* `cupso resume` path — the
//! snapshot does not care whether its jobs arrived in a config file or
//! were admitted live. Finished (and cancelled) jobs are reaped into a
//! results table as they complete and are not part of the snapshot.
//!
//! **Crash safety.** With `checkpoint_every = N` in the knobs (CLI
//! `--checkpoint-every`) the loop *also* persists every live job through
//! the same store at every Nth round boundary, without stopping —
//! durably, via the fsync + manifest-commit-point discipline of
//! [`crate::checkpoint::io`]. [`ServiceSession::adopt`] is the matching
//! warm restart: `cupso serve --checkpoint-dir D` auto-adopts a valid
//! snapshot already in `D`, so a plain supervisor restart loop is a
//! correct recovery story — a `kill -9` loses at most the rounds since
//! the last snapshot, and the continuation is bit-exact for the
//! bit-exact engines (`rust/tests/durability.rs`). A periodic persist
//! *failure* is deliberately fatal: the daemon dies loudly with the last
//! durable snapshot intact rather than serving with silently degraded
//! durability.
//!
//! **Lifecycle.** [`ServiceSession::run`] loops until (a) a drain
//! request arrives, or (b) every [`ServiceHandle`] is dropped *and* all
//! admitted work has finished — so a library caller can simply drop the
//! handle and collect the results.

pub mod proto;
mod server;

pub use server::{
    bind, bind_tcp, spawn_server, spawn_server_on, Listener, DEFAULT_MAX_CONNS,
};

use crate::checkpoint::store::SnapshotSink;
use crate::checkpoint::JobCheckpoint;
use crate::config::{BatchConfig, EngineKind};
use crate::scheduler::{JobOutcome, JobReport, JobScheduler, JobSpec, Session, StopReason};
use crate::telemetry::{self, Counter, Series, TraceKind};
use anyhow::{Context, Result};
use std::collections::VecDeque;
use std::path::PathBuf;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Finished-job rows retained for `status` and the end-of-life summary.
/// A long-lived daemon completes unboundedly many jobs; the results
/// table is a *window* (newest kept, oldest evicted) so memory and
/// status-response size track current interest, not lifetime history —
/// the total count is always reported alongside.
pub const MAX_RESULTS: usize = 4096;

/// Telemetry lines buffered per watcher. A watcher that stops reading
/// (stalled client, full socket) falls behind; once it is this many
/// events behind its subscription is terminated, because the
/// alternative — buffering without bound — lets one stalled observer
/// OOM the whole daemon. The **last slot is reserved** for the
/// protocol-promised `{"event":"end"}` line: regular reports fill at
/// most `WATCH_BUFFER - 1` slots, so end-of-stream is deliverable even
/// to a watcher that overflowed (see [`WatchStream`]).
pub const WATCH_BUFFER: usize = 1024;

/// How often an *idle* service probes its watchers with a
/// `{"event": "ping"}` heartbeat. Rounds reap dead watchers as a side
/// effect of sending events; an idle daemon runs no rounds, so without
/// the probe a disconnected watch client would pin its channel (and its
/// server-side connection thread) forever.
pub const IDLE_WATCH_PROBE: Duration = Duration::from_secs(30);

/// Acknowledgement of a successful admission.
#[derive(Debug, Clone)]
pub struct Submitted {
    /// The job's identity key.
    pub name: std::sync::Arc<str>,
    /// Slot the job landed in (freed slots are recycled).
    pub slot: usize,
    /// Pool stream the job was pinned to at admission (`slot % S`;
    /// preemption may later migrate it).
    pub stream: usize,
}

/// One live job's status row.
#[derive(Debug, Clone)]
pub struct JobStatus {
    /// Job name.
    pub name: String,
    /// Engine kind.
    pub engine: EngineKind,
    /// Steps executed so far.
    pub steps: u64,
    /// Iteration budget.
    pub max_iter: u64,
    /// Current global-best fitness.
    pub gbest_fit: f64,
    /// Pool stream pinning.
    pub stream: usize,
}

/// One finished (or cancelled) job's result row.
#[derive(Debug, Clone)]
pub struct FinishedJob {
    /// Job name.
    pub name: String,
    /// Engine kind.
    pub engine: EngineKind,
    /// Why it stopped.
    pub stop: StopReason,
    /// Steps executed.
    pub steps: u64,
    /// Final global-best fitness.
    pub gbest_fit: f64,
}

/// A point-in-time view of the service.
#[derive(Debug, Clone)]
pub struct StatusReport {
    /// Scheduling rounds executed so far.
    pub rounds: u64,
    /// Concurrent pool streams.
    pub streams: usize,
    /// Live jobs, slot order.
    pub live: Vec<JobStatus>,
    /// The newest completed jobs (at most [`MAX_RESULTS`]), completion
    /// order.
    pub finished: Vec<FinishedJob>,
    /// Every job ever completed (cancellations included) — may exceed
    /// `finished.len()` once old rows have been evicted.
    pub finished_total: u64,
}

/// Acknowledgement of a successful drain.
#[derive(Debug, Clone)]
pub struct DrainReport {
    /// Live jobs checkpointed into the snapshot (0 = the service was
    /// idle; nothing was written).
    pub snapshotted: usize,
    /// Jobs that had already finished over the service's lifetime
    /// (their results were reported, not snapshotted).
    pub finished: u64,
    /// Where the snapshot landed, if one was written — feed it to
    /// `cupso resume`.
    pub dir: Option<PathBuf>,
}

/// Wake callback the socket server registers via [`Control::SetWaker`].
/// The service invokes it after processing controls, after fanning out
/// watch telemetry, and at shutdown — so a single-threaded event loop
/// can park in `poll(2)` and still learn promptly that deferred replies
/// or watch lines became ready (the callback writes one byte into the
/// loop's self-pipe).
pub type Waker = Arc<dyn Fn() + Send + Sync>;

/// Shared core of one watch subscription: a bounded line queue plus
/// lifecycle flags under one mutex, with a condvar for blocking
/// consumers. This replaces the old `SyncSender<String>` plumbing
/// because a plain channel cannot express the end-of-stream guarantee:
/// the queue's **last slot is reserved** for `{"event":"end"}`, so even
/// a stalled watcher whose buffer is full observes a deterministic
/// terminator instead of hanging until raw EOF.
struct WatchShared {
    state: Mutex<WatchState>,
    cv: Condvar,
}

struct WatchState {
    queue: VecDeque<String>,
    /// The end line has been queued; nothing further will ever arrive.
    ended: bool,
    /// The consuming side dropped its [`WatchStream`].
    dropped: bool,
    /// Report lines were refused because the consumer fell behind.
    lagged: bool,
}

/// The service's side of one watch subscription.
pub struct WatchSender {
    shared: Arc<WatchShared>,
}

impl WatchSender {
    /// Queue one telemetry line. `false` means the subscription is dead
    /// — the consumer vanished, or it just overflowed and was
    /// terminated — and the caller should drop this sender (the reap).
    fn send(&self, line: &str) -> bool {
        let mut st = self.shared.state.lock().expect("watch state lock");
        if st.dropped || st.ended {
            return false;
        }
        if st.queue.len() < WATCH_BUFFER - 1 {
            st.queue.push_back(line.to_string());
            self.shared.cv.notify_one();
            return true;
        }
        // Overflow: the consumer is WATCH_BUFFER - 1 lines behind. Keep
        // the bounded-memory promise by ending the subscription — but
        // through the reserved slot, so the client still reads the
        // protocol-promised terminator after its backlog.
        st.lagged = true;
        st.queue.push_back(end_line());
        st.ended = true;
        self.shared.cv.notify_one();
        false
    }

    /// Queue the final `{"event":"end"}` line. The reserved last slot
    /// guarantees space even when the consumer never read a byte.
    fn end(&self) {
        let mut st = self.shared.state.lock().expect("watch state lock");
        if st.ended || st.dropped {
            return;
        }
        st.queue.push_back(end_line());
        st.ended = true;
        self.shared.cv.notify_one();
    }
}

/// The consumer's side of one watch subscription (see
/// [`ServiceHandle::watch`]). Dropping it unsubscribes: the service
/// reaps the dead sender at its next send attempt.
pub struct WatchStream {
    shared: Arc<WatchShared>,
}

impl WatchStream {
    /// Non-blocking pop — the event loop's writable-driven pump.
    pub fn try_next(&self) -> Option<String> {
        self.shared
            .state
            .lock()
            .expect("watch state lock")
            .queue
            .pop_front()
    }

    /// Blocking pop, mpsc-flavoured so test code reads naturally:
    /// `Err(Timeout)` after `timeout` with nothing queued,
    /// `Err(Disconnected)` once the stream ended *and* the backlog is
    /// fully drained.
    pub fn recv_timeout(&self, timeout: Duration) -> Result<String, RecvTimeoutError> {
        let deadline = Instant::now() + timeout;
        let mut st = self.shared.state.lock().expect("watch state lock");
        loop {
            if let Some(line) = st.queue.pop_front() {
                return Ok(line);
            }
            if st.ended {
                return Err(RecvTimeoutError::Disconnected);
            }
            let Some(left) = deadline.checked_duration_since(Instant::now()) else {
                return Err(RecvTimeoutError::Timeout);
            };
            st = self
                .shared
                .cv
                .wait_timeout(st, left)
                .expect("watch state lock")
                .0;
        }
    }

    /// True once the subscription was terminated for falling
    /// [`WATCH_BUFFER`] lines behind (its final line is still `end`).
    pub fn lagged(&self) -> bool {
        self.shared.state.lock().expect("watch state lock").lagged
    }

    /// True once the service queued the final `end` line — after the
    /// backlog drains, [`try_next`](Self::try_next) stays `None` forever.
    pub fn ended(&self) -> bool {
        self.shared.state.lock().expect("watch state lock").ended
    }
}

impl Drop for WatchStream {
    fn drop(&mut self) {
        self.shared.state.lock().expect("watch state lock").dropped = true;
    }
}

/// The rendered `{"event":"end"}` terminator line.
fn end_line() -> String {
    proto::Obj::new().str("event", "end").render()
}

/// A control-queue message. Client convenience wrappers live on
/// [`ServiceHandle`]; each request carries its reply channel.
pub enum Control {
    /// Admit a job at the next round boundary (per-tenant quotas
    /// permitting — see [`crate::config::BatchConfig::quota_jobs`]).
    Submit(Box<JobSpec>, Sender<Result<Submitted, String>>),
    /// Cancel a live job by name at the next round boundary.
    Cancel(String, Sender<Result<FinishedJob, String>>),
    /// Report live jobs + finished results.
    Status(Sender<StatusReport>),
    /// Checkpoint all live jobs and shut down. The optional receiver is
    /// a **completion latch**: after a successful drain the loop waits
    /// (bounded) for it before returning, so the requester can flush
    /// its acknowledgement to its client before the daemon exits — see
    /// [`ServiceHandle::drain_then`].
    Drain(Sender<Result<DrainReport, String>>, Option<Receiver<()>>),
    /// Subscribe to the per-round telemetry stream (one JSON line per
    /// stepped job per round; a final `{"event": "end"}` at shutdown —
    /// guaranteed, even to overflowed subscribers, via the reserved
    /// [`WATCH_BUFFER`] slot).
    Watch(WatchSender),
    /// Register the event loop's wake callback (sent once, at server
    /// startup; MPSC ordering guarantees it precedes any client control
    /// enqueued by the same loop).
    SetWaker(Waker),
}

/// Cloneable client side of a [`ServiceSession`]'s control queue.
#[derive(Clone)]
pub struct ServiceHandle {
    tx: Sender<Control>,
}

impl ServiceHandle {
    fn send(&self, msg: Control) -> Result<()> {
        self.tx.send(msg).ok().context("service is no longer running")
    }

    /// Enqueue a control and hand back its reply channel *without*
    /// waiting — the deferred form the event loop needs, since a
    /// single-threaded loop must never park on one client's reply. The
    /// service calls the registered [`Waker`] once the reply is sent,
    /// so the loop knows when `try_recv` is worth retrying.
    fn defer<T>(&self, build: impl FnOnce(Sender<T>) -> Control) -> Result<Receiver<T>> {
        let (tx, rx) = channel();
        self.send(build(tx))?;
        Ok(rx)
    }

    fn request<T>(&self, build: impl FnOnce(Sender<T>) -> Control) -> Result<T> {
        self.defer(build)?
            .recv()
            .ok()
            .context("service shut down mid-request")
    }

    /// Admit `spec` at the next round boundary (blocks for the ack).
    pub fn submit(&self, spec: JobSpec) -> Result<Submitted> {
        self.request(|tx| Control::Submit(Box::new(spec), tx))?
            .map_err(anyhow::Error::msg)
    }

    /// Non-blocking [`submit`](Self::submit): returns the reply channel.
    pub fn submit_deferred(&self, spec: JobSpec) -> Result<Receiver<Result<Submitted, String>>> {
        self.defer(|tx| Control::Submit(Box::new(spec), tx))
    }

    /// Cancel the live job `name` at the next round boundary.
    pub fn cancel(&self, name: &str) -> Result<FinishedJob> {
        self.request(|tx| Control::Cancel(name.to_string(), tx))?
            .map_err(anyhow::Error::msg)
    }

    /// Non-blocking [`cancel`](Self::cancel): returns the reply channel.
    pub fn cancel_deferred(&self, name: &str) -> Result<Receiver<Result<FinishedJob, String>>> {
        self.defer(|tx| Control::Cancel(name.to_string(), tx))
    }

    /// Snapshot the service's current state.
    pub fn status(&self) -> Result<StatusReport> {
        self.request(Control::Status)
    }

    /// Non-blocking [`status`](Self::status): returns the reply channel.
    pub fn status_deferred(&self) -> Result<Receiver<StatusReport>> {
        self.defer(Control::Status)
    }

    /// Checkpoint all live jobs and shut the service down.
    pub fn drain(&self) -> Result<DrainReport> {
        self.request(|tx| Control::Drain(tx, None))?
            .map_err(anyhow::Error::msg)
    }

    /// [`drain`](Self::drain) with a completion latch: the daemon defers
    /// its exit until `()` arrives on `done` (or a bounded grace period
    /// passes). The socket server uses this so the drain response is
    /// flushed to the client *before* the process goes away — without
    /// it, the reply write races process exit and the client can see a
    /// bare EOF on a perfectly successful drain.
    pub fn drain_then(&self, done: Receiver<()>) -> Result<DrainReport> {
        self.request(|tx| Control::Drain(tx, Some(done)))?
            .map_err(anyhow::Error::msg)
    }

    /// Non-blocking drain with an optional completion latch: returns
    /// the reply channel. The event loop passes a latch it fires only
    /// after the drain reply has been flushed to the requesting client.
    pub fn drain_deferred(
        &self,
        done: Option<Receiver<()>>,
    ) -> Result<Receiver<Result<DrainReport, String>>> {
        self.defer(|tx| Control::Drain(tx, done))
    }

    /// Subscribe to the telemetry stream (bounded: falling
    /// [`WATCH_BUFFER`] lines behind ends the subscription — after a
    /// final, guaranteed `{"event":"end"}`).
    pub fn watch(&self) -> Result<WatchStream> {
        let shared = Arc::new(WatchShared {
            state: Mutex::new(WatchState {
                queue: VecDeque::new(),
                ended: false,
                dropped: false,
                lagged: false,
            }),
            cv: Condvar::new(),
        });
        self.send(Control::Watch(WatchSender {
            shared: Arc::clone(&shared),
        }))?;
        Ok(WatchStream { shared })
    }

    /// Register the event loop's wake callback (see [`Waker`]).
    pub fn set_waker(&self, waker: Waker) -> Result<()> {
        self.send(Control::SetWaker(waker))
    }
}

/// The end-of-life summary [`ServiceSession::run`] returns.
#[derive(Debug)]
pub struct ServiceEnd {
    /// The newest finished (or cancelled) jobs, completion order — at
    /// most [`MAX_RESULTS`] rows; `finished_total` counts all of them.
    pub results: Vec<FinishedJob>,
    /// Every job that completed over the service's lifetime.
    pub finished_total: u64,
    /// Live jobs checkpointed by a drain request (0 = ran dry or idle).
    pub drained: usize,
    /// Where the drain snapshot landed, if one was written.
    pub snapshot_dir: Option<PathBuf>,
}

/// The daemon loop: a dynamic scheduler [`Session`] plus the control
/// queue — see the module docs.
pub struct ServiceSession {
    session: Session,
    rx: Receiver<Control>,
    /// Scheduler knobs recorded in drain-snapshot manifests (the `jobs`
    /// field is unused — the snapshot carries the real job list).
    /// `knobs.checkpoint_every > 0` turns on periodic live snapshots at
    /// round boundaries; `knobs.checkpoint_keep` sets snapshot rotation.
    knobs: BatchConfig,
    snapshot_dir: Option<PathBuf>,
    /// The snapshot writer over `snapshot_dir` (None iff no directory
    /// was configured) — shared by periodic persists and drain.
    sink: Option<SnapshotSink>,
    /// Whether this service owns the snapshot directory's lifecycle:
    /// true once periodic persistence is on (`checkpoint_every > 0`) or
    /// a snapshot was adopted from it. An owning service writes a final
    /// snapshot when it runs dry, so a supervisor restart never re-runs
    /// work that already finished; a drain-only service leaves the
    /// directory alone outside explicit drains.
    owns_dir: bool,
    /// Bounded window of the newest finished-job rows (see
    /// [`MAX_RESULTS`]).
    results: VecDeque<FinishedJob>,
    /// Lifetime completion counter (survives window eviction).
    finished_total: u64,
    watchers: Vec<WatchSender>,
    /// The event loop's wake callback, if a socket server is attached.
    waker: Option<Waker>,
    drained: usize,
    drained_to: Option<PathBuf>,
    /// The drain requester's completion latch (waited on in `finish`).
    drain_ack: Option<Receiver<()>>,
}

impl ServiceSession {
    /// A service over `scheduler`'s configuration. `initial` jobs are
    /// admitted before the loop starts (loud errors, not queued);
    /// `snapshot_dir` is where a drain request checkpoints live jobs —
    /// without it, draining a busy service is refused (data loss would
    /// be silent otherwise).
    pub fn new(
        scheduler: &JobScheduler,
        knobs: BatchConfig,
        snapshot_dir: Option<PathBuf>,
        initial: Vec<JobSpec>,
    ) -> Result<(Self, ServiceHandle)> {
        let mut session = scheduler.session();
        for spec in initial {
            session.admit(spec)?;
        }
        let sink = match &snapshot_dir {
            Some(dir) => Some(SnapshotSink::new(
                dir,
                &knobs,
                knobs.checkpoint_keep.max(1),
                "serve",
            )?),
            None => None,
        };
        let owns_dir = sink.is_some() && knobs.checkpoint_every > 0;
        telemetry::mark_service_start();
        let (tx, rx) = channel();
        Ok((
            Self {
                session,
                rx,
                knobs,
                snapshot_dir,
                sink,
                owns_dir,
                results: VecDeque::new(),
                finished_total: 0,
                watchers: Vec::new(),
                waker: None,
                drained: 0,
                drained_to: None,
                drain_ack: None,
            },
            ServiceHandle { tx },
        ))
    }

    /// Warm restart: admit the jobs of a recovered snapshot before the
    /// loop starts. Already-finished checkpoints are reaped straight
    /// into the results table; live ones resume bit-exactly from their
    /// recorded round. Returns the number of live jobs adopted. After a
    /// successful adopt this service owns the snapshot directory's
    /// lifecycle (see `owns_dir`).
    pub fn adopt(&mut self, ckpts: &[JobCheckpoint]) -> Result<usize> {
        for ckpt in ckpts {
            let spec = JobSpec::from_checkpoint(ckpt)
                .with_context(|| format!("adopting snapshot job {:?}", ckpt.name))?;
            self.session
                .admit_resumed(spec, ckpt)
                .with_context(|| format!("adopting snapshot job {:?}", ckpt.name))?;
        }
        let ServiceSession {
            session,
            results,
            finished_total,
            ..
        } = self;
        session.reap(|outcome| push_result(results, finished_total, finished_row(&outcome)))?;
        if self.sink.is_some() {
            self.owns_dir = true;
        }
        Ok(self.session.live())
    }

    /// Run the daemon loop, discarding telemetry.
    pub fn run(self) -> Result<ServiceEnd> {
        self.run_with(|_| {})
    }

    /// Run the daemon loop, streaming every [`JobReport`] to `telemetry`
    /// (in addition to any protocol-level watchers).
    ///
    /// Per iteration: drain the control queue (blocking while idle,
    /// non-blocking `try_recv` while jobs are live), then execute one
    /// scheduling round and reap finished jobs into the results table.
    /// Returns when a drain request lands or when every handle is gone
    /// and all work has finished.
    pub fn run_with<F: FnMut(&JobReport<'_>)>(mut self, mut telemetry: F) -> Result<ServiceEnd> {
        loop {
            if self.session.live() == 0 {
                // Idle: park on the control queue instead of spinning.
                // With watchers subscribed, wake periodically to probe
                // them — rounds (the only other thing that touches
                // watchers) don't run while idle, so a disconnected
                // watch client would otherwise pin its channel and its
                // server thread forever.
                let received = if self.watchers.is_empty() {
                    self.rx.recv().map_err(|_| ())
                } else {
                    match self.rx.recv_timeout(IDLE_WATCH_PROBE) {
                        Ok(msg) => Ok(msg),
                        Err(RecvTimeoutError::Timeout) => {
                            self.probe_watchers();
                            continue;
                        }
                        Err(RecvTimeoutError::Disconnected) => Err(()),
                    }
                };
                match received {
                    Ok(msg) => {
                        let shutdown = self.apply(msg)?;
                        self.wake();
                        if shutdown {
                            return self.finish();
                        }
                    }
                    Err(()) => return self.finish(), // every handle dropped
                }
            }
            // Round boundary: drain whatever queued up. Empty-queue cost
            // is one non-allocating try_recv.
            loop {
                match self.rx.try_recv() {
                    Ok(msg) => {
                        let shutdown = self.apply(msg)?;
                        self.wake();
                        if shutdown {
                            return self.finish();
                        }
                    }
                    Err(TryRecvError::Empty) => break,
                    Err(TryRecvError::Disconnected) => {
                        if self.session.live() == 0 {
                            return self.finish();
                        }
                        break; // keep crunching the admitted work
                    }
                }
            }
            if self.session.live() > 0 {
                self.step_round(&mut telemetry)?;
                self.maybe_persist()?;
            }
        }
    }

    /// Periodic live snapshot at a round boundary (`checkpoint_every`
    /// rounds apart; 0 = off). Off-cadence rounds cost two field reads
    /// and a modulo — the zero-allocation steady state is untouched. A
    /// persist failure is fatal by design: the daemon dies loudly with
    /// the last durable snapshot intact on disk, and a plain supervisor
    /// restart warm-adopts it (`cupso serve --checkpoint-dir` auto-
    /// resumes) — dying is the recovery story, not an outage.
    fn maybe_persist(&mut self) -> Result<()> {
        let every = self.knobs.checkpoint_every;
        if every == 0 || self.session.rounds() % every != 0 {
            return Ok(());
        }
        let Some(sink) = self.sink.as_mut() else {
            return Ok(());
        };
        let snap = self.session.snapshot();
        sink.persist(&snap)
            .inspect_err(|_| {
                // The daemon is about to die loudly; leave the flight
                // recorder's last words next to the error.
                telemetry::dump_trace("fatal persist failure");
            })
            .context("periodic service snapshot failed (restart to recover the last durable one)")
    }

    /// Rouse the event loop, if one registered a [`Waker`]. One branch
    /// when no server is attached, so library-embedded services (and
    /// the zero-allocation steady state) pay nothing.
    fn wake(&self) {
        if let Some(waker) = &self.waker {
            waker();
        }
    }

    /// Send an idle heartbeat to every watcher, reaping the ones whose
    /// clients are gone or that overflowed. Only called while the
    /// service is idle — busy rounds reap watchers on every event.
    fn probe_watchers(&mut self) {
        let line = proto::Obj::new().str("event", "ping").render();
        self.watchers.retain(|w| w.send(&line));
        self.wake();
    }

    /// One scheduling round + reap, with telemetry fan-out. When no
    /// watcher is subscribed the fan-out is a length check — the
    /// steady-state round allocates nothing.
    fn step_round<F: FnMut(&JobReport<'_>)>(&mut self, telemetry: &mut F) -> Result<()> {
        let ServiceSession {
            session,
            watchers,
            results,
            finished_total,
            ..
        } = self;
        let had_watchers = !watchers.is_empty();
        let round = session.rounds() + 1;
        session.round(&mut |r| {
            telemetry(r);
            if !watchers.is_empty() {
                crate::telemetry::bump(Counter::WatchEvents);
                crate::telemetry::record(Series::WatchFanout, watchers.len() as u64);
                let line = report_event(round, r);
                // Bounded send: a watcher that stopped reading (stalled
                // client, full socket) is terminated once its buffer
                // fills — after a guaranteed final `end` line — instead
                // of buffering the daemon to OOM.
                watchers.retain(|w| w.send(&line));
            }
        })?;
        session.reap(|outcome| push_result(results, finished_total, finished_row(&outcome)))?;
        if had_watchers {
            self.wake();
        }
        Ok(())
    }

    /// Admission with per-tenant quota enforcement: before the
    /// scheduler sees the spec, the submitting tenant's live usage is
    /// checked against the configured caps (0 = unlimited). Usage is
    /// read straight off the live slot table — a cancelled or finished
    /// job releases its quota the moment it leaves — and a job's step
    /// charge is its declared iteration budget (`iters`): tenants are
    /// charged for what they reserve, not for what a lucky early
    /// termination happens to use. Jobs without a tenant pool into one
    /// anonymous tenant, so unlabelled traffic is bounded too.
    fn admit(&mut self, spec: JobSpec) -> Result<usize> {
        let (quota_jobs, quota_steps) = (self.knobs.quota_jobs, self.knobs.quota_steps);
        if quota_jobs > 0 || quota_steps > 0 {
            let tenant = spec.tenant.as_deref();
            let mut jobs_used = 0usize;
            let mut steps_used = 0u64;
            self.session.jobs(|view| {
                if view.stop.is_none() && view.tenant == tenant {
                    jobs_used += 1;
                    steps_used = steps_used.saturating_add(view.max_iter);
                }
            });
            let label = tenant.unwrap_or("<anonymous>");
            if quota_jobs > 0 && jobs_used >= quota_jobs {
                telemetry::bump(Counter::QuotaRefusals);
                telemetry::trace(TraceKind::QuotaRefusal, 0, jobs_used as u64);
                anyhow::bail!(
                    "tenant {label} is at its concurrent-job quota \
                     ({jobs_used} of {quota_jobs} live); cancel a job or wait"
                );
            }
            let charge = spec.params.max_iter;
            if quota_steps > 0 && steps_used.saturating_add(charge) > quota_steps {
                telemetry::bump(Counter::QuotaRefusals);
                telemetry::trace(TraceKind::QuotaRefusal, 1, steps_used);
                anyhow::bail!(
                    "tenant {label} would exceed its step quota: {steps_used} outstanding \
                     + {charge} requested > {quota_steps} allowed"
                );
            }
        }
        self.session.admit(spec)
    }

    /// Apply one control message; `Ok(true)` means shut down (drain).
    fn apply(&mut self, msg: Control) -> Result<bool> {
        match msg {
            Control::Submit(spec, reply) => {
                let name = spec.name.clone();
                let ack = match self.admit(*spec) {
                    Ok(slot) => Ok(Submitted {
                        name,
                        slot,
                        // Read the session's own record — never re-derive
                        // the pinning rule here, migration can overrule it.
                        stream: self.session.stream_of(slot).expect("just admitted"),
                    }),
                    Err(e) => Err(format!("{e:#}")),
                };
                let _ = reply.send(ack);
                Ok(false)
            }
            Control::Cancel(name, reply) => {
                let ack = self
                    .session
                    .cancel(&name)
                    .map(|outcome| {
                        let row = finished_row(&outcome);
                        push_result(&mut self.results, &mut self.finished_total, row.clone());
                        row
                    })
                    .map_err(|e| format!("{e:#}"));
                let _ = reply.send(ack);
                Ok(false)
            }
            Control::Status(reply) => {
                let mut live = Vec::new();
                self.session.jobs(|view| {
                    if view.stop.is_none() {
                        live.push(JobStatus {
                            name: view.name.to_string(),
                            engine: view.engine,
                            steps: view.steps,
                            max_iter: view.max_iter,
                            gbest_fit: view.gbest_fit,
                            stream: view.stream,
                        });
                    }
                });
                let _ = reply.send(StatusReport {
                    rounds: self.session.rounds(),
                    streams: self.session.streams(),
                    live,
                    finished: self.results.iter().cloned().collect(),
                    finished_total: self.finished_total,
                });
                Ok(false)
            }
            Control::Drain(reply, ack) => {
                let live = self.session.live();
                if live > 0 && self.snapshot_dir.is_none() {
                    let _ = reply.send(Err(format!(
                        "cannot drain {live} live job(s): the service was started without \
                         a snapshot directory (cupso serve --checkpoint-dir)"
                    )));
                    return Ok(false);
                }
                let mut dir_written = None;
                if live > 0 {
                    let snap = self.session.snapshot();
                    let sink = self.sink.as_mut().expect("checked above");
                    if let Err(e) = sink.persist(&snap) {
                        // Keep serving: the jobs are still alive in
                        // memory, which beats dying with them unsaved.
                        let _ = reply.send(Err(format!("snapshot failed: {e:#}")));
                        return Ok(false);
                    }
                    dir_written = self.snapshot_dir.clone();
                }
                self.drained = live;
                self.drained_to = dir_written.clone();
                self.drain_ack = ack;
                telemetry::trace(TraceKind::Drain, live as u64, self.finished_total);
                telemetry::dump_trace("drain");
                let _ = reply.send(Ok(DrainReport {
                    snapshotted: live,
                    finished: self.finished_total,
                    dir: dir_written,
                }));
                Ok(true)
            }
            Control::Watch(tx) => {
                self.watchers.push(tx);
                Ok(false)
            }
            Control::SetWaker(waker) => {
                self.waker = Some(waker);
                Ok(false)
            }
        }
    }

    fn finish(mut self) -> Result<ServiceEnd> {
        // A dir-owning service that ran dry (not drained) rewrites its
        // snapshot one final time, so the directory reflects reality: a
        // supervisor restarting the daemon adopts the now-empty (or
        // residual) job set instead of re-running work that already
        // finished. Best-effort — the results are already in hand, and a
        // re-run after a crash here would be deterministic anyway.
        if self.drained == 0 && self.owns_dir {
            if let Some(sink) = self.sink.as_mut() {
                let snap = self.session.snapshot();
                if let Err(e) = sink.persist(&snap) {
                    eprintln!("cupso: warning: final snapshot failed: {e:#}");
                }
            }
        }
        // Every live subscriber gets the protocol-promised terminator —
        // unconditionally, thanks to the reserved queue slot. (The old
        // try_send silently lost `end` for a watcher whose buffer was
        // full, leaving its client hanging until raw EOF.)
        for w in &self.watchers {
            w.end();
        }
        self.wake();
        // A drain requester still has to flush its acknowledgement to
        // its client before the process exits; give it a bounded grace
        // period (either the latch fires or the requester is gone).
        if let Some(ack) = self.drain_ack.take() {
            let _ = ack.recv_timeout(std::time::Duration::from_secs(5));
        }
        Ok(ServiceEnd {
            results: self.results.into_iter().collect(),
            finished_total: self.finished_total,
            drained: self.drained,
            snapshot_dir: self.drained_to,
        })
    }
}

/// Append to the bounded results window (oldest row evicted past
/// [`MAX_RESULTS`]) and bump the lifetime counter.
fn push_result(results: &mut VecDeque<FinishedJob>, total: &mut u64, row: FinishedJob) {
    if results.len() == MAX_RESULTS {
        results.pop_front();
    }
    results.push_back(row);
    *total += 1;
}

/// Project a [`JobOutcome`] onto its status/protocol row.
fn finished_row(outcome: &JobOutcome) -> FinishedJob {
    FinishedJob {
        name: outcome.name.to_string(),
        engine: outcome.engine,
        stop: outcome.stop,
        steps: outcome.steps,
        gbest_fit: outcome.output.gbest_fit,
    }
}

/// Render one telemetry line for the watch stream.
fn report_event(round: u64, r: &JobReport<'_>) -> String {
    let mut obj = proto::Obj::new()
        .str("event", "report")
        .int("round", round)
        .str("job", r.name)
        .int("iter", r.iter)
        .num("gbest", r.gbest_fit)
        .bool("improved", r.improved);
    if let Some(stop) = r.finished {
        obj = obj.str("finished", &stop.to_string());
    }
    obj.render()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fitness::{Cubic, Objective};
    use crate::pso::PsoParams;
    use std::sync::Arc;

    fn knobs() -> BatchConfig {
        BatchConfig {
            workers: 2,
            policy: "round-robin".into(),
            streams: 1,
            batch_steps: 1,
            preempt_quantum: 0,
            pack: false,
            pack_min: 2,
            pack_max: 0,
            quota_jobs: 0,
            quota_steps: 0,
            checkpoint_every: 0,
            checkpoint_keep: 1,
            telemetry: true,
            trace_dump: None,
            jobs: Vec::new(),
        }
    }

    /// Poll status until no job is live (bounded).
    fn wait_idle(handle: &ServiceHandle) {
        let deadline = Instant::now() + Duration::from_secs(120);
        while !handle.status().unwrap().live.is_empty() {
            assert!(Instant::now() < deadline, "service did not run dry");
            std::thread::sleep(Duration::from_millis(10));
        }
    }

    /// Read a watch stream to its deterministic end.
    fn drain_stream(rx: &WatchStream) -> Vec<String> {
        let mut lines = Vec::new();
        while let Ok(line) = rx.recv_timeout(Duration::from_secs(5)) {
            lines.push(line);
        }
        lines
    }

    fn spec(name: &str, iters: u64, seed: u64) -> JobSpec {
        JobSpec::new(
            name,
            EngineKind::Queue,
            PsoParams::paper_1d(64, iters),
            Arc::new(Cubic),
            Objective::Maximize,
            seed,
        )
    }

    #[test]
    fn runs_dry_when_handles_drop() {
        let scheduler = JobScheduler::with_workers(2);
        let (service, handle) =
            ServiceSession::new(&scheduler, knobs(), None, vec![spec("a", 8, 1)]).unwrap();
        drop(handle);
        let end = service.run().unwrap();
        assert_eq!(end.results.len(), 1);
        assert_eq!(&*end.results[0].name, "a");
        assert_eq!(end.results[0].steps, 8);
        assert_eq!(end.drained, 0);
        assert!(end.snapshot_dir.is_none());
    }

    #[test]
    fn submit_cancel_status_drain_through_the_handle() {
        let scheduler = JobScheduler::with_workers(2);
        let (service, handle) =
            ServiceSession::new(&scheduler, knobs(), None, Vec::new()).unwrap();
        let svc = std::thread::spawn(move || service.run().unwrap());

        let ack = handle.submit(spec("long", 1_000_000, 1)).unwrap();
        assert_eq!(&*ack.name, "long");
        assert_eq!(ack.slot, 0);
        let ack = handle.submit(spec("other", 1_000_000, 2)).unwrap();
        assert_eq!(ack.slot, 1);
        // Duplicate live name is refused.
        let err = handle.submit(spec("long", 10, 3)).unwrap_err().to_string();
        assert!(err.contains("unique"), "{err}");

        let status = handle.status().unwrap();
        assert_eq!(status.live.len(), 2);
        assert!(status.streams >= 1);

        let row = handle.cancel("other").unwrap();
        assert_eq!(row.stop, StopReason::Cancelled);
        assert!(handle.cancel("other").is_err(), "double cancel is loud");

        // Idle drain is fine without a snapshot dir once nothing is live;
        // with a live job it must be refused.
        let err = handle.drain().unwrap_err().to_string();
        assert!(err.contains("checkpoint-dir"), "{err}");
        let row = handle.cancel("long").unwrap();
        assert_eq!(row.stop, StopReason::Cancelled);
        let report = handle.drain().unwrap();
        assert_eq!(report.snapshotted, 0);
        assert_eq!(report.finished, 2);
        assert!(report.dir.is_none());

        let end = svc.join().unwrap();
        assert_eq!(end.results.len(), 2);
        assert_eq!(end.drained, 0);
        // The service is gone: the handle reports it loudly.
        assert!(handle.status().is_err());
    }

    #[test]
    fn watch_streams_reports_and_ends() {
        let scheduler = JobScheduler::with_workers(2);
        let (service, handle) =
            ServiceSession::new(&scheduler, knobs(), None, Vec::new()).unwrap();
        let svc = std::thread::spawn(move || service.run().unwrap());
        let rx = handle.watch().unwrap();
        handle.submit(spec("watched", 5, 1)).unwrap();
        // One report per round; the job's budget is 5 steps. The last
        // report carries the finished marker.
        let timeout = std::time::Duration::from_secs(30);
        for round in 1..=5u64 {
            let line = rx.recv_timeout(timeout).expect("telemetry report");
            let doc = proto::Json::parse(&line).unwrap();
            assert_eq!(doc.str_field("event").unwrap(), "report");
            assert_eq!(doc.str_field("job").unwrap(), "watched");
            assert_eq!(doc.get("iter").unwrap().as_u64("iter").unwrap(), round);
            if round == 5 {
                assert_eq!(doc.str_field("finished").unwrap(), "exhausted");
            }
        }
        // Release the idle service; the stream must close with `end`.
        drop(handle);
        let end = svc.join().unwrap();
        assert_eq!(end.results.len(), 1);
        assert_eq!(end.results[0].steps, 5);
        let line = rx.recv_timeout(timeout).expect("end event");
        assert_eq!(
            proto::Json::parse(&line).unwrap().str_field("event").unwrap(),
            "end"
        );
    }

    #[test]
    fn watcher_full_at_shutdown_still_gets_end() {
        // Exactly WATCH_BUFFER - 1 reports fill every regular slot of a
        // never-read subscription; the reserved slot must still carry
        // `{"event":"end"}` at shutdown. (The old try_send-based finish
        // silently lost it and the client hung until raw EOF.)
        let scheduler = JobScheduler::with_workers(2);
        let (service, handle) =
            ServiceSession::new(&scheduler, knobs(), None, Vec::new()).unwrap();
        let svc = std::thread::spawn(move || service.run().unwrap());
        let rx = handle.watch().unwrap();
        let iters = WATCH_BUFFER as u64 - 1;
        handle.submit(spec("flood", iters, 1)).unwrap();
        wait_idle(&handle);
        drop(handle);
        let end = svc.join().unwrap();
        assert_eq!(end.results[0].steps, iters);
        let lines = drain_stream(&rx);
        assert_eq!(lines.len(), WATCH_BUFFER);
        for line in &lines[..WATCH_BUFFER - 1] {
            let doc = proto::Json::parse(line).unwrap();
            assert_eq!(doc.str_field("event").unwrap(), "report");
        }
        let doc = proto::Json::parse(lines.last().unwrap()).unwrap();
        assert_eq!(doc.str_field("event").unwrap(), "end");
        assert!(!rx.lagged(), "nothing was discarded — the buffer just filled");
    }

    #[test]
    fn overflowed_watcher_is_terminated_with_a_deterministic_end() {
        // A subscription that falls WATCH_BUFFER - 1 lines behind is
        // cut off mid-run — but its backlog still terminates with the
        // protocol-promised `end` line, never a hang or a bare EOF.
        let scheduler = JobScheduler::with_workers(2);
        let (service, handle) =
            ServiceSession::new(&scheduler, knobs(), None, Vec::new()).unwrap();
        let svc = std::thread::spawn(move || service.run().unwrap());
        let rx = handle.watch().unwrap();
        let iters = WATCH_BUFFER as u64 + 64;
        handle.submit(spec("flood", iters, 1)).unwrap();
        wait_idle(&handle);
        drop(handle);
        let end = svc.join().unwrap();
        assert_eq!(end.results[0].steps, iters, "the job itself is unaffected");
        let lines = drain_stream(&rx);
        assert_eq!(lines.len(), WATCH_BUFFER);
        let doc = proto::Json::parse(lines.last().unwrap()).unwrap();
        assert_eq!(doc.str_field("event").unwrap(), "end");
        assert!(rx.lagged());
        assert!(rx.ended());
    }

    #[test]
    fn tenant_quotas_shed_at_admission_and_release_on_cancel() {
        let scheduler = JobScheduler::with_workers(2);
        let mut k = knobs();
        k.quota_jobs = 2;
        k.quota_steps = 2_500_000;
        let (service, handle) = ServiceSession::new(&scheduler, k, None, Vec::new()).unwrap();
        let svc = std::thread::spawn(move || service.run().unwrap());
        let tenant_spec = |name: &str, iters: u64, seed: u64, tenant: &str| {
            let mut s = spec(name, iters, seed);
            s.tenant = Some(Arc::from(tenant));
            s
        };
        handle.submit(tenant_spec("a1", 1_000_000, 1, "acme")).unwrap();
        handle.submit(tenant_spec("a2", 1_000_000, 2, "acme")).unwrap();
        // A third concurrent job trips the tenant's job quota.
        let err = handle
            .submit(tenant_spec("a3", 10, 3, "acme"))
            .unwrap_err()
            .to_string();
        assert!(err.contains("concurrent-job quota"), "{err}");
        // Another tenant is unaffected by acme's usage...
        handle.submit(tenant_spec("b1", 1_000_000, 4, "bloor")).unwrap();
        // ...but its own step budget binds: 1M outstanding + 2M > 2.5M.
        let err = handle
            .submit(tenant_spec("b2", 2_000_000, 5, "bloor"))
            .unwrap_err()
            .to_string();
        assert!(err.contains("step quota"), "{err}");
        // Untagged jobs pool into one anonymous tenant, bounded too.
        handle.submit(spec("anon1", 1_000_000, 6)).unwrap();
        handle.submit(spec("anon2", 1_000_000, 7)).unwrap();
        let err = handle.submit(spec("anon3", 10, 8)).unwrap_err().to_string();
        assert!(err.contains("concurrent-job quota"), "{err}");
        // Cancelling releases quota immediately (usage is read off the
        // live slot table, so there is nothing to forget to decrement).
        handle.cancel("a1").unwrap();
        handle.submit(tenant_spec("a3", 10, 3, "acme")).unwrap();
        for name in ["a2", "b1", "anon1", "anon2"] {
            handle.cancel(name).unwrap();
        }
        // a3 (10 iters) runs dry on its own once the rest is cancelled.
        drop(handle);
        let end = svc.join().unwrap();
        assert_eq!(end.finished_total, 6);
    }
}
