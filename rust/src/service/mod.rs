//! `cupso serve` — the live job-service daemon.
//!
//! Every earlier entry point (`cupso batch`, [`crate::scheduler`]'s
//! fixed-batch calls) takes a job list decided before the session
//! starts. A service handling live traffic cannot: tenants submit work
//! to a *running* scheduler, cancel it, watch it, and expect the whole
//! thing to shut down cleanly without losing their state. This module
//! provides exactly that, in three pieces:
//!
//! * [`ServiceSession`] — the daemon loop. It owns a dynamic
//!   [`Session`] (slot table, admission, cancellation, reaping) and an
//!   MPSC **control queue** ([`Control`]) that is drained at every
//!   round boundary: submits, cancels, status probes and the drain
//!   request all take effect *between* scheduling rounds, when every
//!   grid is quiescent. That boundary is what keeps the determinism
//!   invariant alive under live traffic — a job's trajectory is
//!   bit-identical regardless of when its neighbours were admitted or
//!   cancelled (`rust/tests/scheduler_determinism.rs`) — and it costs
//!   nothing in the steady state: an empty control queue is one
//!   non-allocating `try_recv` per round, so warmed-up rounds stay
//!   zero-allocation (`rust/tests/zero_alloc.rs`).
//! * [`ServiceHandle`] — the cloneable client side of the control
//!   queue, with blocking convenience calls (`submit`, `cancel`,
//!   `status`, `drain`, `watch`). The socket server and in-process
//!   tests both drive this.
//! * [`proto`] / [`server`] — a line-oriented JSON protocol over a Unix
//!   domain socket, so `cupso submit/status/cancel/drain` (or `nc -U`)
//!   can talk to a daemon in another process.
//!
//! **Drain semantics.** `drain` checkpoints every live job through the
//! shared snapshot store ([`crate::checkpoint::store`], the same
//! `manifest.toml` + `job_<i>.ckpt` layout `cupso batch
//! --checkpoint-dir` writes) and shuts the loop down. A drained service
//! therefore resumes through the *existing* `cupso resume` path — the
//! snapshot does not care whether its jobs arrived in a config file or
//! were admitted live. Finished (and cancelled) jobs are reaped into a
//! results table as they complete and are not part of the snapshot.
//!
//! **Lifecycle.** [`ServiceSession::run`] loops until (a) a drain
//! request arrives, or (b) every [`ServiceHandle`] is dropped *and* all
//! admitted work has finished — so a library caller can simply drop the
//! handle and collect the results.

pub mod proto;
mod server;

pub use server::{bind, spawn_server};

use crate::checkpoint::store;
use crate::config::{BatchConfig, EngineKind};
use crate::scheduler::{JobOutcome, JobReport, JobScheduler, JobSpec, Session, StopReason};
use anyhow::{Context, Result};
use std::collections::VecDeque;
use std::path::PathBuf;
use std::sync::mpsc::{
    channel, sync_channel, Receiver, RecvTimeoutError, Sender, SyncSender, TryRecvError,
};
use std::time::Duration;

/// Finished-job rows retained for `status` and the end-of-life summary.
/// A long-lived daemon completes unboundedly many jobs; the results
/// table is a *window* (newest kept, oldest evicted) so memory and
/// status-response size track current interest, not lifetime history —
/// the total count is always reported alongside.
pub const MAX_RESULTS: usize = 4096;

/// Telemetry lines buffered per watcher. A watcher that stops reading
/// (stalled client, full socket) falls behind; once it is this many
/// events behind it is dropped, because the alternative — buffering
/// without bound on an unbounded channel — lets one stalled observer
/// OOM the whole daemon.
pub const WATCH_BUFFER: usize = 1024;

/// How often an *idle* service probes its watchers with a
/// `{"event": "ping"}` heartbeat. Rounds reap dead watchers as a side
/// effect of sending events; an idle daemon runs no rounds, so without
/// the probe a disconnected watch client would pin its channel (and its
/// server-side connection thread) forever.
pub const IDLE_WATCH_PROBE: Duration = Duration::from_secs(30);

/// Acknowledgement of a successful admission.
#[derive(Debug, Clone)]
pub struct Submitted {
    /// The job's identity key.
    pub name: std::sync::Arc<str>,
    /// Slot the job landed in (freed slots are recycled).
    pub slot: usize,
    /// Pool stream the job was pinned to at admission (`slot % S`;
    /// preemption may later migrate it).
    pub stream: usize,
}

/// One live job's status row.
#[derive(Debug, Clone)]
pub struct JobStatus {
    /// Job name.
    pub name: String,
    /// Engine kind.
    pub engine: EngineKind,
    /// Steps executed so far.
    pub steps: u64,
    /// Iteration budget.
    pub max_iter: u64,
    /// Current global-best fitness.
    pub gbest_fit: f64,
    /// Pool stream pinning.
    pub stream: usize,
}

/// One finished (or cancelled) job's result row.
#[derive(Debug, Clone)]
pub struct FinishedJob {
    /// Job name.
    pub name: String,
    /// Engine kind.
    pub engine: EngineKind,
    /// Why it stopped.
    pub stop: StopReason,
    /// Steps executed.
    pub steps: u64,
    /// Final global-best fitness.
    pub gbest_fit: f64,
}

/// A point-in-time view of the service.
#[derive(Debug, Clone)]
pub struct StatusReport {
    /// Scheduling rounds executed so far.
    pub rounds: u64,
    /// Concurrent pool streams.
    pub streams: usize,
    /// Live jobs, slot order.
    pub live: Vec<JobStatus>,
    /// The newest completed jobs (at most [`MAX_RESULTS`]), completion
    /// order.
    pub finished: Vec<FinishedJob>,
    /// Every job ever completed (cancellations included) — may exceed
    /// `finished.len()` once old rows have been evicted.
    pub finished_total: u64,
}

/// Acknowledgement of a successful drain.
#[derive(Debug, Clone)]
pub struct DrainReport {
    /// Live jobs checkpointed into the snapshot (0 = the service was
    /// idle; nothing was written).
    pub snapshotted: usize,
    /// Jobs that had already finished over the service's lifetime
    /// (their results were reported, not snapshotted).
    pub finished: u64,
    /// Where the snapshot landed, if one was written — feed it to
    /// `cupso resume`.
    pub dir: Option<PathBuf>,
}

/// A control-queue message. Client convenience wrappers live on
/// [`ServiceHandle`]; each request carries its reply channel.
pub enum Control {
    /// Admit a job at the next round boundary.
    Submit(Box<JobSpec>, Sender<Result<Submitted, String>>),
    /// Cancel a live job by name at the next round boundary.
    Cancel(String, Sender<Result<FinishedJob, String>>),
    /// Report live jobs + finished results.
    Status(Sender<StatusReport>),
    /// Checkpoint all live jobs and shut down. The optional receiver is
    /// a **completion latch**: after a successful drain the loop waits
    /// (bounded) for it before returning, so the requester can flush
    /// its acknowledgement to its client before the daemon exits — see
    /// [`ServiceHandle::drain_then`].
    Drain(Sender<Result<DrainReport, String>>, Option<Receiver<()>>),
    /// Subscribe to the per-round telemetry stream (one JSON line per
    /// stepped job per round; a final `{"event": "end"}` at shutdown).
    /// Bounded: a subscriber more than [`WATCH_BUFFER`] events behind
    /// is dropped.
    Watch(SyncSender<String>),
}

/// Cloneable client side of a [`ServiceSession`]'s control queue.
#[derive(Clone)]
pub struct ServiceHandle {
    tx: Sender<Control>,
}

impl ServiceHandle {
    fn request<T>(&self, build: impl FnOnce(Sender<T>) -> Control) -> Result<T> {
        let (tx, rx) = channel();
        self.tx
            .send(build(tx))
            .ok()
            .context("service is no longer running")?;
        rx.recv().ok().context("service shut down mid-request")
    }

    /// Admit `spec` at the next round boundary (blocks for the ack).
    pub fn submit(&self, spec: JobSpec) -> Result<Submitted> {
        self.request(|tx| Control::Submit(Box::new(spec), tx))?
            .map_err(anyhow::Error::msg)
    }

    /// Cancel the live job `name` at the next round boundary.
    pub fn cancel(&self, name: &str) -> Result<FinishedJob> {
        self.request(|tx| Control::Cancel(name.to_string(), tx))?
            .map_err(anyhow::Error::msg)
    }

    /// Snapshot the service's current state.
    pub fn status(&self) -> Result<StatusReport> {
        self.request(Control::Status)
    }

    /// Checkpoint all live jobs and shut the service down.
    pub fn drain(&self) -> Result<DrainReport> {
        self.request(|tx| Control::Drain(tx, None))?
            .map_err(anyhow::Error::msg)
    }

    /// [`drain`](Self::drain) with a completion latch: the daemon defers
    /// its exit until `()` arrives on `done` (or a bounded grace period
    /// passes). The socket server uses this so the drain response is
    /// flushed to the client *before* the process goes away — without
    /// it, the reply write races process exit and the client can see a
    /// bare EOF on a perfectly successful drain.
    pub fn drain_then(&self, done: Receiver<()>) -> Result<DrainReport> {
        self.request(|tx| Control::Drain(tx, Some(done)))?
            .map_err(anyhow::Error::msg)
    }

    /// Subscribe to the telemetry stream (bounded: falling
    /// [`WATCH_BUFFER`] events behind unsubscribes you).
    pub fn watch(&self) -> Result<Receiver<String>> {
        let (tx, rx) = sync_channel(WATCH_BUFFER);
        self.tx
            .send(Control::Watch(tx))
            .ok()
            .context("service is no longer running")?;
        Ok(rx)
    }
}

/// The end-of-life summary [`ServiceSession::run`] returns.
#[derive(Debug)]
pub struct ServiceEnd {
    /// The newest finished (or cancelled) jobs, completion order — at
    /// most [`MAX_RESULTS`] rows; `finished_total` counts all of them.
    pub results: Vec<FinishedJob>,
    /// Every job that completed over the service's lifetime.
    pub finished_total: u64,
    /// Live jobs checkpointed by a drain request (0 = ran dry or idle).
    pub drained: usize,
    /// Where the drain snapshot landed, if one was written.
    pub snapshot_dir: Option<PathBuf>,
}

/// The daemon loop: a dynamic scheduler [`Session`] plus the control
/// queue — see the module docs.
pub struct ServiceSession {
    session: Session,
    rx: Receiver<Control>,
    /// Scheduler knobs recorded in drain-snapshot manifests (the `jobs`
    /// field is unused — the snapshot carries the real job list).
    knobs: BatchConfig,
    snapshot_dir: Option<PathBuf>,
    /// Bounded window of the newest finished-job rows (see
    /// [`MAX_RESULTS`]).
    results: VecDeque<FinishedJob>,
    /// Lifetime completion counter (survives window eviction).
    finished_total: u64,
    watchers: Vec<SyncSender<String>>,
    drained: usize,
    drained_to: Option<PathBuf>,
    /// The drain requester's completion latch (waited on in `finish`).
    drain_ack: Option<Receiver<()>>,
}

impl ServiceSession {
    /// A service over `scheduler`'s configuration. `initial` jobs are
    /// admitted before the loop starts (loud errors, not queued);
    /// `snapshot_dir` is where a drain request checkpoints live jobs —
    /// without it, draining a busy service is refused (data loss would
    /// be silent otherwise).
    pub fn new(
        scheduler: &JobScheduler,
        knobs: BatchConfig,
        snapshot_dir: Option<PathBuf>,
        initial: Vec<JobSpec>,
    ) -> Result<(Self, ServiceHandle)> {
        let mut session = scheduler.session();
        for spec in initial {
            session.admit(spec)?;
        }
        let (tx, rx) = channel();
        Ok((
            Self {
                session,
                rx,
                knobs,
                snapshot_dir,
                results: VecDeque::new(),
                finished_total: 0,
                watchers: Vec::new(),
                drained: 0,
                drained_to: None,
                drain_ack: None,
            },
            ServiceHandle { tx },
        ))
    }

    /// Run the daemon loop, discarding telemetry.
    pub fn run(self) -> Result<ServiceEnd> {
        self.run_with(|_| {})
    }

    /// Run the daemon loop, streaming every [`JobReport`] to `telemetry`
    /// (in addition to any protocol-level watchers).
    ///
    /// Per iteration: drain the control queue (blocking while idle,
    /// non-blocking `try_recv` while jobs are live), then execute one
    /// scheduling round and reap finished jobs into the results table.
    /// Returns when a drain request lands or when every handle is gone
    /// and all work has finished.
    pub fn run_with<F: FnMut(&JobReport<'_>)>(mut self, mut telemetry: F) -> Result<ServiceEnd> {
        loop {
            if self.session.live() == 0 {
                // Idle: park on the control queue instead of spinning.
                // With watchers subscribed, wake periodically to probe
                // them — rounds (the only other thing that touches
                // watchers) don't run while idle, so a disconnected
                // watch client would otherwise pin its channel and its
                // server thread forever.
                let received = if self.watchers.is_empty() {
                    self.rx.recv().map_err(|_| ())
                } else {
                    match self.rx.recv_timeout(IDLE_WATCH_PROBE) {
                        Ok(msg) => Ok(msg),
                        Err(RecvTimeoutError::Timeout) => {
                            self.probe_watchers();
                            continue;
                        }
                        Err(RecvTimeoutError::Disconnected) => Err(()),
                    }
                };
                match received {
                    Ok(msg) => {
                        if self.apply(msg)? {
                            return self.finish();
                        }
                    }
                    Err(()) => return self.finish(), // every handle dropped
                }
            }
            // Round boundary: drain whatever queued up. Empty-queue cost
            // is one non-allocating try_recv.
            loop {
                match self.rx.try_recv() {
                    Ok(msg) => {
                        if self.apply(msg)? {
                            return self.finish();
                        }
                    }
                    Err(TryRecvError::Empty) => break,
                    Err(TryRecvError::Disconnected) => {
                        if self.session.live() == 0 {
                            return self.finish();
                        }
                        break; // keep crunching the admitted work
                    }
                }
            }
            if self.session.live() > 0 {
                self.step_round(&mut telemetry)?;
            }
        }
    }

    /// Send an idle heartbeat to every watcher, dropping the ones whose
    /// clients are gone (their connection thread died, so the receiver
    /// is disconnected) or wedged (buffer full). Only called while the
    /// service is idle — busy rounds reap watchers on every event.
    fn probe_watchers(&mut self) {
        let line = proto::Obj::new().str("event", "ping").render();
        self.watchers.retain(|w| w.try_send(line.clone()).is_ok());
    }

    /// One scheduling round + reap, with telemetry fan-out. When no
    /// watcher is subscribed the fan-out is a length check — the
    /// steady-state round allocates nothing.
    fn step_round<F: FnMut(&JobReport<'_>)>(&mut self, telemetry: &mut F) -> Result<()> {
        let ServiceSession {
            session,
            watchers,
            results,
            finished_total,
            ..
        } = self;
        let round = session.rounds() + 1;
        session.round(&mut |r| {
            telemetry(r);
            if !watchers.is_empty() {
                let line = report_event(round, r);
                // try_send, never send: a watcher that stopped reading
                // (stalled client, full socket) is dropped once its
                // buffer fills, instead of buffering the daemon to OOM.
                watchers.retain(|w| w.try_send(line.clone()).is_ok());
            }
        })?;
        session.reap(|outcome| push_result(results, finished_total, finished_row(&outcome)))
    }

    /// Apply one control message; `Ok(true)` means shut down (drain).
    fn apply(&mut self, msg: Control) -> Result<bool> {
        match msg {
            Control::Submit(spec, reply) => {
                let name = spec.name.clone();
                let ack = match self.session.admit(*spec) {
                    Ok(slot) => Ok(Submitted {
                        name,
                        slot,
                        // Read the session's own record — never re-derive
                        // the pinning rule here, migration can overrule it.
                        stream: self.session.stream_of(slot).expect("just admitted"),
                    }),
                    Err(e) => Err(format!("{e:#}")),
                };
                let _ = reply.send(ack);
                Ok(false)
            }
            Control::Cancel(name, reply) => {
                let ack = self
                    .session
                    .cancel(&name)
                    .map(|outcome| {
                        let row = finished_row(&outcome);
                        push_result(&mut self.results, &mut self.finished_total, row.clone());
                        row
                    })
                    .map_err(|e| format!("{e:#}"));
                let _ = reply.send(ack);
                Ok(false)
            }
            Control::Status(reply) => {
                let mut live = Vec::new();
                self.session.jobs(|view| {
                    if view.stop.is_none() {
                        live.push(JobStatus {
                            name: view.name.to_string(),
                            engine: view.engine,
                            steps: view.steps,
                            max_iter: view.max_iter,
                            gbest_fit: view.gbest_fit,
                            stream: view.stream,
                        });
                    }
                });
                let _ = reply.send(StatusReport {
                    rounds: self.session.rounds(),
                    streams: self.session.streams(),
                    live,
                    finished: self.results.iter().cloned().collect(),
                    finished_total: self.finished_total,
                });
                Ok(false)
            }
            Control::Drain(reply, ack) => {
                let live = self.session.live();
                if live > 0 && self.snapshot_dir.is_none() {
                    let _ = reply.send(Err(format!(
                        "cannot drain {live} live job(s): the service was started without \
                         a snapshot directory (cupso serve --checkpoint-dir)"
                    )));
                    return Ok(false);
                }
                let mut dir_written = None;
                if live > 0 {
                    let dir = self.snapshot_dir.clone().expect("checked above");
                    let snap = self.session.snapshot();
                    let mut buf = Vec::new();
                    if let Err(e) =
                        store::write_snapshot(&dir, &self.knobs, 1, "serve", &snap, &mut buf)
                    {
                        // Keep serving: the jobs are still alive in
                        // memory, which beats dying with them unsaved.
                        let _ = reply.send(Err(format!("snapshot failed: {e:#}")));
                        return Ok(false);
                    }
                    dir_written = Some(dir);
                }
                self.drained = live;
                self.drained_to = dir_written.clone();
                self.drain_ack = ack;
                let _ = reply.send(Ok(DrainReport {
                    snapshotted: live,
                    finished: self.finished_total,
                    dir: dir_written,
                }));
                Ok(true)
            }
            Control::Watch(tx) => {
                self.watchers.push(tx);
                Ok(false)
            }
        }
    }

    fn finish(mut self) -> Result<ServiceEnd> {
        for w in &self.watchers {
            let _ = w.try_send(proto::Obj::new().str("event", "end").render());
        }
        // A drain requester still has to flush its acknowledgement to
        // its client before the process exits; give it a bounded grace
        // period (either the latch fires or the requester is gone).
        if let Some(ack) = self.drain_ack.take() {
            let _ = ack.recv_timeout(std::time::Duration::from_secs(5));
        }
        Ok(ServiceEnd {
            results: self.results.into_iter().collect(),
            finished_total: self.finished_total,
            drained: self.drained,
            snapshot_dir: self.drained_to,
        })
    }
}

/// Append to the bounded results window (oldest row evicted past
/// [`MAX_RESULTS`]) and bump the lifetime counter.
fn push_result(results: &mut VecDeque<FinishedJob>, total: &mut u64, row: FinishedJob) {
    if results.len() == MAX_RESULTS {
        results.pop_front();
    }
    results.push_back(row);
    *total += 1;
}

/// Project a [`JobOutcome`] onto its status/protocol row.
fn finished_row(outcome: &JobOutcome) -> FinishedJob {
    FinishedJob {
        name: outcome.name.to_string(),
        engine: outcome.engine,
        stop: outcome.stop,
        steps: outcome.steps,
        gbest_fit: outcome.output.gbest_fit,
    }
}

/// Render one telemetry line for the watch stream.
fn report_event(round: u64, r: &JobReport<'_>) -> String {
    let mut obj = proto::Obj::new()
        .str("event", "report")
        .int("round", round)
        .str("job", r.name)
        .int("iter", r.iter)
        .num("gbest", r.gbest_fit)
        .bool("improved", r.improved);
    if let Some(stop) = r.finished {
        obj = obj.str("finished", &stop.to_string());
    }
    obj.render()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fitness::{Cubic, Objective};
    use crate::pso::PsoParams;
    use std::sync::Arc;

    fn knobs() -> BatchConfig {
        BatchConfig {
            workers: 2,
            policy: "round-robin".into(),
            streams: 1,
            batch_steps: 1,
            preempt_quantum: 0,
            pack: false,
            pack_min: 2,
            pack_max: 0,
            jobs: Vec::new(),
        }
    }

    fn spec(name: &str, iters: u64, seed: u64) -> JobSpec {
        JobSpec::new(
            name,
            EngineKind::Queue,
            PsoParams::paper_1d(64, iters),
            Arc::new(Cubic),
            Objective::Maximize,
            seed,
        )
    }

    #[test]
    fn runs_dry_when_handles_drop() {
        let scheduler = JobScheduler::with_workers(2);
        let (service, handle) =
            ServiceSession::new(&scheduler, knobs(), None, vec![spec("a", 8, 1)]).unwrap();
        drop(handle);
        let end = service.run().unwrap();
        assert_eq!(end.results.len(), 1);
        assert_eq!(&*end.results[0].name, "a");
        assert_eq!(end.results[0].steps, 8);
        assert_eq!(end.drained, 0);
        assert!(end.snapshot_dir.is_none());
    }

    #[test]
    fn submit_cancel_status_drain_through_the_handle() {
        let scheduler = JobScheduler::with_workers(2);
        let (service, handle) =
            ServiceSession::new(&scheduler, knobs(), None, Vec::new()).unwrap();
        let svc = std::thread::spawn(move || service.run().unwrap());

        let ack = handle.submit(spec("long", 1_000_000, 1)).unwrap();
        assert_eq!(&*ack.name, "long");
        assert_eq!(ack.slot, 0);
        let ack = handle.submit(spec("other", 1_000_000, 2)).unwrap();
        assert_eq!(ack.slot, 1);
        // Duplicate live name is refused.
        let err = handle.submit(spec("long", 10, 3)).unwrap_err().to_string();
        assert!(err.contains("unique"), "{err}");

        let status = handle.status().unwrap();
        assert_eq!(status.live.len(), 2);
        assert!(status.streams >= 1);

        let row = handle.cancel("other").unwrap();
        assert_eq!(row.stop, StopReason::Cancelled);
        assert!(handle.cancel("other").is_err(), "double cancel is loud");

        // Idle drain is fine without a snapshot dir once nothing is live;
        // with a live job it must be refused.
        let err = handle.drain().unwrap_err().to_string();
        assert!(err.contains("checkpoint-dir"), "{err}");
        let row = handle.cancel("long").unwrap();
        assert_eq!(row.stop, StopReason::Cancelled);
        let report = handle.drain().unwrap();
        assert_eq!(report.snapshotted, 0);
        assert_eq!(report.finished, 2);
        assert!(report.dir.is_none());

        let end = svc.join().unwrap();
        assert_eq!(end.results.len(), 2);
        assert_eq!(end.drained, 0);
        // The service is gone: the handle reports it loudly.
        assert!(handle.status().is_err());
    }

    #[test]
    fn watch_streams_reports_and_ends() {
        let scheduler = JobScheduler::with_workers(2);
        let (service, handle) =
            ServiceSession::new(&scheduler, knobs(), None, Vec::new()).unwrap();
        let svc = std::thread::spawn(move || service.run().unwrap());
        let rx = handle.watch().unwrap();
        handle.submit(spec("watched", 5, 1)).unwrap();
        // One report per round; the job's budget is 5 steps. The last
        // report carries the finished marker.
        let timeout = std::time::Duration::from_secs(30);
        for round in 1..=5u64 {
            let line = rx.recv_timeout(timeout).expect("telemetry report");
            let doc = proto::Json::parse(&line).unwrap();
            assert_eq!(doc.str_field("event").unwrap(), "report");
            assert_eq!(doc.str_field("job").unwrap(), "watched");
            assert_eq!(doc.get("iter").unwrap().as_u64("iter").unwrap(), round);
            if round == 5 {
                assert_eq!(doc.str_field("finished").unwrap(), "exhausted");
            }
        }
        // Release the idle service; the stream must close with `end`.
        drop(handle);
        let end = svc.join().unwrap();
        assert_eq!(end.results.len(), 1);
        assert_eq!(end.results[0].steps, 5);
        let line = rx.recv_timeout(timeout).expect("end event");
        assert_eq!(
            proto::Json::parse(&line).unwrap().str_field("event").unwrap(),
            "end"
        );
    }
}
