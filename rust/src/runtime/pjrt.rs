//! PJRT-backed runtime (the real Plane-B implementation).
//!
//! Compiled only with the `xla` cargo feature: the `xla` bindings wrap
//! the XLA extension shared library, which cannot be fetched in the
//! offline build environment. Enable the feature *and* add the vendored
//! `xla` dependency to Cargo.toml to use this path; the default build
//! uses the API-identical stub in `runtime/mod.rs` whose `open` fails
//! with instructions.

use super::{ArtifactMeta, Manifest, XlaSwarmState};
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

/// A compiled executable, shareable across coordinator threads.
///
/// PJRT executables are internally thread-safe for execution; the `xla`
/// crate just doesn't mark the wrapper Send/Sync, so we assert it here.
struct SharedExe(xla::PjRtLoadedExecutable);

impl std::fmt::Debug for SharedExe {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("SharedExe(<pjrt loaded executable>)")
    }
}

// SAFETY: PJRT's C API allows concurrent Execute calls on one loaded
// executable; the wrapper holds no interior mutability of its own.
unsafe impl Send for SharedExe {}
unsafe impl Sync for SharedExe {}

/// Runtime: PJRT client + artifact registry + executable cache.
pub struct XlaRuntime {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: Manifest,
    cache: Mutex<HashMap<String, Arc<SharedExe>>>,
}

// SAFETY: same argument as SharedExe — the CPU client is thread-safe.
unsafe impl Send for XlaRuntime {}
unsafe impl Sync for XlaRuntime {}

impl XlaRuntime {
    /// Open the runtime over an artifact directory (must contain
    /// `manifest.toml`; run `make artifacts` first).
    pub fn open(dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(&dir.join("manifest.toml"))
            .with_context(|| format!("loading manifest from {}", dir.display()))?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Self {
            client,
            dir: dir.to_path_buf(),
            manifest,
            cache: Mutex::new(HashMap::new()),
        })
    }

    /// The parsed manifest.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// PJRT platform name (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Find an artifact by configuration.
    pub fn find(&self, variant: &str, n: usize, dim: usize) -> Option<&ArtifactMeta> {
        self.manifest.find(variant, n, dim)
    }

    /// Compile (or fetch the cached) executable for `name`.
    pub fn load(&self, name: &str) -> Result<ChunkExec> {
        let meta = self
            .manifest
            .get(name)
            .ok_or_else(|| anyhow!("artifact {name} not in manifest"))?
            .clone();
        let exe = {
            let mut cache = self.cache.lock().unwrap();
            if let Some(e) = cache.get(name) {
                e.clone()
            } else {
                let path = self.dir.join(&meta.file);
                let path_str = path
                    .to_str()
                    .ok_or_else(|| anyhow!("non-utf8 path {}", path.display()))?;
                let proto = xla::HloModuleProto::from_text_file(path_str)
                    .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = self
                    .client
                    .compile(&comp)
                    .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
                let exe = Arc::new(SharedExe(exe));
                cache.insert(name.to_string(), exe.clone());
                exe
            }
        };
        Ok(ChunkExec { exe, meta })
    }

    /// Compile the artifact for `(variant, n, dim)` or explain what exists.
    pub fn load_config(&self, variant: &str, n: usize, dim: usize) -> Result<ChunkExec> {
        match self.find(variant, n, dim) {
            Some(meta) => {
                let name = meta.name.clone();
                self.load(&name)
            }
            None => bail!(
                "no artifact for variant={variant} n={n} dim={dim}; available: {}",
                self.manifest.names().join(", ")
            ),
        }
    }
}

/// One compiled PSO chunk (K iterations per call).
#[derive(Debug)]
pub struct ChunkExec {
    exe: Arc<SharedExe>,
    /// The artifact's ABI description.
    pub meta: ArtifactMeta,
}

impl ChunkExec {
    /// Execute one chunk: advances `state` by `meta.iters` iterations and
    /// returns the gbest-fitness trace (one entry per iteration).
    ///
    /// `key_bits` is the threefry key (stable across the whole run);
    /// `iter0` the global iteration offset (chunks chain exactly — see
    /// python/tests/test_model.py::TestChunkChaining).
    pub fn run(
        &self,
        state: &mut XlaSwarmState,
        key_bits: [u32; 2],
        iter0: i64,
    ) -> Result<Vec<f64>> {
        let (d, n) = (self.meta.dim, self.meta.n);
        if state.dim != d || state.n != n {
            bail!(
                "state shape ({}, {}) does not match artifact {} ({d}, {n})",
                state.dim,
                state.n,
                self.meta.name
            );
        }
        let dims = [d as i64, n as i64];
        let args: Vec<xla::Literal> = vec![
            xla::Literal::vec1(&state.pos).reshape(&dims).map_err(xe)?,
            xla::Literal::vec1(&state.vel).reshape(&dims).map_err(xe)?,
            xla::Literal::vec1(&state.pbest_pos)
                .reshape(&dims)
                .map_err(xe)?,
            xla::Literal::vec1(&state.pbest_fit),
            xla::Literal::vec1(&state.gbest_pos),
            xla::Literal::scalar(state.gbest_fit),
            xla::Literal::vec1(&key_bits[..]),
            xla::Literal::scalar(iter0),
        ];
        let result = self.exe.0.execute::<xla::Literal>(&args).map_err(xe)?;
        let mut out = result[0][0].to_literal_sync().map_err(xe)?;
        let parts = out.decompose_tuple().map_err(xe)?;
        if parts.len() != 7 {
            bail!(
                "artifact {} returned {} outputs, want 7",
                self.meta.name,
                parts.len()
            );
        }
        state.pos = parts[0].to_vec::<f64>().map_err(xe)?;
        state.vel = parts[1].to_vec::<f64>().map_err(xe)?;
        state.pbest_pos = parts[2].to_vec::<f64>().map_err(xe)?;
        state.pbest_fit = parts[3].to_vec::<f64>().map_err(xe)?;
        state.gbest_pos = parts[4].to_vec::<f64>().map_err(xe)?;
        state.gbest_fit = parts[5].get_first_element::<f64>().map_err(xe)?;
        let trace = parts[6].to_vec::<f64>().map_err(xe)?;
        Ok(trace)
    }

    /// Iterations this chunk advances per call.
    pub fn iters_per_call(&self) -> u64 {
        self.meta.iters
    }
}

/// xla::Error → anyhow (stringified; the crate error type is unstable).
fn xe(e: xla::Error) -> anyhow::Error {
    anyhow!("xla: {e:?}")
}
