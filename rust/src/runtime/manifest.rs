//! `artifacts/manifest.toml` parsing — the artifact registry the AOT
//! pipeline emits and the runtime trusts for ABI shapes.

use crate::config::{parse_toml, TomlValue};
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// One artifact's description (a `[artifact.<name>]` section).
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    /// Artifact stem (`pso_queue_n1024_d1_k50`).
    pub name: String,
    /// HLO text filename relative to the artifact dir.
    pub file: String,
    /// Aggregation variant (`reduction` | `queue` | `fused`).
    pub variant: String,
    /// Swarm size the module was lowered for.
    pub n: usize,
    /// Dimensionality.
    pub dim: usize,
    /// Iterations per chunk call.
    pub iters: u64,
    /// Fitness function baked into the module.
    pub fitness: String,
    /// Baked PSO scalars (w, c1, c2, min_pos, max_pos, max_v).
    pub w: f64,
    pub c1: f64,
    pub c2: f64,
    pub min_pos: f64,
    pub max_pos: f64,
    pub max_v: f64,
    /// SHA-256 of the HLO text (staleness check).
    pub sha256: String,
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// jax version that lowered the artifacts (diagnostics).
    pub jax_version: String,
    artifacts: BTreeMap<String, ArtifactMeta>,
}

impl Manifest {
    /// Load and parse `manifest.toml`.
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        Self::parse(&text)
    }

    /// Parse from TOML-subset text.
    pub fn parse(text: &str) -> Result<Self> {
        let doc = parse_toml(text)?;
        let mut jax_version = String::new();
        // Group keys by artifact section.
        let mut sections: BTreeMap<String, BTreeMap<String, TomlValue>> = BTreeMap::new();
        for (key, value) in doc {
            if key == "jax_version" {
                jax_version = value.as_str("jax_version")?.to_string();
                continue;
            }
            if let Some(rest) = key.strip_prefix("artifact.") {
                let Some((name, field)) = rest.rsplit_once('.') else {
                    bail!("malformed manifest key {key}");
                };
                sections
                    .entry(name.to_string())
                    .or_default()
                    .insert(field.to_string(), value);
            }
        }
        let mut artifacts = BTreeMap::new();
        for (name, fields) in sections {
            let get = |f: &str| -> Result<&TomlValue> {
                fields
                    .get(f)
                    .with_context(|| format!("artifact {name} missing field {f}"))
            };
            let meta = ArtifactMeta {
                name: name.clone(),
                file: get("file")?.as_str("file")?.to_string(),
                variant: get("variant")?.as_str("variant")?.to_string(),
                n: get("n")?.as_int("n")? as usize,
                dim: get("dim")?.as_int("dim")? as usize,
                iters: get("iters")?.as_int("iters")? as u64,
                fitness: get("fitness")?.as_str("fitness")?.to_string(),
                w: get("w")?.as_float("w")?,
                c1: get("c1")?.as_float("c1")?,
                c2: get("c2")?.as_float("c2")?,
                min_pos: get("min_pos")?.as_float("min_pos")?,
                max_pos: get("max_pos")?.as_float("max_pos")?,
                max_v: get("max_v")?.as_float("max_v")?,
                sha256: get("sha256")?.as_str("sha256")?.to_string(),
            };
            artifacts.insert(name, meta);
        }
        if artifacts.is_empty() {
            bail!("manifest contains no artifacts");
        }
        Ok(Self {
            jax_version,
            artifacts,
        })
    }

    /// Look up by name.
    pub fn get(&self, name: &str) -> Option<&ArtifactMeta> {
        self.artifacts.get(name)
    }

    /// First artifact matching `(variant, n, dim)`.
    pub fn find(&self, variant: &str, n: usize, dim: usize) -> Option<&ArtifactMeta> {
        self.artifacts
            .values()
            .find(|a| a.variant == variant && a.n == n && a.dim == dim)
    }

    /// All artifact names.
    pub fn names(&self) -> Vec<String> {
        self.artifacts.keys().cloned().collect()
    }

    /// All artifacts.
    pub fn iter(&self) -> impl Iterator<Item = &ArtifactMeta> {
        self.artifacts.values()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
jax_version = "0.8.2"

[artifact.pso_queue_n256_d1_k10]
file = "pso_queue_n256_d1_k10.hlo.txt"
variant = "queue"
n = 256
dim = 1
iters = 10
dtype = "f64"
fitness = "cubic"
w = 1.0
c1 = 2.0
c2 = 2.0
min_pos = -100.0
max_pos = 100.0
max_v = 100.0
sha256 = "abc123"
bytes = 53818
outputs = 7

[artifact.pso_fused_n1024_d120_k25]
file = "pso_fused_n1024_d120_k25.hlo.txt"
variant = "fused"
n = 1024
dim = 120
iters = 25
dtype = "f64"
fitness = "cubic"
w = 1.0
c1 = 2.0
c2 = 2.0
min_pos = -100.0
max_pos = 100.0
max_v = 100.0
sha256 = "def456"
bytes = 1
outputs = 7
"#;

    #[test]
    fn parses_sections_and_fields() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.jax_version, "0.8.2");
        assert_eq!(m.names().len(), 2);
        let a = m.get("pso_queue_n256_d1_k10").unwrap();
        assert_eq!(a.variant, "queue");
        assert_eq!((a.n, a.dim, a.iters), (256, 1, 10));
        assert_eq!(a.max_v, 100.0);
        assert_eq!(a.sha256, "abc123");
    }

    #[test]
    fn find_matches_config() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert!(m.find("fused", 1024, 120).is_some());
        assert!(m.find("fused", 1024, 1).is_none());
        assert!(m.find("reduction", 256, 1).is_none());
    }

    #[test]
    fn missing_field_is_an_error() {
        let broken = "[artifact.x]\nfile = \"x.hlo.txt\"\n";
        let err = Manifest::parse(broken).unwrap_err().to_string();
        assert!(err.contains("missing field"), "{err}");
    }

    #[test]
    fn empty_manifest_is_an_error() {
        assert!(Manifest::parse("jax_version = \"0.8.2\"\n").is_err());
    }
}
