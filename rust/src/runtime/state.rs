//! Host-side swarm state for the XLA plane — flat `[dim, n]` row-major
//! buffers matching the artifact ABI, with init mirroring the Plane-A
//! swarm (Philox draws) so both planes start from comparable swarms.

use crate::fitness::{Fitness, Objective};
use crate::pso::PsoParams;
use crate::rng::PhiloxStream;

/// Swarm state in the artifact ABI layout.
#[derive(Debug, Clone)]
pub struct XlaSwarmState {
    /// Dimensionality.
    pub dim: usize,
    /// Particle count.
    pub n: usize,
    /// `[dim, n]` row-major positions.
    pub pos: Vec<f64>,
    /// `[dim, n]` velocities.
    pub vel: Vec<f64>,
    /// `[dim, n]` best-known positions.
    pub pbest_pos: Vec<f64>,
    /// `[n]` best-known fitness.
    pub pbest_fit: Vec<f64>,
    /// `[dim]` global best position.
    pub gbest_pos: Vec<f64>,
    /// Global best fitness.
    pub gbest_fit: f64,
}

impl XlaSwarmState {
    /// Initialize uniformly inside the bounds (Step 1 of Algorithm 1) and
    /// seed pbest/gbest from the initial fitness.
    ///
    /// `shard_id` decorrelates the Philox draws of different coordinator
    /// shards (they are independent sub-swarms).
    pub fn init(
        params: &PsoParams,
        fitness: &dyn Fitness,
        objective: Objective,
        seed: u64,
        shard_id: u64,
    ) -> Self {
        let stream = PhiloxStream::new(seed ^ (shard_id.wrapping_mul(0x9E37_79B9_7F4A_7C15)));
        let (n, dim) = (params.n, params.dim);
        let mut pos = vec![0.0; n * dim];
        let mut vel = vec![0.0; n * dim];
        for d in 0..dim {
            for i in 0..n {
                let (rp, rv) = stream.r1r2(i as u64, u64::MAX, d as u32);
                pos[d * n + i] = params.min_pos + (params.max_pos - params.min_pos) * rp;
                vel[d * n + i] = -params.max_v + 2.0 * params.max_v * rv;
            }
        }
        let mut fit = vec![0.0; n];
        fitness.eval_batch(&pos, n, dim, &mut fit);
        let mut best = objective.worst();
        let mut gi = 0usize;
        for (i, &f) in fit.iter().enumerate() {
            if objective.better(f, best) {
                best = f;
                gi = i;
            }
        }
        let gbest_pos = (0..dim).map(|d| pos[d * n + gi]).collect();
        Self {
            dim,
            n,
            pbest_pos: pos.clone(),
            pos,
            vel,
            pbest_fit: fit,
            gbest_pos,
            gbest_fit: best,
        }
    }

    /// Adopt a better global best from another shard (the coordinator's
    /// cross-shard merge). Returns true if adopted.
    pub fn adopt_gbest(&mut self, objective: Objective, fit: f64, pos: &[f64]) -> bool {
        if objective.better(fit, self.gbest_fit) {
            self.gbest_fit = fit;
            self.gbest_pos.copy_from_slice(pos);
            true
        } else {
            false
        }
    }

    /// Invariant: all positions within bounds (property tests).
    pub fn check_bounds(&self, params: &PsoParams) -> Result<(), String> {
        for (k, &p) in self.pos.iter().enumerate() {
            if !(params.min_pos..=params.max_pos).contains(&p) {
                return Err(format!("pos[{k}] = {p} out of bounds"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fitness::Cubic;

    #[test]
    fn init_seeds_gbest_from_swarm_argmax() {
        let params = PsoParams::paper_1d(128, 10);
        let st = XlaSwarmState::init(&params, &Cubic, Objective::Maximize, 1, 0);
        st.check_bounds(&params).unwrap();
        let best = st
            .pbest_fit
            .iter()
            .cloned()
            .fold(f64::NEG_INFINITY, f64::max);
        assert_eq!(st.gbest_fit, best);
        assert_eq!(st.pos, st.pbest_pos);
    }

    #[test]
    fn shards_are_decorrelated() {
        let params = PsoParams::paper_1d(64, 10);
        let a = XlaSwarmState::init(&params, &Cubic, Objective::Maximize, 1, 0);
        let b = XlaSwarmState::init(&params, &Cubic, Objective::Maximize, 1, 1);
        assert_ne!(a.pos, b.pos);
    }

    #[test]
    fn adopt_gbest_only_improves() {
        let params = PsoParams::paper_1d(16, 10);
        let mut st = XlaSwarmState::init(&params, &Cubic, Objective::Maximize, 2, 0);
        let old = st.gbest_fit;
        assert!(!st.adopt_gbest(Objective::Maximize, old - 1.0, &[0.0]));
        assert_eq!(st.gbest_fit, old);
        assert!(st.adopt_gbest(Objective::Maximize, old + 1.0, &[5.0]));
        assert_eq!(st.gbest_fit, old + 1.0);
        assert_eq!(st.gbest_pos, vec![5.0]);
    }
}
