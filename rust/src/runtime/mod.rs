//! Plane-B runtime: load AOT-compiled HLO-text artifacts and execute them
//! on the PJRT CPU client from the Rust hot path (Python never runs here).
//!
//! * [`Manifest`] — parses `artifacts/manifest.toml` (emitted by
//!   `python -m compile.aot`) into typed artifact descriptions.
//! * [`XlaRuntime`] — owns the `PjRtClient`, compiles artifacts on first
//!   use and caches the executables keyed by artifact name.
//! * [`ChunkExec`] — one compiled PSO chunk with the 8-in/7-out ABI; runs
//!   K iterations per call against an [`XlaSwarmState`].
//!
//! Interchange is HLO **text** (`HloModuleProto::from_text_file`), per the
//! xla_extension 0.5.1 proto-id constraint (see DESIGN.md / aot.py).
//!
//! ## Feature gating
//!
//! The `xla` bindings need the XLA extension shared library, which the
//! offline build environment cannot provide. The real implementation
//! lives in `pjrt.rs` behind the `xla` cargo feature; the default build
//! compiles an API-identical stub whose [`XlaRuntime::open`] fails with
//! instructions, so every Plane-B caller (coordinator, CLI, benches)
//! still compiles and degrades gracefully at runtime.

mod manifest;
mod state;

pub use manifest::{ArtifactMeta, Manifest};
pub use state::XlaSwarmState;

#[cfg(feature = "xla")]
mod pjrt;
#[cfg(feature = "xla")]
pub use pjrt::{ChunkExec, XlaRuntime};

#[cfg(not(feature = "xla"))]
mod stub {
    use super::{ArtifactMeta, Manifest, XlaSwarmState};
    use anyhow::{bail, Result};
    use std::path::Path;

    /// Offline stand-in for the PJRT runtime: same API, but [`open`]
    /// always fails (so no method past construction is reachable).
    ///
    /// [`open`]: XlaRuntime::open
    #[derive(Debug)]
    pub struct XlaRuntime {
        manifest: Manifest,
    }

    impl XlaRuntime {
        /// Always fails: this build has no PJRT client.
        pub fn open(dir: &Path) -> Result<Self> {
            bail!(
                "cannot open artifacts at {}: cupso was built without the `xla` \
                 feature (PJRT execution is unavailable offline); rebuild with \
                 `--features xla` and a vendored `xla` dependency",
                dir.display()
            )
        }

        /// The parsed manifest.
        pub fn manifest(&self) -> &Manifest {
            &self.manifest
        }

        /// PJRT platform name (diagnostics).
        pub fn platform(&self) -> String {
            "unavailable".to_string()
        }

        /// Find an artifact by configuration.
        pub fn find(&self, variant: &str, n: usize, dim: usize) -> Option<&ArtifactMeta> {
            self.manifest.find(variant, n, dim)
        }

        /// Unreachable in the stub (`open` never succeeds).
        pub fn load(&self, name: &str) -> Result<ChunkExec> {
            bail!("artifact {name}: cupso was built without the `xla` feature")
        }

        /// Unreachable in the stub (`open` never succeeds).
        pub fn load_config(&self, variant: &str, n: usize, dim: usize) -> Result<ChunkExec> {
            bail!(
                "artifact variant={variant} n={n} dim={dim}: cupso was built \
                 without the `xla` feature"
            )
        }
    }

    /// Stub chunk executable (never constructed — see [`XlaRuntime`]).
    #[derive(Debug)]
    pub struct ChunkExec {
        /// The artifact's ABI description.
        pub meta: ArtifactMeta,
    }

    impl ChunkExec {
        /// Unreachable in the stub.
        pub fn run(
            &self,
            _state: &mut XlaSwarmState,
            _key_bits: [u32; 2],
            _iter0: i64,
        ) -> Result<Vec<f64>> {
            bail!(
                "artifact {}: cupso was built without the `xla` feature",
                self.meta.name
            )
        }

        /// Iterations this chunk advances per call.
        pub fn iters_per_call(&self) -> u64 {
            self.meta.iters
        }
    }
}

#[cfg(not(feature = "xla"))]
pub use stub::{ChunkExec, XlaRuntime};
