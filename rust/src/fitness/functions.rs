//! Concrete fitness functions.
//!
//! Each overrides `eval_batch` with an SoA-streaming loop (dimension-major,
//! particle-minor) so the hot path touches memory exactly the way the
//! paper's coalesced layout does (Figure 2): for a fixed dimension `d`, the
//! inner loop walks `pos[d*n .. d*n+n]` contiguously.

use super::{Fitness, Objective};

/// The paper's fitness function (Eq. 3), **maximized** over `[-100,100]^d`:
///
/// `f(x) = Σ_d  x_d³ − 0.8·x_d² − 1000·x_d + 8000`
///
/// Separable; per-dimension maximum on the closed domain sits at the upper
/// boundary `x = 100` with value `100³ − 0.8·100² − 1000·100 + 8000 =
/// 900_000` per dimension.
pub struct Cubic;

impl Cubic {
    /// Per-dimension term — shared by the scalar and batch paths and by the
    /// gpusim FLOP count.
    #[inline(always)]
    pub fn term(x: f64) -> f64 {
        // Horner form: ((x - 0.8) * x - 1000) * x + 8000
        ((x - 0.8) * x - 1000.0) * x + 8000.0
    }
}

impl Fitness for Cubic {
    fn name(&self) -> &'static str {
        "cubic"
    }

    fn default_bounds(&self) -> (f64, f64) {
        (-100.0, 100.0)
    }

    fn default_objective(&self) -> Objective {
        Objective::Maximize
    }

    #[inline]
    fn eval(&self, x: &[f64]) -> f64 {
        x.iter().map(|&v| Self::term(v)).sum()
    }

    fn optimum(&self, dim: usize) -> Option<f64> {
        Some(900_000.0 * dim as f64)
    }

    fn eval_batch(&self, pos: &[f64], n: usize, dim: usize, fit: &mut [f64]) {
        fit.fill(0.0);
        for d in 0..dim {
            let row = &pos[d * n..(d + 1) * n];
            for (f, &x) in fit.iter_mut().zip(row) {
                *f += Self::term(x);
            }
        }
    }

    fn eval_range(&self, pos: &[f64], n: usize, dim: usize, lo: usize, hi: usize, fit: &mut [f64]) {
        fit.fill(0.0);
        for d in 0..dim {
            let row = &pos[d * n + lo..d * n + hi];
            for (f, &x) in fit.iter_mut().zip(row) {
                *f += Self::term(x);
            }
        }
    }
}

/// Sphere: `Σ x²`, minimized over `[-100, 100]^d`, optimum 0 at origin.
pub struct Sphere;

impl Fitness for Sphere {
    fn name(&self) -> &'static str {
        "sphere"
    }

    fn default_bounds(&self) -> (f64, f64) {
        (-100.0, 100.0)
    }

    fn default_objective(&self) -> Objective {
        Objective::Minimize
    }

    #[inline]
    fn eval(&self, x: &[f64]) -> f64 {
        x.iter().map(|&v| v * v).sum()
    }

    fn optimum(&self, _dim: usize) -> Option<f64> {
        Some(0.0)
    }

    fn eval_batch(&self, pos: &[f64], n: usize, dim: usize, fit: &mut [f64]) {
        fit.fill(0.0);
        for d in 0..dim {
            let row = &pos[d * n..(d + 1) * n];
            for (f, &x) in fit.iter_mut().zip(row) {
                *f += x * x;
            }
        }
    }

    fn eval_range(&self, pos: &[f64], n: usize, dim: usize, lo: usize, hi: usize, fit: &mut [f64]) {
        fit.fill(0.0);
        for d in 0..dim {
            let row = &pos[d * n + lo..d * n + hi];
            for (f, &x) in fit.iter_mut().zip(row) {
                *f += x * x;
            }
        }
    }
}

/// Rosenbrock: `Σ 100(x_{d+1} − x_d²)² + (1 − x_d)²`, minimized over
/// `[-30, 30]^d`, optimum 0 at all-ones. Non-separable (couples adjacent
/// dimensions) — exercises the multi-dimension paths differently from the
/// separable functions.
pub struct Rosenbrock;

impl Fitness for Rosenbrock {
    fn name(&self) -> &'static str {
        "rosenbrock"
    }

    fn default_bounds(&self) -> (f64, f64) {
        (-30.0, 30.0)
    }

    fn default_objective(&self) -> Objective {
        Objective::Minimize
    }

    fn eval(&self, x: &[f64]) -> f64 {
        x.windows(2)
            .map(|w| {
                let t = w[1] - w[0] * w[0];
                let u = 1.0 - w[0];
                100.0 * t * t + u * u
            })
            .sum()
    }

    fn optimum(&self, _dim: usize) -> Option<f64> {
        Some(0.0)
    }

    fn eval_batch(&self, pos: &[f64], n: usize, dim: usize, fit: &mut [f64]) {
        fit.fill(0.0);
        for d in 0..dim.saturating_sub(1) {
            let cur = &pos[d * n..(d + 1) * n];
            let nxt = &pos[(d + 1) * n..(d + 2) * n];
            for i in 0..n {
                let t = nxt[i] - cur[i] * cur[i];
                let u = 1.0 - cur[i];
                fit[i] += 100.0 * t * t + u * u;
            }
        }
    }
}

/// Griewank: `1 + Σ x²/4000 − Π cos(x_d/√(d+1))`, minimized over
/// `[-600, 600]^d`, optimum 0 at origin.
pub struct Griewank;

impl Fitness for Griewank {
    fn name(&self) -> &'static str {
        "griewank"
    }

    fn default_bounds(&self) -> (f64, f64) {
        (-600.0, 600.0)
    }

    fn default_objective(&self) -> Objective {
        Objective::Minimize
    }

    fn eval(&self, x: &[f64]) -> f64 {
        let sum: f64 = x.iter().map(|&v| v * v).sum::<f64>() / 4000.0;
        let prod: f64 = x
            .iter()
            .enumerate()
            .map(|(d, &v)| (v / ((d + 1) as f64).sqrt()).cos())
            .product();
        1.0 + sum - prod
    }

    fn optimum(&self, _dim: usize) -> Option<f64> {
        Some(0.0)
    }

    fn eval_batch(&self, pos: &[f64], n: usize, dim: usize, fit: &mut [f64]) {
        // fit accumulates the quadratic sum; prod kept in a scratch row.
        let mut prod = vec![1.0; n];
        fit.fill(0.0);
        for d in 0..dim {
            let row = &pos[d * n..(d + 1) * n];
            let inv_sqrt = 1.0 / ((d + 1) as f64).sqrt();
            for i in 0..n {
                fit[i] += row[i] * row[i];
                prod[i] *= (row[i] * inv_sqrt).cos();
            }
        }
        for i in 0..n {
            fit[i] = 1.0 + fit[i] / 4000.0 - prod[i];
        }
    }
}

/// Rastrigin: `10d + Σ (x² − 10 cos 2πx)`, minimized over `[-5.12, 5.12]^d`,
/// optimum 0 at origin. Highly multimodal.
pub struct Rastrigin;

impl Fitness for Rastrigin {
    fn name(&self) -> &'static str {
        "rastrigin"
    }

    fn default_bounds(&self) -> (f64, f64) {
        (-5.12, 5.12)
    }

    fn default_objective(&self) -> Objective {
        Objective::Minimize
    }

    fn eval(&self, x: &[f64]) -> f64 {
        let d = x.len() as f64;
        10.0 * d
            + x.iter()
                .map(|&v| v * v - 10.0 * (std::f64::consts::TAU * v).cos())
                .sum::<f64>()
    }

    fn optimum(&self, _dim: usize) -> Option<f64> {
        Some(0.0)
    }

    fn eval_batch(&self, pos: &[f64], n: usize, dim: usize, fit: &mut [f64]) {
        fit.fill(10.0 * dim as f64);
        for d in 0..dim {
            let row = &pos[d * n..(d + 1) * n];
            for (f, &x) in fit.iter_mut().zip(row) {
                *f += x * x - 10.0 * (std::f64::consts::TAU * x).cos();
            }
        }
    }

    fn eval_range(&self, pos: &[f64], n: usize, dim: usize, lo: usize, hi: usize, fit: &mut [f64]) {
        fit.fill(10.0 * dim as f64);
        for d in 0..dim {
            let row = &pos[d * n + lo..d * n + hi];
            for (f, &x) in fit.iter_mut().zip(row) {
                *f += x * x - 10.0 * (std::f64::consts::TAU * x).cos();
            }
        }
    }
}

/// Ackley: minimized over `[-32, 32]^d`, optimum 0 at origin.
pub struct Ackley;

impl Fitness for Ackley {
    fn name(&self) -> &'static str {
        "ackley"
    }

    fn default_bounds(&self) -> (f64, f64) {
        (-32.0, 32.0)
    }

    fn default_objective(&self) -> Objective {
        Objective::Minimize
    }

    fn eval(&self, x: &[f64]) -> f64 {
        let d = x.len() as f64;
        let sq: f64 = x.iter().map(|&v| v * v).sum::<f64>() / d;
        let cs: f64 = x
            .iter()
            .map(|&v| (std::f64::consts::TAU * v).cos())
            .sum::<f64>()
            / d;
        -20.0 * (-0.2 * sq.sqrt()).exp() - cs.exp() + 20.0 + std::f64::consts::E
    }

    fn optimum(&self, _dim: usize) -> Option<f64> {
        Some(0.0)
    }
}

/// Schwefel 2.26: `418.9829d − Σ x sin √|x|`, minimized over
/// `[-500, 500]^d`, optimum ≈0 at `x = 420.9687...`. Deceptive: the global
/// optimum is far from the domain center, punishing premature convergence.
pub struct Schwefel226;

impl Fitness for Schwefel226 {
    fn name(&self) -> &'static str {
        "schwefel226"
    }

    fn default_bounds(&self) -> (f64, f64) {
        (-500.0, 500.0)
    }

    fn default_objective(&self) -> Objective {
        Objective::Minimize
    }

    fn eval(&self, x: &[f64]) -> f64 {
        418.9829 * x.len() as f64
            - x.iter().map(|&v| v * v.abs().sqrt().sin()).sum::<f64>()
    }

    fn optimum(&self, _dim: usize) -> Option<f64> {
        Some(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cubic_matches_equation3_reference_points() {
        // f(0) = 8000 per dimension.
        assert_eq!(Cubic.eval(&[0.0]), 8000.0);
        // f(100) = 1e6 - 8000 - 1e5 + 8000 = 900000.
        assert!((Cubic.eval(&[100.0]) - 900_000.0).abs() < 1e-9);
        // f(-100) = -1e6 - 8000 + 1e5 + 8000 = -900000.
        assert!((Cubic.eval(&[-100.0]) + 900_000.0).abs() < 1e-9);
        // Separability: d-dim = sum of 1-dim terms.
        let v = Cubic.eval(&[1.0, 2.0, 3.0]);
        let w = Cubic.eval(&[1.0]) + Cubic.eval(&[2.0]) + Cubic.eval(&[3.0]);
        assert!((v - w).abs() < 1e-9);
    }

    #[test]
    fn cubic_domain_max_is_at_upper_bound() {
        // Dense scan: no interior point beats x=100 on [-100, 100].
        let best = (0..=2000)
            .map(|k| -100.0 + 0.1 * k as f64)
            .map(|x| Cubic.eval(&[x]))
            .fold(f64::NEG_INFINITY, f64::max);
        assert!((best - 900_000.0).abs() < 1e-6);
        assert_eq!(Cubic.optimum(120), Some(900_000.0 * 120.0));
    }

    #[test]
    fn minimized_suite_is_zero_at_optimum() {
        assert_eq!(Sphere.eval(&[0.0; 8]), 0.0);
        assert_eq!(Rosenbrock.eval(&[1.0; 8]), 0.0);
        assert!(Griewank.eval(&[0.0; 8]).abs() < 1e-12);
        assert!(Rastrigin.eval(&[0.0; 8]).abs() < 1e-12);
        assert!(Ackley.eval(&[0.0; 8]).abs() < 1e-12);
        assert!(Schwefel226.eval(&[420.9687; 8]).abs() < 1e-2);
    }

    #[test]
    fn nonoptimal_points_are_worse() {
        assert!(Sphere.eval(&[1.0, 1.0]) > 0.0);
        assert!(Rosenbrock.eval(&[0.0, 0.0]) > 0.0);
        assert!(Rastrigin.eval(&[0.5, 0.5]) > 0.0);
        assert!(Ackley.eval(&[5.0]) > 1.0);
    }

    #[test]
    fn rosenbrock_batch_handles_dim1() {
        // dim=1 has no adjacent pair: fitness must be 0, not a panic.
        let pos = [3.0, -2.0];
        let mut fit = [9.9, 9.9];
        Rosenbrock.eval_batch(&pos, 2, 1, &mut fit);
        assert_eq!(fit, [0.0, 0.0]);
    }
}
