//! Fitness-function library.
//!
//! The paper evaluates exclusively on the **Cubic** function (Eq. 3),
//! *maximizing* it over `[-100, 100]^d` (Algorithm 1 uses `>` comparisons
//! throughout). We implement Cubic plus the standard benchmark suite the
//! paper names as alternatives (Sphere, Rosenbrock, Griewank) and a few
//! more that downstream users expect (Rastrigin, Ackley, Schwefel 2.26),
//! each with its canonical search domain and optimization sense.
//!
//! ## NaN policy
//!
//! A fitness function may return `NaN` (domain violations, `0/0` in a
//! user-supplied objective, …). The policy, uniform across the serial
//! reference and every Plane-A engine, is: **a NaN candidate never
//! wins**. Every best-datum comparison funnels through
//! [`Objective::better`] (or tie-broken wrappers around it), whose strict
//! `>` / `<` is false whenever either side is NaN — so NaN fitness never
//! replaces a personal best, never enters a block best, and never reaches
//! the global best; the same holds for the lock-free
//! [`crate::exec::AtomicF64::fetch_max`] / `fetch_min` fast paths. If
//! *every* evaluation is NaN the global best stays at the seed value
//! [`Objective::worst`] (±∞) with zero improvements, identically in all
//! engines.
//!
//! One asymmetry follows from "NaN never wins": the *personal*-best slots
//! are seeded from the initial evaluation, so a particle whose very first
//! fitness is NaN keeps that NaN pbest forever (a finite later fitness
//! fails the strict comparison against it too). Such a particle still
//! moves, and its per-iteration fitness still competes for block and
//! global bests — only its pbest attractor is frozen at the spawn
//! position. This too is identical across the serial references and every
//! Plane-A engine, which is what the `nan_*` tests here and the NaN suite
//! in `rust/tests/engine_equivalence.rs` pin down.

mod functions;

pub use functions::{Ackley, Cubic, Griewank, Rastrigin, Rosenbrock, Schwefel226, Sphere};

/// Whether larger or smaller fitness is better.
///
/// The paper maximizes (Cubic's `+8000 - 1000x` shape peaks at the upper
/// bound); the classical test suite minimizes. Engines are generic over
/// the sense via [`Objective::better`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Objective {
    /// Larger fitness wins (the paper's setting).
    Maximize,
    /// Smaller fitness wins (classical benchmark convention).
    Minimize,
}

impl Objective {
    /// Is `a` strictly better than `b` under this sense?
    ///
    /// Strict comparison, so it is false when either side is NaN: a NaN
    /// candidate can never displace any incumbent (see the module-level
    /// NaN policy).
    #[inline(always)]
    pub fn better(self, a: f64, b: f64) -> bool {
        match self {
            Objective::Maximize => a > b,
            Objective::Minimize => a < b,
        }
    }

    /// The worst representable fitness (identity of the `better` fold).
    #[inline]
    pub fn worst(self) -> f64 {
        match self {
            Objective::Maximize => f64::NEG_INFINITY,
            Objective::Minimize => f64::INFINITY,
        }
    }

    /// Parse from CLI text.
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "max" | "maximize" => Some(Objective::Maximize),
            "min" | "minimize" => Some(Objective::Minimize),
            _ => None,
        }
    }
}

/// A fitness function over `R^d`.
///
/// `eval` is the scalar hot-path entry (one particle); `eval_batch` is the
/// SoA entry the engines and the AOT plane use — positions laid out
/// `[dim][particle]` (coalesced, Figure 2 of the paper) with `fit` filled
/// per particle.
pub trait Fitness: Sync {
    /// Human-readable name (table headers, CLI).
    fn name(&self) -> &'static str;

    /// Canonical per-dimension search bounds `(min_pos, max_pos)`.
    fn default_bounds(&self) -> (f64, f64);

    /// The optimization sense this function is conventionally used with.
    fn default_objective(&self) -> Objective;

    /// Evaluate one position (length = dimensionality).
    fn eval(&self, x: &[f64]) -> f64;

    /// Known optimum fitness value, if analytic (used by convergence tests).
    /// `dim` is the dimensionality.
    fn optimum(&self, dim: usize) -> Option<f64> {
        let _ = dim;
        None
    }

    /// Batch evaluation over SoA storage: `pos[d * n + i]` is coordinate `d`
    /// of particle `i`; writes `fit[i]`. Default loops over `eval` via a
    /// scratch vector; implementations override with a vectorized loop.
    fn eval_batch(&self, pos: &[f64], n: usize, dim: usize, fit: &mut [f64]) {
        debug_assert_eq!(pos.len(), n * dim);
        debug_assert_eq!(fit.len(), n);
        let mut x = vec![0.0; dim];
        for i in 0..n {
            for d in 0..dim {
                x[d] = pos[d * n + i];
            }
            fit[i] = self.eval(&x);
        }
    }

    /// Range evaluation over SoA storage: fitness of particles `lo..hi`
    /// into `fit[0..hi-lo]`. This is the engines' hot path — the default
    /// gathers per particle (strided), while separable functions override
    /// with **dimension-major row accumulation** that streams each SoA row
    /// contiguously (the CPU analog of the paper's coalesced access).
    ///
    /// Implementations must accumulate per-dimension terms in ascending
    /// dimension order so results are bit-identical to `eval` (the
    /// cross-engine equivalence tests rely on it).
    fn eval_range(&self, pos: &[f64], n: usize, dim: usize, lo: usize, hi: usize, fit: &mut [f64]) {
        debug_assert!(hi <= n && lo <= hi);
        debug_assert_eq!(fit.len(), hi - lo);
        let mut x = vec![0.0; dim];
        for i in lo..hi {
            for d in 0..dim {
                x[d] = pos[d * n + i];
            }
            fit[i - lo] = self.eval(&x);
        }
    }
}

/// Runtime function selection (CLI `--fitness`).
pub fn by_name(name: &str) -> Option<Box<dyn Fitness + Send>> {
    match name.to_ascii_lowercase().as_str() {
        "cubic" => Some(Box::new(Cubic)),
        "sphere" => Some(Box::new(Sphere)),
        "rosenbrock" => Some(Box::new(Rosenbrock)),
        "griewank" => Some(Box::new(Griewank)),
        "rastrigin" => Some(Box::new(Rastrigin)),
        "ackley" => Some(Box::new(Ackley)),
        "schwefel" | "schwefel226" => Some(Box::new(Schwefel226)),
        _ => None,
    }
}

/// All registered function names (for `--help` and the gallery example).
pub const ALL_NAMES: &[&str] = &[
    "cubic",
    "sphere",
    "rosenbrock",
    "griewank",
    "rastrigin",
    "ackley",
    "schwefel",
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn objective_better_semantics() {
        assert!(Objective::Maximize.better(2.0, 1.0));
        assert!(!Objective::Maximize.better(1.0, 2.0));
        assert!(!Objective::Maximize.better(1.0, 1.0));
        assert!(Objective::Minimize.better(1.0, 2.0));
        assert!(Objective::Maximize.better(0.0, Objective::Maximize.worst()));
        assert!(Objective::Minimize.better(0.0, Objective::Minimize.worst()));
    }

    #[test]
    fn nan_never_wins_better() {
        // The NaN policy's foundation: strict comparisons are false when
        // either side is NaN, under both senses.
        for obj in [Objective::Maximize, Objective::Minimize] {
            assert!(!obj.better(f64::NAN, 1.0), "{obj:?}: NaN beat a number");
            assert!(!obj.better(f64::NAN, obj.worst()), "{obj:?}: NaN beat worst");
            assert!(!obj.better(f64::NAN, f64::NAN), "{obj:?}: NaN beat NaN");
            // And an incumbent NaN is never *protected* either: finite
            // candidates also fail the strict comparison against NaN, so
            // comparisons against NaN resolve to "keep the incumbent"
            // both ways — which is why NaN must be kept out of the
            // incumbent slots in the first place (seeding uses worst()).
            assert!(!obj.better(1.0, f64::NAN), "{obj:?}");
        }
    }

    #[test]
    fn registry_resolves_all_names() {
        for name in ALL_NAMES {
            assert!(by_name(name).is_some(), "missing {name}");
        }
        assert!(by_name("nope").is_none());
    }

    #[test]
    fn eval_range_matches_eval_batch_for_all_functions() {
        // Both the streaming overrides (Cubic/Sphere/Rastrigin) and the
        // gather default (Rosenbrock/Griewank/Ackley/Schwefel) must agree
        // with eval_batch on arbitrary sub-ranges — including bit-exact
        // agreement for the separable overrides (ascending-dim sums).
        let n = 13;
        let dim = 6;
        for name in ALL_NAMES {
            let f = by_name(name).unwrap();
            let (lo_b, hi_b) = f.default_bounds();
            let pos: Vec<f64> = (0..n * dim)
                .map(|k| lo_b + (hi_b - lo_b) * ((k * 53 % 97) as f64 / 97.0))
                .collect();
            let mut full = vec![0.0; n];
            f.eval_batch(&pos, n, dim, &mut full);
            for (lo, hi) in [(0usize, n), (0, 5), (4, 11), (12, 13), (7, 7)] {
                let mut part = vec![0.0; hi - lo];
                f.eval_range(&pos, n, dim, lo, hi, &mut part);
                for k in 0..(hi - lo) {
                    assert!(
                        (part[k] - full[lo + k]).abs()
                            <= 1e-12 * full[lo + k].abs().max(1.0),
                        "{name} range ({lo},{hi}) idx {k}: {} vs {}",
                        part[k],
                        full[lo + k]
                    );
                }
            }
        }
    }

    #[test]
    fn eval_range_separable_is_bit_exact_with_eval() {
        // The engines' equivalence tests need eval_range ≡ eval exactly
        // for the functions on the hot path.
        let n = 8;
        let dim = 120;
        let f = Cubic;
        let pos: Vec<f64> = (0..n * dim)
            .map(|k| -100.0 + 200.0 * ((k * 31 % 113) as f64 / 113.0))
            .collect();
        let mut out = vec![0.0; n];
        f.eval_range(&pos, n, dim, 0, n, &mut out);
        for i in 0..n {
            let x: Vec<f64> = (0..dim).map(|d| pos[d * n + i]).collect();
            assert_eq!(out[i], f.eval(&x), "particle {i} not bit-exact");
        }
    }

    #[test]
    fn batch_matches_scalar_eval() {
        let funcs: Vec<Box<dyn Fitness + Send>> =
            ALL_NAMES.iter().map(|n| by_name(n).unwrap()).collect();
        let n = 7;
        let dim = 5;
        for f in &funcs {
            let (lo, hi) = f.default_bounds();
            // Deterministic pseudo-positions inside the domain.
            let pos: Vec<f64> = (0..n * dim)
                .map(|k| lo + (hi - lo) * ((k * 37 % 101) as f64 / 101.0))
                .collect();
            let mut fit = vec![0.0; n];
            f.eval_batch(&pos, n, dim, &mut fit);
            for i in 0..n {
                let x: Vec<f64> = (0..dim).map(|d| pos[d * n + i]).collect();
                let want = f.eval(&x);
                assert!(
                    (fit[i] - want).abs() <= 1e-9 * want.abs().max(1.0),
                    "{}: batch {} vs scalar {}",
                    f.name(),
                    fit[i],
                    want
                );
            }
        }
    }
}
