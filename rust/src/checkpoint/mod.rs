//! Checkpoint & restore — a run's full state as a versioned, serializable
//! value.
//!
//! Every engine draws from counter-based Philox streams keyed by
//! `(seed, particle, iteration, dim)` ([`crate::rng::PhiloxStream`]), so a
//! run's complete state is its SoA arrays plus a handful of counters —
//! no RNG tape, no in-flight kernel state. [`RunCheckpoint`] captures
//! exactly that: swarm state, the global best, the convergence history and
//! the instrumentation counters, keyed by the engine kind, workload
//! parameters and master seed. For the bit-exact engines (CPU serial,
//! the synchronous serial oracle, Reduction, Loop-Unrolling, Queue), a
//! restored run continues **bit-identically** to the uninterrupted one —
//! `rust/tests/checkpoint_resume.rs` proves it at every step boundary.
//! Queue-Lock and Async-Persistent restore to a valid quiescent state
//! (checkpoints are only ever taken between steps, when the grid has
//! joined), but their documented intra-run races make the continuation
//! trajectory theirs to choose.
//!
//! [`JobCheckpoint`] wraps a `RunCheckpoint` with the scheduler-level
//! state of one job (name, fitness registry key, stall counter, stop
//! reason, termination bounds) so a whole batch can be suspended to disk
//! and resumed — possibly on a different stream layout — by
//! [`crate::scheduler::JobScheduler::run_session`] and the `cupso resume`
//! subcommand.
//!
//! ## Wire format (`version: 1`)
//!
//! A small self-contained binary codec — no serde offline. Little-endian
//! throughout; `f64` values travel as their IEEE-754 bit patterns
//! (`to_bits`/`from_bits`), so NaN payloads, signed zeros and infinities
//! round-trip exactly. Layout:
//!
//! ```text
//! magic  [8]   "CUPSOCKP" (run) / "CUPSOJOB" (job)
//! version u32  1
//! body    …    length-prefixed fields (see encode())
//! check   u64  FNV-1a over everything before it
//! ```
//!
//! Decoding is loud and total: a wrong magic, unsupported version,
//! flipped byte, truncation or trailing garbage is an `Err`, never a
//! panic, and never a silently-wrong checkpoint. The golden fixture under
//! `rust/tests/fixtures/` pins the version-1 layout: today's decoder must
//! keep reading it forever (bump `VERSION` for incompatible changes).

pub mod io;
pub mod store;

use crate::config::EngineKind;
use io::write_atomic;
use crate::fitness::Objective;
use crate::pso::{Counters, PsoParams, SwarmState};
use anyhow::{bail, Context, Result};
use std::path::Path;

/// Current wire-format version.
pub const VERSION: u32 = 1;

const RUN_MAGIC: &[u8; 8] = b"CUPSOCKP";
const JOB_MAGIC: &[u8; 8] = b"CUPSOJOB";

/// Which `Run` implementation a checkpoint belongs to. This is
/// [`EngineKind`] plus the synchronous serial oracle (which is a run type
/// but not a launcher-selectable engine).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RunKind {
    /// [`crate::pso::serial::SerialRun`] (Algorithm 1, in-loop gbest).
    SerialCpu,
    /// [`crate::pso::serial_sync::SyncSerialRun`] (the PPSO oracle).
    SerialSync,
    /// [`crate::engine::ReductionEngine`], plain reduction.
    Reduction,
    /// [`crate::engine::ReductionEngine::unrolled`].
    LoopUnrolling,
    /// [`crate::engine::QueueEngine`].
    Queue,
    /// [`crate::engine::QueueLockEngine`].
    QueueLock,
    /// [`crate::engine::AsyncEngine`]'s step-wise run.
    AsyncPersistent,
}

impl RunKind {
    /// Stable wire code (part of the version-1 format — never renumber).
    pub fn code(self) -> u8 {
        match self {
            RunKind::SerialCpu => 0,
            RunKind::SerialSync => 1,
            RunKind::Reduction => 2,
            RunKind::LoopUnrolling => 3,
            RunKind::Queue => 4,
            RunKind::QueueLock => 5,
            RunKind::AsyncPersistent => 6,
        }
    }

    /// Inverse of [`code`](Self::code).
    pub fn from_code(c: u8) -> Result<Self> {
        Ok(match c {
            0 => RunKind::SerialCpu,
            1 => RunKind::SerialSync,
            2 => RunKind::Reduction,
            3 => RunKind::LoopUnrolling,
            4 => RunKind::Queue,
            5 => RunKind::QueueLock,
            6 => RunKind::AsyncPersistent,
            other => bail!("checkpoint: unknown run kind code {other}"),
        })
    }

    /// The launcher-selectable engine kind, if any (`None` for the
    /// synchronous serial oracle, which only exists as a reference).
    pub fn engine_kind(self) -> Option<EngineKind> {
        match self {
            RunKind::SerialCpu => Some(EngineKind::SerialCpu),
            RunKind::SerialSync => None,
            RunKind::Reduction => Some(EngineKind::Reduction),
            RunKind::LoopUnrolling => Some(EngineKind::LoopUnrolling),
            RunKind::Queue => Some(EngineKind::Queue),
            RunKind::QueueLock => Some(EngineKind::QueueLock),
            RunKind::AsyncPersistent => Some(EngineKind::AsyncPersistent),
        }
    }

    /// The run kind a scheduler job of `kind` checkpoints as.
    pub fn from_engine(kind: EngineKind) -> Option<Self> {
        match kind {
            EngineKind::SerialCpu => Some(RunKind::SerialCpu),
            EngineKind::Reduction => Some(RunKind::Reduction),
            EngineKind::LoopUnrolling => Some(RunKind::LoopUnrolling),
            EngineKind::Queue => Some(RunKind::Queue),
            EngineKind::QueueLock => Some(RunKind::QueueLock),
            EngineKind::AsyncPersistent => Some(RunKind::AsyncPersistent),
            EngineKind::XlaSync | EngineKind::XlaAsync => None,
        }
    }
}

impl std::fmt::Display for RunKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            RunKind::SerialCpu => "serial",
            RunKind::SerialSync => "serial-sync",
            RunKind::Reduction => "reduction",
            RunKind::LoopUnrolling => "loop-unrolling",
            RunKind::Queue => "queue",
            RunKind::QueueLock => "queue-lock",
            RunKind::AsyncPersistent => "async-persistent",
        };
        f.write_str(s)
    }
}

/// The complete state of one run at a step boundary.
///
/// Captured by [`crate::engine::Run::checkpoint`] (grid quiescent by
/// construction: `step` only returns after its launches joined) and
/// turned back into a live run by [`crate::engine::Engine::restore`] /
/// [`crate::engine::restore_with`].
#[derive(Debug, Clone)]
pub struct RunCheckpoint {
    /// Wire-format version this checkpoint was captured as.
    pub version: u32,
    /// Which run implementation produced it.
    pub kind: RunKind,
    /// Optimization sense.
    pub objective: Objective,
    /// Master seed (rebuilds the Philox stream namespace exactly).
    pub seed: u64,
    /// Full workload parameters.
    pub params: PsoParams,
    /// Iterations completed.
    pub iter: u64,
    /// Global-best fitness.
    pub gbest_fit: f64,
    /// Global-best position (length = dim).
    pub gbest_pos: Vec<f64>,
    /// Sampled convergence history so far.
    pub history: Vec<(u64, f64)>,
    /// Instrumentation counters as they would appear in a `RunOutput`
    /// finished right now.
    pub counters: Counters,
    /// The swarm's SoA arrays.
    pub swarm: SwarmState,
}

impl RunCheckpoint {
    /// Structural consistency: array lengths agree with `n`/`dim`, the
    /// iteration counter is inside the budget. (Degenerate `n = 0`
    /// checkpoints are codec-valid — engines reject them at restore.)
    pub fn validate(&self) -> Result<()> {
        let (n, dim) = (self.swarm.n, self.swarm.dim);
        if n != self.params.n || dim != self.params.dim {
            bail!(
                "checkpoint: swarm {}x{} disagrees with params {}x{}",
                n,
                dim,
                self.params.n,
                self.params.dim
            );
        }
        let rows = n * dim;
        if self.swarm.pos.len() != rows
            || self.swarm.vel.len() != rows
            || self.swarm.pbest_pos.len() != rows
            || self.swarm.fit.len() != n
            || self.swarm.pbest_fit.len() != n
        {
            bail!("checkpoint: swarm array lengths inconsistent with {n}x{dim}");
        }
        if self.gbest_pos.len() != dim {
            bail!(
                "checkpoint: gbest_pos has {} entries, expected dim {dim}",
                self.gbest_pos.len()
            );
        }
        if self.iter > self.params.max_iter {
            bail!(
                "checkpoint: iter {} exceeds budget {}",
                self.iter,
                self.params.max_iter
            );
        }
        Ok(())
    }

    /// Serialize to the version-1 wire format.
    pub fn encode(&self) -> Vec<u8> {
        let mut buf = Vec::new();
        self.encode_into(&mut buf);
        buf
    }

    /// Serialize into a caller-owned buffer (cleared first), so periodic
    /// persistence reuses one allocation across snapshots instead of
    /// building a fresh `Vec` per checkpoint per round.
    pub fn encode_into(&self, buf: &mut Vec<u8>) {
        let mut w = Writer::open(buf, RUN_MAGIC);
        self.encode_body(&mut w);
        w.seal();
    }

    fn encode_body(&self, w: &mut Writer<'_>) {
        w.u8(self.kind.code());
        w.u8(match self.objective {
            Objective::Maximize => 0,
            Objective::Minimize => 1,
        });
        w.u64(self.seed);
        let p = &self.params;
        w.u64(p.n as u64);
        w.u64(p.dim as u64);
        w.u64(p.max_iter);
        for v in [p.w, p.c1, p.c2, p.min_pos, p.max_pos, p.max_v] {
            w.f64(v);
        }
        w.u64(self.iter);
        w.f64(self.gbest_fit);
        w.f64_slice(&self.gbest_pos);
        w.u64(self.history.len() as u64);
        for &(it, fit) in &self.history {
            w.u64(it);
            w.f64(fit);
        }
        let c = &self.counters;
        for v in [
            c.pbest_improvements,
            c.queue_pushes,
            c.gbest_updates,
            c.particle_updates,
        ] {
            w.u64(v);
        }
        let s = &self.swarm;
        w.f64_slice(&s.pos);
        w.f64_slice(&s.vel);
        w.f64_slice(&s.fit);
        w.f64_slice(&s.pbest_pos);
        w.f64_slice(&s.pbest_fit);
    }

    /// Deserialize, verifying magic, version, checksum and consistency.
    pub fn decode(bytes: &[u8]) -> Result<Self> {
        let mut r = Reader::open(bytes, RUN_MAGIC)?;
        let ckpt = Self::decode_body(&mut r)?;
        r.close()?;
        ckpt.validate()?;
        Ok(ckpt)
    }

    fn decode_body(r: &mut Reader<'_>) -> Result<Self> {
        let kind = RunKind::from_code(r.u8()?)?;
        let objective = match r.u8()? {
            0 => Objective::Maximize,
            1 => Objective::Minimize,
            other => bail!("checkpoint: bad objective code {other}"),
        };
        let seed = r.u64()?;
        let n = r.usize()?;
        let dim = r.usize()?;
        let max_iter = r.u64()?;
        let (w, c1, c2, min_pos, max_pos, max_v) =
            (r.f64()?, r.f64()?, r.f64()?, r.f64()?, r.f64()?, r.f64()?);
        let params = PsoParams {
            w,
            c1,
            c2,
            min_pos,
            max_pos,
            max_v,
            max_iter,
            n,
            dim,
        };
        let iter = r.u64()?;
        let gbest_fit = r.f64()?;
        let gbest_pos = r.f64_slice()?;
        let hist_len = r.usize()?;
        // Each entry is 16 body bytes; a corrupt length cannot pass the
        // checksum, but never allocate beyond what the body can hold.
        if r.remaining() / 16 < hist_len {
            bail!("checkpoint: history length {hist_len} exceeds remaining body");
        }
        let mut history = Vec::with_capacity(hist_len);
        for _ in 0..hist_len {
            let it = r.u64()?;
            let fit = r.f64()?;
            history.push((it, fit));
        }
        let counters = Counters {
            pbest_improvements: r.u64()?,
            queue_pushes: r.u64()?,
            gbest_updates: r.u64()?,
            particle_updates: r.u64()?,
        };
        let swarm = SwarmState {
            n,
            dim,
            pos: r.f64_slice()?,
            vel: r.f64_slice()?,
            fit: r.f64_slice()?,
            pbest_pos: r.f64_slice()?,
            pbest_fit: r.f64_slice()?,
        };
        Ok(Self {
            version: VERSION,
            kind,
            objective,
            seed,
            params,
            iter,
            gbest_fit,
            gbest_pos,
            history,
            counters,
            swarm,
        })
    }

    /// Write to a file (atomic: temp + rename, so a crash mid-write never
    /// leaves a torn checkpoint behind).
    pub fn write_file(&self, path: &Path) -> Result<()> {
        write_atomic(path, &self.encode())
    }

    /// Like [`write_file`](Self::write_file), encoding through a reusable
    /// buffer (see [`encode_into`](Self::encode_into)).
    pub fn write_file_with(&self, path: &Path, buf: &mut Vec<u8>) -> Result<()> {
        self.encode_into(buf);
        write_atomic(path, buf)
    }

    /// Read and decode a checkpoint file.
    pub fn read_file(path: &Path) -> Result<Self> {
        let bytes = std::fs::read(path)
            .with_context(|| format!("reading checkpoint {}", path.display()))?;
        Self::decode(&bytes).with_context(|| format!("decoding checkpoint {}", path.display()))
    }
}

/// Scheduler-level state of one suspended job: the run checkpoint plus
/// everything [`crate::scheduler::JobScheduler`] needs to rebuild the
/// job's spec and termination bookkeeping.
#[derive(Debug, Clone)]
pub struct JobCheckpoint {
    /// Job name (batch-config section name). Interned (`Arc<str>`) so the
    /// scheduler's snapshots share one allocation with the spec instead
    /// of cloning the string per persist.
    pub name: std::sync::Arc<str>,
    /// Fitness registry key ([`crate::fitness::by_name`]).
    pub fitness: String,
    /// Consecutive non-improving steps at suspension.
    pub stalled: u64,
    /// Stop-reason code if the job already terminated (see
    /// [`crate::scheduler::StopReason`]; stored as its wire code so the
    /// codec stays self-contained).
    pub stop: Option<u8>,
    /// Early stop: target fitness.
    pub target_fit: Option<f64>,
    /// Early stop: stall window.
    pub stall_window: Option<u64>,
    /// Early stop: scheduler-step cap.
    pub max_steps: Option<u64>,
    /// EDF deadline in scheduler steps.
    pub deadline: Option<u64>,
    /// The run state itself. Shared (`Arc`) so suspension hands the same
    /// checkpoint from a live run to the scheduler's parked slot and to a
    /// persisted snapshot without deep-copying the swarm arrays.
    pub run: std::sync::Arc<RunCheckpoint>,
}

impl JobCheckpoint {
    /// Serialize to the version-1 wire format.
    pub fn encode(&self) -> Vec<u8> {
        let mut buf = Vec::new();
        self.encode_into(&mut buf);
        buf
    }

    /// Serialize into a caller-owned buffer (cleared first) — the
    /// reusable-allocation form of [`encode`](Self::encode).
    pub fn encode_into(&self, buf: &mut Vec<u8>) {
        let mut w = Writer::open(buf, JOB_MAGIC);
        w.str(&self.name);
        w.str(&self.fitness);
        w.u64(self.stalled);
        w.opt_u8(self.stop);
        w.opt_f64(self.target_fit);
        w.opt_u64(self.stall_window);
        w.opt_u64(self.max_steps);
        w.opt_u64(self.deadline);
        self.run.encode_body(&mut w);
        w.seal();
    }

    /// Deserialize, verifying magic, version, checksum and consistency.
    pub fn decode(bytes: &[u8]) -> Result<Self> {
        let mut r = Reader::open(bytes, JOB_MAGIC)?;
        let name = r.str()?;
        let fitness = r.str()?;
        let stalled = r.u64()?;
        let stop = r.opt_u8()?;
        let target_fit = r.opt_f64()?;
        let stall_window = r.opt_u64()?;
        let max_steps = r.opt_u64()?;
        let deadline = r.opt_u64()?;
        let run = RunCheckpoint::decode_body(&mut r)?;
        r.close()?;
        run.validate()?;
        Ok(Self {
            name: name.into(),
            fitness,
            stalled,
            stop,
            target_fit,
            stall_window,
            max_steps,
            deadline,
            run: std::sync::Arc::new(run),
        })
    }

    /// Write to a file (durable atomic write — see [`io::write_atomic`]).
    pub fn write_file(&self, path: &Path) -> Result<()> {
        write_atomic(path, &self.encode())
    }

    /// Like [`write_file`](Self::write_file), encoding through a reusable
    /// buffer.
    pub fn write_file_with(&self, path: &Path, buf: &mut Vec<u8>) -> Result<()> {
        self.encode_into(buf);
        write_atomic(path, buf)
    }

    /// Read and decode a job-checkpoint file.
    pub fn read_file(path: &Path) -> Result<Self> {
        let bytes = std::fs::read(path)
            .with_context(|| format!("reading job checkpoint {}", path.display()))?;
        Self::decode(&bytes)
            .with_context(|| format!("decoding job checkpoint {}", path.display()))
    }
}

/// FNV-1a 64-bit — tiny, dependency-free corruption detector (not a
/// cryptographic signature).
fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Little-endian append-only encoder over a caller-owned buffer: magic +
/// version up front, FNV seal at the end. Borrowing (rather than owning)
/// the buffer lets periodic persistence reuse one allocation across
/// every checkpoint it writes.
struct Writer<'b>(&'b mut Vec<u8>);

impl<'b> Writer<'b> {
    fn open(buf: &'b mut Vec<u8>, magic: &[u8; 8]) -> Self {
        buf.clear();
        buf.extend_from_slice(magic);
        buf.extend_from_slice(&VERSION.to_le_bytes());
        Self(buf)
    }

    fn u8(&mut self, v: u8) {
        self.0.push(v);
    }

    fn u64(&mut self, v: u64) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }

    fn f64(&mut self, v: f64) {
        self.u64(v.to_bits());
    }

    fn f64_slice(&mut self, vs: &[f64]) {
        self.u64(vs.len() as u64);
        for &v in vs {
            self.f64(v);
        }
    }

    fn str(&mut self, s: &str) {
        self.u64(s.len() as u64);
        self.0.extend_from_slice(s.as_bytes());
    }

    fn opt_u8(&mut self, v: Option<u8>) {
        match v {
            None => self.u8(0),
            Some(x) => {
                self.u8(1);
                self.u8(x);
            }
        }
    }

    fn opt_u64(&mut self, v: Option<u64>) {
        match v {
            None => self.u8(0),
            Some(x) => {
                self.u8(1);
                self.u64(x);
            }
        }
    }

    fn opt_f64(&mut self, v: Option<f64>) {
        match v {
            None => self.u8(0),
            Some(x) => {
                self.u8(1);
                self.f64(x);
            }
        }
    }

    fn seal(self) {
        let check = fnv1a(self.0);
        self.0.extend_from_slice(&check.to_le_bytes());
    }
}

/// Bounds-checked little-endian decoder. Every accessor returns `Err` on
/// underflow; `close` rejects trailing bytes. Never panics on any input.
struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    /// Verify magic, version and checksum, then expose the body.
    fn open(bytes: &'a [u8], magic: &[u8; 8]) -> Result<Self> {
        if bytes.len() < 8 + 4 + 8 {
            bail!("checkpoint: truncated ({} bytes)", bytes.len());
        }
        if &bytes[..8] != magic {
            bail!(
                "checkpoint: bad magic {:02x?} (expected {:?})",
                &bytes[..8],
                std::str::from_utf8(magic).unwrap_or("?")
            );
        }
        let version = u32::from_le_bytes(bytes[8..12].try_into().unwrap());
        if version != VERSION {
            bail!("checkpoint: unsupported version {version} (this build reads {VERSION})");
        }
        let body_end = bytes.len() - 8;
        let stored = u64::from_le_bytes(bytes[body_end..].try_into().unwrap());
        let actual = fnv1a(&bytes[..body_end]);
        if stored != actual {
            bail!("checkpoint: checksum mismatch (corrupted or torn file)");
        }
        Ok(Self {
            buf: &bytes[..body_end],
            pos: 12,
        })
    }

    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.remaining() < n {
            bail!(
                "checkpoint: truncated body (need {n} bytes, have {})",
                self.remaining()
            );
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn usize(&mut self) -> Result<usize> {
        let v = self.u64()?;
        usize::try_from(v).map_err(|_| anyhow::anyhow!("checkpoint: length {v} overflows usize"))
    }

    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }

    fn f64_slice(&mut self) -> Result<Vec<f64>> {
        let len = self.usize()?;
        // A corrupt length cannot pass the checksum, but stay defensive:
        // the body must actually hold that many entries before allocating.
        if self.remaining() / 8 < len {
            bail!("checkpoint: array length {len} exceeds remaining body");
        }
        let mut out = Vec::with_capacity(len);
        for _ in 0..len {
            out.push(self.f64()?);
        }
        Ok(out)
    }

    fn str(&mut self) -> Result<String> {
        let len = self.usize()?;
        let bytes = self.take(len)?;
        String::from_utf8(bytes.to_vec())
            .map_err(|_| anyhow::anyhow!("checkpoint: non-UTF8 string field"))
    }

    fn opt_u8(&mut self) -> Result<Option<u8>> {
        match self.u8()? {
            0 => Ok(None),
            1 => Ok(Some(self.u8()?)),
            t => bail!("checkpoint: bad option tag {t}"),
        }
    }

    fn opt_u64(&mut self) -> Result<Option<u64>> {
        match self.u8()? {
            0 => Ok(None),
            1 => Ok(Some(self.u64()?)),
            t => bail!("checkpoint: bad option tag {t}"),
        }
    }

    fn opt_f64(&mut self) -> Result<Option<f64>> {
        match self.u8()? {
            0 => Ok(None),
            1 => Ok(Some(self.f64()?)),
            t => bail!("checkpoint: bad option tag {t}"),
        }
    }

    fn close(self) -> Result<()> {
        if self.pos != self.buf.len() {
            bail!(
                "checkpoint: {} trailing bytes after body",
                self.buf.len() - self.pos
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    pub(crate) fn sample(n: usize, dim: usize) -> RunCheckpoint {
        let params = PsoParams {
            dim,
            n,
            ..PsoParams::paper_1d(n, 40)
        };
        let rows = n * dim;
        RunCheckpoint {
            version: VERSION,
            kind: RunKind::Queue,
            objective: Objective::Maximize,
            seed: 7,
            params,
            iter: 13,
            gbest_fit: 1.5,
            gbest_pos: vec![0.25; dim],
            history: vec![(0, -1.0), (10, 1.5)],
            counters: Counters {
                pbest_improvements: 3,
                queue_pushes: 5,
                gbest_updates: 2,
                particle_updates: n as u64 * 13,
            },
            swarm: SwarmState {
                n,
                dim,
                pos: (0..rows).map(|i| i as f64 * 0.5).collect(),
                vel: vec![-0.0; rows],
                fit: vec![f64::NAN; n],
                pbest_pos: vec![1.0; rows],
                pbest_fit: vec![f64::NEG_INFINITY; n],
            },
        }
    }

    fn assert_bit_equal(a: &RunCheckpoint, b: &RunCheckpoint) {
        assert_eq!(a.kind, b.kind);
        assert_eq!(a.objective, b.objective);
        assert_eq!(a.seed, b.seed);
        assert_eq!(a.iter, b.iter);
        assert_eq!(a.gbest_fit.to_bits(), b.gbest_fit.to_bits());
        let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&a.gbest_pos), bits(&b.gbest_pos));
        assert_eq!(a.history.len(), b.history.len());
        for (x, y) in a.history.iter().zip(&b.history) {
            assert_eq!(x.0, y.0);
            assert_eq!(x.1.to_bits(), y.1.to_bits());
        }
        assert_eq!(bits(&a.swarm.pos), bits(&b.swarm.pos));
        assert_eq!(bits(&a.swarm.vel), bits(&b.swarm.vel));
        assert_eq!(bits(&a.swarm.fit), bits(&b.swarm.fit));
        assert_eq!(bits(&a.swarm.pbest_pos), bits(&b.swarm.pbest_pos));
        assert_eq!(bits(&a.swarm.pbest_fit), bits(&b.swarm.pbest_fit));
    }

    #[test]
    fn roundtrip_preserves_bit_patterns() {
        // NaN fits, -0.0 velocities and ±∞ pbest values must survive.
        let ckpt = sample(6, 3);
        let decoded = RunCheckpoint::decode(&ckpt.encode()).unwrap();
        assert_bit_equal(&ckpt, &decoded);
    }

    #[test]
    fn degenerate_empty_swarm_roundtrips() {
        let ckpt = sample(0, 1);
        let decoded = RunCheckpoint::decode(&ckpt.encode()).unwrap();
        assert_bit_equal(&ckpt, &decoded);
        assert!(decoded.swarm.pos.is_empty());
    }

    #[test]
    fn bad_magic_version_and_truncation_fail_loudly() {
        let bytes = sample(4, 2).encode();
        // Wrong magic.
        let mut bad = bytes.clone();
        bad[0] ^= 0xFF;
        assert!(RunCheckpoint::decode(&bad).unwrap_err().to_string().contains("magic"));
        // Future version.
        let mut bumped = bytes.clone();
        bumped[8..12].copy_from_slice(&2u32.to_le_bytes());
        let err = RunCheckpoint::decode(&bumped).unwrap_err().to_string();
        assert!(err.contains("version 2"), "{err}");
        // Truncations at every prefix length: Err, never panic.
        for cut in 0..bytes.len() {
            assert!(RunCheckpoint::decode(&bytes[..cut]).is_err(), "cut={cut}");
        }
        // Trailing garbage.
        let mut long = bytes.clone();
        long.push(0);
        assert!(RunCheckpoint::decode(&long).is_err());
    }

    #[test]
    fn job_checkpoint_roundtrips_with_options() {
        let job = JobCheckpoint {
            name: "tenant-α".into(),
            fitness: "cubic".into(),
            stalled: 4,
            stop: Some(2),
            target_fit: Some(899_000.5),
            stall_window: None,
            max_steps: Some(100),
            deadline: None,
            run: std::sync::Arc::new(sample(5, 2)),
        };
        let decoded = JobCheckpoint::decode(&job.encode()).unwrap();
        assert_eq!(&*decoded.name, "tenant-α");
        assert_eq!(decoded.fitness, "cubic");
        assert_eq!(decoded.stalled, 4);
        assert_eq!(decoded.stop, Some(2));
        assert_eq!(decoded.target_fit.map(f64::to_bits), Some(899_000.5f64.to_bits()));
        assert_eq!(decoded.stall_window, None);
        assert_eq!(decoded.max_steps, Some(100));
        assert_eq!(decoded.deadline, None);
        assert_bit_equal(&job.run, &decoded.run);
        // A run checkpoint is not a job checkpoint.
        assert!(JobCheckpoint::decode(&sample(2, 1).encode()).is_err());
    }

    #[test]
    fn file_roundtrip_is_atomic_and_exact() {
        let dir = std::env::temp_dir().join("cupso-ckpt-unit");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("run.ckpt");
        let ckpt = sample(3, 2);
        ckpt.write_file(&path).unwrap();
        assert!(!path.with_extension("tmp").exists(), "temp file leaked");
        let back = RunCheckpoint::read_file(&path).unwrap();
        assert_bit_equal(&ckpt, &back);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn run_kind_codes_are_stable_and_total() {
        for code in 0..7u8 {
            let kind = RunKind::from_code(code).unwrap();
            assert_eq!(kind.code(), code);
        }
        assert!(RunKind::from_code(7).is_err());
        // Engine mapping round-trips for every Plane-A kind.
        for kind in EngineKind::TABLE3 {
            let rk = RunKind::from_engine(kind).unwrap();
            assert_eq!(rk.engine_kind(), Some(kind));
        }
        assert_eq!(RunKind::SerialSync.engine_kind(), None);
        assert!(RunKind::from_engine(EngineKind::XlaSync).is_none());
    }
}
