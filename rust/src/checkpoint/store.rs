//! Batch-snapshot persistence: the on-disk layout shared by
//! `cupso batch --checkpoint-dir`, `cupso resume` and the service
//! daemon's drain-to-snapshot path.
//!
//! A snapshot directory holds one `job_<i>.ckpt` per job (the
//! [`JobCheckpoint`] wire format) plus a `manifest.toml` recording the
//! scheduler knobs, snapshot source and job count. Two layouts exist:
//!
//! * **flat** (`keep == 1`, the default): the directory itself holds the
//!   manifest and is overwritten in place per persist;
//! * **rotated** (`keep > 1`): numbered `snap_<seq>/` subdirectories,
//!   pruned so the latest `keep` survive; [`resolve_snapshot_dir`] picks
//!   the newest on resume.
//!
//! The job list is whatever the session held when the snapshot was
//! taken — for a drained service that includes every dynamically
//! admitted job (minus reaped/cancelled ones), which is exactly why the
//! store lives in the library now: `cupso resume` reconstructs the batch
//! purely from the snapshot, so a drained service resumes through the
//! identical path as a suspended batch.
//!
//! This module used to live inside the launcher binary; it moved into
//! the library so the service layer (and tests) can drive it directly.

use super::JobCheckpoint;
use crate::config::{parse_toml, BatchConfig, TomlValue};
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Writes batch snapshots under a checkpoint directory, with retention.
///
/// `keep == 1` (the default) overwrites the directory in place — the
/// layout `cupso resume` has always read. `keep > 1` rotates numbered
/// `snap_<seq>/` subdirectories, pruning so the latest `keep` survive
/// (ROADMAP retention item); [`resolve_snapshot_dir`] picks the newest on
/// resume. One encode buffer is reused across every checkpoint written.
pub struct SnapshotSink<'a> {
    dir: &'a Path,
    cfg: &'a BatchConfig,
    keep: usize,
    /// Who wrote the snapshot (`"batch"` | `"serve"`), recorded in the
    /// manifest for provenance.
    source: &'static str,
    seq: u64,
    buf: Vec<u8>,
}

impl<'a> SnapshotSink<'a> {
    /// A sink over `dir` with the given retention and provenance tag.
    pub fn new(
        dir: &'a Path,
        cfg: &'a BatchConfig,
        keep: usize,
        source: &'static str,
    ) -> Result<Self> {
        // Continue numbering after any snapshots a previous run left.
        let seq = match list_rotated(dir) {
            Ok(existing) => existing.last().map_or(0, |&(s, _)| s + 1),
            Err(_) => 0, // directory does not exist yet
        };
        Ok(Self {
            dir,
            cfg,
            keep,
            source,
            seq,
            buf: Vec::new(),
        })
    }

    /// Persist one snapshot under the sink's retention policy.
    pub fn persist(&mut self, snap: &[JobCheckpoint]) -> Result<()> {
        if self.keep <= 1 {
            return write_snapshot(self.dir, self.cfg, self.keep, self.source, snap, &mut self.buf);
        }
        let target = self.dir.join(format!("snap_{:06}", self.seq));
        write_snapshot(&target, self.cfg, self.keep, self.source, snap, &mut self.buf)?;
        self.seq += 1;
        // Prune: keep the latest `keep` rotated snapshots.
        let existing = list_rotated(self.dir)?;
        for (_, path) in existing.iter().rev().skip(self.keep) {
            std::fs::remove_dir_all(path)
                .with_context(|| format!("pruning old snapshot {}", path.display()))?;
        }
        Ok(())
    }
}

/// Numbered `snap_<seq>/` subdirectories holding a manifest, ascending.
pub fn list_rotated(dir: &Path) -> Result<Vec<(u64, PathBuf)>> {
    let mut found = Vec::new();
    for entry in std::fs::read_dir(dir).with_context(|| format!("listing {}", dir.display()))? {
        let path = entry?.path();
        let Some(name) = path.file_name().and_then(|n| n.to_str()) else {
            continue;
        };
        if let Some(seq) = name.strip_prefix("snap_").and_then(|s| s.parse::<u64>().ok()) {
            if path.join("manifest.toml").exists() {
                found.push((seq, path));
            }
        }
    }
    found.sort_unstable_by_key(|&(s, _)| s);
    Ok(found)
}

/// The snapshot directory `cupso resume` should read: the directory
/// itself when it holds a manifest (keep = 1 layout), otherwise the
/// newest rotated `snap_<seq>/` subdirectory.
pub fn resolve_snapshot_dir(dir: &Path) -> Result<PathBuf> {
    if dir.join("manifest.toml").exists() {
        return Ok(dir.to_path_buf());
    }
    let mut rotated = list_rotated(dir).unwrap_or_default();
    rotated.pop().map(|(_, p)| p).with_context(|| {
        format!(
            "no manifest.toml or snap_*/ snapshot under {}",
            dir.display()
        )
    })
}

/// Persist a batch snapshot: one `job_<i>.ckpt` per job plus a
/// `manifest.toml` recording the scheduler knobs, provenance and job
/// count. `buf` is the reusable encode buffer.
pub fn write_snapshot(
    dir: &Path,
    cfg: &BatchConfig,
    keep: usize,
    source: &str,
    snap: &[JobCheckpoint],
    buf: &mut Vec<u8>,
) -> Result<()> {
    std::fs::create_dir_all(dir)
        .with_context(|| format!("creating checkpoint dir {}", dir.display()))?;
    for (i, job) in snap.iter().enumerate() {
        job.write_file_with(&dir.join(format!("job_{i}.ckpt")), buf)?;
    }
    let manifest = format!(
        "# cupso batch snapshot — continue with `cupso resume {}`\n\
         version = {}\n\
         source = \"{}\"\n\
         workers = {}\n\
         policy = \"{}\"\n\
         streams = {}\n\
         batch_steps = {}\n\
         preempt_quantum = {}\n\
         pack = {}\n\
         pack_min = {}\n\
         pack_max = {}\n\
         quota_jobs = {}\n\
         quota_steps = {}\n\
         keep = {}\n\
         jobs = {}\n",
        dir.display(),
        super::VERSION,
        source,
        cfg.workers,
        cfg.policy,
        cfg.streams,
        cfg.batch_steps,
        cfg.preempt_quantum,
        cfg.pack,
        cfg.pack_min,
        cfg.pack_max,
        cfg.quota_jobs,
        cfg.quota_steps,
        keep,
        snap.len()
    );
    // Atomic like the job checkpoints: a crash mid-write must never tear
    // the manifest, or the whole snapshot becomes unresumable.
    let tmp = dir.join("manifest.toml.tmp");
    std::fs::write(&tmp, manifest)
        .with_context(|| format!("writing manifest in {}", dir.display()))?;
    std::fs::rename(&tmp, dir.join("manifest.toml"))
        .with_context(|| format!("publishing manifest in {}", dir.display()))?;
    Ok(())
}

/// Load a batch snapshot directory: scheduler knobs (as a job-less
/// [`BatchConfig`]) plus the retention count and every job checkpoint in
/// manifest order.
pub fn read_snapshot(dir: &Path) -> Result<(BatchConfig, usize, Vec<JobCheckpoint>)> {
    let manifest_path = dir.join("manifest.toml");
    let text = std::fs::read_to_string(&manifest_path)
        .with_context(|| format!("reading {}", manifest_path.display()))?;
    let doc: BTreeMap<String, TomlValue> = parse_toml(&text)?.into_iter().collect();
    // Loud on anything out of range — a hand-edited or torn manifest must
    // never wrap into a huge thread count or silently clamp a knob. The
    // caps are per-key: resource-shaped knobs (workers/streams/jobs) get
    // tight plausibility bounds, step-denominated knobs only reject
    // negatives (the writer recorded whatever the user asked for).
    let get_uint = |key: &str, max: u64| -> Result<u64> {
        let v = doc
            .get(key)
            .with_context(|| format!("manifest: missing key {key:?}"))?
            .as_int(key)?;
        if v < 0 || v as u64 > max {
            bail!("manifest: {key} = {v} out of range");
        }
        Ok(v as u64)
    };
    let version = get_uint("version", u32::MAX as u64)?;
    if version != super::VERSION as u64 {
        bail!(
            "manifest: snapshot version {version} unsupported (this build reads {})",
            super::VERSION
        );
    }
    let streams = get_uint("streams", 1_000_000)?;
    let batch_steps = get_uint("batch_steps", u64::MAX)?;
    if streams == 0 || batch_steps == 0 {
        bail!("manifest: streams and batch_steps must be >= 1");
    }
    let knobs = BatchConfig {
        workers: get_uint("workers", 1_000_000)? as usize,
        policy: doc
            .get("policy")
            .context("manifest: missing key \"policy\"")?
            .as_str("policy")?
            .to_string(),
        streams: streams as usize,
        batch_steps,
        preempt_quantum: get_uint("preempt_quantum", u64::MAX)?,
        // Optional for compatibility with pre-packing snapshots.
        pack: match doc.get("pack") {
            Some(v) => v.as_bool("pack")?,
            None => false,
        },
        pack_min: match doc.get("pack_min") {
            Some(v) => {
                let n = v.as_int("pack_min")?;
                if !(2..=100_000).contains(&n) {
                    bail!("manifest: pack_min = {n} out of range");
                }
                n as usize
            }
            None => 2,
        },
        pack_max: match doc.get("pack_max") {
            Some(v) => {
                let n = v.as_int("pack_max")?;
                if !(0..=100_000).contains(&n) {
                    bail!("manifest: pack_max = {n} out of range");
                }
                n as usize
            }
            None => 0,
        },
        // Optional for compatibility with pre-quota snapshots.
        quota_jobs: match doc.get("quota_jobs") {
            Some(v) => {
                let n = v.as_int("quota_jobs")?;
                if !(0..=1_000_000).contains(&n) {
                    bail!("manifest: quota_jobs = {n} out of range");
                }
                n as usize
            }
            None => 0,
        },
        quota_steps: match doc.get("quota_steps") {
            Some(v) => {
                let n = v.as_int("quota_steps")?;
                if n < 0 {
                    bail!("manifest: quota_steps = {n} out of range");
                }
                n as u64
            }
            None => 0,
        },
        jobs: Vec::new(),
    };
    // Optional for compatibility with pre-rotation snapshots.
    let keep = match doc.get("keep") {
        Some(v) => {
            let k = v.as_int("keep")?;
            if !(1..=1_000_000).contains(&k) {
                bail!("manifest: keep = {k} out of range");
            }
            k as usize
        }
        None => 1,
    };
    let job_count = get_uint("jobs", 100_000)?;
    let mut ckpts = Vec::with_capacity(job_count as usize);
    for i in 0..job_count {
        ckpts.push(JobCheckpoint::read_file(&dir.join(format!("job_{i}.ckpt")))?);
    }
    Ok((knobs, keep, ckpts))
}
