//! Batch-snapshot persistence: the on-disk layout shared by
//! `cupso batch --checkpoint-dir`, `cupso resume` and the service
//! daemon's drain-to-snapshot path.
//!
//! A snapshot directory holds one `job_<i>.ckpt` per job (the
//! [`JobCheckpoint`] wire format) plus a `manifest.toml` recording the
//! scheduler knobs, snapshot source and job count. Two layouts exist:
//!
//! * **flat** (`keep == 1`, the default): the directory itself holds the
//!   manifest and is overwritten in place per persist;
//! * **rotated** (`keep > 1`): numbered `snap_<seq>/` subdirectories,
//!   pruned so the latest `keep` survive; [`resolve_snapshot_dir`] picks
//!   the newest on resume.
//!
//! The job list is whatever the session held when the snapshot was
//! taken — for a drained service that includes every dynamically
//! admitted job (minus reaped/cancelled ones), which is exactly why the
//! store lives in the library now: `cupso resume` reconstructs the batch
//! purely from the snapshot, so a drained service resumes through the
//! identical path as a suspended batch.
//!
//! This module used to live inside the launcher binary; it moved into
//! the library so the service layer (and tests) can drive it directly.
//!
//! ## Crash safety
//!
//! All disk I/O goes through the durable seam in [`super::io`]: every
//! file is written temp + fsync + rename + parent-dir fsync, and within
//! a snapshot the `job_<i>.ckpt` files are all durable *before*
//! `manifest.toml` is published — the manifest is the commit point, so
//! its presence certifies a complete snapshot (that is also why
//! [`list_rotated`] only counts directories holding one). Recovery is
//! lenient where strictness would lose work: [`load_snapshot`]
//! quarantines torn/missing job files with a per-job report instead of
//! failing the whole directory, and prefers the newest *fully-valid*
//! rotated snapshot over a newer damaged one.

use super::io::{self, write_atomic};
use super::JobCheckpoint;
use crate::config::{parse_toml, BatchConfig, TomlValue};
use crate::telemetry::{self, Counter, Series, TraceKind};
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::time::Instant;

/// Writes batch snapshots under a checkpoint directory, with retention.
///
/// `keep == 1` (the default) overwrites the directory in place — the
/// layout `cupso resume` has always read. `keep > 1` rotates numbered
/// `snap_<seq>/` subdirectories, pruning so the latest `keep` survive
/// (ROADMAP retention item); [`resolve_snapshot_dir`] picks the newest on
/// resume. One encode buffer is reused across every checkpoint written.
pub struct SnapshotSink {
    dir: PathBuf,
    cfg: BatchConfig,
    keep: usize,
    /// Who wrote the snapshot (`"batch"` | `"serve"`), recorded in the
    /// manifest for provenance.
    source: &'static str,
    seq: u64,
    buf: Vec<u8>,
}

impl SnapshotSink {
    /// A sink over `dir` with the given retention and provenance tag.
    /// The sink owns its path and knob copy so a long-lived service can
    /// hold one for its whole run.
    pub fn new(dir: &Path, cfg: &BatchConfig, keep: usize, source: &'static str) -> Result<Self> {
        // Continue numbering after any snapshots a previous run left. A
        // missing directory means sequence 0, but a *real* listing error
        // (permissions, I/O) must propagate: silently restarting at
        // `snap_000000` would clobber retention.
        let seq = list_rotated(dir)?.last().map_or(0, |&(s, _)| s + 1);
        Ok(Self {
            dir: dir.to_path_buf(),
            cfg: cfg.clone(),
            keep,
            source,
            seq,
            buf: Vec::new(),
        })
    }

    /// The root directory this sink writes under.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Persist one snapshot under the sink's retention policy. On `Ok`
    /// the snapshot is durable (fsynced through the commit point).
    pub fn persist(&mut self, snap: &[JobCheckpoint]) -> Result<()> {
        let t0 = telemetry::enabled().then(Instant::now);
        let bytes0 = telemetry::counter(Counter::SnapshotBytes);
        let fsyncs0 = telemetry::counter(Counter::SnapshotFsyncs);
        let result = self.persist_inner(snap);
        // Store I/O runs on the session thread in program order, so the
        // lifetime-counter deltas are this snapshot's own cost
        // (saturating: parallel tests share the process-global registry).
        let bytes = telemetry::counter(Counter::SnapshotBytes).saturating_sub(bytes0);
        let fsyncs = telemetry::counter(Counter::SnapshotFsyncs).saturating_sub(fsyncs0);
        match &result {
            Ok(()) => {
                telemetry::bump(Counter::Snapshots);
                telemetry::record(Series::SnapshotBytesPer, bytes);
                telemetry::record(Series::SnapshotFsyncsPer, fsyncs);
                if let Some(t0) = t0 {
                    telemetry::record(Series::SnapshotPersistNs, t0.elapsed().as_nanos() as u64);
                }
                telemetry::mark_snapshot_now();
                telemetry::trace(TraceKind::PersistOk, snap.len() as u64, bytes);
            }
            Err(_) => {
                telemetry::bump(Counter::SnapshotFailures);
                telemetry::trace(TraceKind::PersistFail, snap.len() as u64, 0);
            }
        }
        result
    }

    fn persist_inner(&mut self, snap: &[JobCheckpoint]) -> Result<()> {
        if self.keep <= 1 {
            return write_snapshot(
                &self.dir,
                &self.cfg,
                self.keep,
                self.source,
                snap,
                &mut self.buf,
            );
        }
        let target = self.dir.join(format!("snap_{:06}", self.seq));
        write_snapshot(
            &target,
            &self.cfg,
            self.keep,
            self.source,
            snap,
            &mut self.buf,
        )?;
        // Make the new snap_<seq>/ entry itself durable in the root.
        io::io()
            .fsync_dir(&self.dir)
            .with_context(|| format!("fsyncing snapshot root {}", self.dir.display()))?;
        self.seq += 1;
        // Prune to the latest `keep` rotated snapshots. The new snapshot
        // is already durable at this point, so a prune failure must NOT
        // turn a completed persist into an error — report it loudly and
        // retry naturally on the next persist.
        match list_rotated(&self.dir) {
            Ok(existing) => {
                for (_, path) in existing.iter().rev().skip(self.keep) {
                    if let Err(e) = std::fs::remove_dir_all(path) {
                        eprintln!(
                            "cupso: warning: snapshot persisted, but pruning old {} failed: {e}",
                            path.display()
                        );
                    }
                }
            }
            Err(e) => eprintln!(
                "cupso: warning: snapshot persisted, but listing {} for pruning failed: {e:#}",
                self.dir.display()
            ),
        }
        Ok(())
    }
}

/// Numbered `snap_<seq>/` subdirectories holding a manifest, ascending.
/// A directory that does not exist yet lists as empty; every other error
/// (permissions, I/O) propagates.
pub fn list_rotated(dir: &Path) -> Result<Vec<(u64, PathBuf)>> {
    let entries = match std::fs::read_dir(dir) {
        Ok(entries) => entries,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(Vec::new()),
        Err(e) => return Err(e).with_context(|| format!("listing {}", dir.display())),
    };
    let mut found = Vec::new();
    for entry in entries {
        let path = entry?.path();
        let Some(name) = path.file_name().and_then(|n| n.to_str()) else {
            continue;
        };
        if let Some(seq) = name.strip_prefix("snap_").and_then(|s| s.parse::<u64>().ok()) {
            if path.join("manifest.toml").exists() {
                found.push((seq, path));
            }
        }
    }
    found.sort_unstable_by_key(|&(s, _)| s);
    Ok(found)
}

/// The snapshot directory `cupso resume` should read: the directory
/// itself when it holds a manifest (keep = 1 layout), otherwise the
/// newest rotated `snap_<seq>/` subdirectory.
pub fn resolve_snapshot_dir(dir: &Path) -> Result<PathBuf> {
    if dir.join("manifest.toml").exists() {
        return Ok(dir.to_path_buf());
    }
    let mut rotated = list_rotated(dir)?;
    rotated.pop().map(|(_, p)| p).with_context(|| {
        format!(
            "no manifest.toml or snap_*/ snapshot under {}",
            dir.display()
        )
    })
}

/// Persist a batch snapshot: one `job_<i>.ckpt` per job plus a
/// `manifest.toml` recording the scheduler knobs, provenance and job
/// count. `buf` is the reusable encode buffer.
///
/// Ordering is the crash-safety contract: every job checkpoint is
/// durable (written + fsynced + published) *before* the manifest, and
/// the manifest is published last as the commit point — a crash at any
/// interior step leaves either the previous complete snapshot or an
/// uncommitted partial one, never a committed-but-torn one.
pub fn write_snapshot(
    dir: &Path,
    cfg: &BatchConfig,
    keep: usize,
    source: &str,
    snap: &[JobCheckpoint],
    buf: &mut Vec<u8>,
) -> Result<()> {
    io::io()
        .persist_point()
        .context("snapshot persist point")?;
    std::fs::create_dir_all(dir)
        .with_context(|| format!("creating checkpoint dir {}", dir.display()))?;
    for (i, job) in snap.iter().enumerate() {
        job.write_file_with(&dir.join(format!("job_{i}.ckpt")), buf)?;
    }
    let manifest = format!(
        "# cupso batch snapshot — continue with `cupso resume {}`\n\
         version = {}\n\
         source = \"{}\"\n\
         workers = {}\n\
         policy = \"{}\"\n\
         streams = {}\n\
         batch_steps = {}\n\
         preempt_quantum = {}\n\
         pack = {}\n\
         pack_min = {}\n\
         pack_max = {}\n\
         quota_jobs = {}\n\
         quota_steps = {}\n\
         checkpoint_every = {}\n\
         keep = {}\n\
         jobs = {}\n\
         complete = true\n",
        dir.display(),
        super::VERSION,
        source,
        cfg.workers,
        cfg.policy,
        cfg.streams,
        cfg.batch_steps,
        cfg.preempt_quantum,
        cfg.pack,
        cfg.pack_min,
        cfg.pack_max,
        cfg.quota_jobs,
        cfg.quota_steps,
        cfg.checkpoint_every,
        keep,
        snap.len()
    );
    // Durable + atomic like the job checkpoints, and written LAST: the
    // manifest is the commit point, so it must only become visible once
    // every job file above is already durable.
    write_atomic(&dir.join("manifest.toml"), manifest.as_bytes())
        .with_context(|| format!("publishing manifest in {}", dir.display()))?;
    Ok(())
}

/// Load a batch snapshot directory: scheduler knobs (as a job-less
/// [`BatchConfig`]) plus the retention count and every job checkpoint in
/// manifest order. Strict: any torn or missing job checkpoint is an
/// `Err` — resumable-with-losses callers want [`load_snapshot`].
pub fn read_snapshot(dir: &Path) -> Result<(BatchConfig, usize, Vec<JobCheckpoint>)> {
    let (knobs, keep, jobs, quarantined) = read_snapshot_lenient(dir)?;
    if let Some(q) = quarantined.first() {
        bail!(
            "snapshot {}: job checkpoint {} unreadable ({} of {} damaged): {}",
            dir.display(),
            q.path.display(),
            quarantined.len(),
            jobs.len() + quarantined.len(),
            q.error
        );
    }
    Ok((knobs, keep, jobs))
}

/// Parse a snapshot manifest: scheduler knobs, retention count and the
/// number of job checkpoints the snapshot claims to hold.
fn read_manifest(dir: &Path) -> Result<(BatchConfig, usize, usize)> {
    let manifest_path = dir.join("manifest.toml");
    let text = std::fs::read_to_string(&manifest_path)
        .with_context(|| format!("reading {}", manifest_path.display()))?;
    let doc: BTreeMap<String, TomlValue> = parse_toml(&text)?.into_iter().collect();
    // Loud on anything out of range — a hand-edited or torn manifest must
    // never wrap into a huge thread count or silently clamp a knob. The
    // caps are per-key: resource-shaped knobs (workers/streams/jobs) get
    // tight plausibility bounds, step-denominated knobs only reject
    // negatives (the writer recorded whatever the user asked for).
    let get_uint = |key: &str, max: u64| -> Result<u64> {
        let v = doc
            .get(key)
            .with_context(|| format!("manifest: missing key {key:?}"))?
            .as_int(key)?;
        if v < 0 || v as u64 > max {
            bail!("manifest: {key} = {v} out of range");
        }
        Ok(v as u64)
    };
    let version = get_uint("version", u32::MAX as u64)?;
    if version != super::VERSION as u64 {
        bail!(
            "manifest: snapshot version {version} unsupported (this build reads {})",
            super::VERSION
        );
    }
    let streams = get_uint("streams", 1_000_000)?;
    let batch_steps = get_uint("batch_steps", u64::MAX)?;
    if streams == 0 || batch_steps == 0 {
        bail!("manifest: streams and batch_steps must be >= 1");
    }
    let mut knobs = BatchConfig {
        workers: get_uint("workers", 1_000_000)? as usize,
        policy: doc
            .get("policy")
            .context("manifest: missing key \"policy\"")?
            .as_str("policy")?
            .to_string(),
        streams: streams as usize,
        batch_steps,
        preempt_quantum: get_uint("preempt_quantum", u64::MAX)?,
        // Optional for compatibility with pre-packing snapshots.
        pack: match doc.get("pack") {
            Some(v) => v.as_bool("pack")?,
            None => false,
        },
        pack_min: match doc.get("pack_min") {
            Some(v) => {
                let n = v.as_int("pack_min")?;
                if !(2..=100_000).contains(&n) {
                    bail!("manifest: pack_min = {n} out of range");
                }
                n as usize
            }
            None => 2,
        },
        pack_max: match doc.get("pack_max") {
            Some(v) => {
                let n = v.as_int("pack_max")?;
                if !(0..=100_000).contains(&n) {
                    bail!("manifest: pack_max = {n} out of range");
                }
                n as usize
            }
            None => 0,
        },
        // Optional for compatibility with pre-quota snapshots.
        quota_jobs: match doc.get("quota_jobs") {
            Some(v) => {
                let n = v.as_int("quota_jobs")?;
                if !(0..=1_000_000).contains(&n) {
                    bail!("manifest: quota_jobs = {n} out of range");
                }
                n as usize
            }
            None => 0,
        },
        quota_steps: match doc.get("quota_steps") {
            Some(v) => {
                let n = v.as_int("quota_steps")?;
                if n < 0 {
                    bail!("manifest: quota_steps = {n} out of range");
                }
                n as u64
            }
            None => 0,
        },
        // Optional for compatibility with pre-crash-safety snapshots.
        checkpoint_every: match doc.get("checkpoint_every") {
            Some(v) => {
                let n = v.as_int("checkpoint_every")?;
                if n < 0 {
                    bail!("manifest: checkpoint_every = {n} out of range");
                }
                n as u64
            }
            None => 0,
        },
        checkpoint_keep: 1, // overwritten with `keep` below
        // Runtime observability knobs are not snapshot semantics — a
        // resumed session decides its own; the manifest never records them.
        telemetry: true,
        trace_dump: None,
        jobs: Vec::new(),
    };
    // Optional for compatibility with pre-rotation snapshots.
    let keep = match doc.get("keep") {
        Some(v) => {
            let k = v.as_int("keep")?;
            if !(1..=1_000_000).contains(&k) {
                bail!("manifest: keep = {k} out of range");
            }
            k as usize
        }
        None => 1,
    };
    knobs.checkpoint_keep = keep;
    let job_count = get_uint("jobs", 100_000)?;
    // The trailing commit marker: `jobs = N` alone is not enough, because
    // a manifest truncated mid-number (`jobs = 12` cut to `jobs = 1`)
    // still parses and would silently resume a subset. `complete = true`
    // is written last, so any truncation removes or damages it.
    match doc.get("complete") {
        Some(v) if v.as_bool("complete")? => {}
        Some(_) => bail!(
            "manifest {}: complete = false — torn or hand-damaged manifest",
            manifest_path.display()
        ),
        None => bail!(
            "manifest {}: missing trailing commit marker `complete` — \
             manifest torn or truncated",
            manifest_path.display()
        ),
    }
    Ok((knobs, keep, job_count as usize))
}

/// One job checkpoint that could not be read back from a snapshot.
#[derive(Debug)]
pub struct QuarantinedJob {
    /// The job's index in the snapshot (its `job_<i>.ckpt` slot).
    pub index: usize,
    pub path: PathBuf,
    /// The decode/read error, rendered with its full context chain.
    pub error: String,
}

/// Read a snapshot directory leniently: the manifest must parse (it is
/// the commit point — if it is damaged the directory is not a snapshot),
/// but torn or missing `job_<i>.ckpt` files are *quarantined* with a
/// per-job record instead of failing the load. Valid jobs keep their
/// manifest order.
pub fn read_snapshot_lenient(
    dir: &Path,
) -> Result<(BatchConfig, usize, Vec<JobCheckpoint>, Vec<QuarantinedJob>)> {
    let (knobs, keep, job_count) = read_manifest(dir)?;
    let mut ckpts = Vec::with_capacity(job_count);
    let mut quarantined = Vec::new();
    for i in 0..job_count {
        let path = dir.join(format!("job_{i}.ckpt"));
        match JobCheckpoint::read_file(&path) {
            Ok(ckpt) => ckpts.push(ckpt),
            Err(e) => quarantined.push(QuarantinedJob {
                index: i,
                path,
                error: format!("{e:#}"),
            }),
        }
    }
    Ok((knobs, keep, ckpts, quarantined))
}

/// A snapshot as recovered from disk, with the full damage report.
#[derive(Debug)]
pub struct LoadedSnapshot {
    /// The concrete snapshot directory used (the root itself for the
    /// flat layout, a `snap_<seq>/` subdirectory for the rotated one).
    pub dir: PathBuf,
    pub knobs: BatchConfig,
    pub keep: usize,
    pub jobs: Vec<JobCheckpoint>,
    /// Job checkpoints in `dir` that could not be read.
    pub quarantined: Vec<QuarantinedJob>,
    /// Newer rotated snapshots that were skipped as damaged, with why.
    pub skipped: Vec<(PathBuf, String)>,
}

impl LoadedSnapshot {
    /// Whether recovery was lossless: nothing quarantined, nothing skipped.
    pub fn is_clean(&self) -> bool {
        self.quarantined.is_empty() && self.skipped.is_empty()
    }

    /// Print the loud per-job damage report to stderr. Callers that
    /// resume from a dirty snapshot MUST emit this (or an equivalent) —
    /// silently resuming a subset would hide lost work.
    pub fn report(&self) {
        for (path, why) in &self.skipped {
            eprintln!(
                "cupso: warning: skipping damaged snapshot {}: {why}",
                path.display()
            );
        }
        for q in &self.quarantined {
            eprintln!(
                "cupso: warning: quarantined job {} ({}): {}",
                q.index,
                q.path.display(),
                q.error
            );
        }
        if !self.quarantined.is_empty() {
            eprintln!(
                "cupso: warning: resuming {} of {} jobs from {} — {} quarantined",
                self.jobs.len(),
                self.jobs.len() + self.quarantined.len(),
                self.dir.display(),
                self.quarantined.len()
            );
        }
    }
}

/// Whether `root` holds at least one **committed** snapshot: a flat
/// manifest, or a rotated `snap_<seq>/` entry (which [`list_rotated`]
/// only counts once its manifest exists). The manifest is the commit
/// point, so a crash mid-snapshot leaves nothing committed — callers
/// treat that as a cold start, not an error.
pub fn snapshot_present(root: &Path) -> bool {
    root.join("manifest.toml").is_file()
        || list_rotated(root).map_or(false, |v| !v.is_empty())
}

/// Recover the best available snapshot under `root`.
///
/// Flat layout (a manifest directly in `root`): load it leniently. The
/// rotated layout scans `snap_<seq>/` from newest to oldest and returns
/// the newest **fully-valid** snapshot; if every candidate is damaged,
/// it falls back to the newest one whose manifest still parses, with its
/// unreadable jobs quarantined. Only when no candidate has a readable
/// manifest does the load fail.
pub fn load_snapshot(root: &Path) -> Result<LoadedSnapshot> {
    if root.join("manifest.toml").exists() {
        let (knobs, keep, jobs, quarantined) = read_snapshot_lenient(root)?;
        return Ok(LoadedSnapshot {
            dir: root.to_path_buf(),
            knobs,
            keep,
            jobs,
            quarantined,
            skipped: Vec::new(),
        });
    }
    let rotated = list_rotated(root)?;
    if rotated.is_empty() {
        bail!(
            "no manifest.toml or snap_*/ snapshot under {}",
            root.display()
        );
    }
    let mut skipped: Vec<(PathBuf, String)> = Vec::new();
    let mut fallback: Option<LoadedSnapshot> = None;
    for (_, path) in rotated.iter().rev() {
        match read_snapshot_lenient(path) {
            Ok((knobs, keep, jobs, quarantined)) => {
                if quarantined.is_empty() {
                    return Ok(LoadedSnapshot {
                        dir: path.clone(),
                        knobs,
                        keep,
                        jobs,
                        quarantined,
                        skipped,
                    });
                }
                let total = jobs.len() + quarantined.len();
                if fallback.is_none() {
                    fallback = Some(LoadedSnapshot {
                        dir: path.clone(),
                        knobs,
                        keep,
                        jobs,
                        quarantined,
                        skipped: Vec::new(),
                    });
                }
                skipped.push((
                    path.clone(),
                    format!("{} of {total} job checkpoint(s) torn or missing", total - jobs.len()),
                ));
            }
            Err(e) => skipped.push((path.clone(), format!("{e:#}"))),
        }
    }
    if let Some(mut best) = fallback {
        // `skipped` lists everything we passed over, including the
        // fallback itself — keep only snapshots newer than it.
        best.skipped = skipped
            .into_iter()
            .take_while(|(p, _)| *p != best.dir)
            .collect();
        return Ok(best);
    }
    bail!(
        "no loadable snapshot under {}: all {} rotated candidate(s) damaged \
         (newest: {})",
        root.display(),
        skipped.len(),
        skipped
            .first()
            .map(|(p, why)| format!("{} — {why}", p.display()))
            .unwrap_or_default()
    )
}
