//! The durable store-I/O seam: every byte the checkpoint store puts on
//! disk goes through a process-global [`StoreIo`], so durability policy
//! lives in one place and tests can inject faults deterministically.
//!
//! ## Durability discipline
//!
//! [`write_atomic`] is the only way checkpoint bytes reach disk:
//!
//! 1. write the payload to `<path>.tmp`,
//! 2. **fsync the temp file** — data must be durable before it becomes
//!    reachable,
//! 3. rename it over `path` (atomic publish),
//! 4. **fsync the parent directory** — the rename itself must be durable,
//!    or a power loss can forget the publish (or worse, on journaled
//!    filesystems without `auto_da_alloc`, publish a zero-length file).
//!
//! The snapshot store layers a commit-point ordering on top: every
//! `job_<i>.ckpt` is written (durably) *before* `manifest.toml`, so the
//! manifest's existence certifies a complete snapshot.
//!
//! ## Fault injection
//!
//! [`FaultPlan`] is a deterministic schedule of injected failures,
//! parsed from a tiny grammar (also accepted via the `CUPSO_FAULT_PLAN`
//! environment variable by the `cupso` binary):
//!
//! ```text
//! plan      := directive (';' directive)*
//! directive := op '@' nth ['=' action]
//! op        := 'write' | 'fsync' | 'rename' | 'persist'
//! nth       := 1-based index of that op, counted process-wide
//! action    := 'eio' (default) | 'enospc' | 'truncate:<k>' | 'abort'
//! ```
//!
//! `write@3=truncate:17` makes the 3rd write put only its first 17 bytes
//! on disk and report success (a lost tail, as after power loss on a
//! non-fsyncing store); `persist@2=abort` aborts the process at the 2nd
//! snapshot persist point (a crash mid-persist); `fsync@1` fails the
//! first fsync (file or directory) with EIO. Counting is deterministic
//! because all store I/O happens on the session thread in program order.

use crate::telemetry::{self, Counter, TraceKind};
use anyhow::{Context, Result};
use std::io;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, OnceLock, RwLock};

/// The primitive operations the checkpoint store performs against the
/// filesystem. The default implementation is [`RealIo`]; tests install a
/// [`FaultyIo`] to inject failures at exact points.
pub trait StoreIo: Send + Sync {
    /// Create-or-truncate `path` and write `bytes` to it.
    fn write(&self, path: &Path, bytes: &[u8]) -> io::Result<()>;
    /// Flush `path`'s data and metadata to stable storage.
    fn fsync_file(&self, path: &Path) -> io::Result<()>;
    /// Atomically rename `from` to `to`.
    fn rename(&self, from: &Path, to: &Path) -> io::Result<()>;
    /// Flush the directory entry table of `dir` to stable storage.
    fn fsync_dir(&self, dir: &Path) -> io::Result<()>;
    /// Called once at the top of every snapshot persist; the fault
    /// plan's `persist` op hooks here. Real I/O does nothing.
    fn persist_point(&self) -> io::Result<()> {
        Ok(())
    }
}

/// Production I/O: `std::fs` with real fsyncs.
pub struct RealIo;

impl StoreIo for RealIo {
    fn write(&self, path: &Path, bytes: &[u8]) -> io::Result<()> {
        std::fs::write(path, bytes)
    }

    fn fsync_file(&self, path: &Path) -> io::Result<()> {
        std::fs::File::open(path)?.sync_all()
    }

    fn rename(&self, from: &Path, to: &Path) -> io::Result<()> {
        std::fs::rename(from, to)
    }

    fn fsync_dir(&self, dir: &Path) -> io::Result<()> {
        // On Linux a directory opened read-only accepts fsync(2); this is
        // the only portable way to make a rename durable.
        std::fs::File::open(dir)?.sync_all()
    }
}

fn slot() -> &'static RwLock<Arc<dyn StoreIo>> {
    static SLOT: OnceLock<RwLock<Arc<dyn StoreIo>>> = OnceLock::new();
    SLOT.get_or_init(|| RwLock::new(Arc::new(RealIo)))
}

/// The currently installed store I/O (an `Arc` clone — no allocation).
pub fn io() -> Arc<dyn StoreIo> {
    slot().read().unwrap().clone()
}

/// Install a store I/O implementation process-wide. Tests that install a
/// [`FaultyIo`] must serialize with each other and [`reset`] when done.
pub fn install(io: Arc<dyn StoreIo>) {
    *slot().write().unwrap() = io;
}

/// Restore the default [`RealIo`].
pub fn reset() {
    install(Arc::new(RealIo));
}

/// Durable atomic write: temp + fsync + rename + parent-dir fsync (see
/// the module docs for why each step exists). On return the bytes are
/// durable under `path`; a crash at any interior point leaves either the
/// old content or nothing — never a torn file.
pub fn write_atomic(path: &Path, bytes: &[u8]) -> Result<()> {
    let io = io();
    let tmp = path.with_extension("tmp");
    io.write(&tmp, bytes)
        .with_context(|| format!("writing checkpoint {}", tmp.display()))?;
    telemetry::add(Counter::SnapshotBytes, bytes.len() as u64);
    io.fsync_file(&tmp)
        .with_context(|| format!("fsyncing checkpoint {}", tmp.display()))?;
    telemetry::bump(Counter::SnapshotFsyncs);
    io.rename(&tmp, path)
        .with_context(|| format!("publishing checkpoint {}", path.display()))?;
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            io.fsync_dir(parent)
                .with_context(|| format!("fsyncing directory {}", parent.display()))?;
            telemetry::bump(Counter::SnapshotFsyncs);
        }
    }
    Ok(())
}

/// Which store operation a fault directive targets.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum FaultOp {
    /// A payload write (temp-file contents).
    Write,
    /// Any fsync — file or directory; they share one counter.
    Fsync,
    /// The atomic publish rename.
    Rename,
    /// A snapshot persist point (top of `store::write_snapshot`).
    Persist,
}

impl FaultOp {
    /// Position in [`FaultyIo::counts`] order: write, fsync, rename,
    /// persist.
    pub fn index(self) -> usize {
        match self {
            FaultOp::Write => 0,
            FaultOp::Fsync => 1,
            FaultOp::Rename => 2,
            FaultOp::Persist => 3,
        }
    }
}

/// What happens when a directive fires.
#[derive(Clone, Copy, Debug)]
pub enum FaultAction {
    /// Fail with `EIO` (I/O error).
    Eio,
    /// Fail with `ENOSPC` (no space left on device).
    Enospc,
    /// Writes only: put the first `k` bytes on disk, then report
    /// success — a silently lost tail.
    Truncate(usize),
    /// Abort the process — a crash at exactly this operation.
    Abort,
}

/// One injected failure: the `nth` occurrence of `op` (1-based,
/// process-wide) performs `action`.
#[derive(Clone, Copy, Debug)]
pub struct FaultDirective {
    pub op: FaultOp,
    pub nth: u64,
    pub action: FaultAction,
}

/// A deterministic schedule of injected store failures. See the module
/// docs for the grammar.
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    directives: Vec<FaultDirective>,
}

impl FaultPlan {
    /// One directive: the `nth` `op` performs `action`.
    pub fn single(op: FaultOp, nth: u64, action: FaultAction) -> Self {
        Self {
            directives: vec![FaultDirective { op, nth, action }],
        }
    }

    /// Parse the `op@nth[=action]` grammar (see module docs).
    pub fn parse(text: &str) -> Result<Self> {
        let mut directives = Vec::new();
        for part in text.split(';') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (opstr, rest) = part
                .split_once('@')
                .with_context(|| format!("fault directive {part:?}: expected op@nth[=action]"))?;
            let op = match opstr.trim() {
                "write" => FaultOp::Write,
                "fsync" => FaultOp::Fsync,
                "rename" => FaultOp::Rename,
                "persist" => FaultOp::Persist,
                other => anyhow::bail!(
                    "fault directive {part:?}: unknown op {other:?} \
                     (expected write|fsync|rename|persist)"
                ),
            };
            let (nthstr, actionstr) = match rest.split_once('=') {
                Some((n, a)) => (n, Some(a)),
                None => (rest, None),
            };
            let nth: u64 = nthstr
                .trim()
                .parse()
                .with_context(|| format!("fault directive {part:?}: bad index {nthstr:?}"))?;
            if nth == 0 {
                anyhow::bail!("fault directive {part:?}: indices are 1-based");
            }
            let action = match actionstr.map(str::trim) {
                None | Some("eio") => FaultAction::Eio,
                Some("enospc") => FaultAction::Enospc,
                Some("abort") => FaultAction::Abort,
                Some(a) => {
                    if let Some(k) = a.strip_prefix("truncate:") {
                        let k: usize = k.parse().with_context(|| {
                            format!("fault directive {part:?}: bad truncate length {k:?}")
                        })?;
                        if op != FaultOp::Write {
                            anyhow::bail!(
                                "fault directive {part:?}: truncate only applies to write"
                            );
                        }
                        FaultAction::Truncate(k)
                    } else {
                        anyhow::bail!(
                            "fault directive {part:?}: unknown action {a:?} \
                             (expected eio|enospc|truncate:<k>|abort)"
                        );
                    }
                }
            };
            directives.push(FaultDirective { op, nth, action });
        }
        Ok(Self { directives })
    }

    /// A pseudo-random single-fault plan derived from `seed`: picks one
    /// of the first `ops_per_kind` occurrences of write/fsync/rename and
    /// an EIO/ENOSPC/truncate action. Used by the durability tier to add
    /// seeded coverage on top of its exhaustive sweeps; same seed, same
    /// plan.
    pub fn seeded(seed: u64, ops_per_kind: u64) -> Self {
        // splitmix64 — tiny, deterministic, dependency-free.
        let mut s = seed;
        let mut next = move || {
            s = s.wrapping_add(0x9e37_79b9_7f4a_7c15);
            let mut z = s;
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
            z ^ (z >> 31)
        };
        let op = match next() % 3 {
            0 => FaultOp::Write,
            1 => FaultOp::Fsync,
            _ => FaultOp::Rename,
        };
        let nth = next() % ops_per_kind.max(1) + 1;
        let action = match next() % 3 {
            0 => FaultAction::Eio,
            1 => FaultAction::Enospc,
            _ if op == FaultOp::Write => FaultAction::Truncate((next() % 64) as usize),
            _ => FaultAction::Eio,
        };
        Self::single(op, nth, action)
    }

    /// The plan from `CUPSO_FAULT_PLAN`, if set. `Some(Err(..))` means
    /// the variable was set but unparsable — callers must fail loudly,
    /// never ignore a typo'd plan.
    pub fn from_env() -> Option<Result<Self>> {
        std::env::var("CUPSO_FAULT_PLAN")
            .ok()
            .map(|text| Self::parse(&text))
    }

    /// Number of directives in the plan.
    pub fn len(&self) -> usize {
        self.directives.len()
    }

    /// Whether the plan injects nothing (counts still tick).
    pub fn is_empty(&self) -> bool {
        self.directives.is_empty()
    }

    fn lookup(&self, op: FaultOp, n: u64) -> Option<FaultAction> {
        self.directives
            .iter()
            .find(|d| d.op == op && d.nth == n)
            .map(|d| d.action)
    }
}

/// The registry counter tracking fired directives against `op`.
fn fault_counter(op: FaultOp) -> Counter {
    match op {
        FaultOp::Write => Counter::FaultsFiredWrite,
        FaultOp::Fsync => Counter::FaultsFiredFsync,
        FaultOp::Rename => Counter::FaultsFiredRename,
        FaultOp::Persist => Counter::FaultsFiredPersist,
    }
}

fn injected(kind: &str, n: u64, raw_os: i32, what: &str) -> io::Error {
    eprintln!("cupso: fault injection: {kind} #{n} -> injected {what}");
    io::Error::from_raw_os_error(raw_os)
}

/// A [`StoreIo`] that executes a [`FaultPlan`] on top of [`RealIo`],
/// counting every operation process-wide. With an empty plan it is a
/// pure pass-through counter (useful for sizing exhaustive sweeps).
pub struct FaultyIo {
    inner: RealIo,
    plan: FaultPlan,
    counts: [AtomicU64; 4],
}

impl FaultyIo {
    pub fn new(plan: FaultPlan) -> Self {
        Self {
            inner: RealIo,
            plan,
            counts: Default::default(),
        }
    }

    /// Operation counts so far: `[writes, fsyncs, renames, persists]`.
    pub fn counts(&self) -> [u64; 4] {
        [
            self.counts[0].load(Ordering::Relaxed),
            self.counts[1].load(Ordering::Relaxed),
            self.counts[2].load(Ordering::Relaxed),
            self.counts[3].load(Ordering::Relaxed),
        ]
    }

    /// Count one `op`; `Err` or `Ok(Some(k))` (truncate) when a
    /// directive fires, `Ok(None)` to proceed normally.
    fn arm(&self, op: FaultOp) -> io::Result<Option<usize>> {
        let n = self.counts[op.index()].fetch_add(1, Ordering::Relaxed) + 1;
        let kind = match op {
            FaultOp::Write => "write",
            FaultOp::Fsync => "fsync",
            FaultOp::Rename => "rename",
            FaultOp::Persist => "persist",
        };
        let Some(action) = self.plan.lookup(op, n) else {
            return Ok(None);
        };
        // Fault-hit accounting: the durability tier asserts exactly-N
        // directives fired, so a plan targeting an op that never occurs
        // is a loud test failure instead of a silent no-op.
        telemetry::bump(fault_counter(op));
        telemetry::trace(TraceKind::FaultFired, op.index() as u64, n);
        match action {
            FaultAction::Eio => Err(injected(kind, n, 5, "EIO")),
            FaultAction::Enospc => Err(injected(kind, n, 28, "ENOSPC")),
            FaultAction::Truncate(k) => Ok(Some(k)),
            FaultAction::Abort => {
                eprintln!("cupso: fault injection: {kind} #{n} -> aborting process");
                std::process::abort();
            }
        }
    }
}

impl StoreIo for FaultyIo {
    fn write(&self, path: &Path, bytes: &[u8]) -> io::Result<()> {
        match self.arm(FaultOp::Write)? {
            // Torn write: only the first k bytes land, reported as success.
            Some(k) => self.inner.write(path, &bytes[..k.min(bytes.len())]),
            None => self.inner.write(path, bytes),
        }
    }

    fn fsync_file(&self, path: &Path) -> io::Result<()> {
        self.arm(FaultOp::Fsync)?;
        self.inner.fsync_file(path)
    }

    fn rename(&self, from: &Path, to: &Path) -> io::Result<()> {
        self.arm(FaultOp::Rename)?;
        self.inner.rename(from, to)
    }

    fn fsync_dir(&self, dir: &Path) -> io::Result<()> {
        self.arm(FaultOp::Fsync)?;
        self.inner.fsync_dir(dir)
    }

    fn persist_point(&self) -> io::Result<()> {
        self.arm(FaultOp::Persist)?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_grammar_round_trips() {
        let plan =
            FaultPlan::parse("write@3=truncate:17; persist@2=abort;fsync@1 ; rename@4=enospc")
                .unwrap();
        assert_eq!(plan.directives.len(), 4);
        assert!(matches!(
            plan.lookup(FaultOp::Write, 3),
            Some(FaultAction::Truncate(17))
        ));
        assert!(matches!(
            plan.lookup(FaultOp::Persist, 2),
            Some(FaultAction::Abort)
        ));
        assert!(matches!(plan.lookup(FaultOp::Fsync, 1), Some(FaultAction::Eio)));
        assert!(matches!(
            plan.lookup(FaultOp::Rename, 4),
            Some(FaultAction::Enospc)
        ));
        assert!(plan.lookup(FaultOp::Write, 2).is_none());
    }

    #[test]
    fn plan_grammar_rejects_garbage_loudly() {
        for bad in [
            "write",             // no index
            "write@0",           // 1-based
            "write@x",           // bad index
            "chmod@1",           // unknown op
            "write@1=explode",   // unknown action
            "fsync@1=truncate:4", // truncate only on write
            "write@1=truncate:x", // bad length
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn empty_plan_counts_without_failing() {
        let dir = std::env::temp_dir().join(format!("cupso_io_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let io = FaultyIo::new(FaultPlan::default());
        let p = dir.join("a.bin");
        io.write(&p, b"hello").unwrap();
        io.fsync_file(&p).unwrap();
        let q = dir.join("b.bin");
        io.rename(&p, &q).unwrap();
        io.fsync_dir(&dir).unwrap();
        io.persist_point().unwrap();
        assert_eq!(io.counts(), [1, 2, 1, 1]);
        assert_eq!(std::fs::read(&q).unwrap(), b"hello");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn eio_and_truncate_fire_at_exact_indices() {
        let dir = std::env::temp_dir().join(format!("cupso_io_fault_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let io = FaultyIo::new(FaultPlan::parse("write@2=truncate:3; fsync@1=enospc").unwrap());
        let p = dir.join("a.bin");
        io.write(&p, b"first").unwrap(); // write #1: clean
        io.write(&p, b"second").unwrap(); // write #2: torn at 3 bytes
        assert_eq!(std::fs::read(&p).unwrap(), b"sec");
        let err = io.fsync_file(&p).unwrap_err();
        assert_eq!(err.raw_os_error(), Some(28));
        io.fsync_file(&p).unwrap(); // fsync #2: clean
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn seeded_plans_are_deterministic() {
        for seed in 0..16 {
            let a = format!("{:?}", FaultPlan::seeded(seed, 40));
            let b = format!("{:?}", FaultPlan::seeded(seed, 40));
            assert_eq!(a, b);
        }
    }
}
