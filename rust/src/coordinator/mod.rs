//! L3 coordinator — the paper's coordination ideas lifted to the
//! process level over PJRT executions.
//!
//! The swarm is split into `shards` independent sub-swarms ("islands"),
//! each driven by an AOT-compiled chunk executable (K iterations per
//! call). Two schedulers mirror the paper's two synchronization designs:
//!
//! * [`SyncScheduler`] — the *reduction-style* structure: every round all
//!   shards execute one chunk, then a **barrier**, then the global best is
//!   reduced across shards and re-broadcast. Cross-shard information moves
//!   only at round boundaries, and stragglers stall everyone — exactly the
//!   inter-kernel synchronization cost of §3.2.
//! * [`AsyncScheduler`] — the *queue-lock-style* structure: shards
//!   free-run; after each chunk a shard merges with the global best behind
//!   a CAS spin lock ([`crate::exec::SpinLock`]), no barrier anywhere —
//!   Algorithm 3 lifted from thread blocks to OS threads over PJRT calls.
//!
//! Both schedulers preserve the monotone-gbest invariant (property-tested
//! in `rust/tests/coordinator_integration.rs`).

use crate::exec::SpinLock;
use crate::fitness::{by_name, Objective};
use crate::pso::PsoParams;
use crate::runtime::{ChunkExec, XlaRuntime, XlaSwarmState};
use anyhow::{bail, Context, Result};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Coordinator configuration.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// Aggregation variant of the artifacts to load.
    pub variant: String,
    /// Particles **per shard**.
    pub shard_particles: usize,
    /// Problem dimensionality.
    pub dim: usize,
    /// Number of independent shards (each gets an OS thread).
    pub shards: usize,
    /// Total iterations each shard runs (rounded up to whole chunks).
    pub iters: u64,
    /// Master seed.
    pub seed: u64,
}

impl CoordinatorConfig {
    /// Sensible defaults for the e2e example: queue variant, 4 shards.
    pub fn new(variant: &str, shard_particles: usize, dim: usize, iters: u64) -> Self {
        Self {
            variant: variant.to_string(),
            shard_particles,
            dim,
            shards: 4,
            iters,
            seed: 42,
        }
    }
}

/// Result of a coordinated run.
#[derive(Debug, Clone)]
pub struct CoordOutput {
    /// Best fitness across all shards.
    pub gbest_fit: f64,
    /// Best position.
    pub gbest_pos: Vec<f64>,
    /// Iterations executed per shard.
    pub iters_per_shard: u64,
    /// Total PJRT chunk executions.
    pub chunk_calls: u64,
    /// Global-best merges that improved the shared value.
    pub merges: u64,
    /// Per-shard final gbest (dispersion diagnostics).
    pub shard_fits: Vec<f64>,
    /// Concatenated per-round global-best samples (round, gbest).
    pub history: Vec<(u64, f64)>,
}

/// The shared cross-shard best (fit, pos) behind the Algorithm-3 lock.
struct SharedBest {
    inner: SpinLock<(f64, Vec<f64>)>,
    merges: AtomicU64,
}

impl SharedBest {
    fn new(objective: Objective, dim: usize) -> Self {
        Self {
            inner: SpinLock::new((objective.worst(), vec![0.0; dim])),
            merges: AtomicU64::new(0),
        }
    }

    /// Two-way merge: publish the shard's best if better, and pull the
    /// global one into the shard if the global is better.
    fn merge(&self, objective: Objective, state: &mut XlaSwarmState) {
        let mut g = self.inner.lock();
        if objective.better(state.gbest_fit, g.0) {
            g.0 = state.gbest_fit;
            g.1.copy_from_slice(&state.gbest_pos);
            self.merges.fetch_add(1, Ordering::Relaxed);
        } else if objective.better(g.0, state.gbest_fit) {
            state.adopt_gbest(objective, g.0, &g.1.clone());
        }
    }

    fn snapshot(&self) -> (f64, Vec<f64>) {
        let g = self.inner.lock();
        (g.0, g.1.clone())
    }
}

/// Shared plumbing for both schedulers.
struct ShardSet {
    exec: ChunkExec,
    states: Vec<XlaSwarmState>,
    objective: Objective,
    /// Kept for bound/diagnostic checks by future extensions.
    #[allow(dead_code)]
    params: PsoParams,
    rounds: u64,
}

fn prepare(rt: &XlaRuntime, cfg: &CoordinatorConfig) -> Result<ShardSet> {
    if cfg.shards == 0 {
        bail!("shards must be > 0");
    }
    let exec = rt
        .load_config(&cfg.variant, cfg.shard_particles, cfg.dim)
        .context("loading coordinator artifact")?;
    let meta = &exec.meta;
    let fitness = by_name(&meta.fitness)
        .with_context(|| format!("unknown fitness {} in manifest", meta.fitness))?;
    let objective = fitness.default_objective();
    let params = PsoParams {
        w: meta.w,
        c1: meta.c1,
        c2: meta.c2,
        min_pos: meta.min_pos,
        max_pos: meta.max_pos,
        max_v: meta.max_v,
        max_iter: cfg.iters,
        n: cfg.shard_particles,
        dim: cfg.dim,
    };
    let states: Vec<XlaSwarmState> = (0..cfg.shards)
        .map(|s| XlaSwarmState::init(&params, fitness.as_ref(), objective, cfg.seed, s as u64))
        .collect();
    let rounds = cfg.iters.div_ceil(meta.iters);
    Ok(ShardSet {
        exec,
        states,
        objective,
        params,
        rounds,
    })
}

fn finish(set: ShardSet, shared: &SharedBest, chunk_calls: u64, history: Vec<(u64, f64)>) -> CoordOutput {
    let objective = set.objective;
    let (mut best_fit, mut best_pos) = shared.snapshot();
    let mut shard_fits = Vec::with_capacity(set.states.len());
    for st in &set.states {
        shard_fits.push(st.gbest_fit);
        if objective.better(st.gbest_fit, best_fit) {
            best_fit = st.gbest_fit;
            best_pos = st.gbest_pos.clone();
        }
    }
    CoordOutput {
        gbest_fit: best_fit,
        gbest_pos: best_pos,
        iters_per_shard: set.rounds * set.exec.iters_per_call(),
        chunk_calls,
        merges: shared.merges.load(Ordering::Relaxed),
        shard_fits,
        history,
    }
}

/// Barrier-per-round scheduler (reduction-style coordination).
pub struct SyncScheduler;

impl SyncScheduler {
    /// Run to completion.
    pub fn run(rt: &XlaRuntime, cfg: &CoordinatorConfig) -> Result<CoordOutput> {
        let mut set = prepare(rt, cfg)?;
        let shared = SharedBest::new(set.objective, cfg.dim);
        let key_bits = [cfg.seed as u32, (cfg.seed >> 32) as u32];
        let k = set.exec.iters_per_call();
        let mut history = Vec::new();
        let mut chunk_calls = 0u64;

        for round in 0..set.rounds {
            // All shards run one chunk in parallel, then the barrier
            // (scope join) — the inter-kernel sync analog.
            let exec = &set.exec;
            let objective = set.objective;
            let results: Vec<Result<Vec<f64>>> = std::thread::scope(|scope| {
                let handles: Vec<_> = set
                    .states
                    .iter_mut()
                    .enumerate()
                    .map(|(s, st)| {
                        scope.spawn(move || {
                            let kb = [key_bits[0] ^ s as u32, key_bits[1]];
                            exec.run(st, kb, (round * k) as i64)
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().unwrap()).collect()
            });
            for r in results {
                r?;
                chunk_calls += 1;
            }
            // Post-barrier reduction across shards + re-broadcast.
            for st in set.states.iter_mut() {
                shared.merge(objective, st);
            }
            let (g, _) = shared.snapshot();
            for st in set.states.iter_mut() {
                let (gf, gp) = shared.snapshot();
                let _ = st.adopt_gbest(objective, gf, &gp);
                debug_assert!(g <= st.gbest_fit || objective == Objective::Minimize);
            }
            history.push((round * k, shared.snapshot().0));
        }
        Ok(finish(set, &shared, chunk_calls, history))
    }
}

/// Free-running scheduler with lock-based merges (queue-lock-style).
pub struct AsyncScheduler;

impl AsyncScheduler {
    /// Run to completion.
    pub fn run(rt: &XlaRuntime, cfg: &CoordinatorConfig) -> Result<CoordOutput> {
        let mut set = prepare(rt, cfg)?;
        let shared = Arc::new(SharedBest::new(set.objective, cfg.dim));
        let key_bits = [cfg.seed as u32, (cfg.seed >> 32) as u32];
        let k = set.exec.iters_per_call();
        let rounds = set.rounds;
        let objective = set.objective;
        let chunk_calls = AtomicU64::new(0);

        let exec = &set.exec;
        let history_lock: SpinLock<Vec<(u64, f64)>> = SpinLock::new(Vec::new());
        let errors: Result<Vec<()>> = std::thread::scope(|scope| {
            let handles: Vec<_> = set
                .states
                .iter_mut()
                .enumerate()
                .map(|(s, st)| {
                    let shared = shared.clone();
                    let chunk_calls = &chunk_calls;
                    let history_lock = &history_lock;
                    scope.spawn(move || -> Result<()> {
                        // No barrier: this shard sprints through its
                        // rounds, merging through the lock after each
                        // chunk (Algorithm 3 at coordinator scale).
                        for round in 0..rounds {
                            let kb = [key_bits[0] ^ s as u32, key_bits[1]];
                            exec.run(st, kb, (round * k) as i64)?;
                            chunk_calls.fetch_add(1, Ordering::Relaxed);
                            shared.merge(objective, st);
                            if s == 0 {
                                history_lock
                                    .lock()
                                    .push((round * k, shared.snapshot().0));
                            }
                        }
                        Ok(())
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        errors?;
        let history = history_lock.into_inner();
        let calls = chunk_calls.load(Ordering::Relaxed);
        Ok(finish(set, &shared, calls, history))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_builder_defaults() {
        let c = CoordinatorConfig::new("queue", 1024, 1, 500);
        assert_eq!(c.shards, 4);
        assert_eq!(c.variant, "queue");
    }

    #[test]
    fn shared_best_merge_is_two_way() {
        let shared = SharedBest::new(Objective::Maximize, 1);
        let params = PsoParams::paper_1d(8, 1);
        let mut a = XlaSwarmState::init(&params, &crate::fitness::Cubic, Objective::Maximize, 1, 0);
        let mut b = XlaSwarmState::init(&params, &crate::fitness::Cubic, Objective::Maximize, 1, 1);
        a.gbest_fit = 10.0;
        a.gbest_pos = vec![1.0];
        b.gbest_fit = 5.0;
        b.gbest_pos = vec![2.0];
        shared.merge(Objective::Maximize, &mut a);
        shared.merge(Objective::Maximize, &mut b);
        // b pulled a's better value.
        assert_eq!(b.gbest_fit, 10.0);
        assert_eq!(b.gbest_pos, vec![1.0]);
        assert_eq!(shared.snapshot().0, 10.0);
        assert_eq!(shared.merges.load(Ordering::Relaxed), 1);
    }
}
