//! Property-testing support (proptest is unavailable offline).
//!
//! [`prop_check`] runs a predicate over `n` randomly generated cases from
//! a seeded generator, with greedy shrinking on failure: the failing case
//! is re-generated with progressively "smaller" parameters via the
//! caller's `shrink` hook, and the smallest still-failing case is
//! reported. Deterministic per seed, so CI failures reproduce.

use crate::rng::{RngEngine, SplitMix64};

/// Outcome of a property run.
#[derive(Debug)]
pub struct PropFailure<C: std::fmt::Debug> {
    /// The (possibly shrunk) counterexample.
    pub case: C,
    /// Cases executed before the failure.
    pub cases_run: usize,
    /// Message from the failing predicate.
    pub message: String,
}

impl<C: std::fmt::Debug> std::fmt::Display for PropFailure<C> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "property failed after {} cases: {}\ncounterexample: {:#?}",
            self.cases_run, self.message, self.case
        )
    }
}

/// Run `check` on `cases` generated cases; panic with the shrunk
/// counterexample on failure.
///
/// * `gen`: produce a case from the RNG.
/// * `shrink`: yield strictly-smaller variants of a case (may be empty).
/// * `check`: return `Err(msg)` to fail the property.
pub fn prop_check<C, G, S, F>(seed: u64, cases: usize, mut gen: G, shrink: S, mut check: F)
where
    C: Clone + std::fmt::Debug,
    G: FnMut(&mut dyn RngEngine) -> C,
    S: Fn(&C) -> Vec<C>,
    F: FnMut(&C) -> Result<(), String>,
{
    let mut rng = SplitMix64::new(seed);
    for i in 0..cases {
        let case = gen(&mut rng);
        if let Err(msg) = check(&case) {
            // Greedy shrink: repeatedly take the first smaller variant
            // that still fails, up to a budget.
            let mut best = case.clone();
            let mut best_msg = msg;
            let mut budget = 200;
            'outer: while budget > 0 {
                for cand in shrink(&best) {
                    budget -= 1;
                    if let Err(m) = check(&cand) {
                        best = cand;
                        best_msg = m;
                        continue 'outer;
                    }
                    if budget == 0 {
                        break;
                    }
                }
                break;
            }
            panic!(
                "{}",
                PropFailure {
                    case: best,
                    cases_run: i + 1,
                    message: best_msg
                }
            );
        }
    }
}

/// Uniform usize in `[lo, hi]` from an engine (generator helper).
pub fn gen_usize(rng: &mut dyn RngEngine, lo: usize, hi: usize) -> usize {
    debug_assert!(lo <= hi);
    lo + (rng.next_u64() as usize) % (hi - lo + 1)
}

/// Standard shrink for a usize toward `lo`: halving steps.
pub fn shrink_usize(v: usize, lo: usize) -> Vec<usize> {
    let mut out = Vec::new();
    if v > lo {
        out.push(lo);
        let mid = lo + (v - lo) / 2;
        if mid != lo && mid != v {
            out.push(mid);
        }
        if v - 1 != lo {
            out.push(v - 1);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        prop_check(
            1,
            50,
            |r| gen_usize(r, 0, 100),
            |_| vec![],
            |_| {
                count += 1;
                Ok(())
            },
        );
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "counterexample")]
    fn failing_property_panics_with_case() {
        prop_check(
            2,
            100,
            |r| gen_usize(r, 0, 1000),
            |&c| shrink_usize(c, 0),
            |&c| {
                if c >= 10 {
                    Err(format!("{c} too big"))
                } else {
                    Ok(())
                }
            },
        );
    }

    #[test]
    fn shrink_finds_small_counterexample() {
        // Capture the panic message and assert the shrunk case is minimal.
        let result = std::panic::catch_unwind(|| {
            prop_check(
                3,
                100,
                |r| gen_usize(r, 0, 1000),
                |&c| shrink_usize(c, 0),
                |&c| if c >= 10 { Err("big".into()) } else { Ok(()) },
            );
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        // Greedy halving should land at or near the boundary (10..20).
        let case: usize = msg
            .lines()
            .find_map(|l| l.strip_prefix("counterexample: ")?.trim().parse().ok())
            .expect("case in message");
        assert!(case < 30, "shrunk case {case} not small: {msg}");
    }

    #[test]
    fn deterministic_per_seed() {
        let mut a = vec![];
        let mut b = vec![];
        prop_check(9, 10, |r| gen_usize(r, 0, 99), |_| vec![], |&c| {
            a.push(c);
            Ok(())
        });
        prop_check(9, 10, |r| gen_usize(r, 0, 99), |_| vec![], |&c| {
            b.push(c);
            Ok(())
        });
        assert_eq!(a, b);
    }
}
