//! The paper's Table 1 parameter set.

use crate::config::RunConfig;
use crate::fitness::Fitness;

/// PSO hyper-parameters (Table 1). `w = 1`, `c1 = c2 = 2` are the paper's
/// settings (§6.1); position bounds default to the fitness function's
/// domain and the velocity clamp to half the position range.
#[derive(Debug, Clone)]
pub struct PsoParams {
    /// Inertia weight.
    pub w: f64,
    /// Cognitive coefficient.
    pub c1: f64,
    /// Social coefficient.
    pub c2: f64,
    /// Lower position bound (per dimension).
    pub min_pos: f64,
    /// Upper position bound (per dimension).
    pub max_pos: f64,
    /// Velocity clamp: `v ∈ [-max_v, max_v]`.
    pub max_v: f64,
    /// Iteration budget (`max_iter`).
    pub max_iter: u64,
    /// Swarm size (`particle_cnt`).
    pub n: usize,
    /// Problem dimensionality (1 or 120 in the paper).
    pub dim: usize,
}

impl PsoParams {
    /// The paper's 1-D Cubic workload (§6.2): `w=1, c1=c2=2`, domain
    /// `[-100, 100]`.
    pub fn paper_1d(particles: usize, iters: u64) -> Self {
        Self {
            w: 1.0,
            c1: 2.0,
            c2: 2.0,
            min_pos: -100.0,
            max_pos: 100.0,
            max_v: 100.0,
            max_iter: iters,
            n: particles,
            dim: 1,
        }
    }

    /// The paper's 120-D Cubic workload (§6.3).
    pub fn paper_120d(particles: usize, iters: u64) -> Self {
        Self {
            dim: 120,
            ..Self::paper_1d(particles, iters)
        }
    }

    /// Parameters for an arbitrary fitness function: bounds from its
    /// domain, velocity clamp = `vmax_frac` × range.
    pub fn for_fitness(f: &dyn Fitness, particles: usize, dim: usize, iters: u64, vmax_frac: f64) -> Self {
        let (lo, hi) = f.default_bounds();
        Self {
            w: 1.0,
            c1: 2.0,
            c2: 2.0,
            min_pos: lo,
            max_pos: hi,
            max_v: vmax_frac * (hi - lo),
            max_iter: iters,
            n: particles,
            dim,
        }
    }

    /// Build from a launcher [`RunConfig`] (bounds override respected).
    pub fn from_config(cfg: &RunConfig, f: &dyn Fitness) -> Self {
        let mut p = Self::for_fitness(f, cfg.particles, cfg.dim, cfg.iters, cfg.vmax_frac);
        p.w = cfg.w;
        p.c1 = cfg.c1;
        p.c2 = cfg.c2;
        if let Some((lo, hi)) = cfg.bounds {
            p.min_pos = lo;
            p.max_pos = hi;
            p.max_v = cfg.vmax_frac * (hi - lo);
        }
        p
    }

    /// Total scalar state in the SoA arrays (for footprint reporting).
    pub fn state_doubles(&self) -> usize {
        // pos + vel + pbest_pos (n×dim each) + fit + pbest_fit (n each)
        3 * self.n * self.dim + 2 * self.n
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fitness::Sphere;

    #[test]
    fn paper_constructors_match_section_6_1() {
        let p = PsoParams::paper_1d(2048, 100_000);
        assert_eq!((p.w, p.c1, p.c2), (1.0, 2.0, 2.0));
        assert_eq!((p.min_pos, p.max_pos), (-100.0, 100.0));
        assert_eq!(p.dim, 1);
        assert_eq!(PsoParams::paper_120d(128, 5000).dim, 120);
    }

    #[test]
    fn for_fitness_uses_function_domain() {
        let p = PsoParams::for_fitness(&Sphere, 64, 10, 100, 0.5);
        assert_eq!((p.min_pos, p.max_pos), (-100.0, 100.0));
        assert_eq!(p.max_v, 100.0);
    }

    #[test]
    fn state_footprint() {
        let p = PsoParams::paper_120d(1000, 1);
        assert_eq!(p.state_doubles(), 3 * 120_000 + 2000);
    }
}
