//! Synchronous serial reference — PPSO semantics on one core.
//!
//! Identical physics to [`super::serial`], but the global best is frozen
//! for the whole sweep and applied once at the end of each iteration,
//! exactly like the GPU algorithms (the "1st kernel" computes every
//! particle against the *previous* iteration's gbest, then the best data
//! is aggregated). The four Plane-A parallel engines must reproduce this
//! trajectory **bit-exactly** — that equivalence is the core correctness
//! test for the queue algorithms.
//!
//! [`SyncSerialRun`] is the step-wise form ([`crate::engine::Run`]): one
//! `step()` = one frozen-gbest sweep + the end-of-iteration update.
//! [`run`] drives it to exhaustion, so the oracle and its step-wise form
//! cannot drift apart.

use super::{eval_and_pbest, history_stride, update_particle, PsoParams, RunOutput, SwarmState};
use crate::checkpoint::{RunCheckpoint, RunKind, VERSION};
use crate::engine::{restore_guard, Run, StepReport};
use crate::fitness::{Fitness, Objective};
use crate::rng::PhiloxStream;
use anyhow::Result;

/// Tie-break rule shared with every parallel engine: on equal fitness the
/// smaller particle index wins. This makes the argmax total so engines
/// with different scan orders still agree bit-exactly.
#[inline]
pub fn better_with_tie(
    objective: Objective,
    fit: f64,
    idx: usize,
    best_fit: f64,
    best_idx: usize,
) -> bool {
    objective.better(fit, best_fit) || (fit == best_fit && idx < best_idx)
}

/// Run the synchronous serial PSO (the parallel engines' oracle).
pub fn run(
    params: &PsoParams,
    fitness: &dyn Fitness,
    objective: Objective,
    seed: u64,
) -> RunOutput {
    let mut r = Box::new(SyncSerialRun::new(params, fitness, objective, seed));
    while !r.step().done {}
    r.finish()
}

/// A prepared synchronous-serial run (the oracle, resumable).
pub struct SyncSerialRun<'a> {
    params: PsoParams,
    fitness: &'a dyn Fitness,
    objective: Objective,
    seed: u64,
    stream: PhiloxStream,
    state: SwarmState,
    gbest_fit: f64,
    gbest_pos: Vec<f64>,
    counters: super::Counters,
    stride: u64,
    history: Vec<(u64, f64)>,
    iter: u64,
}

impl<'a> SyncSerialRun<'a> {
    /// Seed the swarm and the initial global best.
    pub fn new(
        params: &PsoParams,
        fitness: &'a dyn Fitness,
        objective: Objective,
        seed: u64,
    ) -> Self {
        let stream = PhiloxStream::new(seed);
        let mut state = SwarmState::init(params, &stream);
        let (gbest_fit, gi) = state.seed_fitness(fitness, objective);
        let gbest_pos = state.position_of(gi);
        Self {
            params: params.clone(),
            fitness,
            objective,
            seed,
            stream,
            state,
            gbest_fit,
            gbest_pos,
            counters: super::Counters::default(),
            stride: history_stride(params.max_iter),
            history: Vec::with_capacity(super::history_capacity(params.max_iter)),
            iter: 0,
        }
    }

    /// Rebuild a suspended oracle run from its checkpoint — bit-exact,
    /// like the serial reference.
    pub fn restore(ckpt: &RunCheckpoint, fitness: &'a dyn Fitness) -> Result<Self> {
        restore_guard(ckpt, RunKind::SerialSync)?;
        let mut history = ckpt.history.clone();
        history
            .reserve(super::history_capacity(ckpt.params.max_iter).saturating_sub(history.len()));
        Ok(Self {
            params: ckpt.params.clone(),
            fitness,
            objective: ckpt.objective,
            seed: ckpt.seed,
            stream: PhiloxStream::new(ckpt.seed),
            state: ckpt.swarm.clone(),
            gbest_fit: ckpt.gbest_fit,
            gbest_pos: ckpt.gbest_pos.clone(),
            counters: ckpt.counters.clone(),
            stride: history_stride(ckpt.params.max_iter),
            history,
            iter: ckpt.iter,
        })
    }
}

impl Run for SyncSerialRun<'_> {
    fn iters_done(&self) -> u64 {
        self.iter
    }

    fn max_iter(&self) -> u64 {
        self.params.max_iter
    }

    fn gbest_fit(&self) -> f64 {
        self.gbest_fit
    }

    fn gbest_pos(&self) -> Vec<f64> {
        self.gbest_pos.clone()
    }

    fn step(&mut self) -> StepReport {
        if self.iter >= self.params.max_iter {
            return StepReport {
                iter: self.iter,
                gbest_fit: self.gbest_fit,
                gbest_pos: None,
                improved: false,
                done: true,
            };
        }
        let iter = self.iter;
        let objective = self.objective;
        // Sweep with frozen gbest.
        let mut iter_best_fit = objective.worst();
        let mut iter_best_idx = usize::MAX;
        for i in 0..self.params.n {
            update_particle(
                &mut self.state,
                i,
                &self.gbest_pos,
                &self.params,
                &self.stream,
                iter,
            );
            let before = self.state.pbest_fit[i];
            let fit = eval_and_pbest(&mut self.state, i, self.fitness, objective);
            self.counters.particle_updates += 1;
            if objective.better(fit, before) {
                self.counters.pbest_improvements += 1;
            }
            // The GPU kernels aggregate this iteration's `fit` (Algorithm 2
            // pushes `fit`, not `pbest_fit`); the resulting gbest
            // trajectory is identical because gbest(t-1) already dominates
            // all older fits.
            if better_with_tie(objective, self.state.fit[i], i, iter_best_fit, iter_best_idx) {
                iter_best_fit = self.state.fit[i];
                iter_best_idx = i;
            }
        }
        // Single end-of-iteration gbest update (the "2nd kernel").
        let improved = objective.better(iter_best_fit, self.gbest_fit);
        if improved {
            self.gbest_fit = iter_best_fit;
            // The winning particle just improved its pbest, so pos ==
            // pbest_pos for it; read pos for symmetry with the kernels.
            self.state.position_into(iter_best_idx, &mut self.gbest_pos);
            self.counters.gbest_updates += 1;
        }
        self.iter += 1;
        if iter % self.stride == 0 {
            self.history.push((iter, self.gbest_fit));
        }
        StepReport {
            iter: self.iter,
            gbest_fit: self.gbest_fit,
            gbest_pos: improved.then(|| self.gbest_pos.clone()),
            improved,
            done: self.iter >= self.params.max_iter,
        }
    }

    fn finish(self: Box<Self>) -> RunOutput {
        let this = *self;
        let SyncSerialRun {
            gbest_fit,
            gbest_pos,
            counters,
            mut history,
            iter,
            ..
        } = this;
        history.push((iter, gbest_fit));
        RunOutput {
            gbest_fit,
            gbest_pos,
            iters: iter,
            history,
            counters,
        }
    }

    fn checkpoint(&self) -> RunCheckpoint {
        RunCheckpoint {
            version: VERSION,
            kind: RunKind::SerialSync,
            objective: self.objective,
            seed: self.seed,
            params: self.params.clone(),
            iter: self.iter,
            gbest_fit: self.gbest_fit,
            gbest_pos: self.gbest_pos.clone(),
            history: self.history.clone(),
            counters: self.counters.clone(),
            swarm: self.state.clone(),
        }
    }

    fn into_checkpoint(self: Box<Self>) -> RunCheckpoint {
        // Suspension path: swarm, gbest position and history are MOVED,
        // never deep-copied (rust/tests/zero_alloc.rs pins this).
        let this = *self;
        RunCheckpoint {
            version: VERSION,
            kind: RunKind::SerialSync,
            objective: this.objective,
            seed: this.seed,
            iter: this.iter,
            gbest_fit: this.gbest_fit,
            gbest_pos: this.gbest_pos,
            history: this.history,
            counters: this.counters,
            params: this.params,
            swarm: this.state,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fitness::Cubic;

    #[test]
    fn converges_like_the_async_serial() {
        let params = PsoParams::paper_1d(128, 200);
        let sync = run(&params, &Cubic, Objective::Maximize, 1);
        let asyn = super::super::serial::run(&params, &Cubic, Objective::Maximize, 1);
        // Both should essentially solve the 1-D problem; they are distinct
        // algorithms (gbest propagation timing) so exact equality is NOT
        // expected — closeness is.
        assert!(sync.gbest_fit > 899_000.0);
        assert!(asyn.gbest_fit > 899_000.0);
    }

    #[test]
    fn trajectories_differ_from_async_serial_in_general() {
        // With few particles and iterations the propagation-timing
        // difference is observable — documents that these are two
        // different reference semantics, as the paper describes.
        let params = PsoParams::paper_120d(8, 30);
        let sync = run(&params, &Cubic, Objective::Maximize, 2);
        let asyn = super::super::serial::run(&params, &Cubic, Objective::Maximize, 2);
        assert!(
            sync.gbest_fit != asyn.gbest_fit || sync.gbest_pos != asyn.gbest_pos,
            "sync and async serial coincided unexpectedly (not wrong, but \
             suspicious for this workload)"
        );
    }

    #[test]
    fn tie_break_is_total_and_index_ordered() {
        use crate::fitness::Objective::*;
        assert!(better_with_tie(Maximize, 2.0, 5, 1.0, 0));
        assert!(better_with_tie(Maximize, 2.0, 3, 2.0, 5)); // tie → lower idx
        assert!(!better_with_tie(Maximize, 2.0, 7, 2.0, 5));
        assert!(better_with_tie(Minimize, 1.0, 9, 2.0, 0));
    }

    #[test]
    fn deterministic_per_seed() {
        let params = PsoParams::paper_1d(64, 50);
        let a = run(&params, &Cubic, Objective::Maximize, 4);
        let b = run(&params, &Cubic, Objective::Maximize, 4);
        assert_eq!(a.gbest_fit, b.gbest_fit);
        assert_eq!(a.history, b.history);
    }

    #[test]
    fn stepwise_oracle_matches_one_shot() {
        let params = PsoParams::paper_120d(24, 20);
        let one_shot = run(&params, &Cubic, Objective::Maximize, 8);
        let mut r = Box::new(SyncSerialRun::new(&params, &Cubic, Objective::Maximize, 8));
        while !r.step().done {}
        let out = r.finish();
        assert_eq!(out.gbest_fit, one_shot.gbest_fit);
        assert_eq!(out.gbest_pos, one_shot.gbest_pos);
        assert_eq!(out.history, one_shot.history);
    }
}
