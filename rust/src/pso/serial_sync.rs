//! Synchronous serial reference — PPSO semantics on one core.
//!
//! Identical physics to [`super::serial`], but the global best is frozen
//! for the whole sweep and applied once at the end of each iteration,
//! exactly like the GPU algorithms (the "1st kernel" computes every
//! particle against the *previous* iteration's gbest, then the best data
//! is aggregated). The four Plane-A parallel engines must reproduce this
//! trajectory **bit-exactly** — that equivalence is the core correctness
//! test for the queue algorithms.

use super::{eval_and_pbest, history_stride, update_particle, PsoParams, RunOutput, SwarmState};
use crate::fitness::{Fitness, Objective};
use crate::rng::PhiloxStream;

/// Tie-break rule shared with every parallel engine: on equal fitness the
/// smaller particle index wins. This makes the argmax total so engines
/// with different scan orders still agree bit-exactly.
#[inline]
pub fn better_with_tie(
    objective: Objective,
    fit: f64,
    idx: usize,
    best_fit: f64,
    best_idx: usize,
) -> bool {
    objective.better(fit, best_fit) || (fit == best_fit && idx < best_idx)
}

/// Run the synchronous serial PSO (the parallel engines' oracle).
pub fn run(
    params: &PsoParams,
    fitness: &dyn Fitness,
    objective: Objective,
    seed: u64,
) -> RunOutput {
    let stream = PhiloxStream::new(seed);
    let mut state = SwarmState::init(params, &stream);
    let (mut gbest_fit, gi) = state.seed_fitness(fitness, objective);
    let mut gbest_pos = state.position_of(gi);

    let stride = history_stride(params.max_iter);
    let mut history = Vec::with_capacity(super::HISTORY_SAMPLES as usize + 1);
    let mut counters = super::Counters::default();

    for iter in 0..params.max_iter {
        // Sweep with frozen gbest.
        let mut iter_best_fit = objective.worst();
        let mut iter_best_idx = usize::MAX;
        for i in 0..params.n {
            update_particle(&mut state, i, &gbest_pos, params, &stream, iter);
            let before = state.pbest_fit[i];
            let fit = eval_and_pbest(&mut state, i, fitness, objective);
            counters.particle_updates += 1;
            if objective.better(fit, before) {
                counters.pbest_improvements += 1;
            }
            // The GPU kernels aggregate this iteration's `fit` (Algorithm 2
            // pushes `fit`, not `pbest_fit`); the resulting gbest
            // trajectory is identical because gbest(t-1) already dominates
            // all older fits.
            if better_with_tie(objective, state.fit[i], i, iter_best_fit, iter_best_idx) {
                iter_best_fit = state.fit[i];
                iter_best_idx = i;
            }
        }
        // Single end-of-iteration gbest update (the "2nd kernel").
        if objective.better(iter_best_fit, gbest_fit) {
            gbest_fit = iter_best_fit;
            // The winning particle just improved its pbest, so pos ==
            // pbest_pos for it; read pos for symmetry with the kernels.
            gbest_pos = state.position_of(iter_best_idx);
            counters.gbest_updates += 1;
        }
        if iter % stride == 0 {
            history.push((iter, gbest_fit));
        }
    }
    history.push((params.max_iter, gbest_fit));

    RunOutput {
        gbest_fit,
        gbest_pos,
        iters: params.max_iter,
        history,
        counters,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fitness::Cubic;

    #[test]
    fn converges_like_the_async_serial() {
        let params = PsoParams::paper_1d(128, 200);
        let sync = run(&params, &Cubic, Objective::Maximize, 1);
        let asyn = super::super::serial::run(&params, &Cubic, Objective::Maximize, 1);
        // Both should essentially solve the 1-D problem; they are distinct
        // algorithms (gbest propagation timing) so exact equality is NOT
        // expected — closeness is.
        assert!(sync.gbest_fit > 899_000.0);
        assert!(asyn.gbest_fit > 899_000.0);
    }

    #[test]
    fn trajectories_differ_from_async_serial_in_general() {
        // With few particles and iterations the propagation-timing
        // difference is observable — documents that these are two
        // different reference semantics, as the paper describes.
        let params = PsoParams::paper_120d(8, 30);
        let sync = run(&params, &Cubic, Objective::Maximize, 2);
        let asyn = super::super::serial::run(&params, &Cubic, Objective::Maximize, 2);
        assert!(
            sync.gbest_fit != asyn.gbest_fit || sync.gbest_pos != asyn.gbest_pos,
            "sync and async serial coincided unexpectedly (not wrong, but \
             suspicious for this workload)"
        );
    }

    #[test]
    fn tie_break_is_total_and_index_ordered() {
        use crate::fitness::Objective::*;
        assert!(better_with_tie(Maximize, 2.0, 5, 1.0, 0));
        assert!(better_with_tie(Maximize, 2.0, 3, 2.0, 5)); // tie → lower idx
        assert!(!better_with_tie(Maximize, 2.0, 7, 2.0, 5));
        assert!(better_with_tie(Minimize, 1.0, 9, 2.0, 0));
    }

    #[test]
    fn deterministic_per_seed() {
        let params = PsoParams::paper_1d(64, 50);
        let a = run(&params, &Cubic, Objective::Maximize, 4);
        let b = run(&params, &Cubic, Objective::Maximize, 4);
        assert_eq!(a.gbest_fit, b.gbest_fit);
        assert_eq!(a.history, b.history);
    }
}
