//! Particle storage.
//!
//! [`SwarmState`] is the SoA layout of §5.1 (Data Structure SoA /
//! Figure 2): every field is a flat array, dimension-major
//! (`pos[d * n + i]`), so a sweep over particles at fixed dimension walks
//! memory contiguously — the CPU-cache analog of coalesced access.
//!
//! [`AosSwarm`] is the Array-of-Structures layout the paper calls "almost
//! the worst case" for parallel code; it exists solely for
//! `benches/ablation_layout.rs` to measure the difference.

use super::PsoParams;
use crate::fitness::Objective;
use crate::rng::PhiloxStream;

/// SoA swarm storage (the production layout).
#[derive(Debug, Clone)]
pub struct SwarmState {
    /// Particle count.
    pub n: usize,
    /// Dimensionality.
    pub dim: usize,
    /// Positions, `pos[d * n + i]`.
    pub pos: Vec<f64>,
    /// Velocities, same layout.
    pub vel: Vec<f64>,
    /// Current fitness per particle.
    pub fit: Vec<f64>,
    /// Best-known position per particle, same layout as `pos`.
    pub pbest_pos: Vec<f64>,
    /// Best-known fitness per particle.
    pub pbest_fit: Vec<f64>,
}

impl SwarmState {
    /// Step-1 initialization (Algorithm 1 lines 1–6): uniform random
    /// positions and velocities inside the bounds, pbest = initial state.
    /// Deterministic in the stream: position/velocity of particle `i`
    /// come from counter slots independent of execution order, so serial
    /// and parallel engines start from the *identical* swarm.
    pub fn init(params: &PsoParams, stream: &PhiloxStream) -> Self {
        let (n, dim) = (params.n, params.dim);
        let mut pos = vec![0.0; n * dim];
        let mut vel = vec![0.0; n * dim];
        for d in 0..dim {
            for i in 0..n {
                // Iteration counter u64::MAX is reserved for init draws so
                // they never collide with update draws (iter < max_iter).
                let (rp, rv) = stream.r1r2(i as u64, u64::MAX, d as u32);
                pos[d * n + i] = params.min_pos + (params.max_pos - params.min_pos) * rp;
                vel[d * n + i] = -params.max_v + 2.0 * params.max_v * rv;
            }
        }
        Self {
            n,
            dim,
            pos: pos.clone(),
            vel,
            fit: vec![0.0; n],
            pbest_pos: pos,
            pbest_fit: vec![0.0; n],
        }
    }

    /// Evaluate all particles and seed pbest/fit from the initial
    /// positions (the tail of Step 1). Returns the initial global best
    /// `(fit, particle index)`.
    pub fn seed_fitness(
        &mut self,
        fitness: &dyn crate::fitness::Fitness,
        objective: Objective,
    ) -> (f64, usize) {
        fitness.eval_batch(&self.pos, self.n, self.dim, &mut self.fit);
        self.pbest_fit.copy_from_slice(&self.fit);
        self.pbest_pos.copy_from_slice(&self.pos);
        let mut best = objective.worst();
        let mut best_i = 0;
        for (i, &f) in self.fit.iter().enumerate() {
            if objective.better(f, best) {
                best = f;
                best_i = i;
            }
        }
        (best, best_i)
    }

    /// Copy particle `i`'s position out (length-dim row gather).
    pub fn position_of(&self, i: usize) -> Vec<f64> {
        let mut out = vec![0.0; self.dim];
        self.position_into(i, &mut out);
        out
    }

    /// Gather particle `i`'s position into `out` (length = dim) without
    /// allocating — the hot-path form of [`position_of`](Self::position_of)
    /// used by the engines' global-best updates.
    #[inline]
    pub fn position_into(&self, i: usize, out: &mut [f64]) {
        debug_assert_eq!(out.len(), self.dim);
        for (d, slot) in out.iter_mut().enumerate() {
            *slot = self.pos[d * self.n + i];
        }
    }

    /// Copy particle `i`'s pbest position out.
    pub fn pbest_of(&self, i: usize) -> Vec<f64> {
        let mut out = vec![0.0; self.dim];
        self.pbest_into(i, &mut out);
        out
    }

    /// Gather particle `i`'s pbest position into `out` (length = dim)
    /// without allocating.
    #[inline]
    pub fn pbest_into(&self, i: usize, out: &mut [f64]) {
        debug_assert_eq!(out.len(), self.dim);
        for (d, slot) in out.iter_mut().enumerate() {
            *slot = self.pbest_pos[d * self.n + i];
        }
    }

    /// Invariant check used by property tests: all positions and
    /// velocities inside bounds.
    pub fn check_bounds(&self, params: &PsoParams) -> Result<(), String> {
        for (k, &p) in self.pos.iter().enumerate() {
            if !(params.min_pos..=params.max_pos).contains(&p) {
                return Err(format!("pos[{k}] = {p} out of bounds"));
            }
        }
        for (k, &v) in self.vel.iter().enumerate() {
            if !(-params.max_v..=params.max_v).contains(&v) {
                return Err(format!("vel[{k}] = {v} out of clamp"));
            }
        }
        Ok(())
    }
}

/// One particle in AoS layout (the paper's "Data Structure AoS").
#[derive(Debug, Clone)]
pub struct AosParticle {
    /// Position (length dim).
    pub pos: Vec<f64>,
    /// Velocity.
    pub vel: Vec<f64>,
    /// Current fitness.
    pub fit: f64,
    /// Best-known position.
    pub pbest_pos: Vec<f64>,
    /// Best-known fitness.
    pub pbest_fit: f64,
}

/// AoS swarm — layout-ablation only.
#[derive(Debug, Clone)]
pub struct AosSwarm {
    /// The particles.
    pub particles: Vec<AosParticle>,
}

impl AosSwarm {
    /// Mirror of [`SwarmState::init`] producing the identical swarm in
    /// AoS layout (same RNG draws).
    pub fn init(params: &PsoParams, stream: &PhiloxStream) -> Self {
        let soa = SwarmState::init(params, stream);
        Self::from_soa(&soa)
    }

    /// Convert from SoA (test/ablation bridge).
    pub fn from_soa(s: &SwarmState) -> Self {
        let particles = (0..s.n)
            .map(|i| AosParticle {
                pos: s.position_of(i),
                vel: (0..s.dim).map(|d| s.vel[d * s.n + i]).collect(),
                fit: s.fit[i],
                pbest_pos: s.pbest_of(i),
                pbest_fit: s.pbest_fit[i],
            })
            .collect();
        Self { particles }
    }

    /// Convert to SoA (equivalence checks).
    pub fn to_soa(&self, dim: usize) -> SwarmState {
        let n = self.particles.len();
        let mut s = SwarmState {
            n,
            dim,
            pos: vec![0.0; n * dim],
            vel: vec![0.0; n * dim],
            fit: vec![0.0; n],
            pbest_pos: vec![0.0; n * dim],
            pbest_fit: vec![0.0; n],
        };
        for (i, p) in self.particles.iter().enumerate() {
            for d in 0..dim {
                s.pos[d * n + i] = p.pos[d];
                s.vel[d * n + i] = p.vel[d];
                s.pbest_pos[d * n + i] = p.pbest_pos[d];
            }
            s.fit[i] = p.fit;
            s.pbest_fit[i] = p.pbest_fit;
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fitness::{Cubic, Objective};

    #[test]
    fn init_is_inside_bounds_and_deterministic() {
        let params = PsoParams::paper_1d(256, 10);
        let stream = PhiloxStream::new(42);
        let a = SwarmState::init(&params, &stream);
        let b = SwarmState::init(&params, &stream);
        a.check_bounds(&params).unwrap();
        assert_eq!(a.pos, b.pos);
        assert_eq!(a.vel, b.vel);
        // Positions should not all be equal (it's a random swarm).
        assert!(a.pos.iter().any(|&p| (p - a.pos[0]).abs() > 1e-9));
    }

    #[test]
    fn seed_fitness_finds_argmax() {
        let params = PsoParams::paper_1d(64, 10);
        let stream = PhiloxStream::new(3);
        let mut st = SwarmState::init(&params, &stream);
        let (best, best_i) = st.seed_fitness(&Cubic, Objective::Maximize);
        assert_eq!(best, st.fit[best_i]);
        for &f in &st.fit {
            assert!(f <= best);
        }
        assert_eq!(st.pbest_fit, st.fit);
    }

    #[test]
    fn aos_soa_roundtrip_is_identity() {
        let params = PsoParams::paper_120d(16, 1);
        let stream = PhiloxStream::new(9);
        let mut soa = SwarmState::init(&params, &stream);
        soa.seed_fitness(&Cubic, Objective::Maximize);
        let aos = AosSwarm::from_soa(&soa);
        let back = aos.to_soa(params.dim);
        assert_eq!(soa.pos, back.pos);
        assert_eq!(soa.vel, back.vel);
        assert_eq!(soa.fit, back.fit);
        assert_eq!(soa.pbest_pos, back.pbest_pos);
        assert_eq!(soa.pbest_fit, back.pbest_fit);
    }

    #[test]
    fn init_draws_do_not_collide_with_update_draws() {
        // Init uses iter = u64::MAX; updates use iter < max_iter. Check a
        // couple of values differ (no accidental counter reuse).
        let stream = PhiloxStream::new(5);
        assert_ne!(stream.r1r2(0, u64::MAX, 0), stream.r1r2(0, 0, 0));
    }
}
