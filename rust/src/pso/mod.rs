//! Core PSO types and the serial baseline.
//!
//! * [`PsoParams`] — Table 1 of the paper, with constructors for the two
//!   evaluated workloads (1-D and 120-D Cubic).
//! * [`SwarmState`] — SoA particle storage (§5.1 / Figure 2), plus an AoS
//!   variant used only by the layout ablation.
//! * [`serial`] — Algorithm 1 verbatim (the paper's "CPU" column):
//!   in-loop gbest updates (a later particle in the same sweep sees the
//!   gbest a previous particle just set).
//! * [`serial_sync`] — a synchronous serial reference with PPSO semantics
//!   (gbest is frozen for the whole iteration, applied at the end). This
//!   is the *oracle* for the parallel engines: Reduction / Loop-Unrolling
//!   / Queue / Queue-Lock must reproduce its gbest trajectory bit-exactly,
//!   because all four differ only in aggregation mechanics.

mod params;
pub mod serial;
pub mod serial_sync;
mod state;

pub use params::PsoParams;
pub use state::{AosSwarm, SwarmState};

use crate::fitness::Objective;

/// Result of a full PSO run.
#[derive(Debug, Clone)]
pub struct RunOutput {
    /// Best fitness found.
    pub gbest_fit: f64,
    /// Best position found (length = dim).
    pub gbest_pos: Vec<f64>,
    /// Iterations executed.
    pub iters: u64,
    /// Sampled convergence history: `(iteration, gbest_fit)`.
    pub history: Vec<(u64, f64)>,
    /// Instrumentation counters (queue pushes, lock acquisitions, …).
    pub counters: Counters,
}

/// Hot-loop instrumentation the ablation benches read.
#[derive(Debug, Clone, Default)]
pub struct Counters {
    /// Particle updates that improved their pbest.
    pub pbest_improvements: u64,
    /// Conditional queue pushes (Algorithm 2 line 2) across all blocks.
    pub queue_pushes: u64,
    /// Global-lock acquisitions (Algorithm 3) / gbest update attempts.
    pub gbest_updates: u64,
    /// Total particle-iteration updates (denominator for rarity rates).
    pub particle_updates: u64,
}

impl Counters {
    /// The paper's §4.1 observation: fraction of particle updates that
    /// pushed to the queue (they report < 0.1%).
    pub fn queue_push_rate(&self) -> f64 {
        if self.particle_updates == 0 {
            0.0
        } else {
            self.queue_pushes as f64 / self.particle_updates as f64
        }
    }
}

/// Shared convergence bookkeeping: how many history samples a run keeps.
pub const HISTORY_SAMPLES: u64 = 64;

/// Stride so a run of `iters` yields ≈[`HISTORY_SAMPLES`] samples.
pub fn history_stride(iters: u64) -> u64 {
    (iters / HISTORY_SAMPLES).max(1)
}

/// Upper bound on the history entries a run of `iters` can record
/// (stride marks plus the `finish` sample). Runs reserve this up front so
/// steady-state stepping never reallocates the history vector.
pub fn history_capacity(iters: u64) -> usize {
    (iters / history_stride(iters)) as usize + 2
}

/// One velocity+position update for particle `i`, dimension-major SoA —
/// Eq. (1) and Eq. (2) plus the clamps of Algorithm 1 lines 9–12.
///
/// Shared by the serial baselines and all Plane-A engines so the physics
/// is one piece of code and cross-engine equivalence is meaningful.
#[inline]
pub fn update_particle(
    state: &mut SwarmState,
    i: usize,
    gbest_pos: &[f64],
    params: &PsoParams,
    rng: &crate::rng::PhiloxStream,
    iter: u64,
) {
    let n = state.n;
    for d in 0..state.dim {
        let idx = d * n + i;
        let (r1, r2) = rng.r1r2(i as u64, iter, d as u32);
        let v = params.w * state.vel[idx]
            + params.c1 * r1 * (state.pbest_pos[idx] - state.pos[idx])
            + params.c2 * r2 * (gbest_pos[d] - state.pos[idx]);
        let v = v.clamp(-params.max_v, params.max_v);
        let p = (state.pos[idx] + v).clamp(params.min_pos, params.max_pos);
        state.vel[idx] = v;
        state.pos[idx] = p;
    }
}

/// Fitness evaluation + pbest update for particle `i` (Algorithm 1 lines
/// 13–16). Returns the new fitness.
#[inline]
pub fn eval_and_pbest(
    state: &mut SwarmState,
    i: usize,
    fitness: &dyn crate::fitness::Fitness,
    objective: Objective,
) -> f64 {
    let n = state.n;
    let dim = state.dim;
    // Gather the particle's position into a scratch row. dim==1 takes the
    // scalar fast path (the paper's 1-D problem).
    let fit = if dim == 1 {
        fitness.eval(&state.pos[i..=i])
    } else {
        let mut x = [0.0f64; 256];
        if dim <= 256 {
            for (d, slot) in x[..dim].iter_mut().enumerate() {
                *slot = state.pos[d * n + i];
            }
            fitness.eval(&x[..dim])
        } else {
            let xs: Vec<f64> = (0..dim).map(|d| state.pos[d * n + i]).collect();
            fitness.eval(&xs)
        }
    };
    state.fit[i] = fit;
    if objective.better(fit, state.pbest_fit[i]) {
        state.pbest_fit[i] = fit;
        for d in 0..dim {
            state.pbest_pos[d * n + i] = state.pos[d * n + i];
        }
    }
    fit
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fitness::{Cubic, Fitness};
    use crate::rng::PhiloxStream;

    #[test]
    fn history_stride_is_sane() {
        assert_eq!(history_stride(64), 1);
        assert_eq!(history_stride(6400), 100);
        assert_eq!(history_stride(1), 1);
    }

    #[test]
    fn update_respects_clamps() {
        let params = PsoParams::paper_1d(4, 10);
        let stream = PhiloxStream::new(1);
        let mut st = SwarmState::init(&params, &stream);
        // Force extreme velocity to exercise the clamp.
        st.vel[0] = 1e9;
        let g = vec![params.max_pos];
        update_particle(&mut st, 0, &g, &params, &stream, 0);
        assert!(st.vel[0] <= params.max_v && st.vel[0] >= -params.max_v);
        assert!(st.pos[0] <= params.max_pos && st.pos[0] >= params.min_pos);
    }

    #[test]
    fn eval_updates_pbest_only_on_improvement() {
        let params = PsoParams::paper_1d(2, 10);
        let stream = PhiloxStream::new(2);
        let mut st = SwarmState::init(&params, &stream);
        st.pos[0] = 100.0; // cubic max on the domain
        let f = eval_and_pbest(&mut st, 0, &Cubic, Objective::Maximize);
        assert_eq!(f, Cubic.eval(&[100.0]));
        assert_eq!(st.pbest_fit[0], f);
        assert_eq!(st.pbest_pos[0], 100.0);
        // Now a worse position must not disturb pbest.
        st.pos[0] = 0.0;
        eval_and_pbest(&mut st, 0, &Cubic, Objective::Maximize);
        assert_eq!(st.pbest_fit[0], f);
        assert_eq!(st.pbest_pos[0], 100.0);
    }
}
