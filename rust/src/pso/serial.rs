//! Algorithm 1, verbatim — the paper's serial "CPU" implementation.
//!
//! Note the in-loop global-best update (lines 17–19): particle `i+1`
//! already sees a gbest improved by particle `i` *within the same
//! iteration*. This asynchronous-within-sweep behaviour is the classic
//! sequential SPSO; the parallel engines are synchronous instead
//! (see [`super::serial_sync`]), exactly as in the paper.
//!
//! [`SerialRun`] is the step-wise form ([`crate::engine::Run`]): one
//! `step()` = one full sweep over the swarm. [`run`] drives it to
//! exhaustion, so the one-shot and step-wise paths are the same code.

use super::{eval_and_pbest, history_stride, update_particle, PsoParams, RunOutput, SwarmState};
use crate::checkpoint::{RunCheckpoint, RunKind, VERSION};
use crate::engine::{restore_guard, Run, StepReport};
use crate::fitness::{Fitness, Objective};
use crate::rng::PhiloxStream;
use anyhow::Result;

/// Run the sequential SPSO (Algorithm 1).
pub fn run(
    params: &PsoParams,
    fitness: &dyn Fitness,
    objective: Objective,
    seed: u64,
) -> RunOutput {
    let mut r = Box::new(SerialRun::new(params, fitness, objective, seed));
    while !r.step().done {}
    r.finish()
}

/// A prepared serial run: swarm state plus the in-loop global best.
pub struct SerialRun<'a> {
    params: PsoParams,
    fitness: &'a dyn Fitness,
    objective: Objective,
    seed: u64,
    stream: PhiloxStream,
    state: SwarmState,
    gbest_fit: f64,
    gbest_pos: Vec<f64>,
    counters: super::Counters,
    stride: u64,
    history: Vec<(u64, f64)>,
    iter: u64,
}

impl<'a> SerialRun<'a> {
    /// Step-1 initialization: seed the swarm, fitness, pbest and the
    /// initial global best (Algorithm 1 lines 1–6).
    pub fn new(
        params: &PsoParams,
        fitness: &'a dyn Fitness,
        objective: Objective,
        seed: u64,
    ) -> Self {
        let stream = PhiloxStream::new(seed);
        let mut state = SwarmState::init(params, &stream);
        let (gbest_fit, gi) = state.seed_fitness(fitness, objective);
        let gbest_pos = state.position_of(gi);
        Self {
            params: params.clone(),
            fitness,
            objective,
            seed,
            stream,
            state,
            gbest_fit,
            gbest_pos,
            counters: super::Counters::default(),
            stride: history_stride(params.max_iter),
            history: Vec::with_capacity(super::history_capacity(params.max_iter)),
            iter: 0,
        }
    }

    /// Rebuild a suspended serial run from its checkpoint — bit-exact:
    /// the counter-based RNG plus the restored swarm/gbest/counters make
    /// the continuation identical to the uninterrupted run.
    pub fn restore(ckpt: &RunCheckpoint, fitness: &'a dyn Fitness) -> Result<Self> {
        restore_guard(ckpt, RunKind::SerialCpu)?;
        let mut history = ckpt.history.clone();
        history
            .reserve(super::history_capacity(ckpt.params.max_iter).saturating_sub(history.len()));
        Ok(Self {
            params: ckpt.params.clone(),
            fitness,
            objective: ckpt.objective,
            seed: ckpt.seed,
            stream: PhiloxStream::new(ckpt.seed),
            state: ckpt.swarm.clone(),
            gbest_fit: ckpt.gbest_fit,
            gbest_pos: ckpt.gbest_pos.clone(),
            counters: ckpt.counters.clone(),
            stride: history_stride(ckpt.params.max_iter),
            history,
            iter: ckpt.iter,
        })
    }
}

impl Run for SerialRun<'_> {
    fn iters_done(&self) -> u64 {
        self.iter
    }

    fn max_iter(&self) -> u64 {
        self.params.max_iter
    }

    fn gbest_fit(&self) -> f64 {
        self.gbest_fit
    }

    fn gbest_pos(&self) -> Vec<f64> {
        self.gbest_pos.clone()
    }

    fn step(&mut self) -> StepReport {
        if self.iter >= self.params.max_iter {
            return StepReport {
                iter: self.iter,
                gbest_fit: self.gbest_fit,
                gbest_pos: None,
                improved: false,
                done: true,
            };
        }
        let iter = self.iter;
        let updates_before = self.counters.gbest_updates;
        // Steps 2–5 for every particle (one sweep).
        for i in 0..self.params.n {
            // Step 2: velocity + position (Eq. 1, Eq. 2, clamps).
            update_particle(
                &mut self.state,
                i,
                &self.gbest_pos,
                &self.params,
                &self.stream,
                iter,
            );
            // Step 3 + 4: fitness, local best.
            let before = self.state.pbest_fit[i];
            let fit = eval_and_pbest(&mut self.state, i, self.fitness, self.objective);
            self.counters.particle_updates += 1;
            if self.objective.better(fit, before) {
                self.counters.pbest_improvements += 1;
            }
            // Step 5: global best — *inside* the particle loop.
            if self.objective.better(self.state.pbest_fit[i], self.gbest_fit) {
                self.gbest_fit = self.state.pbest_fit[i];
                self.state.pbest_into(i, &mut self.gbest_pos);
                self.counters.gbest_updates += 1;
            }
        }
        self.iter += 1;
        if iter % self.stride == 0 {
            self.history.push((iter, self.gbest_fit));
        }
        let improved = self.counters.gbest_updates > updates_before;
        StepReport {
            iter: self.iter,
            gbest_fit: self.gbest_fit,
            gbest_pos: improved.then(|| self.gbest_pos.clone()),
            improved,
            done: self.iter >= self.params.max_iter,
        }
    }

    fn finish(self: Box<Self>) -> RunOutput {
        let this = *self;
        let SerialRun {
            gbest_fit,
            gbest_pos,
            counters,
            mut history,
            iter,
            ..
        } = this;
        history.push((iter, gbest_fit));
        RunOutput {
            gbest_fit,
            gbest_pos,
            iters: iter,
            history,
            counters,
        }
    }

    fn checkpoint(&self) -> RunCheckpoint {
        RunCheckpoint {
            version: VERSION,
            kind: RunKind::SerialCpu,
            objective: self.objective,
            seed: self.seed,
            params: self.params.clone(),
            iter: self.iter,
            gbest_fit: self.gbest_fit,
            gbest_pos: self.gbest_pos.clone(),
            history: self.history.clone(),
            counters: self.counters.clone(),
            swarm: self.state.clone(),
        }
    }

    fn into_checkpoint(self: Box<Self>) -> RunCheckpoint {
        // Suspension path: swarm, gbest position and history are MOVED,
        // never deep-copied (rust/tests/zero_alloc.rs pins this).
        let this = *self;
        RunCheckpoint {
            version: VERSION,
            kind: RunKind::SerialCpu,
            objective: this.objective,
            seed: this.seed,
            iter: this.iter,
            gbest_fit: this.gbest_fit,
            gbest_pos: this.gbest_pos,
            history: this.history,
            counters: this.counters,
            params: this.params,
            swarm: this.state,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fitness::{Cubic, Sphere};

    #[test]
    fn converges_on_cubic_1d() {
        let params = PsoParams::paper_1d(128, 200);
        let out = run(&params, &Cubic, Objective::Maximize, 1);
        // Optimum is 900_000 at x = 100; PSO should get very close in
        // 200 iterations with 128 particles on a 1-D problem.
        assert!(
            out.gbest_fit > 899_000.0,
            "gbest {} too far from 900000",
            out.gbest_fit
        );
        assert!((out.gbest_pos[0] - 100.0).abs() < 1.0);
    }

    #[test]
    fn converges_on_sphere_minimization() {
        let params = PsoParams::for_fitness(&Sphere, 64, 3, 300, 0.5);
        let out = run(&params, &Sphere, Objective::Minimize, 7);
        assert!(out.gbest_fit < 1.0, "gbest {}", out.gbest_fit);
    }

    #[test]
    fn gbest_history_is_monotone() {
        let params = PsoParams::paper_120d(32, 100);
        let out = run(&params, &Cubic, Objective::Maximize, 3);
        for w in out.history.windows(2) {
            assert!(
                w[1].1 >= w[0].1,
                "gbest worsened: {:?} -> {:?}",
                w[0],
                w[1]
            );
        }
    }

    #[test]
    fn deterministic_per_seed() {
        // 120-D so the boundary optimum is not reached instantly (1-D
        // Cubic clamps a particle to x=100 within an iteration or two,
        // making any two seeds coincide at exactly 900000).
        let params = PsoParams::paper_120d(16, 30);
        let a = run(&params, &Cubic, Objective::Maximize, 11);
        let b = run(&params, &Cubic, Objective::Maximize, 11);
        assert_eq!(a.gbest_fit, b.gbest_fit);
        assert_eq!(a.gbest_pos, b.gbest_pos);
        assert_eq!(a.history, b.history);
        let c = run(&params, &Cubic, Objective::Maximize, 12);
        assert_ne!(a.history, c.history);
    }

    #[test]
    fn counters_are_consistent() {
        let params = PsoParams::paper_1d(32, 20);
        let out = run(&params, &Cubic, Objective::Maximize, 5);
        assert_eq!(out.counters.particle_updates, 32 * 20);
        assert!(out.counters.gbest_updates <= out.counters.pbest_improvements);
    }

    #[test]
    fn stepwise_pauses_and_resumes_exactly() {
        // Driving SerialRun step by step equals the one-shot run.
        let params = PsoParams::paper_120d(16, 25);
        let one_shot = run(&params, &Cubic, Objective::Maximize, 4);
        let mut r = Box::new(SerialRun::new(&params, &Cubic, Objective::Maximize, 4));
        for expected in 1..=25u64 {
            let rep = r.step();
            assert_eq!(rep.iter, expected);
        }
        assert!(r.step().done);
        let out = r.finish();
        assert_eq!(out.gbest_fit, one_shot.gbest_fit);
        assert_eq!(out.gbest_pos, one_shot.gbest_pos);
        assert_eq!(out.history, one_shot.history);
    }
}
