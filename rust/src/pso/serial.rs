//! Algorithm 1, verbatim — the paper's serial "CPU" implementation.
//!
//! Note the in-loop global-best update (lines 17–19): particle `i+1`
//! already sees a gbest improved by particle `i` *within the same
//! iteration*. This asynchronous-within-sweep behaviour is the classic
//! sequential SPSO; the parallel engines are synchronous instead
//! (see [`super::serial_sync`]), exactly as in the paper.

use super::{eval_and_pbest, history_stride, update_particle, PsoParams, RunOutput, SwarmState};
use crate::fitness::{Fitness, Objective};
use crate::rng::PhiloxStream;

/// Run the sequential SPSO (Algorithm 1).
pub fn run(
    params: &PsoParams,
    fitness: &dyn Fitness,
    objective: Objective,
    seed: u64,
) -> RunOutput {
    let stream = PhiloxStream::new(seed);
    let mut state = SwarmState::init(params, &stream);

    // Step 1 tail: seed fitness/pbest and the initial global best.
    let (mut gbest_fit, gi) = state.seed_fitness(fitness, objective);
    let mut gbest_pos = state.position_of(gi);

    let stride = history_stride(params.max_iter);
    let mut history = Vec::with_capacity(super::HISTORY_SAMPLES as usize + 1);
    let mut counters = super::Counters::default();

    // Steps 2–5.
    for iter in 0..params.max_iter {
        for i in 0..params.n {
            // Step 2: velocity + position (Eq. 1, Eq. 2, clamps).
            update_particle(&mut state, i, &gbest_pos, params, &stream, iter);
            // Step 3 + 4: fitness, local best.
            let before = state.pbest_fit[i];
            let fit = eval_and_pbest(&mut state, i, fitness, objective);
            counters.particle_updates += 1;
            if objective.better(fit, before) {
                counters.pbest_improvements += 1;
            }
            // Step 5: global best — *inside* the particle loop.
            if objective.better(state.pbest_fit[i], gbest_fit) {
                gbest_fit = state.pbest_fit[i];
                gbest_pos = state.pbest_of(i);
                counters.gbest_updates += 1;
            }
        }
        if iter % stride == 0 {
            history.push((iter, gbest_fit));
        }
    }
    history.push((params.max_iter, gbest_fit));

    RunOutput {
        gbest_fit,
        gbest_pos,
        iters: params.max_iter,
        history,
        counters,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fitness::{Cubic, Sphere};

    #[test]
    fn converges_on_cubic_1d() {
        let params = PsoParams::paper_1d(128, 200);
        let out = run(&params, &Cubic, Objective::Maximize, 1);
        // Optimum is 900_000 at x = 100; PSO should get very close in
        // 200 iterations with 128 particles on a 1-D problem.
        assert!(
            out.gbest_fit > 899_000.0,
            "gbest {} too far from 900000",
            out.gbest_fit
        );
        assert!((out.gbest_pos[0] - 100.0).abs() < 1.0);
    }

    #[test]
    fn converges_on_sphere_minimization() {
        let params = PsoParams::for_fitness(&Sphere, 64, 3, 300, 0.5);
        let out = run(&params, &Sphere, Objective::Minimize, 7);
        assert!(out.gbest_fit < 1.0, "gbest {}", out.gbest_fit);
    }

    #[test]
    fn gbest_history_is_monotone() {
        let params = PsoParams::paper_120d(32, 100);
        let out = run(&params, &Cubic, Objective::Maximize, 3);
        for w in out.history.windows(2) {
            assert!(
                w[1].1 >= w[0].1,
                "gbest worsened: {:?} -> {:?}",
                w[0],
                w[1]
            );
        }
    }

    #[test]
    fn deterministic_per_seed() {
        // 120-D so the boundary optimum is not reached instantly (1-D
        // Cubic clamps a particle to x=100 within an iteration or two,
        // making any two seeds coincide at exactly 900000).
        let params = PsoParams::paper_120d(16, 30);
        let a = run(&params, &Cubic, Objective::Maximize, 11);
        let b = run(&params, &Cubic, Objective::Maximize, 11);
        assert_eq!(a.gbest_fit, b.gbest_fit);
        assert_eq!(a.gbest_pos, b.gbest_pos);
        assert_eq!(a.history, b.history);
        let c = run(&params, &Cubic, Objective::Maximize, 12);
        assert_ne!(a.history, c.history);
    }

    #[test]
    fn counters_are_consistent() {
        let params = PsoParams::paper_1d(32, 20);
        let out = run(&params, &Cubic, Objective::Maximize, 5);
        assert_eq!(out.counters.particle_updates, 32 * 20);
        assert!(out.counters.gbest_updates <= out.counters.pbest_improvements);
    }
}
